"""Golden-schema contract: every bench.json record and every trace JSONL
line must validate against the committed ``benchmarks/bench_schema.json``.
A suite that adds/renames a column without updating the schema fails here
— BEFORE the perf gate ever diffs a silently-reshaped record."""
import json
import pathlib

import numpy as np
import pytest

from repro.core import csr_from_dense, plan_and_convert
from repro.perf.schema import load_schema, validate
from repro.perf.trace import TraceRecorder

ROOT = pathlib.Path(__file__).resolve().parents[1]
SCHEMA = load_schema(ROOT / "benchmarks" / "bench_schema.json")

BENCH_REF = {"$ref": "#/definitions/bench_file"}
TRACE_REF = {"$ref": "#/definitions/trace_file"}


def test_committed_baseline_validates():
    with open(ROOT / "benchmarks" / "results" / "BENCH_010.json") as f:
        recs = json.load(f)
    assert recs
    assert validate(recs, BENCH_REF, SCHEMA) == []


def test_current_bench_json_validates_when_present():
    path = ROOT / "benchmarks" / "results" / "bench.json"
    if not path.exists():
        pytest.skip("no bench.json in this checkout (benchmarks not run)")
    with open(path) as f:
        recs = json.load(f)
    assert validate(recs, BENCH_REF, SCHEMA) == []


def test_schema_rejects_missing_required_column():
    with open(ROOT / "benchmarks" / "results" / "BENCH_010.json") as f:
        recs = json.load(f)
    rec = dict(next(r for r in recs if r.get("suite") == "batched"))
    del rec["grid_steps_native"]
    assert validate([rec], BENCH_REF, SCHEMA)


def test_schema_rejects_wrong_types():
    rec = {"suite": "fig4", "matrix": "m6", "dtype": "fp32",
           "panel_g": "eight", "nnz": 10, "us_per_call": 1.0,
           "gflops": 1.0, "vs_taco": 1.0, "vs_dense": 1.0}
    assert validate([rec], BENCH_REF, SCHEMA)


def test_skip_record_validates():
    rec = {"suite": "compress_bytes", "skipped": True,
           "reason": "needs 16 devices"}
    assert validate([rec], BENCH_REF, SCHEMA) == []
    # skipped must be literally true
    assert validate([{**rec, "skipped": False}], BENCH_REF, SCHEMA)


def test_live_trace_records_validate(rng):
    a = ((rng.random((48, 32)) < 0.1)
         * rng.standard_normal((48, 32))).astype(np.float32)
    csr = csr_from_dense(a)
    _, plan = plan_and_convert(csr, total_workers=4)

    rec = TraceRecorder(source="schema-test")
    rec.record_spmm(csr, plan, wall_s=1e-4, n_cols=8, backend="jnp")
    rec.record_spmm(csr, plan, wall_s=2e-4, n_cols=8, backend="interpret",
                    kind="search_trial")
    rec.record("step", part="step", op="train_step", step=0, wall_us=12.5)
    rec.record("dispatch", part="csr", op="spmm", backend="jnp", impl="ref",
               units=csr.nnz, batch=1, n=8)
    assert validate(rec.records, TRACE_REF, SCHEMA) == []


def test_trace_schema_rejects_unstamped_record():
    rec = {"kind": "step", "source": "x", "part": "step", "op": "decode",
           "step": 0, "wall_us": 1.0}   # no schema stamp
    assert validate([rec], TRACE_REF, SCHEMA)


OBS_REF = {"$ref": "#/definitions/obs_file"}


def test_live_obs_records_validate(rng, tmp_path):
    """A real Obs capture — spans, every instrument kind, engine counters —
    must validate line-for-line against the obs_file golden schema."""
    import jax.numpy as jnp

    from repro.core import loops_spmm
    from repro.obs import Obs, load_obs

    a = ((rng.random((48, 32)) < 0.1)
         * rng.standard_normal((48, 32))).astype(np.float32)
    fmt, _ = plan_and_convert(csr_from_dense(a), total_workers=4)
    obs = Obs(source="schema-test")
    with obs.attach_engine():
        with obs.span("outer"):
            with obs.span("inner", k=1):
                loops_spmm(fmt, jnp.ones((32, 8), jnp.float32),
                           backend="jnp")
    obs.histogram("serve.decode_token_us").observe(42.0)
    obs.gauge("serve.tokens_per_s").set(3.5)
    jsonl, _ = obs.save(tmp_path, stem="schema-test")
    recs = load_obs(jsonl)
    assert {r["kind"] for r in recs} == {"meta", "span", "counter", "gauge",
                                         "hist"}
    assert validate(recs, OBS_REF, SCHEMA) == []


def test_obs_schema_rejects_malformed_records():
    # missing the labels object
    bad = {"schema": 1, "kind": "counter", "source": "x",
           "metric": "engine.dispatch", "value": 1.0}
    assert validate([bad], OBS_REF, SCHEMA)
    # negative counter value
    bad2 = {"schema": 1, "kind": "counter", "source": "x",
            "metric": "c", "labels": {}, "value": -1.0}
    assert validate([bad2], OBS_REF, SCHEMA)
    # hist bucket counts must be integers
    bad3 = {"schema": 1, "kind": "hist", "source": "x", "metric": "h",
            "labels": {}, "count": 1, "sum": 1.0, "mean": 1.0, "min": 1.0,
            "max": 1.0, "p50": 1.0, "p90": 1.0, "p99": 1.0,
            "buckets": [1.0], "counts": [0.5, 0.5]}
    assert validate([bad3], OBS_REF, SCHEMA)


def test_autotune_cache_record_validates():
    rec = {"suite": "autotune", "matrix": "cache", "hits": 7,
           "near_hits": 1, "misses": 6, "hit_rate": 0.57, "stored": 7,
           "tuned_vs_model_geomean": 1.42}
    assert validate([rec], BENCH_REF, SCHEMA) == []
    assert validate([{**rec, "hits": -1}], BENCH_REF, SCHEMA)


def test_trace_dispatch_accepts_optional_steps():
    rec = {"schema": 1, "kind": "dispatch", "source": "x", "part": "csr",
           "op": "spmm", "backend": "jnp", "impl": "ref", "units": 10,
           "batch": 1, "n": 8, "steps": 10}
    assert validate([rec], TRACE_REF, SCHEMA) == []
    assert validate([{**rec, "steps": -1}], TRACE_REF, SCHEMA)
