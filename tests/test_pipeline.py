"""Pipelined panel kernels: depth/macro parity, grid-step accounting,
packed half-precision, knob plumbing, and the default_bn regression.

The contracts under test (docs/architecture.md §"Pipelined panels"):

  * ``pipeline_depth ∈ {1, 2}`` NEVER changes results — unbatched results
    are *bitwise* identical across depths (the piped compute stream replays
    the depth-1 expression from scratch); batched results agree to ~1 ulp
    (XLA contracts multiply-adds differently across the two graphs);
  * ``macro_m`` panelizes at the effective width ``panel_g·macro_m`` and
    agrees with the oracle to dtype tolerance;
  * grid steps = ``(panels_at_g_eff + depth - 1) × col_blocks`` per
    non-empty part, and ``perf.replay.predict_part_steps`` replicates the
    conversion exactly;
  * ``default_bn`` picks the largest lane-aligned divisor ≤ 512 (the
    ``N=600`` ValueError regression);
  * plans round-trip the knobs through the v4 tuner cache, and dispatch
    notes carry ``scratch_bytes``/``prefetch_overlap`` into obs gauges.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr_from_dense, loops_from_csr, loops_spmm
from repro.core.spmm import SpmmPlan, loops_grid_steps, plan_and_convert
from repro.kernels.panel_common import default_bn
from repro.perf.replay import predict_part_steps

try:
    from hypothesis import given
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # minimal env: property test skipped below
    HAVE_HYPOTHESIS = False

DTYPES = [(jnp.float32, 1e-5), (jnp.bfloat16, 3e-2)]
M, K, N = 21, 17, 16         # awkward: not multiples of br/g/panel widths


def _sparse(rng, m, k, density, dtype):
    a = ((rng.random((m, k)) < density) * rng.standard_normal((m, k)))
    return np.asarray(jnp.asarray(a, dtype))


def _fmt(csr, g, depth, macro, r_frac=0.5, br=4):
    r_b = min(max(int(r_frac * csr.nrows) // br * br, 0), csr.nrows)
    return loops_from_csr(csr, r_b, br, panel_g=g, pipeline_depth=depth,
                          macro_m=macro)


# -- forward parity vs oracle: dtypes x G x depth x macro -------------------

@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("g", [1, 8])
@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("macro", [1, 4])
def test_piped_fused_path_matches_oracle(rng, dtype, tol, g, depth, macro):
    """The fused single-pass engine path (input_output_aliases carry) under
    every knob combination must agree with the dense oracle."""
    a = _sparse(rng, M, K, 0.3, dtype)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype)
    fmt = _fmt(csr_from_dense(a), g, depth, macro)
    got = loops_spmm(fmt, b, backend="interpret")
    want = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=10 * tol, atol=10 * tol)


def test_fp64_piped_matches_oracle(rng):
    jax.config.update("jax_enable_x64", True)
    try:
        a = _sparse(rng, M, K, 0.3, jnp.float64)
        b = jnp.asarray(rng.standard_normal((K, N)), jnp.float64)
        for g in (1, 8):
            fmt = _fmt(csr_from_dense(a), g, 2, 4)
            got = loops_spmm(fmt, b, backend="interpret")
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(a) @ np.asarray(b),
                                       rtol=1e-12)
    finally:
        jax.config.update("jax_enable_x64", False)


# -- the depth contract: bitwise unbatched, ~ulp batched --------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("g", [1, 4, 8])
@pytest.mark.parametrize("macro", [1, 4])
def test_depth_is_bitwise_invariant_unbatched(rng, dtype, g, macro):
    """pipeline_depth=2 must be EXACTLY depth-1, bit for bit (unbatched):
    the piped kernels stage raw B rows + the mask panel and replay the
    depth-1 expression, so the float graphs are identical."""
    a = _sparse(rng, M, K, 0.3, dtype)
    csr = csr_from_dense(a)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype)
    y1 = loops_spmm(_fmt(csr, g, 1, macro), b, backend="interpret")
    y2 = loops_spmm(_fmt(csr, g, 2, macro), b, backend="interpret")
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_depth_parity_batched(rng):
    """Batched (rank-3) depth parity: allclose, not bitwise — XLA contracts
    the multiply-adds of the two graphs differently at bz > 1."""
    a = _sparse(rng, M, K, 0.3, jnp.float32)
    csr = csr_from_dense(a)
    b3 = jnp.asarray(rng.standard_normal((4, K, N)).astype(np.float32))
    y1 = loops_spmm(_fmt(csr, 4, 1, 1), b3, backend="interpret")
    y2 = loops_spmm(_fmt(csr, 4, 2, 1), b3, backend="interpret")
    assert y1.shape == (4, M, N)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-6, atol=1e-6)


def test_depth_parity_row_boundary_tails(rng):
    """Row-boundary tails: a conversion whose last CSR panel and last BCSR
    block-row are both partial must stay depth-invariant."""
    a = _sparse(rng, 23, 19, 0.4, jnp.float32)
    csr = csr_from_dense(a)
    b = jnp.asarray(rng.standard_normal((19, 8)).astype(np.float32))
    for r_b in (4, 20):     # tails in both parts
        y1 = loops_spmm(loops_from_csr(csr, r_b, 8, panel_g=4), b,
                        backend="interpret")
        y2 = loops_spmm(loops_from_csr(csr, r_b, 8, panel_g=4,
                                       pipeline_depth=2), b,
                        backend="interpret")
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_depth_parity_gradients(rng):
    """The SDD backward pipeline (depth-2 column-block reduction) must
    produce the same gradients as the serial path."""
    a = _sparse(rng, M, K, 0.3, jnp.float32)
    csr = csr_from_dense(a)
    b = jnp.asarray(rng.standard_normal((K, N)).astype(np.float32))

    def loss(fmt):
        return jax.grad(lambda bb: jnp.sum(
            loops_spmm(fmt, bb, backend="interpret")))(b)

    g1 = loss(_fmt(csr, 4, 1, 1))
    g2 = loss(_fmt(csr, 4, 2, 1))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-6, atol=1e-6)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**31 - 1),
           g=st.sampled_from([1, 4, 8]),
           depth=st.sampled_from([1, 2]),
           macro=st.sampled_from([1, 2, 4]),
           density=st.floats(0.05, 0.6))
    def test_knobs_never_change_results_property(seed, g, depth, macro,
                                                 density):
        """Property: for ANY seeded matrix, (depth, macro) only reshape the
        schedule — the result still matches the knob-less execution to
        float32 tolerance, and depth alone is bitwise-invariant."""
        rng = np.random.default_rng(seed)
        a = _sparse(rng, 12, 10, density, jnp.float32)
        csr = csr_from_dense(a)
        b = jnp.asarray(rng.standard_normal((10, 8)).astype(np.float32))
        base = loops_spmm(_fmt(csr, g, 1, 1), b, backend="interpret")
        knobbed = loops_spmm(_fmt(csr, g, depth, macro), b,
                             backend="interpret")
        np.testing.assert_allclose(np.asarray(base), np.asarray(knobbed),
                                   rtol=1e-5, atol=1e-5)
        if macro == 1:
            np.testing.assert_array_equal(
                np.asarray(base), np.asarray(knobbed))
else:
    def test_knobs_never_change_results_property():
        pytest.skip("hypothesis not installed")


# -- default_bn: the N=600 regression --------------------------------------

def test_default_bn_units():
    assert default_bn(600) == 200       # largest lane-aligned divisor <= 512
    assert default_bn(1024) == 512
    assert default_bn(512) == 512
    assert default_bn(32) == 32         # n <= 512: whole operand, one block
    assert default_bn(1) == 1
    for n in (600, 1000, 1536, 700):
        bn = default_bn(n)
        assert n % bn == 0 and bn <= 512


def test_wide_operand_n600_regression(rng):
    """N=600 used to raise (600 % min(600, 512) != 0); default_bn now picks
    a clean divisor and the kernels execute end to end."""
    a = _sparse(rng, 16, 12, 0.3, jnp.float32)
    csr = csr_from_dense(a)
    b = jnp.asarray(rng.standard_normal((12, 600)).astype(np.float32))
    got = loops_spmm(_fmt(csr, 4, 2, 4), b, backend="interpret")
    want = np.asarray(a) @ np.asarray(b)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)


# -- grid-step accounting ---------------------------------------------------

def test_grid_steps_ramp_and_macro(rng):
    """Steps = (panels_at_g_eff + depth - 1) x col_blocks per non-empty
    part; macro_m shrinks the panel count, depth adds the ramp."""
    a = _sparse(rng, 24, 20, 0.4, jnp.float32)
    csr = csr_from_dense(a)
    base = loops_grid_steps(_fmt(csr, 4, 1, 1), 16)
    fused = loops_grid_steps(_fmt(csr, 4, 1, 4), 16)
    piped = loops_grid_steps(_fmt(csr, 4, 2, 1), 16)
    assert fused < base                  # macro fusion shrinks the grid
    assert piped == base + 2             # one ramp step per non-empty part


@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("macro", [1, 4])
@pytest.mark.parametrize("n_cols", [16, 600])
def test_predict_part_steps_matches_conversion(rng, depth, macro, n_cols):
    """perf.replay's structural predictor must replicate the conversion's
    grid-step count exactly for every knob combination."""
    a = _sparse(rng, 32, 24, 0.25, jnp.float32)
    csr = csr_from_dense(a)
    for r_frac in (0.0, 0.5, 1.0):
        r_b = min(max(int(r_frac * 32) // 4 * 4, 0), 32)
        plan = SpmmPlan(r_boundary=r_b, t_vpu=2, t_mxu=2, br=4, panel_g=4,
                        pipeline_depth=depth, macro_m=macro)
        fmt = loops_from_csr(csr, r_b, 4, panel_g=4, pipeline_depth=depth,
                             macro_m=macro)
        s_csr, s_bcsr = predict_part_steps(csr, plan, n_cols)
        assert s_csr + s_bcsr == loops_grid_steps(fmt, n_cols)


# -- knob plumbing: plan/convert, tuner cache v4, dispatch notes ------------

def test_plan_and_convert_threads_knobs(rng):
    a = _sparse(rng, 24, 20, 0.3, jnp.float32)
    fmt, plan = plan_and_convert(csr_from_dense(a), total_workers=4,
                                 pipeline_depth=2, macro_m=4)
    assert plan.pipeline_depth == 2 and plan.macro_m == 4
    assert fmt.pipeline_depth == 2 and fmt.macro_m == 4
    assert fmt.panel_g_eff == max(fmt.panel_g, 1) * 4


def test_cache_v4_roundtrip_and_v3_miss(tmp_path, rng):
    """Records round-trip the knobs; a v3 (knob-less) cache file misses
    cleanly under CACHE_VERSION 4."""
    import json

    from repro.tune.api import make_record, plan_from_record
    from repro.tune.cache import CACHE_VERSION, PlanCache

    assert CACHE_VERSION == 4
    rec = make_record([1.0], dtype=np.float32, n_cols=32, backend="jnp",
                      r_frac=0.5, t_vpu=2, t_mxu=2, br=4, panel_g=8,
                      pipeline_depth=2, macro_m=4)
    plan = plan_from_record(rec, 48)
    assert plan.pipeline_depth == 2 and plan.macro_m == 4
    # knob-less records (a near-hit from an old neighbour) default to serial
    legacy = {"plan": {"r_frac": 0.5, "t_vpu": 2, "t_mxu": 2, "br": 4}}
    p0 = plan_from_record(legacy, 48)
    assert p0.pipeline_depth == 1 and p0.macro_m == 1

    stale = tmp_path / "stale"
    stale.mkdir()
    (stale / "plans.json").write_text(json.dumps(
        {"version": 3, "entries": {"k": {"version": 3}}}))
    cache = PlanCache(path=str(stale))
    assert len(cache) == 0 and cache.lookup("k") is None


def test_search_space_has_pipeline_axes(rng):
    from repro.tune.search import enumerate_plans
    a = _sparse(rng, 24, 20, 0.3, jnp.float32)
    plans = enumerate_plans(csr_from_dense(a), total_workers=4)
    assert {p.pipeline_depth for p in plans} == {1, 2}
    assert {p.macro_m for p in plans} == {1, 4}


def test_obs_gauges_scratch_and_overlap(rng):
    """Dispatch notes surface scratch bytes + prefetch overlap as gauges."""
    from repro.obs import Obs
    a = _sparse(rng, 24, 20, 0.3, jnp.float32)
    fmt = _fmt(csr_from_dense(a), 4, 2, 2)
    obs = Obs(source="pipeline-test")
    with obs.attach_engine():
        loops_spmm(fmt, jnp.ones((20, 16), jnp.float32),
                   backend="interpret")
    recs = obs.records()
    sb = [r for r in recs if r.get("metric") == "kernel.scratch_bytes"]
    ov = [r for r in recs if r.get("metric") == "engine.prefetch_overlap"]
    assert sb and all(r["value"] > 0 for r in sb)
    assert ov and any(r["value"] > 0 for r in ov)   # depth 2 => overlap
    # serial execution reports zero overlap
    obs2 = Obs(source="pipeline-test-serial")
    fmt1 = _fmt(csr_from_dense(a), 4, 1, 1)
    with obs2.attach_engine():
        loops_spmm(fmt1, jnp.ones((20, 16), jnp.float32),
                   backend="interpret")
    ov1 = [r for r in obs2.records()
           if r.get("metric") == "engine.prefetch_overlap"]
    assert ov1 and all(r["value"] == 0.0 for r in ov1)


def test_packed_halfprec_scratch_and_accumulate(rng):
    """bf16 B panels stay packed (b.dtype scratch) with fp32 accumulation:
    the bf16 result must match the fp32-upcast oracle to bf16 tolerance,
    and the scratch note must reflect the packed (2-byte) element size."""
    from repro.kernels.engine import _panel_note_fields
    a = _sparse(rng, M, K, 0.3, jnp.bfloat16)
    b16 = jnp.asarray(rng.standard_normal((K, N)), jnp.bfloat16)
    fmt = _fmt(csr_from_dense(a), 4, 2, 1)
    got = loops_spmm(fmt, b16, backend="interpret")
    want = np.asarray(a, np.float32) @ np.asarray(b16, np.float32)
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=3e-2, atol=3e-2)
    packed = _panel_note_fields(part="csr", depth=2, npanels=8, nb=1, n=N,
                                bn=None, g=4, br=1,
                                b_dtype=jnp.bfloat16,
                                value_dtype=jnp.bfloat16)
    wide = _panel_note_fields(part="csr", depth=2, npanels=8, nb=1, n=N,
                              bn=None, g=4, br=1,
                              b_dtype=jnp.float32,
                              value_dtype=jnp.float32)
    assert packed["scratch_bytes"] < wide["scratch_bytes"]
