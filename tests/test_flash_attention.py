"""Fused flash-attention Pallas kernel vs the XLA chunked oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.layers import flash_attention


def _qkv(rng, B, Sq, Sk, H, KV, hd, dtype):
    q = jnp.asarray(rng.standard_normal((B, Sq, H, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((B, Sk, KV, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((B, Sk, KV, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 1, 1, 16), (2, 128, 4, 2, 32), (1, 64, 6, 2, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_matches_xla(rng, dtype, tol, B, S, H, KV, hd, causal):
    q, k, v = _qkv(rng, B, S, S, H, KV, hd, dtype)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=32,
                                 block_k=32, interpret=True)
    want = flash_attention(q, k, v, causal=causal, q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_block_shape_invariance(rng):
    q, k, v = _qkv(rng, 1, 128, 128, 2, 2, 16, jnp.float32)
    outs = [flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                   block_k=bk, interpret=True)
            for (bq, bk) in [(32, 32), (64, 32), (128, 128)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_against_naive_softmax(rng):
    """Ground truth: full softmax(QK^T)V."""
    B, S, H, hd = 1, 64, 2, 16
    q, k, v = _qkv(rng, B, S, S, H, H, hd, jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=16,
                                 block_k=16, interpret=True)
    qf = np.asarray(q, np.float32)
    kf = np.asarray(k, np.float32)
    vf = np.asarray(v, np.float32)
    s = np.einsum("bqhd,bkhd->bhqk", qf, kf) / np.sqrt(hd)
    mask = np.tril(np.ones((S, S), bool))
    s = np.where(mask, s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    want = np.einsum("bhqk,bkhd->bqhd", p, vf)
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-5, atol=2e-5)
