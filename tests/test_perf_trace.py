"""Trace → fit → replay loop: recorder, structural step prediction, cost
fit, and the replay-accuracy acceptance bar.

The load-bearing claims:

  * :func:`repro.perf.replay.predict_part_steps` reproduces the REAL
    conversion's grid-step count exactly (per part, including empty-row
    pads, zero-valued entry dropping, and skipped parts) without paying
    Algorithm 1;
  * ``replay()`` — per-step cost fitted from measured traces × predicted
    steps — lands within 25% of measured step time for ≥ 90% of
    (matrix, plan) cells on the interpret backend (the tentpole acceptance
    criterion);
  * the fig4 smoke suite is bit-deterministic in its grid-step columns
    (what the perf gate's exact checks rely on cross-machine).
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (csr_from_coo, csr_from_dense, loops_spmm,
                        plan_and_convert)
from repro.core.formats import loops_from_csr
from repro.core.spmm import SpmmPlan, loops_grid_steps
from repro.kernels import engine
from repro.perf import (TraceDB, TraceRecorder, fit_cost_model, load_traces,
                        matrix_key, predict_grid_steps, predict_part_steps,
                        replay)
from repro.perf.trace import TRACE_SCHEMA_VERSION
from repro.tune.search import SearchBudget, search


def random_sparse(rng, m, k, density, dtype=np.float32):
    a = (rng.random((m, k)) < density) * rng.standard_normal((m, k))
    return a.astype(dtype)


def _plan(csr, r_frac, g, br=8):
    r_b = int(r_frac * csr.nrows) // br * br
    return SpmmPlan(r_boundary=r_b, t_vpu=4, t_mxu=4, br=br, panel_g=g)


# ---------------------------------------------------------------------------
# Structural prediction == real conversion
# ---------------------------------------------------------------------------

def test_predict_part_steps_matches_conversion(rng):
    n_cols = 16
    for m, k, density in [(64, 48, 0.05), (96, 40, 0.15), (48, 48, 0.4)]:
        csr = csr_from_dense(random_sparse(rng, m, k, density))
        for r_frac in (0.0, 0.3, 0.7, 1.0):
            for g in (1, 4, 8):
                plan = _plan(csr, r_frac, g)
                fmt = loops_from_csr(csr, plan.r_boundary, plan.br,
                                     panel_g=plan.panel_g)
                assert predict_grid_steps(csr, plan, n_cols) \
                    == loops_grid_steps(fmt, n_cols), \
                    f"mismatch at r_frac={r_frac} g={g} shape={(m, k)}"


def test_predict_part_steps_drops_zero_valued_entries(rng):
    # bcsr_from_csr_rows drops stored-but-zero entries; the predictor must
    # count distinct columns among nonzero-VALUED entries only.
    m = k = 48
    rows = np.repeat(np.arange(m, dtype=np.int64), 3)
    cols = np.tile(np.array([0, 7, 23], dtype=np.int64), m)
    vals = rng.standard_normal(rows.shape[0]).astype(np.float32)
    vals[::2] = 0.0   # half the stored entries are explicit zeros
    csr = csr_from_coo(rows, cols, vals, (m, k))
    for r_frac in (0.0, 0.5):
        plan = _plan(csr, r_frac, 4)
        fmt = loops_from_csr(csr, plan.r_boundary, plan.br,
                             panel_g=plan.panel_g)
        assert predict_grid_steps(csr, plan, 16) == loops_grid_steps(fmt, 16)


def test_predict_part_steps_empty_rows(rng):
    # Empty rows pad to one stored entry (CSR part) / one pad tile per
    # empty block-row (BCSR part) — both floor at one panel.
    m, k = 40, 32
    rows = np.array([0, 0, 5], dtype=np.int64)   # rows 1-4, 6-39 empty
    cols = np.array([1, 9, 2], dtype=np.int64)
    vals = np.ones(3, np.float32)
    csr = csr_from_coo(rows, cols, vals, (m, k))
    for r_frac in (0.0, 0.4, 1.0):
        plan = _plan(csr, r_frac, 8)
        fmt = loops_from_csr(csr, plan.r_boundary, plan.br,
                             panel_g=plan.panel_g)
        assert predict_grid_steps(csr, plan, 16) == loops_grid_steps(fmt, 16)


def test_predict_col_blocking():
    csr = csr_from_dense(np.eye(16, dtype=np.float32))
    plan = _plan(csr, 1.0, 1)
    s1 = predict_grid_steps(csr, plan, 16)
    # bn caps at 512, so 1024 columns = 2 column blocks
    assert predict_grid_steps(csr, plan, 1024) == 2 * s1


# ---------------------------------------------------------------------------
# Recorder: dispatch capture, save/load round-trip, versioning
# ---------------------------------------------------------------------------

def test_recorder_round_trip(rng, tmp_path):
    csr = csr_from_dense(random_sparse(rng, 48, 32, 0.1))
    fmt, plan = plan_and_convert(csr, total_workers=4)
    b = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))

    rec = TraceRecorder(source="unit")
    with rec.attach_engine():
        assert engine.get_tracer() is rec
        out = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))(b)
        jax.block_until_ready(out)
    assert engine.get_tracer() is None   # restored on exit

    dispatches = [r for r in rec.records if r["kind"] == "dispatch"]
    assert dispatches and all(r["part"] in ("csr", "bcsr")
                              for r in dispatches)
    rec.record_spmm(csr, plan, wall_s=1e-4, n_cols=8, backend="jnp")

    path = rec.save(tmp_path / "unit.jsonl")
    loaded = load_traces(path)
    assert loaded == rec.records
    assert all(r["schema"] == TRACE_SCHEMA_VERSION and r["source"] == "unit"
               for r in loaded)


def test_load_traces_rejects_future_schema(tmp_path):
    p = tmp_path / "future.jsonl"
    p.write_text(json.dumps({"schema": 99, "kind": "spmm"}) + "\n")
    with pytest.raises(ValueError, match="schema"):
        load_traces(p)


def test_record_rejects_unknown_kind():
    with pytest.raises(ValueError, match="kind"):
        TraceRecorder().record("wall_clock")


def test_wrap_step_counts_calls():
    rec = TraceRecorder(source="steps")
    f = rec.wrap_step(jax.jit(lambda x: x * 2.0), op="train_step")
    for _ in range(3):
        f(jnp.ones((4,)))
    steps = [r for r in rec.records if r["kind"] == "step"]
    assert [r["step"] for r in steps] == [0, 1, 2]
    assert all(r["op"] == "train_step" and r["wall_us"] >= 0 for r in steps)


def test_matrix_key_ignores_values(rng):
    a = random_sparse(rng, 64, 48, 0.1)
    csr1 = csr_from_dense(a)
    csr2 = csr_from_dense(np.where(a != 0, 3.5, 0.0).astype(np.float32))
    assert matrix_key(csr1) == matrix_key(csr2)


# ---------------------------------------------------------------------------
# Cost-model fit from traces
# ---------------------------------------------------------------------------

def _synth_spmm(x, y, g, gflops):
    return {"schema": 1, "kind": "spmm", "source": "synth", "t_vpu": x,
            "t_mxu": y, "panel_g": g, "gflops": gflops}


def test_fit_cost_model_recovers_surface():
    def perf(x, y):
        return 10.0 + 2.0 * x + 3.0 * y - 0.05 * x * x - 0.04 * y * y

    recs = [_synth_spmm(x, y, 1, perf(x, y))
            for x in (1, 2, 4, 6, 8) for y in (1, 3, 5)]
    model = fit_cost_model(recs, ridge=1e-9)
    assert model is not None
    assert model.calibrated_from.startswith("traces:")
    for x, y in [(3, 2), (5, 4)]:
        assert float(model.predict(x, y)) == pytest.approx(perf(x, y),
                                                           rel=0.05)


def test_fit_cost_model_underdetermined_returns_none():
    recs = [_synth_spmm(1, 1, 1, 5.0), _synth_spmm(2, 2, 1, 7.0)]
    assert fit_cost_model(recs) is None
    assert fit_cost_model([]) is None


# ---------------------------------------------------------------------------
# Replay accuracy — the tentpole acceptance criterion
# ---------------------------------------------------------------------------

def _measured_wall(f, b, repeats=5):
    """Best-of-N wall clock: timing noise (scheduler preemption, other
    suite processes) is strictly additive, so the minimum is the robust
    estimator of the true step cost — a median still drifts when the
    machine is loaded for the whole window."""
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(b))
        ts.append(time.perf_counter() - t0)
    return min(ts)


def test_replay_predicts_step_time_within_25pct(rng):
    """replay() must land within 25% of measured interpret-mode step time
    for >= 90% of (matrix, plan) cells (ISSUE 6 acceptance).

    Wall clocks are best-of-5 per pass; if the fit misses the bar the
    cells are remeasured (up to 2 extra passes, keeping the per-cell
    minimum) so one load spike during the first sweep cannot flake the
    suite — the *model* is deterministic given the walls.
    """
    n_cols = 16
    mats = [csr_from_dense(random_sparse(rng, 64, 48, 0.08)),
            csr_from_dense(random_sparse(rng, 96, 48, 0.18))]
    cells = []
    for csr in mats:
        b = jnp.asarray(rng.standard_normal((csr.shape[1], n_cols))
                        .astype(np.float32))
        for r_frac, g in [(0.25, 1), (0.25, 8), (0.75, 1), (0.75, 8),
                          (0.5, 4)]:
            plan = _plan(csr, r_frac, g)
            fmt = loops_from_csr(csr, plan.r_boundary, plan.br,
                                 panel_g=plan.panel_g)
            f = jax.jit(lambda bb, fmt=fmt: loops_spmm(
                fmt, bb, backend="interpret"))
            jax.block_until_ready(f(b))   # compile + warm
            cells.append({"csr": csr, "plan": plan, "f": f, "b": b,
                          "wall": np.inf})

    def sweep():
        for c in cells:
            c["wall"] = min(c["wall"], _measured_wall(c["f"], c["b"]))
        rec = TraceRecorder(source="replay-test")
        for c in cells:
            rec.record_spmm(c["csr"], c["plan"], wall_s=c["wall"],
                            n_cols=n_cols, backend="interpret")
        db = TraceDB(records=rec.records)
        assert db.step_cost("interpret") is not None
        errs = []
        for c in cells:
            pred = replay(c["plan"], db, csr=c["csr"], n_cols=n_cols,
                          backend="interpret")
            assert pred is not None and pred >= 0
            errs.append(abs(pred - c["wall"]) / c["wall"])
        return errs

    for _ in range(3):
        errs = sweep()
        ok = sum(e <= 0.25 for e in errs)
        if ok / len(cells) >= 0.9:
            break
    assert ok / len(cells) >= 0.9, \
        f"replay within 25% for only {ok}/{len(cells)} cells: " \
        f"{[f'{e:.2f}' for e in errs]}"


def test_replay_returns_none_without_fit(rng):
    csr = csr_from_dense(random_sparse(rng, 32, 32, 0.1))
    assert replay(_plan(csr, 0.5, 4), TraceDB(records=[]), csr=csr,
                  n_cols=8) is None


# ---------------------------------------------------------------------------
# Integration: search pruning + device-split prediction + fig4 determinism
# ---------------------------------------------------------------------------

def _db_with_step_costs():
    # wall_us = 5 + 2*s_csr + 1*s_bcsr, three distinct cells
    recs = []
    for s_csr, s_bcsr in [(10, 0), (0, 20), (15, 30)]:
        recs.append({"schema": 1, "kind": "spmm", "source": "synth",
                     "backend": "jnp", "grid_steps": s_csr + s_bcsr,
                     "grid_steps_csr": s_csr, "grid_steps_bcsr": s_bcsr,
                     "wall_us": 5.0 + 2.0 * s_csr + 1.0 * s_bcsr})
    return TraceDB(records=recs)


def test_trace_db_step_cost_fit():
    coef = _db_with_step_costs().step_cost("jnp")
    assert coef is not None
    assert coef[1] == pytest.approx(2.0, rel=0.1)
    assert coef[2] == pytest.approx(1.0, rel=0.1)


def test_search_with_trace_db_and_recorder(rng):
    csr = csr_from_dense(random_sparse(rng, 48, 32, 0.1))
    rec = TraceRecorder(source="search")
    res = search(csr, n_cols=8, total_workers=4,
                 budget=SearchBudget(top_k=2, repeats=1, warmup=0),
                 backend="jnp", trace_db=_db_with_step_costs(), recorder=rec)
    assert res.plan is not None and res.measured >= 1
    trials = [r for r in rec.records if r["kind"] == "search_trial"]
    assert len(trials) == res.measured
    assert all(r["grid_steps"] > 0 and r["panel_g"] >= 1 for r in trials)


def test_shard_loops_auto_accepts_trace_db(rng):
    from repro.core.distributed import shard_loops_auto

    csr = csr_from_dense(random_sparse(rng, 64, 48, 0.1))
    fmt, _ = plan_and_convert(csr, total_workers=8)
    # Rich db: enough distinct (t_vpu, t_mxu) knobs to fit Eq. 2.
    recs = [_synth_spmm(x, y, 1, 1.0 * x + 4.0 * y)
            for x in (1, 2, 4, 6, 8) for y in (1, 3, 5)]
    sharded = shard_loops_auto(fmt, 8, trace_db=TraceDB(records=recs))
    assert sharded.g_vpu >= 0
    # Empty db: falls back to the proportional split without error.
    sharded2 = shard_loops_auto(fmt, 8, trace_db=TraceDB(records=[]))
    assert sharded2.g_vpu >= 0


def test_fig4_smoke_grid_steps_deterministic(monkeypatch):
    """Two runs of the fig4 smoke suite must emit identical grid-step
    columns — the property the perf gate's exact checks rely on."""
    from benchmarks import fig4_throughput as f4

    monkeypatch.setattr(f4, "SMOKE_MATRICES", ["m6"])
    monkeypatch.setattr(f4, "WALL_MATRICES", 0)   # structural columns only

    exact = ("suite", "matrix", "panel_g", "nnz", "steps_g1", "steps_g8",
             "steps_tuned")

    def run_once():
        recs = []
        f4.main(out=lambda s: None, record=recs.append, smoke=True)
        return [{k: r[k] for k in exact if k in r} for r in recs]

    first, second = run_once(), run_once()
    assert first == second
    assert any("steps_g1" in r for r in first)
