"""Checkpointing: atomic save/restore round-trip, async writer, retention."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step, restore, save


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 8)),
                                        jnp.bfloat16),
                       "b": jnp.asarray(rng.standard_normal(8), jnp.float32)},
            "opt": {"m": jnp.zeros((3,), jnp.float32),
                    "count": jnp.int32(7)}}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    save(str(tmp_path), 42, tree, meta={"arch": "x"})
    step, restored, meta = restore(str(tmp_path), tree)
    assert step == 42 and meta["arch"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_latest_and_retention(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, tree, keep=3)
    assert latest_step(str(tmp_path)) == 5
    kept = [f for f in os.listdir(tmp_path) if f.startswith("ckpt_")]
    assert len(kept) == 3


def test_no_partial_files_after_save(tmp_path):
    save(str(tmp_path), 9, _tree())
    assert not [f for f in os.listdir(tmp_path) if f.startswith("tmp.")]


def test_async_checkpointer(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = _tree(1)
    for s in (10, 20):
        ck.save_async(s, tree, meta={"s": s})
    ck.close()
    assert latest_step(str(tmp_path)) == 20
    step, restored, meta = restore(str(tmp_path), tree)
    assert meta["s"] == 20


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path / "nope"), _tree())


def test_elastic_restore_shape_independent(tmp_path):
    """Checkpoint written by one 'topology' restores into another: trees are
    unsharded numpy, so only the tree structure must match."""
    tree = _tree(2)
    save(str(tmp_path), 1, tree)
    _, restored, _ = restore(str(tmp_path), tree)
    # device_put with a different sharding (simulating a different mesh)
    placed = jax.device_put(restored)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(placed)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
