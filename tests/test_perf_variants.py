"""§Perf optimization variants: every hillclimb change must be
math-preserving (same outputs as the baseline path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr_from_dense
from repro.core.formats import loops_from_csr_sorted, permute_rows
from repro.core.spmm import loops_spmm
from repro.models.layers import flash_attention, flash_attention_triangular
from repro.models.moe import moe_apply, moe_init


def test_sorted_split_is_value_preserving(rng):
    a = ((rng.random((90, 40)) < 0.12)
         * rng.standard_normal((90, 40))).astype(np.float32)
    b = jnp.asarray(rng.standard_normal((40, 8)), jnp.float32)
    fmt, order = loops_from_csr_sorted(csr_from_dense(a), 16, 8)
    out = np.asarray(loops_spmm(fmt, b, backend="jnp"))
    np.testing.assert_allclose(out, a[order] @ np.asarray(b), rtol=1e-4,
                               atol=1e-4)
    # the permutation is a bijection and inverts cleanly
    inv = np.empty_like(order)
    inv[order] = np.arange(len(order))
    np.testing.assert_allclose(out[inv], a @ np.asarray(b), rtol=1e-4,
                               atol=1e-4)
    # hubs really did move to the CSR part (sorted by nnz descending)
    counts = np.diff(csr_from_dense(a[order]).row_ptr)
    assert (np.diff(counts) <= 0).all()


def test_permute_rows_identity(rng):
    a = ((rng.random((20, 10)) < 0.3)
         * rng.standard_normal((20, 10))).astype(np.float32)
    csr = csr_from_dense(a)
    from repro.core import csr_to_dense
    same = permute_rows(csr, np.arange(20))
    assert np.array_equal(csr_to_dense(same), a)


@pytest.mark.parametrize("window", [0, 48])
def test_triangular_schedule_exact(rng, window):
    B, S, H, KV, hd = 2, 192, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    full = flash_attention(q, k, v, causal=True, window=window,
                           q_chunk=32, k_chunk=32)
    tri = flash_attention_triangular(q, k, v, causal=True, window=window,
                                     q_chunk=32, k_chunk=32)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tri), rtol=1e-5,
                               atol=1e-6)


def test_moe_gather_equals_scatter_dispatch():
    p = moe_init(jax.random.key(0), 16, 8, 6, 8, 2, jnp.float32,
                 num_shared=1, shared_d_ff=8)
    x = jax.random.normal(jax.random.key(1), (2, 12, 16))
    g = moe_apply(p, x, num_experts=6, top_k=2, dispatch="gather")
    s = moe_apply(p, x, num_experts=6, top_k=2, dispatch="scatter")
    np.testing.assert_allclose(np.asarray(g), np.asarray(s), rtol=1e-5,
                               atol=1e-5)


def test_moe_gather_grads_match_scatter():
    p = moe_init(jax.random.key(0), 8, 4, 4, 4, 2, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 8, 8))

    def loss(params, dispatch):
        return jnp.sum(moe_apply(params, x, num_experts=4, top_k=2,
                                 dispatch=dispatch) ** 2)

    gg = jax.grad(lambda q: loss(q, "gather"))(p)
    gs = jax.grad(lambda q: loss(q, "scatter"))(p)
    for a, b in zip(jax.tree.leaves(gg), jax.tree.leaves(gs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


def test_kv_aligned_rule_replicates_misaligned_heads():
    from repro.compat import abstract_mesh
    from repro.configs import REDUCED
    from repro.dist.sharding import param_specs
    from repro.launch import specs as specs_lib
    from jax.sharding import PartitionSpec as P
    # spec rules only read mesh.shape -> an AbstractMesh needs no devices
    mesh = abstract_mesh((1, 2), ("data", "model"))
    cfg = REDUCED["hymba-1.5b"]()          # 4 heads, kv=2: aligned on 2-way
    pav = specs_lib.abstract_params(cfg)
    sp = param_specs(pav, mesh, cfg)
    assert sp["layers"]["attn"]["wk"] == P(None, None, "model")
    cfg_bad = dataclasses.replace(cfg, num_kv_heads=3)  # 3 % 2 != 0
    sp = param_specs(pav, mesh, cfg_bad)
    assert sp["layers"]["attn"]["wk"] == P()            # replicated
    cfg_naive = dataclasses.replace(cfg_bad, tp_rule="naive")
    sp = param_specs(pav, mesh, cfg_naive)
    assert sp["layers"]["attn"]["wk"] == P(None, None, "model")