"""Model zoo: per-arch reduced-config smoke tests + prefill/decode
consistency (the serving path must agree with the teacher-forced forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, REDUCED
from repro.models import api, frontends


def _batch(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = frontends.vision_patches_stub(cfg, B)
    if cfg.frontend == "audio_stub":
        batch["frames"] = frontends.audio_frames_stub(cfg, B)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    """One forward/backward on the reduced config: shapes + finiteness."""
    cfg = REDUCED[arch]()
    params = api.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, B=2, S=16)

    def loss_fn(p):
        return api.train_loss(cfg, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                               for g in jax.tree.leaves(grads))))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step(token_S | prefill(tokens[:S])) must equal
    prefill(tokens[:S+1])'s last logits (same math, different path).

    MoE archs use a large capacity factor here: capacity-based token
    dropping legitimately depends on the total token count, so the
    equivalence only holds drop-free (verified exactly in that regime)."""
    import dataclasses
    cfg = REDUCED[arch]()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = api.init_params(cfg, jax.random.key(1))
    B, S = 2, 17
    full = _batch(cfg, B, S + 1, seed=3)
    prefix = dict(full)
    prefix["tokens"] = full["tokens"][:, :S]
    prefix.pop("labels")
    full2 = dict(full)
    full2.pop("labels")

    cache, _ = jax.jit(lambda p, b: api.prefill(cfg, p, b))(params, prefix)
    # headroom for ONE more token (ring caches are already final-size);
    # vlm caches also hold the patch prefix
    from repro.launch.serve import pad_cache
    pos0 = S + (cfg.num_patches if cfg.frontend == "vision_stub" else 0)
    cache = pad_cache(cfg, cache, pos0 + 1)
    _, logits_dec = jax.jit(
        lambda p, c, t: api.decode_step(cfg, p, c, t, jnp.int32(pos0)))(
        params, cache, full["tokens"][:, S:S + 1])

    _, logits_full = jax.jit(lambda p, b: api.prefill(cfg, p, b))(params,
                                                                  full2)
    got = np.asarray(logits_dec, np.float32)
    want = np.asarray(logits_full, np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_sliding_window_ring_cache_long_decode():
    """hymba: decode far past the window; ring buffer must keep only the
    window and stay finite/consistent in shape."""
    cfg = REDUCED["hymba-1.5b"]()
    params = api.init_params(cfg, jax.random.key(0))
    B = 1
    cache = api.init_cache(cfg, B, max_len=64)
    assert cache["k"].shape[2] == cfg.sliding_window  # capped
    tok = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda c, t, l: api.decode_step(cfg, params, c, t, l))
    for length in [0, 1, 15, 16, 17, 40]:
        cache, logits = step(cache, tok, jnp.int32(length))
        assert np.isfinite(np.asarray(logits)).all()


def test_rwkv_state_is_constant_size():
    cfg = REDUCED["rwkv6-3b"]()
    c1 = jax.eval_shape(lambda: api.init_cache(cfg, 1, 32))
    c2 = jax.eval_shape(lambda: api.init_cache(cfg, 1, 1 << 19))
    assert jax.tree.map(lambda a: a.shape, c1) == \
        jax.tree.map(lambda a: a.shape, c2)  # O(1) in seq -> long_500k ready


def test_vocab_padding_never_predicted_needed():
    cfg = REDUCED["llama3.2-1b"]()
    assert cfg.vocab_padded() % 256 == 0
    assert cfg.vocab_padded() >= cfg.vocab_size


def test_moe_expert_padding_inert():
    """Routing to padded experts is impossible (-inf logits) and their
    zero weights keep them inert even if numerics went wrong."""
    from repro.models.moe import moe_init, moe_apply
    rng = jax.random.key(0)
    p = moe_init(rng, d_model=16, moe_d_ff=8, num_experts=6,
                 num_experts_padded=8, top_k=2, dtype=jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16))
    out = moe_apply(p, x, num_experts=6, top_k=2)
    assert np.isfinite(np.asarray(out)).all()
    # padded expert weights are exactly zero
    assert float(jnp.abs(p["wi"][6:]).sum()) == 0.0
