"""Property-based invariants of the LOOPS format layer (hypothesis).

Skipped at collection when hypothesis is absent (tests/conftest.py adds
this module to ``collect_ignore``).  Three satellite invariants of ISSUE 6:

  * panelize/depanelize round-trip: the ``(P, G)`` panel pack and its
    gather/scatter maps are exact inverses on stored values;
  * ``TransposedLoops`` double-transpose identity: (Aᵀ)ᵀ reconstructs A's
    dense content bit-for-bit (structure moves, values never change);
  * ``matrix_key`` (the trace-record fingerprint prefix) is invariant
    under row permutation — two equal-row-stat matrices share trace cells.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import csr_from_dense, csr_to_dense
from repro.core.formats import (bcsr_from_csr_rows, loops_from_csr,
                                panelize_bcsr, panelize_csr, permute_rows)
from repro.perf import matrix_key

dims = st.integers(min_value=4, max_value=40)
densities = st.sampled_from([0.05, 0.15, 0.4])
seeds = st.integers(min_value=0, max_value=2**31 - 1)
widths = st.sampled_from([1, 2, 4, 8])


def _sparse(seed, m, k, density):
    rng = np.random.default_rng(seed)
    a = (rng.random((m, k)) < density) * rng.standard_normal((m, k))
    return a.astype(np.float32)


def _dense_of(fmt):
    """Dense content of a LoopsFormat, reconstructed on the host (no
    kernels): CSR part verbatim, BCSR tiles expanded at their (block-row,
    column) coordinates.  Zero-valued pads add exact zeros."""
    m, k = fmt.shape
    out = np.zeros((m, k), np.float64)
    if fmt.r_boundary > 0:
        out[:fmt.r_boundary] = csr_to_dense(fmt.csr_part)
    bc = fmt.bcsr_part
    for t in range(bc.ntiles):
        r0 = fmt.r_boundary + int(bc.tile_rows[t]) * bc.br
        rows = np.arange(r0, min(r0 + bc.br, m))
        out[rows, int(bc.tile_cols[t])] += bc.tile_vals[t, :len(rows)]
    return out


@given(seed=seeds, m=dims, k=dims, density=densities, g=widths)
def test_panelize_csr_round_trip(seed, m, k, density, g):
    csr = csr_from_dense(_sparse(seed, m, k, density))
    panels = panelize_csr(csr, g)
    # gather is the exact inverse of the pack on stored values (including
    # the zero pads csr_from_dense inserts for empty rows) ...
    np.testing.assert_array_equal(
        np.asarray(panels.gather_values(np.asarray(panels.panel_vals))),
        csr.vals)
    # ... and scatter rebuilds the panel layout bit-for-bit, with padding
    # lanes exactly zero.
    import jax.numpy as jnp
    rebuilt = np.asarray(panels.scatter_values(jnp.asarray(csr.vals)))
    np.testing.assert_array_equal(rebuilt, np.asarray(panels.panel_vals))
    # a row never shares a panel and every row appears
    assert set(np.asarray(panels.panel_rows)) == set(range(csr.nrows))


@given(seed=seeds, m=dims, k=dims, density=densities, g=widths)
def test_panelize_bcsr_round_trip(seed, m, k, density, g):
    csr = csr_from_dense(_sparse(seed, m, k, density))
    bcsr = bcsr_from_csr_rows(csr, 0, csr.nrows, 8)
    panels = panelize_bcsr(bcsr, g)
    np.testing.assert_array_equal(
        np.asarray(panels.gather_values(np.asarray(panels.panel_vals))),
        bcsr.tile_vals)
    import jax.numpy as jnp
    rebuilt = np.asarray(panels.scatter_values(jnp.asarray(bcsr.tile_vals)))
    np.testing.assert_array_equal(rebuilt, np.asarray(panels.panel_vals))


@settings(max_examples=10)   # two transposed conversions per example
@given(seed=seeds, m=dims, k=dims, density=densities)
def test_double_transpose_identity(seed, m, k, density):
    a = _sparse(seed, m, k, density)
    csr = csr_from_dense(a)
    fmt = loops_from_csr(csr, (csr.nrows // 2) // 8 * 8, 8)
    tl = fmt.transposed(total_workers=4)
    np.testing.assert_array_equal(_dense_of(tl.fmt), a.T.astype(np.float64))
    tl2 = tl.fmt.transposed(total_workers=4)
    np.testing.assert_array_equal(_dense_of(tl2.fmt), a.astype(np.float64))


@given(seed=seeds, m=dims, k=dims, density=densities)
def test_matrix_key_row_permutation_invariant(seed, m, k, density):
    csr = csr_from_dense(_sparse(seed, m, k, density))
    order = np.random.default_rng(seed + 1).permutation(csr.nrows)
    assert matrix_key(permute_rows(csr, order)) == matrix_key(csr)
