"""CI perf gate: passes on the committed baseline, provably fails on an
injected regression (the negative self-test), and enforces the per-metric
tolerance classes (exact grid-step counts, near-exact derived ratios,
banded wall-clock)."""
import copy
import json
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

import perf_gate  # noqa: E402

BASELINE = ROOT / "benchmarks" / "results" / "BENCH_010.json"


def _baseline():
    with open(BASELINE) as f:
        return json.load(f)


def test_baseline_is_committed_and_nonempty():
    recs = _baseline()
    assert recs, "BENCH_010.json must hold the smoke-suite records"
    suites = {r.get("suite") for r in recs}
    assert "fig4_panel" in suites and "batched" in suites


def test_gate_passes_on_itself():
    assert perf_gate.run_gate(BASELINE, BASELINE, wall_tol=1.5) == []
    assert perf_gate.diff_records(_baseline(), _baseline()) == []


def test_gate_fails_on_injected_grid_step_regression():
    """Negative self-test (ISSUE 6): a 2x grid-step regression in a
    synthetic bench record must fail the gate."""
    base = [{"suite": "batched", "batch": 4, "n_cols": 32, "panel_g": 8,
             "grid_steps_loop": 160, "grid_steps_native": 40,
             "step_reduction_vs_loop": 4.0, "fwd_us_loop": 100.0,
             "fwd_us_vmap": 80.0, "fwd_us_native": 60.0,
             "fwdbwd_us_loop": 300.0, "fwdbwd_us_vmap": 200.0,
             "fwdbwd_us_native": 150.0}]
    cur = copy.deepcopy(base)
    cur[0]["grid_steps_native"] *= 2
    fails = perf_gate.diff_records(base, cur)
    assert fails and "grid_steps_native" in fails[0]
    # An injected *improvement* also trips the exact class: the baseline is
    # stale and must be refreshed explicitly, never drift silently.
    cur2 = copy.deepcopy(base)
    cur2[0]["grid_steps_native"] //= 2
    assert perf_gate.diff_records(base, cur2)


def test_gate_fails_on_committed_baseline_regression():
    cur = _baseline()
    victim = next(r for r in cur if r.get("suite") == "fig4_panel")
    victim["steps_tuned"] *= 2
    fails = perf_gate.diff_records(_baseline(), cur, wall_tol=float("inf"))
    assert any("steps_tuned" in f for f in fails)


def test_gate_fails_on_dropped_record_and_column():
    base = _baseline()
    cur = [r for r in base if r.get("suite") != "batched"]
    assert any("missing" in f for f in perf_gate.diff_records(base, cur))

    cur2 = copy.deepcopy(base)
    rec = next(r for r in cur2 if r.get("suite") == "batched")
    del rec["grid_steps_native"]
    assert any("dropped" in f for f in perf_gate.diff_records(base, cur2))


def test_wall_band_tolerance():
    base = [{"suite": "fig4", "matrix": "m6", "dtype": "fp32", "panel_g": 8,
             "nnz": 100, "us_per_call": 100.0, "gflops": 2.0,
             "vs_taco": 1.5, "vs_dense": 0.5}]
    cur = copy.deepcopy(base)
    cur[0]["us_per_call"] = 500.0    # 5x slower: inside the 10x band
    cur[0]["gflops"] = 0.4
    assert perf_gate.diff_records(base, cur, wall_tol=10.0) == []
    cur[0]["us_per_call"] = 2000.0   # 20x slower: outside
    assert perf_gate.diff_records(base, cur, wall_tol=10.0)
    # inf disables the wall class entirely (the CI cross-machine setting)
    assert perf_gate.diff_records(base, cur,
                                  wall_tol=float("inf")) == []


def test_near_class_catches_ratio_drift():
    base = [{"suite": "fig4_panel_geomean", "matrix": "geomean",
             "dtype": "fp32", "step_reduction_g8": 7.21}]
    cur = copy.deepcopy(base)
    cur[0]["step_reduction_g8"] = 6.5
    assert perf_gate.diff_records(base, cur)
    assert perf_gate.diff_records(base, base) == []


def test_skip_records_are_exempt():
    base = [{"suite": "spmm_dryrun", "skipped": True, "reason": "no mesh"}]
    assert perf_gate.diff_records(base, []) == []


def test_schema_validation_is_part_of_the_gate(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([{"suite": "fig4", "matrix": "m6"}]))
    fails = perf_gate.run_gate(BASELINE, bad, wall_tol=float("inf"))
    assert any("schema violation" in f for f in fails)


def test_cli_exit_codes(tmp_path):
    ok = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "perf_gate.py"),
         "--baseline", str(BASELINE), "--current", str(BASELINE),
         "--wall-tol", "inf"], capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr

    cur = _baseline()
    next(r for r in cur if r.get("suite") == "batched")["grid_steps_native"] \
        += 1
    bad_path = tmp_path / "bench.json"
    bad_path.write_text(json.dumps(cur))
    bad = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "perf_gate.py"),
         "--baseline", str(BASELINE), "--current", str(bad_path),
         "--wall-tol", "inf"], capture_output=True, text=True)
    assert bad.returncode == 1
    assert "grid_steps_native" in bad.stdout
