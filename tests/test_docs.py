"""The documentation layer is part of tier-1: dead relative links or
references to renamed/removed symbols in README.md / docs/*.md fail the
suite, not just the CI docs job (``tools/check_docs.py`` is the single
implementation; CI invokes it standalone)."""
import pathlib
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_docs_links_and_symbol_refs_resolve():
    res = subprocess.run([sys.executable, str(ROOT / "tools/check_docs.py")],
                         capture_output=True, text=True, timeout=120)
    assert res.returncode == 0, res.stdout + res.stderr


def test_docs_cover_the_training_surface():
    """training.md and api.md exist and mention the load-bearing entry
    points (a rename must update the docs, not silently orphan them)."""
    training = (ROOT / "docs" / "training.md").read_text()
    api = (ROOT / "docs" / "api.md").read_text()
    for needle in ("loops_spmm_values", "transposed", "spmm_sdd",
                   "loops_cotangent_psum"):
        assert needle in training, f"docs/training.md lost '{needle}'"
    for needle in ("loops_spmm", "loops_sdd", "CACHE_VERSION", "panel_g",
                   "grad?"):
        assert needle in api, f"docs/api.md lost '{needle}'"


def test_docs_cover_the_pipeline_surface():
    """The pipelined-panel knobs are documented: api.md states the knob
    contract (+ v4 cache entry), architecture.md has the subsection, and
    the README maps them to the paper's SME techniques."""
    api = (ROOT / "docs" / "api.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    readme = (ROOT / "README.md").read_text()
    for needle in ("pipeline_depth", "macro_m", "panel_g_eff",
                   "default_bn", "currently **4**", "BENCH_010"):
        assert needle in api, f"docs/api.md lost '{needle}'"
    for needle in ("Pipelined panels", "pipeline_depth", "macro_m",
                   "prefetch_overlap", "scratch_bytes", "BENCH_010"):
        assert needle in arch, f"docs/architecture.md lost '{needle}'"
    for needle in ("pipeline_depth", "macro_m", "BENCH_010"):
        assert needle in readme, f"README.md lost '{needle}'"


def test_docs_cover_the_observability_surface():
    """observability.md and architecture.md §8 mention the load-bearing
    obs entry points and the jit-safety contract."""
    obs_doc = (ROOT / "docs" / "observability.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    for needle in ("observe_in_jit", "attach_engine", "watch_cache",
                   "obs_report.py", "OBS_SCHEMA_VERSION", "Span.fence",
                   "spans_dropped_traced"):
        assert needle in obs_doc, f"docs/observability.md lost '{needle}'"
    assert "## 8. Runtime observability" in arch
    for needle in ("observe_in_jit", "tune.cache.", "obs_file"):
        assert needle in arch, f"architecture.md §8 lost '{needle}'"


def test_docs_cover_the_robustness_surface():
    """robustness.md and architecture.md §9 mention the load-bearing
    resilience entry points (taxonomy, chain order, site names, gates)."""
    rob = (ROOT / "docs" / "robustness.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    readme = (ROOT / "README.md").read_text()
    for needle in ("SparseInputError", "DEFECT_KINDS", "run_chain",
                   "REPRO_FAULT_PLAN", "REPRO_NO_FALLBACK", "fault_point",
                   "pallas → interpret → jnp", "plans.json.quarantined",
                   "trial_timeout_s", "--fail-on-degraded",
                   "--require-degraded", "retry_with_backoff",
                   "on_miss"):
        assert needle in rob, f"docs/robustness.md lost '{needle}'"
    assert "## 9. Resilience" in arch
    for needle in ("fault_point", "engine.fallback", "REPRO_NO_FALLBACK"):
        assert needle in arch, f"architecture.md §9 lost '{needle}'"
    assert "docs/robustness.md" in readme


def test_docs_cover_the_serving_surface():
    """serving.md and architecture.md §10 mention the load-bearing serving
    entry points (lifecycle, knobs, clocks, warm pools, benchmark)."""
    serving = (ROOT / "docs" / "serving.md").read_text()
    arch = (ROOT / "docs" / "architecture.md").read_text()
    readme = (ROOT / "README.md").read_text()
    for needle in ("shape_key", "max_queue_depth", "max_in_flight",
                   "max_wait_s", "serve.rejected", "scheduler clock",
                   "wall clock", "padded_batch", "ExecutorPool", "prewarm",
                   "sample_token", "serve_traffic", "REPRO_TEST_SEED",
                   "prefill-first", "serve.ttft_us"):
        assert needle in serving, f"docs/serving.md lost '{needle}'"
    assert "## 10. Serving" in arch
    for needle in ("ServeQueue", "padded_batch", "prewarm",
                   "virtual clock"):
        assert needle in arch, f"architecture.md §10 lost '{needle}'"
    assert "docs/serving.md" in readme
