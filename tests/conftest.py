import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# Deterministic, CI-friendly hypothesis profile (interpret-mode kernels are
# slow per-example; keep example counts modest).
settings.register_profile(
    "repro", max_examples=25, deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
settings.load_profile("repro")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def random_sparse(rng, m, k, density, dtype=np.float32):
    a = (rng.random((m, k)) < density) * rng.standard_normal((m, k))
    return a.astype(dtype)
