"""Shared fixtures.  Degrades gracefully when ``hypothesis`` is missing
(minimal environments install only ``jax``/``numpy``/``pytest``): the
property-based test modules are skipped at collection instead of killing the
whole run with an ImportError.  ``pip install -e .[test]`` restores them.

One seed — ``REPRO_TEST_SEED`` (default 0) — feeds both the ``rng`` fixture
here and the benchmark input streams (``benchmarks/_util.bench_rng``), so a
full test+bench sweep can be re-rolled under a different seed with a single
env var and stays bit-reproducible under the default.
"""
import importlib.util
import os

import numpy as np
import pytest

TEST_SEED = int(os.environ.get("REPRO_TEST_SEED", "0"))
HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

if HAVE_HYPOTHESIS:
    from hypothesis import HealthCheck, settings

    # Deterministic, CI-friendly hypothesis profile (interpret-mode kernels
    # are slow per-example; keep example counts modest).
    settings.register_profile(
        "repro", max_examples=25, deadline=None,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("repro")
else:
    # These modules import hypothesis at module scope; without it they can't
    # even be collected, so skip the files (not just the tests).
    collect_ignore = ["test_formats.py", "test_perf_model.py",
                      "test_spmm.py", "test_formats_properties.py"]


@pytest.fixture
def rng():
    return np.random.default_rng(TEST_SEED)


def random_sparse(rng, m, k, density, dtype=np.float32):
    a = (rng.random((m, k)) < density) * rng.standard_normal((m, k))
    return a.astype(dtype)
