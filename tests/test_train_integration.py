"""Integration: the full train step (grad-accum + ZeRO-1 AdamW) learns, the
data pipeline is deterministic/resumable, checkpoints round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, global_batch_at, host_shard
from repro.dist import step as step_lib
from repro.launch.mesh import make_test_mesh
from repro.launch import specs
from repro.models import api
from repro.optim import adamw
from repro.optim.adamw import OptConfig, from_flat, to_flat


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = REDUCED["llama3.2-1b"]()
    mesh = make_test_mesh(1, 1)
    shape = ShapeConfig("t", 32, 4, "train")
    n_mb = 2
    params = api.init_params(cfg, jax.random.key(0))
    pav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       params)
    bav = specs.train_batch_specs(cfg, shape, n_mb)
    bundle = step_lib.build_train_step(
        cfg, mesh, pav, bav, OptConfig(lr=1e-2, warmup_steps=2,
                                       total_steps=50),
        n_microbatches=n_mb)
    opt_state = adamw.init_opt_state(params, 1)
    return cfg, mesh, shape, n_mb, params, opt_state, bundle


def test_loss_decreases(tiny_setup):
    cfg, mesh, shape, n_mb, params, opt_state, bundle = tiny_setup
    data = DataConfig(seed=7)
    losses = []
    for step in range(30):
        batch = global_batch_at(data, cfg, shape, n_mb, step % 2)
        params, opt_state, metrics = bundle.fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
    assert all(np.isfinite(l) for l in losses)


def test_grad_accum_equals_big_batch():
    """n_mb=2 over batch 4 must match n_mb=1 over the same 4 samples."""
    cfg = REDUCED["qwen3-32b"]()
    mesh = make_test_mesh(1, 1)
    params = api.init_params(cfg, jax.random.key(0))
    pav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       params)
    data = DataConfig(seed=1)
    shape = ShapeConfig("t", 16, 4, "train")
    outs = {}
    for n_mb in (1, 2):
        bav = specs.train_batch_specs(cfg, shape, n_mb)
        bundle = step_lib.build_train_step(
            cfg, mesh, pav, bav, OptConfig(lr=1e-3), n_microbatches=n_mb)
        batch = global_batch_at(data, cfg, shape, n_mb, 0)
        opt = adamw.init_opt_state(params, 1)
        # bundle.fn donates (params, opt): hand it copies, keep the originals
        new_p, _, m = bundle.fn(jax.tree.map(jnp.copy, params), opt, batch)
        outs[n_mb] = (jax.device_get(new_p), float(m["loss"]))
    flat1 = jax.tree.leaves(outs[1][0])
    flat2 = jax.tree.leaves(outs[2][0])
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-2)
    assert outs[1][1] == pytest.approx(outs[2][1], rel=1e-2)


def test_data_determinism_and_host_sharding():
    cfg = REDUCED["llama3.2-1b"]()
    shape = ShapeConfig("t", 16, 8, "train")
    d = DataConfig(seed=3)
    b1 = global_batch_at(d, cfg, shape, 2, step=5)
    b2 = global_batch_at(d, cfg, shape, 2, step=5)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = global_batch_at(d, cfg, shape, 2, step=6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # host shards partition the batch exactly
    shards = [host_shard(b1, h, 4) for h in range(4)]
    recon = np.concatenate([np.asarray(s["tokens"]) for s in shards], axis=1)
    assert np.array_equal(recon, np.asarray(b1["tokens"]))
    # labels are the next-token shift with the tail masked
    assert np.array_equal(np.asarray(b1["labels"][..., :-1]),
                          np.asarray(b1["tokens"][..., 1:]))
    assert (np.asarray(b1["labels"][..., -1]) == -1).all()


def test_flat_roundtrip():
    x = jnp.arange(13, dtype=jnp.bfloat16).reshape(13)
    f = to_flat(x, 4)
    assert f.shape == (4, 4)
    y = from_flat(f, (13,), jnp.bfloat16)
    assert np.array_equal(np.asarray(y, np.float32),
                          np.asarray(x, np.float32))


def test_lr_schedule_shape():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    lrs = [float(adamw.lr_at(opt, jnp.int32(s))) for s in
           [0, 5, 10, 50, 100, 200]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(0.1, rel=1e-2)
    assert lrs[5] == pytest.approx(0.1, rel=1e-2)
