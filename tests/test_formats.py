"""Format construction: CSR round-trip, Algorithm 1 conversion, invariants."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import (bcsr_from_csr_rows, csr_from_coo, csr_from_dense,
                        csr_to_dense, loops_from_csr, row_stats)


def _rand_dense(seed, m, k, density):
    rng = np.random.default_rng(seed)
    return ((rng.random((m, k)) < density)
            * rng.standard_normal((m, k))).astype(np.float32)


def _loops_to_dense(fmt):
    """Reassemble a dense matrix from the hybrid format."""
    out = np.zeros(fmt.shape, np.float32)
    c = fmt.csr_part
    np.add.at(out[:fmt.r_boundary], (c.row_ids, c.col_idx), c.vals)
    b = fmt.bcsr_part
    for t in range(b.ntiles):
        r0 = fmt.r_boundary + int(b.tile_rows[t]) * b.br
        col = int(b.tile_cols[t])
        for i in range(b.br):
            if r0 + i < fmt.shape[0]:
                out[r0 + i, col] += b.tile_vals[t, i]
    return out


def test_csr_round_trip():
    a = _rand_dense(0, 23, 17, 0.2)
    assert np.array_equal(csr_to_dense(csr_from_dense(a)), a)


def test_csr_empty_rows_padded():
    a = np.zeros((5, 4), np.float32)
    a[1, 2] = 3.0
    csr = csr_from_dense(a)
    counts = np.diff(csr.row_ptr)
    assert (counts >= 1).all()  # every row visited (kernel contract)
    assert np.array_equal(csr_to_dense(csr), a)


@given(st.integers(0, 6), st.integers(1, 40), st.integers(1, 30),
       st.sampled_from([0.0, 0.05, 0.3, 0.9]), st.sampled_from([2, 4, 8]))
def test_loops_conversion_value_preserving(seed, m, k, density, br):
    """Algorithm 1 must preserve every value for ANY r_boundary."""
    a = _rand_dense(seed, m, k, density)
    csr = csr_from_dense(a)
    for r_b in {0, m // 2, m}:
        fmt = loops_from_csr(csr, r_b, br)
        np.testing.assert_allclose(_loops_to_dense(fmt), a, rtol=1e-6)


@given(st.integers(0, 5), st.integers(1, 50), st.sampled_from([2, 8]))
def test_bcsr_invariants(seed, m, br):
    a = _rand_dense(seed, m, m, 0.2)
    csr = csr_from_dense(a)
    b = bcsr_from_csr_rows(csr, 0, m, br)
    # tiles sorted by (block_row, col); every block-row represented
    rows = b.tile_rows
    assert (np.diff(rows) >= 0).all()
    assert set(range(b.nblocks)) <= set(rows.tolist())
    assert b.nblocks == max((m + br - 1) // br, 1)
    # block_ptr consistent with tile_rows
    counts = np.bincount(rows, minlength=b.nblocks)
    assert np.array_equal(np.diff(b.block_ptr), counts)


def test_row_stats_matches_numpy():
    a = _rand_dense(1, 64, 32, 0.15)
    csr = csr_from_dense(a)
    s = row_stats(csr)
    counts = (a != 0).sum(1)
    # stats include structural pads for empty rows; only compare when no
    # empty rows exist
    if (counts > 0).all():
        assert s.nnz_max == counts.max()
        assert abs(s.nnz_mean - counts.mean()) < 1e-9


def test_coo_duplicate_accumulation():
    rows = [0, 0, 1]
    cols = [1, 1, 0]
    vals = [2.0, 3.0, 4.0]
    csr = csr_from_coo(rows, cols, vals, (2, 2))
    dense = csr_to_dense(csr)
    assert dense[0, 1] == pytest.approx(5.0)
    assert dense[1, 0] == pytest.approx(4.0)
    # Coalescing happens during *construction* (full regression suite:
    # tests/test_tune.py, which also runs in hypothesis-free environments).
    coords = list(zip(csr.row_ids.tolist(), csr.col_idx.tolist()))
    assert len(coords) == len(set(coords))
