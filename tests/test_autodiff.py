"""Gradients through the LOOPS kernels: the custom VJP vs the dense /
jnp-reference oracles.

Covers the tentpole contract of the differentiable-LOOPS work:
  * ``jax.grad`` through ``loops_spmm`` on the Pallas (interpret) backend
    equals the dense-adjacency reference across dtypes × panel widths ×
    plan shapes (pure-CSR / pure-BCSR / hybrid boundary);
  * ``loops_spmm_values`` additionally yields per-stored-value gradients
    that equal ``dY @ Bᵀ`` masked to the sparsity pattern (the SDD kernel);
  * the transposed format is built once and cached on the ``LoopsFormat`` —
    a second backward pass performs no re-conversion;
  * the sparse FFN layer trains identically on the interpret and jnp paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (csr_from_dense, loops_from_csr, loops_spmm,
                        loops_spmm_values, plan_and_convert)
from repro.core import formats as formats_lib

DTYPES = [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)]
PANEL_GS = [1, 8]


def _sparse(rng, m, k, density, dtype):
    a = (rng.random((m, k)) < density) * rng.standard_normal((m, k))
    return np.asarray(jnp.asarray(a, dtype))


def _boundaries(m, br):
    # pure CSR, pure BCSR, and a hybrid br-aligned interior boundary
    return [m, 0, br]


@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("g", PANEL_GS)
def test_grad_b_matches_dense_reference(rng, dtype, tol, g):
    """check_grads-style: the custom VJP's dB equals Aᵀ·dY from the dense
    reference, across pure-CSR / pure-BCSR / hybrid plans."""
    m, k, n = 24, 17, 16
    br = 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8
    a = _sparse(rng, m, k, 0.3, dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    dy = rng.standard_normal((m, n)).astype(np.float32)
    want = np.asarray(a, np.float32).T @ dy
    for r_b in _boundaries(m, br):
        fmt = loops_from_csr(csr_from_dense(a), r_b, br, panel_g=g)

        def loss(bb):
            out = loops_spmm(fmt, bb, backend="interpret")
            return jnp.sum(out * jnp.asarray(dy, out.dtype))

        db = jax.jit(jax.grad(loss))(b)
        assert db.dtype == b.dtype
        np.testing.assert_allclose(
            np.asarray(db, np.float32), want, rtol=tol,
            atol=tol * max(np.abs(want).max(), 1.0),
            err_msg=f"r_boundary={r_b} g={g}")


@pytest.mark.parametrize("g", PANEL_GS)
def test_grad_matches_jnp_backend(rng, g):
    """The jnp reference differentiates natively; the custom VJP must agree
    with it bit-for-tolerance on the same format."""
    m, k, n = 21, 13, 8
    a = _sparse(rng, m, k, 0.35, jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8, panel_g=g)

    def loss(bb, backend):
        return jnp.sum(jnp.tanh(loops_spmm(fmt, bb, backend=backend)))

    d_interp = jax.grad(lambda bb: loss(bb, "interpret"))(b)
    d_jnp = jax.grad(lambda bb: loss(bb, "jnp"))(b)
    np.testing.assert_allclose(np.asarray(d_interp), np.asarray(d_jnp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("g", PANEL_GS)
def test_sdd_value_grads_match_masked_dense(rng, dtype, tol, g):
    """d(stored values) = (dY @ Bᵀ) sampled at the stored coordinates —
    CSR-part entries and BCSR-part tile elements both."""
    m, k, n = 21, 17, 16
    br = 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8
    a = _sparse(rng, m, k, 0.3, dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    dy = rng.standard_normal((m, n)).astype(np.float32)
    r_b = br if m > br else m
    fmt = loops_from_csr(csr_from_dense(a), r_b, br, panel_g=g)
    cv = jnp.asarray(fmt.csr_part.vals)
    bv = jnp.asarray(fmt.bcsr_part.tile_vals)

    def loss(cv_, bv_, bb):
        out = loops_spmm_values(fmt, cv_, bv_, bb, backend="interpret")
        return jnp.sum(out * jnp.asarray(dy, out.dtype))

    d_cv, d_bv, d_b = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(cv, bv, b)
    dw = dy @ np.asarray(b, np.float32).T        # (m, k) dense dY·Bᵀ

    csr = fmt.csr_part
    want_cv = dw[csr.row_ids, csr.col_idx]
    np.testing.assert_allclose(np.asarray(d_cv, np.float32), want_cv,
                               rtol=tol, atol=tol * np.abs(dw).max())

    bc = fmt.bcsr_part
    rows_g = (fmt.r_boundary + np.repeat(bc.tile_rows, bc.br) * bc.br
              + np.tile(np.arange(bc.br), bc.ntiles))
    cols_g = np.repeat(bc.tile_cols, bc.br)
    want_bv = np.where(rows_g < m, dw[np.minimum(rows_g, m - 1), cols_g],
                       0.0).reshape(bc.ntiles, bc.br)
    np.testing.assert_allclose(np.asarray(d_bv, np.float32), want_bv,
                               rtol=tol, atol=tol * np.abs(dw).max())

    want_db = np.asarray(a, np.float32).T @ dy
    np.testing.assert_allclose(np.asarray(d_b, np.float32), want_db,
                               rtol=tol, atol=tol * np.abs(want_db).max())


def test_transpose_cache_reused_across_backwards(rng, monkeypatch):
    """The O(nnz) transpose conversion runs once; the second backward pass
    is a pure cache hit on the LoopsFormat instance."""
    m, k, n = 24, 16, 8
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    fmt, _ = plan_and_convert(csr_from_dense(a), total_workers=4)

    calls = {"n": 0}
    real = formats_lib._build_transposed

    def counting(*args, **kwargs):
        calls["n"] += 1
        return real(*args, **kwargs)

    monkeypatch.setattr(formats_lib, "_build_transposed", counting)

    def loss(bb):
        return jnp.sum(loops_spmm(fmt, bb, backend="interpret") ** 2)

    g1 = jax.grad(loss)(b)
    g2 = jax.grad(loss)(b)   # second backward: no re-conversion
    assert calls["n"] == 1
    assert fmt.transposed() is fmt.transposed()
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=0,
                               atol=0)


def test_transposed_structure_roundtrip(rng):
    """Aᵀ's LOOPS format densifies back to the dense transpose, and the
    value-linear maps reproduce the converted parts from A's flat values
    (the invariant the traced-values backward leans on)."""
    m, k = 19, 12
    a = _sparse(rng, m, k, 0.4, jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8, panel_g=4)
    tl = fmt.transposed()
    assert tl.fmt.shape == (k, m)
    # densify Aᵀ from its two parts
    from repro.core import csr_to_dense
    dense_t = np.zeros((k, m), np.float32)
    dense_t[:tl.fmt.r_boundary] = csr_to_dense(tl.fmt.csr_part)
    bc = tl.fmt.bcsr_part
    for t in range(bc.ntiles):
        r0 = tl.fmt.r_boundary + int(bc.tile_rows[t]) * bc.br
        for off in range(bc.br):
            if r0 + off < k:
                dense_t[r0 + off, bc.tile_cols[t]] += bc.tile_vals[t, off]
    np.testing.assert_allclose(dense_t, np.asarray(a, np.float32).T,
                               rtol=1e-6, atol=1e-6)
    # traced-value carry: injecting A's values reproduces the parts
    cv, bv = formats_lib.transposed_values(
        tl, jnp.asarray(fmt.csr_part.vals),
        jnp.asarray(fmt.bcsr_part.tile_vals))
    np.testing.assert_allclose(np.asarray(cv), tl.fmt.csr_part.vals,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(bv), tl.fmt.bcsr_part.tile_vals,
                               rtol=1e-6, atol=1e-6)


def test_sparse_ffn_grads_interpret_vs_jnp(rng):
    """The sparse FFN layer trains on the real kernel path: gradients on
    the interpret backend match the jnp oracle for values AND activations."""
    from repro.models.sparse_ffn import (sparse_linear_apply,
                                         sparse_linear_from_dense)
    w = rng.standard_normal((24, 16)).astype(np.float32)
    layer = sparse_linear_from_dense(w, 0.6)
    vals = layer.init_values()
    x = jnp.asarray(rng.standard_normal((5, 16)), jnp.float32)

    def loss(v, x_, backend):
        y = sparse_linear_apply(layer, v, x_, backend=backend)
        return jnp.sum(y ** 2)

    gi = jax.grad(loss, argnums=(0, 1))(vals, x, "interpret")
    gj = jax.grad(loss, argnums=(0, 1))(vals, x, "jnp")
    for a_, b_ in zip(jax.tree.leaves(gi), jax.tree.leaves(gj)):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)
    gn = sum(float(jnp.abs(t).sum()) for t in jax.tree.leaves(gi))
    assert np.isfinite(gn) and gn > 0


def test_gcn_hybrid_grad_end_to_end(rng):
    """The acceptance-criterion scenario: a hybrid-plan GCN loss, fp32,
    grads through backend='interpret' vs the dense-adjacency reference to
    <= 1e-4 — with no csr_to_dense in the differentiated path."""
    from repro.core import csr_to_dense, suite
    adj = suite.gcn_graph(256, 4, seed=0)
    fmt, plan = plan_and_convert(adj, total_workers=8)
    assert 0 < plan.r_boundary < adj.nrows, "scenario must be hybrid"
    n_in, n_out = 8, 4
    x = jnp.asarray(rng.standard_normal((adj.nrows, n_in)), jnp.float32)
    y = jnp.asarray(rng.integers(0, n_out, adj.nrows), jnp.int32)
    w = jnp.asarray(rng.standard_normal((n_in, n_out)) * 0.1, jnp.float32)

    def loss(w_, agg):
        logits = agg(jax.nn.relu(agg(x)) @ w_)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold)

    g_loops = jax.grad(
        lambda w_: loss(w_, lambda h: loops_spmm(fmt, h,
                                                 backend="interpret")))(w)
    dense = jnp.asarray(csr_to_dense(adj))
    g_dense = jax.grad(lambda w_: loss(w_, lambda h: dense @ h))(w)
    np.testing.assert_allclose(np.asarray(g_loops), np.asarray(g_dense),
                               rtol=1e-4, atol=1e-4)
