"""Batched multi-RHS execution through the engine (``kernels/engine.py``).

The contract under test:
  * ``loops_spmm``/``loops_spmm_values`` accept ``B`` of shape
    ``(..., K, N)`` and return ``(..., M, N)`` — native batched == the
    vmap-unrolled per-element stack == the jnp oracle, for forward AND
    gradients, across {fp32, bf16} × G{1, 8} × {pure-CSR, pure-BCSR,
    hybrid};
  * ``jax.vmap`` over the operand and a direct ``(batch, K, N)`` input both
    lower to ONE batched ``pallas_call`` per part (no unrolling in the
    jaxpr);
  * the value cotangents of ``loops_spmm_values`` are summed over the batch
    (values are shared), while ``dB`` stays per-element;
  * empty batches and the empty-matrix path return correctly-shaped zeros
    on every backend; rank-1 / K-mismatched operands raise ``ValueError``;
  * one native batched call costs ``ceil(batch/bz)`` × the single-element
    grid steps — strictly fewer than the per-element loop from batch ≥ 2.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (csr_from_dense, loops_from_csr, loops_spmm,
                        loops_spmm_values)
from repro.core.spmm import loops_batched_grid_steps, loops_grid_steps
from repro.kernels import engine

DTYPES = [(jnp.float32, 1e-4), (jnp.bfloat16, 5e-2)]
PANEL_GS = [1, 8]
BATCH = 3


def _sparse(rng, m, k, density, dtype):
    a = (rng.random((m, k)) < density) * rng.standard_normal((m, k))
    return np.asarray(jnp.asarray(a, dtype))


def _boundaries(m, br):
    # pure CSR, pure BCSR, and a hybrid br-aligned interior boundary
    return [m, 0, br]


def _count_pallas_calls(jaxpr) -> int:
    """Recursively count pallas_call equations, re-visiting shared
    sub-jaxprs per call site (= number of kernel dispatches)."""
    import jax.core as core

    def subjaxprs(v):
        if isinstance(v, core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from subjaxprs(x)

    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for j in subjaxprs(v):
                n += _count_pallas_calls(j)
    return n


@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("g", PANEL_GS)
def test_batched_forward_parity(rng, dtype, tol, g):
    """Native batched == vmap-unrolled == jnp oracle, fwd, across plans."""
    m, k, n = 24, 17, 8
    br = 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8
    a = _sparse(rng, m, k, 0.3, dtype)
    b3 = jnp.asarray(rng.standard_normal((BATCH, k, n)), dtype)
    want = np.einsum("mk,zkn->zmn", np.asarray(a, np.float32),
                     np.asarray(b3, np.float32))
    for r_b in _boundaries(m, br):
        fmt = loops_from_csr(csr_from_dense(a), r_b, br, panel_g=g)
        native = loops_spmm(fmt, b3, backend="interpret")
        assert native.shape == (BATCH, m, n)
        oracle = loops_spmm(fmt, b3, backend="jnp")
        unrolled = jnp.stack([loops_spmm(fmt, b3[i], backend="interpret")
                              for i in range(BATCH)])
        atol = tol * max(np.abs(want).max(), 1.0)
        np.testing.assert_allclose(np.asarray(native, np.float32), want,
                                   rtol=tol, atol=atol,
                                   err_msg=f"r_boundary={r_b} g={g}")
        np.testing.assert_allclose(np.asarray(native, np.float32),
                                   np.asarray(oracle, np.float32),
                                   rtol=tol, atol=atol)
        np.testing.assert_allclose(np.asarray(native, np.float32),
                                   np.asarray(unrolled, np.float32),
                                   rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("g", PANEL_GS)
def test_batched_grad_b_parity(rng, dtype, tol, g):
    """The custom VJP carries the batch through dB = Aᵀ·dY per element."""
    m, k, n = 24, 17, 8
    br = 16 if jnp.dtype(dtype) == jnp.bfloat16 else 8
    a = _sparse(rng, m, k, 0.3, dtype)
    b3 = jnp.asarray(rng.standard_normal((BATCH, k, n)), dtype)
    dy = rng.standard_normal((BATCH, m, n)).astype(np.float32)
    want = np.einsum("mk,zmn->zkn", np.asarray(a, np.float32), dy)
    for r_b in _boundaries(m, br):
        fmt = loops_from_csr(csr_from_dense(a), r_b, br, panel_g=g)

        def loss(bb):
            out = loops_spmm(fmt, bb, backend="interpret")
            return jnp.sum(out * jnp.asarray(dy, out.dtype))

        db = jax.jit(jax.grad(loss))(b3)
        assert db.dtype == b3.dtype and db.shape == b3.shape
        np.testing.assert_allclose(
            np.asarray(db, np.float32), want, rtol=tol,
            atol=tol * max(np.abs(want).max(), 1.0),
            err_msg=f"r_boundary={r_b} g={g}")


@pytest.mark.parametrize("g", PANEL_GS)
def test_batched_value_grads_summed_over_batch(rng, g):
    """loops_spmm_values under a batched operand: d(values) is the batch
    sum (shared parameters), dB stays per-element — both equal the jnp
    oracle's native autodiff."""
    m, k, n = 21, 13, 8
    a = _sparse(rng, m, k, 0.35, jnp.float32)
    b3 = jnp.asarray(rng.standard_normal((BATCH, k, n)), jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8, panel_g=g)
    cv = jnp.asarray(fmt.csr_part.vals)
    bv = jnp.asarray(fmt.bcsr_part.tile_vals)

    def loss(cv_, bv_, bb, backend):
        out = loops_spmm_values(fmt, cv_, bv_, bb, backend=backend)
        return jnp.sum(jnp.tanh(out))

    gi = jax.jit(jax.grad(loss, argnums=(0, 1, 2)),
                 static_argnums=3)(cv, bv, b3, "interpret")
    gj = jax.grad(loss, argnums=(0, 1, 2))(cv, bv, b3, "jnp")
    for got, want in zip(gi, gj):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
    # the value grads of a batch are the sum of the per-element grads
    per_elem = [jax.grad(loss, argnums=0)(cv, bv, b3[i:i + 1], "interpret")
                for i in range(BATCH)]
    np.testing.assert_allclose(np.asarray(gi[0]),
                               np.asarray(sum(per_elem)), rtol=1e-4,
                               atol=1e-4)


def test_vmap_lowers_to_single_batched_call(rng):
    """jax.vmap and a direct (batch, K, N) input both produce ONE
    pallas_call per part in the jaxpr; the per-element loop pays batch ×."""
    m, k, n = 24, 16, 8
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8, panel_g=8)  # hybrid: 2 parts
    b3 = jnp.asarray(rng.standard_normal((BATCH, k, n)), jnp.float32)

    def f(bb):
        return loops_spmm(fmt, bb, backend="interpret")

    n_vmap = _count_pallas_calls(jax.make_jaxpr(jax.vmap(f))(b3).jaxpr)
    n_direct = _count_pallas_calls(jax.make_jaxpr(f)(b3).jaxpr)
    n_loop = _count_pallas_calls(jax.make_jaxpr(
        lambda bb: jnp.stack([f(bb[i]) for i in range(BATCH)]))(b3).jaxpr)
    assert n_vmap == 2, f"vmap must lower to one pallas_call per part, got " \
                        f"{n_vmap}"
    assert n_direct == 2
    assert n_loop == 2 * BATCH
    # and the vmapped execution matches the native batched one exactly
    np.testing.assert_allclose(np.asarray(jax.vmap(f)(b3)),
                               np.asarray(f(b3)), rtol=0, atol=0)


def test_multi_leading_batch_dims(rng):
    """Arbitrary-rank leading dims flatten into one batched call."""
    m, k, n = 16, 12, 8
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8, panel_g=4)
    b4 = jnp.asarray(rng.standard_normal((2, 2, k, n)), jnp.float32)
    out = loops_spmm(fmt, b4, backend="interpret")
    assert out.shape == (2, 2, m, n)
    want = loops_spmm(fmt, b4, backend="jnp")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["interpret", "jnp"])
def test_empty_batch_returns_zeros(rng, backend):
    """A zero-size batch dim yields correctly shaped zeros (all backends),
    as does the empty-matrix path under batching."""
    m, k, n = 16, 12, 8
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8)
    out = loops_spmm(fmt, jnp.zeros((0, k, n)), backend=backend)
    assert out.shape == (0, m, n)
    out = loops_spmm(fmt, jnp.zeros((2, 0, k, n)), backend=backend)
    assert out.shape == (2, 0, m, n)
    cv = jnp.asarray(fmt.csr_part.vals)
    bv = jnp.asarray(fmt.bcsr_part.tile_vals)
    out = loops_spmm_values(fmt, cv, bv, jnp.zeros((0, k, n)),
                            backend=backend)
    assert out.shape == (0, m, n)
    # empty matrix × non-empty batch
    zfmt = loops_from_csr(csr_from_dense(np.zeros((m, k), np.float32)), 8, 8)
    out = loops_spmm(zfmt, jnp.zeros((2, k, n)), backend=backend)
    assert out.shape == (2, m, n)
    assert not np.asarray(out).any()


def test_bad_rhs_raises_value_error(rng):
    """Rank-1 and K-mismatched operands fail fast with a clear message,
    not an opaque Pallas shape error."""
    m, k = 16, 12
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8)
    with pytest.raises(ValueError, match=r"\(\.\.\., K, N\)"):
        loops_spmm(fmt, jnp.zeros((k,)), backend="jnp")
    with pytest.raises(ValueError, match="ncols"):
        loops_spmm(fmt, jnp.zeros((k + 1, 4)), backend="interpret")
    cv = jnp.asarray(fmt.csr_part.vals)
    bv = jnp.asarray(fmt.bcsr_part.tile_vals)
    with pytest.raises(ValueError, match=r"\(\.\.\., K, N\)"):
        loops_spmm_values(fmt, cv, bv, jnp.zeros((k,)), backend="jnp")
    with pytest.raises(ValueError, match="ncols"):
        engine.csr_spmm(fmt.csr_part, jnp.zeros((k + 3, 4)), backend="jnp")


def test_batched_grid_steps_beat_per_element_loop(rng):
    """One native batched call costs ceil(batch/bz) × the single-element
    steps — strictly below batch × (the per-element loop) from batch 2 up,
    and equal to the single-element count while batch ≤ MAX_BATCH_BLOCK."""
    m, k = 48, 32
    a = _sparse(rng, m, k, 0.15, jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 24, 8, panel_g=8)
    one = loops_grid_steps(fmt, 32)
    for batch in (2, 4, 8):
        native = loops_batched_grid_steps(fmt, batch, 32)
        assert native < batch * one
        assert native == one  # batch <= MAX_BATCH_BLOCK folds into bz
    assert loops_batched_grid_steps(fmt, 16, 32) == 2 * one
    assert loops_batched_grid_steps(fmt, 0, 32) == 0
    assert loops_batched_grid_steps(fmt, (2, 4), 32) == one
    # awkward sizes (no divisor <= MAX_BATCH_BLOCK) zero-pad into wide
    # blocks instead of degrading to per-slice steps
    assert loops_batched_grid_steps(fmt, 11, 32) == 2 * one
    assert loops_batched_grid_steps(fmt, 13, 32) == 2 * one
    assert loops_batched_grid_steps(fmt, 12, 32) == 2 * one  # divisor 6


def test_prime_batch_pads_not_degrades(rng):
    """A batch with no small divisor (11) stays correct fwd + bwd — the
    engine pads it to full-width blocks and trims, rather than falling
    back to one slice per grid step."""
    m, k, n, batch = 16, 12, 8, 11
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8, panel_g=4)
    b3 = jnp.asarray(rng.standard_normal((batch, k, n)), jnp.float32)
    out = loops_spmm(fmt, b3, backend="interpret")
    assert out.shape == (batch, m, n)
    want = loops_spmm(fmt, b3, backend="jnp")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    cv = jnp.asarray(fmt.csr_part.vals)
    bv = jnp.asarray(fmt.bcsr_part.tile_vals)

    def loss(cv_, bv_, bb, backend):
        return jnp.sum(loops_spmm_values(fmt, cv_, bv_, bb,
                                         backend=backend) ** 2)

    gi = jax.grad(loss, argnums=(0, 1, 2))(cv, bv, b3, "interpret")
    gj = jax.grad(loss, argnums=(0, 1, 2))(cv, bv, b3, "jnp")
    for got, ref in zip(gi, gj):
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_sparse_ffn_batched_activations(rng):
    """The sparse FFN consumes the batched path: rank-3 activations keep
    their batch structure and match the jnp oracle fwd + bwd."""
    from repro.models.sparse_ffn import (sparse_linear_apply,
                                         sparse_linear_from_dense)
    w = rng.standard_normal((24, 16)).astype(np.float32)
    layer = sparse_linear_from_dense(w, 0.6)
    vals = layer.init_values()
    x = jnp.asarray(rng.standard_normal((2, 5, 16)), jnp.float32)

    def loss(v, x_, backend):
        y = sparse_linear_apply(layer, v, x_, backend=backend)
        assert y.shape == (2, 5, 24)
        return jnp.sum(y ** 2)

    gi = jax.grad(loss, argnums=(0, 1))(vals, x, "interpret")
    gj = jax.grad(loss, argnums=(0, 1))(vals, x, "jnp")
    for a_, b_ in zip(jax.tree.leaves(gi), jax.tree.leaves(gj)):
        np.testing.assert_allclose(np.asarray(a_), np.asarray(b_),
                                   rtol=1e-4, atol=1e-4)
