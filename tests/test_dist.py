"""Unit coverage for the ``repro.dist`` layer: sharding-spec rules, the
jitted train/prefill/decode step builders on a CPU mesh, and the compressed
all-reduce's round-trip error bounds (multi-device parts run in a subprocess
with forced host devices, keeping the main pytest process single-device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import abstract_mesh
from repro.configs import REDUCED
from repro.configs.base import ShapeConfig
from repro.data import DataConfig, global_batch_at
from repro.dist import sharding as shr
from repro.dist import step as step_lib
from repro.launch import specs
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.optim import adamw
from repro.optim.adamw import OptConfig

_ENV = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def _run(body: str):
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=_ENV, capture_output=True, text=True,
                         timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


# ---------------------------------------------------------------------------
# spec rules (device-free: AbstractMesh)
# ---------------------------------------------------------------------------

def test_param_specs_shard_the_big_matrices():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    cfg = REDUCED["llama3.2-1b"]()          # 4 heads, kv=2 — 4-way aligned
    sp = shr.param_specs(specs.abstract_params(cfg), mesh, cfg)
    assert sp["layers"]["attn"]["wq"] == P(None, None, "model")
    assert sp["layers"]["attn"]["wo"] == P(None, "model", None)
    assert sp["layers"]["mlp"]["wi"] == P(None, None, "model")
    assert sp["layers"]["mlp"]["wo"] == P(None, "model", None)
    assert sp["embed"] == P("model", None)   # vocab 256 % 4 == 0
    assert sp["final_norm"]["scale"] == P()
    # kv = 2 does not divide the 4-way model axis -> kv_aligned replicates
    assert sp["layers"]["attn"]["wk"] == P()


def test_moe_param_specs_expert_parallel():
    mesh = abstract_mesh((1, 2), ("data", "model"))
    cfg = REDUCED["qwen2-moe-a2.7b"]()
    sp = shr.param_specs(specs.abstract_params(cfg), mesh, cfg)
    moe = sp["layers"]["moe"]
    assert moe["wi"] == P(None, "model", None, None)
    assert moe["wo"] == P(None, "model", None, None)
    assert moe["router"] == P()


def test_batch_cache_and_flat_specs():
    mesh = abstract_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = REDUCED["llama3.2-1b"]()
    shape = ShapeConfig("t", 16, 8, "train")
    bav = specs.train_batch_specs(cfg, shape, 2)
    bsp = shr.train_batch_specs(bav, mesh)
    assert bsp["tokens"] == P(None, ("pod", "data"), None)
    cav = jax.eval_shape(lambda: api.init_cache(cfg, 8, 32))
    csp = shr.cache_specs(cav, mesh, cfg)
    assert csp["k"] == P(None, ("pod", "data"), None, "model", None)
    pav = specs.abstract_params(cfg)
    gsp = shr.flat_grad_specs(pav, mesh)
    assert all(s == P(("pod", "data", "model"), None)
               for s in jax.tree.leaves(gsp))
    assert shr.dp_size(mesh) == 4 and shr.model_size(mesh) == 2


def test_loops_specs_row_shard_the_workload():
    assert shr.loops_in_specs("model") == (P("model"),) * 6 + (P(),)
    assert shr.loops_in_specs(("data", "model")) == \
        (P(("data", "model")),) * 6 + (P(),)
    assert shr.loops_out_spec("model") == P("model")


def test_distributed_spmm_cotangent_psum():
    """Grad of the row-sharded distributed SpMM w.r.t. the replicated dense
    operand: each device contributes Aᵀ_shard·dY_shard over its exclusive
    rows, and the loops_cotangent_psum over the worker axis recovers the
    full Aᵀ·dY — for both the assembled and stacked output layouts."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import (csr_from_dense, loops_from_csr, shard_loops,
                                distributed_spmm)
        rng = np.random.default_rng(0)
        m, k, n = 64, 40, 16
        a = ((rng.random((m, k)) < 0.25)
             * rng.standard_normal((m, k))).astype(np.float32)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        dy = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        fmt = loops_from_csr(csr_from_dense(a), 32, 8)
        mesh = make_mesh((8,), ("spmm",))
        sh = shard_loops(fmt, 8, 3)
        want = a.T @ np.asarray(dy)
        db = jax.grad(lambda bb: jnp.sum(
            distributed_spmm(sh, bb, mesh, axis="spmm") * dy))(b)
        np.testing.assert_allclose(np.asarray(db), want, rtol=1e-4,
                                   atol=1e-4)
        def loss_stacked(bb):
            st = distributed_spmm(sh, bb, mesh, axis="spmm",
                                  assemble=False)
            tot = 0.0
            for d in range(8):
                o, c = sh.row_offset[d], sh.row_count[d]
                if c:
                    tot = tot + jnp.sum(st[d, :c] * dy[o:o + c])
            return tot
        db2 = jax.grad(loss_stacked)(b)
        np.testing.assert_allclose(np.asarray(db2), want, rtol=1e-4,
                                   atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_shard_loops_auto_uses_perf_model_split():
    """Coarse-level scheduling: Eq. 3's argmax applied to device groups."""
    from repro.core import csr_from_dense, loops_from_csr, shard_loops_auto
    from repro.core.perf_model import calibrate
    rng = np.random.default_rng(0)
    a = ((rng.random((96, 32)) < 0.2)
         * rng.standard_normal((96, 32))).astype(np.float32)
    fmt = loops_from_csr(csr_from_dense(a), 48, 8)
    # vector unit scales linearly, matrix unit saturates past 2 workers
    model = calibrate(lambda x, y: 1.0 * x + 4.0 * min(y, 2)
                      + 0.3 * max(y - 2, 0), total=8)
    sh = shard_loops_auto(fmt, 8, model=model)
    assert sh.g_vpu == model.best_allocation(8)[0]
    assert 1 <= sh.g_vpu <= 7            # both regions non-empty -> both groups
    assert sum(sh.row_count) == fmt.nrows  # every global row owned exactly once
    # fallback (no model): nnz-proportional, still a valid full cover
    sh2 = shard_loops_auto(fmt, 8)
    assert sum(sh2.row_count) == fmt.nrows
    # one device cannot host two disjoint groups -> explicit error, not a
    # silently dropped region
    with pytest.raises(ValueError):
        shard_loops_auto(fmt, 1)


def test_default_microbatches_divides_cleanly():
    mesh = abstract_mesh((4, 2), ("data", "model"))
    n_mb = step_lib.default_microbatches(
        ShapeConfig("t", 128, 64, "train"), mesh)
    assert 64 % n_mb == 0
    assert (64 // n_mb) % 4 == 0
    # degenerate: tiny batch on a big mesh still yields >= 1
    assert step_lib.default_microbatches(
        ShapeConfig("t", 128, 2, "train"), mesh) == 1


def test_spec_to_sharding_builds_named_shardings():
    mesh = make_test_mesh(1, 1)
    tree = {"a": P(), "b": {"c": P("data")}}
    sh = shr.spec_to_sharding(tree, mesh)
    assert isinstance(sh["b"]["c"], NamedSharding)
    assert sh["b"]["c"].spec == P("data")


# ---------------------------------------------------------------------------
# step builders on a 1-device CPU mesh (in-process)
# ---------------------------------------------------------------------------

def test_one_train_step_runs_and_is_finite():
    cfg = REDUCED["llama3.2-1b"]()
    mesh = make_test_mesh(1, 1)
    shape = ShapeConfig("t", 16, 2, "train")
    params = api.init_params(cfg, jax.random.key(0))
    pav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       params)
    bav = specs.train_batch_specs(cfg, shape, 1)
    bundle = step_lib.build_train_step(cfg, mesh, pav, bav, OptConfig(),
                                       n_microbatches=1)
    opt = adamw.init_opt_state(params, 1)
    batch = global_batch_at(DataConfig(seed=0), cfg, shape, 1, 0)
    # fn donates (params, opt): hand it copies, keep the originals
    new_p, new_opt, m = bundle.fn(jax.tree.map(jnp.copy, params), opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["grad_norm"]) > 0
    assert int(new_opt["count"]) == 1
    # params actually moved
    deltas = [float(jnp.abs(a.astype(jnp.float32)
                            - b.astype(jnp.float32)).max())
              for a, b in zip(jax.tree.leaves(new_p),
                              jax.tree.leaves(params))]
    assert max(deltas) > 0


def test_prefill_then_decode_consistent_cache():
    cfg = REDUCED["llama3.2-1b"]()
    mesh = make_test_mesh(1, 1)
    B, S = 2, 8
    params = api.init_params(cfg, jax.random.key(0))
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                          cfg.vocab_size, jnp.int32)}
    pav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       params)
    bav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch)
    prefill_fn, _, c_spec = step_lib.build_prefill(cfg, mesh, pav, bav)
    cache, logits = prefill_fn(params, batch)
    assert logits.shape == (B, cfg.vocab_padded())
    # grow the cache for one decode step, then step it
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, 4), (0, 0), (0, 0)])
        if x.ndim == 5 else x, cache)
    cav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       cache)
    serve_fn, _, _ = step_lib.build_serve_step(cfg, mesh, pav, cav)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    cache2, logits2 = serve_fn(params, cache, tok, jnp.int32(S))
    assert logits2.shape == (B, cfg.vocab_padded())
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)
    # the decoded token's k was written at slot S
    assert float(jnp.abs(cache2["k"][:, :, S]).sum()) > 0


# ---------------------------------------------------------------------------
# compressed_psum error bounds (multi-device: subprocess)
# ---------------------------------------------------------------------------

def test_compressed_psum_roundtrip_error_bounds():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.dist.compress import compressed_psum
        D, n = 8, 10_000          # n not divisible by D: exercises padding
        mesh = make_mesh((D,), ("d",))
        x = jnp.asarray(np.random.default_rng(3)
                        .standard_normal((D, n)).astype(np.float32))
        want = np.asarray(x).sum(0)
        for prec, bound in [("int8", 2e-2), ("bf16", 1e-2), ("none", 1e-6)]:
            @partial(shard_map, mesh=mesh, in_specs=P("d"),
                     out_specs=P("d"))
            def f(xs, _p=prec):
                return compressed_psum(xs[0], "d", _p)[None]
            got = np.asarray(f(x))[0]
            err = np.abs(got - want).max() / np.abs(want).max()
            assert err < bound, (prec, err)
            # every device agrees on the reduced value (it's an all-reduce)
            full = np.asarray(jax.jit(f)(x))
            assert np.allclose(full, full[0:1], atol=0), prec
        print("OK")
    """)
    assert "OK" in out
