"""Per-kernel sweeps: Pallas (interpret=True) vs the pure-jnp oracle, over
shapes x dtypes x sparsity patterns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr_from_dense, loops_from_csr
from repro.kernels import ref
from repro.kernels.bcsr_spmm import bcsr_spmm_pallas
from repro.kernels.csr_spmm import csr_spmm_pallas

DTYPES = [(jnp.float32, 1e-5), (jnp.bfloat16, 3e-2)]
SHAPES = [(1, 1, 4), (7, 5, 8), (16, 16, 16), (33, 29, 32), (40, 64, 128)]
DENSITIES = [0.02, 0.2, 0.7]


def _sparse(rng, m, k, density, dtype):
    a = ((rng.random((m, k)) < density) * rng.standard_normal((m, k)))
    return np.asarray(jnp.asarray(a, dtype))


@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("density", DENSITIES)
def test_csr_kernel_matches_ref(rng, dtype, tol, m, k, n, density):
    a = _sparse(rng, m, k, density, dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    csr = csr_from_dense(a)
    row_ids = jnp.asarray(csr.row_ids)
    col_idx = jnp.asarray(csr.col_idx)
    vals = jnp.asarray(csr.vals)
    got = csr_spmm_pallas(row_ids, col_idx, vals, b, nrows=m, interpret=True)
    want = ref.csr_spmm_ref(row_ids, col_idx, vals, b, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)
    # and against the dense ground truth
    dense = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    np.testing.assert_allclose(np.asarray(got), dense, rtol=10 * tol,
                               atol=10 * tol)


@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("br", [2, 8])
def test_bcsr_kernel_matches_ref(rng, dtype, tol, m, k, n, br):
    a = _sparse(rng, m, k, 0.25, dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    fmt = loops_from_csr(csr_from_dense(a), 0, br)  # pure BCSR
    bc = fmt.bcsr_part
    got = bcsr_spmm_pallas(jnp.asarray(bc.tile_rows),
                           jnp.asarray(bc.tile_cols),
                           jnp.asarray(bc.tile_vals), b,
                           nblocks=bc.nblocks, interpret=True)
    want = ref.bcsr_spmm_ref(jnp.asarray(bc.tile_rows),
                             jnp.asarray(bc.tile_cols),
                             jnp.asarray(bc.tile_vals), b, bc.nblocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)
    dense = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    np.testing.assert_allclose(np.asarray(got)[:m], dense, rtol=10 * tol,
                               atol=10 * tol)


def test_fp64_kernels(rng):
    """FP64 path (paper's highest precision) — needs x64."""
    jax.config.update("jax_enable_x64", True)
    try:
        m, k, n = 19, 13, 8
        a = _sparse(rng, m, k, 0.3, jnp.float64)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float64)
        csr = csr_from_dense(a)
        got = csr_spmm_pallas(jnp.asarray(csr.row_ids),
                              jnp.asarray(csr.col_idx),
                              jnp.asarray(csr.vals), b, nrows=m,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(a) @ np.asarray(b), rtol=1e-12)
        assert got.dtype == jnp.float64
    finally:
        jax.config.update("jax_enable_x64", False)


def test_bn_blocking_equivalence(rng):
    """Wider bn (the multi-ZA-tile analogue) must not change results."""
    m, k, n = 24, 16, 64
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    csr = csr_from_dense(a)
    args = (jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx),
            jnp.asarray(csr.vals), b)
    outs = [csr_spmm_pallas(*args, nrows=m, bn=bn, interpret=True)
            for bn in (16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-6)


def test_out_dtype_override(rng):
    m, k, n = 8, 8, 8
    a = _sparse(rng, m, k, 0.5, jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.bfloat16)
    csr = csr_from_dense(a)
    out = csr_spmm_pallas(jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx),
                          jnp.asarray(csr.vals), b, nrows=m,
                          out_dtype=jnp.bfloat16, interpret=True)
    assert out.dtype == jnp.bfloat16
