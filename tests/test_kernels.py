"""Per-kernel sweeps: Pallas (interpret=True) vs the pure-jnp oracle, over
shapes x dtypes x sparsity patterns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr_from_dense, loops_from_csr
from repro.kernels import ref
from repro.kernels.bcsr_spmm import bcsr_spmm_pallas
from repro.kernels.csr_spmm import csr_spmm_pallas

DTYPES = [(jnp.float32, 1e-5), (jnp.bfloat16, 3e-2)]
SHAPES = [(1, 1, 4), (7, 5, 8), (16, 16, 16), (33, 29, 32), (40, 64, 128)]
DENSITIES = [0.02, 0.2, 0.7]


def _sparse(rng, m, k, density, dtype):
    a = ((rng.random((m, k)) < density) * rng.standard_normal((m, k)))
    return np.asarray(jnp.asarray(a, dtype))


@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("density", DENSITIES)
def test_csr_kernel_matches_ref(rng, dtype, tol, m, k, n, density):
    a = _sparse(rng, m, k, density, dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    csr = csr_from_dense(a)
    row_ids = jnp.asarray(csr.row_ids)
    col_idx = jnp.asarray(csr.col_idx)
    vals = jnp.asarray(csr.vals)
    got = csr_spmm_pallas(row_ids, col_idx, vals, b, nrows=m, interpret=True)
    want = ref.csr_spmm_ref(row_ids, col_idx, vals, b, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)
    # and against the dense ground truth
    dense = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    np.testing.assert_allclose(np.asarray(got), dense, rtol=10 * tol,
                               atol=10 * tol)


@pytest.mark.parametrize("dtype,tol", DTYPES)
@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("br", [2, 8])
def test_bcsr_kernel_matches_ref(rng, dtype, tol, m, k, n, br):
    a = _sparse(rng, m, k, 0.25, dtype)
    b = jnp.asarray(rng.standard_normal((k, n)), dtype)
    fmt = loops_from_csr(csr_from_dense(a), 0, br)  # pure BCSR
    bc = fmt.bcsr_part
    got = bcsr_spmm_pallas(jnp.asarray(bc.tile_rows),
                           jnp.asarray(bc.tile_cols),
                           jnp.asarray(bc.tile_vals), b,
                           nblocks=bc.nblocks, interpret=True)
    want = ref.bcsr_spmm_ref(jnp.asarray(bc.tile_rows),
                             jnp.asarray(bc.tile_cols),
                             jnp.asarray(bc.tile_vals), b, bc.nblocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)
    dense = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    np.testing.assert_allclose(np.asarray(got)[:m], dense, rtol=10 * tol,
                               atol=10 * tol)


def test_fp64_kernels(rng):
    """FP64 path (paper's highest precision) — needs x64."""
    jax.config.update("jax_enable_x64", True)
    try:
        m, k, n = 19, 13, 8
        a = _sparse(rng, m, k, 0.3, jnp.float64)
        b = jnp.asarray(rng.standard_normal((k, n)), jnp.float64)
        csr = csr_from_dense(a)
        got = csr_spmm_pallas(jnp.asarray(csr.row_ids),
                              jnp.asarray(csr.col_idx),
                              jnp.asarray(csr.vals), b, nrows=m,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(a) @ np.asarray(b), rtol=1e-12)
        assert got.dtype == jnp.float64
    finally:
        jax.config.update("jax_enable_x64", False)


def test_bn_blocking_equivalence(rng):
    """Wider bn (the multi-ZA-tile analogue) must not change results."""
    m, k, n = 24, 16, 64
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    csr = csr_from_dense(a)
    args = (jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx),
            jnp.asarray(csr.vals), b)
    outs = [csr_spmm_pallas(*args, nrows=m, bn=bn, interpret=True)
            for bn in (16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-6)


def test_out_dtype_override(rng):
    m, k, n = 8, 8, 8
    a = _sparse(rng, m, k, 0.5, jnp.bfloat16)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.bfloat16)
    csr = csr_from_dense(a)
    out = csr_spmm_pallas(jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx),
                          jnp.asarray(csr.vals), b, nrows=m,
                          out_dtype=jnp.bfloat16, interpret=True)
    assert out.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# G-wide panel kernels: adversarial panel shapes vs the jnp oracle
# ---------------------------------------------------------------------------

import contextlib

from repro.core import loops_grid_steps, loops_spmm
from repro.core.formats import panelize_bcsr, panelize_csr
from repro.kernels.bcsr_spmm import bcsr_panels_spmm_pallas
from repro.kernels.csr_spmm import csr_panels_spmm_pallas

PANEL_GS = [1, 4, 8]
PANEL_DTYPES = [(jnp.float32, 1e-5), (jnp.bfloat16, 3e-2),
                (jnp.float64, 1e-12)]


@contextlib.contextmanager
def _x64_if(dtype):
    if jnp.dtype(dtype) == jnp.float64:
        jax.config.update("jax_enable_x64", True)
        try:
            yield
        finally:
            jax.config.update("jax_enable_x64", False)
    else:
        yield


def _adversarial_cases(rng, dtype):
    """Dense matrices whose panelizations exercise every padding edge."""
    cases = {}
    # nnz not divisible by G: odd-count random fill
    a = _sparse(rng, 11, 9, 0.35, dtype)
    cases["indivisible"] = a
    # single-row matrix
    cases["single_row"] = _sparse(rng, 1, 13, 0.6, dtype)
    # one hub row spanning multiple panels (nnz >> G)
    hub = np.zeros((5, 24))
    hub[2, :] = rng.standard_normal(24)
    hub[0, 3] = 1.5
    cases["row_spans_panels"] = np.asarray(jnp.asarray(hub, dtype))
    # many short rows: a contiguous nonzero stream would let panels span row
    # boundaries — packing must pad at each boundary instead
    short = np.zeros((9, 6))
    for r in range(9):
        short[r, r % 6] = r + 1.0
        if r % 2:
            short[r, (r + 3) % 6] = -1.0
    cases["panel_at_row_boundary"] = np.asarray(jnp.asarray(short, dtype))
    return cases


@pytest.mark.parametrize("dtype,tol", PANEL_DTYPES)
@pytest.mark.parametrize("g", PANEL_GS)
def test_csr_panel_kernel_adversarial(rng, dtype, tol, g):
    with _x64_if(dtype):
        for name, a in _adversarial_cases(rng, dtype).items():
            m, k = a.shape
            b = jnp.asarray(rng.standard_normal((k, 8)), dtype)
            csr = csr_from_dense(a)
            p = panelize_csr(csr, g)
            # no panel mixes rows, all rows covered, mask marks real lanes
            assert (np.diff(p.panel_rows) >= 0).all()
            assert set(p.panel_rows.tolist()) == set(range(m))
            assert int(p.panel_mask.sum()) == csr.nnz
            got = csr_panels_spmm_pallas(
                jnp.asarray(p.panel_rows), jnp.asarray(p.panel_cols),
                jnp.asarray(p.panel_vals), jnp.asarray(p.panel_mask), b,
                nrows=m, interpret=True)
            want = ref.csr_spmm_ref(jnp.asarray(csr.row_ids),
                                    jnp.asarray(csr.col_idx),
                                    jnp.asarray(csr.vals), b, m)
            np.testing.assert_allclose(np.asarray(got, np.float64),
                                       np.asarray(want, np.float64),
                                       rtol=tol, atol=tol, err_msg=name)


@pytest.mark.parametrize("dtype,tol", PANEL_DTYPES)
@pytest.mark.parametrize("g", PANEL_GS)
def test_bcsr_panel_kernel_adversarial(rng, dtype, tol, g):
    with _x64_if(dtype):
        for name, a in _adversarial_cases(rng, dtype).items():
            m, k = a.shape
            b = jnp.asarray(rng.standard_normal((k, 8)), dtype)
            fmt = loops_from_csr(csr_from_dense(a), 0, 4, panel_g=g)
            p = fmt.bcsr_panels
            assert (np.diff(p.panel_rows) >= 0).all()
            assert set(p.panel_rows.tolist()) == set(range(p.nblocks))
            got = bcsr_panels_spmm_pallas(
                jnp.asarray(p.panel_rows), jnp.asarray(p.panel_cols),
                jnp.asarray(p.panel_vals), jnp.asarray(p.panel_mask), b,
                nblocks=p.nblocks, interpret=True)
            bc = fmt.bcsr_part
            want = ref.bcsr_spmm_ref(jnp.asarray(bc.tile_rows),
                                     jnp.asarray(bc.tile_cols),
                                     jnp.asarray(bc.tile_vals), b,
                                     bc.nblocks)
            np.testing.assert_allclose(np.asarray(got, np.float64),
                                       np.asarray(want, np.float64),
                                       rtol=tol, atol=tol, err_msg=name)


@pytest.mark.parametrize("g", PANEL_GS)
def test_hybrid_panel_parity_nondivisible(rng, g):
    """End-to-end hybrid at a br-aligned boundary, nnz not divisible by G:
    the fused single-pass output must match dense exactly."""
    m, k, n = 21, 17, 16
    a = _sparse(rng, m, k, 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 8, 8, panel_g=g)
    out = loops_spmm(fmt, b, backend="interpret")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(a, np.float32) @ np.asarray(b),
        rtol=1e-4, atol=1e-4)


def test_fused_single_pass_no_concatenate(rng):
    """Hybrid Pallas execution is single-pass: both kernels write disjoint
    row ranges of one buffer; no concatenate appears anywhere in the jaxpr
    (inner pallas jaxprs included)."""
    a = _sparse(rng, 32, 24, 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((24, 16)), jnp.float32)
    fmt = loops_from_csr(csr_from_dense(a), 16, 8, panel_g=4)
    jaxpr = jax.make_jaxpr(
        lambda bb: loops_spmm(fmt, bb, backend="interpret"))(b)
    assert "concatenate" not in str(jaxpr)


def test_empty_matrix_returns_full_zero_block(rng):
    """Zero nnz in both parts with nrows > 0 must yield (nrows, N) zeros,
    not a (0, N) stub."""
    fmt = loops_from_csr(csr_from_dense(np.zeros((7, 5), np.float32)), 0, 8)
    assert fmt.nnz == 0 and fmt.nrows == 7
    b = jnp.asarray(rng.standard_normal((5, 8)), jnp.float32)
    for backend in ("interpret", "jnp"):
        out = loops_spmm(fmt, b, backend=backend)
        assert out.shape == (7, 8)
        assert not np.asarray(out).any()


def test_grid_steps_shrink_with_g(rng):
    a = _sparse(rng, 64, 48, 0.25, jnp.float32)
    csr = csr_from_dense(a)
    steps = {g: loops_grid_steps(loops_from_csr(csr, 32, 8, panel_g=g), 32)
             for g in (1, 4, 8)}
    assert steps[8] <= steps[4] <= steps[1]
    assert steps[1] >= 2 * steps[8]  # the Fig.2 batching pays off


def test_default_br_named_constants():
    from repro.core.formats import HALF_PACKED_ROWS, SUBLANE_ROWS
    from repro.core.spmm import default_br
    assert default_br(jnp.float32) == SUBLANE_ROWS == 8
    assert default_br(jnp.float64) == SUBLANE_ROWS
    assert default_br(jnp.bfloat16) == HALF_PACKED_ROWS == 16
    assert default_br(jnp.float16) == HALF_PACKED_ROWS
