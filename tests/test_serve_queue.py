"""Fake-clock unit tests for the pure serving scheduler.

The whole point of splitting ``repro.serve`` into a policy half
(``scheduler.py``/``session.py``) and a device half (``queue.py``) is that
admission control, coalescing, fairness and deadline handling are testable
as plain Python over explicit ``now`` values.  Accordingly this file
imports **no JAX and no numpy** — a static test at the bottom pins the
policy modules to that diet too, so a future edit can't quietly drag an
array library into the decision path.

Clock convention: ``now`` is just a float the test advances by hand.
"""
import pathlib
import subprocess
import sys

import pytest

from repro.serve.scheduler import (MAX_BATCH_BLOCK, POLICIES, Decode, Group,
                                   Prefill, Scheduler, SchedulerConfig,
                                   batch_block, padded_batch)
from repro.serve.session import (ACTIVE, DONE, EVICTED, QUEUED, REJECTED,
                                 TERMINAL_STATES, Request, make_request)


def mk(prompt_len=8, gen_len=4, now=0.0, deadline_s=None):
    return make_request(prompt_len=prompt_len, gen_len=gen_len, now=now,
                        deadline_s=deadline_s)


def sched(**kw):
    return Scheduler(SchedulerConfig(**kw))


def run_prefill(s, now=0.0):
    """Poll expecting a Prefill; ack it and return the group."""
    action = s.poll(now)
    assert isinstance(action, Prefill), f"expected Prefill, got {action}"
    s.note_prefill_done(action.group.gid, now)
    return action.group


# ---------------------------------------------------------------------------
# grid mirrors: batch_block / padded_batch value tables
# ---------------------------------------------------------------------------

def test_batch_block_values():
    # largest divisor of batch that is <= MAX_BATCH_BLOCK
    assert MAX_BATCH_BLOCK == 8
    expected = {1: 1, 2: 2, 3: 3, 4: 4, 5: 5, 6: 6, 7: 7, 8: 8,
                9: 3, 10: 5, 11: 1, 12: 6, 13: 1, 16: 8, 24: 8, 40: 8}
    for batch, blk in expected.items():
        assert batch_block(batch) == blk, batch
    assert batch_block(0) == 1 and batch_block(-3) == 1


def test_padded_batch_values():
    # <= MAX_BATCH_BLOCK is never padded; awkward sizes round up to full
    # blocks only when that walks fewer grid-step groups
    for batch in range(1, MAX_BATCH_BLOCK + 1):
        assert padded_batch(batch) == batch
    expected = {9: 16, 10: 10, 11: 16, 12: 12, 13: 16, 14: 14, 16: 16}
    for batch, padded in expected.items():
        assert padded_batch(batch) == padded, batch
    assert padded_batch(0) == 0


def test_padded_batch_never_shrinks_and_stays_blocked():
    for batch in range(1, 64):
        p = padded_batch(batch)
        assert p >= batch
        assert p % batch_block(p) == 0


# ---------------------------------------------------------------------------
# session: make_request validation + latency fields
# ---------------------------------------------------------------------------

def test_make_request_validation():
    with pytest.raises(ValueError, match="prompt token ids"):
        make_request()
    with pytest.raises(ValueError, match="positive"):
        make_request(prompt_len=0)
    with pytest.raises(ValueError, match="gen_len"):
        make_request(prompt_len=4, gen_len=0)
    with pytest.raises(ValueError, match="contradicts"):
        make_request(prompt=[1, 2, 3], prompt_len=4)


def test_make_request_prompt_inference_and_rids():
    r = make_request(prompt=[5, 6, 7], gen_len=2, now=1.5)
    assert r.prompt == (5, 6, 7) and r.prompt_len == 3
    assert r.shape_key == (3,) and r.arrival_s == 1.5
    assert r.state == QUEUED and not r.finished
    r2 = make_request(prompt_len=3)
    assert r2.rid > r.rid                       # fresh ids are monotonic
    assert make_request(prompt_len=3, rid=99).rid == 99   # pinnable


def test_request_identity_not_field_equality():
    a, b = mk(), mk()
    a.rid = b.rid = 7
    assert a != b and a in [a] and b not in [a]


def test_request_latency_fields_none_until_stamped():
    r = mk(gen_len=3, now=10.0)
    assert r.queue_wait_s is None and r.ttft_s is None and r.e2e_s is None
    assert r.wall_ttft_s is None and r.wall_e2e_s is None
    r.admitted_s, r.prefill_start_s = 10.0, 12.0
    r.first_token_s, r.finish_s = 13.0, 15.0
    assert r.queue_wait_s == 2.0 and r.ttft_s == 3.0 and r.e2e_s == 5.0


def test_request_expiry_is_strict():
    r = mk(deadline_s=5.0)
    assert not r.expired(5.0) and r.expired(5.0001)
    assert not mk().expired(1e9)                # no deadline, never expires


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError, match="policy"):
        SchedulerConfig(policy="round-robin")
    with pytest.raises(ValueError, match="min_batch"):
        SchedulerConfig(min_batch=5, max_batch=4)
    with pytest.raises(ValueError, match=">= 1"):
        SchedulerConfig(max_batch=0)
    assert set(POLICIES) == {"prefill-first", "decode-first"}


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_fifo_and_counters():
    s = sched()
    reqs = [mk(now=float(i)) for i in range(3)]
    for i, r in enumerate(reqs):
        assert s.submit(r, float(i))
        assert r.admitted_s == float(i)
    assert s.queue_depth == 3 and s.counters["admitted"] == 3
    assert s.pending


def test_admission_sheds_beyond_queue_depth():
    s = sched(max_queue_depth=2)
    ok = [s.submit(mk(), 0.0) for _ in range(4)]
    assert ok == [True, True, False, False]
    assert s.queue_depth == 2 and s.counters["rejected"] == 2


def test_shed_request_is_terminal_rejected():
    s = sched(max_queue_depth=1)
    s.submit(mk(), 0.0)
    shed = mk()
    s.submit(shed, 0.0)
    assert shed.state == REJECTED and shed.finished
    assert REJECTED in TERMINAL_STATES


def test_resubmission_raises():
    s = sched()
    r = mk()
    s.submit(r, 0.0)
    with pytest.raises(ValueError, match="resubmitted"):
        s.submit(r, 1.0)
    shed_s = sched(max_queue_depth=0)
    r2 = mk()
    shed_s.submit(r2, 0.0)
    with pytest.raises(ValueError, match="resubmitted"):
        shed_s.submit(r2, 1.0)


# ---------------------------------------------------------------------------
# coalescing: shape keys, FIFO fairness, batch formation triggers
# ---------------------------------------------------------------------------

def test_idle_engine_fires_partial_batch():
    # min_batch=4 but nothing else to do -> a singleton fires immediately
    s = sched(min_batch=4, max_batch=8, max_wait_s=100.0)
    s.submit(mk(), 0.0)
    action = s.poll(0.0)
    assert isinstance(action, Prefill) and action.group.size == 1


def test_min_batch_holds_while_decode_work_exists():
    s = sched(min_batch=4, max_batch=8, max_wait_s=10.0, max_in_flight=2)
    s.submit(mk(gen_len=5), 0.0)
    g = run_prefill(s, 0.0)                     # busy group: decode pending
    for i in range(2):
        s.submit(mk(), 1.0)
    action = s.poll(1.0)                        # 2 < min_batch, not waited
    assert isinstance(action, Decode) and action.group.gid == g.gid


def test_max_wait_overrides_min_batch():
    s = sched(min_batch=4, max_batch=8, max_wait_s=10.0, max_in_flight=2)
    s.submit(mk(gen_len=5), 0.0)
    run_prefill(s, 0.0)
    s.submit(mk(), 1.0)
    s.submit(mk(), 2.0)
    action = s.poll(11.0)                       # head waited max_wait_s
    assert isinstance(action, Prefill) and action.group.size == 2


def test_full_batch_fires_and_caps_at_max_batch():
    s = sched(min_batch=3, max_batch=3, max_wait_s=100.0, max_in_flight=2)
    s.submit(mk(gen_len=5), 0.0)
    run_prefill(s, 0.0)                         # keep the engine non-idle
    for _ in range(5):
        s.submit(mk(), 1.0)
    action = s.poll(1.0)
    assert isinstance(action, Prefill) and action.group.size == 3
    assert s.queue_depth == 2                   # the overflow stays queued


def test_fifo_head_never_overtaken_by_younger_shape():
    s = sched()
    s.submit(mk(prompt_len=8), 0.0)             # lone head shape
    for _ in range(4):
        s.submit(mk(prompt_len=16), 1.0)        # younger, more popular
    action = s.poll(2.0)
    assert isinstance(action, Prefill)
    assert action.group.prompt_len == 8 and action.group.size == 1


def test_same_shape_coriders_join_past_other_shapes():
    # co-riders join the head's call; the intervening shape is NOT displaced
    # from the queue, it simply forms the next batch
    s = sched(max_in_flight=2)
    a1 = mk(prompt_len=8)
    b1 = mk(prompt_len=16)
    a2 = mk(prompt_len=8)
    for r in (a1, b1, a2):
        s.submit(r, 0.0)
    first = s.poll(0.0)
    assert isinstance(first, Prefill)
    assert first.group.requests == [a1, a2]     # a2 rode a1's batch
    assert s.queue_depth == 1
    s.note_prefill_done(first.group.gid, 0.0)
    second = s.poll(0.0)
    assert isinstance(second, Prefill) and second.group.requests == [b1]


def test_group_padding_accounting():
    s = sched(min_batch=1, max_batch=16)
    for _ in range(11):
        s.submit(mk(), 0.0)
    action = s.poll(0.0)
    g = action.group
    assert g.size == 11 and g.padded_size == padded_batch(11) == 16
    assert g.pad_slots == 5
    assert s.counters["padded_slots"] == 5
    assert s.counters["prefill_batches"] == 1


# ---------------------------------------------------------------------------
# interleave policy + in-flight limits
# ---------------------------------------------------------------------------

def test_prefill_first_prefers_new_work():
    s = sched(policy="prefill-first", max_in_flight=2)
    s.submit(mk(gen_len=5), 0.0)
    run_prefill(s, 0.0)                         # decodable group exists
    s.submit(mk(), 1.0)
    assert isinstance(s.poll(1.0), Prefill)


def test_decode_first_drains_tokens_first():
    s = sched(policy="decode-first", max_in_flight=2)
    s.submit(mk(gen_len=5), 0.0)
    g = run_prefill(s, 0.0)
    s.submit(mk(), 1.0)
    action = s.poll(1.0)
    assert isinstance(action, Decode) and action.group.gid == g.gid


def test_max_in_flight_blocks_batch_formation():
    s = sched(max_in_flight=1)
    s.submit(mk(gen_len=5), 0.0)
    g = run_prefill(s, 0.0)
    s.submit(mk(), 1.0)
    # the queued request must wait: the one slot is occupied by g
    action = s.poll(1.0)
    assert isinstance(action, Decode) and action.group.gid == g.gid
    assert s.queue_depth == 1 and s.in_flight == 1
    # draining g frees the slot
    while g.state != "done":
        s.note_decode_done(g.gid, 2.0)
    assert isinstance(s.poll(3.0), Prefill)


def test_decode_fifo_over_groups():
    s = sched(max_in_flight=3)
    s.submit(mk(prompt_len=8, gen_len=5), 0.0)
    g0 = run_prefill(s, 0.0)
    s.submit(mk(prompt_len=16, gen_len=5), 1.0)
    g1 = run_prefill(s, 1.0)
    assert g1.gid > g0.gid
    action = s.poll(2.0)
    assert isinstance(action, Decode) and action.group.gid == g0.gid
    # drain g0 -> g1 becomes the oldest decodable
    while g0.state != "done":
        s.note_decode_done(g0.gid, 2.0)
    action = s.poll(3.0)
    assert isinstance(action, Decode) and action.group.gid == g1.gid


def test_poll_empty_returns_none():
    s = sched()
    assert s.poll(0.0) is None and not s.pending


# ---------------------------------------------------------------------------
# lifecycle: completion, early exit, decode accounting
# ---------------------------------------------------------------------------

def test_gen_len_one_finishes_at_prefill():
    s = sched()
    r = mk(gen_len=1)
    s.submit(r, 0.0)
    action = s.poll(1.0)
    done = s.note_prefill_done(action.group.gid, 2.0)
    assert done == [r] and r.state == DONE
    assert r.first_token_s == 2.0 and r.finish_s == 2.0 and r.ttft_s == 2.0
    assert action.group.state == "done"
    assert s.counters["completed"] == 1 and not s.pending
    assert s.completed == [r]


def test_mixed_gen_len_early_exit_and_drain():
    s = sched(max_batch=8)
    rs = [mk(gen_len=g) for g in (1, 2, 4)]
    for r in rs:
        s.submit(r, 0.0)
    g = s.poll(0.0).group
    assert g.max_gen == 4 and g.remaining_steps == 3
    done = s.note_prefill_done(g.gid, 1.0)
    assert done == [rs[0]]                      # gen_len=1 exits at prefill
    assert s.note_decode_done(g.gid, 2.0) == [rs[1]]
    assert s.note_decode_done(g.gid, 3.0) == []
    assert s.note_decode_done(g.gid, 4.0) == [rs[2]]
    assert g.state == "done" and g.steps_done == 3
    assert [r.state for r in rs] == [DONE, DONE, DONE]
    assert s.counters["decode_steps"] == 3
    assert s.completed == rs                    # completion order == exits


def test_group_drains_when_all_members_exit_early():
    # remaining_steps > 0 but nobody is active -> no wasted decode steps
    s = sched()
    a, b = mk(gen_len=2), mk(gen_len=2)
    s.submit(a, 0.0)
    s.submit(b, 0.0)
    g = s.poll(0.0).group
    s.note_prefill_done(g.gid, 0.0)
    assert s.note_decode_done(g.gid, 1.0) == [a, b]
    assert g.state == "done" and s.poll(2.0) is None


def test_completion_callbacks_validate_group_state():
    s = sched()
    s.submit(mk(gen_len=3), 0.0)
    g = s.poll(0.0).group
    with pytest.raises(ValueError, match="not decoding"):
        s.note_decode_done(g.gid, 0.0)
    s.note_prefill_done(g.gid, 0.0)
    with pytest.raises(ValueError, match="not awaiting prefill"):
        s.note_prefill_done(g.gid, 1.0)


def test_single_request_lifecycle_latency_contract():
    s = sched()
    r = mk(gen_len=3, now=1.0)
    s.submit(r, 2.0)
    g = s.poll(5.0).group
    assert r.state == ACTIVE and r.prefill_start_s == 5.0
    assert r.queue_wait_s == 3.0
    s.note_prefill_done(g.gid, 6.0)
    assert r.ttft_s == 5.0                      # first token - arrival
    s.note_decode_done(g.gid, 7.0)
    s.note_decode_done(g.gid, 8.0)
    assert r.state == DONE and r.e2e_s == 7.0


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_queued_deadline_eviction_on_poll():
    s = sched()
    stale = mk(deadline_s=5.0)
    fresh = mk(deadline_s=50.0)
    s.submit(stale, 0.0)
    s.submit(fresh, 0.0)
    action = s.poll(10.0)                       # stale expired while queued
    assert stale.state == EVICTED and stale.finish_s == 10.0
    assert isinstance(action, Prefill) and action.group.requests == [fresh]
    assert s.counters["evicted"] == 1
    assert stale not in s.completed             # evictions are not completions


def test_active_deadline_eviction_at_step_boundary():
    s = sched()
    doomed = mk(gen_len=10, deadline_s=2.0)
    rider = mk(gen_len=3)
    s.submit(doomed, 0.0)
    s.submit(rider, 0.0)
    g = s.poll(0.0).group
    s.note_prefill_done(g.gid, 1.0)
    done = s.note_decode_done(g.gid, 5.0)       # past doomed's deadline
    assert done == []                           # evictions aren't returned
    assert doomed.state == EVICTED and doomed in g.requests
    assert g.active_requests == [rider]         # the group keeps stepping
    assert s.note_decode_done(g.gid, 6.0) == [rider]
    assert g.state == "done" and s.counters["evicted"] == 1


def test_eviction_of_whole_queue_leaves_scheduler_idle():
    s = sched()
    for _ in range(3):
        s.submit(mk(deadline_s=1.0), 0.0)
    assert s.poll(2.0) is None
    assert s.counters["evicted"] == 3 and not s.pending


# ---------------------------------------------------------------------------
# introspection invariants
# ---------------------------------------------------------------------------

def test_counters_and_gauges_track_a_full_run():
    s = sched(max_queue_depth=3, max_in_flight=2)
    for _ in range(4):
        s.submit(mk(gen_len=2), 0.0)            # 4th is shed
    assert s.counters == {
        "admitted": 3, "rejected": 1, "evicted": 0, "completed": 0,
        "prefill_batches": 0, "decode_steps": 0, "padded_slots": 0}
    g = s.poll(0.0).group
    assert s.in_flight == 1 and s.active_requests == 3
    s.note_prefill_done(g.gid, 1.0)
    s.note_decode_done(g.gid, 2.0)
    assert s.counters["completed"] == 3 and s.in_flight == 0
    assert not s.pending and s.group(g.gid) is g


def test_group_remaining_steps_floor_at_zero():
    g = Group(gid=0, requests=[], prompt_len=8, max_gen=1, padded_size=1,
              formed_s=0.0)
    assert g.remaining_steps == 0
    g.steps_done = 5
    assert g.remaining_steps == 0


# ---------------------------------------------------------------------------
# the policy layer stays JAX-free (and numpy-free)
# ---------------------------------------------------------------------------

def test_policy_modules_import_no_array_library():
    import repro.serve as serve_pkg
    pkg_dir = pathlib.Path(serve_pkg.__file__).parent
    for name in ("__init__.py", "scheduler.py", "session.py"):
        src = (pkg_dir / name).read_text()
        for banned in ("import jax", "import numpy"):
            assert banned not in src, f"{name} must stay array-free"


def test_serve_package_import_pulls_no_jax():
    # fresh interpreter: importing the policy package must not load jax
    code = ("import sys; import repro.serve; "
            "assert 'jax' not in sys.modules, 'repro.serve imported jax'; "
            "assert 'numpy' not in sys.modules")
    subprocess.run([sys.executable, "-c", code], check=True,
                   cwd=str(pathlib.Path(__file__).resolve().parent.parent),
                   env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
