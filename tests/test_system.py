"""End-to-end behaviour of the paper's system (LOOPS pipeline, Fig. 1):
statistics -> perf model -> boundary -> conversion -> hybrid execution,
plus the GCN case-study operator (§4.5) and the CLI drivers."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (csr_from_dense, csr_to_dense, loops_spmm,
                        plan_and_convert, row_stats, suite)
from repro.core.perf_model import calibrate

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_full_pipeline_on_skewed_matrix():
    """The paper's whole point: a matrix with hub rows AND a regular region
    runs correctly through the adaptive hybrid path."""
    top = csr_to_dense(suite.powerlaw(64, 256, 8.0, seed=0))
    bot = csr_to_dense(suite.banded(192, 256, 4, seed=1))
    dense = np.concatenate([top, bot], axis=0).astype(np.float32)
    csr = csr_from_dense(dense)
    stats = row_stats(csr)
    assert stats.nnz_std > 0

    # calibrate a perf model from (synthetic) warm-up measurements: vector
    # unit scales linearly, matrix unit contends past 2 workers
    def measure(x, y):
        return x * 1.0 + min(y, 2) * 4.0 + max(y - 2, 0) * 0.5

    model = calibrate(measure, total=8)
    fmt, plan = plan_and_convert(csr, total_workers=8, model=model)
    assert plan.t_vpu + plan.t_mxu <= 8
    b = jnp.asarray(np.random.default_rng(2)
                    .standard_normal((256, 32)).astype(np.float32))
    out = loops_spmm(fmt, b, backend="jnp")
    np.testing.assert_allclose(np.asarray(out), dense @ np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_gcn_aggregation_operator():
    """GCN feature aggregation (paper §4.5): hat(A) @ H via LOOPS."""
    adj = suite.gcn_graph(128, 4, seed=0)
    h = jnp.asarray(np.random.default_rng(1)
                    .standard_normal((128, 16)).astype(np.float32))
    fmt, _ = plan_and_convert(adj, total_workers=4)
    agg = loops_spmm(fmt, h, backend="jnp")
    want = csr_to_dense(adj) @ np.asarray(h)
    np.testing.assert_allclose(np.asarray(agg), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("driver,extra", [
    ("repro.launch.train",
     ["--steps", "3", "--seq-len", "32", "--global-batch", "2",
      "--ckpt-every", "0", "--log-every", "1"]),
    ("repro.launch.serve",
     ["--batch", "2", "--prompt-len", "8", "--gen-len", "4"]),
])
def test_cli_drivers(tmp_path, driver, extra):
    cmd = [sys.executable, "-m", driver, "--arch", "llama3.2-1b",
           "--reduced"] + extra
    if driver.endswith("train"):
        cmd += ["--ckpt-dir", str(tmp_path)]
    res = subprocess.run(cmd, env={**os.environ, "PYTHONPATH": _SRC},
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
