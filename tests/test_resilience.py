"""Chaos coverage for the resilience layer (docs/robustness.md).

Each documented degradation path is *proved* here: install a seeded
:class:`repro.resilience.FaultPlan` at the site, assert the fallback fires
(counter on a live obs capture), and — for the compute paths — that the
degraded result still matches the jnp oracle bit-for-semantics.  The kill
switch (``fallback.disabled()``) is asserted to re-raise, so error-path
tests elsewhere keep their semantics.
"""
from __future__ import annotations

import importlib.util
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.formats import csr_from_coo, csr_from_dense, loops_from_csr
from repro.core.spmm import loops_spmm, plan_and_convert
from repro.kernels import engine
from repro.obs import Obs, set_active
from repro.resilience import fallback, inject, validate
from repro.resilience.fallback import DeadlineExceeded, retry_with_backoff
from repro.resilience.inject import FaultClause, FaultPlan, InjectedFault
from repro.tune import PlanCache, SearchBudget, autotune
from repro.tune import cache as cache_mod
from repro.tune.search import search

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

ROOT = pathlib.Path(__file__).resolve().parent.parent


def random_sparse(rng, m, k, density=0.3, dtype=np.float32):
    a = (rng.random((m, k)) < density) * rng.standard_normal((m, k))
    return a.astype(dtype)


@pytest.fixture(autouse=True)
def _clean_resilience():
    """No fault plan / default policy / no capture leaks across tests."""
    yield
    inject.set_plan(None)
    fallback.set_policy(fallback.FallbackPolicy())
    set_active(None)


def _counter_total(obs, name, **labels):
    total = 0.0
    for kind, inst in obs.metrics.instruments():
        if kind == "counter" and inst.name == name and all(
                inst.labels.get(k) == v for k, v in labels.items()):
            total += inst.value
    return total


# ---------------------------------------------------------------------------
# FaultPlan: parsing, counting, determinism
# ---------------------------------------------------------------------------

def test_fault_plan_parse_full_syntax():
    p = FaultPlan.parse("seed=7; engine.*.interpret:raise:0 ;"
                        "cache.read:corrupt-bytes:1:0")
    assert p.seed == 7
    assert p.clauses == (
        FaultClause("engine.*.interpret", "raise", 0, 1),
        FaultClause("cache.read", "corrupt-bytes", 1, 0))


def test_fault_plan_rejects_bad_kind_and_bad_clause():
    with pytest.raises(ValueError):
        FaultPlan.parse("site:explode")
    with pytest.raises(ValueError):
        FaultPlan.parse("just-a-site")


def test_fault_clause_nth_and_count_window():
    c = FaultClause("s", "raise", nth=1, count=2)
    assert [c.fires(n) for n in range(5)] == [False, True, True, False,
                                              False]
    every = FaultClause("s", "raise", nth=2, count=0)
    assert [every.fires(n) for n in range(5)] == [False, False, True, True,
                                                  True]


def test_fault_point_counts_per_site_and_resets():
    plan = FaultPlan.parse("s:raise:1")
    inject.set_plan(plan)
    assert inject.fault_point("s", "ok") == "ok"      # call 0: below nth
    with pytest.raises(InjectedFault):
        inject.fault_point("s")                        # call 1: fires
    assert inject.fault_point("s", "ok") == "ok"      # call 2: past window
    plan.reset()
    assert inject.fault_point("s", "ok") == "ok"      # counting restarts
    with pytest.raises(InjectedFault):
        inject.fault_point("s")


def test_corrupt_bytes_is_deterministic_and_unparseable():
    payload = json.dumps({"k": list(range(64))}).encode()
    inject.set_plan(FaultPlan.parse("seed=3;blob:corrupt-bytes:0:0"))
    a = inject.fault_point("blob", payload)
    inject.get_plan().reset()
    b = inject.fault_point("blob", payload)
    assert a == b and a != payload
    with pytest.raises(ValueError):
        json.loads(a.decode("utf-8", errors="replace"))


def test_nan_values_is_deterministic_on_numpy():
    x = np.ones((8, 8), np.float32)
    inject.set_plan(FaultPlan.parse("seed=5;w:nan-values:0:0"))
    a = inject.fault_point("w", x)
    inject.get_plan().reset()
    b = inject.fault_point("w", x)
    assert np.isnan(a).any() and not np.isnan(x).any()   # input untouched
    assert np.array_equal(np.isnan(a), np.isnan(b))


def test_install_from_env_and_disabled_state():
    assert inject.install_from_env({}) is None
    plan = inject.install_from_env({inject.ENV_VAR: "s:raise"})
    assert plan is not None and inject.get_plan() is plan
    inject.set_plan(None)
    assert inject.fault_point("s", 1) == 1    # no plan: pure pass-through


# ---------------------------------------------------------------------------
# Engine fallback chains: injected kernel faults degrade to the jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,density", [((32, 24), 0.3)])
def test_csr_part_falls_back_to_oracle(rng, shape, density):
    csr = csr_from_dense(random_sparse(rng, *shape, density))
    fmt = loops_from_csr(csr, csr.nrows, 4)            # pure CSR part
    b = jnp.asarray(rng.standard_normal((shape[1], 8)).astype(np.float32))
    ref = loops_spmm(fmt, b, backend="jnp")
    obs = Obs(source="t")
    set_active(obs)
    inject.set_plan(FaultPlan.parse("engine.csr.spmm.interpret:raise:0:0"))
    got = loops_spmm(fmt, b, backend="interpret")
    assert jnp.allclose(got, ref, atol=1e-5)
    assert _counter_total(obs, "engine.fallback", part="csr",
                          op="spmm") >= 1
    assert _counter_total(obs, "inject.fired") >= 1


def test_bcsr_part_falls_back_to_oracle(rng):
    csr = csr_from_dense(random_sparse(rng, 32, 24))
    fmt = loops_from_csr(csr, 0, 4)                    # pure BCSR part
    b = jnp.asarray(rng.standard_normal((24, 8)).astype(np.float32))
    ref = loops_spmm(fmt, b, backend="jnp")
    obs = Obs(source="t")
    set_active(obs)
    inject.set_plan(FaultPlan.parse("engine.bcsr.spmm.interpret:raise:0:0"))
    got = loops_spmm(fmt, b, backend="interpret")
    assert jnp.allclose(got, ref, atol=1e-5)
    assert _counter_total(obs, "engine.fallback", part="bcsr",
                          op="spmm") >= 1


def test_fused_exhaustion_degrades_to_parts_path(rng):
    csr = csr_from_dense(random_sparse(rng, 32, 24))
    fmt = loops_from_csr(csr, 16, 4)                   # hybrid, aligned
    assert fmt.r_boundary % 4 == 0 and 0 < fmt.r_boundary < fmt.nrows
    b = jnp.asarray(rng.standard_normal((24, 8)).astype(np.float32))
    ref = loops_spmm(fmt, b, backend="jnp")
    obs = Obs(source="t")
    set_active(obs)
    inject.set_plan(FaultPlan.parse("engine.fused.spmm.*:raise:0:0"))
    got = loops_spmm(fmt, b, backend="interpret")
    assert jnp.allclose(got, ref, atol=1e-5)
    assert _counter_total(obs, "engine.fallback", part="fused",
                          op="spmm") >= 1
    # the parts path itself stayed healthy: no csr/bcsr fallbacks
    assert _counter_total(obs, "engine.fallback", part="csr") == 0


def test_sdd_falls_back_to_oracle(rng):
    csr = csr_from_dense(random_sparse(rng, 16, 12))
    fmt = loops_from_csr(csr, 8, 4)
    dy = jnp.asarray(rng.standard_normal((16, 4)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((12, 4)).astype(np.float32))
    ref = engine.loops_sdd(fmt, dy, b, backend="jnp")
    obs = Obs(source="t")
    set_active(obs)
    inject.set_plan(FaultPlan.parse("engine.loops.sdd.interpret:raise:0:0"))
    got = engine.loops_sdd(fmt, dy, b, backend="interpret")
    for g, r in zip(got, ref):
        assert jnp.allclose(g, r, atol=1e-5)
    assert _counter_total(obs, "engine.fallback", part="loops",
                          op="sdd") >= 1


def test_kill_switch_propagates_the_failure(rng):
    csr = csr_from_dense(random_sparse(rng, 16, 12))
    fmt = loops_from_csr(csr, csr.nrows, 4)
    b = jnp.asarray(rng.standard_normal((12, 4)).astype(np.float32))
    inject.set_plan(FaultPlan.parse("engine.csr.spmm.interpret:raise:0:0"))
    with fallback.disabled():
        with pytest.raises(Exception):
            loops_spmm(fmt, b, backend="interpret")
    # same plan, chains re-enabled: degrades instead
    inject.get_plan().reset()
    ref = loops_spmm(fmt, b, backend="jnp")
    assert jnp.allclose(loops_spmm(fmt, b, backend="interpret"), ref,
                        atol=1e-5)


def test_no_fallback_env_kill_switch():
    assert fallback.FallbackPolicy().chain_for("csr", "spmm", "pallas") == \
        ("pallas", "interpret", "jnp")
    assert fallback.FallbackPolicy(enabled=False).chain_for(
        "csr", "spmm", "pallas") == ("pallas",)
    # a caller already on a degraded link never climbs back up
    assert fallback.FallbackPolicy().chain_for("csr", "spmm", "jnp") == \
        ("jnp",)
    assert fallback.FallbackPolicy().chain_for("fused", "spmm", "pallas") \
        == ("pallas", "interpret")


# ---------------------------------------------------------------------------
# Plan-cache resilience: quarantine, read-retry, merge-on-save
# ---------------------------------------------------------------------------

def _rec(gflops=1.0):
    from repro.tune.api import make_record
    return make_record([0.0] * 4, dtype=np.float32, n_cols=8, backend="jnp",
                       r_frac=0.5, t_vpu=2, t_mxu=6, br=4, gflops=gflops)


def test_cache_corrupt_file_is_quarantined(tmp_path, monkeypatch):
    monkeypatch.setattr(cache_mod, "_retry_sleep", lambda s: None)
    f = tmp_path / "plans.json"
    f.write_text("{not json")
    c = PlanCache(str(tmp_path))
    assert c.get("k") is None
    assert c.stats.quarantined == 1
    assert (tmp_path / "plans.json.quarantined").exists()
    assert not f.exists()
    c.put("k", _rec())                         # cache heals
    assert PlanCache(str(tmp_path)).peek("k") is not None


def test_cache_reader_racing_writer_retries_not_quarantines(tmp_path,
                                                            monkeypatch):
    """Regression: a half-written blob must be re-read, not quarantined."""
    f = tmp_path / "plans.json"
    good = json.dumps({"version": cache_mod.CACHE_VERSION,
                       "entries": {"k": _rec()}})
    f.write_text(good[: len(good) // 2])       # torn write in flight

    def finish_write(_delay):                  # the writer completes
        f.write_text(good)

    monkeypatch.setattr(cache_mod, "_retry_sleep", finish_write)
    c = PlanCache(str(tmp_path))
    assert c.peek("k") is not None
    assert c.stats.quarantined == 0
    assert not (tmp_path / "plans.json.quarantined").exists()


def test_cache_injected_corruption_quarantines_and_counts(tmp_path,
                                                          monkeypatch):
    monkeypatch.setattr(cache_mod, "_retry_sleep", lambda s: None)
    c = PlanCache(str(tmp_path))
    c.put("k", _rec())
    obs = Obs(source="t")
    set_active(obs)
    inject.set_plan(FaultPlan.parse("cache.read:corrupt-bytes:0:0"))
    c2 = PlanCache(str(tmp_path))              # fresh instance: re-reads
    assert c2.get("k") is None
    assert c2.stats.quarantined == 1
    assert _counter_total(obs, "tune.cache.quarantined") >= 1
    assert _counter_total(obs, "inject.fired") >= 1


def test_cache_concurrent_writers_both_survive(tmp_path):
    c1 = PlanCache(str(tmp_path))
    c2 = PlanCache(str(tmp_path))
    c2._load()                                 # c2 snapshots BEFORE c1 writes
    c1.put("a", _rec(1.0))
    c2.put("b", _rec(2.0))                     # merge-on-save folds "a" in
    fresh = PlanCache(str(tmp_path))
    assert fresh.peek("a") is not None and fresh.peek("b") is not None


def test_cache_clear_does_not_resurrect(tmp_path):
    c1 = PlanCache(str(tmp_path))
    c1.put("a", _rec())
    c2 = PlanCache(str(tmp_path))
    c2.clear()
    assert PlanCache(str(tmp_path)).peek("a") is None


# ---------------------------------------------------------------------------
# Tuner: trial isolation + all-fail degraded plan
# ---------------------------------------------------------------------------

def _cheap_measure(csr, p, b):
    fmt = loops_from_csr(csr, p.r_boundary, p.br, panel_g=p.panel_g)
    return fmt, 1.0 + p.r_boundary / max(csr.nrows, 1)


def test_search_skips_failed_trial_and_counts_it(rng):
    csr = csr_from_dense(random_sparse(rng, 32, 16))
    obs = Obs(source="t")
    set_active(obs)
    inject.set_plan(FaultPlan.parse("tune.trial:raise:0"))   # first only
    res = search(csr, n_cols=8, budget=SearchBudget(top_k=3),
                 measure=_cheap_measure)
    assert res.gflops > 0 and res.measured >= 1
    assert _counter_total(obs, "tune.search.trial_failed") == 1
    assert _counter_total(obs, "tune.search.degraded") == 0


def test_search_all_trials_failed_degrades_to_model_plan(rng):
    csr = csr_from_dense(random_sparse(rng, 32, 16))
    obs = Obs(source="t")
    set_active(obs)

    def boom(c, p, bb):
        raise RuntimeError("measurement backend down")

    res = search(csr, n_cols=8, budget=SearchBudget(top_k=3), measure=boom)
    assert res.measured == 0 and res.gflops == 0.0
    assert res.plan is not None and res.fmt is not None
    b = jnp.asarray(rng.standard_normal((16, 8)).astype(np.float32))
    assert loops_spmm(res.fmt, b).shape == (32, 8)
    assert _counter_total(obs, "tune.search.degraded") == 1
    assert _counter_total(obs, "tune.search.trial_failed") == 3


def test_search_trial_timeout_counts_as_failed(rng):
    csr = csr_from_dense(random_sparse(rng, 32, 16))
    obs = Obs(source="t")
    set_active(obs)
    res = search(csr, n_cols=8,
                 budget=SearchBudget(top_k=2, trial_timeout_s=0.0),
                 measure=_cheap_measure)       # any elapsed > 0.0 overruns
    assert res.gflops == 0.0                   # every trial timed out
    assert _counter_total(obs, "tune.search.trial_failed",
                          reason="timeout") == 2


def test_autotune_on_miss_model_skips_measurement(tmp_path, rng):
    csr = csr_from_dense(random_sparse(rng, 32, 16))
    cache = PlanCache(str(tmp_path))

    def forbidden(c, p, bb):                   # pragma: no cover
        raise AssertionError("on_miss='model' must never measure")

    fmt, plan = autotune(csr, n_cols=8, cache=cache, on_miss="model")
    assert cache.stats.misses == 1
    rec = next(iter(cache._load().values()))
    assert rec["gflops"] == 0.0 and rec["trials"] == 0
    fmt2, plan2 = autotune(csr, n_cols=8, cache=cache, on_miss="model")
    assert cache.stats.hits == 1 and plan2 == plan
    with pytest.raises(ValueError):
        autotune(csr, n_cols=8, cache=cache, on_miss="yolo")


# ---------------------------------------------------------------------------
# retry_with_backoff / deadlines
# ---------------------------------------------------------------------------

def test_retry_with_backoff_recovers_and_reports():
    calls, retries = [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    out = retry_with_backoff(flaky, retries=3, backoff_s=0.001,
                             on_retry=lambda n, e: retries.append(n))
    assert out == "ok" and len(calls) == 3 and retries == [1, 2]


def test_retry_with_backoff_exhaustion_reraises():
    def always():
        raise ValueError("permanent")

    with pytest.raises(ValueError, match="permanent"):
        retry_with_backoff(always, retries=1, backoff_s=0.001)


def test_retry_deadline_raises_instead_of_sleeping_past():
    def always():
        raise OSError("down")

    with pytest.raises(DeadlineExceeded):
        retry_with_backoff(always, retries=50, backoff_s=10.0,
                           deadline_s=0.01)


# ---------------------------------------------------------------------------
# Validated ingestion
# ---------------------------------------------------------------------------

def _toy_csr(rng):
    return csr_from_dense(random_sparse(rng, 16, 12, 0.4))


def test_validate_classifies_each_defect(rng):
    import dataclasses
    csr = _toy_csr(rng)

    bad_ptr = csr.row_ptr.copy()
    bad_ptr[2] = bad_ptr[1] - 1 if bad_ptr[1] > 0 else bad_ptr[3] + 99
    kinds = validate.csr_defects(bad_ptr, csr.col_idx, csr.vals, csr.shape)
    assert "nonmonotone-indptr" in kinds

    oob = csr.col_idx.copy()
    oob[0] = csr.shape[1] + 5
    with pytest.raises(validate.SparseInputError) as ei:
        validate.validate_csr(dataclasses.replace(csr, col_idx=oob))
    assert ei.value.kind == "out-of-range-index"

    neg = csr.col_idx.copy()
    neg[0] = -1
    with pytest.raises(validate.SparseInputError) as ei:
        validate.validate_csr(dataclasses.replace(csr, col_idx=neg))
    assert ei.value.kind == "negative-index"

    nanv = csr.vals.copy()
    nanv[0] = np.nan
    with pytest.raises(validate.SparseInputError) as ei:
        validate.validate_csr(dataclasses.replace(csr, vals=nanv))
    assert ei.value.kind == "nonfinite-value"


def test_validate_repair_drop_yields_clean_csr(rng):
    import dataclasses
    csr = _toy_csr(rng)
    bad_cols = csr.col_idx.copy()
    bad_cols[0] = csr.shape[1] + 3
    bad_vals = csr.vals.copy()
    bad_vals[1] = np.inf
    bad = dataclasses.replace(csr, col_idx=bad_cols, vals=bad_vals)
    obs = Obs(source="t")
    set_active(obs)
    fixed, report = validate.validate_csr(bad, repair="drop")
    assert report.repaired and not validate.csr_defects(
        fixed.row_ptr, fixed.col_idx, fixed.vals, fixed.shape)
    assert _counter_total(obs, "validate.repaired") >= 1
    # repaired matrix still multiplies
    b = jnp.ones((fixed.shape[1], 4), jnp.float32)
    fmt = loops_from_csr(fixed, fixed.nrows, 4)
    assert loops_spmm(fmt, b).shape == (fixed.shape[0], 4)


def test_csr_from_coo_rejects_and_repairs_bad_coords():
    rows = np.array([0, 1, -1, 2])
    cols = np.array([0, 9, 1, 2])              # 9 is OOB for shape (4, 4)
    vals = np.ones(4, np.float32)
    with pytest.raises(validate.SparseInputError):
        csr_from_coo(rows, cols, vals, (4, 4))
    csr = csr_from_coo(rows, cols, vals, (4, 4), validate="drop")
    # two bad entries dropped (remaining stored entries are empty-row padding)
    assert int(np.count_nonzero(csr.vals)) == 2
    dense = np.zeros((4, 4), np.float32)
    dense[0, 0] = dense[2, 2] = 1.0
    b = np.eye(4, dtype=np.float32)
    fmt = loops_from_csr(csr, csr.nrows, 2)
    assert np.allclose(np.asarray(loops_spmm(fmt, jnp.asarray(b))), dense)


def test_plan_and_convert_validates_strictly(rng):
    import dataclasses
    csr = _toy_csr(rng)
    bad = dataclasses.replace(csr, vals=np.where(
        np.arange(csr.vals.size) == 0, np.nan, csr.vals).astype(np.float32))
    with pytest.raises(validate.SparseInputError):
        plan_and_convert(bad)
    fmt, plan = plan_and_convert(bad, validate="clip")   # repaired instead
    assert fmt is not None and plan is not None


def test_validate_loops_checks_both_parts(rng):
    csr = _toy_csr(rng)
    fmt = loops_from_csr(csr, 8, 4)
    validate.validate_loops(fmt)               # clean format passes
    import dataclasses
    bad_part = dataclasses.replace(
        fmt.bcsr_part, tile_vals=np.full_like(fmt.bcsr_part.tile_vals,
                                              np.nan))
    with pytest.raises(validate.SparseInputError):
        validate.validate_loops(dataclasses.replace(fmt,
                                                    bcsr_part=bad_part))


def test_check_finite_tree_flags_nan_checkpoint():
    good = {"a": np.ones(3, np.float32), "b": {"c": jnp.zeros(2)}}
    validate.check_finite_tree(good)
    bad = {"a": np.array([1.0, np.nan], np.float32)}
    with pytest.raises(validate.SparseInputError) as ei:
        validate.check_finite_tree(bad, what="restored params")
    assert "restored params" in str(ei.value)


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_validate_property_classify_or_accept():
    from hypothesis import given, strategies as st

    @given(st.data())
    def run(data):
        n_rows = data.draw(st.integers(1, 6))
        n_cols = data.draw(st.integers(1, 6))
        nnz = data.draw(st.integers(0, 8))
        ptr_steps = data.draw(st.lists(st.integers(-2, 4),
                                       min_size=n_rows, max_size=n_rows))
        row_ptr = np.concatenate([[0], np.cumsum(ptr_steps)]).astype(
            np.int64)
        row_ptr = np.clip(row_ptr, -3, nnz + 3)
        row_ptr[-1] = nnz
        col_idx = np.asarray(data.draw(st.lists(
            st.integers(-2, n_cols + 1), min_size=nnz, max_size=nnz)),
            np.int64)
        vals = np.asarray(data.draw(st.lists(
            st.sampled_from([0.0, 1.0, np.nan, np.inf]),
            min_size=nnz, max_size=nnz)), np.float32)
        kinds = validate.csr_defects(row_ptr, col_idx, vals,
                                     (n_rows, n_cols))
        for k in kinds:       # every defect is in the documented taxonomy
            assert k in validate.DEFECT_KINDS
        import dataclasses

        from repro.core.formats import CSR
        if "length-mismatch" in kinds:
            return            # unrepairable by construction
        csr = CSR(row_ptr=row_ptr, col_idx=col_idx, vals=vals,
                  row_ids=np.arange(n_rows), shape=(n_rows, n_cols)) \
            if hasattr(CSR, "row_ids") else None
        if csr is None:
            return
        if kinds:
            with pytest.raises(validate.SparseInputError):
                validate.validate_csr(csr)
        fixed, _ = validate.validate_csr(csr, repair="drop")
        assert not validate.csr_defects(fixed.row_ptr, fixed.col_idx,
                                        fixed.vals, fixed.shape)

    run()


# ---------------------------------------------------------------------------
# Collective fallback (multi-device: subprocess)
# ---------------------------------------------------------------------------

def test_compressed_psum_falls_back_to_plain(tmp_path):
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
           "PYTHONPATH": str(ROOT / "src")}
    body = """
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.compat import make_mesh, shard_map
        from repro.dist.compress import compressed_psum
        from repro.obs import Obs, set_active
        from repro.resilience.inject import FaultPlan, set_plan

        mesh = make_mesh((2,), ("d",))
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((2, 64)).astype(np.float32))
        want = np.asarray(x).sum(0)
        obs = Obs(source="t")
        set_active(obs)
        set_plan(FaultPlan.parse("dist.psum.int8:raise:0:0"))

        @partial(shard_map, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
        def f(xs):
            return compressed_psum(xs[0], "d", "int8")[None]

        got = np.asarray(f(x))
        assert np.allclose(got[0], want, atol=1e-5)      # exact fp32 psum
        c = sum(inst.value for kind, inst in obs.metrics.instruments()
                if kind == "counter" and inst.name == "dist.fallback")
        assert c >= 1, c
        print("OK")
    """
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=300)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


# ---------------------------------------------------------------------------
# obs_report: degradations section and gates
# ---------------------------------------------------------------------------

def _saved_capture(tmp_path, *, degraded: bool):
    obs = Obs(source="gate-test")
    obs.counter("engine.dispatch", part="csr", op="spmm").inc(3)
    if degraded:
        obs.counter("engine.fallback", part="csr", op="spmm",
                    reason="injected").inc(2)
        obs.counter("tune.cache.quarantined").inc(1)
    jsonl, _ = obs.save(str(tmp_path), stem="gate")
    return jsonl


def _report(path, *flags):
    return subprocess.run(
        [sys.executable, str(ROOT / "tools" / "obs_report.py"), str(path),
         *flags],
        capture_output=True, text=True, timeout=120)


def test_obs_report_degradation_gates(tmp_path):
    clean = _saved_capture(tmp_path / "clean", degraded=False)
    dirty = _saved_capture(tmp_path / "dirty", degraded=True)

    r = _report(clean, "--fail-on-degraded")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "degradations" not in r.stdout

    r = _report(dirty, "--fail-on-degraded")
    assert r.returncode == 4, r.stdout + r.stderr
    assert "engine.fallback" in r.stdout

    r = _report(dirty, "--require-degraded", "engine.fallback",
                "--require-degraded", "tune.cache.quarantined")
    assert r.returncode == 0, r.stdout + r.stderr

    r = _report(clean, "--require-degraded", "engine.fallback")
    assert r.returncode == 5, r.stdout + r.stderr
