"""repro.tune: fingerprint stability, cache persistence/invalidation,
model-pruned search correctness, and end-to-end autotune numerics."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr_from_dense, csr_to_dense, loops_spmm, suite
from repro.core.spmm import SpmmPlan, plan_and_convert
from repro.tune import (CACHE_VERSION, PlanCache, SearchBudget, Tuner,
                        autotune, cache_key, enumerate_plans,
                        feature_distance, fingerprint, search)
from repro.tune import api as tune_api


def _dense(seed, m, k, density):
    rng = np.random.default_rng(seed)
    return ((rng.random((m, k)) < density)
            * rng.standard_normal((m, k))).astype(np.float32)


# ---------------------------------------------------------------------------
# fingerprint
# ---------------------------------------------------------------------------

def test_fingerprint_stable_across_reconstruction():
    """Same structure -> identical fingerprint and key, however built."""
    a = _dense(0, 64, 48, 0.2)
    fp1 = fingerprint(csr_from_dense(a))
    fp2 = fingerprint(csr_from_dense(csr_to_dense(csr_from_dense(a))))
    assert fp1 == fp2
    k1 = cache_key(fp1, n_cols=32, dtype=np.float32, backend="jnp")
    k2 = cache_key(fp2, n_cols=32, dtype=np.float32, backend="jnp")
    assert k1 == k2


def test_fingerprint_value_invariant():
    """Fingerprints key on structure, not values (pruned layers share)."""
    a = _dense(1, 32, 32, 0.3)
    b = a * 3.5
    assert fingerprint(csr_from_dense(a)) == fingerprint(csr_from_dense(b))


def test_fingerprint_sensitive_to_structure():
    band = suite.banded(256, 256, 4, seed=0)
    power = suite.powerlaw(256, 256, 6.0, seed=0)
    fpb, fpp = fingerprint(band), fingerprint(power)
    assert feature_distance(fpb.features(), fpp.features()) > 0.25
    assert cache_key(fpb, n_cols=32, dtype=np.float32, backend="jnp") != \
        cache_key(fpp, n_cols=32, dtype=np.float32, backend="jnp")


def test_cache_key_separates_execution_context():
    fp = fingerprint(suite.banded(128, 128, 3, seed=0))
    base = cache_key(fp, n_cols=32, dtype=np.float32, backend="jnp")
    assert base != cache_key(fp, n_cols=64, dtype=np.float32, backend="jnp")
    assert base != cache_key(fp, n_cols=32, dtype=jnp.bfloat16, backend="jnp")
    assert base != cache_key(fp, n_cols=32, dtype=np.float32,
                             backend="interpret")


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def _record(features, gflops=1.0, backend="jnp"):
    return {"version": CACHE_VERSION, "fingerprint": list(features),
            "dtype": "float32", "n_cols": 32, "backend": backend,
            "plan": {"r_frac": 0.25, "t_vpu": 2, "t_mxu": 6, "br": 8},
            "gflops": gflops, "trials": 3}


def test_cache_round_trip(tmp_path):
    c1 = PlanCache(str(tmp_path))
    c1.put("k1", _record([1.0, 2.0]))
    # A fresh instance reads the same file from disk.
    c2 = PlanCache(str(tmp_path))
    rec = c2.get("k1")
    assert rec is not None and rec["plan"]["t_mxu"] == 6
    assert c2.stats.hits == 1
    assert c2.get("absent") is None
    assert c2.stats.misses == 1


def test_cache_version_mismatch_invalidates(tmp_path):
    c1 = PlanCache(str(tmp_path))
    c1.put("k1", _record([1.0]))
    blob = json.loads((tmp_path / "plans.json").read_text())
    blob["version"] = CACHE_VERSION + 1
    (tmp_path / "plans.json").write_text(json.dumps(blob))
    c2 = PlanCache(str(tmp_path))
    assert c2.get("k1") is None   # stale-version entries are discarded
    assert len(c2) == 0


def test_cache_corrupt_file_is_empty_not_fatal(tmp_path):
    (tmp_path / "plans.json").write_text("{not json")
    c = PlanCache(str(tmp_path))
    assert c.get("k") is None
    c.put("k", _record([0.0]))    # and the file heals on the next put
    assert PlanCache(str(tmp_path)).peek("k") is not None


def test_cache_env_override(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "envdir"))
    c = PlanCache()
    assert c.dir == str(tmp_path / "envdir")
    c.put("k", _record([1.0]))
    assert (tmp_path / "envdir" / "plans.json").exists()


def test_cache_near_match_lookup(tmp_path):
    c = PlanCache(str(tmp_path))
    c.put("k1", _record([1.0, 2.0, 3.0]))
    # close by but not exact: near-hit within distance
    rec = c.lookup("other-key", features=[1.05, 2.0, 3.0], dtype="float32",
                   n_cols=32, backend="jnp", max_distance=0.25)
    assert rec is not None
    assert c.stats.near_hits == 1
    # far away: miss
    assert c.lookup("other-key", features=[5.0, 2.0, 3.0], dtype="float32",
                    n_cols=32, backend="jnp", max_distance=0.25) is None
    assert c.stats.misses == 1
    # same features, different execution context: miss
    assert c.lookup("other-key", features=[1.0, 2.0, 3.0], dtype="bfloat16",
                    n_cols=32, backend="jnp", max_distance=0.25) is None


def test_cache_lru_front_bounded(tmp_path):
    c = PlanCache(str(tmp_path), lru_size=2)
    for i in range(5):
        c.put(f"k{i}", _record([float(i)]))
    assert len(c._lru) <= 2       # front stays bounded...
    assert len(c) == 5            # ...while disk keeps everything
    assert c.get("k0") is not None


# ---------------------------------------------------------------------------
# search
# ---------------------------------------------------------------------------

def test_search_matches_exhaustive_on_tiny_space():
    """With a deterministic score and a budget covering the whole space, the
    search must return the exhaustive argmax."""
    csr = csr_from_dense(_dense(2, 32, 24, 0.2))

    def score(plan):   # deterministic, maximised at (r_b high, br=4, G=8)
        return plan.r_boundary * 0.1 + (10.0 if plan.br == 4 else 0.0) \
            + plan.t_mxu * 0.01 + plan.panel_g * 0.001

    def measure(c, plan, b):
        from repro.core import loops_from_csr
        return loops_from_csr(c, plan.r_boundary, plan.br,
                              panel_g=plan.panel_g), score(plan)

    plans = enumerate_plans(csr, total_workers=4, br_choices=(2, 4),
                            g_choices=(1, 8))
    # budget large enough that pruning keeps every distinct conversion
    # (the pipeline knobs are part of the conversion identity since v4)
    n_convs = len({(p.r_boundary, p.br, p.panel_g, p.macro_m,
                    p.pipeline_depth) for p in plans})
    res = search(csr, n_cols=8, total_workers=4, br_choices=(2, 4),
                 g_choices=(1, 8),
                 budget=SearchBudget(top_k=n_convs, max_trials=n_convs),
                 measure=measure)
    best_conv = max(plans, key=score)
    assert (res.plan.r_boundary, res.plan.br, res.plan.panel_g) == \
        (best_conv.r_boundary, best_conv.br, best_conv.panel_g)
    assert res.gflops == pytest.approx(max(g for _, g in res.trials))


def test_search_prunes_to_budget():
    csr = csr_from_dense(_dense(3, 40, 16, 0.15))
    calls = []

    def measure(c, plan, b):
        from repro.core import loops_from_csr
        calls.append(plan)
        return loops_from_csr(c, plan.r_boundary, plan.br), 1.0

    res = search(csr, n_cols=8, total_workers=8,
                 budget=SearchBudget(top_k=3, max_trials=3), measure=measure)
    assert len(calls) <= 3
    assert res.measured == len(calls)


def test_search_warm_start_spans_conversions():
    """The prior must rank conversions, not just splits: at the default
    budget the measured set has to include an *interior* (hybrid) boundary
    from the Eq. 1 sweep, not only the enumeration-order pure plans."""
    csr = csr_from_dense(_dense(8, 256, 64, 0.1))
    measured = []

    def measure(c, plan, b):
        from repro.core import loops_from_csr
        measured.append(plan)
        return loops_from_csr(c, plan.r_boundary, plan.br), 1.0

    search(csr, n_cols=8, total_workers=8, measure=measure)
    r_bs = {p.r_boundary for p in measured}
    assert any(0 < r < csr.nrows for r in r_bs), r_bs
    assert len({(p.r_boundary, p.br, p.panel_g, p.macro_m, p.pipeline_depth)
                for p in measured}) == len(measured)


def test_plan_from_record_preserves_pure_plans():
    """A pure-CSR winner must rehydrate to r_boundary == nrows even when
    nrows is not a br multiple (and pure-BCSR to 0) — the floor-to-tile
    snap applies only to interior boundaries."""
    from repro.tune import make_record, plan_from_record
    rec = make_record([0.0], dtype=np.float32, n_cols=8, backend="jnp",
                      r_frac=1.0, t_vpu=8, t_mxu=0, br=4)
    plan = plan_from_record(rec, nrows=130)
    assert plan.r_boundary == 130          # not floored to 128
    rec = make_record([0.0], dtype=np.float32, n_cols=8, backend="jnp",
                      r_frac=0.0, t_vpu=0, t_mxu=8, br=4)
    assert plan_from_record(rec, nrows=130).r_boundary == 0
    # boundary forced consistent with a degenerate split
    rec = make_record([0.0], dtype=np.float32, n_cols=8, backend="jnp",
                      r_frac=0.5, t_vpu=8, t_mxu=0, br=4)
    assert plan_from_record(rec, nrows=130).r_boundary == 130


def test_autotune_near_hit_promotes_to_exact_key(tmp_path):
    """A near-match is promoted under the matrix's own exact key, so the
    next lookup is exact and reporting paths (tune_suite) never see NaN."""
    from repro.tune import make_record, tune_suite
    cache = PlanCache(str(tmp_path))
    csr = suite.table2_like("m12", scale_rows=128, seed=3)
    fp = fingerprint(csr)
    neighbour = make_record(fp.features() + 0.05, dtype=np.float32,
                            n_cols=8, backend="jnp", r_frac=0.25,
                            t_vpu=2, t_mxu=6, br=8, gflops=1.5, trials=3)
    cache.put("neighbour-key", neighbour)
    _, plan = autotune(csr, n_cols=8, cache=cache)
    assert cache.stats.near_hits == 1 and cache.stats.misses == 0
    exact = cache_key(fp, n_cols=8, dtype=np.float32, backend="jnp")
    assert cache.peek(exact) is not None   # promoted
    # and tune_suite reports the borrowed gflops, never NaN
    report = tune_suite({"m": csr}, n_cols=8, cache=cache)
    assert np.isfinite(report["m"][1])
    assert cache.stats.hits >= 1           # follow-up lookups are exact


def test_enumerate_plans_no_degenerate_splits():
    csr = csr_from_dense(_dense(4, 24, 24, 0.2))
    for p in enumerate_plans(csr, total_workers=4):
        if p.r_boundary > 0:
            assert p.t_vpu > 0    # a non-empty CSR region needs VPU workers
        if p.r_boundary < csr.nrows:
            assert p.t_mxu > 0


# ---------------------------------------------------------------------------
# autotune end-to-end
# ---------------------------------------------------------------------------

def test_autotune_repeat_is_pure_cache_hit(tmp_path, monkeypatch):
    """Acceptance criterion: the second call is an exact hit that performs
    zero measurements (search is never entered)."""
    cache = PlanCache(str(tmp_path))
    csr = suite.table2_like("m12", scale_rows=128, seed=1)
    budget = SearchBudget(top_k=2, repeats=1, warmup=0)
    fmt1, plan1 = autotune(csr, n_cols=8, cache=cache, budget=budget)
    assert cache.stats.misses == 1 and cache.stats.hits == 0

    def no_search(*a, **k):
        raise AssertionError("cache hit must skip the search entirely")
    monkeypatch.setattr(tune_api, "search", no_search)
    fmt2, plan2 = autotune(csr, n_cols=8, cache=cache, budget=budget)
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    assert plan2 == plan1
    assert fmt2.r_boundary == fmt1.r_boundary


def test_autotune_numerics_match_loops_spmm(tmp_path):
    """autotune's (fmt, plan) executes to the same result as the dense
    ground truth — tuning never changes semantics."""
    cache = PlanCache(str(tmp_path))
    a = _dense(5, 48, 32, 0.25)
    csr = csr_from_dense(a)
    fmt, plan = autotune(csr, n_cols=8, cache=cache,
                         budget=SearchBudget(top_k=2, repeats=1, warmup=0))
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
    out = loops_spmm(fmt, b, backend="jnp")
    np.testing.assert_allclose(np.asarray(out), a @ np.asarray(b),
                               rtol=2e-4, atol=2e-4)
    assert 0 <= plan.r_boundary <= csr.nrows


def test_plan_and_convert_tuner_path(tmp_path):
    """core front door: `tuner=` replaces the model-only path and shares the
    cache across call sites (the sparse-FFN / GCN reuse story)."""
    tuner = Tuner(cache=PlanCache(str(tmp_path)), n_cols=8,
                  budget=SearchBudget(top_k=2, repeats=1, warmup=0))
    a = _dense(6, 40, 24, 0.2)
    fmt, plan = plan_and_convert(csr_from_dense(a), tuner=tuner)
    assert isinstance(plan, SpmmPlan)
    # second call site with the same structure: a hit, same plan
    _, plan2 = plan_and_convert(csr_from_dense(a), tuner=tuner)
    assert plan2 == plan
    assert tuner.cache.stats.hits == 1 and tuner.cache.stats.misses == 1


# ---------------------------------------------------------------------------
# satellite regressions (hypothesis-free home: runs in minimal environments
# where tests/test_formats.py / test_perf_model.py are collect-ignored)
# ---------------------------------------------------------------------------

def test_coo_duplicates_coalesced_in_structure():
    """csr_from_coo must *sum* colliding (row, col) coordinates during
    construction: un-coalesced duplicates inflate nnz and every statistic
    derived from it (row stats, perf-model inputs, tuner fingerprints)."""
    from repro.core import csr_from_coo
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 16, 200)
    cols = rng.integers(0, 16, 200)
    vals = rng.standard_normal(200).astype(np.float32)
    csr = csr_from_coo(rows, cols, vals, (16, 16))
    coords = list(zip(csr.row_ids.tolist(), csr.col_idx.tolist()))
    assert len(coords) == len(set(coords))
    # Regression vs csr_to_dense: reconstruction equals the summed scatter.
    want = np.zeros((16, 16), np.float32)
    np.add.at(want, (rows, cols), vals)
    np.testing.assert_allclose(csr_to_dense(csr), want, rtol=1e-6)


def test_suite_uniform_has_no_duplicate_coords():
    """suite.uniform draws colliding coordinates; construction coalesces."""
    csr = suite.uniform(64, 64, 0.2, seed=0)
    coords = list(zip(csr.row_ids.tolist(), csr.col_idx.tolist()))
    assert len(coords) == len(set(coords))


def test_perf_model_rank_deficient_fit_is_ridge():
    """< 5 distinct (x, y) points underdetermine Eq. 2: the fit must stay
    finite, interpolate the measurements, and keep best_allocation sane."""
    from repro.core.perf_model import fit_perf_model
    pts = [(1, 1), (2, 2), (4, 4)] * 2
    perfs = [2.0, 4.0, 8.0] * 2
    m = fit_perf_model(pts, perfs)
    assert np.isfinite(m.coef).all()
    for (x, y), p in zip(pts, perfs):
        assert float(m.predict(x, y)) == pytest.approx(p, rel=1e-3)
    x, y = m.best_allocation(8)
    assert 0 < x + y <= 8
    # Collinear axis-only samples: predictions off-axis stay bounded.
    m2 = fit_perf_model([(x, 0) for x in range(6)],
                        [float(x) for x in range(6)])
    assert np.isfinite(m2.coef).all()
    assert abs(float(m2.predict(0, 8))) < 1e3


def test_perf_model_panel_terms():
    """(x, y, g) samples fit the panel-extended model: g is ranked by its
    own concave terms and best_allocation_g recovers the sweet spot, while
    5-coefficient models keep ignoring g (backward compatibility)."""
    from repro.core.perf_model import calibrate, fit_perf_model

    def perf(x, y, g):  # saturating panel win, peak at g = 8
        return 2.0 * x + 5.0 * y + 3.0 * g - 0.18 * g * g

    samples = [(x, y, g) for x in range(5) for y in range(5 - x)
               for g in (1, 4, 8)]
    m = fit_perf_model(samples, [perf(*s) for s in samples])
    assert m.has_panel_terms
    assert float(m.predict(2, 2, 8)) == pytest.approx(perf(2, 2, 8), rel=1e-6)
    x, y, g = m.best_allocation_g(8, g_choices=(1, 4, 8))
    assert (x + y <= 8) and g == 8
    # calibrate() crosses the representative splits with g_choices
    m2 = calibrate(lambda x, y, g: perf(x, y, g), 8, g_choices=(1, 4, 8))
    assert m2.has_panel_terms
    # a plain Eq. 2 model ignores g entirely
    flat = fit_perf_model([(x, y) for x in range(5) for y in range(5)],
                          [2.0 * x + 5.0 * y for x in range(5)
                           for y in range(5)])
    assert not flat.has_panel_terms
    assert float(flat.predict(1, 1, 8)) == float(flat.predict(1, 1, 1))


def test_cached_plan_replays_panel_g(tmp_path):
    """A tuned plan's panel width survives the cache round trip and drives
    the rehydrated conversion."""
    from repro.tune import make_record, plan_from_record
    rec = make_record([0.0], dtype=np.float32, n_cols=8, backend="jnp",
                      r_frac=0.5, t_vpu=4, t_mxu=4, br=8, panel_g=4)
    plan = plan_from_record(rec, nrows=64)
    assert plan.panel_g == 4
    from repro.core import loops_from_csr
    fmt = loops_from_csr(csr_from_dense(_dense(1, 64, 32, 0.2)),
                         plan.r_boundary, plan.br, panel_g=plan.panel_g)
    assert fmt.panel_g == 4
    assert fmt.csr_panels.g == 4 and fmt.bcsr_panels.g == 4


def test_shard_loops_auto_consults_cache(tmp_path):
    from repro.core import loops_from_csr
    from repro.core.distributed import shard_loops_auto
    cache = PlanCache(str(tmp_path))
    a = _dense(7, 64, 32, 0.2)
    fmt = loops_from_csr(csr_from_dense(a), 32, 8)
    s1 = shard_loops_auto(fmt, 4, cache=cache)      # miss -> solve -> put
    assert cache.stats.misses == 1
    s2 = shard_loops_auto(fmt, 4, cache=cache)      # hit -> reuse split
    assert cache.stats.hits == 1
    assert s2.g_vpu == s1.g_vpu
    # a different device count is a different cache context
    shard_loops_auto(fmt, 8, cache=cache)
    assert cache.stats.misses == 2


def test_effective_n_cols_and_batched_cache_key(tmp_path):
    """Batched operands key plans on prod(batch)*N — a (4, K, 16) workload
    and an unbatched n_cols=64 one share the key; n_cols=16 does not."""
    from repro.tune import effective_n_cols
    assert effective_n_cols((64, 16)) == 16
    assert effective_n_cols((4, 64, 16)) == 64
    assert effective_n_cols((2, 3, 64, 16)) == 96
    with pytest.raises(ValueError):
        effective_n_cols((64,))
    a = _dense(11, 96, 64, 0.2)
    csr = csr_from_dense(a)
    cache = PlanCache(str(tmp_path))
    budget = SearchBudget(top_k=1, repeats=1, warmup=0)
    autotune(csr, rhs_shape=(4, 64, 16), cache=cache, budget=budget)
    autotune(csr, n_cols=64, cache=cache, budget=budget)   # same effective
    assert cache.stats.hits == 1 and cache.stats.misses == 1
    autotune(csr, n_cols=16, cache=cache, budget=budget)   # different
    assert cache.stats.misses == 2


def test_search_measures_batched_operand(tmp_path):
    """search(rhs_shape=...) hands the measurement fn a batched operand of
    exactly that shape, so candidates are timed on the real batched call."""
    a = _dense(12, 64, 32, 0.25)
    csr = csr_from_dense(a)
    seen = []

    def fake_measure(c, plan, b):
        seen.append(tuple(b.shape))
        from repro.core import loops_from_csr
        fmt = loops_from_csr(c, plan.r_boundary, plan.br,
                             panel_g=plan.panel_g)
        return fmt, 1.0

    search(csr, rhs_shape=(3, 32, 8), measure=fake_measure,
           budget=SearchBudget(top_k=2))
    assert seen and all(s == (3, 32, 8) for s in seen)
    with pytest.raises(ValueError, match="ncols"):
        search(csr, rhs_shape=(3, 16, 8), measure=fake_measure)
    # an explicit b that disagrees with rhs_shape is an error, not a
    # silently-unbatched measurement
    with pytest.raises(ValueError, match="rhs_shape"):
        search(csr, b=jnp.zeros((32, 8)), rhs_shape=(3, 32, 8),
               measure=fake_measure)


def test_cache_stats_counted_exactly_once(tmp_path):
    """One logical lookup lands in exactly one bucket — an exact-probe
    fall-through to the near scan that then misses is ONE miss, never an
    exact-miss plus a near-miss (the counted-exactly-once contract the
    obs ``tune.cache.*`` gauges rely on)."""
    cache = PlanCache(str(tmp_path))
    a = _dense(21, 96, 64, 0.2)
    csr = csr_from_dense(a)
    fp = fingerprint(csr)
    key = cache_key(fp, n_cols=32, dtype="float32", backend="jnp")
    # miss with the near scan enabled: exact probe + near scan = 1 miss
    cache.lookup(key, features=fp.features, dtype="float32",
                 n_cols=32, backend="jnp", max_distance=0.25)
    assert (cache.stats.hits, cache.stats.near_hits,
            cache.stats.misses) == (0, 0, 1)
    assert cache.stats.lookups == 1
    # get() routes through the same single accounting point
    cache.put("k", {"plan": 1})
    assert cache.get("k") is not None
    assert (cache.stats.hits, cache.stats.misses) == (1, 1)
    assert cache.stats.lookups == 2
    # peek/nearest are side-effect-free internals
    cache.peek("k")
    cache.peek("absent")
    cache.nearest(fp.features, dtype="float32", n_cols=32, backend="jnp",
                  max_distance=0.25)
    assert cache.stats.lookups == 2


def test_cache_stats_reset(tmp_path):
    cache = PlanCache(str(tmp_path))
    cache.put("k", {"plan": 1})
    cache.lookup("k")
    cache.lookup("absent")
    assert cache.stats.lookups == 2 and cache.stats.hit_rate == 0.5
    cache.stats.reset()
    assert (cache.stats.hits, cache.stats.near_hits,
            cache.stats.misses) == (0, 0, 0)
    assert cache.stats.lookups == 0 and cache.stats.hit_rate == 0.0
    cache.lookup("k")                      # a fresh measurement window
    assert cache.stats.hits == 1 and cache.stats.lookups == 1


# ---------------------------------------------------------------------------
# prewarm: the serving warm-pool bulk-install path
# ---------------------------------------------------------------------------

def _tuned_record(features, **kw):
    from repro.tune import make_record
    defaults = dict(dtype=np.float32, n_cols=8, backend="jnp", r_frac=0.5,
                    t_vpu=4, t_mxu=6, br=8)
    defaults.update(kw)
    return make_record(features, **defaults)


def test_prewarm_counts_each_new_key_exactly_once(tmp_path):
    from repro.tune.fingerprint import cache_key_from_features
    cache = PlanCache(str(tmp_path))
    recs = [_tuned_record([1.0, 2.0]), _tuned_record([3.0, 4.0])]
    assert cache.prewarm(recs) == 2
    assert cache.stats.prewarmed == 2
    # re-prewarming the same set is a no-op that counts ZERO...
    before = (tmp_path / "plans.json").stat().st_mtime_ns
    assert cache.prewarm(recs) == 0
    assert cache.stats.prewarmed == 2
    # ...and never touches disk (one atomic save on install, none on no-op)
    assert (tmp_path / "plans.json").stat().st_mtime_ns == before
    # a partially-fresh batch counts only the newcomers
    assert cache.prewarm(recs + [_tuned_record([5.0, 6.0])]) == 1
    assert cache.stats.prewarmed == 3
    # installed records are served as plain hits under their rebuilt key
    key = cache_key_from_features([1.0, 2.0], n_cols=8, dtype=np.float32,
                                  backend="jnp")
    assert cache.get(key)["plan"]["t_mxu"] == 6


def test_prewarm_keys_match_cache_key_of_source_matrix(tmp_path):
    """A record tuned via the normal put(cache_key(...)) path and the same
    record bulk-installed via prewarm land under ONE key — the warm pool
    actually front-loads the hits the tuner would have minted."""
    cache = PlanCache(str(tmp_path))
    fp = fingerprint(csr_from_dense(_dense(2, 64, 48, 0.2)))
    key = cache_key(fp, n_cols=8, dtype=np.float32, backend="jnp")
    rec = _tuned_record(fp.features())
    cache.put(key, rec)
    pool = PlanCache(str(tmp_path / "pool"))
    assert pool.prewarm([rec]) == 1
    assert pool.peek(key) is not None      # rebuilt key == minted key
    assert pool.get(key)["fingerprint"] == rec["fingerprint"]


def test_prewarm_accepts_explicit_key_mapping(tmp_path):
    cache = PlanCache(str(tmp_path))
    assert cache.prewarm({"a": {"plan": 1}, "b": {"plan": 2}}) == 2
    cache.put("c", {"plan": 3})
    # mapping form skips present keys too, whoever installed them
    assert cache.prewarm({"b": {"plan": 9}, "c": {"plan": 9},
                          "d": {"plan": 4}}) == 1
    assert cache.peek("b")["plan"] == 2    # prewarm never overwrites
    assert cache.stats.prewarmed == 3
    # a fresh instance reads everything back (the one save was real)
    assert PlanCache(str(tmp_path)).peek("d")["plan"] == 4


def test_prewarm_survives_round_trip_through_disk(tmp_path):
    """serve.py's flow: tune into one cache, prewarm a pool from the tuned
    records, reload the pool in a fresh process."""
    tuned = PlanCache(str(tmp_path / "tuned"))
    fp = fingerprint(csr_from_dense(_dense(3, 48, 32, 0.3)))
    key = cache_key(fp, n_cols=8, dtype=np.float32, backend="jnp")
    tuned.put(key, _tuned_record(fp.features()))
    pool = PlanCache(str(tmp_path / "pool"))
    pool.prewarm([tuned.peek(key)])
    fresh = PlanCache(str(tmp_path / "pool"))
    assert fresh.get(key) is not None and fresh.stats.hits == 1
    # and the stats line surfaces the prewarm count
    assert "prewarmed=1" in str(pool.stats)
