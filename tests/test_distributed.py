"""Multi-device behaviour (subprocess with forced host devices: the main
pytest process keeps the assignment's 1-device contract)."""
import os
import subprocess
import sys
import textwrap

import pytest

_ENV = {**os.environ,
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def _run(body: str):
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=_ENV, capture_output=True, text=True,
                         timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


def test_distributed_spmm_device_groups():
    """Two-level LOOPS schedule under shard_map == dense ground truth, for
    several (g_vpu, g_mxu) splits including the §4.3 ablation extremes."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import csr_from_dense, plan_and_convert, loops_from_csr
        from repro.core import shard_loops, distributed_spmm
        rng = np.random.default_rng(0)
        A = ((rng.random((210, 64)) < 0.15)
             * rng.standard_normal((210, 64))).astype(np.float32)
        B = rng.standard_normal((64, 16)).astype(np.float32)
        csr = csr_from_dense(A)
        mesh = make_mesh((8,), ("model",))
        for g_vpu, r_frac in [(2, 0.25), (4, 0.5), (7, 0.9)]:
            r_b = int(210 * r_frac) // 8 * 8
            fmt = loops_from_csr(csr, r_b, 8)
            sh = shard_loops(fmt, 8, g_vpu=g_vpu)
            out = distributed_spmm(sh, jnp.asarray(B), mesh)
            np.testing.assert_allclose(np.asarray(out), A @ B,
                                       rtol=1e-4, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_distributed_spmm_batched_rhs():
    """distributed_spmm consumes the batched (..., K, N) contract directly:
    one shard_map call serves every batch slice, fwd and bwd — no
    per-element loops or flattening reshapes at the call site."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.compat import make_mesh
        from repro.core import (csr_from_dense, loops_from_csr, shard_loops,
                                distributed_spmm)
        rng = np.random.default_rng(0)
        A = ((rng.random((100, 32)) < 0.2)
             * rng.standard_normal((100, 32))).astype(np.float32)
        B = rng.standard_normal((3, 32, 8)).astype(np.float32)
        mesh = make_mesh((8,), ("model",))
        fmt = loops_from_csr(csr_from_dense(A), 48, 8)
        sh = shard_loops(fmt, 8, g_vpu=3)
        got = distributed_spmm(sh, jnp.asarray(B), mesh)
        want = np.einsum("mk,zkn->zmn", A, B)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4,
                                   atol=1e-4)
        dy = rng.standard_normal(got.shape).astype(np.float32)
        db = jax.grad(lambda b: jnp.sum(
            distributed_spmm(sh, b, mesh) * dy))(jnp.asarray(B))
        want_db = np.einsum("mk,zmn->zkn", A, dy)
        np.testing.assert_allclose(np.asarray(db), want_db, rtol=1e-4,
                                   atol=1e-4)
        stacked = distributed_spmm(sh, jnp.asarray(B), mesh,
                                   assemble=False)
        assert stacked.shape[0] == 8 and stacked.shape[1] == 3
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_close_to_exact():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from repro.compat import make_mesh, shard_map
        from repro.dist.compress import compressed_psum
        mesh = make_mesh((8,), ("d",))
        x = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((8, 8192)).astype(np.float32))
        from jax.sharding import PartitionSpec as P
        @partial(shard_map, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
        def f(xs):
            return compressed_psum(xs[0], "d")[None]
        got = np.asarray(f(x))[0]
        want = np.asarray(x).sum(0)
        err = np.abs(got - want).max() / np.abs(want).max()
        assert err < 2e-2, err
        print("OK", err)
    """)
    assert "OK" in out


def test_train_step_multi_device_matches_single():
    """Same seed, 1 device vs 2x4 mesh: loss must agree (parallelism is
    numerics-preserving up to reduction order)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import REDUCED
        from repro.configs.base import ShapeConfig
        from repro.data import DataConfig, global_batch_at
        from repro.dist import step as step_lib
        from repro.launch.mesh import make_test_mesh
        from repro.launch import specs
        from repro.models import api
        from repro.optim import adamw
        from repro.optim.adamw import OptConfig
        cfg = REDUCED["llama3.2-1b"]()
        shape = ShapeConfig("t", 32, 8, "train")
        data = DataConfig(seed=5)
        params = api.init_params(cfg, jax.random.key(0))
        pav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           params)
        losses = []
        for (d, m) in [(1, 1), (2, 4)]:
            mesh = make_test_mesh(d, m)
            n_mb = 2
            bav = specs.train_batch_specs(cfg, shape, n_mb)
            bundle = step_lib.build_train_step(cfg, mesh, pav, bav,
                                               OptConfig(),
                                               n_microbatches=n_mb)
            opt = adamw.init_opt_state(params, d * m)
            batch = global_batch_at(data, cfg, shape, n_mb, 0)
            _, _, metrics = bundle.fn(jax.tree.map(jnp.copy, params), opt,
                                      batch)
            losses.append(float(metrics["loss"]))
        assert abs(losses[0] - losses[1]) < 1e-2, losses
        print("OK", losses)
    """)
    assert "OK" in out


def test_dryrun_entrypoint_single_cell():
    """The dry-run script itself works end to end (reduced device count via
    its own hardcoded 512 flag is too heavy for CI; use the real thing on
    the smallest arch/shape)."""
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "llama3.2-1b", "--shape", "decode_32k", "--mesh", "single"],
        env={**os.environ,
             "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                        "src")},
        capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "[ok]" in res.stdout
