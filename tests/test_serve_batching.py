"""Continuous-batching correctness: coalesced == sequential, pad == no-pad.

The serving layer's core numerical claim (docs/serving.md) is that batching
is an *optimisation, not a semantic*: a request emits the same token stream
whether it rode a coalesced ragged batch or ran alone, and zero-padding the
batch axis to the engine's block grid never perturbs the live rows.  This
file pins both halves of that claim on the reduced llama config, pins the
scheduler's pure grid mirrors to ``kernels/engine.py``, and pins the
closed-loop load benchmark's virtual-clock schedule to ``REPRO_TEST_SEED``
(the same two-runs-identical framing as fig4's determinism test).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import HAVE_HYPOTHESIS, TEST_SEED
from repro.configs import REDUCED
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.serve import scheduler as pure_sched
from repro.serve.queue import ExecutorPool, ServeQueue, sample_token
from repro.serve.scheduler import SchedulerConfig

ARCH = "llama3.2-1b"


@pytest.fixture(scope="module")
def serving():
    cfg = REDUCED[ARCH]()
    mesh = make_test_mesh(1, 1)
    params = api.init_params(cfg, jax.random.key(TEST_SEED))
    # one pool for the whole module: parity runs share compiled bundles
    pool = ExecutorPool(cfg, mesh, params)
    return cfg, mesh, params, pool


def _drive(queue, prompts, gen_lens, rids):
    """Submit everything at t=0 on a virtual clock and run to idle."""
    reqs = [queue.submit(p, g, now=0.0, rid=rid)
            for p, g, rid in zip(prompts, gen_lens, rids)]
    t = 0.0
    while queue.pending:
        if not queue.step(now=t):
            break
        t += 1.0
    return reqs


def _queues(cfg, mesh, params, pool, *, temperature):
    batched = ServeQueue(
        cfg, mesh, params, pool=pool, temperature=temperature,
        seed=TEST_SEED, record_logits=True,
        config=SchedulerConfig(max_in_flight=2, max_batch=8, min_batch=1,
                               max_wait_s=0.0))
    sequential = ServeQueue(
        cfg, mesh, params, pool=pool, temperature=temperature,
        seed=TEST_SEED, record_logits=True,
        config=SchedulerConfig(max_in_flight=1, max_batch=1, min_batch=1,
                               max_wait_s=0.0))
    return batched, sequential


# ---------------------------------------------------------------------------
# the scheduler's grid mirrors never drift from the engine
# ---------------------------------------------------------------------------

def test_grid_mirrors_match_engine():
    from repro.kernels import engine
    assert pure_sched.MAX_BATCH_BLOCK == engine.MAX_BATCH_BLOCK
    for batch in range(1, 41):
        assert pure_sched.batch_block(batch) == engine.batch_block(batch), \
            f"batch_block({batch}) drifted from kernels/engine.py"
        assert pure_sched.padded_batch(batch) == engine.padded_batch(batch), \
            f"padded_batch({batch}) drifted from kernels/engine.py"


# ---------------------------------------------------------------------------
# sampling is a pure function of (seed, rid, index) — never of the batch
# ---------------------------------------------------------------------------

def test_sample_token_greedy_ignores_seed():
    row = np.array([0.1, 2.0, -1.0, 0.5])
    for seed in (0, 7, 123):
        assert sample_token(row, temperature=0.0, seed=seed, rid=9,
                            index=3) == 1


def test_sample_token_stream_is_keyed_on_seed_rid_index(rng):
    row = rng.normal(size=64)
    base = sample_token(row, temperature=0.8, seed=1, rid=2, index=3)
    assert base == sample_token(row, temperature=0.8, seed=1, rid=2, index=3)
    # perturbing any key component changes the draw for *some* row; check
    # across many rows so the test isn't hostage to one lucky collision
    for kw in ({"seed": 4}, {"rid": 5}, {"index": 6}):
        diffs = 0
        for _ in range(20):
            r = rng.normal(size=64)
            a = sample_token(r, temperature=0.8, seed=1, rid=2, index=3)
            b = sample_token(r, temperature=0.8,
                             **{"seed": 1, "rid": 2, "index": 3, **kw})
            diffs += a != b
        assert diffs > 0, f"stream ignored key component {kw}"


# ---------------------------------------------------------------------------
# parity: a coalesced ragged batch emits the same streams as one-at-a-time
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_coalesced_equals_sequential(serving, temperature):
    cfg, mesh, params, pool = serving
    rng = np.random.default_rng(TEST_SEED + 11)
    prompts = [rng.integers(0, cfg.vocab_size, 8).tolist() for _ in range(3)]
    gen_lens = [3, 2, 3]                     # mixed budgets: early exit rides
    rids = [1000, 1001, 1002]                # pinned -> same sampling streams

    batched, sequential = _queues(cfg, mesh, params, pool,
                                  temperature=temperature)
    b_reqs = _drive(batched, prompts, gen_lens, rids)
    s_reqs = _drive(sequential, prompts, gen_lens, rids)

    # the coalesced path ran ONE prefill for all three riders...
    assert batched.sched.counters["prefill_batches"] == 1
    assert sequential.sched.counters["prefill_batches"] == 3
    # ...yet every request got exactly the tokens it gets when run alone
    for br, sr in zip(b_reqs, s_reqs):
        assert br.tokens == sr.tokens, f"rid {br.rid} diverged"
        assert br.tokens_generated == br.gen_len
        b_log, s_log = batched.logits_log[br.rid], sequential.logits_log[
            sr.rid]
        assert len(b_log) == len(s_log) == br.gen_len
        for bl, sl in zip(b_log, s_log):
            np.testing.assert_allclose(bl, sl, rtol=1e-5, atol=1e-5)


def test_batched_engine_calls_never_exceed_sequential(serving):
    # the structural inequality the load benchmark asserts, in miniature:
    # group decode steps = max over members <= sum over members
    cfg, mesh, params, pool = serving
    rng = np.random.default_rng(TEST_SEED + 13)
    prompts = [rng.integers(0, cfg.vocab_size, 8).tolist() for _ in range(3)]
    gen_lens, rids = [3, 2, 3], [1100, 1101, 1102]
    batched, sequential = _queues(cfg, mesh, params, pool, temperature=0.0)
    _drive(batched, prompts, gen_lens, rids)
    _drive(sequential, prompts, gen_lens, rids)
    calls = lambda q: (q.sched.counters["prefill_batches"]
                       + q.sched.counters["decode_steps"])
    assert calls(batched) < calls(sequential)
    assert calls(batched) == 1 + 2           # one prefill + max(gen)-1 steps


# ---------------------------------------------------------------------------
# batch-axis padding never changes a live row's logits
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_batch_pad_never_changes_per_request_logits(serving):
    from hypothesis import given, settings, strategies as st

    cfg, _, params, _ = serving
    prefill = jax.jit(lambda p, b: api.prefill(cfg, p, b))

    @settings(max_examples=10, deadline=None)   # one jit per (live+pad) size
    @given(st.data())
    def run(data):
        live = data.draw(st.integers(1, 3))
        pad = data.draw(st.integers(1, 2))
        toks = np.asarray(data.draw(st.lists(
            st.integers(0, cfg.vocab_size - 1), min_size=live * 8,
            max_size=live * 8)), np.int32).reshape(live, 8)
        padded = np.zeros((live + pad, 8), np.int32)
        padded[:live] = toks
        _, lg_live = prefill(params, {"tokens": jnp.asarray(toks)})
        _, lg_pad = prefill(params, {"tokens": jnp.asarray(padded)})
        np.testing.assert_allclose(np.asarray(lg_pad)[:live],
                                   np.asarray(lg_live), rtol=1e-5, atol=1e-5)

    run()


# ---------------------------------------------------------------------------
# the load benchmark's virtual-clock schedule is seed-deterministic
# ---------------------------------------------------------------------------

# The structural columns: everything the scheduler decides on the virtual
# clock.  Wall-clock columns (goodput, percentiles) legitimately vary.
STRUCTURAL = ("n_requests", "completed", "rejected", "evicted",
              "prefill_batches", "decode_steps", "engine_calls",
              "padded_slots", "tokens")


def test_serve_traffic_smoke_deterministic():
    """Two runs of the smoke load suite must make identical scheduling
    decisions (mirrors fig4's grid-step determinism test): same groups,
    same interleave, same token counts — a pure function of
    ``REPRO_TEST_SEED``."""
    from benchmarks import serve_traffic

    def run():
        records = []
        serve_traffic.main(out=lambda line: None, record=records.append,
                           smoke=True, n_clients=2, rounds=1)
        return records

    first, second = run(), run()
    assert len(first) == len(second) == 2    # batched + sequential
    for a, b in zip(first, second):
        assert a["matrix"] == b["matrix"]
        for col in STRUCTURAL:
            assert a[col] == b[col], \
                f"{a['matrix']}.{col}: {a[col]} != {b[col]} across reruns"
    by_mode = {r["matrix"]: r for r in first}
    assert by_mode["batched"]["engine_calls"] <= \
        by_mode["sequential"]["engine_calls"]
