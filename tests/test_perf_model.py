"""Quadratic performance model (Eq. 2) + scheduler (Eq. 3) properties."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.perf_model import (QuadraticPerfModel, calibrate,
                                   default_candidates, fit_perf_model)


def test_fit_recovers_exact_quadratic():
    coef = np.array([1.0, 2.0, -0.5, -0.1, -0.2])
    xs = [(x, y) for x in range(5) for y in range(5)]
    m = QuadraticPerfModel(coef)
    fit = fit_perf_model(xs, [m.predict(x, y) for x, y in xs])
    np.testing.assert_allclose(fit.coef, coef, atol=1e-8)


@given(st.tuples(*[st.floats(-2, 2) for _ in range(5)]), st.integers(1, 12))
def test_argmax_matches_brute_force(coef, total):
    m = QuadraticPerfModel(np.asarray(coef))
    x, y = m.best_allocation(total)
    assert 0 < x + y <= total
    best = max(float(m.predict(a, b))
               for a in range(total + 1) for b in range(total + 1 - a)
               if a + b > 0)
    assert float(m.predict(x, y)) == pytest.approx(best)


def test_calibrate_finds_contention_optimum():
    """Paper §4.3 scenario: SME(y) throughput saturates past 1 worker
    (shared-unit contention); the scheduler must not over-allocate it."""
    def measure(x, y):
        return 1.0 * x + (4.0 * min(y, 1) + 0.25 * max(y - 1, 0))
    model = calibrate(measure, total=8)
    x, y = model.best_allocation(8)
    assert y <= 4  # fitted quadratic discourages piling onto the matrix unit
    assert x >= 4


def test_default_candidates_valid():
    for t in (1, 2, 8, 12):
        for (x, y) in default_candidates(t):
            assert 0 < x + y <= t


def test_fit_requires_enough_samples():
    with pytest.raises(ValueError):
        fit_perf_model([(0, 0), (1, 1)], [0.0, 1.0])


def test_fit_rank_deficient_falls_back_to_ridge():
    """Fewer than 5 distinct (x, y) points underdetermine Eq. 2; the fit
    must stay finite and interpolate the measurements instead of returning
    an arbitrary exact solution that best_allocation would extrapolate.
    (Also covered hypothesis-free in tests/test_tune.py.)"""
    pts = [(1, 1), (2, 2), (4, 4)] * 2          # 3 distinct points, 6 samples
    perfs = [2.0, 4.0, 8.0] * 2                 # linear along the diagonal
    m = fit_perf_model(pts, perfs)
    assert np.isfinite(m.coef).all()
    for (x, y), p in zip(pts, perfs):
        assert float(m.predict(x, y)) == pytest.approx(p, rel=1e-3)
    x, y = m.best_allocation(8)
    assert 0 < x + y <= 8                        # scheduler still sane
