"""The roofline engine: trip-count-corrected HLO accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.perf.hlo_analysis import analyze_hlo, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("f32[32,32]{1,0}") == 4096
    assert _shape_bytes("bf16[8]") == 16
    assert _shape_bytes("(s32[], f32[4,4])") == 4 + 64
    assert _shape_bytes("pred[]") == 1


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=11)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    st = analyze_hlo(c.as_text())
    assert st.flops == 11 * 2 * 64 ** 3
    assert st.unknown_trip_loops == 0
    # cost_analysis undercounts (one body visit) — the reason this module
    # exists; guard the assumption so a jax upgrade that fixes it is noticed
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax <= 0.4.x: one dict per device
        ca = ca[0]
    assert ca["flops"] < st.flops / 2


def test_nested_scan():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out

    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    st = analyze_hlo(c.as_text())
    assert st.flops == 15 * 2 * 16 ** 3


def test_dot_general_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    st = analyze_hlo(c.as_text())
    assert st.flops == 2 * 4 * 8 * 8 * 16


def test_hbm_bytes_reasonable_for_elementwise():
    def f(a, b):
        return a + b
    a = jax.ShapeDtypeStruct((1024,), jnp.float32)
    c = jax.jit(f).lower(a, a).compile()
    st = analyze_hlo(c.as_text())
    # read a, read b, write out = 3 * 4096 (fusion boundary accounting)
    assert 2 * 4096 <= st.hbm_bytes <= 4 * 4096
