"""Runtime observability (`repro.obs`): jit-safety, exporters, seams.

The load-bearing claims:

  * **jit-safety** — `observe_in_jit` records once per *execution* (never
    once per trace); a `span()` entered during abstract tracing records
    NOTHING (dropped + counted), so no capture can silently report compile
    time as steady-state latency;
  * **exporter validity** — the Chrome trace round-trips `json.loads`,
    events are properly nested per thread, and the JSONL stream is
    schema-stamped with future-version rejection (the
    `repro.perf.trace` contract);
  * **seams** — the engine dispatch hook feeds per-(part, op) counters and
    chains to an already-installed `TraceRecorder`; a watched `PlanCache`
    exports `tune.cache.*` gauges; `compressed_psum` reports wire bytes;
    `wrap_step` lands per-call latency histograms.
"""
from __future__ import annotations

import json
import pathlib
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import csr_from_dense, loops_spmm, plan_and_convert
from repro.obs import (OBS_SCHEMA_VERSION, Histogram, MetricsRegistry, Obs,
                       SpanSink, current_span, get_active, load_obs,
                       set_active)

ROOT = pathlib.Path(__file__).resolve().parent.parent


def random_sparse(rng, m, k, density=0.3):
    a = (rng.random((m, k)) < density) * rng.standard_normal((m, k))
    return a.astype(np.float32)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_baspo():
    reg = MetricsRegistry()
    reg.counter("c", part="csr").inc()
    reg.counter("c", part="csr").inc(2)
    reg.counter("c", part="bcsr").inc()
    assert reg.find("counter", "c", part="csr").value == 3
    assert reg.find("counter", "c", part="bcsr").value == 1
    assert reg.find("counter", "c", part="nope") is None
    reg.gauge("g").set(7)
    reg.gauge("g").set(9)
    assert reg.find("gauge", "g").value == 9.0


def test_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(ValueError, match="already registered"):
        reg.histogram("m")


def test_histogram_quantiles_single_sample_and_spread():
    h = Histogram("h", {})
    h.observe(42.0)
    s = h.summary()
    # single sample: clamping pins every quantile to the observation
    assert s["p50"] == s["p99"] == s["min"] == s["max"] == 42.0
    h2 = Histogram("h2", {})
    for v in range(1, 1001):
        h2.observe(float(v))
    s2 = h2.summary()
    assert s2["count"] == 1000 and s2["min"] == 1.0 and s2["max"] == 1000.0
    assert s2["p50"] <= s2["p90"] <= s2["p99"] <= s2["max"]
    assert 300.0 < s2["p50"] < 700.0          # interpolated, not a bound


def test_histogram_bucket_validation():
    with pytest.raises(ValueError, match="ascending"):
        Histogram("h", {}, buckets=[1.0, 1.0, 2.0])
    with pytest.raises(ValueError, match="ascending"):
        Histogram("h", {}, buckets=[2.0, 1.0])


def test_histogram_overflow_bucket():
    h = Histogram("h", {}, buckets=[10.0, 20.0])
    h.observe(1e9)
    assert h.counts[-1] == 1
    assert h.percentile(0.5) == 1e9   # clamped to observed max


# ---------------------------------------------------------------------------
# jit-safety: record once per EXECUTION, never per trace
# ---------------------------------------------------------------------------

def test_observe_in_jit_records_once_per_execution():
    reg = MetricsRegistry()

    @jax.jit
    def f(x):
        reg.observe_in_jit("jit.lat_us", x * 2.0)
        return x + 1.0

    for i in range(3):                 # one compilation, three executions
        f(jnp.float32(i)).block_until_ready()
    jax.effects_barrier()
    h = reg.find("hist", "jit.lat_us")
    assert h.count == 3, "must count executions, not compilations"


def test_count_in_jit_records_once_per_execution():
    reg = MetricsRegistry()

    @jax.jit
    def f(x):
        reg.count_in_jit("jit.calls")
        return x * 2.0

    for _ in range(4):
        f(jnp.ones(2)).block_until_ready()
    jax.effects_barrier()
    assert reg.find("counter", "jit.calls").value == 4


def test_span_inside_jit_records_nothing_and_counts_drop():
    obs = Obs(source="t")

    @jax.jit
    def f(x):
        with obs.span("traced.region"):
            return x * 2.0

    f(jnp.ones(2)).block_until_ready()       # compile 1
    f(jnp.ones(2)).block_until_ready()       # cached: no trace, no span
    f(jnp.ones(3)).block_until_ready()       # compile 2 (new shape)
    assert obs.sink.events == [], "no span may be emitted during tracing"
    drops = obs.metrics.find("counter", "obs.spans_dropped_traced",
                             span="traced.region")
    assert drops is not None and drops.value == 2   # once per compilation


def test_span_records_on_host():
    obs = Obs(source="t")
    with obs.span("host.region", cat="test", k=1) as sp:
        sp.fence(jnp.ones(4) * 2)
    (ev,) = obs.sink.events
    assert ev["name"] == "host.region" and ev["cat"] == "test"
    assert ev["args"] == {"k": 1} and ev["dur"] >= 0.0


def test_span_nesting_depth_and_order():
    obs = Obs(source="t")
    with obs.span("outer"):
        assert current_span().name == "outer"
        with obs.span("inner"):
            assert current_span().name == "inner"
    assert current_span() is None
    inner, outer = obs.sink.events            # completion order
    assert inner["name"] == "inner" and inner["depth"] == 1
    assert outer["name"] == "outer" and outer["depth"] == 0
    # proper nesting: inner's interval inside outer's
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6


def test_span_exception_unwind_records_error():
    obs = Obs(source="t")
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    (ev,) = obs.sink.events
    assert ev["args"]["error"] == "RuntimeError"
    assert current_span() is None


def test_spans_are_thread_local():
    obs = Obs(source="t")
    seen = []

    def worker():
        with obs.span("thread.region"):
            seen.append(current_span().name)

    with obs.span("main.region"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
        assert current_span().name == "main.region"
    names = {e["name"]: e for e in obs.sink.events}
    assert seen == ["thread.region"]
    assert names["thread.region"]["depth"] == 0    # own stack, not nested
    assert names["thread.region"]["tid"] != names["main.region"]["tid"]


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def _capture(tmp_path):
    obs = Obs(source="t")
    with obs.span("outer", cat="test"):
        with obs.span("inner", cat="test"):
            pass
    obs.counter("c", part="csr").inc(2)
    obs.gauge("g").set(3.5)
    obs.histogram("h").observe(10.0)
    return obs


def test_jsonl_round_trip(tmp_path):
    obs = _capture(tmp_path)
    jsonl, chrome = obs.save(tmp_path, stem="t")
    assert jsonl.name == "t.jsonl" and chrome.name == "t.trace.json"
    recs = load_obs(jsonl)
    assert recs[0]["kind"] == "meta" and recs[0]["spans"] == 2
    kinds = {r["kind"] for r in recs}
    assert kinds == {"meta", "span", "counter", "gauge", "hist"}
    assert all(r["schema"] == OBS_SCHEMA_VERSION for r in recs)
    assert all(r["source"] == "t" for r in recs)
    hist = next(r for r in recs if r["kind"] == "hist")
    assert hist["count"] == 1 and hist["p50"] == 10.0
    assert sum(hist["counts"]) == 1
    # directory load merges every *.jsonl
    assert len(load_obs(tmp_path)) == len(recs)


def test_jsonl_rejects_future_schema_and_unknown_kind(tmp_path):
    p = tmp_path / "future.jsonl"
    p.write_text(json.dumps({"schema": OBS_SCHEMA_VERSION + 1,
                             "kind": "span"}) + "\n")
    with pytest.raises(ValueError, match="schema"):
        load_obs(p)
    p2 = tmp_path / "weird.jsonl"
    p2.write_text(json.dumps({"schema": OBS_SCHEMA_VERSION,
                              "kind": "wat"}) + "\n")
    with pytest.raises(ValueError, match="kind"):
        load_obs(p2)


def test_chrome_trace_is_valid_and_nested(tmp_path):
    obs = _capture(tmp_path)
    _, chrome_path = obs.save(tmp_path, stem="t")
    blob = json.loads(chrome_path.read_text())    # round-trips json.loads
    evs = blob["traceEvents"]
    assert blob["otherData"]["schema"] == OBS_SCHEMA_VERSION
    assert {e["ph"] for e in evs} == {"M", "X", "C"}
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"outer", "inner"}
    for e in xs.values():                          # complete-event shape
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0 and e["pid"] == 0
    assert xs["inner"]["ts"] >= xs["outer"]["ts"]
    assert (xs["inner"]["ts"] + xs["inner"]["dur"]
            <= xs["outer"]["ts"] + xs["outer"]["dur"] + 1e-6)
    assert xs["inner"]["args"]["depth"] == 1
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert "c{part=csr}" in counters and "g" in counters
    # histograms are report-rendered, never counter tracks
    assert not any(n.startswith("h") for n in counters)


# ---------------------------------------------------------------------------
# Engine seam
# ---------------------------------------------------------------------------

def test_attach_engine_counts_dispatches(rng):
    csr = csr_from_dense(random_sparse(rng, 64, 32))
    fmt, _ = plan_and_convert(csr, total_workers=4)
    b = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    obs = Obs(source="t")
    with obs.attach_engine():
        loops_spmm(fmt, b, backend="jnp")
    total = sum(inst.value for kind, inst in obs.metrics.instruments()
                if kind == "counter" and inst.name == "engine.dispatch")
    assert total >= 1
    for kind, inst in obs.metrics.instruments():
        if inst.name == "engine.dispatch":
            assert set(inst.labels) == {"part", "op", "backend", "impl"}
    # grid-step accounting rode along
    steps = [inst for kind, inst in obs.metrics.instruments()
             if inst.name == "engine.grid_steps_compiled"]
    assert steps and all(inst.value > 0 for inst in steps)
    assert obs.summary()["engine_dispatches"] == int(total)


def test_attach_engine_chains_to_trace_recorder(rng):
    from repro.perf.trace import TraceRecorder
    csr = csr_from_dense(random_sparse(rng, 32, 16))
    fmt, _ = plan_and_convert(csr, total_workers=2)
    b = jnp.asarray(rng.standard_normal((16, 4)), jnp.float32)
    rec = TraceRecorder(source="t")
    obs = Obs(source="t")
    with rec.attach_engine():
        with obs.attach_engine():
            loops_spmm(fmt, b, backend="jnp")
    n_obs = sum(inst.value for kind, inst in obs.metrics.instruments()
                if kind == "counter" and inst.name == "engine.dispatch")
    n_rec = sum(1 for r in rec.records if r["kind"] == "dispatch")
    assert n_obs >= 1 and n_rec == n_obs, \
        "chained tracer must forward every dispatch"


def test_attach_engine_restores_previous_tracer():
    from repro.kernels import engine
    before = engine.get_tracer()
    obs = Obs(source="t")
    with obs.attach_engine():
        assert engine.get_tracer() is not before
    assert engine.get_tracer() is before


# ---------------------------------------------------------------------------
# Tuner seam
# ---------------------------------------------------------------------------

def test_watch_cache_exports_hit_rate(tmp_path):
    from repro.tune import PlanCache
    cache = PlanCache(str(tmp_path))
    cache.put("k1", {"plan": 1})
    cache.lookup("k1")
    cache.lookup("k2")
    obs = Obs(source="t")
    obs.watch_cache(cache, name="test")
    recs = obs.records()
    gauges = {(r["metric"], r["labels"]["cache"]): r["value"]
              for r in recs if r["kind"] == "gauge"}
    assert gauges[("tune.cache.hits", "test")] == 1.0
    assert gauges[("tune.cache.misses", "test")] == 1.0
    assert gauges[("tune.cache.hit_rate", "test")] == 0.5


# ---------------------------------------------------------------------------
# Step seam
# ---------------------------------------------------------------------------

def test_wrap_step_records_latency_and_spans():
    obs = Obs(source="t")
    fn = jax.jit(lambda x: x * 2.0)
    wrapped = obs.wrap_step(fn, op="toy")
    for _ in range(3):
        wrapped(jnp.ones(4))
    h = obs.metrics.find("hist", "step.wall_us", op="toy")
    assert h.count == 3
    assert [e["name"] for e in obs.sink.events] == ["step.toy"] * 3
    assert [e["args"]["step"] for e in obs.sink.events] == [0, 1, 2]


# ---------------------------------------------------------------------------
# Collective seam
# ---------------------------------------------------------------------------

def test_compressed_psum_reports_bytes():
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro.compat import make_mesh, shard_map
    from repro.dist.compress import compressed_psum

    mesh = make_mesh((1,), ("d",))
    obs = Obs(source="t")
    prev = set_active(obs)
    try:
        @partial(shard_map, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
        def f(xs):
            return compressed_psum(xs[0], "d", precision="int8")[None]

        f(jnp.ones((1, 16), jnp.float32))
    finally:
        set_active(prev)
    g = obs.metrics.find("gauge", "dist.collective_bytes",
                         kind="psum", precision="int8")
    assert g is not None and g.value == 0.0    # D==1: nothing on the wire
    c = obs.metrics.find("counter", "dist.collective_sites",
                         kind="psum", precision="int8")
    assert c is not None and c.value >= 1


def test_active_capture_set_and_restore():
    assert get_active() is None
    obs = Obs(source="t")
    prev = set_active(obs)
    assert prev is None and get_active() is obs
    set_active(prev)
    assert get_active() is None


# ---------------------------------------------------------------------------
# Report CLI
# ---------------------------------------------------------------------------

def test_obs_report_cli_renders_capture(tmp_path, rng):
    csr = csr_from_dense(random_sparse(rng, 32, 16))
    fmt, _ = plan_and_convert(csr, total_workers=2)
    obs = Obs(source="cli-test")
    with obs.attach_engine():
        loops_spmm(fmt, jnp.ones((16, 4), jnp.float32), backend="jnp")
    obs.histogram("serve.decode_token_us").observe(123.0)
    jsonl, chrome = obs.save(tmp_path, stem="cli-test")

    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "obs_report.py"), str(jsonl),
         "--require-dispatch"],
        capture_output=True, text=True, cwd=ROOT)
    assert out.returncode == 0, out.stderr
    assert "engine.dispatch" in out.stdout
    assert "serve.decode_token_us" in out.stdout

    # the Chrome serialisation renders through the same CLI
    out2 = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "obs_report.py"), str(chrome)],
        capture_output=True, text=True, cwd=ROOT)
    assert out2.returncode == 0, out2.stderr


def test_obs_report_cli_failure_modes(tmp_path):
    obs = Obs(source="empty-ish")          # spans/metrics but no dispatches
    obs.counter("c").inc()
    jsonl, _ = obs.save(tmp_path, stem="nodispatch")
    out = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "obs_report.py"), str(jsonl),
         "--require-dispatch"],
        capture_output=True, text=True, cwd=ROOT)
    assert out.returncode == 3

    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    out2 = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "obs_report.py"), str(empty)],
        capture_output=True, text=True, cwd=ROOT)
    assert out2.returncode == 2
