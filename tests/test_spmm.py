"""End-to-end LOOPS SpMM: hybrid execution == dense ground truth, across
backends, precisions, planners and the synthetic SuiteSparse suite."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import (csr_from_dense, loops_from_csr, loops_spmm,
                        plan_and_convert, spmm_csr_baseline,
                        spmm_dense_baseline, suite)
from repro.core.partition import choose_r_boundary, regularity_boundary


def _dense(seed, m, k, density, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return ((rng.random((m, k)) < density)
            * rng.standard_normal((m, k))).astype(dtype)


@given(st.integers(0, 8), st.integers(1, 48), st.integers(1, 32),
       st.sampled_from([0.0, 0.1, 0.5]),
       st.sampled_from(["interpret", "jnp"]))
def test_hybrid_equals_dense(seed, m, k, density, backend):
    a = _dense(seed, m, k, density)
    rngb = np.random.default_rng(seed + 100)
    b = jnp.asarray(rngb.standard_normal((k, 8)).astype(np.float32))
    fmt, plan = plan_and_convert(csr_from_dense(a), total_workers=4)
    out = loops_spmm(fmt, b, backend=backend)
    np.testing.assert_allclose(np.asarray(out), a @ np.asarray(b),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("r_frac", [0.0, 0.3, 1.0])
def test_explicit_boundary(rng, r_frac):
    """Pure-CSR (r_b = nrows), pure-BCSR (r_b = 0) and hybrid all agree —
    the §4.3 ablation's correctness precondition."""
    a = _dense(3, 40, 24, 0.2)
    b = jnp.asarray(rng.standard_normal((24, 16)).astype(np.float32))
    r_b = int(r_frac * 40) // 8 * 8
    fmt = loops_from_csr(csr_from_dense(a), r_b, 8)
    out = loops_spmm(fmt, b, backend="interpret")
    np.testing.assert_allclose(np.asarray(out), a @ np.asarray(b), rtol=1e-4)


@pytest.mark.parametrize("mid", ["m6", "m8", "m10", "m13"])
def test_suite_matrices(mid):
    csr = suite.table2_like(mid, scale_rows=256, seed=1)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal((csr.shape[1], 8)).astype(np.float32))
    fmt, _ = plan_and_convert(csr, total_workers=4)
    out = loops_spmm(fmt, b, backend="jnp")
    want = spmm_dense_baseline(
        np.asarray(jnp.zeros(csr.shape)) * 0 +  # densify via round-trip
        _csr_dense(csr), b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


def _csr_dense(csr):
    from repro.core import csr_to_dense
    return csr_to_dense(csr)


def test_baselines_agree(rng):
    a = _dense(5, 32, 20, 0.3)
    b = jnp.asarray(rng.standard_normal((20, 8)).astype(np.float32))
    csr = csr_from_dense(a)
    base_csr = spmm_csr_baseline(csr, b)
    base_dense = spmm_dense_baseline(a, b)
    np.testing.assert_allclose(np.asarray(base_csr), np.asarray(base_dense),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# boundary / scheduler properties
# ---------------------------------------------------------------------------

@given(st.integers(1, 5000), st.floats(0.1, 10), st.floats(0.1, 10),
       st.integers(1, 16), st.integers(1, 16))
def test_boundary_in_range_and_aligned(nrows, tpv, tpm, tv, tm):
    r = choose_r_boundary(nrows, tpv, tpm, tv, tm, br=8)
    assert 0 <= r <= nrows
    assert r % 8 == 0 or r == nrows


def test_boundary_monotone_in_vpu_capability():
    rs = [choose_r_boundary(1024, tpv, 4.0, 4, 4, br=8)
          for tpv in (0.5, 1.0, 2.0, 4.0)]
    assert rs == sorted(rs)  # more VPU capability -> more CSR rows


def test_boundary_degenerate_cases():
    assert choose_r_boundary(100, 1, 1, 4, 0) == 100  # no MXU -> pure CSR
    assert choose_r_boundary(100, 1, 1, 0, 4) == 0    # no VPU -> pure BCSR


def test_paper_literal_flag_differs():
    balanced = choose_r_boundary(1000, 1.0, 4.0, 2, 2, br=8)
    literal = choose_r_boundary(1000, 1.0, 4.0, 2, 2, br=8,
                                paper_literal=True)
    assert balanced + literal == pytest.approx(1000, abs=16)


def test_regularity_boundary_prefers_regular_suffix():
    # first half: power-law hubs; second half: regular band
    top = suite.powerlaw(128, 128, 6.0, seed=0)
    bot = suite.banded(128, 128, 3, seed=1)
    import numpy as np
    from repro.core import csr_to_dense, csr_from_dense
    dense = np.concatenate([csr_to_dense(top), csr_to_dense(bot)], axis=0)
    csr = csr_from_dense(dense)
    r = regularity_boundary(csr, br=8)
    assert 0 <= r <= 192  # boundary should not eat the regular suffix
