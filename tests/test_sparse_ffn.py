"""The paper's technique inside the LM: pruned linear via LOOPS SpMM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.sparse_ffn import (magnitude_prune, sparse_linear_apply,
                                     sparse_linear_from_dense)


def test_magnitude_prune_levels(rng):
    w = rng.standard_normal((64, 64)).astype(np.float32)
    for s in (0.0, 0.5, 0.9):
        pruned = magnitude_prune(w, s)
        frac = (pruned == 0).mean()
        assert frac == pytest.approx(s, abs=0.05)


@pytest.mark.parametrize("sparsity", [0.5, 0.9])
def test_sparse_linear_matches_pruned_dense(rng, sparsity):
    w = rng.standard_normal((24, 16)).astype(np.float32)
    layer = sparse_linear_from_dense(w, sparsity)
    vals = layer.init_values()
    x = jnp.asarray(rng.standard_normal((5, 16)), jnp.float32)
    got = sparse_linear_apply(layer, vals, x, backend="jnp")
    want = x @ magnitude_prune(w, sparsity).T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


def test_ref_and_pallas_backends_agree(rng):
    """Train-on-ref / serve-on-Pallas contract: identical outputs."""
    w = rng.standard_normal((32, 16)).astype(np.float32)
    layer = sparse_linear_from_dense(w, 0.7)
    vals = layer.init_values()
    x = jnp.asarray(rng.standard_normal((4, 16)), jnp.float32)
    a = sparse_linear_apply(layer, vals, x, backend="jnp")
    b = sparse_linear_apply(layer, vals, x, backend="interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_values_are_trainable(rng):
    """Grads flow to the LOOPS value arrays (structure stays fixed)."""
    w = rng.standard_normal((16, 12)).astype(np.float32)
    layer = sparse_linear_from_dense(w, 0.5)
    vals = layer.init_values()
    x = jnp.asarray(rng.standard_normal((3, 12)), jnp.float32)

    def loss(v):
        return jnp.sum(sparse_linear_apply(layer, v, x, backend="jnp") ** 2)

    g = jax.grad(loss)(vals)
    gn = sum(float(jnp.abs(t).sum()) for t in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
