#!/usr/bin/env python
"""Documentation link/reference checker (CI docs job; no dependencies).

Scans the repo's markdown surface (``README.md``, ``docs/*.md``,
``ROADMAP.md``, ``CHANGES.md``) for

  * **relative markdown links** ``[text](path)`` — the target file must
    exist (anchors and external ``http(s)``/``mailto`` links are skipped);
  * **code references** of the form ``path/to/file.py::symbol`` (the house
    style throughout ``docs/architecture.md``) — the file must exist
    (resolved against the repo root, then ``src/repro/``) and the symbol
    name must occur in it, so renaming or deleting a function without
    updating the docs fails CI;
  * **bare ``.py`` paths in backticks** — same existence resolution.
    Exception: ``ROADMAP.md`` names files *to be built* (it is the forward-
    looking plan), so its bare-path references are exempt from the
    existence check; its links and ``::symbol`` references still must
    resolve.

Exit status 0 when clean; 1 with a per-problem listing otherwise.

Run:  python tools/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SYMREF_RE = re.compile(r"([\w][\w./-]*\.py)::([A-Za-z_][A-Za-z0-9_]*)")
PYPATH_RE = re.compile(r"`([\w][\w./-]*\.py)`")

# Docs that describe planned work: bare .py mentions may not exist yet.
ASPIRATIONAL = {"ROADMAP.md"}


def _doc_files():
    files = [ROOT / "README.md", ROOT / "ROADMAP.md", ROOT / "CHANGES.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def _resolve_py(path_str: str, base: pathlib.Path):
    """A .py reference may be repo-root-relative, src- or src/repro-relative
    (the architecture.md shorthands) or relative to the referencing
    document; a bare filename (``train.py`` under a ``launch/`` heading)
    resolves if any file of that name exists in the tree."""
    for cand in (ROOT / path_str, ROOT / "src" / path_str,
                 ROOT / "src" / "repro" / path_str, base.parent / path_str):
        if cand.exists():
            return cand
    if "/" not in path_str:
        for cand in ROOT.rglob(path_str):
            return cand
    return None


def check_file(md: pathlib.Path):
    problems = []
    text = md.read_text()
    rel = md.relative_to(ROOT)

    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        plain = target.split("#", 1)[0]
        if not plain:
            continue
        if not (md.parent / plain).exists() and not (ROOT / plain).exists():
            problems.append(f"{rel}: dead link -> {target}")

    for m in SYMREF_RE.finditer(text):
        path_str, symbol = m.groups()
        target = _resolve_py(path_str, md)
        if target is None:
            problems.append(f"{rel}: missing file in ref {path_str}::{symbol}")
            continue
        if not re.search(rf"\b{re.escape(symbol)}\b", target.read_text()):
            problems.append(
                f"{rel}: {path_str} no longer defines '{symbol}'")

    if str(rel) not in ASPIRATIONAL:
        for m in PYPATH_RE.finditer(text):
            path_str = m.group(1)
            if "::" in m.group(0):
                continue
            if _resolve_py(path_str, md) is None:
                problems.append(
                    f"{rel}: referenced file missing -> {path_str}")

    return problems


def main() -> int:
    problems = []
    for md in _doc_files():
        problems += check_file(md)
    if problems:
        print(f"check_docs: {len(problems)} problem(s)")
        for p in problems:
            print("  " + p)
        return 1
    print(f"check_docs: OK ({len(_doc_files())} files clean)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
