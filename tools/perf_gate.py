#!/usr/bin/env python
"""Perf regression gate: diff bench.json against a committed baseline.

Closes the trace → fit → replay → **gate** loop (docs/architecture.md):
``benchmarks/run.py --smoke`` writes ``benchmarks/results/bench.json``;
this tool diffs it against the committed ``BENCH_<PR>.json`` baseline and
exits non-zero on regression, so a kernel change that preserves correctness
but inflates the grid (or silently drops a benchmark column) fails CI.

Both files are first validated against ``benchmarks/bench_schema.json``
(via the dependency-free subset validator ``repro.perf.schema``).  Records
pair up on the identity key ``(suite, matrix, dtype, batch, n_cols)``;
per-metric tolerance bands then apply:

  * **exact**   — ``steps_*`` / ``grid_steps*`` / ``panel_g`` / ``nnz`` /
    ``pipeline_depth`` / ``macro_m``:
    structural counts, deterministic functions of the seeded matrices and
    the resolved plan; ANY difference fails (an improvement means the
    baseline is stale — refresh it with ``run.py --update-baseline``);
  * **near**    — ``step_reduction*``: derived ratios of exact counts;
    relative tolerance 1e-6 (float formatting slack only);
  * **wall**    — every other numeric column (``*_us*``, ``gflops``,
    ``vs_*``): machine-dependent; a wide worse-than ratio band
    (``--wall-tol``, default 10x) catches order-of-magnitude cliffs while
    tolerating cross-machine variance.  ``--wall-tol inf`` disables wall
    checks entirely (what CI uses — baselines are recorded on developer
    machines; the grid-step columns carry the cross-machine gate).

A baseline record missing from the current run, or a baseline column
missing from its paired record, is always a failure.

Run:  python tools/perf_gate.py [--baseline F] [--current F] [--wall-tol X]
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
from typing import Dict, List, Sequence

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.perf.schema import load_schema, validate  # noqa: E402

SCHEMA_PATH = ROOT / "benchmarks" / "bench_schema.json"
DEFAULT_BASELINE = ROOT / "benchmarks" / "results" / "BENCH_010.json"
DEFAULT_CURRENT = ROOT / "benchmarks" / "results" / "bench.json"

KEY_FIELDS = ("suite", "matrix", "dtype", "batch", "n_cols")
EXACT_PREFIXES = ("steps_", "grid_steps")
EXACT_FIELDS = {"panel_g", "nnz", "pipeline_depth", "macro_m"}
NEAR_PREFIX = "step_reduction"
HIGHER_BETTER_TOKENS = ("gflops", "vs_", "speedup", "reduction")


def record_key(rec: Dict) -> tuple:
    return tuple(rec.get(k) for k in KEY_FIELDS)


def classify(field: str) -> str:
    """Tolerance class of a numeric column: 'key', 'exact', 'near', 'wall'."""
    if field in KEY_FIELDS:
        return "key"
    if field.startswith(EXACT_PREFIXES) or field in EXACT_FIELDS:
        return "exact"
    if field.startswith(NEAR_PREFIX):
        return "near"
    return "wall"


def _higher_better(field: str) -> bool:
    return any(tok in field for tok in HIGHER_BETTER_TOKENS)


def validate_records(records, schema: Dict, label: str) -> List[str]:
    """Schema-validate a bench record list; returns problem strings."""
    probs = validate(records, {"$ref": "#/definitions/bench_file"}, schema)
    return [f"{label}: schema violation at {p}" for p in probs]


def diff_records(baseline: Sequence[Dict], current: Sequence[Dict], *,
                 wall_tol: float = 10.0,
                 near_rtol: float = 1e-6) -> List[str]:
    """Compare current records against the baseline; returns failures.

    Library entry point — the negative self-test
    (tests/test_perf_gate.py) injects synthetic regressions through here.
    """
    failures: List[str] = []
    cur_by_key = {record_key(r): r for r in current}
    for brec in baseline:
        if brec.get("skipped"):
            continue
        key = record_key(brec)
        crec = cur_by_key.get(key)
        if crec is None:
            failures.append(f"{key}: baseline record missing from current "
                            "bench.json (suite dropped or renamed?)")
            continue
        for field, bval in brec.items():
            if isinstance(bval, bool) or not isinstance(bval, (int, float)):
                continue
            kind = classify(field)
            if kind == "key":
                continue
            if field not in crec:
                failures.append(f"{key}: column {field!r} dropped from "
                                "current record")
                continue
            cval = crec[field]
            if isinstance(cval, bool) or not isinstance(cval, (int, float)):
                failures.append(f"{key}: column {field!r} is no longer "
                                f"numeric ({cval!r})")
                continue
            if kind == "exact":
                if int(round(cval)) != int(round(bval)):
                    failures.append(
                        f"{key}: {field} changed {int(round(bval))} -> "
                        f"{int(round(cval))} (exact metric; regression, or "
                        "refresh the baseline with --update-baseline)")
            elif kind == "near":
                denom = max(abs(bval), 1e-12)
                if abs(cval - bval) / denom > near_rtol:
                    failures.append(
                        f"{key}: {field} drifted {bval:.6f} -> {cval:.6f} "
                        f"(derived ratio; tolerance {near_rtol:g})")
            else:   # wall-clock class
                if not math.isfinite(wall_tol):
                    continue
                if _higher_better(field):
                    if bval > 0 and cval < bval / wall_tol:
                        failures.append(
                            f"{key}: {field} collapsed {bval:.3g} -> "
                            f"{cval:.3g} (> {wall_tol:g}x worse)")
                else:
                    if bval > 0 and cval > bval * wall_tol:
                        failures.append(
                            f"{key}: {field} inflated {bval:.3g} -> "
                            f"{cval:.3g} (> {wall_tol:g}x worse)")
    return failures


def run_gate(baseline_path, current_path, *, wall_tol: float = 10.0,
             near_rtol: float = 1e-6,
             schema_path=SCHEMA_PATH) -> List[str]:
    """Load + schema-validate + diff; returns the full failure list."""
    failures: List[str] = []
    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as e:
        return [f"baseline {baseline_path}: unreadable ({e})"]
    try:
        with open(current_path) as f:
            current = json.load(f)
    except (OSError, ValueError) as e:
        return [f"current {current_path}: unreadable ({e})"]
    schema = load_schema(schema_path)
    failures += validate_records(baseline, schema, f"baseline")
    failures += validate_records(current, schema, f"current")
    failures += diff_records(baseline, current, wall_tol=wall_tol,
                             near_rtol=near_rtol)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff bench.json against the committed baseline; "
                    "non-zero exit on regression.")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="committed BENCH_<PR>.json baseline")
    ap.add_argument("--current", default=str(DEFAULT_CURRENT),
                    help="bench.json produced by benchmarks/run.py")
    ap.add_argument("--wall-tol", type=float, default=10.0,
                    help="worse-than ratio band for wall-clock metrics "
                         "('inf' disables them; exact/near classes are "
                         "unaffected)")
    ap.add_argument("--near-rtol", type=float, default=1e-6,
                    help="relative tolerance for derived-ratio metrics")
    args = ap.parse_args(argv)

    failures = run_gate(args.baseline, args.current, wall_tol=args.wall_tol,
                        near_rtol=args.near_rtol)
    if failures:
        print(f"perf_gate: {len(failures)} failure(s) vs {args.baseline}")
        for f in failures:
            print("  " + f)
        return 1
    print(f"perf_gate: OK ({args.current} vs {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
