#!/usr/bin/env python
"""Render a captured obs run into a terminal summary.

Reads either serialisation an :class:`repro.obs.Obs` capture produces —
the versioned JSONL record stream (``*.jsonl``, preferred: it carries the
full metric instruments) or the Chrome/Perfetto trace (``*.trace.json`` /
any ``{"traceEvents": [...]}`` file, from which spans and counter tracks
are reconstructed) — and prints:

  * top ops by total span time (count / total / mean / max per span name),
  * per-``(part, op)`` engine dispatch counters and grid-step totals,
  * every latency histogram with count / p50 / p90 / p99,
  * tuner plan-cache hit rate (``tune.cache.*`` gauges, per watched cache),
  * serving section — the continuous-batching queue's admission/latency
    surface: ``serve.queue_depth`` gauge, request/reject/evict counters,
    and ``serve.request_us`` / ``serve.ttft_us`` p50/p99,
  * throughput gauges (``serve.tokens_per_s``, ``train.steps_per_s``, ...).

  * degradations — every resilience counter the run recorded
    (``engine.fallback``, ``serve.degraded``, ``tune.cache.quarantined``,
    ``tune.search.trial_failed``, ``dist.fallback``, ``validate.repaired``,
    ``inject.fired``, ...; see docs/robustness.md).

Exit codes: 0 on a rendered report, 2 on an empty capture, 1 on an
unreadable/invalid file.  ``--require-dispatch`` additionally exits 3 when
the capture holds no nonzero ``engine.dispatch`` counter — CI uses this to
assert the serve smoke run actually exercised the kernel engine —
and ``--require-serving`` exits 3 when it holds no nonzero
``serve.requests`` counter (the serving-CI analogue: a batching capture
need not touch the sparse engine at all).
``--fail-on-degraded`` exits 4 when ANY degradation counter is nonzero
(the normal CI path asserts a clean run); ``--require-degraded METRIC``
(repeatable) exits 5 unless that degradation metric is nonzero (the chaos
CI asserts its injected faults actually degraded, not crashed).

Run:  python tools/obs_report.py benchmarks/results/obs/serve.jsonl
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from collections import defaultdict
from typing import Dict, List

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.obs.export import load_obs  # noqa: E402

# Resilience counters (docs/robustness.md): any nonzero value here means the
# run degraded somewhere — fell back, retried, repaired, or quarantined.
DEGRADATION_METRICS = (
    "engine.fallback", "serve.degraded", "serve.retries",
    "tune.cache.quarantined", "tune.search.trial_failed",
    "tune.search.degraded", "dist.fallback", "validate.repaired",
    "inject.fired",
)


def records_from_chrome(path: pathlib.Path) -> List[Dict]:
    """Reconstruct obs-style records from a Chrome trace: ``X`` events
    become span records, ``C`` counter tracks become gauge records (the
    JSONL keeps richer data — histograms don't survive the round trip)."""
    with open(path) as f:
        blob = json.load(f)
    events = blob.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError(f"{path}: not a Chrome trace (no traceEvents)")
    source = str(blob.get("otherData", {}).get("source", path.stem))
    recs: List[Dict] = []
    for ev in events:
        if ev.get("ph") == "X":
            recs.append({"kind": "span", "source": source,
                         "name": ev.get("name", "?"),
                         "cat": ev.get("cat", ""),
                         "ts": float(ev.get("ts", 0.0)),
                         "dur": float(ev.get("dur", 0.0)),
                         "tid": int(ev.get("tid", 0)),
                         "depth": int(ev.get("args", {}).get("depth", 0)),
                         "args": ev.get("args", {})})
        elif ev.get("ph") == "C":
            name = str(ev.get("name", "?"))
            labels = {}
            if "{" in name and name.endswith("}"):
                name, _, lab = name.partition("{")
                for pair in lab[:-1].split(","):
                    if "=" in pair:
                        k, _, v = pair.partition("=")
                        labels[k] = v
            recs.append({"kind": "gauge", "source": source, "metric": name,
                         "labels": labels,
                         "value": float(ev.get("args", {})
                                        .get("value", 0.0))})
    return recs


def load_records(path: pathlib.Path) -> List[Dict]:
    if path.is_dir() or path.suffix == ".jsonl":
        return load_obs(path)
    return records_from_chrome(path)


def _label_str(labels: Dict) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}"
                          for k, v in sorted(labels.items())) + "}"


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:8.2f}s "
    if us >= 1e3:
        return f"{us / 1e3:8.2f}ms"
    return f"{us:8.1f}us"


def report(records: List[Dict], *, top: int = 10,
           out=print) -> Dict[str, int]:
    """Print the report; returns counters the caller gates on
    (``spans``, ``dispatches``)."""
    spans = [r for r in records if r.get("kind") == "span"]
    counters = [r for r in records if r.get("kind") == "counter"]
    gauges = [r for r in records if r.get("kind") == "gauge"]
    hists = [r for r in records if r.get("kind") == "hist"]
    sources = sorted({r.get("source", "?") for r in records})

    out(f"obs report: source={','.join(sources) or '?'}  "
        f"spans={len(spans)}  counters={len(counters)}  "
        f"gauges={len(gauges)}  hists={len(hists)}")

    if spans:
        agg = defaultdict(lambda: [0, 0.0, 0.0])   # count, total, max
        for s in spans:
            a = agg[s["name"]]
            a[0] += 1
            a[1] += float(s["dur"])
            a[2] = max(a[2], float(s["dur"]))
        out(f"\ntop ops by total span time (top {top}):")
        out(f"  {'span':<28} {'count':>6} {'total':>10} {'mean':>10} "
            f"{'max':>10}")
        ranked = sorted(agg.items(), key=lambda kv: -kv[1][1])[:top]
        for name, (cnt, tot, mx) in ranked:
            out(f"  {name:<28} {cnt:>6} {_fmt_us(tot):>10} "
                f"{_fmt_us(tot / cnt):>10} {_fmt_us(mx):>10}")

    disp = [c for c in counters if c.get("metric") == "engine.dispatch"]
    n_disp = int(sum(c.get("value", 0) for c in disp))
    if disp:
        out("\nengine dispatches (compiled workloads, per (part, op)):")
        steps = {_label_str({k: v for k, v in g.get("labels", {}).items()
                             if k in ("part", "op")}): g.get("value")
                 for g in counters
                 if g.get("metric") == "engine.grid_steps_compiled"}
        for c in sorted(disp, key=lambda c: -c.get("value", 0)):
            lab = c.get("labels", {})
            key = _label_str({k: v for k, v in lab.items()
                              if k in ("part", "op")})
            extra = f"  grid_steps={int(steps[key])}" if key in steps else ""
            out(f"  {c['metric']}{_label_str(lab):<50} "
                f"{int(c.get('value', 0)):>8}{extra}")
        out(f"  total dispatches: {n_disp}")

    # Pipelined-kernel gauges: VMEM scratch footprint and the fraction of
    # grid steps whose B-panel assembly overlaps compute (0.0 = serial).
    kern = defaultdict(dict)
    for g in gauges:
        m = g.get("metric", "")
        if m in ("kernel.scratch_bytes", "engine.prefetch_overlap"):
            key = _label_str({k: v for k, v in g.get("labels", {}).items()
                              if k in ("part", "op")})
            kern[key][m] = float(g.get("value", 0.0))
    if kern:
        out("\nkernel pipeline (per (part, op)):")
        out(f"  {'labels':<40} {'scratch':>12} {'overlap':>8}")
        for key, row in sorted(kern.items()):
            sb = row.get("kernel.scratch_bytes")
            ov = row.get("engine.prefetch_overlap")
            sb_s = f"{int(sb):>10}B " if sb is not None else f"{'-':>12}"
            ov_s = f"{ov:>7.2f} " if ov is not None else f"{'-':>8}"
            out(f"  {key:<40} {sb_s} {ov_s}")

    if hists:
        out("\nlatency histograms:")
        out(f"  {'metric':<40} {'count':>6} {'p50':>10} {'p90':>10} "
            f"{'p99':>10}")
        for h in hists:
            name = f"{h['metric']}{_label_str(h.get('labels', {}))}"
            out(f"  {name:<40} {int(h.get('count', 0)):>6} "
                f"{_fmt_us(float(h.get('p50', 0))):>10} "
                f"{_fmt_us(float(h.get('p90', 0))):>10} "
                f"{_fmt_us(float(h.get('p99', 0))):>10}")

    cache_rows = defaultdict(dict)
    for g in gauges:
        m = g.get("metric", "")
        if m.startswith("tune.cache."):
            name = g.get("labels", {}).get("cache", "?")
            cache_rows[name][m.rsplit(".", 1)[1]] = g.get("value", 0.0)
    if cache_rows:
        out("\ntuner plan-cache:")
        for name, row in sorted(cache_rows.items()):
            out(f"  cache={name}: hits={int(row.get('hits', 0))} "
                f"near={int(row.get('near_hits', 0))} "
                f"misses={int(row.get('misses', 0))} "
                f"hit_rate={row.get('hit_rate', 0.0):.2f}")

    # Serving: the continuous-batching queue's admission / latency surface
    # (docs/serving.md).  Counters roll up across sources; the queue-depth
    # and in-flight gauges report the last captured value per source.
    serve_ctr = defaultdict(float)
    for c in counters:
        if c.get("metric", "").startswith("serve.") \
                and c.get("metric") not in DEGRADATION_METRICS:
            serve_ctr[c["metric"]] += float(c.get("value", 0))
    serve_gauge = {g["metric"]: float(g.get("value", 0.0)) for g in gauges
                   if g.get("metric") in ("serve.queue_depth",
                                          "serve.in_flight")}
    serve_hist = [h for h in hists
                  if h.get("metric") in ("serve.request_us",
                                         "serve.ttft_us")]
    if serve_ctr or serve_gauge or serve_hist:
        out("\nserving (continuous-batching queue):")
        for m in ("serve.requests", "serve.rejected", "serve.evicted",
                  "serve.prefill_calls", "serve.decode_calls",
                  "serve.tokens_generated"):
            if m in serve_ctr:
                out(f"  {m:<28} {int(serve_ctr[m]):>8}")
        for m, v in sorted(serve_gauge.items()):
            out(f"  {m:<28} {int(v):>8}")
        for h in serve_hist:
            out(f"  {h['metric']:<28} count={int(h.get('count', 0)):>5} "
                f"p50={_fmt_us(float(h.get('p50', 0))).strip()} "
                f"p99={_fmt_us(float(h.get('p99', 0))).strip()}")

    thr = [g for g in gauges
           if g.get("metric", "").endswith(("_per_s", "tokens_per_s"))]
    if thr:
        out("\nthroughput:")
        for g in thr:
            out(f"  {g['metric']}{_label_str(g.get('labels', {}))} = "
                f"{float(g.get('value', 0.0)):.2f}")

    # Degradations: per-metric totals across counters, plus the
    # tune.cache.quarantined gauge (counter and gauge describe the same
    # events — take the max per metric, never the sum, to avoid double
    # counting a quarantine that landed on both).
    degr_rows = defaultdict(float)
    degraded: Dict[str, float] = defaultdict(float)
    gauge_q = 0.0
    for c in counters:
        m = c.get("metric", "")
        if m in DEGRADATION_METRICS:
            degr_rows[f"{m}{_label_str(c.get('labels', {}))}"] += \
                float(c.get("value", 0))
            degraded[m] += float(c.get("value", 0))
    for g in gauges:
        if g.get("metric") == "tune.cache.quarantined_files":
            gauge_q += float(g.get("value", 0.0))
    if gauge_q > degraded.get("tune.cache.quarantined", 0.0):
        degraded["tune.cache.quarantined"] = gauge_q
        degr_rows["tune.cache.quarantined (gauge)"] = gauge_q
    if degr_rows:
        out("\ndegradations (fallbacks / retries / repairs / quarantines):")
        for name, v in sorted(degr_rows.items()):
            out(f"  {name:<60} {int(v):>6}")
    n_degraded = sum(v for v in degraded.values() if v > 0)

    return {"spans": len(spans), "dispatches": n_disp,
            "served": int(serve_ctr.get("serve.requests", 0)),
            "degraded": dict(degraded), "n_degraded": int(n_degraded)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", type=pathlib.Path,
                    help="obs .jsonl (or a directory of them), or a "
                         "Chrome .trace.json")
    ap.add_argument("--top", type=int, default=10,
                    help="rows in the top-ops table")
    ap.add_argument("--require-dispatch", action="store_true",
                    help="exit 3 unless a nonzero engine.dispatch counter "
                         "is present (CI smoke gate)")
    ap.add_argument("--require-serving", action="store_true",
                    help="exit 3 unless a nonzero serve.requests counter is "
                         "present (serving-CI smoke gate; serving captures "
                         "need not touch the sparse engine, so this is "
                         "their analogue of --require-dispatch)")
    ap.add_argument("--fail-on-degraded", action="store_true",
                    help="exit 4 if ANY degradation counter is nonzero "
                         "(normal-path CI gate)")
    ap.add_argument("--require-degraded", action="append", default=[],
                    metavar="METRIC",
                    help="exit 5 unless this degradation metric is nonzero "
                         "(repeatable; chaos-CI gate)")
    args = ap.parse_args(argv)

    try:
        records = load_records(args.path)
    except (OSError, ValueError) as e:
        print(f"obs_report: {e}", file=sys.stderr)
        return 1
    if not records:
        print(f"obs_report: {args.path}: empty capture", file=sys.stderr)
        return 2
    stats = report(records, top=args.top)
    if args.require_dispatch and stats["dispatches"] <= 0:
        print("obs_report: no nonzero engine.dispatch counters "
              "(--require-dispatch)", file=sys.stderr)
        return 3
    if args.require_serving and stats["served"] <= 0:
        print("obs_report: no nonzero serve.requests counter "
              "(--require-serving)", file=sys.stderr)
        return 3
    if args.fail_on_degraded and stats["n_degraded"] > 0:
        print(f"obs_report: degradations recorded "
              f"({stats['degraded']}) (--fail-on-degraded)",
              file=sys.stderr)
        return 4
    for metric in args.require_degraded:
        if stats["degraded"].get(metric, 0) <= 0:
            print(f"obs_report: degradation metric {metric!r} is zero "
                  f"(--require-degraded)", file=sys.stderr)
            return 5
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
