"""Sparse-FFN LM training: the paper's technique inside a transformer.

Trains a ~100M-parameter llama-style LM for a few hundred steps where every
FFN up/down projection is magnitude-pruned and executed through LOOPS SpMM
(values trainable, structure fixed — DESIGN.md §Arch-applicability), then
cross-checks the final sparse layers on the Pallas kernel path.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(defaults are sized for the 1-core CPU container; increase for real runs)
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.sparse_ffn import (sparse_linear_apply,
                                     sparse_linear_from_dense)


def build(d_model, d_ff, n_layers, vocab, sparsity, rng):
    """A small decoder-only LM with LOOPS-sparse FFNs (dense attention)."""
    params = {"embed": np.asarray(
        rng.standard_normal((vocab, d_model)) * 0.02, np.float32)}
    structures = []
    for i in range(n_layers):
        wi = rng.standard_normal((d_ff, d_model)).astype(np.float32) * 0.05
        wo = rng.standard_normal((d_model, d_ff)).astype(np.float32) * 0.05
        li = sparse_linear_from_dense(wi, sparsity)
        lo = sparse_linear_from_dense(wo, sparsity)
        structures.append((li, lo))
        params[f"ffn{i}_in"] = li.init_values()
        params[f"ffn{i}_out"] = lo.init_values()
        params[f"attn{i}"] = {
            "wq": np.asarray(rng.standard_normal((d_model, d_model)) * 0.05,
                             np.float32),
            "wk": np.asarray(rng.standard_normal((d_model, d_model)) * 0.05,
                             np.float32),
            "wv": np.asarray(rng.standard_normal((d_model, d_model)) * 0.05,
                             np.float32),
            "wo": np.asarray(rng.standard_normal((d_model, d_model)) * 0.05,
                             np.float32),
        }
        params[f"norm{i}a"] = {"scale": np.ones(d_model, np.float32)}
        params[f"norm{i}b"] = {"scale": np.ones(d_model, np.float32)}
    params["final_norm"] = {"scale": np.ones(d_model, np.float32)}
    params = jax.tree.map(jnp.asarray, params)
    return params, structures


def forward(params, structures, tokens, n_heads, backend="jnp"):
    x = params["embed"][tokens]
    B, S, d = x.shape
    pos = jnp.arange(S)[None]
    for i, (li, lo) in enumerate(structures):
        h = L.rmsnorm(params[f"norm{i}a"], x)
        ap = params[f"attn{i}"]
        hd = d // n_heads
        q = L.rope((h @ ap["wq"]).reshape(B, S, n_heads, hd), pos, 1e4)
        k = L.rope((h @ ap["wk"]).reshape(B, S, n_heads, hd), pos, 1e4)
        v = (h @ ap["wv"]).reshape(B, S, n_heads, hd)
        attn = L.flash_attention(q, k, v, causal=True, q_chunk=64, k_chunk=64)
        x = x + attn.reshape(B, S, d) @ ap["wo"]
        h2 = L.rmsnorm(params[f"norm{i}b"], x)
        inner = jax.nn.relu(sparse_linear_apply(
            li, params[f"ffn{i}_in"], h2, backend=backend))
        x = x + sparse_linear_apply(lo, params[f"ffn{i}_out"], inner,
                                    backend=backend)
    x = L.rmsnorm(params["final_norm"], x)
    return x @ params["embed"].T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--d-ff", type=int, default=512)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--sparsity", type=float, default=0.8)
    ap.add_argument("--lr", type=float, default=3e-2)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    params, structures = build(args.d_model, args.d_ff, args.layers,
                               args.vocab, args.sparsity, rng)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    nnz = sum(len(p["csr_vals"]) + p["bcsr_vals"].size
              for name, p in params.items() if name.startswith("ffn"))
    print(f"params: {n_params / 1e6:.2f}M  (sparse FFN values: {nnz / 1e6:.2f}M "
          f"at {args.sparsity:.0%} sparsity)")

    def batch_at(step):
        key = jax.random.fold_in(jax.random.key(7), step)
        seq = jax.random.randint(key, (args.batch, args.seq + 1), 0,
                                 args.vocab)
        # learnable structure: the stream repeats with period 8, so the
        # next token is visible 8 positions back — a canonical induction task
        seq = jnp.tile(seq[:, :8], (1, (args.seq + 8) // 8 + 1))
        return seq[:, :args.seq], seq[:, 1:args.seq + 1]

    def loss_fn(p, toks, tgt):
        logits = forward(p, structures, toks, args.heads)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - gold)

    @jax.jit
    def step_fn(p, toks, tgt):
        loss, g = jax.value_and_grad(loss_fn)(p, toks, tgt)
        p = jax.tree.map(lambda w, gw: w - args.lr * gw, p, g)
        return p, loss

    t0 = time.time()
    first = None
    for s in range(args.steps):
        toks, tgt = batch_at(s)
        params, loss = step_fn(params, toks, tgt)
        if first is None:
            first = float(loss)
        if s % max(args.steps // 10, 1) == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(loss):.4f}")
    print(f"{args.steps} steps in {time.time() - t0:.1f}s; "
          f"loss {first:.3f} -> {float(loss):.3f}")
    assert float(loss) < first, "did not learn"

    # serve-path cross-check: Pallas kernels produce the same logits the
    # model was trained with (train-on-ref / serve-on-kernel contract)
    toks, _ = batch_at(0)
    l_ref = forward(params, structures, toks[:1, :16], args.heads, "jnp")
    l_pal = forward(params, structures, toks[:1, :16], args.heads,
                    "interpret")
    np.testing.assert_allclose(np.asarray(l_ref), np.asarray(l_pal),
                               rtol=1e-3, atol=1e-3)
    print("OK: Pallas serve path matches trained reference path")


if __name__ == "__main__":
    main()
