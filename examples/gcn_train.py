"""Paper §4.5 end-to-end case study: train a 2-layer GCN whose neighbourhood
aggregation runs through the LOOPS SpMM operator.

Trains a few hundred steps of node classification on a synthetic graph and
verifies (a) loss decreases, (b) the LOOPS operator's gradients match the
dense-adjacency reference (no accuracy loss, as the paper reports).

Since the custom VJP landed, training runs on the *real* kernel path by
default — 'pallas' on TPU, 'interpret' (the Pallas oracle) elsewhere: the
forward pass is the fused panel kernels and the backward pass is the same
kernels on the cached transposed format (``docs/training.md`` walks the
dataflow).  ``--backend jnp`` keeps the pure-reference path as the gradient
oracle; the dense adjacency appears only in the one-off parity check, never
in the training step.

Run:  PYTHONPATH=src python examples/gcn_train.py              # real kernels
      PYTHONPATH=src python examples/gcn_train.py --backend jnp --steps 300
      PYTHONPATH=src python examples/gcn_train.py --steps 2    # CI smoke
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csr_to_dense, loops_spmm, plan_and_convert, suite
from repro.kernels import ops

F_IN, F_HID, F_OUT = 64, 64, 10


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None,
                    help="default 300 (jnp/pallas) / 40 (interpret: the "
                         "sequential Pallas oracle is ~100x slower per "
                         "nonzero, so the default problem is sized down)")
    ap.add_argument("--nodes", type=int, default=None,
                    help="default 2048 (jnp/pallas) / 256 (interpret)")
    ap.add_argument("--degree", type=int, default=None,
                    help="default 8 (jnp/pallas) / 4 (interpret)")
    ap.add_argument("--lr", type=float, default=5.0)
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "interpret", "jnp"],
                    help="kernel path for BOTH the forward and backward "
                         "SpMM (default: the real kernels — 'pallas' on "
                         "TPU, 'interpret' elsewhere; 'jnp' is the "
                         "reference/oracle path)")
    ap.add_argument("--skip-grad-check", action="store_true",
                    help="skip the one-off custom-VJP vs dense-adjacency "
                         "gradient parity check")
    ap.add_argument("--autotune", action="store_true",
                    help="plan via the measured repro.tune cache instead of "
                         "the hand-set total_workers=8 model path; the two "
                         "GCN layers (and every restart of this script with "
                         "the same graph statistics) share one cached plan")
    args = ap.parse_args()
    backend = args.backend or ops.default_backend()
    small = backend == "interpret"   # oracle mode: keep the default quick
    if args.steps is None:
        args.steps = 40 if small else 300
    if args.nodes is None:
        args.nodes = 256 if small else 2048
    if args.degree is None:
        args.degree = 4 if small else 8

    t0 = time.time()
    adj = suite.gcn_graph(args.nodes, args.degree, seed=0)
    if args.autotune:
        from repro.tune import PlanCache, autotune
        cache = PlanCache()   # $REPRO_TUNE_CACHE honoured
        # One autotune per layer: layer 0 pays for the search (or hits a
        # previous run's plan on disk), layer 1 is an in-process cache hit.
        fmt, plan = autotune(adj, n_cols=F_HID, cache=cache, backend="jnp")
        _, plan1 = autotune(adj, n_cols=F_HID, cache=cache, backend="jnp")
        assert plan1 == plan, "same fingerprint must yield the same plan"
        print(f"autotune: plan={plan}; cache {cache.stats} "
              f"({len(cache)} stored plans in {cache.dir})")
    else:
        fmt, plan = plan_and_convert(adj, total_workers=8)
    t_prep = time.time() - t0
    print(f"graph: {args.nodes} nodes, nnz={adj.nnz}; conversion {t_prep:.3f}s "
          f"(r_boundary={plan.r_boundary}); backend={backend}")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((args.nodes, F_IN)), jnp.float32)
    # planted labels: community = argmax of a random linear map of features
    w_true = rng.standard_normal((F_IN, F_OUT))
    y = jnp.asarray(np.argmax(csr_to_dense(adj) @ (np.asarray(x) @ w_true),
                              axis=1), jnp.int32)

    params = {"w0": jnp.asarray(rng.standard_normal((F_IN, F_HID)) * 0.1,
                                jnp.float32),
              "w1": jnp.asarray(rng.standard_normal((F_HID, F_OUT)) * 0.1,
                                jnp.float32)}

    def loss_fn(p, agg):
        h = jax.nn.relu(agg(x @ p["w0"]))
        logits = agg(h @ p["w1"])
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return jnp.mean(logz - gold), acc

    def agg(h):  # the paper's operator — custom VJP on the Pallas backends
        return loops_spmm(fmt, h, backend=backend)

    if not args.skip_grad_check:
        # One-off parity: jax.grad through the LOOPS custom VJP must match
        # the dense-adjacency reference (paper: "no accuracy loss").  The
        # densified adjacency exists only here — the training step below
        # never touches it.
        dense_adj = jnp.asarray(csr_to_dense(adj))
        g_loops = jax.grad(lambda p: loss_fn(p, agg)[0])(params)
        g_dense = jax.grad(
            lambda p: loss_fn(p, lambda h: dense_adj @ h)[0])(params)
        err = max(float(jnp.abs(a - b).max())
                  for a, b in zip(jax.tree.leaves(g_loops),
                                  jax.tree.leaves(g_dense)))
        assert err <= 1e-4, f"custom-VJP grads off by {err:.2e} (> 1e-4)"
        print(f"grad check: max |loops - dense| = {err:.2e}  (<= 1e-4) OK")

    @jax.jit
    def step(p):
        (loss, acc), g = jax.value_and_grad(
            lambda p_: loss_fn(p_, agg), has_aux=True)(p)
        p = jax.tree.map(lambda w, gw: w - args.lr * gw, p, g)
        return p, loss, acc

    t0 = time.time()
    first = None
    for s in range(args.steps):
        params, loss, acc = step(params)
        if first is None:
            first = float(loss)
        if s % max(args.steps // 10, 1) == 0 or s == args.steps - 1:
            print(f"step {s:4d} loss {float(loss):.4f} acc {float(acc):.3f}")
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s "
          f"({dt / args.steps * 1e3:.1f} ms/step); "
          f"prep amortised over {t_prep / (dt / args.steps):.0f} steps "
          f"(paper: 1.3% of e2e)")
    if args.steps >= 40:
        assert float(loss) < first * 0.7, "GCN failed to learn"
        print("OK: loss decreased", first, "->", float(loss))


if __name__ == "__main__":
    main()
