"""Quickstart: the LOOPS hybrid SpMM pipeline in ~40 lines.

  stats -> perf-model calibration -> boundary (Eq. 1) -> Algorithm 1
  conversion -> hybrid execution (CSR on the vector path, BCSR on the
  matrix path).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (csr_to_dense, loops_spmm, plan_and_convert,
                        row_stats, suite)
from repro.core.perf_model import calibrate


def main():
    # A skewed matrix: hub rows on top (web-graph-like), regular band below —
    # the regime the paper's hybrid format exists for.
    top = csr_to_dense(suite.powerlaw(256, 1024, 12.0, seed=0))
    bot = csr_to_dense(suite.banded(768, 1024, 5, seed=1))
    dense = np.concatenate([top, bot], axis=0).astype(np.float32)

    from repro.core import csr_from_dense
    csr = csr_from_dense(dense)
    print("matrix:", csr.shape, "nnz:", csr.nnz)
    print("row stats:", row_stats(csr))

    # Calibrate the quadratic perf model (paper Eq. 2) from warm-up probes.
    # Here the probe is synthetic; on device it times real kernel splits.
    model = calibrate(lambda x, y: 1.0 * x + 4.0 * min(y, 2)
                      + 0.3 * max(y - 2, 0), total=8)
    fmt, plan = plan_and_convert(csr, total_workers=8, model=model)
    print(f"plan: r_boundary={plan.r_boundary} "
          f"(CSR rows -> vector pipe: {plan.r_boundary}, "
          f"BCSR rows -> matrix pipe: {csr.nrows - plan.r_boundary}), "
          f"workers vpu={plan.t_vpu} mxu={plan.t_mxu}, Br={plan.br}")

    B = jnp.asarray(np.random.default_rng(2)
                    .standard_normal((1024, 32)).astype(np.float32))
    out = loops_spmm(fmt, B, backend="jnp")           # XLA reference path
    out_k = loops_spmm(fmt, B, backend="interpret")   # Pallas kernels (interpret)
    np.testing.assert_allclose(np.asarray(out), dense @ np.asarray(B),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_k),
                               rtol=1e-4, atol=1e-4)
    print("hybrid SpMM == dense ground truth == Pallas kernels: OK")
    print("C shape:", out.shape, "||C|| =", float(jnp.linalg.norm(out)))


if __name__ == "__main__":
    main()
