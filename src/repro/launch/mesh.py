"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialisation and only then builds the mesh.

Axis semantics:
  pod   — data-parallel replica groups across pods (2 pods = 512 chips)
  data  — in-pod data parallelism (batch + ZeRO-1 optimizer shards)
  model — tensor/expert parallelism (Megatron col/row splits, EP, KV shards)

``repro.dist.sharding`` builds every PartitionSpec in the system against
these axis names; this module is the single source of truth for them.
"""
from __future__ import annotations

from jax.sharding import Mesh

from ..compat import make_mesh

__all__ = ["make_production_mesh", "make_test_mesh", "dp_axes", "flat_axes"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    import math

    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = math.prod(shape)
    return make_mesh(shape, axes, devices=jax.devices()[:ndev])


def make_test_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Small CPU mesh for integration tests (requires the host-device flag)."""
    return make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axis name(s): ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def flat_axes(mesh) -> tuple:
    """All axes, for fully-flat (ZeRO) sharding."""
    return tuple(mesh.axis_names)
