"""End-to-end training driver.

Thin CLI over the device layer: ``repro.dist.step.build_train_step`` builds
the jitted grad-accumulating ZeRO-1 step, ``repro.dist.sharding`` places
params/optimizer/batches on the mesh (docs/architecture.md §4 for the spec
conventions).  This driver only owns the loop: data, checkpoints, logging.

Fault tolerance contract:
  * checkpoints are step-atomic and async (``repro.checkpoint``); the data
    "iterator" is the step counter itself (deterministic pipeline), so
    restart resumes the exact token stream;
  * ``--resume`` restores from the newest checkpoint — with ANY mesh shape
    (checkpoints are unsharded; the restoring job re-applies its own
    shardings => elastic up/down-scaling across restarts);
  * a heartbeat file is touched every step; an external supervisor (or the
    ``--max-step-seconds`` watchdog here) can kill and restart a hung run —
    combined with atomic checkpoints this is the whole crash-recovery story.

Observability: ``--obs`` captures the run with :class:`repro.obs.Obs` —
per-step latency histogram (``step.wall_us{op=train_step}`` via the step
builder), engine dispatch counters, a ``train.steps_per_s`` gauge — and
saves a versioned JSONL + Chrome trace under ``benchmarks/results/obs/``
(render with ``tools/obs_report.py``).

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 20 --seq-len 64 --global-batch 8 --mesh-data 1 --mesh-model 1
"""
from __future__ import annotations

import argparse
import contextlib
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer, latest_step, restore
from ..configs import REDUCED, get_config
from ..configs.base import ShapeConfig
from ..data import DataConfig, global_batch_at
from ..dist import sharding as shr
from ..dist import step as step_lib
from ..models import api
from ..optim import adamw
from ..optim.adamw import OptConfig
from .mesh import make_test_mesh


def build_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-scale config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-step-seconds", type=float, default=0,
                    help="watchdog: abort if one step exceeds this")
    ap.add_argument("--obs", nargs="?", const="train", default=None,
                    metavar="STEM",
                    help="capture runtime metrics/spans; writes STEM.jsonl "
                         "+ STEM.trace.json (Chrome/Perfetto) under "
                         "--obs-dir (default benchmarks/results/obs/)")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="override the obs output directory")
    return ap.parse_args()


def main():
    args = build_args()
    # Chaos harness: honour REPRO_FAULT_PLAN (docs/robustness.md).
    from ..resilience.inject import install_from_env
    install_from_env()
    obs = None
    if args.obs:
        from ..obs import Obs, set_active
        obs = Obs(source=args.obs)
        set_active(obs)
    cfg = REDUCED[args.arch]() if args.reduced else get_config(args.arch)
    mesh = make_test_mesh(args.mesh_data, args.mesh_model)
    n_devices = args.mesh_data * args.mesh_model
    shape = ShapeConfig("cli_train", args.seq_len, args.global_batch, "train")
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 1))
    data_cfg = DataConfig(seed=args.seed)

    n_mb = step_lib.default_microbatches(shape, mesh)
    params = api.init_params(cfg, jax.random.key(args.seed))
    pav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       params)
    bav = jax.eval_shape(
        lambda: global_batch_at(data_cfg, cfg, shape, n_mb, 0))
    bundle = step_lib.build_train_step(cfg, mesh, pav, bav, opt_cfg,
                                       n_microbatches=n_mb, obs=obs)

    # placement
    psh = shr.spec_to_sharding(bundle.param_spec, mesh)
    params = jax.device_put(params, psh)
    opt_state = adamw.init_opt_state(params, n_devices)
    osh = shr.spec_to_sharding(bundle.opt_spec, mesh)
    opt_state = jax.device_put(opt_state, osh)

    start_step = 0
    ckpt = Checkpointer(args.ckpt_dir)
    if args.resume and latest_step(args.ckpt_dir) is not None:
        tmpl = {"params": params, "opt": opt_state}
        start_step, tree, meta = restore(args.ckpt_dir, tmpl)
        # Validated ingestion: a checkpoint that restores NaN/Inf params
        # would train to garbage silently — fail loudly at the boundary.
        from ..resilience.validate import check_finite_tree
        check_finite_tree(tree["params"], what="restored params")
        params = jax.device_put(tree["params"], psh)
        opt_state = jax.device_put(tree["opt"], osh)
        print(f"[resume] step {start_step} from {args.ckpt_dir} "
              f"(meta={meta})")

    hb_path = os.path.join(args.ckpt_dir, "heartbeat")
    os.makedirs(args.ckpt_dir, exist_ok=True)
    batch_fn = jax.jit(lambda s: global_batch_at(data_cfg, cfg, shape, n_mb,
                                                 s))
    t_start = time.perf_counter()
    engine_ctx = obs.attach_engine() if obs else contextlib.nullcontext()
    with engine_ctx:
        for step in range(start_step, args.steps):
            t0 = time.perf_counter()
            batch = batch_fn(step)
            params, opt_state, metrics = bundle.fn(params, opt_state, batch)
            t_step = time.perf_counter() - t0
            if args.max_step_seconds and t_step > args.max_step_seconds:
                raise TimeoutError(
                    f"step {step} exceeded watchdog "
                    f"({t_step:.1f}s > {args.max_step_seconds}s)")
            with open(hb_path, "w") as f:
                f.write(str(step))
            if step % args.log_every == 0 or step == args.steps - 1:
                m = jax.device_get(metrics)
                print(f"step {step:6d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"lr {float(m['lr']):.2e} "
                      f"({time.perf_counter() - t0:.2f}s/step)", flush=True)
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(step + 1,
                                {"params": params, "opt": opt_state},
                                meta={"arch": cfg.name})
    ckpt.save_async(args.steps, {"params": params, "opt": opt_state},
                    meta={"arch": cfg.name, "final": True})
    ckpt.close()
    t_total = time.perf_counter() - t_start
    n_steps = args.steps - start_step
    print(f"trained {n_steps} steps in {t_total:.1f}s; final loss "
          f"{float(jax.device_get(metrics)['loss']):.4f}")
    if obs is not None:
        from ..obs import set_active
        obs.gauge("train.steps_per_s").set(n_steps / max(t_total, 1e-9))
        obs.counter("train.steps").inc(n_steps)
        jsonl, chrome = obs.save(args.obs_dir, stem=args.obs)
        print(f"obs: {jsonl}")
        print(f"obs: {chrome}  (load in ui.perfetto.dev)")
        print(f"obs summary: {obs.summary()}")
        set_active(None)


if __name__ == "__main__":
    main()
