"""Serving driver: continuous-batching queue over the compiled step halves.

Requests flow through :mod:`repro.serve` (PR 9, docs/serving.md): the pure
injectable-clock scheduler coalesces same-prompt-shape requests into ragged
batches padded to the engine's batch-block grid, ``ServeQueue`` executes the
resulting prefill/decode actions through the two compiled halves from
``repro.dist.step`` (``build_prefill`` / cache-donating ``build_serve_step``)
via a warm :class:`~repro.serve.queue.ExecutorPool`, and admission control
sheds overload with a counted ``serve.rejected``.

Observability (``--obs``): the run is captured by a :class:`repro.obs.Obs` —
engine dispatch counters via the kernel-registry tracer hook, per-request
``serve.prefill_us`` / ``serve.decode_token_us`` / ``serve.ttft_us`` /
``serve.request_us`` histograms, ``serve.queue_depth`` / ``serve.in_flight``
gauges, spans around every phase, and a LOOPS plan-cache warm-up for the
model's FFN weight shapes (the "warm plan-cache pool" half of continuous
batching: the tuner search is paid before traffic, then bulk-installed into
the serving pool via ``PlanCache.prewarm`` — never on the hot path).  The
capture saves a versioned JSONL plus a Perfetto-loadable Chrome trace under
``benchmarks/results/obs/``; render either with ``tools/obs_report.py``.

Resilience (PR 8, docs/robustness.md): ``REPRO_FAULT_PLAN`` is honoured,
every engine call passes the ``serve.prefill`` / ``serve.step`` fault points
and retries with backoff, and retries/degraded plans are counted.

Demonstrates the serving path end-to-end on CPU with a reduced config:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 32 --gen-len 16 --obs
"""
from __future__ import annotations

import argparse
import contextlib
import os
import time

import jax
import numpy as np

from ..configs import REDUCED, get_config
from ..resilience.inject import fault_point, install_from_env, note_degraded
# compat re-export: the cache-padding helper moved to the serve package
# (tests and notebooks import it from here)
from ..serve.queue import pad_cache  # noqa: F401
from ..serve.queue import ServeQueue
from ..serve.scheduler import POLICIES, SchedulerConfig
from .mesh import make_test_mesh


def warm_spmm_plan_cache(cfg, params, obs, *, sparsity: float = 0.9,
                         n_cols: int = 8, on_miss: str = "search",
                         pool=None):
    """Warm the LOOPS plan pool for this model's FFN weight shapes.

    The "warm plan-cache pool" prerequisite of continuous batching
    (ROADMAP item 1): magnitude-prune each layer's FFN weight, tune-or-
    fetch its execution plan through the persistent cache, and run one
    engine SpMM per layer to validate the plan.  Same-shaped layers
    fingerprint alike, so layer 0 pays the (budgeted) search and every
    later layer is a cache hit — the hit rate lands in the obs capture's
    ``tune.cache.*`` gauges, and each validation SpMM lands in the
    ``engine.dispatch`` counters.  Families without a stacked dense FFN
    (MoE/SSM variants) warm a synthetic ``(4*d_model, d_model)`` matrix of
    the same sparsity instead.

    The tuned records are then bulk-installed into the serving ``pool``
    (default: a ``serve-pool`` cache beside the tuning store) in ONE atomic
    write via :meth:`repro.tune.PlanCache.prewarm` — ``stats.prewarmed``
    counts exactly the newly installed keys, so a re-warmed pool counts
    zero and no request ever pays a tuner search on the hot path.

    Resilience (docs/robustness.md): the weight passes an
    ``ingest.serve.weights`` fault point and the pruned CSR is validated
    with ``repair="drop"`` — corrupt values are repaired (and counted)
    rather than fed to Algorithm 1.  ``on_miss="model"`` switches the
    cache-miss policy to degraded mode: serve the Eq. 2 model-prior plan
    immediately (no measurement sweep on the request path), counting each
    such miss as ``serve.degraded{reason="plan-cache-miss"}``.

    Returns the warmed pool cache.
    """
    import jax.numpy as jnp

    from ..core.formats import csr_from_dense
    from ..core.spmm import loops_spmm
    from ..models.sparse_ffn import magnitude_prune
    from ..resilience.validate import validate_csr
    from ..tune import PlanCache, SearchBudget, autotune
    from ..tune.fingerprint import cache_key, fingerprint

    cache = PlanCache()
    cache.stats.reset()
    obs.watch_cache(cache, name="serve-warm")
    budget = SearchBudget(top_k=2, repeats=1, warmup=0)

    mlp = params.get("layers", {}).get("mlp") if isinstance(params, dict) \
        else None
    if mlp is not None and "wi" in mlp and np.asarray(mlp["wi"]).ndim == 3:
        weights = [np.asarray(w).T for w in np.asarray(mlp["wi"],
                                                       np.float32)]
    else:
        rng = np.random.default_rng(0)
        d = cfg.d_model
        weights = [rng.standard_normal((4 * d, d)).astype(np.float32)]

    keys = []
    for i, w in enumerate(weights):
        with obs.span("serve.warm_plan", cat="warm", layer=i):
            w = np.asarray(fault_point("ingest.serve.weights", w))
            csr = csr_from_dense(magnitude_prune(w, sparsity))
            csr, _ = validate_csr(csr, repair="drop")
            misses0 = cache.stats.misses
            fmt, _plan = autotune(csr, n_cols=n_cols, cache=cache,
                                  budget=budget, backend="jnp",
                                  on_miss=on_miss)
            if on_miss == "model" and cache.stats.misses > misses0:
                note_degraded("serve.degraded", reason="plan-cache-miss")
            keys.append(cache_key(fingerprint(csr), n_cols=n_cols,
                                  dtype=csr.vals.dtype, backend="jnp"))
            x = jnp.ones((csr.ncols, n_cols), jnp.float32)
            jax.block_until_ready(loops_spmm(fmt, x))
    # Hand the tuned plans to the serving pool in one bulk write.
    if pool is None:
        pool = PlanCache(os.path.join(cache.dir, "serve-pool"))
    obs.watch_cache(pool, name="serve-pool")
    records = [cache.peek(k) for k in dict.fromkeys(keys)]
    installed = pool.prewarm([r for r in records if r is not None])
    obs.gauge("serve.warm_layers").set(len(weights))
    obs.gauge("serve.prewarmed_plans").set(installed)
    return pool


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of concurrent requests to submit")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16,
                    help="tokens generated per request (prefill's first "
                         "token included)")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int,
                    default=int(os.environ.get("REPRO_TEST_SEED", "0")),
                    help="params/prompt/sampling seed (default honours "
                         "REPRO_TEST_SEED for machine-reproducible runs)")
    # continuous-batching knobs (docs/serving.md)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="requests coalesced per prefill call")
    ap.add_argument("--min-batch", type=int, default=1)
    ap.add_argument("--max-wait-ms", type=float, default=50.0,
                    help="batch-formation timeout for the oldest request")
    ap.add_argument("--max-in-flight", type=int, default=2,
                    help="groups admitted to the engine at once")
    ap.add_argument("--max-queue-depth", type=int, default=64,
                    help="admission control: submits beyond this are shed "
                         "(counted as serve.rejected)")
    ap.add_argument("--policy", choices=POLICIES, default="prefill-first",
                    help="prefill/decode interleave policy")
    ap.add_argument("--obs", nargs="?", const="serve", default=None,
                    metavar="STEM",
                    help="capture runtime metrics/spans; writes STEM.jsonl "
                         "+ STEM.trace.json (Chrome/Perfetto) under "
                         "--obs-dir (default benchmarks/results/obs/)")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="override the obs output directory")
    ap.add_argument("--no-warm-spmm-cache", action="store_true",
                    help="skip the LOOPS plan-cache warm-up under --obs")
    ap.add_argument("--plan-on-miss", choices=("search", "model"),
                    default="search",
                    help="plan-cache miss policy for the warm-up: 'search' "
                         "pays the measurement sweep (default); 'model' "
                         "serves the Eq. 2 model-prior plan immediately "
                         "(degraded mode, counted as serve.degraded)")
    ap.add_argument("--step-retries", type=int, default=2,
                    help="host-level retries per prefill/decode step")
    ap.add_argument("--retry-backoff-ms", type=float, default=10.0,
                    help="initial retry backoff (doubles per attempt)")
    ap.add_argument("--step-deadline-ms", type=float, default=None,
                    help="per-request deadline across retries; exceeding it "
                         "raises DeadlineExceeded instead of sleeping past")
    args = ap.parse_args()

    # Chaos harness: honour REPRO_FAULT_PLAN so CI can inject failures into
    # a stock serving run (docs/robustness.md).
    install_from_env()

    obs = None
    if args.obs:
        from ..obs import Obs, set_active
        obs = Obs(source=args.obs)
        set_active(obs)

    cfg = REDUCED[args.arch]() if args.reduced else get_config(args.arch)
    mesh = make_test_mesh(args.mesh_data, args.mesh_model)
    from ..models import api
    params = api.init_params(cfg, jax.random.key(args.seed))

    # Degraded-mode step execution: transient host-level failures retry
    # with exponential backoff under the optional per-request deadline;
    # every retry is a counted degradation, never a silent one.
    retry_kw = dict(
        retries=args.step_retries,
        backoff_s=args.retry_backoff_ms / 1e3,
        deadline_s=(args.step_deadline_ms / 1e3
                    if args.step_deadline_ms is not None else None),
        on_retry=lambda n, e: (
            note_degraded("serve.degraded", reason="retry"),
            note_degraded("serve.retries")),
    )

    sched_cfg = SchedulerConfig(
        max_queue_depth=args.max_queue_depth,
        max_in_flight=args.max_in_flight,
        max_batch=args.max_batch, min_batch=args.min_batch,
        max_wait_s=args.max_wait_ms / 1e3, policy=args.policy)

    engine_ctx = obs.attach_engine() if obs else contextlib.nullcontext()
    with engine_ctx:
        if obs is not None and not args.no_warm_spmm_cache:
            warm_spmm_plan_cache(cfg, params, obs,
                                 on_miss=args.plan_on_miss)

        queue = ServeQueue(cfg, mesh, params, config=sched_cfg, obs=obs,
                           temperature=args.temperature, seed=args.seed,
                           retry_kw=retry_kw)

        # Seeded prompt set: one request per row, all through the queue.
        rng = np.random.default_rng(args.seed + 1)
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.batch, args.prompt_len))
        t0 = time.perf_counter()
        reqs = [queue.submit([int(t) for t in row], args.gen_len)
                for row in prompts]
        done = queue.drain()
        t_total = time.perf_counter() - t0

    rejected = queue.sched.counters["rejected"]
    n_tokens = sum(r.tokens_generated for r in done)
    tps = n_tokens / max(t_total, 1e-9)
    print(f"served {len(done)}/{len(reqs)} requests "
          f"({args.batch}x{args.prompt_len}+{args.gen_len}) in "
          f"{t_total:.2f}s; {n_tokens} tokens at {tps:.1f} tok/s; "
          f"{queue.sched.counters['prefill_batches']} prefill batches, "
          f"{queue.sched.counters['decode_steps']} decode steps, "
          f"{rejected} rejected")
    if done:
        print("generated token ids (first request):",
              np.asarray(done[0].tokens[:16]))

    if obs is not None:
        from ..obs import set_active
        obs.gauge("serve.tokens_per_s").set(tps)
        obs.counter("serve.tokens_generated").inc(n_tokens)
        jsonl, chrome = obs.save(args.obs_dir, stem=args.obs)
        print(f"obs: {jsonl}")
        print(f"obs: {chrome}  (load in ui.perfetto.dev)")
        print(f"obs summary: {obs.summary()}")
        set_active(None)


if __name__ == "__main__":
    main()
