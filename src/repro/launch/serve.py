"""Batched serving driver: prefill a prompt batch, then decode N tokens.

Uses the two compiled halves from ``repro.dist.step``:
``build_prefill`` (batch -> sharded KV cache + last logits) and
``build_serve_step`` (one cache-donating decode step).  Between them the
cache's sequence axis is grown once to prompt+gen length — decode then runs
allocation-free.

Observability (``--obs``): the run is captured by a :class:`repro.obs.Obs`
— engine dispatch counters via the kernel-registry tracer hook, per-request
prefill latency and per-token decode latency histograms (the exact
accounting the ROADMAP's admission-control item consumes), spans around
every phase, and a LOOPS plan-cache warm-up for the model's FFN weight
shapes (the "warm plan-cache pool" half of continuous batching: the tuner
search is paid before traffic, never on the hot path, and the cache hit
rate is exported as ``tune.cache.*`` gauges).  The capture saves a
versioned JSONL plus a Perfetto-loadable Chrome trace under
``benchmarks/results/obs/``; render either with ``tools/obs_report.py``.

Demonstrates the serving path end-to-end on CPU with a reduced config:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 32 --gen-len 16 --obs
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import REDUCED, get_config
from ..dist import step as step_lib
from ..models import api, frontends
from ..resilience.fallback import retry_with_backoff
from ..resilience.inject import fault_point, install_from_env, note_degraded
from .mesh import make_test_mesh


def pad_cache(cfg, cache, max_len: int):
    """Grow the prefill cache's sequence axis to ``max_len`` (headroom for
    decode).  Window-capped and state caches are already final-size."""
    def leaf(path, x):
        names = [getattr(k, "key", str(k)) for k in path]
        if names[-1] in ("k", "v") and x.ndim == 5:
            cap = max_len
            if cfg.sliding_window:
                cap = min(max_len, cfg.sliding_window)
            if x.shape[2] < cap:
                pad = [(0, 0)] * 5
                pad[2] = (0, cap - x.shape[2])
                return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(leaf, cache)


def warm_spmm_plan_cache(cfg, params, obs, *, sparsity: float = 0.9,
                         n_cols: int = 8, on_miss: str = "search"):
    """Warm the LOOPS plan cache for this model's FFN weight shapes.

    The "warm plan-cache pool" prerequisite of continuous batching
    (ROADMAP item 1): magnitude-prune each layer's FFN weight, tune-or-
    fetch its execution plan through the persistent cache, and run one
    engine SpMM per layer to validate the plan.  Same-shaped layers
    fingerprint alike, so layer 0 pays the (budgeted) search and every
    later layer is a cache hit — the hit rate lands in the obs capture's
    ``tune.cache.*`` gauges, and each validation SpMM lands in the
    ``engine.dispatch`` counters.  Families without a stacked dense FFN
    (MoE/SSM variants) warm a synthetic ``(4*d_model, d_model)`` matrix of
    the same sparsity instead.

    Resilience (docs/robustness.md): the weight passes an
    ``ingest.serve.weights`` fault point and the pruned CSR is validated
    with ``repair="drop"`` — corrupt values are repaired (and counted)
    rather than fed to Algorithm 1.  ``on_miss="model"`` switches the
    cache-miss policy to degraded mode: serve the Eq. 2 model-prior plan
    immediately (no measurement sweep on the request path), counting each
    such miss as ``serve.degraded{reason="plan-cache-miss"}``.
    """
    from ..core.formats import csr_from_dense
    from ..core.spmm import loops_spmm
    from ..models.sparse_ffn import magnitude_prune
    from ..resilience.validate import validate_csr
    from ..tune import PlanCache, SearchBudget, autotune

    cache = PlanCache()
    cache.stats.reset()
    obs.watch_cache(cache, name="serve-warm")
    budget = SearchBudget(top_k=2, repeats=1, warmup=0)

    mlp = params.get("layers", {}).get("mlp") if isinstance(params, dict) \
        else None
    if mlp is not None and "wi" in mlp and np.asarray(mlp["wi"]).ndim == 3:
        weights = [np.asarray(w).T for w in np.asarray(mlp["wi"],
                                                       np.float32)]
    else:
        rng = np.random.default_rng(0)
        d = cfg.d_model
        weights = [rng.standard_normal((4 * d, d)).astype(np.float32)]

    for i, w in enumerate(weights):
        with obs.span("serve.warm_plan", cat="warm", layer=i):
            w = np.asarray(fault_point("ingest.serve.weights", w))
            csr = csr_from_dense(magnitude_prune(w, sparsity))
            csr, _ = validate_csr(csr, repair="drop")
            misses0 = cache.stats.misses
            fmt, _plan = autotune(csr, n_cols=n_cols, cache=cache,
                                  budget=budget, backend="jnp",
                                  on_miss=on_miss)
            if on_miss == "model" and cache.stats.misses > misses0:
                note_degraded("serve.degraded", reason="plan-cache-miss")
            x = jnp.ones((csr.ncols, n_cols), jnp.float32)
            jax.block_until_ready(loops_spmm(fmt, x))
    obs.gauge("serve.warm_layers").set(len(weights))
    return cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs", nargs="?", const="serve", default=None,
                    metavar="STEM",
                    help="capture runtime metrics/spans; writes STEM.jsonl "
                         "+ STEM.trace.json (Chrome/Perfetto) under "
                         "--obs-dir (default benchmarks/results/obs/)")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="override the obs output directory")
    ap.add_argument("--no-warm-spmm-cache", action="store_true",
                    help="skip the LOOPS plan-cache warm-up under --obs")
    ap.add_argument("--plan-on-miss", choices=("search", "model"),
                    default="search",
                    help="plan-cache miss policy for the warm-up: 'search' "
                         "pays the measurement sweep (default); 'model' "
                         "serves the Eq. 2 model-prior plan immediately "
                         "(degraded mode, counted as serve.degraded)")
    ap.add_argument("--step-retries", type=int, default=2,
                    help="host-level retries per prefill/decode step")
    ap.add_argument("--retry-backoff-ms", type=float, default=10.0,
                    help="initial retry backoff (doubles per attempt)")
    ap.add_argument("--step-deadline-ms", type=float, default=None,
                    help="per-request deadline across retries; exceeding it "
                         "raises DeadlineExceeded instead of sleeping past")
    args = ap.parse_args()

    # Chaos harness: honour REPRO_FAULT_PLAN so CI can inject failures into
    # a stock serving run (docs/robustness.md).
    install_from_env()

    obs = None
    if args.obs:
        from ..obs import Obs, set_active
        obs = Obs(source=args.obs)
        set_active(obs)

    cfg = REDUCED[args.arch]() if args.reduced else get_config(args.arch)
    mesh = make_test_mesh(args.mesh_data, args.mesh_model)
    params = api.init_params(cfg, jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen_len

    key = jax.random.key(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = frontends.vision_patches_stub(cfg, args.batch)
    if cfg.frontend == "audio_stub":
        batch["frames"] = frontends.audio_frames_stub(cfg, args.batch)

    pav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       params)
    bav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch)

    # Degraded-mode step execution: transient host-level failures retry
    # with exponential backoff under the optional per-request deadline;
    # every retry is a counted degradation, never a silent one.
    retry_kw = dict(
        retries=args.step_retries,
        backoff_s=args.retry_backoff_ms / 1e3,
        deadline_s=(args.step_deadline_ms / 1e3
                    if args.step_deadline_ms is not None else None),
        on_retry=lambda n, e: (
            note_degraded("serve.degraded", reason="retry"),
            note_degraded("serve.retries")),
    )

    engine_ctx = obs.attach_engine() if obs else contextlib.nullcontext()
    with engine_ctx:
        if obs is not None and not args.no_warm_spmm_cache:
            warm_spmm_plan_cache(cfg, params, obs,
                                 on_miss=args.plan_on_miss)

        prefill_fn, _, _ = step_lib.build_prefill(cfg, mesh, pav, bav,
                                                  obs=obs)

        def run_prefill():
            fault_point("serve.prefill")
            return prefill_fn(params, batch)

        t0 = time.perf_counter()
        cache, logits = retry_with_backoff(run_prefill, **retry_kw)
        jax.block_until_ready(logits)
        t_pf_call = time.perf_counter() - t0
        if obs is not None:
            # Every request in the coalesced batch experienced the batch
            # call's latency — one observation per request, the accounting
            # admission control will consume.
            pf_hist = obs.histogram("serve.prefill_us")
            for _ in range(args.batch):
                pf_hist.observe(t_pf_call * 1e6)
            obs.counter("serve.requests").inc(args.batch)
        extra = cfg.num_patches if cfg.frontend == "vision_stub" else 0
        cache = pad_cache(cfg, cache, max_len + extra)
        cav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                           cache)
        serve_fn, _, _ = step_lib.build_serve_step(cfg, mesh, pav, cav,
                                                   obs=obs)
        t_prefill = time.perf_counter() - t0

        def sample(lg, k):
            if args.temperature <= 0:
                return jnp.argmax(lg, axis=-1)
            return jax.random.categorical(k, lg / args.temperature, axis=-1)

        toks = sample(logits, key)[:, None].astype(jnp.int32)
        out_tokens = [toks]
        # prefill offset: vlm prefixes shift absolute positions
        pos0 = args.prompt_len + (cfg.num_patches
                                  if cfg.frontend == "vision_stub" else 0)
        tok_hist = obs.histogram("serve.decode_token_us") if obs else None
        t0 = time.perf_counter()
        def run_step(c, tk, pos):
            # the fault point fires BEFORE serve_fn, so a retried step never
            # reuses an already-donated cache buffer
            fault_point("serve.step")
            return serve_fn(params, c, tk, pos)

        for i in range(args.gen_len - 1):
            t_step = time.perf_counter()
            cache, logits = retry_with_backoff(
                run_step, cache, toks, jnp.int32(pos0 + i), **retry_kw)
            key, sub = jax.random.split(key)
            toks = sample(logits, sub)[:, None].astype(jnp.int32)
            jax.block_until_ready(toks)
            if tok_hist is not None:
                # per-token decode latency: the step's wall clock is what a
                # request waits for its next token
                tok_hist.observe((time.perf_counter() - t_step) * 1e6)
            out_tokens.append(toks)
        t_decode = time.perf_counter() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    tps = args.batch * (args.gen_len - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen_len - 1} steps at {tps:.1f} tok/s")
    print("generated token ids (first row):", gen[0][:16])

    if obs is not None:
        from ..obs import set_active
        obs.gauge("serve.tokens_per_s").set(tps)
        obs.counter("serve.tokens_generated").inc(
            args.batch * len(out_tokens))
        jsonl, chrome = obs.save(args.obs_dir, stem=args.obs)
        print(f"obs: {jsonl}")
        print(f"obs: {chrome}  (load in ui.perfetto.dev)")
        print(f"obs summary: {obs.summary()}")
        set_active(None)


if __name__ == "__main__":
    main()
