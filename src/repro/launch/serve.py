"""Batched serving driver: prefill a prompt batch, then decode N tokens.

Uses the two compiled halves from ``repro.dist.step``:
``build_prefill`` (batch -> sharded KV cache + last logits) and
``build_serve_step`` (one cache-donating decode step).  Between them the
cache's sequence axis is grown once to prompt+gen length — decode then runs
allocation-free.

Demonstrates the serving path end-to-end on CPU with a reduced config:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 32 --gen-len 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import REDUCED, get_config
from ..configs.base import ShapeConfig
from ..dist import sharding as shr
from ..dist import step as step_lib
from ..models import api, frontends
from .mesh import make_test_mesh


def pad_cache(cfg, cache, max_len: int):
    """Grow the prefill cache's sequence axis to ``max_len`` (headroom for
    decode).  Window-capped and state caches are already final-size."""
    def leaf(path, x):
        names = [getattr(k, "key", str(k)) for k in path]
        if names[-1] in ("k", "v") and x.ndim == 5:
            cap = max_len
            if cfg.sliding_window:
                cap = min(max_len, cfg.sliding_window)
            if x.shape[2] < cap:
                pad = [(0, 0)] * 5
                pad[2] = (0, cap - x.shape[2])
                return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(leaf, cache)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = REDUCED[args.arch]() if args.reduced else get_config(args.arch)
    mesh = make_test_mesh(args.mesh_data, args.mesh_model)
    params = api.init_params(cfg, jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen_len

    key = jax.random.key(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = frontends.vision_patches_stub(cfg, args.batch)
    if cfg.frontend == "audio_stub":
        batch["frames"] = frontends.audio_frames_stub(cfg, args.batch)

    pav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       params)
    bav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       batch)
    prefill_fn, _, _ = step_lib.build_prefill(cfg, mesh, pav, bav)
    t0 = time.time()
    cache, logits = prefill_fn(params, batch)
    extra = cfg.num_patches if cfg.frontend == "vision_stub" else 0
    cache = pad_cache(cfg, cache, max_len + extra)
    cav = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       cache)
    serve_fn, _, _ = step_lib.build_serve_step(cfg, mesh, pav, cav)
    t_prefill = time.time() - t0

    def sample(lg, k):
        if args.temperature <= 0:
            return jnp.argmax(lg, axis=-1)
        return jax.random.categorical(k, lg / args.temperature, axis=-1)

    toks = sample(logits, key)[:, None].astype(jnp.int32)
    out_tokens = [toks]
    # prefill offset: vlm prefixes shift absolute positions
    pos0 = args.prompt_len + (cfg.num_patches
                              if cfg.frontend == "vision_stub" else 0)
    t0 = time.time()
    for i in range(args.gen_len - 1):
        cache, logits = serve_fn(params, cache, toks,
                                 jnp.int32(pos0 + i))
        key, sub = jax.random.split(key)
        toks = sample(logits, sub)[:, None].astype(jnp.int32)
        out_tokens.append(toks)
    t_decode = time.time() - t0

    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    tps = args.batch * (args.gen_len - 1) / max(t_decode, 1e-9)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decoded {args.gen_len - 1} steps at {tps:.1f} tok/s")
    print("generated token ids (first row):", gen[0][:16])


if __name__ == "__main__":
    main()
