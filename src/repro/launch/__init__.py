"""Launchers: mesh construction (the axis vocabulary every PartitionSpec in
``repro.dist.sharding`` is written against), abstract input specs for the
dry-run, and the train / serve / dryrun CLI drivers."""
