"""ShapeDtypeStruct input stand-ins for every (arch x shape) cell.

No device allocation happens here: params, optimizer state, batches and
caches are all abstract.  The dry-run lowers against exactly these avals.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import api

__all__ = ["abstract_params", "train_batch_specs", "prefill_batch_specs",
           "decode_input_specs"]

I32 = jnp.int32
F32 = jnp.float32


def abstract_params(cfg: ModelConfig):
    """Allocation-free param avals (jax.eval_shape over the real init)."""
    return jax.eval_shape(lambda: api.init_params(cfg, jax.random.key(0)))


def _frontend_extras(cfg: ModelConfig, lead: Tuple[int, ...]):
    if cfg.frontend == "vision_stub":
        return {"patches": jax.ShapeDtypeStruct(
            (*lead, cfg.num_patches, cfg.d_model), F32)}
    if cfg.frontend == "audio_stub":
        return {"frames": jax.ShapeDtypeStruct(
            (*lead, cfg.encoder_seq, cfg.d_model), F32)}
    return {}


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      n_microbatches: int) -> Dict[str, Any]:
    """Microbatched layout: (n_mb, mb, ...)."""
    mb = shape.global_batch // n_microbatches
    lead = (n_microbatches, mb)
    batch = {"tokens": jax.ShapeDtypeStruct((*lead, shape.seq_len), I32),
             "labels": jax.ShapeDtypeStruct((*lead, shape.seq_len), I32)}
    batch.update(_frontend_extras(cfg, lead))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    batch = {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), I32)}
    batch.update(_frontend_extras(cfg, (B,)))
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(cache_avals, tokens_aval, length_aval) for one serve step against a
    cache of ``seq_len`` entries."""
    B = shape.global_batch
    cache = jax.eval_shape(
        lambda: api.init_cache(cfg, B, shape.seq_len))
    tokens = jax.ShapeDtypeStruct((B, 1), I32)
    length = jax.ShapeDtypeStruct((), I32)
    return cache, tokens, length
