import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
initialisation, and the production meshes need 512 placeholder host devices
(single-pod cells use the first 256).

The step functions come from ``repro.dist.step`` (built against abstract
avals — nothing is allocated) with in/out shardings baked from
``repro.dist.sharding``; a successful compile is therefore a proof that the
sharding config is coherent at production scale (docs/architecture.md §4).

For each cell this script:
  1. builds allocation-free avals (params / optimizer / batch / cache),
  2. lowers the pjit'd step with explicit in/out shardings,
  3. compiles — success proves the sharding config is coherent (no mismatch,
     no unsupported collective, no compile-time OOM),
  4. records memory_analysis() + cost_analysis() + the HLO-derived roofline
     inputs (trip-count-corrected flops / hbm bytes / collective bytes) to
     benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import math
import sys
import time
import traceback

import jax

from repro.configs import ALL_ARCHS, SHAPES, applicable_shapes, get_config
from repro.dist import step as step_lib
from repro.launch import specs
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw
from repro.optim.adamw import OptConfig
from repro.perf.hlo_analysis import analyze_hlo

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _mem_dict(ma) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(ma, k))
        except Exception:
            pass
    return out


def lower_cell(arch: str, shape_name: str, mesh, overrides: dict | None = None):
    """Returns (lowered, n_microbatches) for one cell.  ``overrides`` are
    dataclasses.replace fields on the ModelConfig (perf-iteration knobs)."""
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    pav = specs.abstract_params(cfg)
    n_devices = math.prod(mesh.shape.values())
    if shape.kind == "train":
        n_mb = step_lib.default_microbatches(shape, mesh)
        bav = specs.train_batch_specs(cfg, shape, n_mb)
        oav = adamw.abstract_opt_state(pav, n_devices)
        bundle = step_lib.build_train_step(cfg, mesh, pav, bav, OptConfig(),
                                           n_microbatches=n_mb)
        return bundle.fn.lower(pav, oav, bav), n_mb
    if shape.kind == "prefill":
        bav = specs.prefill_batch_specs(cfg, shape)
        fn, _, _ = step_lib.build_prefill(cfg, mesh, pav, bav)
        return fn.lower(pav, bav), 1
    # decode
    cav, tok, ln = specs.decode_input_specs(cfg, shape)
    fn, _, _ = step_lib.build_serve_step(cfg, mesh, pav, cav)
    return fn.lower(pav, cav, tok, ln), 1


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             keep_hlo: bool = False, overrides: dict | None = None,
             tag: str = "") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "status": "error",
           "overrides": overrides or {}, "tag": tag}
    try:
        with mesh:  # ambient mesh for bare-PartitionSpec constraints
            lowered, n_mb = lower_cell(arch, shape_name, mesh, overrides)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        rec["n_microbatches"] = n_mb
        try:
            rec["memory_analysis"] = _mem_dict(compiled.memory_analysis())
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis"] = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):  # jax <= 0.4.x: per-device list
                ca = ca[0]
            rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                    if isinstance(v, (int, float))
                                    and ("flops" in k or "bytes" in k
                                         or "utilization" not in k)}
        except Exception as e:
            rec["cost_analysis"] = {"error": str(e)}
        hlo_text = compiled.as_text()
        st = analyze_hlo(hlo_text)
        rec["hlo"] = {
            "flops_per_device": st.flops,
            "hbm_bytes_per_device": st.hbm_bytes,
            "collective_bytes_per_device": st.collective_bytes,
            "collective_by_kind": st.collective_by_kind,
            "unknown_trip_loops": st.unknown_trip_loops,
            "text_len": len(hlo_text),
        }
        if keep_hlo:
            suffix = f"__{tag}" if tag else ""
            rec["hlo_path"] = os.path.join(
                RESULTS_DIR,
                f"{arch}__{shape_name}__{mesh_kind}{suffix}.hlo.txt")
            with open(rec["hlo_path"], "w") as f:
                f.write(hlo_text)
        rec["lower_s"] = round(t_lower - t0, 2)
        rec["compile_s"] = round(t_compile - t_lower, 2)
        rec["status"] = "ok"
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--tag", default="",
                    help="suffix for result files (perf iterations)")
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig override key=value (perf knobs), e.g. "
                         "--set attn_schedule=triangular")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        key, val = kv.split("=", 1)
        for cast in (int, float):
            try:
                val = cast(val)
                break
            except ValueError:
                continue
        overrides[key] = val

    os.makedirs(RESULTS_DIR, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ALL_ARCHS if args.all or not args.arch else [args.arch]

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else list(applicable_shapes(cfg)))
        for shape_name in shapes:
            for mk in meshes:
                suffix = f"__{args.tag}" if args.tag else ""
                out_path = os.path.join(
                    RESULTS_DIR, f"{arch}__{shape_name}__{mk}{suffix}.json")
                if args.skip_done and os.path.exists(out_path):
                    try:
                        old = json.load(open(out_path))
                        if old.get("status") == "ok":
                            print(f"[skip] {arch} {shape_name} {mk}")
                            continue
                    except Exception:
                        pass
                rec = run_cell(arch, shape_name, mk, keep_hlo=args.keep_hlo,
                               overrides=overrides or None, tag=args.tag)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                ok = rec["status"] == "ok"
                n_ok += ok
                n_fail += (not ok)
                msg = (f"lower={rec.get('lower_s')}s "
                       f"compile={rec.get('compile_s')}s"
                       if ok else rec.get("error", ""))
                print(f"[{'ok' if ok else 'FAIL'}] {arch} {shape_name} {mk} "
                      f"{msg}", flush=True)
    print(f"done: {n_ok} ok, {n_fail} failed")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
