"""Fault-tolerant checkpointing (step-atomic, async, topology-independent).

Design points for 1000+-node runs:
  * **atomicity**: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash
    mid-write never corrupts the latest checkpoint;
  * **async**: ``save_async`` hands the (host-fetched) tree to a writer
    thread; training continues.  The queue is bounded (depth 1) so checkpoint
    backpressure surfaces instead of silently eating RAM;
  * **topology independence / elasticity**: trees are saved *unsharded*
    (device_get'd numpy) together with the step and metadata, and resharded
    on restore by whatever mesh the restarting job brings — restart on 256
    chips from a 512-chip checkpoint "just works" (the launcher re-applies
    its own shardings);
  * **retention**: keep the newest ``keep`` checkpoints, delete older.

Format: one msgpack file; arrays as (dtype, shape, raw bytes) triples keyed
by flattened tree path.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Any, Dict, Tuple

import jax
import msgpack
import numpy as np

__all__ = ["Checkpointer", "save", "restore", "latest_step"]


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(jax.device_get(leaf))
        flat[key] = (str(arr.dtype), list(arr.shape), arr.tobytes())
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    def rebuild(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        dtype, shape, raw = flat[key]
        return np.frombuffer(raw, dtype=dtype).reshape(shape)
    return jax.tree_util.tree_map_with_path(rebuild, template)


def _ckpt_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:010d}.msgpack")


def save(directory: str, step: int, tree, meta: Dict[str, Any] | None = None,
         keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    payload = {"step": step, "meta": meta or {}, "tree": _flatten(tree)}
    tmp = os.path.join(directory, f"tmp.{step}")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    final = _ckpt_path(directory, step)
    os.replace(tmp, final)  # atomic on POSIX
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    ckpts = sorted(f for f in os.listdir(directory)
                   if f.startswith("ckpt_"))
    for old in ckpts[:-keep]:
        try:
            os.remove(os.path.join(directory, old))
        except OSError:
            pass


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    ckpts = sorted(f for f in os.listdir(directory) if f.startswith("ckpt_"))
    if not ckpts:
        return None
    return int(ckpts[-1].split("_")[1].split(".")[0])


def restore(directory: str, template, step: int | None = None
            ) -> Tuple[int, Any, Dict[str, Any]]:
    """Returns (step, tree-of-numpy, meta).  The caller device_puts with its
    own shardings (this is what makes restore elastic)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    with open(_ckpt_path(directory, step), "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    tree = _unflatten_into(template, payload["tree"])
    return payload["step"], tree, payload["meta"]


class Checkpointer:
    """Bounded-queue async writer."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Exception | None = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, tree, meta = item
            try:
                save(self.directory, step, tree, meta, keep=self.keep)
            except Exception as e:  # surfaced on next save/wait
                self._err = e
            finally:
                self._q.task_done()

    def save_async(self, step: int, tree, meta=None):
        if self._err:
            raise self._err
        # device_get on the caller thread so the writer never touches jax
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree, meta or {}))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join()
