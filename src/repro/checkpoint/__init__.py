"""Step-atomic async checkpointing (topology-independent restore)."""
from .checkpoint import Checkpointer, latest_step, restore, save
