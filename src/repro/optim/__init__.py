"""Optimizers: AdamW with fully-flat ZeRO-1 state sharding."""
from .adamw import OptConfig, init_opt_state, abstract_opt_state, opt_specs, apply_updates, lr_at
