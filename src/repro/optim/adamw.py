"""AdamW with fully-flat ZeRO-1 state sharding (pure JAX).

Every parameter's optimizer triple (fp32 master copy, first and second
moments) lives in a *flat* representation: ravel -> pad -> reshape
``(n_shards, -1)`` with the leading dim sharded over **all** mesh axes.  A
34B-param model's 408 GB of fp32 Adam state becomes ~0.8 GB per chip on a
512-chip mesh — the difference between fitting and not fitting v5e HBM.

Data flow per step (the ZeRO-1 schedule, expressed as sharding constraints
that XLA lowers to reduce-scatter + all-gather):
  bf16 grads (model-sharded, data-replicated)
    -> flatten + constrain to P((all axes), None)   [reduce-scatter]
    -> Adam update on flat shards (elementwise, no comms)
    -> unflatten + constrain to the param's spec     [all-gather]

Gradient accumulation happens *in the flat fp32 layout*, so the accumulator
costs |params| * 4 / n_devices bytes and each microbatch's reduce-scatter
overlaps with the next microbatch's compute under the XLA latency-hiding
scheduler.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..launch.mesh import flat_axes

F32 = jnp.float32

__all__ = ["OptConfig", "init_opt_state", "opt_specs", "apply_updates",
           "to_flat", "from_flat", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(opt: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(F32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(opt.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - opt.warmup_steps)
                    / max(opt.total_steps - opt.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = opt.min_lr_frac + (1 - opt.min_lr_frac) * cos
    return opt.lr * warm * frac


def _flat_cols(size: int, n_shards: int) -> int:
    return math.ceil(size / n_shards)


def to_flat(x: jax.Array, n_shards: int) -> jax.Array:
    """(…shape…) -> fp32 (n_shards, cols), zero-padded."""
    cols = _flat_cols(x.size, n_shards)
    flat = jnp.ravel(x).astype(F32)
    flat = jnp.pad(flat, (0, n_shards * cols - x.size))
    return flat.reshape(n_shards, cols)


def from_flat(flat: jax.Array, shape, dtype) -> jax.Array:
    size = math.prod(shape) if shape else 1
    return flat.reshape(-1)[:size].reshape(shape).astype(dtype)


def init_opt_state(params, n_shards: int):
    """Flat ZeRO state: master fp32 + m + v per param, plus the step count."""
    def triple(x):
        master = to_flat(x, n_shards)
        return {"master": master, "m": jnp.zeros_like(master),
                "v": jnp.zeros_like(master)}
    return {"flat": jax.tree.map(triple, params),
            "count": jnp.zeros((), jnp.int32)}


def abstract_opt_state(params_avals, n_shards: int):
    """ShapeDtypeStruct version (dry-run: no allocation)."""
    def triple(x):
        cols = _flat_cols(x.size, n_shards)
        s = jax.ShapeDtypeStruct((n_shards, cols), F32)
        return {"master": s, "m": s, "v": s}
    return {"flat": jax.tree.map(triple, params_avals),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def opt_specs(params_avals, mesh: Mesh):
    """PartitionSpecs for the opt state: flat leaves over ALL mesh axes."""
    axes = flat_axes(mesh)
    flat_spec = P(axes, None)
    def triple(_):
        return {"master": flat_spec, "m": flat_spec, "v": flat_spec}
    return {"flat": jax.tree.map(triple, params_avals),
            "count": P()}


def global_norm_flat(flat_tree) -> jax.Array:
    leaves = jax.tree.leaves(flat_tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in leaves))


def apply_updates(params, opt_state, grads_flat, opt: OptConfig,
                  param_specs_tree, mesh: Mesh):
    """One AdamW step on flat shards; returns (new_params, new_opt_state,
    grad_norm).  ``grads_flat`` must already be in the flat fp32 layout."""
    count = opt_state["count"] + 1
    lr = lr_at(opt, count)
    gnorm = global_norm_flat(grads_flat)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-12))
    b1, b2 = opt.beta1, opt.beta2
    bc1 = 1 - b1 ** count.astype(F32)
    bc2 = 1 - b2 ** count.astype(F32)

    def upd(tr, g):
        g = g * scale
        m = b1 * tr["m"] + (1 - b1) * g
        v = b2 * tr["v"] + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step_ = mh / (jnp.sqrt(vh) + opt.eps) + opt.weight_decay * tr["master"]
        master = tr["master"] - lr * step_
        return {"master": master, "m": m, "v": v}

    new_flat = jax.tree.map(upd, opt_state["flat"], grads_flat,
                            is_leaf=lambda x: isinstance(x, dict)
                            and "master" in x)

    def unflatten(tr, x, spec):
        y = from_flat(tr["master"], x.shape, x.dtype)
        return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, spec))

    new_params = jax.tree.map(
        lambda tr, x, s: unflatten(tr, x, s), new_flat, params,
        param_specs_tree,
        is_leaf=lambda x: isinstance(x, dict) and "master" in x)
    return new_params, {"flat": new_flat, "count": count}, gnorm
