"""Front door of the tuner: ``autotune`` and ``tune_suite``.

Data flow (docs/architecture.md §5):

    CSR ──fingerprint──▶ cache lookup (exact key, then near-match)
          │ hit: rehydrate the plan (r_boundary stored as a row *fraction*
          │      so a near-match transfers across sizes), run Algorithm 1,
          │      skip all measurement
          ▼ miss
        search (model-pruned, wall-clock-ranked) ──▶ cache.put ──▶ execute

A repeated ``autotune`` on the same matrix is an exact hit that performs
zero measurements; a structurally similar unseen matrix is a near-hit that
reuses the neighbour's plan.  Both are counted in ``cache.stats``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from ..core.formats import CSR, LoopsFormat, loops_from_csr
from ..core.perf_model import QuadraticPerfModel
from ..core.spmm import SpmmPlan
from .cache import CACHE_VERSION, PlanCache
from .fingerprint import (Fingerprint, cache_key, effective_n_cols,
                          fingerprint)
from .search import SearchBudget, SearchResult, search

__all__ = ["autotune", "tune_suite", "Tuner", "default_cache",
           "make_record", "plan_from_record", "record_from_result"]

_DEFAULT_CACHE: Optional[PlanCache] = None


def default_cache() -> PlanCache:
    """Process-wide cache instance (``$REPRO_TUNE_CACHE`` honoured)."""
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None:
        _DEFAULT_CACHE = PlanCache()
    return _DEFAULT_CACHE


def make_record(features, *, dtype, n_cols: int, backend: str, r_frac: float,
                t_vpu: int, t_mxu: int, br: int, panel_g: int = 1,
                pipeline_depth: int = 1, macro_m: int = 1,
                gflops: float = 0.0, trials: int = 0) -> Dict:
    """The one place the cache-record schema is spelled out (the distributed
    scheduler and the search path both store through here).  ``r_frac`` (not
    the absolute boundary) is stored so a plan transfers to same-bucket
    matrices of slightly different height."""
    return {
        "version": CACHE_VERSION,
        "fingerprint": [float(f) for f in features],
        "dtype": str(np.dtype(dtype).name),
        "n_cols": int(n_cols),
        "backend": backend,
        "plan": {"r_frac": float(r_frac), "t_vpu": int(t_vpu),
                 "t_mxu": int(t_mxu), "br": int(br),
                 "panel_g": int(panel_g),
                 "pipeline_depth": int(pipeline_depth),
                 "macro_m": int(macro_m)},
        "gflops": float(gflops),
        "trials": int(trials),
    }


def record_from_result(fp: Fingerprint, res: SearchResult, *, nrows: int,
                       dtype, n_cols: int, backend: str) -> Dict:
    """Serialisable cache record for a completed search."""
    return make_record(
        fp.features(), dtype=dtype, n_cols=n_cols, backend=backend,
        r_frac=float(res.plan.r_boundary) / max(nrows, 1),
        t_vpu=res.plan.t_vpu, t_mxu=res.plan.t_mxu, br=res.plan.br,
        panel_g=res.plan.panel_g,
        pipeline_depth=getattr(res.plan, "pipeline_depth", 1),
        macro_m=getattr(res.plan, "macro_m", 1),
        gflops=res.gflops, trials=res.measured)


def plan_from_record(rec: Mapping, nrows: int) -> SpmmPlan:
    """Rehydrate a concrete plan for an ``nrows``-row matrix.

    The endpoints are preserved exactly (a pure-CSR plan must stay
    ``r_boundary == nrows`` even when ``nrows`` is not a ``br`` multiple),
    and the boundary is forced consistent with the worker split: a plan
    with no MXU workers cannot leave a BCSR region behind, nor vice versa.
    """
    p = rec["plan"]
    br = int(p["br"])
    t_vpu, t_mxu = int(p["t_vpu"]), int(p["t_mxu"])
    r_frac = float(p["r_frac"])
    r_b = int(round(r_frac * nrows))
    if r_b < nrows:                    # interior boundaries snap to tiles
        r_b = min(max(r_b // br * br, 0), nrows)
    if t_mxu == 0:                     # no matrix workers -> pure CSR
        r_b = nrows
    elif t_vpu == 0:                   # no vector workers -> pure BCSR
        r_b = 0
    return SpmmPlan(r_boundary=r_b, t_vpu=t_vpu, t_mxu=t_mxu, br=br,
                    panel_g=int(p.get("panel_g", 1)),
                    pipeline_depth=int(p.get("pipeline_depth", 1)),
                    macro_m=int(p.get("macro_m", 1)))


def autotune(csr: CSR, *, n_cols: int = 32, rhs_shape=None,
             backend: str = "jnp",
             total_workers: int = 8, cache: Optional[PlanCache] = None,
             model: Optional[QuadraticPerfModel] = None,
             budget: SearchBudget = SearchBudget(),
             near_distance: float = 0.25,
             on_miss: str = "search",
             ) -> Tuple[LoopsFormat, SpmmPlan]:
    """Tune-or-fetch an execution plan for ``csr`` against an (ncols, n_cols)
    dense operand; returns the converted format plus the resolved plan.

    ``rhs_shape`` — the full ``(..., K, N)`` shape of a (possibly batched)
    dense operand — overrides ``n_cols`` with the *effective* column count
    ``prod(batch) * N`` (:func:`repro.tune.fingerprint.effective_n_cols`)
    and makes the search measure candidates against an operand of exactly
    that shape, so batched workloads tune (and cache) the plan the batched
    engine call will actually execute.

    On a cache hit (exact or near) only the Algorithm 1 conversion runs —
    no candidate is ever measured.  On a miss, ``on_miss`` picks the policy:

      * ``"search"`` (default) — :func:`repro.tune.search.search` spends its
        budget and the measured winner is persisted;
      * ``"model"`` — degraded mode (docs/robustness.md): skip measurement
        entirely and serve the Eq. 2 model-prior plan *now*
        (:func:`repro.core.spmm.plan_and_convert`), persisting it with
        ``gflops=0.0, trials=0`` so a later search-mode call can tell the
        record was never measured.  This is what lets a latency-bound server
        answer a cold request without paying a tuning sweep.
    """
    if on_miss not in ("search", "model"):
        raise ValueError(f"on_miss must be 'search' or 'model', "
                         f"got {on_miss!r}")
    if cache is None:   # NB: not `cache or ...` — an empty PlanCache is falsy
        cache = default_cache()
    if rhs_shape is not None:
        n_cols = effective_n_cols(rhs_shape)
    fp = fingerprint(csr)
    dt = np.dtype(csr.vals.dtype)
    key = cache_key(fp, n_cols=n_cols, dtype=dt, backend=backend)
    rec = cache.lookup(key, features=fp.features(), dtype=dt.name,
                       n_cols=n_cols, backend=backend,
                       max_distance=near_distance)
    if rec is not None:
        plan = plan_from_record(rec, csr.nrows)
        if cache.peek(key) is None:
            # Near-hit: promote the borrowed plan under THIS matrix's exact
            # key (with its own fingerprint), so the next lookup is exact
            # and downstream peeks (tune_suite reporting) always resolve.
            cache.put(key, {**rec,
                            "fingerprint": [float(f) for f in fp.features()]})
        return loops_from_csr(csr, plan.r_boundary, plan.br,
                              panel_g=plan.panel_g,
                              macro_m=plan.macro_m,
                              pipeline_depth=plan.pipeline_depth), plan
    if on_miss == "model":
        from ..core.spmm import plan_and_convert
        fmt, plan = plan_and_convert(csr, total_workers=total_workers,
                                     model=model, validate=None)
        cache.put(key, make_record(
            fp.features(), dtype=dt, n_cols=n_cols, backend=backend,
            r_frac=float(plan.r_boundary) / max(csr.nrows, 1),
            t_vpu=plan.t_vpu, t_mxu=plan.t_mxu, br=plan.br,
            panel_g=plan.panel_g,
            pipeline_depth=getattr(plan, "pipeline_depth", 1),
            macro_m=getattr(plan, "macro_m", 1),
            gflops=0.0, trials=0))
        return fmt, plan
    res = search(csr, n_cols=n_cols, rhs_shape=rhs_shape,
                 total_workers=total_workers,
                 model=model, budget=budget, backend=backend)
    cache.put(key, record_from_result(fp, res, nrows=csr.nrows, dtype=dt,
                                      n_cols=n_cols, backend=backend))
    return res.fmt, res.plan


def tune_suite(matrices: Mapping[str, CSR], *, n_cols: int = 32,
               backend: str = "jnp", total_workers: int = 8,
               cache: Optional[PlanCache] = None,
               budget: SearchBudget = SearchBudget(),
               ) -> Dict[str, Tuple[SpmmPlan, float]]:
    """Batch-tune a named matrix set (e.g. ``suite.table2_like`` outputs).

    Returns ``{name: (plan, cached_gflops)}``; structurally similar matrices
    later in the iteration order ride the near-match path of earlier ones.
    """
    if cache is None:
        cache = default_cache()
    out: Dict[str, Tuple[SpmmPlan, float]] = {}
    for name, csr in matrices.items():
        _, plan = autotune(csr, n_cols=n_cols, backend=backend,
                           total_workers=total_workers, cache=cache,
                           budget=budget)
        key = cache_key(fingerprint(csr), n_cols=n_cols,
                        dtype=np.dtype(csr.vals.dtype), backend=backend)
        rec = cache.peek(key)
        gf = float(rec["gflops"]) if rec else float("nan")
        out[name] = (plan, gf)
    return out


@dataclasses.dataclass
class Tuner:
    """Bound tuning context, pluggable into ``plan_and_convert(tuner=...)``
    and ``sparse_linear_from_dense(tuner=...)`` so call sites that used a
    hand-set ``total_workers=8`` instead share one measured plan cache."""

    cache: PlanCache = dataclasses.field(default_factory=default_cache)
    n_cols: int = 32
    rhs_shape: Optional[Tuple[int, ...]] = None  # full (..., K, N) operand
    backend: str = "jnp"
    total_workers: int = 8
    budget: SearchBudget = dataclasses.field(default_factory=SearchBudget)
    model: Optional[QuadraticPerfModel] = None
    near_distance: float = 0.25

    def tune(self, csr: CSR) -> Tuple[LoopsFormat, SpmmPlan]:
        return autotune(csr, n_cols=self.n_cols, rhs_shape=self.rhs_shape,
                        backend=self.backend,
                        total_workers=self.total_workers, cache=self.cache,
                        model=self.model, budget=self.budget,
                        near_distance=self.near_distance)
