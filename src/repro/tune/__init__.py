"""repro.tune — measured autotuner with a persistent plan cache.

The paper schedules with a *model* (Eq. 2/3); production SpMM services (and
the SME kernel-generation line of related work) *measure* and *reuse*.  This
subsystem closes that gap: structural fingerprints key a versioned on-disk
plan cache, a budgeted search (model-pruned, wall-clock-ranked) fills it,
and every call site in the stack (`plan_and_convert(tuner=...)`,
`sparse_linear_from_dense(tuner=...)`, `shard_loops_auto(cache=...)`) can
amortise one measurement sweep across millions of requests.
"""
from .api import (Tuner, autotune, default_cache, make_record,
                  plan_from_record, record_from_result, tune_suite)
from .cache import CACHE_VERSION, CacheStats, PlanCache
from .fingerprint import (Fingerprint, cache_key, effective_n_cols,
                          feature_distance, fingerprint, loops_fingerprint)
from .search import (SearchBudget, SearchResult, enumerate_plans,
                     measure_plan_gflops, prior_model, search)

__all__ = [
    "Tuner", "autotune", "default_cache", "tune_suite", "make_record",
    "plan_from_record", "record_from_result", "CACHE_VERSION", "CacheStats",
    "PlanCache",
    "Fingerprint", "cache_key", "effective_n_cols", "feature_distance",
    "fingerprint",
    "loops_fingerprint", "SearchBudget", "SearchResult", "enumerate_plans",
    "measure_plan_gflops", "prior_model", "search",
]
