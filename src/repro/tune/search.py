"""Budgeted empirical plan search, warm-started by the quadratic model.

The plan space is the cross product the paper's pipeline exposes:

  * ``r_boundary`` — candidates from the Eq. 1 solution under each worker
    split, the regularity heuristic, the pure-CSR / pure-BCSR extremes and a
    fraction sweep (the Algorithm 1 conversion is re-run per candidate, as a
    per-shape search would on hardware);
  * ``Br ∈ {2, 4, 8}`` — tile heights (cntd/cntf/cnth analogues);
  * ``G ∈ {1, 4, 8}`` — panel widths (Figure-2 multi-tile fmopa rounds per
    ZA-tile visit; the kernels' grid shrinks ~G-fold, padding permitting);
  * ``(t_vpu, t_mxu)`` — worker splits with ``t_vpu + t_mxu = T``.

Exhaustively *measuring* that space is what the paper avoids — its quadratic
model (Eq. 2) is the low-cost scheduler.  The tuner keeps the model in that
role but adds the step related work ("Hello SME!", "Demystifying ARM SME")
shows matters: the model only *prunes* to the top-k candidates, and
wall-clock measurement (``benchmarks/_util.time_fn``-style median timing)
picks the winner among them.  Model wrong by a constant factor?  Harmless —
it only has to rank.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.formats import CSR, LoopsFormat, loops_from_csr
from ..core.partition import choose_r_boundary, regularity_boundary
from ..core.perf_model import QuadraticPerfModel, fit_perf_model
from ..core.spmm import SpmmPlan, loops_spmm
from ..resilience.fallback import classify
from ..resilience.inject import fault_point, note_degraded

__all__ = ["SearchBudget", "SearchResult", "enumerate_plans", "search",
           "prior_model", "measure_plan_gflops"]


@dataclasses.dataclass(frozen=True)
class SearchBudget:
    """Caps on how much the empirical stage may spend."""

    top_k: int = 4        # candidates that survive the model pruning
    repeats: int = 3      # timed repetitions per candidate (median)
    warmup: int = 1       # untimed warm-up calls (trigger jit)
    max_trials: int = 12  # hard cap on measured conversions
    trial_timeout_s: Optional[float] = None  # wall-clock cap per trial —
    # an overrunning candidate is treated as a failed trial (skipped,
    # counted), never the winner; None disables the check


@dataclasses.dataclass(frozen=True)
class SearchResult:
    plan: SpmmPlan
    fmt: LoopsFormat                      # the winning conversion, reusable
    gflops: float                         # measured throughput of the winner
    trials: Tuple[Tuple[SpmmPlan, float], ...]  # every measured (plan, gflops)

    @property
    def measured(self) -> int:
        return len(self.trials)


def prior_model(total_workers: int, *, tp_vpu: float = 1.0,
                tp_mxu: float = 4.0) -> QuadraticPerfModel:
    """Warm-start model when no calibrated one is supplied: fit Eq. 2 to the
    linear capacity surface ``tp_vpu*x + tp_mxu*y`` (the same proportional
    prior ``plan_and_convert`` uses), so pruning is deterministic."""
    pts = [(x, y) for x in range(total_workers + 1)
           for y in range(total_workers + 1 - x)]
    perfs = [tp_vpu * x + tp_mxu * y for (x, y) in pts]
    return fit_perf_model(pts, perfs)


def _worker_splits(total: int) -> List[Tuple[int, int]]:
    """All (t_vpu, t_mxu) with t_vpu + t_mxu = total, plus the pure ends."""
    splits = [(x, total - x) for x in range(total + 1)]
    return splits


def _r_candidates(csr: CSR, br: int, splits: Sequence[Tuple[int, int]],
                  *, tp_vpu: float, tp_mxu: float) -> List[int]:
    """r_boundary candidates: Eq. 1 under each split + heuristic + extremes
    + a coarse fraction sweep (Alg. 1 is re-run per surviving candidate)."""
    n = csr.nrows
    cands = {0, n}
    for (x, y) in splits:
        if x + y:
            cands.add(choose_r_boundary(n, tp_vpu, tp_mxu, x, y, br=br))
    cands.add(regularity_boundary(csr, br=br))
    for frac in (0.125, 0.25, 0.5, 0.75):
        cands.add(min(max(int(frac * n) // br * br, 0), n))
    return sorted(cands)


def enumerate_plans(csr: CSR, *, total_workers: int = 8,
                    br_choices: Sequence[int] = (2, 4, 8),
                    g_choices: Sequence[int] = (1, 4, 8),
                    depth_choices: Sequence[int] = (1, 2),
                    macro_choices: Sequence[int] = (1, 4),
                    tp_vpu: float = 1.0, tp_mxu: float = 4.0
                    ) -> List[SpmmPlan]:
    """The full (deduplicated) candidate plan space, including the pipeline
    axes: ``pipeline_depth`` (double-buffered B-panel prefetch) and
    ``macro_m`` (same-row macro-step fusion, panelizing at the effective
    width ``panel_g·macro_m``)."""
    seen, plans = set(), []
    splits = [(x, y) for (x, y) in _worker_splits(total_workers) if x + y > 0]
    for br in br_choices:
        for r_b in _r_candidates(csr, br, splits, tp_vpu=tp_vpu,
                                 tp_mxu=tp_mxu):
            for (t_vpu, t_mxu) in splits:
                # A split must be executable for the regions it implies.
                if r_b > 0 and t_vpu == 0:
                    continue
                if r_b < csr.nrows and t_mxu == 0:
                    continue
                for g in g_choices:
                    for d in depth_choices:
                        for m in macro_choices:
                            key = (r_b, br, t_vpu, t_mxu, g, d, m)
                            if key in seen:
                                continue
                            seen.add(key)
                            plans.append(SpmmPlan(
                                r_boundary=r_b, t_vpu=t_vpu, t_mxu=t_mxu,
                                br=br, panel_g=g, pipeline_depth=d,
                                macro_m=m))
    return plans


def _time_fn(fn, *args, repeats: int, warmup: int) -> float:
    """Median wall seconds per call (benchmarks/_util.time_fn's shape,
    duplicated here so ``src/`` never imports the benchmarks package)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measure_plan_gflops(csr: CSR, plan: SpmmPlan, b: jax.Array, *,
                        backend: str = "jnp",
                        budget: SearchBudget = SearchBudget()
                        ) -> Tuple[LoopsFormat, float]:
    """Convert (Algorithm 1) under ``plan`` and time the hybrid execution.

    ``b`` may carry leading batch dims — the timed call is then the native
    batched engine call, and the FLOP count uses the effective column count
    ``prod(batch) * N`` the engine actually processes."""
    from .fingerprint import effective_n_cols
    fmt = loops_from_csr(csr, plan.r_boundary, plan.br,
                         panel_g=plan.panel_g,
                         macro_m=getattr(plan, "macro_m", 1),
                         pipeline_depth=getattr(plan, "pipeline_depth", 1))
    f = jax.jit(lambda bb: loops_spmm(fmt, bb, backend=backend))
    secs = _time_fn(f, b, repeats=budget.repeats, warmup=budget.warmup)
    nnz = max(fmt.nnz, 1)
    return fmt, 2.0 * nnz * effective_n_cols(b.shape) / secs / 1e9


def _step_reduction_priors(csr: CSR, g_choices: Sequence[int]
                           ) -> dict[int, float]:
    """Structural grid-step reduction per panel width: nnz over the panel
    count ``sum(max(ceil(c_row / g), 1))`` — the exact factor by which G-wide
    panels shrink the kernel grid for THIS matrix (padding included), used to
    rank the G axis before any wall-clock measurement."""
    counts = np.diff(csr.row_ptr).astype(np.int64)
    nnz = max(int(counts.sum()), 1)
    return {g: nnz / max(int(np.maximum(-(-counts // g), 1).sum()), 1)
            for g in g_choices}


def search(csr: CSR, *, n_cols: int = 32, rhs_shape=None,
           total_workers: int = 8,
           model: Optional[QuadraticPerfModel] = None,
           br_choices: Sequence[int] = (2, 4, 8),
           g_choices: Sequence[int] = (1, 4, 8),
           depth_choices: Sequence[int] = (1, 2),
           macro_choices: Sequence[int] = (1, 4),
           budget: SearchBudget = SearchBudget(), backend: str = "jnp",
           b: Optional[jax.Array] = None, seed: int = 0,
           tp_vpu: float = 1.0, tp_mxu: float = 4.0,
           measure: Optional[Callable[[CSR, SpmmPlan, jax.Array],
                                      Tuple[LoopsFormat, float]]] = None,
           trace_db=None, recorder=None
           ) -> SearchResult:
    """Model-pruned, measurement-ranked plan search.

    ``rhs_shape`` — a full ``(..., K, N)`` operand shape — makes the
    measurement operand batched, so candidates are timed on the exact
    batched engine call the workload will issue (``n_cols`` is then ignored
    in favour of the effective column count).  ``measure(csr, plan, b) ->
    (fmt, gflops)`` may be injected for deterministic tests; the default is
    wall-clock :func:`measure_plan_gflops` with ``backend``.

    ``trace_db`` — a :class:`repro.perf.replay.TraceDB` of measured cells —
    upgrades the pruning stage: candidates are ranked by their *replayed*
    step time (structural grid steps × fitted per-step cost, no conversion
    paid) instead of the capacity prior; the measurement stage is unchanged.
    ``recorder`` — a :class:`repro.perf.trace.TraceRecorder` — captures
    every measured trial as a ``search_trial`` record, feeding the next
    fit/replay round.
    """
    if rhs_shape is not None and tuple(rhs_shape)[-2] != csr.ncols:
        raise ValueError(f"rhs_shape K={tuple(rhs_shape)[-2]} does not "
                         f"match csr.ncols={csr.ncols}")
    if b is not None and rhs_shape is not None \
            and tuple(b.shape) != tuple(rhs_shape):
        raise ValueError(f"explicit b has shape {tuple(b.shape)} but "
                         f"rhs_shape={tuple(rhs_shape)}; pass one or make "
                         "them agree — candidates are measured on b")
    if b is None:
        rng = np.random.default_rng(seed)
        dt = csr.vals.dtype if np.issubdtype(csr.vals.dtype, np.floating) \
            else np.float32
        shape = tuple(rhs_shape) if rhs_shape is not None \
            else (csr.ncols, n_cols)
        b = jnp.asarray(rng.standard_normal(shape).astype(dt))
    model = model or prior_model(total_workers)
    plans = enumerate_plans(csr, total_workers=total_workers,
                            br_choices=br_choices, g_choices=g_choices,
                            depth_choices=depth_choices,
                            macro_choices=macro_choices,
                            tp_vpu=tp_vpu, tp_mxu=tp_mxu)

    # Warm start.  The Eq. 2 model only sees the worker split, so by itself
    # it cannot rank *conversions* (all (r_boundary, br) share a split
    # score); couple it with the balanced-time term of Eq. 1 — the bottleneck
    # pipeline's finish time for THIS boundary under THIS split — so the
    # ranking prefers boundary/split pairs that are mutually consistent and
    # the top-k survivors span genuinely different conversions.  The G axis
    # is ranked by its measured panel terms when the model has them, else by
    # the structural grid-step reduction it buys on this matrix.
    n = max(csr.nrows, 1)
    # Priors are computed over *effective* widths (panel_g·macro_m) — the
    # width the conversion actually panelizes at — so the macro axis shares
    # the same structural step-reduction signal as the G axis.
    eff_widths = sorted({max(g, 1) * max(m, 1)
                         for g in g_choices for m in macro_choices}
                        | set(g_choices))
    step_prior = _step_reduction_priors(csr, eff_widths)

    if measure is None and backend == "jnp":
        # The jnp reference executes the flat arrays — wall clock on it is
        # blind to panel_g/macro_m/pipeline_depth, so "measuring" those axes
        # would let timing noise pick the cached knobs.  Pin (G, macro_m) to
        # the structural winner (max grid-step reduction at the effective
        # width; ties prefer the narrower effective panel, whose padding DMA
        # is smaller, and within a width the macro-fused shape, which costs
        # fewer grid dispatches), pin depth to 1 (ramp steps only ever add
        # work the jnp path cannot observe), and spend the whole measurement
        # budget on genuinely different (r_boundary, br) conversions.
        g_star, m_star = max(
            ((g, m) for g in g_choices for m in macro_choices),
            key=lambda gm: (step_prior.get(gm[0] * gm[1], 0.0),
                            -(gm[0] * gm[1]), gm[1]))
        plans = [p for p in plans if p.panel_g == g_star
                 and p.macro_m == m_star and p.pipeline_depth == 1]

    def _prior(p: SpmmPlan) -> float:
        t_v = p.r_boundary / (tp_vpu * p.t_vpu) if p.r_boundary else 0.0
        t_m = (n - p.r_boundary) / (tp_mxu * p.t_mxu) \
            if p.r_boundary < n else 0.0
        bottleneck = max(t_v, t_m, 1e-12)
        if model.has_panel_terms:
            capacity = float(model.predict(p.t_vpu, p.t_mxu, p.panel_g))
            g_scale = 1.0
        else:
            capacity = float(model.predict(p.t_vpu, p.t_mxu))
            g_scale = step_prior.get(p.panel_g * p.macro_m, 1.0)
        return max(capacity, 1e-12) * g_scale * n / bottleneck

    # Replay-based pruning: when a trace database can support a per-step
    # cost fit, rank candidates by predicted wall time of THIS matrix under
    # each plan (lower is better) — a measured signal that already folds in
    # boundary, tile height and panel width — instead of the capacity prior.
    replay_rank = None
    if trace_db is not None:
        from ..perf.replay import predict_part_steps
        from .fingerprint import effective_n_cols
        coef = trace_db.step_cost(backend)
        if coef is not None:
            eff_cols = effective_n_cols(rhs_shape) if rhs_shape is not None \
                else n_cols
            def replay_rank(p: SpmmPlan) -> float:  # noqa: E731-style rebind
                s_csr, s_bcsr = predict_part_steps(csr, p, eff_cols)
                return trace_db.predict_us(
                    coef, s_csr, s_bcsr, p.panel_g * p.macro_m,
                    depth=p.pipeline_depth)

    scored = sorted(plans, key=(replay_rank if replay_rank is not None
                                else lambda p: -_prior(p)))
    survivors: List[SpmmPlan] = []
    seen_conv = set()
    seen_base = set()
    k = min(budget.top_k, budget.max_trials)
    # Two-pass slot allocation: a small budget must still span genuinely
    # different (r_boundary, br) conversions — the panel/pipeline axes
    # multiply the space and would otherwise fill every slot with shape
    # variants of the single best boundary.  Each boundary/tile pair is
    # represented by its best-ranked (G, macro_m, depth) shape; leftover
    # slots then explore the remaining variants in rank order.
    for p in scored:
        base = (p.r_boundary, p.br)
        if base in seen_base:
            continue
        seen_base.add(base)
        seen_conv.add((p.r_boundary, p.br, p.panel_g, p.macro_m,
                       p.pipeline_depth))
        survivors.append(p)
        if len(survivors) >= k:
            break
    if len(survivors) < k:
        for p in scored:
            conv = (p.r_boundary, p.br, p.panel_g, p.macro_m,
                    p.pipeline_depth)
            if conv in seen_conv:
                continue
            seen_conv.add(conv)
            survivors.append(p)
            if len(survivors) >= k:
                break

    meas = measure or (lambda c, p, bb: measure_plan_gflops(
        c, p, bb, backend=backend, budget=budget))
    trials: List[Tuple[SpmmPlan, float]] = []
    best_plan, best_fmt, best_g = None, None, -1.0
    for p in survivors:
        # Trial isolation (docs/robustness.md): one candidate crashing —
        # or, under ``trial_timeout_s``, grossly overrunning — must not
        # abort the whole search.  The failed trial is counted and skipped;
        # the surviving measurements still rank.  ``tune.trial`` is the
        # chaos injection site.
        t0 = time.perf_counter()
        try:
            fault_point("tune.trial")
            fmt, g = meas(csr, p, b)
        except Exception as e:   # noqa: BLE001 - skipping IS the handler
            note_degraded("tune.search.trial_failed", reason=classify(e))
            continue
        if budget.trial_timeout_s is not None \
                and time.perf_counter() - t0 > budget.trial_timeout_s:
            note_degraded("tune.search.trial_failed", reason="timeout")
            continue
        trials.append((p, g))
        if recorder is not None:
            from .fingerprint import effective_n_cols as _eff
            eff = _eff(b.shape)
            nnz = max(int(np.count_nonzero(csr.vals)), 1)
            wall_s = 2.0 * nnz * eff / (g * 1e9) if g > 0 else 0.0
            recorder.record_spmm(csr, p, wall_s=wall_s, n_cols=eff,
                                 backend=backend, kind="search_trial",
                                 gflops=g)
        if g > best_g:
            best_plan, best_fmt, best_g = p, fmt, g
    if best_plan is None:
        # Every trial failed: degrade to the model-ranked front-runner (the
        # Eq. 2 prior / replay ranking) rather than raising — the same plan
        # the paper's low-cost scheduler would have picked with no
        # measurement at all.  gflops=0.0 marks the record as unmeasured.
        note_degraded("tune.search.degraded", reason="all-trials-failed")
        best_plan = survivors[0] if survivors else scored[0]
        best_fmt = loops_from_csr(csr, best_plan.r_boundary, best_plan.br,
                                  panel_g=best_plan.panel_g,
                                  macro_m=best_plan.macro_m,
                                  pipeline_depth=best_plan.pipeline_depth)
        best_g = 0.0
    return SearchResult(plan=best_plan, fmt=best_fmt, gflops=best_g,
                        trials=tuple(trials))
