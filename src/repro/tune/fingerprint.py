"""Structural fingerprints of sparse matrices — the plan-cache key.

A plan tuned for one matrix transfers to another exactly when the two look
alike *structurally*: same scale (rows/cols/nnz, log-bucketed), same per-row
nonzero distribution (the Table 2 feature columns the paper keys its analysis
on: mean/std/max nnz per row), same block density (what makes the BCSR-part
efficient, §4.4) and same bandwidth (banded vs scattered).  Values are
irrelevant — two magnitude-pruned FFN layers with the same mask statistics
share a plan.

The fingerprint therefore lives in log/ratio space so it is scale-comparable:
``features()`` returns a vector whose Euclidean distance is meaningful across
matrices of different absolute sizes, and ``cache_key`` quantises that vector
(so measurement noise in construction order can never split a bucket) and
hashes it together with the execution context (dtype, ``n_cols`` of the dense
operand, backend) that changes which plan wins.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Tuple

import numpy as np

from ..core.formats import CSR, LoopsFormat

__all__ = ["Fingerprint", "fingerprint", "loops_fingerprint", "cache_key",
           "cache_key_from_features", "feature_distance",
           "effective_n_cols"]

# Block height used for the block-density feature.  Fixed (not the plan's Br)
# so fingerprints are comparable before any plan exists.
_FP_BR = 8


@dataclasses.dataclass(frozen=True)
class Fingerprint:
    """Structural summary of one sparse matrix (values excluded)."""

    log_nrows: float       # log2(nrows)
    log_ncols: float       # log2(ncols)
    log_nnz: float         # log2(nnz + 1)
    log_row_mean: float    # log2(mean nnz/row + 1)   (Table 2 'mean')
    row_cv: float          # std/mean nnz per row     (Table 2 'std', scaled)
    log_row_max: float     # log2(max nnz/row + 1)    (Table 2 'max')
    block_density: float   # nnz / (nonempty 8x1 tiles * 8)   (paper §4.4)
    bandwidth: float       # mean |col - row*ncols/nrows| / ncols

    def features(self) -> np.ndarray:
        """Vector for distance computation (order is part of the cache
        format; bump ``cache.CACHE_VERSION`` if it changes)."""
        return np.array([
            self.log_nrows, self.log_ncols, self.log_nnz, self.log_row_mean,
            self.row_cv, self.log_row_max, self.block_density * 4.0,
            self.bandwidth * 4.0,
        ], np.float64)

    def quantised(self) -> Tuple[float, ...]:
        """Bucketed features for the exact-match key: 0.5-wide bins in log
        space (a matrix and its ~1.4x-scaled sibling share a bucket)."""
        return tuple(round(float(f) * 2.0) / 2.0 for f in self.features())


def fingerprint(csr: CSR) -> Fingerprint:
    """Fingerprint a CSR matrix in O(nnz)."""
    counts = np.diff(csr.row_ptr).astype(np.float64)
    nrows, ncols = csr.shape
    nnz = max(csr.nnz, 1)
    mean = float(counts.mean()) if counts.size else 0.0
    std = float(counts.std()) if counts.size else 0.0
    rmax = float(counts.max(initial=0.0))
    # Block density over fixed 8x1 tiles: how full would the BCSR-part be?
    lin = (csr.row_ids.astype(np.int64) // _FP_BR) * ncols \
        + csr.col_idx.astype(np.int64)
    ntiles = max(len(np.unique(lin)), 1)
    bdens = min(nnz / (ntiles * _FP_BR), 1.0)
    # Bandwidth: normalised mean distance from the (scaled) diagonal.
    diag = csr.row_ids.astype(np.float64) * (ncols / max(nrows, 1))
    bw = float(np.abs(csr.col_idx - diag).mean() / max(ncols, 1)) \
        if csr.nnz else 0.0
    return Fingerprint(
        log_nrows=math.log2(max(nrows, 1)),
        log_ncols=math.log2(max(ncols, 1)),
        log_nnz=math.log2(nnz + 1),
        log_row_mean=math.log2(mean + 1),
        row_cv=min(std / max(mean, 1e-9), 8.0) if mean > 0 else 0.0,
        log_row_max=math.log2(rmax + 1),
        block_density=bdens,
        bandwidth=bw)


def loops_fingerprint(fmt: LoopsFormat) -> Fingerprint:
    """Fingerprint an already-converted :class:`LoopsFormat` (used by the
    distributed scheduler, which receives the format, not the CSR).

    Reconstructs per-row counts from the two parts; tile padding rows are
    structural zeros and do not perturb the statistics materially.
    """
    csr, bcsr = fmt.csr_part, fmt.bcsr_part
    counts_csr = np.diff(csr.row_ptr).astype(np.float64)
    # Per-row counts of the BCSR region from the tile values' nonzero mask.
    nz = np.count_nonzero(bcsr.tile_vals, axis=1) if bcsr.ntiles else \
        np.zeros(0, np.int64)
    per_block = np.bincount(bcsr.tile_rows,
                            weights=np.asarray(nz, np.float64),
                            minlength=bcsr.nblocks) if bcsr.ntiles else \
        np.zeros(bcsr.nblocks)
    counts_b = np.repeat(per_block / max(bcsr.br, 1), bcsr.br)[:bcsr.nrows]
    counts = np.concatenate([counts_csr, counts_b]) if len(counts_b) else \
        counts_csr
    nrows, ncols = fmt.shape
    nnz = max(fmt.nnz, 1)
    mean = float(counts.mean()) if counts.size else 0.0
    std = float(counts.std()) if counts.size else 0.0
    rmax = float(counts.max(initial=0.0))
    ntiles = max(bcsr.ntiles + csr.nnz, 1)
    return Fingerprint(
        log_nrows=math.log2(max(nrows, 1)),
        log_ncols=math.log2(max(ncols, 1)),
        log_nnz=math.log2(nnz + 1),
        log_row_mean=math.log2(mean + 1),
        row_cv=min(std / max(mean, 1e-9), 8.0) if mean > 0 else 0.0,
        log_row_max=math.log2(rmax + 1),
        block_density=min(nnz / (ntiles * _FP_BR), 1.0),
        bandwidth=0.0)


def effective_n_cols(shape) -> int:
    """Column count the execution engine actually feeds the matrix pipeline
    for a dense operand of shape ``(..., K, N)``: ``prod(batch) * N``.

    The batched kernels reuse A's panel layout across every batch slice, so
    a ``(4, K, 128)`` operand exercises the grid like a ``(K, 512)`` one —
    plans (and therefore cache keys, which hash ``n_cols``) must be keyed on
    this effective count, not the trailing dim alone."""
    shape = tuple(int(s) for s in shape)
    if len(shape) < 2:
        raise ValueError(f"dense operand shape must be (..., K, N); got "
                         f"{shape}")
    cols = shape[-1]
    for d in shape[:-2]:
        cols *= d
    return cols


def feature_distance(a: np.ndarray, b: np.ndarray) -> float:
    """RMS distance between two feature vectors — the near-match metric."""
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    if a.shape != b.shape:
        return float("inf")
    return float(np.sqrt(np.mean((a - b) ** 2)))


def cache_key_from_features(features, *, n_cols: int, dtype,
                            backend: str) -> str:
    """Key from a raw feature vector (what cache records store) — the bulk
    ``PlanCache.prewarm`` path rebuilds keys through here, so a record
    round-tripped through the cache rehashes to the key ``cache_key`` would
    have minted for its source matrix."""
    quant = tuple(round(float(f) * 2.0) / 2.0 for f in features)
    payload = ",".join(f"{q:.1f}" for q in quant)
    ctx = f"{np.dtype(dtype).name}|n{int(n_cols)}|{backend}"
    digest = hashlib.sha1(f"{payload}|{ctx}".encode()).hexdigest()[:16]
    return f"v-{digest}"


def cache_key(fp: Fingerprint, *, n_cols: int, dtype, backend: str) -> str:
    """Stable cache key: quantised structure + execution context."""
    return cache_key_from_features(fp.features(), n_cols=n_cols,
                                   dtype=dtype, backend=backend)
