"""Persistent, versioned plan cache with an in-memory LRU front.

Layout: one JSON file (``plans.json``) under the cache directory —
``$REPRO_TUNE_CACHE`` if set, else ``~/.cache/repro-tune``.  The file carries
a ``version`` stamp; a mismatch (older tuner, changed fingerprint layout)
discards the stored entries rather than mis-applying them.  Writes are
atomic (tmp + rename) so a crashed tuning run can never corrupt the cache.

Lookup is two-tier:

1. **exact** — the quantised-fingerprint key (``fingerprint.cache_key``);
2. **near** — scan entries with the same execution context (dtype, n_cols,
   backend) and accept the closest fingerprint within ``max_distance``
   (RMS over the log/ratio feature vector).  This is what lets an unseen
   matrix reuse the plan of a structurally similar one (same Table-2-style
   statistics) without paying for a measurement sweep.

``stats`` counts hits / near-hits / misses — the amortisation story a
production SpMM service lives on (a repeated ``autotune`` call must be a
pure cache hit; tests assert this).

Resilience (docs/robustness.md):

  * **lock-free read-retry** — writes are atomic (tmp + rename), but a
    reader racing a writer on filesystems without atomic rename visibility
    can observe a partial file; a parse failure re-reads up to
    :data:`READ_RETRIES` times (a racing write completes in well under the
    backoff) before concluding the file is actually corrupt;
  * **quarantine-on-corrupt** — a file that still fails to parse is moved
    aside to ``plans.json.quarantined`` (``stats.quarantined`` counts it,
    ``tune.cache.quarantined`` lands on the active obs capture) and the
    cache rebuilds from empty instead of raising on every lookup;
  * **merge-on-save** — ``put`` folds fresh on-disk entries from concurrent
    writers into the blob before writing, so two processes tuning disjoint
    matrices both keep their work (last writer wins per key).
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from collections import OrderedDict
from typing import Any, Dict, Optional

from ..resilience.inject import fault_point, note_degraded
from .fingerprint import feature_distance

__all__ = ["PlanCache", "CacheStats", "CACHE_VERSION", "default_cache_dir"]

# Bump when the record schema or the fingerprint feature layout changes.
# v2: plans carry ``panel_g`` (G-wide kernel panels) — v1 records predate the
# panelized kernels and must never be replayed as-if G=1 were still the only
# execution shape.
# v3: ``n_cols`` is the *effective* column count ``prod(batch) * N`` of the
# (possibly batched) dense operand (``fingerprint.effective_n_cols``) — the
# batched execution engine amortises A's panels across batch slices, so a
# v2 record keyed on the trailing dim alone would transfer a plan tuned for
# an 8x narrower workload.
# v4: plans carry ``pipeline_depth`` (double-buffered B-panel prefetch) and
# ``macro_m`` (same-row macro-step fusion) — knob-less v3 plans were tuned
# against a strictly serial, unfused search space and must never replay as
# if depth-1/macro-1 were still the only execution shape.
CACHE_VERSION = 4

# Lock-free read-retry: parse attempts before a persistently unparseable
# file is quarantined, and the wait between them (a racing atomic write
# completes in far less).  ``_retry_sleep`` is an indirection point so tests
# can interleave a writer with the retries.
READ_RETRIES = 3
READ_RETRY_DELAY_S = 0.01
_retry_sleep = time.sleep


def default_cache_dir() -> str:
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-tune")


@dataclasses.dataclass
class CacheStats:
    """Lookup accounting with a counted-exactly-once contract.

    Every *logical* lookup — one :meth:`PlanCache.get` or
    :meth:`PlanCache.lookup` call — lands in exactly one bucket, however it
    resolves internally: an exact-key probe that falls through to the
    near-match scan and then misses is ONE miss, never an exact-miss plus a
    near-miss (``tests/test_tune.py`` pins this).  ``peek``/``nearest`` are
    the side-effect-free internals and never count.
    """

    hits: int = 0        # exact fingerprint-key hits
    near_hits: int = 0   # near-match (fingerprint-distance) hits
    misses: int = 0
    quarantined: int = 0  # corrupt files moved to *.quarantined (not a
    # lookup bucket: quarantine happens during _load, the lookup that
    # triggered it still counts its own miss)
    prewarmed: int = 0   # records bulk-installed by :meth:`PlanCache.prewarm`
    # (not a lookup bucket either: counted exactly once per NEWLY installed
    # key — re-prewarming an already-present record counts nothing)

    @property
    def lookups(self) -> int:
        return self.hits + self.near_hits + self.misses

    @property
    def hit_rate(self) -> float:
        n = self.lookups
        return (self.hits + self.near_hits) / n if n else 0.0

    def reset(self) -> None:
        """Zero all buckets (start of a measurement window — e.g. an obs
        capture that wants per-run rather than per-process rates)."""
        self.hits = self.near_hits = self.misses = self.quarantined = 0
        self.prewarmed = 0

    def __str__(self) -> str:
        q = f" quarantined={self.quarantined}" if self.quarantined else ""
        w = f" prewarmed={self.prewarmed}" if self.prewarmed else ""
        return (f"hits={self.hits} near={self.near_hits} "
                f"misses={self.misses} rate={self.hit_rate:.2f}{q}{w}")


class PlanCache:
    """Fingerprint-keyed plan store: disk JSON + in-memory LRU front."""

    def __init__(self, path: Optional[str] = None, *, lru_size: int = 128):
        self.dir = path or default_cache_dir()
        self.file = os.path.join(self.dir, "plans.json")
        self.lru_size = lru_size
        self._lru: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self._entries: Optional[Dict[str, Dict[str, Any]]] = None
        self.stats = CacheStats()

    # -- disk ---------------------------------------------------------------

    def _read_blob(self) -> Dict[str, Any]:
        """One raw read+parse of the cache file (``cache.read`` is the chaos
        injection site — a ``corrupt-bytes`` clause mangles the payload the
        parser sees, never the file itself)."""
        with open(self.file, "rb") as f:
            data = f.read()
        data = fault_point("cache.read", data)
        return json.loads(data.decode("utf-8"))

    def _quarantine(self) -> None:
        """Move the corrupt file aside (``plans.json.quarantined``) so the
        cache rebuilds instead of re-raising on every lookup; the event is
        counted in ``stats`` and on the active obs capture."""
        try:
            os.replace(self.file, self.file + ".quarantined")
        except OSError:
            return   # raced away / unwritable dir: rebuilding in memory only
        self.stats.quarantined += 1
        note_degraded("tune.cache.quarantined", path=self.file)

    def _load(self) -> Dict[str, Dict[str, Any]]:
        """All on-disk entries; {} on absence, corruption or version skew.

        A parse failure is retried (lock-free read-retry: a reader racing
        an atomic writer may glimpse a partial file on non-atomic-visibility
        filesystems); a file that *keeps* failing is genuinely corrupt and
        is quarantined rather than raised on.
        """
        if self._entries is not None:
            return self._entries
        blob = None
        for retry in range(READ_RETRIES + 1):
            try:
                blob = self._read_blob()
                break
            except OSError:
                self._entries = {}   # absent (or vanished mid-race)
                return self._entries
            except ValueError:
                if retry < READ_RETRIES:
                    _retry_sleep(READ_RETRY_DELAY_S)
        if blob is None:
            self._quarantine()
            self._entries = {}
        elif blob.get("version") == CACHE_VERSION:
            self._entries = dict(blob.get("entries", {}))
        else:
            self._entries = {}   # version mismatch: invalidate
        return self._entries

    def _save(self, *, merge: bool = True) -> None:
        os.makedirs(self.dir, exist_ok=True)
        entries = self._load()
        if merge:
            # Fold in fresh same-version entries from concurrent writers —
            # two processes tuning disjoint matrices both keep their work.
            # Best-effort raw read (no retry/quarantine: a transiently
            # unreadable file just skips the merge; our write still lands).
            try:
                with open(self.file) as f:
                    disk = json.load(f)
                if disk.get("version") == CACHE_VERSION:
                    entries = {**dict(disk.get("entries", {})), **entries}
                    self._entries = entries
            except (OSError, ValueError):
                pass
        blob = {"version": CACHE_VERSION, "entries": entries}
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
            os.replace(tmp, self.file)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # -- LRU front ----------------------------------------------------------

    def _touch(self, key: str, record: Dict[str, Any]) -> None:
        self._lru[key] = record
        self._lru.move_to_end(key)
        while len(self._lru) > self.lru_size:
            self._lru.popitem(last=False)

    # -- API ----------------------------------------------------------------

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        """Exact-key lookup (counts one hit or miss — routed through the
        same single accounting point as :meth:`lookup`)."""
        return self.lookup(key)

    def peek(self, key: str) -> Optional[Dict[str, Any]]:
        """Exact-key lookup with no stats side effects."""
        if key in self._lru:
            self._lru.move_to_end(key)
            return self._lru[key]
        rec = self._load().get(key)
        if rec is not None:
            self._touch(key, rec)
        return rec

    def put(self, key: str, record: Dict[str, Any]) -> None:
        self._load()[key] = record
        self._touch(key, record)
        self._save()

    def prewarm(self, records) -> int:
        """Bulk-install tuned records ahead of traffic (the warm-pool path:
        ``launch/serve.py`` tunes once, every serving process prewarms).

        ``records`` is either an iterable of ``make_record``-schema dicts —
        keys are rebuilt from each record's stored fingerprint + execution
        context via ``fingerprint.cache_key_from_features`` — or a mapping
        of explicit ``{key: record}``.  Only keys not already present are
        installed, in ONE atomic save (``put`` would pay a disk write per
        record), and ``stats.prewarmed`` counts exactly the newly installed
        keys: re-prewarming the same set is a no-op that counts zero and
        never touches disk.  Returns the number installed.
        """
        from .fingerprint import cache_key_from_features
        if hasattr(records, "items"):
            pairs = list(records.items())
        else:
            pairs = [(cache_key_from_features(
                rec["fingerprint"], n_cols=rec["n_cols"],
                dtype=rec["dtype"], backend=rec["backend"]), rec)
                for rec in records]
        entries = self._load()
        installed = 0
        for key, rec in pairs:
            if key in entries:
                continue
            entries[key] = rec
            self._touch(key, rec)
            installed += 1
        if installed:
            self._save()
        self.stats.prewarmed += installed
        return installed

    def nearest(self, features, *, dtype: str, n_cols: int, backend: str,
                max_distance: float) -> Optional[Dict[str, Any]]:
        """Closest same-context entry within ``max_distance`` (no stats)."""
        best, best_d = None, max_distance
        for rec in self._load().values():
            if (rec.get("dtype") != dtype or rec.get("n_cols") != n_cols
                    or rec.get("backend") != backend):
                continue
            d = feature_distance(features, rec.get("fingerprint", []))
            if d <= best_d:
                best, best_d = rec, d
        return best

    def lookup(self, key: str, *, features=None, dtype: str = "",
               n_cols: int = 0, backend: str = "",
               max_distance: float = 0.0) -> Optional[Dict[str, Any]]:
        """Exact then near lookup — the ONE accounting point.

        One call counts exactly one of {hit, near_hit, miss}, regardless of
        how many internal probes the exact→near fall-through performs.  The
        context arguments (``features``/``dtype``/``n_cols``/``backend``)
        are only consulted when ``max_distance > 0`` enables the near scan.
        """
        rec = self.peek(key)
        if rec is not None:
            self.stats.hits += 1
            return rec
        if max_distance > 0.0 and features is not None:
            rec = self.nearest(features, dtype=dtype, n_cols=n_cols,
                               backend=backend, max_distance=max_distance)
            if rec is not None:
                self.stats.near_hits += 1
                return rec
        self.stats.misses += 1
        return None

    def __len__(self) -> int:
        return len(self._load())

    def clear(self) -> None:
        self._entries = {}
        self._lru.clear()
        self._save(merge=False)   # an explicit clear must not resurrect
        # concurrent writers' entries
