"""Model zoo substrate: shared layers + family implementations + unified API."""
from . import api, encdec, frontends, layers, moe, rwkv6, sparse_ffn, ssm, transformer

__all__ = ["api", "encdec", "frontends", "layers", "moe", "rwkv6",
           "sparse_ffn", "ssm", "transformer"]
