"""Selective state-space (Mamba-style) head used by the Hymba hybrid layer.

Diagonal-A selective scan:  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t,
y_t = C_t . h_t + D x_t — with data-dependent (dt, B, C) and a short causal
conv front.  Train/prefill run the scan over time; decode is one step of the
same recurrence on an O(1) state (why hymba-1.5b runs ``long_500k``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import F32, Params, dense_init, matmul

__all__ = ["ssm_init", "ssm_forward", "ssm_decode_step"]

CONV_K = 4


def ssm_init(rng, d_inner: int, state: int, dtype) -> Params:
    ks = jax.random.split(rng, 5)
    return {
        "conv": (jax.random.normal(ks[0], (CONV_K, d_inner), F32)
                 * 0.2).astype(dtype),
        "w_dt": dense_init(ks[1], d_inner, d_inner, dtype),
        "dt_bias": jnp.zeros((d_inner,), dtype),
        "w_b": dense_init(ks[2], d_inner, state, dtype),
        "w_c": dense_init(ks[3], d_inner, state, dtype),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, state + 1, dtype=F32),
                                  (d_inner, 1))).astype(F32),
        "d_skip": jnp.ones((d_inner,), dtype),
    }


def _causal_conv(p: Params, x: jax.Array, carry: jax.Array | None):
    """Depthwise causal conv, kernel CONV_K.  carry: (B, CONV_K-1, d)."""
    B, T, d = x.shape
    if carry is None:
        carry = jnp.zeros((B, CONV_K - 1, d), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)  # (B, T+K-1, d)
    w = p["conv"].astype(F32)
    out = sum(xp[:, i:i + T].astype(F32) * w[i] for i in range(CONV_K))
    return jax.nn.silu(out).astype(x.dtype), xp[:, -(CONV_K - 1):]


def _scan(dt, b, c, x, a, h0):
    """dt, x: (B,T,d); b,c: (B,T,N); a: (d,N); h0: (B,d,N).

    §Perf note: da/dbx are computed *inside* the step from the (B,d)/(B,N)
    slices — pre-materialising the (B,T,d,N) tensors (the obvious vectorised
    form) costs 2 x B*T*d*N*4 bytes of HBM traffic per layer (13 GB/layer at
    32k prefill), dominating the hymba/rwkv memory term."""

    def step(h, inp):
        dt_t, b_t, c_t, x_t = (t.astype(jnp.float32) for t in inp)
        da_t = jnp.exp(dt_t[..., None] * a[None])        # (B,d,N)
        h = da_t * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    # xs streamed in bf16 (halves the scan's HBM/ICI traffic); the state and
    # per-step arithmetic stay fp32.  dt keeps fp32: exp(dt*A) is the decay
    # and bf16 dt visibly perturbs long-horizon state retention.
    xs = (jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b.astype(jnp.bfloat16), 1, 0),
          jnp.moveaxis(c.astype(jnp.bfloat16), 1, 0),
          jnp.moveaxis(x.astype(jnp.bfloat16), 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT                     # (B,T,d), (B,d,N)


def ssm_forward(p: Params, x: jax.Array, state: tuple | None = None):
    """x: (B, T, d_inner) -> (y, new_state); state = (conv_carry, h)."""
    B, T, d = x.shape
    n = p["w_b"].shape[1]
    conv_carry = None if state is None else state[0]
    h0 = (jnp.zeros((B, d, n), F32) if state is None
          else state[1])
    xc, conv_carry = _causal_conv(p, x, conv_carry)
    dt = jax.nn.softplus(matmul(xc, p["w_dt"]).astype(F32)
                         + p["dt_bias"].astype(F32))
    b = matmul(xc, p["w_b"]).astype(F32)
    c = matmul(xc, p["w_c"]).astype(F32)
    a = -jnp.exp(p["a_log"])                              # (d, N), negative
    y, hT = _scan(dt, b, c, xc.astype(F32), a, h0)
    y = y + xc.astype(F32) * p["d_skip"].astype(F32)
    return y.astype(x.dtype), (conv_carry, hT)


def ssm_decode_step(p: Params, x: jax.Array, state: tuple):
    """x: (B, 1, d_inner) single-token step."""
    return ssm_forward(p, x, state)
