"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, encoder_seq, d_model).  The encoder is a
bidirectional transformer (layernorm + gelu, learned positions); the decoder
adds causal self-attention + cross-attention to the encoder output.

Serving: ``prefill`` encodes once and caches both the decoder self-attention
KV and the (per-layer) cross-attention KV of the encoder output; decode steps
touch only the self-attention cache.  The assignment's decode shapes size the
*decoder* self-cache (32k — far past Whisper's real 448-token decoder limit;
we lower the backbone at the assigned shape and note the discrepancy in
DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers
from .layers import F32, Params
from .transformer import _pick_chunk, chunked_ce

__all__ = ["init_params", "train_loss", "prefill", "decode_step",
           "init_cache"]


def _enc_layer_init(cfg: ModelConfig, rng) -> Params:
    ks = jax.random.split(rng, 2)
    hd = cfg.resolved_head_dim
    return {
        "norm1": layers.layernorm_init(cfg.d_model, cfg.dtype),
        "attn": layers.attention_init(ks[0], cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, hd, cfg.dtype),
        "norm2": layers.layernorm_init(cfg.d_model, cfg.dtype),
        "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.dtype,
                               act="gelu"),
    }


def _dec_layer_init(cfg: ModelConfig, rng) -> Params:
    ks = jax.random.split(rng, 3)
    hd = cfg.resolved_head_dim
    return {
        "norm1": layers.layernorm_init(cfg.d_model, cfg.dtype),
        "self_attn": layers.attention_init(ks[0], cfg.d_model, cfg.num_heads,
                                           cfg.num_kv_heads, hd, cfg.dtype),
        "norm_x": layers.layernorm_init(cfg.d_model, cfg.dtype),
        "cross_attn": layers.attention_init(ks[1], cfg.d_model, cfg.num_heads,
                                            cfg.num_kv_heads, hd, cfg.dtype),
        "norm2": layers.layernorm_init(cfg.d_model, cfg.dtype),
        "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.dtype,
                               act="gelu"),
    }


def init_params(cfg: ModelConfig, rng) -> Params:
    ks = jax.random.split(rng, 6)
    enc_L = cfg.encoder_layers or cfg.num_layers
    dec_L = cfg.num_layers
    return {
        "embed": layers.embed_init(ks[0], cfg.vocab_padded(), cfg.d_model,
                                   cfg.dtype),
        "enc_pos": layers.embed_init(ks[1], cfg.encoder_seq, cfg.d_model,
                                     cfg.dtype),
        "dec_pos": layers.embed_init(ks[2], 32_768 + 8, cfg.d_model,
                                     cfg.dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(cfg, k))(
            jax.random.split(ks[3], enc_L)),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(cfg, k))(
            jax.random.split(ks[4], dec_L)),
        "enc_norm": layers.layernorm_init(cfg.d_model, cfg.dtype),
        "dec_norm": layers.layernorm_init(cfg.d_model, cfg.dtype),
    }


def _proj_qkv(cfg, ap, x, n_heads, n_kv):
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    q = layers.matmul(x, ap["wq"]).reshape(B, S, n_heads, hd)
    k = layers.matmul(x, ap["wk"]).reshape(B, S, n_kv, hd)
    v = layers.matmul(x, ap["wv"]).reshape(B, S, n_kv, hd)
    return q, k, v


def _encode(cfg: ModelConfig, params: Params, frames: jax.Array):
    """frames: (B, S_enc, d_model) stub embeddings -> encoder output."""
    x = frames.astype(cfg.dtype) + params["enc_pos"][None, :frames.shape[1]]

    def body(xc, lp):
        h = layers.layernorm(lp["norm1"], xc)
        q, k, v = _proj_qkv(cfg, lp["attn"], h, cfg.num_heads,
                            cfg.num_kv_heads)
        S = h.shape[1]
        attn = layers.flash_attention(q, k, v, causal=False,
                                      q_chunk=_pick_chunk(S, 512),
                                      k_chunk=_pick_chunk(S, 512))
        attn = attn.reshape(h.shape[0], S, -1)
        xc = xc + layers.matmul(attn, lp["attn"]["wo"])
        h2 = layers.layernorm(lp["norm2"], xc)
        xc = xc + layers.mlp_apply(lp["mlp"], h2, act="gelu")
        return xc, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layers.layernorm(params["enc_norm"], x)


def _decode_stack(cfg: ModelConfig, params: Params, x, enc_out, *, mode,
                  cache=None, length=None):
    """Decoder over stacked layers.  In prefill, cross-attn K/V are computed
    once per layer and emitted into the cache; decode reuses them."""
    B = x.shape[0]

    def body(xc, inp):
        lp, layer_cache = inp
        S = xc.shape[1]
        # --- causal self attention ---
        h = layers.layernorm(lp["norm1"], xc)
        q, k, v = _proj_qkv(cfg, lp["self_attn"], h, cfg.num_heads,
                            cfg.num_kv_heads)
        cache_out = None
        if mode == "decode":
            k_cache = jax.lax.dynamic_update_slice(
                layer_cache["k"], k.astype(layer_cache["k"].dtype),
                (0, length, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                layer_cache["v"], v.astype(layer_cache["v"].dtype),
                (0, length, 0, 0))
            attn = layers.decode_attention(q, k_cache, v_cache, length + 1)
            cache_out = {"k": k_cache, "v": v_cache,
                         "xk": layer_cache["xk"], "xv": layer_cache["xv"]}
        else:
            attn_fn = (layers.flash_attention_triangular
                       if cfg.attn_schedule == "triangular"
                       else layers.flash_attention)
            attn = attn_fn(q, k, v, causal=True,
                           q_chunk=_pick_chunk(S, 512),
                           k_chunk=_pick_chunk(S, 512))
        attn = attn.reshape(B, S, -1)
        xc = xc + layers.matmul(attn, lp["self_attn"]["wo"])

        # --- cross attention ---
        h = layers.layernorm(lp["norm_x"], xc)
        cp = lp["cross_attn"]
        hd = cfg.resolved_head_dim
        qx = layers.matmul(h, cp["wq"]).reshape(B, S, cfg.num_heads, hd)
        if mode == "decode":
            xk, xv = layer_cache["xk"], layer_cache["xv"]
            Se = xk.shape[1]
            cross = layers.decode_attention(qx, xk, xv, Se)
        else:
            Se = enc_out.shape[1]
            xk = layers.matmul(enc_out, cp["wk"]).reshape(
                B, Se, cfg.num_kv_heads, hd)
            xv = layers.matmul(enc_out, cp["wv"]).reshape(
                B, Se, cfg.num_kv_heads, hd)
            cross = layers.flash_attention(qx, xk, xv, causal=False,
                                           q_chunk=_pick_chunk(S, 512),
                                           k_chunk=_pick_chunk(Se, 512))
            if mode == "prefill":
                cache_out = {"k": k, "v": v, "xk": xk, "xv": xv}
        cross = cross.reshape(B, S, -1)
        xc = xc + layers.matmul(cross, cp["wo"])

        # --- mlp ---
        h2 = layers.layernorm(lp["norm2"], xc)
        xc = xc + layers.mlp_apply(lp["mlp"], h2, act="gelu")
        return xc, cache_out

    if mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)
    if cache is None:
        x, caches = jax.lax.scan(lambda c, lp: body(c, (lp, None)), x,
                                 params["dec_layers"])
    else:
        x, caches = jax.lax.scan(body, x, (params["dec_layers"], cache))
    return x, caches


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    """batch: frames (B, S_enc, d), tokens (B, S), labels (B, S)."""
    enc_out = _encode(cfg, params, batch["frames"])
    S = batch["tokens"].shape[1]
    x = params["embed"][batch["tokens"]] + params["dec_pos"][None, :S]
    x, _ = _decode_stack(cfg, params, x, enc_out, mode="train")
    x = layers.layernorm(params["dec_norm"], x)
    loss, count = chunked_ce(cfg, params, x, batch["labels"])
    return loss, {"tokens": count}


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    enc_out = _encode(cfg, params, batch["frames"])
    S = batch["tokens"].shape[1]
    x = params["embed"][batch["tokens"]] + params["dec_pos"][None, :S]
    x, caches = _decode_stack(cfg, params, x, enc_out, mode="prefill")
    x = layers.layernorm(params["dec_norm"], x)
    logits = jax.lax.dot_general(x[:, -1], params["embed"],
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    return caches, logits


def decode_step(cfg: ModelConfig, params: Params, cache, tokens: jax.Array,
                length: jax.Array):
    x = params["embed"][tokens]
    x = x + jnp.take(params["dec_pos"], jnp.full((1,), length), axis=0)[None]
    x, new_cache = _decode_stack(cfg, params, x, None, mode="decode",
                                 cache=cache, length=length)
    x = layers.layernorm(params["dec_norm"], x)
    logits = jax.lax.dot_general(x[:, 0], params["embed"],
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    return new_cache, logits


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    L = cfg.num_layers
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, hd), dtype),
        "xk": jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_kv_heads, hd),
                        dtype),
        "xv": jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_kv_heads, hd),
                        dtype),
    }
