"""Stub modality frontends (per assignment: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; the frontend provides precomputed
frame/patch embeddings).

These helpers generate concrete stub inputs for smoke tests / examples; the
dry-run uses the matching ``ShapeDtypeStruct`` from ``launch.specs``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

__all__ = ["audio_frames_stub", "vision_patches_stub"]


def audio_frames_stub(cfg: ModelConfig, batch: int, rng=None) -> jax.Array:
    """Whisper conv frontend stub: (B, encoder_seq, d_model) frame embeds
    (the real model downsamples 30 s of mel features to 1500 frames)."""
    rng = rng if rng is not None else jax.random.key(0)
    return 0.02 * jax.random.normal(
        rng, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32)


def vision_patches_stub(cfg: ModelConfig, batch: int, rng=None) -> jax.Array:
    """CLIP-style patch embedding stub: (B, num_patches, d_model).

    phi-3-vision's real tower emits 576 patch features per 336px crop; we use
    a 512-patch stub so the packed (patches + tokens) sequence stays
    chunk-friendly (DESIGN.md records the simplification)."""
    rng = rng if rng is not None else jax.random.key(0)
    return 0.02 * jax.random.normal(
        rng, (batch, cfg.num_patches, cfg.d_model), jnp.float32)
