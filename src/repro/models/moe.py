"""Mixture-of-Experts layer (Qwen-MoE style: routed top-k + optional shared
experts) with a sort-based, capacity-bounded dispatch.

Two dispatch paths:
  * ``dispatch="dense"`` — sort-by-expert + capacity gather/scatter, batched
    expert matmuls (E on the leading dim so EP sharding is a plain
    PartitionSpec).  This is the production path the dry-runs exercise.
  * ``dispatch="loops"`` — the token->expert assignment is materialised as a
    vector-wise BCSR operand and the combine runs through the LOOPS SpMM
    (the paper's format applied to MoE: each expert's token group is a block
    of ``Br x 1`` column tiles).  Exercised by tests as the paper-technique
    integration point (DESIGN.md §Arch-applicability).

Expert count is padded to the EP shard count (e.g. qwen2-moe's 60 routed
experts pad to 64 for a 16-way axis); padded experts receive zero router
probability and zero-initialised weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers
from .layers import F32, Params, dense_init, matmul

__all__ = ["moe_init", "moe_apply", "pad_experts"]


def pad_experts(num_experts: int, shards: int) -> int:
    return ((num_experts + shards - 1) // shards) * shards


def moe_init(rng, d_model: int, moe_d_ff: int, num_experts: int,
             num_experts_padded: int, top_k: int, dtype,
             num_shared: int = 0, shared_d_ff: int = 0) -> Params:
    ks = jax.random.split(rng, 6)
    e = num_experts_padded

    def expert_stack(key, d_in, d_out):
        w = jax.random.normal(key, (e, d_in, d_out), F32) / jnp.sqrt(d_in)
        # zero the padded experts so they are inert even if routed to
        mask = (jnp.arange(e) < num_experts)[:, None, None]
        return (w * mask).astype(dtype)

    p = {
        "router": dense_init(ks[0], d_model, num_experts_padded, jnp.float32),
        "wi": expert_stack(ks[1], d_model, moe_d_ff),
        "wg": expert_stack(ks[2], d_model, moe_d_ff),
        "wo": expert_stack(ks[3], moe_d_ff, d_model),
    }
    if num_shared > 0:
        p["shared"] = layers.mlp_init(ks[4], d_model, shared_d_ff, dtype)
        p["shared_gate"] = dense_init(ks[5], d_model, 1, dtype)
    return p


def _route(router_w, x2d, num_experts: int, top_k: int):
    """Top-k routing with softmax-renormalised weights over the selected k."""
    logits = jnp.einsum("td,de->te", x2d.astype(F32),
                        router_w.astype(F32))
    e_pad = router_w.shape[1]
    neg = jnp.where(jnp.arange(e_pad) < num_experts, 0.0, -1e30)
    logits = logits + neg[None, :]
    weights, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, idx  # (T, k) each


def _sort_dispatch(idx, T: int, k: int, e_pad: int, capacity: int):
    """Sort-based capacity dispatch: returns (slot_of_assignment, keep_mask).

    slot = expert * capacity + position-in-expert for kept assignments;
    dropped (over-capacity) assignments get slot = e_pad * capacity (one past
    the buffer, scatter mode='drop')."""
    flat_e = idx.reshape(-1)                        # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    ar = jnp.arange(T * k)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    start_marker = jnp.where(is_start, ar, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, start_marker)
    pos_sorted = ar - seg_start                      # position within expert
    # un-permute back to assignment order
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < capacity
    slot = jnp.where(keep, flat_e.reshape(-1) * capacity + pos,
                     e_pad * capacity)
    return slot, keep


def moe_apply(p: Params, x: jax.Array, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25, act: str = "swiglu",
              dispatch: str = "gather") -> jax.Array:
    """x: (B, S, d) -> (B, S, d).

    ``dispatch="gather"`` (§Perf iteration, default): both the expert buffer
    fill and the token combine are expressed as GATHERS driven by small 1-D
    integer scatters.  The naive ``"scatter"`` path (buf.at[slot].set /
    out.at[token].add on (E*C, d) operands) lowers to element-wise u32 index
    maps the size of the whole buffer — profiled at 11.5 TB of HBM traffic
    per step on qwen3-moe train_4k; the gather path removes every wide
    scatter.
    """
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    e_pad = p["router"].shape[1]
    weights, idx = _route(p["router"], x2d, num_experts, top_k)

    capacity = max(int(T * top_k / e_pad * capacity_factor), 4)
    # round capacity for friendlier tiling
    capacity = (capacity + 3) // 4 * 4
    slot, keep = _sort_dispatch(idx, T, k=top_k, e_pad=e_pad,
                                capacity=capacity)

    token_of_assignment = jnp.repeat(jnp.arange(T), top_k)
    if dispatch == "gather":
        # 1-D int scatter: which assignment fills each buffer slot
        tk = T * top_k
        filler = jnp.full((e_pad * capacity,), tk, jnp.int32)
        filler = filler.at[slot].set(jnp.arange(tk, dtype=jnp.int32),
                                     mode="drop")
        valid = filler < tk
        tok = token_of_assignment[jnp.minimum(filler, tk - 1)]
        buf = jnp.where(valid[:, None], x2d[tok], 0)
        buf = buf.reshape(e_pad, capacity, d)
    else:
        # naive wide scatter (kept for ablation/benchmarks)
        buf = jnp.zeros((e_pad * capacity, d), x.dtype)
        buf = buf.at[slot].set(x2d[token_of_assignment], mode="drop")
        buf = buf.reshape(e_pad, capacity, d)

    # Batched expert FFN (leading E dim -> EP sharding is P("model") on dim 0)
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf.astype(F32), p["wg"].astype(F32),
                       preferred_element_type=F32)
        h = jnp.einsum("ecd,edf->ecf", buf.astype(F32), p["wi"].astype(F32),
                       preferred_element_type=F32)
        inner = (jax.nn.silu(g) * h).astype(x.dtype)
    else:
        h = jnp.einsum("ecd,edf->ecf", buf.astype(F32), p["wi"].astype(F32),
                       preferred_element_type=F32)
        inner = jax.nn.gelu(h).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", inner.astype(F32), p["wo"].astype(F32),
                   preferred_element_type=F32)          # (E, C, d) f32
    y = y.astype(x.dtype).reshape(e_pad * capacity, d)

    # Combine: weighted gather back to tokens (sum over the k slots).
    w_flat = jnp.where(keep, weights.reshape(-1), 0.0)
    contrib = (y[jnp.minimum(slot, e_pad * capacity - 1)].astype(F32)
               * w_flat[:, None])
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    if dispatch == "gather":
        out = contrib.reshape(T, top_k, d).sum(axis=1).astype(x.dtype)
    else:
        out = jnp.zeros((T, d), F32).at[token_of_assignment].add(contrib)
        out = out.astype(x.dtype)

    if "shared" in p:
        gate = jax.nn.sigmoid(
            jnp.einsum("td,do->to", x2d.astype(F32),
                       p["shared_gate"].astype(F32)))
        shared = layers.mlp_apply(p["shared"], x2d, act=act)
        out = out + (shared.astype(F32) * gate).astype(x.dtype)

    return out.reshape(B, S, d)
