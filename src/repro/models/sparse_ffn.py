"""Weight-sparse FFN executed through LOOPS SpMM — the paper's technique as a
first-class LM feature (DESIGN.md §Arch-applicability).

A magnitude-pruned linear layer stores its weight as a LOOPS hybrid format:
the *structure* (row_ptr/col_idx/tile indices) is static host-side metadata;
the *values* (CSR vals + BCSR tile vals) are trainable pytree leaves.  The
forward pass is

    y = (W_loops @ x^T)^T        # SpMM with the activation as the dense B

so the hot loop is exactly the paper's kernel pair: irregular weight rows on
the vector pipeline, regular rows as Br x 1 outer-product tiles on the matrix
pipeline.

Differentiation note: training runs the ``jnp`` (reference) backend — the
Pallas kernels target inference/serving and carry no custom VJP; both share
the same format, so a model trained on the reference path serves on the
Pallas path bit-for-bit (tests assert this).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.formats import CSR, LoopsFormat, csr_from_dense, loops_from_csr
from ..core.spmm import plan_and_convert
from ..kernels import ref
from ..kernels.bcsr_spmm import bcsr_spmm_pallas
from ..kernels.csr_spmm import csr_spmm_pallas
from .layers import F32, Params

__all__ = ["SparseLinear", "sparse_linear_from_dense", "magnitude_prune",
           "sparse_linear_apply"]


@dataclasses.dataclass(frozen=True)
class SparseLinear:
    """Static structure of one pruned linear (d_out x d_in)."""

    fmt: LoopsFormat          # holds the *initial* values; live values in params
    d_in: int
    d_out: int

    def init_values(self) -> Params:
        return {"csr_vals": jnp.asarray(self.fmt.csr_part.vals),
                "bcsr_vals": jnp.asarray(self.fmt.bcsr_part.tile_vals)}


def magnitude_prune(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Zero out the smallest-|w| fraction ``sparsity`` of entries."""
    flat = np.abs(w).ravel()
    k = int(len(flat) * sparsity)
    if k == 0:
        return w
    thresh = np.partition(flat, k)[k]
    return np.where(np.abs(w) >= thresh, w, 0.0).astype(w.dtype)


def sparse_linear_from_dense(w: np.ndarray, sparsity: float, *,
                             total_workers: int = 8,
                             tuner=None) -> SparseLinear:
    """Prune a dense (d_out, d_in) weight and convert to LOOPS format.

    ``tuner`` (a :class:`repro.tune.Tuner`) routes planning through the
    measured plan cache: same-shaped layers of a deep model fingerprint
    alike, so the first layer pays for the search and every later layer is
    a cache hit — conversion only, no measurement.
    """
    pruned = magnitude_prune(np.asarray(w), sparsity)
    csr = csr_from_dense(pruned)
    fmt, _ = plan_and_convert(csr, total_workers=total_workers, tuner=tuner)
    return SparseLinear(fmt=fmt, d_in=w.shape[1], d_out=w.shape[0])


def sparse_linear_apply(layer: SparseLinear, values: Params, x: jax.Array,
                        *, backend: str = "jnp") -> jax.Array:
    """x: (..., d_in) -> (..., d_out) via LOOPS SpMM with live values."""
    lead = x.shape[:-1]
    xt = x.reshape(-1, layer.d_in).T           # (d_in, T) dense operand B
    fmt = layer.fmt
    out_dtype = ref.acc_dtype_for(values["csr_vals"].dtype)
    parts = []
    if fmt.r_boundary > 0:
        csr = fmt.csr_part
        row_ids, col_idx = jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx)
        if backend == "jnp":
            parts.append(ref.csr_spmm_ref(row_ids, col_idx,
                                          values["csr_vals"], xt, csr.nrows,
                                          out_dtype=out_dtype))
        else:
            parts.append(csr_spmm_pallas(row_ids, col_idx,
                                         values["csr_vals"], xt,
                                         nrows=csr.nrows, out_dtype=out_dtype,
                                         interpret=(backend == "interpret")))
    if fmt.r_boundary < fmt.nrows:
        b = fmt.bcsr_part
        trows, tcols = jnp.asarray(b.tile_rows), jnp.asarray(b.tile_cols)
        if backend == "jnp":
            padded = ref.bcsr_spmm_ref(trows, tcols, values["bcsr_vals"], xt,
                                       b.nblocks, out_dtype=out_dtype)
        else:
            padded = bcsr_spmm_pallas(trows, tcols, values["bcsr_vals"], xt,
                                      nblocks=b.nblocks, out_dtype=out_dtype,
                                      interpret=(backend == "interpret"))
        parts.append(padded[:b.nrows])
    y = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    return y.T.reshape(*lead, layer.d_out).astype(x.dtype)
