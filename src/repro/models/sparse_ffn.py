"""Weight-sparse FFN executed through LOOPS SpMM — the paper's technique as a
first-class LM feature (DESIGN.md §Arch-applicability).

A magnitude-pruned linear layer stores its weight as a LOOPS hybrid format:
the *structure* (row_ptr/col_idx/tile indices) is static host-side metadata;
the *values* (CSR vals + BCSR tile vals) are trainable pytree leaves.  The
forward pass is

    y = (W_loops @ x^T)^T        # SpMM with the activation as the dense B

so the hot loop is exactly the paper's kernel pair: irregular weight rows on
the vector pipeline, regular rows as Br x 1 outer-product tiles on the matrix
pipeline.

Differentiation: the layer trains directly on the Pallas backends through
:func:`repro.core.spmm.loops_spmm_values` — a ``jax.custom_vjp`` whose
backward pass runs ``dx = Wᵀ·dy`` on the cached transposed format (live
values carried across by static scatter maps) and computes the value
gradients with the sampled dense-dense kernels (``kernels/spmm_sdd.py``),
never materialising ``dy @ xᵀ`` densely.  The ``jnp`` backend remains the
gradient oracle (native autodiff through the reference kernels); both share
the same format, so a model trained on either path serves on the Pallas path
bit-for-bit (tests assert this).  See ``docs/training.md``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.formats import LoopsFormat, csr_from_dense
from ..core.spmm import loops_spmm_values, plan_and_convert
from ..kernels import ops
from .layers import Params

__all__ = ["SparseLinear", "sparse_linear_from_dense", "magnitude_prune",
           "sparse_linear_apply"]


@dataclasses.dataclass(frozen=True)
class SparseLinear:
    """Static structure of one pruned linear (d_out x d_in)."""

    fmt: LoopsFormat          # holds the *initial* values; live values in params
    d_in: int
    d_out: int

    def init_values(self) -> Params:
        return {"csr_vals": jnp.asarray(self.fmt.csr_part.vals),
                "bcsr_vals": jnp.asarray(self.fmt.bcsr_part.tile_vals)}


def magnitude_prune(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Zero out the smallest-|w| fraction ``sparsity`` of entries."""
    flat = np.abs(w).ravel()
    k = int(len(flat) * sparsity)
    if k == 0:
        return w
    thresh = np.partition(flat, k)[k]
    return np.where(np.abs(w) >= thresh, w, 0.0).astype(w.dtype)


def sparse_linear_from_dense(w: np.ndarray, sparsity: float, *,
                             total_workers: int = 8,
                             tuner=None) -> SparseLinear:
    """Prune a dense (d_out, d_in) weight and convert to LOOPS format.

    ``tuner`` (a :class:`repro.tune.Tuner`) routes planning through the
    measured plan cache: same-shaped layers of a deep model fingerprint
    alike, so the first layer pays for the search and every later layer is
    a cache hit — conversion only, no measurement.
    """
    pruned = magnitude_prune(np.asarray(w), sparsity)
    csr = csr_from_dense(pruned)
    fmt, _ = plan_and_convert(csr, total_workers=total_workers, tuner=tuner)
    return SparseLinear(fmt=fmt, d_in=w.shape[1], d_out=w.shape[0])


def sparse_linear_apply(layer: SparseLinear, values: Params, x: jax.Array,
                        *, backend: str | None = None) -> jax.Array:
    """x: (..., d_in) -> (..., d_out) via LOOPS SpMM with live values.

    A rank-2 activation ``(T, d_in)`` executes as the classic single SpMM
    against ``xᵀ``; higher ranks ``(*batch, T, d_in)`` keep their batch
    structure and ride the engine's native batched path — ONE kernel call
    per weight regardless of the batch size, instead of flattening every
    leading dim into the dense-column axis (which destroyed the batch
    layout for downstream per-sequence consumers) or looping per element.

    Fully differentiable on every backend (``backend=None`` picks the real
    kernel path — 'pallas' on TPU, 'interpret' elsewhere): gradients flow to
    both the activation and the stored weight values through the custom VJP.
    """
    backend = backend or ops.default_backend()
    vec = x.ndim == 1
    xm = x[None] if vec else x                 # (..., T, d_in)
    xt = jnp.swapaxes(xm, -1, -2)              # (..., d_in, T) dense operand
    y = loops_spmm_values(layer.fmt, values["csr_vals"], values["bcsr_vals"],
                          xt, backend=backend)
    y = jnp.swapaxes(y, -1, -2)                # (..., T, d_out)
    return (y[0] if vec else y).astype(x.dtype)
