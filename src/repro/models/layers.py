"""Shared neural-net layers (pure JAX, pytree params, no framework deps).

Conventions
-----------
* Params are nested dicts of jax arrays; init functions take an ``rng`` and
  return the pytree.  Abstract (allocation-free) init for the dry-run is done
  by the caller via ``jax.eval_shape``.
* All matmuls take ``preferred_element_type=f32`` so bf16 models accumulate
  in fp32 on the MXU (the same contract as the LOOPS bf16 kernels).
* Attention is chunked/online-softmax (flash-style) so 32k-token prefill
  never materialises an (S, S) score matrix.
* Every layer is differentiable on its real execution path — there is no
  dense-gradient or reference-backend detour anywhere in the training
  graph.  Dense layers rely on native autodiff; the LOOPS-sparse linear
  (:mod:`repro.models.sparse_ffn`) carries its own custom VJP so the
  Pallas kernels train directly (see ``docs/training.md``).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict

F32 = jnp.float32


# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------

def dense_init(rng, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(rng, (d_in, d_out), F32) * scale).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(rng, (vocab, d), F32) * 0.02).astype(dtype)


def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """x @ w with fp32 accumulation, result cast back to x.dtype."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=F32).astype(x.dtype)


def replicate_last_dim(x: jax.Array) -> jax.Array:
    """Constrain the last dim to be replicated over the mesh (batch/seq dims
    stay unconstrained).  No-op when traced without an ambient mesh (unit
    tests / single-device runs).

    §Perf use: architectures whose head counts don't divide the model axis
    (hymba's 25 heads) would otherwise enter attention with a d-sharded
    residual stream, making every score einsum contract a sharded dim (an
    all-reduce per chunk pair).  One explicit reshard here replaces TBs of
    score all-reduce with one (B, S, d) gather per layer."""
    from jax.sharding import PartitionSpec as P
    try:
        spec = P(*([P.UNCONSTRAINED] * (x.ndim - 1)), None)
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(F32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(F32) + p["bias"].astype(F32)).astype(x.dtype)


def norm_init(kind: str, d: int, dtype) -> Params:
    return layernorm_init(d, dtype) if kind == "layernorm" else rmsnorm_init(d, dtype)


def norm_apply(kind: str, p: Params, x: jax.Array) -> jax.Array:
    return layernorm(p, x) if kind == "layernorm" else rmsnorm(p, x)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    angles = positions.astype(F32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_init(rng, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   dtype, qk_norm: bool = False,
                   cross: bool = False) -> Params:
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim, dtype)
        p["k_norm"] = rmsnorm_init(head_dim, dtype)
    return p


def _flash_body(q, k, v, mask_fn, q_chunk, k_chunk):
    """Online-softmax attention.  q: (B, Sq, H, hd), k/v: (B, Sk, KV, hd).
    ``mask_fn(qi, ki)`` -> (q_chunk, k_chunk) boolean allow-mask given chunk
    start offsets."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    nq, nk = Sq // q_chunk, Sk // k_chunk

    q = q.reshape(B, nq, q_chunk, H, hd)
    k = k.reshape(B, nk, k_chunk, KV, hd)
    v = v.reshape(B, nk, k_chunk, KV, hd)

    def q_step(_, qi):
        qc = q[:, qi]  # (B, qc, H, hd)

        def k_step(carry, ki):
            acc, m, l = carry
            kc = k[:, ki]  # (B, kc, KV, hd)
            vc = v[:, ki]
            # scores: (B, H, qc, kc) with GQA head grouping
            qg = qc.reshape(B, q_chunk, KV, rep, hd)
            s = jnp.einsum("bqgrh,bkgh->bgrqk", qg.astype(F32),
                           kc.astype(F32),
                           preferred_element_type=F32) * scale
            s = s.reshape(B, KV, rep, q_chunk, k_chunk)
            allow = mask_fn(qi * q_chunk, ki * k_chunk)  # (qc, kc)
            s = jnp.where(allow[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bgrqk,bkgh->bgrqh", p, vc.astype(F32),
                            preferred_element_type=F32)
            acc_new = acc * corr[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, KV, rep, q_chunk, hd), F32)
        m0 = jnp.full((B, KV, rep, q_chunk), -1e30, F32)
        l0 = jnp.zeros((B, KV, rep, q_chunk), F32)
        (acc, m, l), _ = jax.lax.scan(k_step, (acc0, m0, l0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, rep, qc, hd) -> (B, qc, H, hd)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, H, hd)
        return None, out

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))
    # outs: (nq, B, qc, H, hd) -> (B, Sq, H, hd)
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_chunk: int = 512, k_chunk: int = 512) -> jax.Array:
    """Chunked attention; O(S) memory.  window > 0 = sliding-window causal."""
    Sq, Sk = q.shape[1], k.shape[1]
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)

    def mask_fn(q0, k0):
        qpos = q0 + jnp.arange(q_chunk)[:, None]
        kpos = k0 + jnp.arange(k_chunk)[None, :]
        allow = jnp.ones((q_chunk, k_chunk), bool)
        if causal:
            allow &= kpos <= qpos + (Sk - Sq)  # prefix-cache offset
        if window:
            allow &= kpos > qpos + (Sk - Sq) - window
        return allow

    out = _flash_body(q, k, v, mask_fn, q_chunk, k_chunk)
    return out.astype(q.dtype)


def flash_attention_triangular(q, k, v, *, causal: bool = True,
                               window: int = 0, q_chunk: int = 512,
                               k_chunk: int = 512) -> jax.Array:
    """Causal/windowed attention with a *triangular* static schedule.

    §Perf iteration: the plain chunked path computes all nq x nk chunk pairs
    and masks the dead ones — half the score FLOPs/traffic above the causal
    diagonal is wasted (all but ~window/S of it for sliding-window layers).
    Here the q-chunk loop is unrolled (python loop -> static HLO) and each
    q-chunk attends only to its live k-span [lo, hi):

        hi = causal frontier, rounded up to a k_chunk multiple
        lo = window start, rounded down (0 for full attention)

    Savings are *visible to static HLO analysis* (and to real hardware):
    ~2x for full causal, ~S/(window+qc) for sliding-window prefill.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    off = Sk - Sq
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq = Sq // q_chunk
    outs = []
    for qi in range(nq):
        q0 = qi * q_chunk
        hi = min(((q0 + q_chunk - 1 + off) // k_chunk + 1) * k_chunk, Sk) \
            if causal else Sk
        lo = 0
        if window:
            lo = max(((q0 + off - window + 1) // k_chunk) * k_chunk, 0)
        qc = q[:, q0:q0 + q_chunk]
        kc = k[:, lo:hi]
        vc = v[:, lo:hi]
        span = hi - lo

        def mask_fn(mq0, mk0, _q0=q0, _lo=lo):
            qpos = _q0 + mq0 + jnp.arange(q_chunk)[:, None] + off
            kpos = _lo + mk0 + jnp.arange(min(k_chunk, span))[None, :]
            allow = jnp.ones((q_chunk, min(k_chunk, span)), bool)
            if causal:
                allow &= kpos <= qpos
            if window:
                allow &= kpos > qpos - window
            return allow

        outs.append(_flash_body(qc, kc, vc, mask_fn, q_chunk,
                                min(k_chunk, span)))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length, *, window: int = 0):
    """Single-token attention against a cache.

    q: (B, 1, H, hd); caches: (B, S, KV, hd); length: scalar int — number of
    valid cache entries (the new token's k/v already written at length-1).
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, rep, hd)
    s = jnp.einsum("bgrh,bsgh->bgrs", qg.astype(F32), k_cache.astype(F32),
                   preferred_element_type=F32) * scale
    pos = jnp.arange(S)
    valid = pos < length
    if window:
        valid &= pos >= length - window
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrs,bsgh->bgrh", p, v_cache.astype(F32),
                     preferred_element_type=F32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(rng, d_model: int, d_ff: int, dtype, act: str = "swiglu") -> Params:
    ks = jax.random.split(rng, 3)
    if act == "swiglu":
        return {"wi": dense_init(ks[0], d_model, d_ff, dtype),
                "wg": dense_init(ks[1], d_model, d_ff, dtype),
                "wo": dense_init(ks[2], d_ff, d_model, dtype)}
    return {"wi": dense_init(ks[0], d_model, d_ff, dtype),
            "wo": dense_init(ks[2], d_ff, d_model, dtype)}


def mlp_apply(p: Params, x: jax.Array, act: str = "swiglu") -> jax.Array:
    if act == "swiglu":
        h = jax.nn.silu(matmul(x, p["wg"]).astype(F32)).astype(x.dtype)
        return matmul(h * matmul(x, p["wi"]), p["wo"])
    h = jax.nn.gelu(matmul(x, p["wi"]).astype(F32)).astype(x.dtype)
    return matmul(h, p["wo"])
