"""RWKV-6 "Finch" block (attention-free, data-dependent decay).

Faithful structure: token-shift ddlerp mixing, low-rank (LoRA) adapters for
the five mixes and the decay, per-head matrix-valued state with
data-dependent diagonal decay, bonus ``u`` term, per-head groupnorm, silu
gate.  The recurrence

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

runs as a ``lax.scan`` over time for train/prefill and as a single-step
update for decode (state is O(1) in sequence length — the reason rwkv6-3b
runs the ``long_500k`` cell that full-attention archs skip).

LOOPS applicability: none in the time-mix (dense square projections +
elementwise recurrence; no sparse x dense product) — see DESIGN.md
§Arch-applicability.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import F32, Params, dense_init, layernorm, layernorm_init, matmul

__all__ = ["rwkv6_init", "rwkv6_forward", "rwkv6_decode_step", "rwkv6_state"]

_MIXES = ("r", "k", "v", "w", "g")


def rwkv6_init(rng, d_model: int, n_heads: int, dtype,
               lora_rank: int = 32, decay_rank: int = 64) -> Params:
    hd = d_model // n_heads
    ks = jax.random.split(rng, 16)
    p: Params = {
        "mu_x": jnp.full((d_model,), 0.5, dtype),
        # ddlerp LoRA: shared A, per-mix B
        "mix_a": dense_init(ks[0], d_model, lora_rank * 5, dtype),
        "mix_b": (jax.random.normal(ks[1], (5, lora_rank, d_model), F32)
                  * 0.01).astype(dtype),
        "mu": (jnp.tile(jnp.linspace(0.3, 0.7, 5)[:, None],
                        (1, d_model))).astype(dtype),
        "wr": dense_init(ks[2], d_model, d_model, dtype),
        "wk": dense_init(ks[3], d_model, d_model, dtype),
        "wv": dense_init(ks[4], d_model, d_model, dtype),
        "wg": dense_init(ks[5], d_model, d_model, dtype),
        "wo": dense_init(ks[6], d_model, d_model, dtype),
        # decay: w_t = exp(-exp(w0 + lora)), data-dependent (the Finch bit)
        "w0": jnp.full((d_model,), -2.0, dtype),
        "decay_a": dense_init(ks[7], d_model, decay_rank, dtype),
        "decay_b": (jax.random.normal(ks[8], (decay_rank, d_model), F32)
                    * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[9], (n_heads, hd), F32) * 0.1).astype(dtype),
        "ln_out": layernorm_init(d_model, dtype),
    }
    return p


def rwkv6_state(batch: int, n_heads: int, head_dim: int, dtype=jnp.float32):
    """(prev_x, S): token-shift carry + per-head matrix state."""
    return (jnp.zeros((batch, 0), dtype),  # placeholder; real init by caller
            jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32))


def _mixed_inputs(p: Params, x: jax.Array, x_prev: jax.Array):
    """ddlerp token-shift: five data-dependent interpolations of (x, x_prev).

    x, x_prev: (B, T, d).  Returns dict mix -> (B, T, d).
    """
    dx = x_prev - x
    xx = x + dx * p["mu_x"].astype(x.dtype)
    lora = jnp.tanh(matmul(xx, p["mix_a"]))            # (B, T, 5r)
    B, T, _ = lora.shape
    r5 = lora.reshape(B, T, 5, -1)
    adj = jnp.einsum("btfr,frd->btfd", r5.astype(F32),
                     p["mix_b"].astype(F32)).astype(x.dtype)
    mixed = {}
    for i, name in enumerate(_MIXES):
        mu_i = p["mu"][i].astype(x.dtype)
        mixed[name] = x + dx * (mu_i + adj[:, :, i])
    return mixed


def _decay(p: Params, xw: jax.Array) -> jax.Array:
    lora = matmul(jnp.tanh(matmul(xw, p["decay_a"])), p["decay_b"])
    wraw = p["w0"].astype(F32) + lora.astype(F32)
    return jnp.exp(-jnp.exp(wraw))  # (B, T, d) in (0, 1)


def _wkv_scan(r, k, v, w, u, s0):
    """r,k,v,w: (B, T, H, N); u: (H, N); s0: (B, H, N, N) -> y, sT.

    §Perf note: bf16-streaming the xs was tried and REFUTED — the per-step
    converts add backward-pass cast chains that tripled the measured traffic
    (15.4 -> 55.6 s on train_4k); fp32 streaming restored."""
    def step(s, inp):
        rt, kt, vt, wt = inp  # (B, H, N) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt,
                       s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    sT, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), sT  # (B, T, H, N)


def rwkv6_forward(p: Params, x: jax.Array, n_heads: int,
                  state: tuple | None = None):
    """x: (B, T, d) -> (out, new_state).  state = (x_last, S)."""
    B, T, d = x.shape
    hd = d // n_heads
    if state is None:
        x_last = jnp.zeros((B, 1, d), x.dtype)
        s0 = jnp.zeros((B, n_heads, hd, hd), F32)
    else:
        x_last, s0 = state
        x_last = x_last.reshape(B, 1, d).astype(x.dtype)

    x_prev = jnp.concatenate([x_last, x[:, :-1]], axis=1)
    mixed = _mixed_inputs(p, x, x_prev)
    r = matmul(mixed["r"], p["wr"]).reshape(B, T, n_heads, hd).astype(F32)
    k = matmul(mixed["k"], p["wk"]).reshape(B, T, n_heads, hd).astype(F32)
    v = matmul(mixed["v"], p["wv"]).reshape(B, T, n_heads, hd).astype(F32)
    g = jax.nn.silu(matmul(mixed["g"], p["wg"]).astype(F32))
    w = _decay(p, mixed["w"]).reshape(B, T, n_heads, hd)

    y, sT = _wkv_scan(r, k, v, w, p["u"].astype(F32), s0)
    y = y.reshape(B, T, d)
    y = layernorm(p["ln_out"], y.astype(x.dtype))
    out = matmul((y.astype(F32) * g).astype(x.dtype), p["wo"])
    return out, (x[:, -1], sT)


def rwkv6_decode_step(p: Params, x: jax.Array, n_heads: int, state: tuple):
    """Single token: x (B, 1, d)."""
    return rwkv6_forward(p, x, n_heads, state)


# ---------------------------------------------------------------------------
# channel mix (RWKV's FFN: token-shifted, relu^2, receptance-gated)
# ---------------------------------------------------------------------------

def channel_mix_init(rng, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(rng, 3)
    return {
        "mu_k": jnp.full((d_model,), 0.5, dtype),
        "mu_r": jnp.full((d_model,), 0.5, dtype),
        "wk": dense_init(ks[0], d_model, d_ff, dtype),
        "wv": dense_init(ks[1], d_ff, d_model, dtype),
        "wr": dense_init(ks[2], d_model, d_model, dtype),
    }


def channel_mix(p: Params, x: jax.Array, x_last: jax.Array | None):
    """x: (B, T, d); x_last: (B, d) carry from the previous segment."""
    B, T, d = x.shape
    if x_last is None:
        x_last = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([x_last[:, None], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["mu_k"].astype(x.dtype)
    xr = x + (x_prev - x) * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(matmul(xk, p["wk"]).astype(F32))).astype(x.dtype)
    r = jax.nn.sigmoid(matmul(xr, p["wr"]).astype(F32)).astype(x.dtype)
    return r * matmul(k, p["wv"]), x[:, -1]
