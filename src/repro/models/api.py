"""Family-dispatching model API.

Every architecture exposes the same four entry points regardless of family:

    init_params(cfg, rng)                     -> params pytree
    train_loss(cfg, params, batch)            -> (loss, aux)
    prefill(cfg, params, batch)               -> (cache, last_logits)
    decode_step(cfg, params, cache, tok, len) -> (cache, logits)
    init_cache(cfg, batch, max_len)           -> cache pytree

``batch`` layouts per family (all arrays sharded by the launch layer):
    dense/moe/ssm/hybrid: {tokens (B,S) i32, labels (B,S) i32}
    vlm:   + {patches (B,P,d) f32}
    audio: {frames (B,S_enc,d) f32, tokens (B,S), labels (B,S)}
"""
from __future__ import annotations

from ..configs.base import ModelConfig
from . import encdec, transformer

__all__ = ["init_params", "train_loss", "prefill", "decode_step",
           "init_cache", "num_params"]


def _mod(cfg: ModelConfig):
    return encdec if cfg.family == "audio" else transformer


def init_params(cfg: ModelConfig, rng):
    return _mod(cfg).init_params(cfg, rng)


def train_loss(cfg: ModelConfig, params, batch):
    return _mod(cfg).train_loss(cfg, params, batch)


def prefill(cfg: ModelConfig, params, batch):
    return _mod(cfg).prefill(cfg, params, batch)


def decode_step(cfg: ModelConfig, params, cache, tokens, length):
    return _mod(cfg).decode_step(cfg, params, cache, tokens, length)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    return _mod(cfg).init_cache(cfg, batch, max_len, dtype=dtype)


def num_params(params) -> int:
    return transformer.num_params(params)
