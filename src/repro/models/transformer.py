"""Decoder-only LM assembly covering the dense / moe / vlm / ssm / hybrid
families, built for compile-efficiency at 10^2..10^3-device scale:

* one ``lax.scan`` over a stacked-parameter layer pytree (HLO size is O(1) in
  depth — 88-layer granite compiles as fast as 16-layer llama),
* ``jax.checkpoint`` (full remat) around the scanned layer body in training,
* chunked flash attention (no (S, S) buffer) and chunked cross-entropy
  (no (T, vocab) buffer) so 32k prefill and 152k vocabs fit HBM,
* decode paths operate on an explicit cache pytree (attention KV, SSM state,
  RWKV matrix state) sized by the caller — `input_specs` builds the
  assignment's decode cells directly from these shapes.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers, moe as moe_lib, rwkv6, ssm as ssm_lib
from .layers import F32, Params

__all__ = ["init_params", "train_loss", "prefill", "decode_step",
           "init_cache", "num_params"]


def _pick_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (for flash/CE chunking)."""
    if n <= target:
        return max(n, 1)
    best = 1
    for d in range(1, int(n ** 0.5) + 1):
        if n % d == 0:
            if d <= target:
                best = max(best, d)
            if n // d <= target:
                best = max(best, n // d)
    return best


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _layer_init(cfg: ModelConfig, rng) -> Params:
    ks = jax.random.split(rng, 8)
    hd = cfg.resolved_head_dim
    p: Params = {"norm1": layers.norm_init(cfg.norm, cfg.d_model, cfg.dtype),
                 "norm2": layers.norm_init(cfg.norm, cfg.d_model, cfg.dtype)}
    if cfg.family == "ssm":  # rwkv6
        p["time_mix"] = rwkv6.rwkv6_init(ks[0], cfg.d_model, cfg.rwkv_heads,
                                         cfg.dtype)
        p["channel_mix"] = rwkv6.channel_mix_init(ks[1], cfg.d_model,
                                                  cfg.d_ff, cfg.dtype)
        return p
    p["attn"] = layers.attention_init(ks[0], cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, hd, cfg.dtype,
                                      qk_norm=cfg.qk_norm)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_lib.ssm_init(ks[1], cfg.d_model, cfg.ssm_state,
                                    cfg.dtype)
        p["ssm_out"] = layers.dense_init(ks[2], cfg.d_model, cfg.d_model,
                                         cfg.dtype)
        p["fuse_norm_attn"] = layers.rmsnorm_init(cfg.d_model, cfg.dtype)
        p["fuse_norm_ssm"] = layers.rmsnorm_init(cfg.d_model, cfg.dtype)
    if cfg.family == "moe":
        p["moe"] = moe_lib.moe_init(
            ks[3], cfg.d_model, cfg.moe_d_ff, cfg.num_experts,
            moe_lib.pad_experts(cfg.num_experts, 16), cfg.top_k, cfg.dtype,
            num_shared=cfg.num_shared_experts,
            shared_d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
    else:
        p["mlp"] = layers.mlp_init(ks[3], cfg.d_model, cfg.d_ff, cfg.dtype,
                                   act=cfg.act)
    return p


def init_params(cfg: ModelConfig, rng) -> Params:
    ks = jax.random.split(rng, 4)
    L = cfg.num_layers
    stacked = jax.vmap(lambda k: _layer_init(cfg, k))(jax.random.split(ks[0], L))
    p: Params = {
        "embed": layers.embed_init(ks[1], cfg.vocab_padded(), cfg.d_model,
                                   cfg.dtype),
        "layers": stacked,
        "final_norm": layers.norm_init(cfg.norm, cfg.d_model, cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = layers.embed_init(ks[2], cfg.vocab_padded(),
                                         cfg.d_model, cfg.dtype)
    if cfg.frontend == "vision_stub":
        p["patch_proj"] = layers.dense_init(ks[3], cfg.d_model, cfg.d_model,
                                            cfg.dtype)
    return p


def num_params(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _attn_block(cfg: ModelConfig, lp: Params, x, positions, *, mode,
                cache=None, length=None):
    """Returns (attn_out, cache_out) — cache_out is (k, v) for prefill/decode."""
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    ap = lp["attn"]
    q = layers.matmul(x, ap["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = layers.matmul(x, ap["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = layers.matmul(x, ap["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = layers.rmsnorm(ap["q_norm"], q)
        k = layers.rmsnorm(ap["k_norm"], k)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)

    if mode == "decode":
        k_cache, v_cache = cache
        Smax = k_cache.shape[1]
        # a buffer capped at the window size is a RING (slot = pos % Smax);
        # larger buffers hold absolute positions with window masking
        is_ring = bool(cfg.sliding_window) and Smax <= cfg.sliding_window
        write_at = length % Smax if is_ring else length
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, write_at, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, write_at, 0, 0))
        if is_ring:
            out = layers.decode_attention(q, k_cache, v_cache,
                                          jnp.minimum(length + 1, Smax),
                                          window=0)  # whole ring is in-window
        else:
            out = layers.decode_attention(q, k_cache, v_cache, length + 1,
                                          window=cfg.sliding_window)
        cache_out = (k_cache, v_cache)
    else:
        attn_fn = (layers.flash_attention_triangular
                   if cfg.attn_schedule == "triangular"
                   else layers.flash_attention)
        out = attn_fn(
            q, k, v, causal=True, window=cfg.sliding_window,
            q_chunk=_pick_chunk(S, 512), k_chunk=_pick_chunk(S, 512))
        if mode == "prefill":
            if cfg.sliding_window and cfg.sliding_window < S:
                # ring-consistent layout: entry e lives at slot e % w so
                # decode's ring writes continue seamlessly for any S
                w = cfg.sliding_window
                cache_out = (jnp.roll(k[:, -w:], S % w, axis=1),
                             jnp.roll(v[:, -w:], S % w, axis=1))
            else:
                cache_out = (k, v)
        else:
            cache_out = None
    out = out.reshape(B, S, cfg.num_heads * hd)
    return layers.matmul(out, ap["wo"]), cache_out


def _layer_apply(cfg: ModelConfig, lp: Params, x, positions, *, mode,
                 cache=None, length=None):
    """One block.  Returns (x, cache_out_pytree)."""
    if cfg.family == "ssm":
        st_tm = None if cache is None else (cache["x_tm"], cache["s"])
        h, st_tm_new = rwkv6.rwkv6_forward(
            lp["time_mix"], layers.norm_apply(cfg.norm, lp["norm1"], x),
            cfg.rwkv_heads, st_tm)
        x = x + h
        st_cm = None if cache is None else cache["x_cm"]
        h, x_cm = rwkv6.channel_mix(
            lp["channel_mix"], layers.norm_apply(cfg.norm, lp["norm2"], x),
            st_cm)
        x = x + h
        cache_out = None
        if mode in ("prefill", "decode"):
            cache_out = {"x_tm": st_tm_new[0], "s": st_tm_new[1],
                         "x_cm": x_cm}
        return x, cache_out

    h = layers.norm_apply(cfg.norm, lp["norm1"], x)
    if cfg.replicate_attn_input and mode != "decode":
        h = layers.replicate_last_dim(h)
    attn_cache = None
    ssm_cache = None
    if cache is not None:
        attn_cache = (cache["k"], cache["v"])
        if cfg.family == "hybrid":
            ssm_cache = (cache["conv"], cache["h"])
    attn_out, attn_cache_out = _attn_block(cfg, lp, h, positions, mode=mode,
                                           cache=attn_cache, length=length)
    if cfg.family == "hybrid":
        ssm_out, ssm_state = ssm_lib.ssm_forward(lp["ssm"], h,
                                                 state=ssm_cache)
        ssm_out = layers.matmul(ssm_out, lp["ssm_out"])
        fused = 0.5 * (layers.rmsnorm(lp["fuse_norm_attn"], attn_out)
                       + layers.rmsnorm(lp["fuse_norm_ssm"], ssm_out))
        x = x + fused
    else:
        ssm_state = None
        x = x + attn_out

    h2 = layers.norm_apply(cfg.norm, lp["norm2"], x)
    if cfg.family == "moe":
        ffn = moe_lib.moe_apply(lp["moe"], h2, num_experts=cfg.num_experts,
                                top_k=cfg.top_k, act=cfg.act,
                                capacity_factor=cfg.capacity_factor,
                                dispatch=cfg.moe_dispatch)
    else:
        ffn = layers.mlp_apply(lp["mlp"], h2, act=cfg.act)
    x = x + ffn

    cache_out = None
    if mode in ("prefill", "decode"):
        cache_out = {"k": attn_cache_out[0], "v": attn_cache_out[1]}
        if cfg.family == "hybrid":
            cache_out["conv"] = ssm_state[0]
            cache_out["h"] = ssm_state[1]
    return x, cache_out


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    """tokens (+ optional patch prefix) -> (x, text_start)."""
    x = params["embed"][batch["tokens"]]
    if cfg.frontend == "vision_stub" and "patches" in batch:
        patches = layers.matmul(batch["patches"].astype(cfg.dtype),
                                params["patch_proj"])
        x = jnp.concatenate([patches, x], axis=1)
        return x, patches.shape[1]
    return x, 0


def _unembed_w(cfg: ModelConfig, params: Params):
    return params["embed"] if cfg.tie_embeddings else params["unembed"]


def chunked_ce(cfg: ModelConfig, params: Params, hidden: jax.Array,
               labels: jax.Array):
    """Cross-entropy without a (T, vocab) buffer: scan over token chunks.

    hidden: (B, S, d); labels: (B, S) with -1 = masked.  Returns (loss_mean,
    n_tokens).
    """
    w = _unembed_w(cfg, params)  # (V, d)
    B, S, d = hidden.shape
    T = B * S
    h2 = hidden.reshape(T, d)
    l2 = labels.reshape(T)
    chunk = _pick_chunk(T, cfg.ce_chunk)
    nC = T // chunk
    h3 = h2.reshape(nC, chunk, d)
    l3 = l2.reshape(nC, chunk)

    def step(carry, inp):
        loss_sum, count = carry
        hc, lc = inp
        logits = jax.lax.dot_general(
            hc, w, (((1,), (1,)), ((), ())),
            preferred_element_type=F32)           # (chunk, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[:, None], axis=-1)[:, 0]
        valid = (lc >= 0).astype(F32)
        loss_sum += jnp.sum((logz - gold) * valid)
        count += jnp.sum(valid)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(step, (jnp.zeros((), F32),
                                               jnp.zeros((), F32)), (h3, l3))
    return loss_sum / jnp.maximum(count, 1.0), count


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def _run_stack(cfg: ModelConfig, params: Params, x, positions, *, mode,
               cache=None, length=None):
    """lax.scan over stacked layer params; remat in train mode."""

    def body(xc, inp):
        lp, layer_cache = inp
        out, cache_out = _layer_apply(cfg, lp, xc, positions, mode=mode,
                                      cache=layer_cache, length=length)
        return out, cache_out

    if mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    if cache is None:
        x, caches = jax.lax.scan(
            lambda c, lp: body(c, (lp, None)), x, params["layers"])
    else:
        x, caches = jax.lax.scan(body, x, (params["layers"], cache))
    return x, caches


def train_loss(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    """batch: tokens (B, S), labels (B, S) [+ patches].  Returns (loss, aux)."""
    x, text_start = _embed_inputs(cfg, params, batch)
    S_total = x.shape[1]
    positions = jnp.arange(S_total)[None, :]
    x, _ = _run_stack(cfg, params, x, positions, mode="train")
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    x = x[:, text_start:]
    loss, count = chunked_ce(cfg, params, x, batch["labels"])
    return loss, {"tokens": count}


def prefill(cfg: ModelConfig, params: Params, batch: Dict[str, Any]):
    """Build the serving cache.  Returns (cache, last_token_logits)."""
    x, text_start = _embed_inputs(cfg, params, batch)
    S_total = x.shape[1]
    positions = jnp.arange(S_total)[None, :]
    x, caches = _run_stack(cfg, params, x, positions, mode="prefill")
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    last = x[:, -1]
    logits = jax.lax.dot_general(last, _unembed_w(cfg, params),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    return caches, logits


def decode_step(cfg: ModelConfig, params: Params, cache, tokens: jax.Array,
                length: jax.Array):
    """One serving step: tokens (B, 1) + cache + current length -> logits.

    ``length`` is the number of tokens already in the cache (the new token is
    written at slot ``length``; for windowed caches, modulo the ring size).
    """
    x = params["embed"][tokens]
    positions = jnp.full((1, 1), length, jnp.int32)
    x, new_cache = _run_stack(cfg, params, x, positions, mode="decode",
                              cache=cache, length=length)
    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    logits = jax.lax.dot_general(x[:, 0], _unembed_w(cfg, params),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)
    return new_cache, logits


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, jax.Array]:
    """Allocate (or abstractly describe) the decode cache."""
    dtype = dtype or cfg.dtype
    L = cfg.num_layers
    if cfg.family == "ssm":
        H, hd = cfg.rwkv_heads, cfg.rwkv_head_dim
        return {"x_tm": jnp.zeros((L, batch, cfg.d_model), dtype),
                "s": jnp.zeros((L, batch, H, hd, hd), F32),
                "x_cm": jnp.zeros((L, batch, cfg.d_model), dtype)}
    hd = cfg.resolved_head_dim
    S = max_len
    if cfg.sliding_window and cfg.sliding_window < max_len:
        S = cfg.sliding_window
    cache = {"k": jnp.zeros((L, batch, S, cfg.num_kv_heads, hd), dtype),
             "v": jnp.zeros((L, batch, S, cfg.num_kv_heads, hd), dtype)}
    if cfg.family == "hybrid":
        cache["conv"] = jnp.zeros((L, batch, ssm_lib.CONV_K - 1, cfg.d_model),
                                  dtype)
        cache["h"] = jnp.zeros((L, batch, cfg.d_model, cfg.ssm_state), F32)
    return cache
