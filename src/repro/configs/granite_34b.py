"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, llama-arch, code model.  [arXiv:2405.04324]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,        # multi-query attention
    d_ff=24_576,
    vocab_size=49_152,
    rope_theta=10_000.0,
))


def reduced() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-reduced", family="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=1, d_ff=256, vocab_size=256)
