"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064; phi3-mini backbone + CLIP frontend (STUB: precomputed patch
embeddings, 512-patch prefix).  [hf:microsoft/Phi-3-vision-128k-instruct]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32_064,
    rope_theta=10_000.0,
    frontend="vision_stub",
    num_patches=512,
    notes="CLIP tower stubbed; 512-patch prefix keeps packed seq chunkable",
))


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b-reduced", family="vlm", num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=256,
        frontend="vision_stub", num_patches=8)
