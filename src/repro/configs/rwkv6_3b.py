"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free, Finch data-dependent
decay) d_ff=8960 vocab=65536; head size 64 -> 40 matrix-state heads.
[arXiv:2404.05892]

Sub-quadratic (O(1) decode state) -> runs the long_500k cell."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # informational; rwkv path uses rwkv_heads
    num_kv_heads=40,
    rwkv_head_dim=64,
    d_ff=8960,
    vocab_size=65_536,
    norm="layernorm",
    subquadratic=True,
))


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-reduced", family="ssm", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, rwkv_head_dim=16, d_ff=128,
        vocab_size=256, norm="layernorm", subquadratic=True)
