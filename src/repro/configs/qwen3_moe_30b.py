"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff=768(expert)
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,          # qwen3 uses explicit head_dim 128 (32*128 != 2048)
    d_ff=6144,             # dense fallback width (unused: all layers MoE)
    moe_d_ff=768,
    num_experts=128,
    top_k=8,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    notes="128 routed experts, top-8, no shared expert; qk_norm GQA",
))


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b-reduced", family="moe", num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        moe_d_ff=32, num_experts=8, top_k=2, vocab_size=256, qk_norm=True)
