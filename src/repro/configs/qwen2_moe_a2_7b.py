"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408(expert)
vocab=151936, 4 shared + 60 routed experts top-4.  [hf:Qwen/Qwen1.5-MoE-A2.7B]

The 60 routed experts pad to 64 for the 16-way EP axis (padded experts carry
zero weights and -inf router logits — inert)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,             # shared-expert width = 4 * 1408
    moe_d_ff=1408,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    notes="4 shared (always-on, sigmoid-gated) + 60 routed top-4",
))


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b-reduced", family="moe", num_layers=2,
        d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, moe_d_ff=32,
        num_experts=6, num_shared_experts=2, top_k=2, vocab_size=256)
