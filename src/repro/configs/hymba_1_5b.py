"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
ssm_state=16 vocab=32001; parallel attention + mamba heads per layer.
[arXiv:2411.13676]

Adaptations (DESIGN.md): all layers use sliding-window attention (the SSM
path carries global context — Hymba's stated rationale; the real model keeps
3 full-attention layers, which would break uniform layer stacking); meta
tokens are omitted.  Sub-quadratic -> runs the long_500k cell."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    ssm_state=16,
    sliding_window=2048,
    rope_theta=10_000.0,
    subquadratic=True,
))


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-reduced", family="hybrid", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, ssm_state=4,
        sliding_window=16, subquadratic=True)
