"""Model/run configuration dataclasses + the architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "register", "get_config",
           "list_configs"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.  One instance per assigned arch (exact
    literature values) plus reduced variants for smoke tests."""

    name: str
    family: str               # dense | moe | vlm | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0          # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    # --- attention flavour ---
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    sliding_window: int = 0    # 0 = full attention
    # --- ssm / hybrid ---
    ssm_state: int = 0
    rwkv_head_dim: int = 64
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_seq: int = 0       # fixed encoder length (whisper: 1500 frames)
    # --- frontends ---
    frontend: str = "none"     # none | audio_stub | vision_stub
    num_patches: int = 0       # vision_stub prefix length
    # --- misc ---
    norm: str = "rmsnorm"      # rmsnorm | layernorm
    act: str = "swiglu"        # swiglu | gelu
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    # sub-quadratic? (drives the long_500k skip rule)
    subquadratic: bool = False
    # loss / dispatch tuning
    ce_chunk: int = 2048
    capacity_factor: float = 1.25
    # attention schedule: "triangular" (§Perf default: unrolled causal
    # frontier + window span slicing — exact same math, ~2x less score
    # traffic, ~13x for sliding windows) or "full" (all chunk pairs, masked
    # — the pre-optimization baseline, kept for ablation)
    attn_schedule: str = "triangular"
    # TP rule: "kv_aligned" (§Perf default: replicate head-misaligned
    # projections so attention stays local) or "naive" (shard flattened
    # projections blindly — baseline)
    tp_rule: str = "kv_aligned"
    # MoE dispatch: "gather" (1-D int scatters + gathers; §Perf) or
    # "scatter" (naive wide buf.at[].set / out.at[].add — ablation only)
    moe_dispatch: str = "gather"
    # §Perf: force the attention input to be model-replicated (one reshard
    # per layer instead of per-score-tile all-reduces; for head counts that
    # do not divide the model axis)
    replicate_attn_input: bool = False
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def vocab_padded(self, multiple: int = 256) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # populate registry lazily
        from . import ALL_ARCHS  # noqa: F401
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    from . import ALL_ARCHS  # noqa: F401
    return tuple(sorted(_REGISTRY))


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """The assignment's skip rules: long_500k only for sub-quadratic archs."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return tuple(names)
