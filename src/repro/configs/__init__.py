"""Architecture registry: one module per assigned architecture.

``get_config(name)`` / ``--arch <id>`` resolve through here; each module also
provides ``reduced()`` — the same family at smoke-test scale.
"""
from .base import (SHAPES, ModelConfig, ShapeConfig, applicable_shapes,
                   get_config, list_configs, register)
from . import (granite_34b, hymba_1_5b, internlm2_20b, llama3_2_1b,
               phi3_vision_4_2b, qwen2_moe_a2_7b, qwen3_32b, qwen3_moe_30b,
               rwkv6_3b, whisper_small)

ALL_ARCHS = (
    "qwen3-moe-30b-a3b", "qwen2-moe-a2.7b", "qwen3-32b", "granite-34b",
    "llama3.2-1b", "internlm2-20b", "phi-3-vision-4.2b", "whisper-small",
    "rwkv6-3b", "hymba-1.5b",
)

REDUCED = {
    "qwen3-moe-30b-a3b": qwen3_moe_30b.reduced,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b.reduced,
    "qwen3-32b": qwen3_32b.reduced,
    "granite-34b": granite_34b.reduced,
    "llama3.2-1b": llama3_2_1b.reduced,
    "internlm2-20b": internlm2_20b.reduced,
    "phi-3-vision-4.2b": phi3_vision_4_2b.reduced,
    "whisper-small": whisper_small.reduced,
    "rwkv6-3b": rwkv6_3b.reduced,
    "hymba-1.5b": hymba_1_5b.reduced,
}

__all__ = ["ALL_ARCHS", "REDUCED", "SHAPES", "ModelConfig", "ShapeConfig",
           "applicable_shapes", "get_config", "list_configs", "register"]
