"""whisper-small [audio] — enc-dec, 12L each, d_model=768 12H d_ff=3072
vocab=51865; conv/mel frontend STUB (precomputed 1500-frame embeddings).
[arXiv:2212.04356]

The assignment's decode shapes size the *decoder* self-cache (32k), well past
Whisper's real 448-token decoder window — we lower the backbone at the
assigned shape (DESIGN.md notes the discrepancy)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    norm="layernorm",
    act="gelu",
    frontend="audio_stub",
    tie_embeddings=True,
    # kv_aligned replication is a LOSS here: 12 heads on a 16-way axis would
    # replicate a cheap (d=768, S<=4k) attention whose score all-reduce was
    # only ~0.8 s — measured regression 3.5x, so whisper keeps naive TP
    # (EXPERIMENTS.md §Perf, refuted-hypothesis record).
    tp_rule="naive",
))


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-reduced", family="audio", num_layers=2,
        encoder_layers=2, encoder_seq=30, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, norm="layernorm",
        act="gelu", frontend="audio_stub", tie_embeddings=True)
