"""Pure continuous-batching scheduler: admission, coalescing, interleave.

The policy half of the serve queue (docs/serving.md).  **No JAX, no
clocks**: every method takes ``now`` explicitly, so the whole decision
surface is a deterministic function of (submissions, timestamps, config) —
``tests/test_serve_queue.py`` drives it with a fake clock and asserts the
fairness/admission/deadline invariants without an array in sight.  The
queue layer (``repro.serve.queue``) translates the returned actions into
engine calls and reports completions back.

State machine::

    submit(req, now)  ──admission──▶  FIFO queue        (or REJECTED)
    poll(now)         ──coalesce───▶  Prefill(group)    prompt-shape-keyed
                      ──interleave─▶  Decode(group)     FIFO over groups
    note_prefill_done(gid, now)       first token landed; gen_len==1 exit
    note_decode_done(gid, now)        one token per active member; early
                                      exits; group drains at max_gen

Coalescing contract: a *group* is a set of same-``shape_key`` requests
(identical prompt length — batch rows are independent in every model
family, so padding the **batch** axis is exact; padding the **sequence**
axis is not) taken from the queue in FIFO order and padded to the engine's
batch-block grid (:func:`padded_batch`, a pure mirror of
``kernels/engine.py`` — parity-pinned by ``tests/test_serve_batching.py``).
Requests with shorter ``gen_len`` finish early and their slot idles; the
group drains when its longest member does.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

from .session import (ACTIVE, DONE, EVICTED, QUEUED, REJECTED, Request)

__all__ = ["SchedulerConfig", "Scheduler", "Group", "Prefill", "Decode",
           "batch_block", "padded_batch", "MAX_BATCH_BLOCK",
           "POLICIES"]

# Pure mirror of repro.kernels.engine.MAX_BATCH_BLOCK — re-stated here so
# the scheduler stays importable without jax; tests/test_serve_batching.py
# asserts the two constants (and both grid functions) never drift.
MAX_BATCH_BLOCK = 8

POLICIES = ("prefill-first", "decode-first")

# Group lifecycle.
G_PREFILL = "prefill"
G_DECODE = "decode"
G_DONE = "done"


def batch_block(batch: int) -> int:
    """Batch slices per grid step — the largest divisor of ``batch`` that is
    ≤ :data:`MAX_BATCH_BLOCK` (pure mirror of ``engine.batch_block``)."""
    if batch <= 0:
        return 1
    for d in range(min(batch, MAX_BATCH_BLOCK), 0, -1):
        if batch % d == 0:
            return d
    return 1


def padded_batch(batch: int) -> int:
    """Flat batch size after zero-padding to the step-minimising block
    (pure mirror of ``engine.padded_batch``): keep ``batch`` blocked by its
    largest divisor, or round up to full-width blocks, whichever walks
    fewer grid-step groups; ties keep the unpadded batch."""
    if batch <= 0:
        return batch
    bz_pad = min(batch, MAX_BATCH_BLOCK)
    groups_pad = -(-batch // bz_pad)
    if groups_pad < batch // batch_block(batch):
        return groups_pad * bz_pad
    return batch


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Admission + coalescing + interleave knobs (docs/serving.md)."""

    max_queue_depth: int = 64     # admission control: submits beyond this
    # are shed (REJECTED, counted in ``rejected``)
    max_in_flight: int = 2        # groups admitted to the engine at once
    max_batch: int = 8            # requests coalesced per prefill call
    min_batch: int = 1            # hold a prefill until this many same-
    # shape requests wait (overridden by max_wait_s or an idle engine)
    max_wait_s: float = 0.05      # batch-formation timeout: the oldest
    # compatible request never waits longer than this for co-riders
    policy: str = "prefill-first"   # interleave: which action wins when
    # both a formable batch and a decodable group exist

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}, "
                             f"got {self.policy!r}")
        if self.max_batch < 1 or self.min_batch < 1:
            raise ValueError("max_batch and min_batch must be >= 1")
        if self.min_batch > self.max_batch:
            raise ValueError(f"min_batch={self.min_batch} > "
                             f"max_batch={self.max_batch}")


@dataclasses.dataclass
class Group:
    """A coalesced ragged batch: ``len(requests)`` live rows padded to
    ``padded_size`` slots on the engine's batch-block grid."""

    gid: int
    requests: List[Request]
    prompt_len: int
    max_gen: int
    padded_size: int
    formed_s: float
    state: str = G_PREFILL
    steps_done: int = 0           # decode steps completed so far

    @property
    def size(self) -> int:
        return len(self.requests)

    @property
    def pad_slots(self) -> int:
        return self.padded_size - self.size

    @property
    def remaining_steps(self) -> int:
        """Decode steps still owed (prefill supplies token 1 of max_gen)."""
        return max(self.max_gen - 1 - self.steps_done, 0)

    @property
    def active_requests(self) -> List[Request]:
        return [r for r in self.requests if r.state == ACTIVE]


@dataclasses.dataclass(frozen=True)
class Prefill:
    """Run one coalesced prefill for ``group`` (launch decision already
    taken: the member requests left the queue when this was returned)."""
    group: Group


@dataclasses.dataclass(frozen=True)
class Decode:
    """Run one decode step for ``group`` (every active member advances by
    one token)."""
    group: Group


Action = Union[Prefill, Decode]


class Scheduler:
    """The injectable-clock state machine; all methods take ``now``."""

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.cfg = config or SchedulerConfig()
        self._queue: List[Request] = []
        self._groups: Dict[int, Group] = {}
        self._next_gid = 0
        self.completed: List[Request] = []
        self.counters: Dict[str, int] = {
            "admitted": 0, "rejected": 0, "evicted": 0, "completed": 0,
            "prefill_batches": 0, "decode_steps": 0, "padded_slots": 0,
        }

    # -- introspection -------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        """Groups occupying the engine (prefilling or decoding)."""
        return sum(1 for g in self._groups.values() if g.state != G_DONE)

    @property
    def active_requests(self) -> int:
        return sum(len(g.active_requests) for g in self._groups.values()
                   if g.state != G_DONE)

    @property
    def pending(self) -> bool:
        """Work left: queued requests or undrained groups."""
        return bool(self._queue) or self.in_flight > 0

    def group(self, gid: int) -> Group:
        return self._groups[gid]

    # -- admission -----------------------------------------------------------

    def submit(self, req: Request, now: float) -> bool:
        """Admit ``req`` or shed it (queue-depth admission control).

        Returns True when admitted.  A shed request transitions straight to
        REJECTED and is counted — the caller surfaces ``serve.rejected``.
        """
        if req.state != QUEUED or req.admitted_s is not None:
            raise ValueError(f"request {req.rid} resubmitted in state "
                             f"{req.state!r}")
        if len(self._queue) >= self.cfg.max_queue_depth:
            req.state = REJECTED
            self.counters["rejected"] += 1
            return False
        req.admitted_s = now
        self.counters["admitted"] += 1
        self._queue.append(req)
        return True

    # -- deadline eviction ---------------------------------------------------

    def _evict_expired(self, now: float) -> List[Request]:
        """Drop queued requests whose deadline passed before they were ever
        scheduled (they would burn a prefill slot to produce a late
        answer); active requests are evicted at the next step boundary in
        :meth:`note_decode_done`."""
        evicted = [r for r in self._queue if r.expired(now)]
        if evicted:
            self._queue = [r for r in self._queue if not r.expired(now)]
            for r in evicted:
                r.state = EVICTED
                r.finish_s = now
                self.counters["evicted"] += 1
        return evicted

    # -- coalescing ----------------------------------------------------------

    def _formable(self, now: float) -> List[Request]:
        """The FIFO-ordered same-shape set a prefill would coalesce now
        (empty when the batch should keep waiting for co-riders).

        Keyed on the *head* request's shape: strict FIFO across shapes
        (the head is never overtaken by a younger, more popular shape),
        shape-keyed FIFO within one (same-shape co-riders may ride the
        head's batch past older incompatible requests — they join its
        call, they do not displace it).
        """
        if not self._queue or self.in_flight >= self.cfg.max_in_flight:
            return []
        key = self._queue[0].shape_key
        ready = [r for r in self._queue if r.shape_key == key]
        ready = ready[:self.cfg.max_batch]
        full = len(ready) >= self.cfg.max_batch
        waited = now - ready[0].admitted_s >= self.cfg.max_wait_s
        idle = not self._decodable()
        if len(ready) >= self.cfg.min_batch or full or waited or idle:
            return ready
        return []

    def _form_group(self, ready: List[Request], now: float) -> Group:
        taken = set(id(r) for r in ready)
        self._queue = [r for r in self._queue if id(r) not in taken]
        gid = self._next_gid
        self._next_gid += 1
        group = Group(gid=gid, requests=list(ready),
                      prompt_len=ready[0].prompt_len,
                      max_gen=max(r.gen_len for r in ready),
                      padded_size=padded_batch(len(ready)),
                      formed_s=now)
        for r in ready:
            r.state = ACTIVE
            r.group_id = gid
            r.prefill_start_s = now
        self._groups[gid] = group
        self.counters["prefill_batches"] += 1
        self.counters["padded_slots"] += group.pad_slots
        return group

    def _decodable(self) -> Optional[Group]:
        """Oldest group with decode work left (FIFO over groups)."""
        for gid in sorted(self._groups):
            g = self._groups[gid]
            if g.state == G_DECODE and g.remaining_steps > 0 \
                    and g.active_requests:
                return g
        return None

    # -- the decision point --------------------------------------------------

    def poll(self, now: float) -> Optional[Action]:
        """Next engine action, or None when idle.

        Deadline-expired queued requests are evicted first.  Then the
        interleave policy arbitrates: ``prefill-first`` admits new work as
        soon as a batch is formable (lower TTFT, decode steps yield),
        ``decode-first`` drains in-flight tokens before growing the working
        set (lower per-token jitter, batches form fatter while waiting).
        Either way a formable batch fires when it is full, when its head
        request has waited ``max_wait_s``, or when the engine would
        otherwise idle — and a decodable group runs when no batch fires.
        """
        self._evict_expired(now)
        ready = self._formable(now)
        dec = self._decodable()
        if self.cfg.policy == "prefill-first":
            if ready:
                return Prefill(self._form_group(ready, now))
            if dec is not None:
                return Decode(dec)
        else:   # decode-first
            if dec is not None:
                return Decode(dec)
            if ready:
                return Prefill(self._form_group(ready, now))
        return None

    # -- completion callbacks ------------------------------------------------

    def _finish(self, req: Request, now: float) -> None:
        req.state = DONE
        req.finish_s = now
        self.completed.append(req)
        self.counters["completed"] += 1

    def note_prefill_done(self, gid: int, now: float) -> List[Request]:
        """Prefill landed: every member has its first token.  Returns the
        requests that finished outright (``gen_len == 1``)."""
        group = self._groups[gid]
        if group.state != G_PREFILL:
            raise ValueError(f"group {gid} not awaiting prefill "
                             f"(state {group.state!r})")
        finished = []
        for r in group.requests:
            r.first_token_s = now
            if r.gen_len <= 1:
                self._finish(r, now)
                finished.append(r)
        group.state = G_DECODE
        if group.remaining_steps == 0 or not group.active_requests:
            group.state = G_DONE
        return finished

    def note_decode_done(self, gid: int, now: float) -> List[Request]:
        """One decode step landed: every active member gained one token.
        Early-exits members whose budget is met, evicts deadline-expired
        ones, and drains the group at ``max_gen``.  Returns the requests
        that finished this step (DONE ones only; evictions are counted but
        not returned — their tokens were already short)."""
        group = self._groups[gid]
        if group.state != G_DECODE:
            raise ValueError(f"group {gid} not decoding "
                             f"(state {group.state!r})")
        group.steps_done += 1
        self.counters["decode_steps"] += 1
        finished = []
        for r in group.active_requests:
            if r.gen_len <= 1 + group.steps_done:
                self._finish(r, now)
                finished.append(r)
            elif r.expired(now):
                r.state = EVICTED
                r.finish_s = now
                self.counters["evicted"] += 1
        if group.remaining_steps == 0 or not group.active_requests:
            group.state = G_DONE
        return finished
