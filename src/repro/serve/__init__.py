"""Continuous-batching serving layer (docs/serving.md).

Split along the JAX boundary:

  * :mod:`repro.serve.session` / :mod:`repro.serve.scheduler` — pure
    Python request lifecycle and the injectable-clock scheduling state
    machine (admission, shape-keyed coalescing, prefill/decode
    interleave).  No JAX anywhere in the import chain, so the whole
    policy surface unit-tests with a fake clock.
  * :mod:`repro.serve.queue` — the device half: ``ServeQueue`` turns
    scheduler actions into coalesced ``dist/step.py`` prefill/decode
    calls through a warm ``ExecutorPool``, with obs latency accounting
    and the PR 7–8 fault/retry/degraded paths intact.

``launch/serve.py`` is the CLI over this package; the closed-loop load
benchmark is ``benchmarks/serve_traffic.py``.
"""
from .scheduler import (MAX_BATCH_BLOCK, POLICIES, Decode, Group, Prefill,
                        Scheduler, SchedulerConfig, batch_block,
                        padded_batch)
from .session import (ACTIVE, DONE, EVICTED, QUEUED, REJECTED,
                      TERMINAL_STATES, Request, make_request)

__all__ = [
    "Scheduler", "SchedulerConfig", "Group", "Prefill", "Decode",
    "batch_block", "padded_batch", "MAX_BATCH_BLOCK", "POLICIES",
    "Request", "make_request", "QUEUED", "ACTIVE", "DONE", "REJECTED",
    "EVICTED", "TERMINAL_STATES",
]
