"""The device half of continuous batching: coalesced engine calls.

``ServeQueue`` joins the pure scheduler (``repro.serve.scheduler``) to the
compiled prefill/decode halves from ``repro.dist.step``:

  * **ragged batching** — a :class:`~repro.serve.scheduler.Group`'s live
    requests are stacked on the batch axis and zero-padded to the engine's
    batch-block grid (``scheduler.padded_batch``, the pure mirror of
    ``kernels/engine.py``); batch rows are independent in every model
    family, so padding is exact — the pad rows' outputs are simply dropped
    (``tests/test_serve_batching.py`` pins coalesced == sequential).
  * **warm executor pool** — :class:`ExecutorPool` builds and caches one
    ``(prefill_fn, serve_fn)`` pair per ``(padded_batch, prompt_len,
    max_len)`` shape bucket, so steady-state traffic never pays a jit
    compile; ``warm()`` pays them before traffic (the compiled-function
    analogue of the tuner's plan-cache ``prewarm``).
  * **two clocks** — scheduling decisions run on the injectable ``clock``
    (virtual in the deterministic load benchmark), latency *accounting*
    always on the wall clock: per-request ``serve.request_us`` /
    ``serve.ttft_us`` / ``serve.prefill_us`` / ``serve.decode_token_us``
    obs histograms plus ``serve.queue_depth`` / ``serve.in_flight`` gauges
    and ``serve.rejected`` / ``serve.evicted`` counters.
  * **resilience** — every engine call passes the ``serve.prefill`` /
    ``serve.step`` fault points and retries with backoff under the
    degraded-mode accounting PR 8 introduced (docs/robustness.md).

Sampling is host-side and *batch-composition independent*: greedy argmax,
or for ``temperature > 0`` a per-request Gumbel draw seeded by
``(seed, rid, token_index)`` — the same request yields the same tokens
whether it rode a coalesced batch or ran alone, which is what makes the
parity tests (and cross-mode benchmark comparisons) meaningful.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .scheduler import (G_DONE, Decode, Group, Prefill, Scheduler,
                        SchedulerConfig, padded_batch)
from .session import DONE, Request, make_request

__all__ = ["ServeQueue", "ExecutorPool", "pad_cache", "sample_token",
           "DEFAULT_LEN_QUANTUM"]

# Decode-capacity quantum: a group's cache length is its prompt plus
# max_gen rounded up to this, so nearby generation budgets share one
# compiled (batch, prompt, max_len) executor instead of each paying a jit.
DEFAULT_LEN_QUANTUM = 8


def pad_cache(cfg, cache, max_len: int):
    """Grow the prefill cache's sequence axis to ``max_len`` (headroom for
    decode).  Window-capped and state caches are already final-size."""
    import jax
    import jax.numpy as jnp

    def leaf(path, x):
        names = [getattr(k, "key", str(k)) for k in path]
        if names[-1] in ("k", "v") and x.ndim == 5:
            cap = max_len
            if cfg.sliding_window:
                cap = min(max_len, cfg.sliding_window)
            if x.shape[2] < cap:
                pad = [(0, 0)] * 5
                pad[2] = (0, cap - x.shape[2])
                return jnp.pad(x, pad)
        return x
    return jax.tree_util.tree_map_with_path(leaf, cache)


def sample_token(logits_row: np.ndarray, *, temperature: float, seed: int,
                 rid: int, index: int) -> int:
    """Sample one token from a single request's logits row.

    Greedy at ``temperature <= 0``; otherwise a Gumbel-max draw whose
    randomness is a pure function of ``(seed, rid, index)`` — never of the
    batch the row rode in — so batched and sequential execution of the same
    request emit identical streams (the parity contract)."""
    row = np.asarray(logits_row, np.float64)
    if temperature <= 0:
        return int(np.argmax(row))
    rng = np.random.default_rng([abs(int(seed)), int(rid), int(index)])
    u = rng.random(row.shape[0])
    gumbel = -np.log(-np.log(u + 1e-20) + 1e-20)
    return int(np.argmax(row / temperature + gumbel))


@dataclasses.dataclass
class _Bundle:
    """One compiled shape cell: ``(padded_batch, prompt_len, max_len)``."""

    prefill_fn: Callable
    serve_fn: Callable
    batch: int
    prompt_len: int
    max_len: int            # prompt + decode capacity (pre-frontend-prefix)
    extra_prefix: int       # vision patch prefix shifting absolute positions
    extras: Dict[str, Any]  # frontend stub inputs for this batch size


class ExecutorPool:
    """Build-once cache of jitted prefill/decode pairs per shape bucket.

    The serving analogue of the tuner's warm plan cache: a bucket is built
    (and optionally :meth:`warm`\\ ed — compiled *and* executed once) ahead
    of traffic, after which every group landing in it is dispatch-only.
    """

    def __init__(self, cfg, mesh, params, *, obs=None, recorder=None):
        self.cfg = cfg
        self.mesh = mesh
        self.params = params
        self.obs = obs
        self.recorder = recorder
        self._bundles: Dict[Tuple[int, int, int], _Bundle] = {}
        self.builds = 0

    def _extras(self, batch: int) -> Dict[str, Any]:
        from ..models import frontends
        cfg = self.cfg
        if cfg.frontend == "vision_stub":
            return {"patches": frontends.vision_patches_stub(cfg, batch)}
        if cfg.frontend == "audio_stub":
            return {"frames": frontends.audio_frames_stub(cfg, batch)}
        return {}

    def bundle(self, batch: int, prompt_len: int, max_len: int) -> _Bundle:
        key = (batch, prompt_len, max_len)
        hit = self._bundles.get(key)
        if hit is not None:
            return hit
        import jax
        import jax.numpy as jnp

        from ..dist import step as step_lib
        from ..models import api

        cfg = self.cfg
        extras = self._extras(batch)
        pav = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.params)
        bav = {"tokens": jax.ShapeDtypeStruct((batch, prompt_len),
                                              jnp.int32)}
        bav.update({k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                    for k, v in extras.items()})
        prefill_fn, _, _ = step_lib.build_prefill(
            cfg, self.mesh, pav, bav, obs=self.obs, recorder=self.recorder)
        extra = cfg.num_patches if cfg.frontend == "vision_stub" else 0
        cache_avals, _ = jax.eval_shape(
            lambda p, b: api.prefill(cfg, p, b), pav, bav)
        padded_avals = jax.eval_shape(
            lambda c: pad_cache(cfg, c, max_len + extra), cache_avals)
        serve_fn, _, _ = step_lib.build_serve_step(
            cfg, self.mesh, pav, padded_avals, obs=self.obs,
            recorder=self.recorder)
        b = _Bundle(prefill_fn=prefill_fn, serve_fn=serve_fn, batch=batch,
                    prompt_len=prompt_len, max_len=max_len,
                    extra_prefix=extra, extras=extras)
        self._bundles[key] = b
        self.builds += 1
        return b

    def warm(self, shapes: Sequence[Tuple[int, int, int]]) -> int:
        """Compile AND execute each ``(batch, prompt_len, max_len)`` cell
        once on dummy tokens, so the first real request in the bucket pays
        dispatch cost only.  Returns the number of cells warmed."""
        import jax
        import jax.numpy as jnp
        n = 0
        for batch, prompt_len, max_len in dict.fromkeys(shapes):
            b = self.bundle(padded_batch(batch), prompt_len, max_len)
            toks = jnp.zeros((b.batch, b.prompt_len), jnp.int32)
            cache, logits = b.prefill_fn(self.params,
                                         {"tokens": toks, **b.extras})
            cache = pad_cache(self.cfg, cache, b.max_len + b.extra_prefix)
            step_toks = jnp.zeros((b.batch, 1), jnp.int32)
            pos = jnp.int32(b.prompt_len + b.extra_prefix)
            cache, logits = b.serve_fn(self.params, cache, step_toks, pos)
            jax.block_until_ready(logits)
            n += 1
        return n

    def __len__(self) -> int:
        return len(self._bundles)


@dataclasses.dataclass
class _GroupRuntime:
    """Device-side state of an in-flight group between engine calls."""

    bundle: _Bundle
    cache: Any
    toks: Any               # (padded_batch, 1) int32 — next step's inputs
    pos0: int               # absolute position of the first decode write


class ServeQueue:
    """Continuous-batching front end over the compiled serving halves."""

    def __init__(self, cfg, mesh, params, *,
                 scheduler: Optional[Scheduler] = None,
                 config: Optional[SchedulerConfig] = None,
                 pool: Optional[ExecutorPool] = None,
                 obs=None, recorder=None,
                 clock: Callable[[], float] = time.perf_counter,
                 temperature: float = 0.0, seed: int = 0,
                 len_quantum: int = DEFAULT_LEN_QUANTUM,
                 retry_kw: Optional[Dict[str, Any]] = None,
                 record_logits: bool = False):
        if scheduler is not None and config is not None:
            raise ValueError("pass scheduler= or config=, not both")
        self.cfg = cfg
        self.params = params
        self.sched = scheduler or Scheduler(config)
        # NB: not `pool or ...` — an empty ExecutorPool is falsy (__len__)
        self.pool = pool if pool is not None else \
            ExecutorPool(cfg, mesh, params, obs=obs, recorder=recorder)
        self.obs = obs
        self.clock = clock
        self.temperature = float(temperature)
        self.seed = int(seed)
        self.len_quantum = max(int(len_quantum), 1)
        self.retry_kw = dict(retry_kw) if retry_kw else {"retries": 0}
        self.record_logits = record_logits
        self.logits_log: Dict[int, List[np.ndarray]] = {}
        self.completed: List[Request] = []
        self._rt: Dict[int, _GroupRuntime] = {}
        self._seen = {k: 0 for k in ("rejected", "evicted")}

    # -- obs plumbing --------------------------------------------------------

    def _hist(self, name: str):
        return self.obs.histogram(name) if self.obs is not None else None

    def _observe(self, name: str, us: float, n: int = 1) -> None:
        h = self._hist(name)
        if h is not None:
            for _ in range(max(n, 1)):
                h.observe(us)

    def _sync_counters(self) -> None:
        """Mirror scheduler-side sheds/evictions into obs counters (delta
        sync: the scheduler is obs-free by design) and refresh gauges."""
        if self.obs is None:
            return
        for key, metric in (("rejected", "serve.rejected"),
                            ("evicted", "serve.evicted")):
            delta = self.sched.counters[key] - self._seen[key]
            if delta > 0:
                self.obs.counter(metric).inc(delta)
                self._seen[key] = self.sched.counters[key]
        self.obs.gauge("serve.queue_depth").set(self.sched.queue_depth)
        self.obs.gauge("serve.in_flight").set(self.sched.in_flight)

    # -- submission ----------------------------------------------------------

    def submit(self, prompt: Sequence[int], gen_len: int, *,
               deadline_s: Optional[float] = None,
               now: Optional[float] = None,
               rid: Optional[int] = None) -> Request:
        """Admit one request (or shed it: ``req.state == REJECTED``).

        ``now`` defaults to the scheduling clock; pass an explicit value
        when driving a virtual timeline.  ``deadline_s`` is absolute on
        that same clock.  ``rid`` pins the request id — the sampling stream
        is keyed on ``(seed, rid, token_index)``, so pinning it makes the
        same request reproducible across queues (the parity tests run one
        request through a batched and a sequential queue and compare token
        streams).
        """
        now = self.clock() if now is None else now
        req = make_request(prompt=prompt, gen_len=gen_len, now=now,
                           deadline_s=deadline_s, rid=rid)
        req.wall_arrival_s = time.perf_counter()
        self.sched.submit(req, now)
        self._sync_counters()
        return req

    # -- group execution -----------------------------------------------------

    def _max_len(self, group: Group) -> int:
        q = self.len_quantum
        return group.prompt_len + -(-group.max_gen // q) * q

    def _sample_rows(self, logits: np.ndarray, group: Group,
                     index_of: Callable[[Request], int]) -> np.ndarray:
        """Next-token column for every slot; live rows sample per-request,
        pad rows (whose outputs are discarded) take the argmax."""
        toks = np.zeros((logits.shape[0], 1), np.int32)
        for i in range(logits.shape[0]):
            if i < group.size:
                r = group.requests[i]
                toks[i, 0] = sample_token(
                    logits[i], temperature=self.temperature, seed=self.seed,
                    rid=r.rid, index=index_of(r))
            else:
                toks[i, 0] = int(np.argmax(logits[i]))
        return toks

    def _run_prefill(self, group: Group, now: float) -> List[Request]:
        import jax
        import jax.numpy as jnp

        from ..resilience.fallback import retry_with_backoff
        from ..resilience.inject import fault_point

        bundle = self.pool.bundle(group.padded_size, group.prompt_len,
                                  self._max_len(group))
        tokens = np.zeros((group.padded_size, group.prompt_len), np.int32)
        for i, r in enumerate(group.requests):
            tokens[i] = np.asarray(r.prompt, np.int32)
        batch = {"tokens": jnp.asarray(tokens), **bundle.extras}

        def call():
            # the fault point fires BEFORE the jitted call, so a retried
            # prefill never reuses a consumed buffer
            fault_point("serve.prefill")
            return bundle.prefill_fn(self.params, batch)

        t0 = time.perf_counter()
        cache, logits = retry_with_backoff(call, **self.retry_kw)
        jax.block_until_ready(logits)
        dt_us = (time.perf_counter() - t0) * 1e6
        cache = pad_cache(self.cfg, cache,
                          bundle.max_len + bundle.extra_prefix)
        logits_np = np.asarray(logits)
        wall = time.perf_counter()
        toks = self._sample_rows(logits_np, group, lambda r: 0)
        for i, r in enumerate(group.requests):
            r.tokens.append(int(toks[i, 0]))
            r.wall_first_token_s = wall
            if self.record_logits:
                self.logits_log.setdefault(r.rid, []).append(
                    logits_np[i].copy())
        # Every rider experienced the coalesced call's latency — one
        # observation per request, the accounting admission control reads.
        self._observe("serve.prefill_us", dt_us, group.size)
        if self.obs is not None:
            self.obs.counter("serve.requests").inc(group.size)
            self.obs.counter("serve.prefill_calls").inc()
        finished = self.sched.note_prefill_done(group.gid, now)
        self._note_finished(finished, wall)
        if group.state != G_DONE:
            self._rt[group.gid] = _GroupRuntime(
                bundle=bundle, cache=cache, toks=jnp.asarray(toks),
                pos0=group.prompt_len + bundle.extra_prefix)
        return finished

    def _run_decode(self, group: Group, now: float) -> List[Request]:
        import jax
        import jax.numpy as jnp

        from ..resilience.fallback import retry_with_backoff
        from ..resilience.inject import fault_point

        rt = self._rt[group.gid]
        pos = jnp.int32(rt.pos0 + group.steps_done)

        def call(cache, toks, pos):
            fault_point("serve.step")
            return rt.bundle.serve_fn(self.params, cache, toks, pos)

        was_active = list(group.active_requests)
        t0 = time.perf_counter()
        cache, logits = retry_with_backoff(call, rt.cache, rt.toks, pos,
                                           **self.retry_kw)
        jax.block_until_ready(logits)
        dt_us = (time.perf_counter() - t0) * 1e6
        logits_np = np.asarray(logits)
        wall = time.perf_counter()
        step_index = group.steps_done + 1   # token index this step emits
        toks = self._sample_rows(logits_np, group, lambda r: step_index)
        for i, r in enumerate(group.requests):
            if r in was_active:
                r.tokens.append(int(toks[i, 0]))
                if self.record_logits:
                    self.logits_log.setdefault(r.rid, []).append(
                        logits_np[i].copy())
        rt.cache, rt.toks = cache, jnp.asarray(toks)
        # per-token decode latency: the step's wall clock is what every
        # still-active rider waited for its next token
        self._observe("serve.decode_token_us", dt_us, len(was_active))
        if self.obs is not None:
            self.obs.counter("serve.decode_calls").inc()
        finished = self.sched.note_decode_done(group.gid, now)
        self._note_finished(finished, wall)
        if group.state == G_DONE:
            self._rt.pop(group.gid, None)
        return finished

    def _note_finished(self, finished: List[Request], wall: float) -> None:
        for r in finished:
            r.wall_finish_s = wall
            if r.state == DONE:
                self.completed.append(r)
                if r.wall_e2e_s is not None:
                    self._observe("serve.request_us", r.wall_e2e_s * 1e6)
                if r.wall_ttft_s is not None:
                    self._observe("serve.ttft_us", r.wall_ttft_s * 1e6)

    # -- the drive loop ------------------------------------------------------

    @property
    def pending(self) -> bool:
        return self.sched.pending

    def step(self, now: Optional[float] = None) -> bool:
        """Run the scheduler's next engine action (one coalesced prefill or
        one decode step); returns False when the engine would idle."""
        now = self.clock() if now is None else now
        action = self.sched.poll(now)
        if action is None:
            self._sync_counters()
            return False
        if isinstance(action, Prefill):
            self._run_prefill(action.group, now)
        elif isinstance(action, Decode):
            self._run_decode(action.group, now)
        self._sync_counters()
        return True

    def drain(self, max_steps: int = 1_000_000) -> List[Request]:
        """Step until idle (bounded by ``max_steps``); returns every
        request completed so far, submission order preserved."""
        steps = 0
        while self.pending and steps < max_steps:
            if not self.step():
                break
            steps += 1
        return list(self.completed)
