"""Per-request serving state: lifecycle, shape key, latency accounting.

A :class:`Request` is the unit the continuous-batching layer schedules.
This module is deliberately **JAX-free** (so is ``scheduler.py``): the whole
policy surface — admission, coalescing, fairness, deadlines — is plain
Python over these records, unit-testable with a fake clock and no arrays in
sight (``tests/test_serve_queue.py`` imports neither ``jax`` nor the queue
layer).  The queue layer (``repro.serve.queue``) owns everything that
touches devices and fills in the token/wall-clock fields here.

Two time domains, two sets of fields:

  * ``*_s`` — the **scheduler clock** (whatever ``now`` the caller passes:
    wall seconds in production, a fake or virtual clock in tests and the
    deterministic load benchmark).  Every scheduling decision — admission,
    batch-formation timeouts, deadline eviction — reads only these.
  * ``wall_*_s`` — the **wall clock**, stamped by the queue layer around
    real engine calls.  Latency *reporting* (p50/p99 request latency,
    time-to-first-token, the ``serve.*`` obs histograms) reads only these,
    so a virtually-clocked benchmark still reports real latencies.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

__all__ = ["Request", "make_request", "QUEUED", "ACTIVE", "DONE",
           "REJECTED", "EVICTED", "TERMINAL_STATES"]

# Request lifecycle.  QUEUED -> ACTIVE (group formed, prefill launched) ->
# DONE; QUEUED -> REJECTED (admission shed) | EVICTED (deadline passed);
# ACTIVE -> EVICTED (deadline passed mid-decode: the slot idles, the group
# keeps stepping for its remaining members).
QUEUED = "queued"
ACTIVE = "active"
DONE = "done"
REJECTED = "rejected"
EVICTED = "evicted"
TERMINAL_STATES = (DONE, REJECTED, EVICTED)

_RID = itertools.count()


@dataclasses.dataclass(eq=False)   # identity semantics: the scheduler and
# queue track requests by object, never by field equality
class Request:
    """One generation request: a prompt, a token budget, and the lifecycle
    timestamps the latency-accounting contract (docs/serving.md) promises."""

    rid: int
    prompt_len: int
    gen_len: int                      # total tokens wanted (>= 1; the first
    # comes out of the coalesced prefill, the rest out of decode steps)
    arrival_s: float                  # scheduler clock at submit()
    deadline_s: Optional[float] = None   # absolute scheduler-clock deadline
    prompt: Optional[Tuple[int, ...]] = None   # token ids; None = metadata-
    # only request (pure scheduler tests never materialise tokens)
    state: str = QUEUED

    # scheduler-clock milestones (set by repro.serve.scheduler)
    admitted_s: Optional[float] = None
    prefill_start_s: Optional[float] = None
    first_token_s: Optional[float] = None
    finish_s: Optional[float] = None

    # wall-clock milestones (set by repro.serve.queue around engine calls)
    wall_arrival_s: Optional[float] = None
    wall_first_token_s: Optional[float] = None
    wall_finish_s: Optional[float] = None

    # outputs (filled by the queue layer)
    tokens: List[int] = dataclasses.field(default_factory=list)
    group_id: Optional[int] = None

    # -- shape / scheduling --------------------------------------------------

    @property
    def shape_key(self) -> Tuple[int, ...]:
        """Coalescing key: requests sharing it may ride one ragged batch.

        Only the prompt length participates — batch rows are independent in
        every model family, so ragged *batch* padding is exact, but ragged
        *sequence* padding is not (causal attention sees pad positions), so
        mixed prompt lengths never share a prefill call.  Mixed ``gen_len``
        within a group is fine: short requests exit early and their slot
        idles until the group drains.
        """
        return (self.prompt_len,)

    def expired(self, now: float) -> bool:
        return self.deadline_s is not None and now > self.deadline_s

    # -- latency accounting (scheduler clock) --------------------------------

    @property
    def queue_wait_s(self) -> Optional[float]:
        if self.prefill_start_s is None or self.admitted_s is None:
            return None
        return self.prefill_start_s - self.admitted_s

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def e2e_s(self) -> Optional[float]:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s

    # -- latency accounting (wall clock; what the obs histograms carry) ------

    @property
    def wall_ttft_s(self) -> Optional[float]:
        if self.wall_first_token_s is None or self.wall_arrival_s is None:
            return None
        return self.wall_first_token_s - self.wall_arrival_s

    @property
    def wall_e2e_s(self) -> Optional[float]:
        if self.wall_finish_s is None or self.wall_arrival_s is None:
            return None
        return self.wall_finish_s - self.wall_arrival_s

    @property
    def tokens_generated(self) -> int:
        return len(self.tokens)

    @property
    def finished(self) -> bool:
        return self.state in TERMINAL_STATES


def make_request(*, prompt: Optional[Sequence[int]] = None,
                 prompt_len: Optional[int] = None, gen_len: int = 1,
                 now: float = 0.0, deadline_s: Optional[float] = None,
                 rid: Optional[int] = None) -> Request:
    """Build a :class:`Request`; either concrete ``prompt`` token ids or a
    bare ``prompt_len`` (scheduler-only tests).  ``gen_len`` counts the
    total tokens generated, prefill's first token included."""
    if prompt is None and prompt_len is None:
        raise ValueError("need prompt token ids or an explicit prompt_len")
    if prompt is not None:
        prompt = tuple(int(t) for t in prompt)
        if prompt_len is not None and prompt_len != len(prompt):
            raise ValueError(f"prompt_len={prompt_len} contradicts "
                             f"len(prompt)={len(prompt)}")
        prompt_len = len(prompt)
    if prompt_len <= 0:
        raise ValueError(f"prompt_len must be positive, got {prompt_len}")
    if gen_len < 1:
        raise ValueError(f"gen_len must be >= 1, got {gen_len}")
    return Request(rid=next(_RID) if rid is None else rid,
                   prompt_len=int(prompt_len), gen_len=int(gen_len),
                   arrival_s=float(now), deadline_s=deadline_s,
                   prompt=prompt)
