"""Jitted, sharded, donating step functions for train / prefill / decode.

This is the device-level layer between the model API (pure functions over
pytrees) and the launch drivers: every entry point closes over a mesh, bakes
the :mod:`repro.dist.sharding` spec trees into ``jax.jit`` in/out shardings,
and donates the state it updates (params + optimizer for training, the KV
cache for decode), so a step is allocation-neutral.

Train-step data flow (one jitted call)::

    batch (n_mb, mb, ...)  -- data-sharded
      scan over microbatches:
        value_and_grad(train_loss)          # remat'd layer stack
        grads -> flat fp32 (n_dev, cols)    # adamw.to_flat
              -> constrain to P(all_axes)   # ZeRO reduce-scatter point
        accumulate in the flat layout       # |params|*4/n_dev bytes
      adamw.apply_updates                   # elementwise on local shards
        -> unflatten + constrain to param specs   # ZeRO all-gather point

Gradient accumulation therefore never materialises a replicated fp32
gradient: each microbatch's reduce-scatter overlaps the next microbatch's
compute under the XLA latency-hiding scheduler (see ``optim/adamw.py``).

All functions accept abstract avals (``jax.ShapeDtypeStruct`` trees) for
params/batches/caches, so the dry-run can ``.lower().compile()`` every
(arch x shape x mesh) cell without allocating anything.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..launch.mesh import dp_axes
from ..models import api
from ..optim import adamw
from ..optim.adamw import OptConfig
from . import sharding as shr

__all__ = ["StepBundle", "default_microbatches", "build_train_step",
           "build_prefill", "build_serve_step", "loops_cotangent_psum"]

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class StepBundle:
    """A compiled-on-first-call train step plus the spec trees a driver needs
    to place (or restore) the state it feeds in.

    ``fn(params, opt_state, batch) -> (params, opt_state, metrics)`` with
    ``params``/``opt_state`` donated; ``metrics`` carries scalar ``loss``,
    ``grad_norm``, ``lr`` and ``tokens``.
    """

    fn: Any                    # jitted step
    param_spec: Any            # PartitionSpec tree for params
    opt_spec: Any              # PartitionSpec tree for optimizer state
    batch_spec: Any            # PartitionSpec tree for the global batch
    n_microbatches: int


def loops_cotangent_psum(partial_db: jax.Array, axis) -> jax.Array:
    """Row-shard-aware reduction of the dense-operand cotangent of a
    distributed LOOPS SpMM.

    Forward, ``B`` enters the ``shard_map`` replicated
    (:func:`repro.dist.sharding.loops_in_specs`'s trailing ``P()``) while
    the workload is row-sharded over the SpMM worker axis.  The transpose of
    "replicate, then use on every shard" is "sum the per-shard cotangents":
    each device owns an exclusive row slice of ``dY`` (paper §3.4 row
    exclusivity), computes its partial ``Aᵀ_shard · dY_shard``, and this
    psum over the worker axis produces the full ``dB`` — replicated again,
    matching B's forward spec, so the gradient of a replicated operand never
    leaves the mesh in a mixed layout.  ``axis`` is a mesh axis name or a
    tuple of names (the flattened-pod spelling accepted everywhere else in
    :mod:`repro.dist.sharding`).
    """
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    return jax.lax.psum(partial_db, axes)


def default_microbatches(shape: ShapeConfig, mesh: Mesh,
                         per_device_batch: int = 4) -> int:
    """Pick a microbatch count for a train cell.

    Targets ``per_device_batch`` sequences per data-parallel worker per
    microbatch, then walks down until the count divides the global batch AND
    the resulting microbatch divides evenly over the data axes (shard_map-
    clean even though the jit path tolerates padding).
    """
    dp = shr.dp_size(mesh)
    n_mb = max(shape.global_batch // max(dp * per_device_batch, 1), 1)
    while n_mb > 1 and (shape.global_batch % n_mb
                        or (shape.global_batch // n_mb) % dp):
        n_mb -= 1
    return n_mb


def _flat_zeros(params_avals, n_shards: int):
    """Zero accumulator in the flat fp32 layout (matches ``adamw.to_flat``)."""
    return jax.tree.map(
        lambda x: jnp.zeros((n_shards, math.ceil(x.size / n_shards)), F32),
        params_avals)


def _maybe_record(fn, recorder, op: str, obs=None):
    """Wrap a jitted step fn with the perf-trace recorder and/or a live
    obs capture (no-op without either).  ``recorder``
    (``repro.perf.trace.TraceRecorder.wrap_step``) lands one ``step``
    trace record per call; ``obs`` (``repro.obs.Obs.wrap_step``) runs the
    call under a span and feeds the ``step.wall_us{op=...}`` latency
    histogram.  Both block on the outputs; obs wraps outermost so its span
    brackets the recorder's timing too."""
    if recorder is not None:
        fn = recorder.wrap_step(fn, op=op)
    if obs is not None:
        fn = obs.wrap_step(fn, op=op)
    return fn


def build_train_step(cfg: ModelConfig, mesh: Mesh, params_avals, batch_avals,
                     opt: OptConfig, *, n_microbatches: int = 1,
                     loss_fn: Callable | None = None,
                     recorder=None, obs=None) -> StepBundle:
    """Build the jitted grad-accumulating ZeRO-1 train step for ``cfg``.

    ``loss_fn(params, microbatch) -> (loss, aux)`` defaults to the family-
    dispatched ``models.api.train_loss``.  ``recorder`` — a
    :class:`repro.perf.trace.TraceRecorder` — wraps the returned step so
    every call appends a per-step wall-clock trace record; ``obs`` — a
    :class:`repro.obs.Obs` — additionally spans each call and feeds the
    live ``step.wall_us{op=train_step}`` latency histogram.
    """
    loss_fn = loss_fn or (lambda p, mb: api.train_loss(cfg, p, mb))
    p_spec = shr.param_specs(params_avals, mesh, cfg)
    o_spec = shr.opt_specs(params_avals, mesh)
    b_spec = shr.train_batch_specs(batch_avals, mesh)
    g_spec = shr.flat_grad_specs(params_avals, mesh)
    n_shards = math.prod(mesh.shape.values())
    n_mb = n_microbatches

    def step(params, opt_state, batch):
        def microbatch(carry, mb):
            g_acc, loss_sum, tok_sum = carry
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            # flat fp32 + all-axes constraint == the reduce-scatter point
            gflat = jax.tree.map(lambda g: adamw.to_flat(g, n_shards), grads)
            gflat = shr.constrain(gflat, mesh, g_spec)
            g_acc = jax.tree.map(jnp.add, g_acc, gflat)
            return (g_acc, loss_sum + loss,
                    tok_sum + aux.get("tokens", 0.0)), None

        init = (_flat_zeros(params, n_shards), jnp.zeros((), F32),
                jnp.zeros((), F32))
        (g_acc, loss_sum, tok_sum), _ = jax.lax.scan(microbatch, init, batch)
        g_mean = jax.tree.map(lambda g: g / n_mb, g_acc)
        new_params, new_opt, gnorm = adamw.apply_updates(
            params, opt_state, g_mean, opt, p_spec, mesh)
        metrics = {"loss": loss_sum / n_mb, "grad_norm": gnorm,
                   "lr": adamw.lr_at(opt, new_opt["count"]),
                   "tokens": tok_sum}
        return new_params, new_opt, metrics

    psh = shr.spec_to_sharding(p_spec, mesh)
    osh = shr.spec_to_sharding(o_spec, mesh)
    bsh = shr.spec_to_sharding(b_spec, mesh)
    rep = NamedSharding(mesh, P())
    fn = jax.jit(step, in_shardings=(psh, osh, bsh),
                 out_shardings=(psh, osh, rep), donate_argnums=(0, 1))
    return StepBundle(fn=_maybe_record(fn, recorder, "train_step", obs),
                      param_spec=p_spec, opt_spec=o_spec,
                      batch_spec=b_spec, n_microbatches=n_mb)


def build_prefill(cfg: ModelConfig, mesh: Mesh, params_avals, batch_avals,
                  *, recorder=None, obs=None):
    """Jitted prefill: ``fn(params, batch) -> (cache, last_logits)``.

    Returns ``(fn, param_spec, cache_spec)``; the cache comes out already
    sharded per :func:`repro.dist.sharding.cache_specs`, so the decode step
    built against it never reshards.  ``recorder``/``obs`` trace per-call
    wall clock like :func:`build_train_step`.
    """
    p_spec = shr.param_specs(params_avals, mesh, cfg)
    b_spec = shr.prefill_batch_specs(batch_avals, mesh)

    def prefill(params, batch):
        return api.prefill(cfg, params, batch)

    cache_avals, _ = jax.eval_shape(prefill, params_avals, batch_avals)
    c_spec = shr.cache_specs(cache_avals, mesh, cfg)
    fn = jax.jit(
        prefill,
        in_shardings=(shr.spec_to_sharding(p_spec, mesh),
                      shr.spec_to_sharding(b_spec, mesh)),
        out_shardings=(shr.spec_to_sharding(c_spec, mesh),
                       NamedSharding(mesh, shr.logits_spec(mesh))))
    return _maybe_record(fn, recorder, "prefill", obs), p_spec, c_spec


def build_serve_step(cfg: ModelConfig, mesh: Mesh, params_avals, cache_avals,
                     *, recorder=None, obs=None):
    """Jitted single-token decode:
    ``fn(params, cache, tokens, length) -> (cache, logits)`` with the cache
    donated (decode is a pure cache update — the old buffers are dead).

    Returns ``(fn, param_spec, cache_spec)``.  ``recorder``/``obs`` trace
    per-call wall clock like :func:`build_train_step`.
    """
    p_spec = shr.param_specs(params_avals, mesh, cfg)
    c_spec = shr.cache_specs(cache_avals, mesh, cfg)
    rep = NamedSharding(mesh, P())
    tok_sh = NamedSharding(mesh, P(shr.data_axis(mesh), None))

    def decode(params, cache, tokens, length):
        return api.decode_step(cfg, params, cache, tokens, length)

    fn = jax.jit(
        decode,
        in_shardings=(shr.spec_to_sharding(p_spec, mesh),
                      shr.spec_to_sharding(c_spec, mesh), tok_sh, rep),
        out_shardings=(shr.spec_to_sharding(c_spec, mesh),
                       NamedSharding(mesh, shr.logits_spec(mesh))),
        donate_argnums=(1,))
    return _maybe_record(fn, recorder, "decode", obs), p_spec, c_spec
