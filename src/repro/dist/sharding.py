"""Mesh-axis conventions and ``NamedSharding`` builders for the whole system.

This module is the single place that knows how logical arrays map onto the
meshes from :mod:`repro.launch.mesh` (axes ``pod`` / ``data`` / ``model``).
Everything downstream — the train/serve step builders in
:mod:`repro.dist.step`, the launch drivers, the dry-run — consumes the
``PartitionSpec`` trees built here and never spells a mesh axis by hand.

Conventions
-----------
* **params** (:func:`param_specs`) — Megatron-style tensor parallelism over
  the ``model`` axis: column-split the up-projections (``wq``/``wk``/``wv``,
  MLP ``wi``/``wg``), row-split the down-projections (``wo``), vocab-split
  the (un)embeddings, expert-split stacked MoE weights.  The ``kv_aligned``
  TP rule (the §Perf default, ablated in ``tests/test_perf_variants.py``)
  replicates any projection whose head count does not divide the model axis,
  so attention stays device-local; ``tp_rule="naive"`` shards blindly.
* **activations / batches** (:func:`train_batch_specs`,
  :func:`prefill_batch_specs`) — batch dim over the data-parallel axes
  (``('pod', 'data')`` on multi-pod meshes), everything else unconstrained.
* **KV-cache** (:func:`cache_specs`) — ``(L, B, S, KV, hd)`` leaves carry the
  batch dim on the data axes and the KV-head dim on ``model`` when aligned;
  SSM/RWKV state leaves shard on batch only.
* **optimizer** — flat ZeRO-1 shards over *all* axes; the spec lives in
  :func:`repro.optim.adamw.opt_specs`, re-exported here, and
  :func:`flat_grad_specs` gives the matching gradient layout (the
  reduce-scatter point of the ZeRO schedule).
* **LOOPS operands** (:func:`loops_in_specs`, :func:`loops_shardings`) — the
  device-stacked :class:`repro.core.distributed.ShardedLoops` arrays are
  row-sharded (leading device axis) over the SpMM worker axis, composing the
  paper's CSR-part/BCSR-part device-group split with mesh sharding; ``B`` is
  replicated, matching the paper's broadcast of the dense operand.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..launch.mesh import dp_axes, flat_axes
from ..optim.adamw import opt_specs  # noqa: F401  (re-export: one spec home)

__all__ = [
    "model_axis", "model_size", "data_axis", "dp_size",
    "param_specs", "train_batch_specs", "prefill_batch_specs", "cache_specs",
    "logits_spec", "flat_grad_specs", "opt_specs",
    "spec_to_sharding", "constrain",
    "loops_in_specs", "loops_out_spec", "loops_shardings",
]


# ---------------------------------------------------------------------------
# axis helpers
# ---------------------------------------------------------------------------

def model_axis(mesh) -> str | None:
    """The tensor-parallel axis name, or None on a mesh without one."""
    return "model" if "model" in mesh.axis_names else None


def model_size(mesh) -> int:
    m = model_axis(mesh)
    return mesh.shape[m] if m else 1


def data_axis(mesh):
    """The data-parallel PartitionSpec entry: one name, or a tuple of names
    (``('pod', 'data')``) that flattens all replica axes into one dim."""
    axes = dp_axes(mesh)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def dp_size(mesh) -> int:
    return math.prod(mesh.shape[a] for a in dp_axes(mesh))


def _path_names(path) -> list:
    return [getattr(k, "key", getattr(k, "name", str(k))) for k in path]


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def param_specs(params_avals, mesh, cfg: ModelConfig):
    """PartitionSpec tree for a model's parameters.

    Rules key off leaf names (the init functions in ``models/layers.py`` fix
    the vocabulary: wq/wk/wv/wo, wi/wg, embed/unembed, router, ...) and leaf
    rank (stacked-layer leaves carry a leading ``L`` dim; stacked MoE expert
    weights are rank 4).  Anything unmatched is replicated — always correct,
    never fast, which is the right default for norms and small vectors.
    """
    m = model_axis(mesh)
    msize = model_size(mesh)
    if m is None:
        return jax.tree.map(lambda _: P(), params_avals)
    naive = cfg.tp_rule == "naive"
    heads_ok = naive or (cfg.num_heads and cfg.num_heads % msize == 0)
    kv_ok = naive or (cfg.num_kv_heads and cfg.num_kv_heads % msize == 0)

    def div(n: int) -> bool:
        return naive or n % msize == 0

    def rule(path, x):
        names = _path_names(path)
        leaf = names[-1]
        nd = x.ndim
        # --- top-level embeddings: vocab-parallel ---
        if leaf in ("embed", "unembed") and nd == 2:
            return P(m, None) if div(x.shape[0]) else P()
        if leaf == "patch_proj" and nd == 2:
            return P(None, m) if div(x.shape[1]) else P()
        # --- attention projections (stacked: (L, d_in, d_out)) ---
        if "attn" in names or "cross" in names:
            if leaf == "wq" and nd == 3:
                return P(None, None, m) if heads_ok else P()
            if leaf in ("wk", "wv") and nd == 3:
                return P(None, None, m) if kv_ok else P()
            if leaf == "wo" and nd == 3:
                return P(None, m, None) if heads_ok else P()
            return P()  # q_norm / k_norm scales
        # --- MoE: expert-parallel stacks (L, E, d_in, d_out) ---
        if "moe" in names:
            if nd == 4 and leaf in ("wi", "wg", "wo"):
                return P(None, m, None, None) if div(x.shape[1]) else P()
            if nd == 3 and leaf in ("wi", "wg"):   # shared-expert MLP
                return P(None, None, m) if div(x.shape[2]) else P()
            if nd == 3 and leaf == "wo":
                return P(None, m, None) if div(x.shape[1]) else P()
            return P()  # router, shared_gate
        # --- dense MLP (stacked: (L, d_in, d_out)) ---
        if leaf in ("wi", "wg") and nd == 3:
            return P(None, None, m) if div(x.shape[2]) else P()
        if leaf == "wo" and nd == 3:
            return P(None, m, None) if div(x.shape[1]) else P()
        # norms, biases, ssm/rwkv mixing vectors, everything small
        return P()

    return jax.tree_util.tree_map_with_path(rule, params_avals)


# ---------------------------------------------------------------------------
# batches / activations / caches
# ---------------------------------------------------------------------------

def _nones(k: int):
    return (None,) * max(k, 0)


def train_batch_specs(batch_avals, mesh):
    """Microbatched train batch ``(n_mb, mb, ...)``: the scan axis stays
    replicated, the per-microbatch batch dim shards over the data axes."""
    d = data_axis(mesh)
    return jax.tree.map(lambda x: P(None, d, *_nones(x.ndim - 2)),
                        batch_avals)


def prefill_batch_specs(batch_avals, mesh):
    """Serving batch ``(B, ...)``: batch dim over the data axes."""
    d = data_axis(mesh)
    return jax.tree.map(lambda x: P(d, *_nones(x.ndim - 1)), batch_avals)


def cache_specs(cache_avals, mesh, cfg: ModelConfig):
    """Decode-cache tree: leaves are layer-stacked ``(L, B, ...)``.

    KV leaves ``(L, B, S, KV, hd)`` additionally shard the KV-head dim on
    ``model`` when the head count is aligned (same rule as the projections
    that produce them — a cache must never be sharded differently from its
    writer, or every decode step pays a reshard).
    """
    m = model_axis(mesh)
    msize = model_size(mesh)
    d = data_axis(mesh)
    # same alignment rule (incl. the naive ablation) as param_specs' wk/wv:
    # the cache must shard exactly like the projection that writes it
    kv_ok = (m is not None and cfg.num_kv_heads
             and (cfg.tp_rule == "naive"
                  or cfg.num_kv_heads % msize == 0))

    def rule(path, x):
        leaf = _path_names(path)[-1]
        if leaf in ("k", "v") and x.ndim == 5 and kv_ok:
            return P(None, d, None, m, None)
        return P(None, d, *_nones(x.ndim - 2))

    return jax.tree_util.tree_map_with_path(rule, cache_avals)


def logits_spec(mesh):
    """(B, vocab) logits: batch over data axes, vocab gathered."""
    return P(data_axis(mesh), None)


def flat_grad_specs(params_avals, mesh):
    """Flat fp32 gradient layout ``(n_devices, cols)`` sharded over ALL axes
    — constraining a microbatch gradient to this spec is the reduce-scatter
    half of the ZeRO-1 schedule (``adamw`` docstring has the data flow)."""
    spec = P(flat_axes(mesh), None)
    return jax.tree.map(lambda _: spec, params_avals)


# ---------------------------------------------------------------------------
# spec tree -> shardings
# ---------------------------------------------------------------------------

def _is_spec(x) -> bool:
    return isinstance(x, P)


def spec_to_sharding(spec_tree, mesh):
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=_is_spec)


def constrain(tree, mesh, spec_tree):
    """``with_sharding_constraint`` a whole pytree against a spec tree.

    Uses explicit ``NamedSharding`` so it works without an ambient mesh
    context (the launch drivers never install one)."""
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)),
        tree, spec_tree)


# ---------------------------------------------------------------------------
# LOOPS row-shard specs (paper §3.5 coarse level x mesh sharding)
# ---------------------------------------------------------------------------

def loops_axis_spec(axis):
    """Normalise a SpMM worker axis (name or tuple of names) to a P entry."""
    if isinstance(axis, str):
        return axis
    axes = tuple(axis)
    return axes[0] if len(axes) == 1 else axes


def loops_in_specs(axis):
    """``shard_map`` in_specs for ``distributed_spmm``'s operands, in the
    :class:`~repro.core.distributed.ShardedLoops` field order

        (row_ids, col_idx, vals, tile_rows, tile_cols, tile_vals, B)

    — the six device-stacked workload arrays row-shard on the worker axis
    (one CSR chunk or BCSR chunk per device; off-group devices hold a single
    zero entry), the dense ``B`` is replicated (the paper's broadcast)."""
    a = loops_axis_spec(axis)
    return (P(a),) * 6 + (P(),)


def loops_out_spec(axis):
    """Per-device output rows stay row-sharded; assembly (when requested) is
    a concatenation of exclusively-owned row slices — paper §3.4's
    conflict-free row ownership, scaled out."""
    return P(loops_axis_spec(axis))


def loops_shardings(mesh, axis):
    """NamedShardings to ``device_put`` a ShardedLoops' stacked arrays before
    repeated SpMM calls (avoids re-transferring the workload every call)."""
    return tuple(NamedSharding(mesh, s) for s in loops_in_specs(axis)[:-1])
