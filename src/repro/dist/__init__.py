"""Device-level distribution layer (sharding specs, step functions,
compressed collectives).

This package is the TPU-mesh analogue of the paper's §3.5 two-level
parallelization: the *coarse* level (the paper's disjoint NEON/SME thread
groups) becomes device groups and mesh-axis shardings, the *fine* level
stays inside each device's kernel grid.  Three modules:

* :mod:`repro.dist.sharding` — mesh-axis conventions and every
  ``PartitionSpec``/``NamedSharding`` in the system (params, batches,
  KV-caches, ZeRO flat state, LOOPS row shards);
* :mod:`repro.dist.step` — jitted + donating train / prefill / decode step
  builders consumed by ``launch/train.py``, ``launch/serve.py`` and the
  ``launch/dryrun.py`` compile sweep;
* :mod:`repro.dist.compress` — ``compressed_psum``, int8/bf16 gradient
  all-reduce compression (measured by ``benchmarks/compress_bytes.py``).

Submodules load lazily (PEP 562): ``repro.core.distributed`` needs only the
LOOPS specs from ``sharding``, and importing that must not drag the model /
optimizer stack behind ``step`` into every ``import repro.core``.
"""
import importlib

__all__ = ["compress", "sharding", "step"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
