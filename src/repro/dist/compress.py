"""Compressed cross-device reductions (gradient all-reduce on a byte diet).

``compressed_psum`` is a drop-in for ``jax.lax.psum`` inside ``shard_map``
that moves int8 (or bf16) over the interconnect instead of fp32.  The int8
path is the ZeRO++-style quantized all-reduce:

  1. share one symmetric scale across the axis (a scalar ``pmax`` — the only
     fp32 that crosses the wire besides the final gather),
  2. quantize to int8 and **all-to-all** so each device receives every
     peer's slice of its own 1/D-th of the vector (int8 on the wire),
  3. accumulate locally in int32 — this is why a naive ``psum`` of int8
     operands is unusable: XLA reduces in the operand dtype, and D devices
     of ±127 overflow ±127 immediately; the all-to-all decomposition keeps
     the wide accumulation off the wire and on the VPU,
  4. dequantize and **all-gather** the reduced fp32 slices (4/D of the
     fp32-psum bytes).

Wire bytes per device: ``n`` (int8 all-to-all) + ``4n/D`` (fp32 all-gather)
vs ``4n`` for an fp32 ring psum — ~3.2x fewer at D=16 (measured from
optimized HLO by ``benchmarks/compress_bytes.py``).  Error: one rounding per
element at a shared scale, so the reduced value carries at most
``D * scale/2`` absolute error — ``tests/test_distributed.py`` bounds it at
2% relative on gradient-like normals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum"]

F32 = jnp.float32


def _int8_psum(x: jax.Array, axis_name: str, D: int) -> jax.Array:
    flat = x.reshape(-1).astype(F32)
    n = flat.size
    pad = (-n) % D
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # one shared symmetric scale per call: quantized values from different
    # devices must be summable, so the scale cannot be per-device
    amax = jax.lax.pmax(jnp.max(jnp.abs(flat)), axis_name)
    scale = jnp.maximum(amax, jnp.finfo(F32).tiny) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    # row d of the (D, n/D) view is the slice device d will reduce
    q = q.reshape(D, -1)
    qx = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0)
    part = qx.astype(jnp.int32).sum(axis=0).astype(F32) * scale
    full = jax.lax.all_gather(part, axis_name, tiled=True)
    if pad:
        full = full[:n]
    return full.reshape(x.shape).astype(x.dtype)


def compressed_psum(x: jax.Array, axis_name: str,
                    precision: str = "int8") -> jax.Array:
    """All-reduce ``x`` over ``axis_name`` with compressed communication.

    ``precision``: ``"int8"`` (quantized all-to-all reduce, ~3-4x fewer
    collective bytes), ``"bf16"`` (cast-psum-cast — 2x where the backend has
    a native bf16 all-reduce; the CPU backend upcasts to f32, so
    ``benchmarks/compress_bytes.py`` honestly reports 1.0x there), or
    ``"none"`` (plain fp32 psum — the ablation baseline).

    Must be called inside ``shard_map`` (it uses named-axis collectives).

    When a live obs capture is active (``repro.obs.set_active``), each call
    site reports its per-device wire bytes — ``4n`` fp32, ``2n`` bf16,
    ``n + 4n/D`` int8 — to the ``dist.collective_bytes`` gauge.  The shapes
    (and therefore the bytes) are static, so this fires at trace time: it
    is a bytes-per-call figure, not an execution counter.
    """
    D = jax.lax.psum(1, axis_name)
    if precision not in ("none", "bf16", "int8"):
        raise ValueError(f"unknown compression precision: {precision!r}")
    n = x.size
    _note_bytes(0 if D == 1 else
                {"none": 4 * n, "bf16": 2 * n,
                 "int8": n + 4 * n // D}[precision], precision)
    if precision == "none" or D == 1:
        return jax.lax.psum(x, axis_name)
    # Resilience (docs/robustness.md): a failing compressed path degrades to
    # the plain fp32 psum — numerically a strict upgrade, just more bytes —
    # with a ``dist.fallback`` counter.  ``dist.psum.{precision}`` is the
    # chaos injection site; this fires at trace time like the byte gauge.
    from ..resilience.fallback import classify, get_policy
    from ..resilience.inject import fault_point, note_degraded
    try:
        fault_point(f"dist.psum.{precision}")
        if precision == "bf16":
            return jax.lax.psum(x.astype(jnp.bfloat16),
                                axis_name).astype(x.dtype)
        return _int8_psum(x, axis_name, D)
    except Exception as e:    # noqa: BLE001 - plain psum IS the handler
        if not get_policy().enabled:
            raise
        note_degraded("dist.fallback", precision=precision,
                      reason=classify(e))
        return jax.lax.psum(x, axis_name)


def _note_bytes(nbytes: int, precision: str) -> None:
    """Report one call site's wire bytes to the active obs capture (no-op
    without one; lazy import keeps ``repro.obs`` optional here)."""
    try:
        from ..obs.runtime import note_collective
    except ImportError:     # pragma: no cover - obs is part of the tree
        return
    note_collective(int(nbytes), kind="psum", precision=precision)
