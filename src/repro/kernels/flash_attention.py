"""Fused flash-attention Pallas kernel (TPU MXU + VMEM-resident scores).

Why this kernel exists (§Perf iteration 1): the XLA-level chunked attention
in ``models/layers.py`` materialises every (q_chunk x k_chunk) score tile in
HBM — the dry-run roofline shows 32k-token prefill spending >90% of its
memory term on score traffic.  On TPU the fix is a fused kernel: scores,
softmax statistics and the output accumulator live in VMEM; only Q, K, V and
O ever cross HBM.  Per (batch*head, q_block) grid step the kernel loops over
k blocks with ``fmopa``-style MXU dots accumulated in fp32.

GQA is expressed in the BlockSpec index_map (q-head -> kv-head integer
division), causal masking via in-kernel iota comparison, and the k-loop is
*triangular*: grid dimension k stops contributing past the causal frontier
with @pl.when (on TPU, Mosaic's grid dim skipping elides the dead steps; the
roofline model counts only the live ones).

Validated in interpret mode against ``ref.flash_attention_ref`` /
``models.layers.flash_attention`` over shape x dtype x GQA sweeps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            causal: bool, scale: float, block_q: int, block_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # triangular schedule: steps entirely above the causal diagonal are dead
    live = (not causal) or (ki * block_k <= qi * block_q + block_q - 1)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)              # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 512,
                           block_k: int = 512,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) with H % KV == 0.

    Returns (B, Sq, H, hd) in q.dtype.  Scores never leave VMEM: HBM traffic
    is exactly Q+K+V read + O written (the §Perf kernel-adjusted model).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    assert H % KV == 0
    rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0

    # (B, S, H, hd) -> (B*H, S, hd) head-major for clean 2-D blocks
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)

    grid = (B * H, Sq // block_q, Sk // block_k)

    def q_map(h, qi, ki):
        return (h, qi, 0)

    def kv_map(h, qi, ki):
        return (h // rep, ki, 0)  # GQA: q-head group -> kv head

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=scale,
                          block_q=block_q, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
