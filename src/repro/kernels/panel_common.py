"""Shared scaffolding for the G-wide panel kernels.

Both panel kernels (``csr_spmm``, ``bcsr_spmm``) speak the same operand
protocol: scalar-prefetched ``(panel_rows, panel_cols)``, then the tensor
train ``[panel_vals, panel_mask, carry?, B x G]``, then outputs and scratch.
The operand ORDER is load-bearing — ``input_output_aliases`` is positional —
so it is defined here exactly once and both kernels assemble their specs and
unpack their refs through these helpers.

Batched execution: when the dense operand carries a leading batch dimension
``(batch, K, N)``, the grid gains a leading batch-block axis and every
tensor BlockSpec gains a leading ``bz``-wide block dimension (``bz`` batch
slices per grid step, :func:`repro.kernels.engine.batch_block`).  The
scalar-prefetch panel metadata is shared across the batch — A's static
panel layout is loaded once per grid step and applied to all ``bz``
slices.  ``grid_dims`` centralises the two grid layouts so the kernels'
``first``/``last`` revisit predicates can never disagree with the specs.

Pipelining (``pipeline_depth=2``): the panel axis is stretched by
``depth - 1`` ramp steps and the load/compute streams are skewed one step
apart — grid step ``k`` *assembles* panel ``lidx(k) = min(k, P-1)``'s B rows
into the ping-pong scratch slot ``k % 2`` while it *contracts* panel
``cidx(k) = max(k - (depth-1), 0)`` out of slot ``(k+1) % 2``.  The B-row
gathers (the dominant DMA traffic) for panel ``p+1`` thus overlap the MXU
contraction of panel ``p``.  ``pipeline_index`` builds the two index maps;
with ``depth=1`` both are the identity and every spec below is exactly the
unpipelined layout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["CARRY_OPERAND_INDEX", "PIPELINE_DEPTHS", "check_pipeline_depth",
           "default_bn", "first_last", "first_last_at", "grid_dims",
           "panel_operands", "parity", "pipeline_index", "split_panel_refs"]

# Position of the fused-path carry among ALL pallas_call operands (scalar
# prefetch included): rows(0), cols(1), vals(2), mask(3), carry(4).
CARRY_OPERAND_INDEX = 4

# Supported software-pipeline depths: 1 = today's serial gather->contract
# kernels, 2 = double-buffered B-panel prefetch (ping-pong scratch).
PIPELINE_DEPTHS = (1, 2)


def default_bn(n: int) -> int:
    """Largest lane-aligned column-block width that tiles ``n`` exactly.

    ``n <= 512`` keeps the whole row in one block; above that, pick the
    largest divisor of ``n`` that is ``<= 512``, preferring MXU-lane
    multiples (128), then VPU-lane multiples (8), then any divisor — so
    awkward widths (N=600 -> 200) get a legal default instead of the old
    ``min(n, 512)`` raising ``ValueError`` when ``512 ∤ n``.
    """
    n = int(n)
    if n <= 512:
        return max(n, 1)
    divisors = [d for d in range(1, 513) if n % d == 0]
    for align in (128, 8, 1):
        aligned = [d for d in divisors if d % align == 0]
        if aligned:
            return max(aligned)
    return 1   # unreachable: 1 always divides n


def parity(k):
    """``k % 2`` in ``k``'s own integer dtype — ``jax.lax.rem(k, 2)`` trips
    the stablehlo verifier under x64 (i32 program_id vs weak-i64 literal)."""
    return jax.lax.rem(k, jnp.asarray(2, k.dtype))


def check_pipeline_depth(pipeline_depth: int) -> int:
    depth = int(pipeline_depth)
    if depth not in PIPELINE_DEPTHS:
        raise ValueError(f"pipeline_depth must be one of {PIPELINE_DEPTHS}, "
                         f"got {pipeline_depth}")
    return depth


def pipeline_index(depth: int, npanels: int):
    """``(lidx, cidx)`` index maps for a depth-deep panel pipeline.

    ``lidx(k)`` is the panel whose B rows grid step ``k`` loads (clamped to
    the last panel during the drain); ``cidx(k)`` is the panel it contracts
    (clamped to 0 during the fill ramp — compute is predicated off there,
    the clamp only keeps the block indices in range).  ``depth=1`` returns
    identities, reproducing the unpipelined specs exactly.
    """
    if depth == 1:
        return (lambda k: k), (lambda k: k)
    return (lambda k: jnp.minimum(k, npanels - 1),
            lambda k: jnp.maximum(k - (depth - 1), 0))


def grid_dims(*, batch: int | None, bz: int, n: int, bn: int, npanels: int,
              pipeline_depth: int = 1):
    """``(grid, panel_axis)`` for a panel kernel: the panel axis is always
    innermost (the accumulator-revisit protocol needs all panels of a row
    consecutive); batched calls prepend a batch-block axis.  A depth-``d``
    pipeline stretches the panel axis by ``d - 1`` fill/drain ramp steps."""
    steps = npanels + check_pipeline_depth(pipeline_depth) - 1
    if batch is None:
        return (n // bn, steps), 1
    return (batch // bz, n // bn, steps), 2


def first_last(rows_ref, panel_axis: int = 1):
    """(first, last) predicates for the nondecreasing-row revisit protocol:
    does the inner grid step ``k`` (on ``panel_axis``) open / close its
    output row's visit?"""
    k = pl.program_id(panel_axis)
    return first_last_at(rows_ref, k, pl.num_programs(panel_axis))


def first_last_at(rows_ref, c, npanels):
    """(first, last) revisit predicates evaluated at an explicit panel
    index ``c`` over ``npanels`` panels — the pipelined kernels compute
    panel ``cidx(k)``, not panel ``k``, so the predicates must follow the
    compute stream, not the grid step."""
    row_here = rows_ref[c]
    row_prev = rows_ref[jnp.maximum(c - 1, 0)]
    row_next = rows_ref[jnp.minimum(c + 1, npanels - 1)]
    first = jnp.logical_or(c == 0, row_here != row_prev)
    last = jnp.logical_or(c == npanels - 1, row_here != row_next)
    return first, last


def split_panel_refs(refs, g: int, has_carry: bool):
    """Unpack a panel kernel's ref train into
    ``(rows, cols, vals, mask, b_refs, tail)`` where ``tail`` is the
    kernel-specific (outputs + scratch) remainder.  The carry ref, when
    present, is never read in-kernel (aliasing preserves it) and is
    skipped here."""
    rows_ref, cols_ref, vals_ref, mask_ref = refs[:4]
    rest = refs[4 + (1 if has_carry else 0):]
    return rows_ref, cols_ref, vals_ref, mask_ref, rest[:g], rest[g:]


def panel_operands(*, g: int, bn: int, vals_block, vals, mask, b,
                   carry=None, carry_block=None, row_map=None,
                   bz: int | None = None, pipeline_depth: int = 1,
                   npanels: int | None = None):
    """Assemble the tensor-operand train shared by both panel kernels.

    Args:
      vals_block:  block shape of the panel-values operand ((1, g) for CSR,
                   (1, br, g) for BCSR) — indexed ``(k, 0, ...)`` on the
                   panel axis regardless of batching.
      row_map:     ``row_index(rows, k, j)`` → the (row-ish, col) block
                   index of the carry/output; used to build the carry spec.
      bz:          batch slices per grid step, or None for the unbatched
                   2-D layout.
      pipeline_depth / npanels: skew the load stream (mask + B gathers,
                   indexed at ``lidx(k)``) ``depth - 1`` steps ahead of the
                   compute stream (vals + carry, indexed at ``cidx(k)``).
                   ``depth=1`` keeps both at ``k`` — today's layout.

    Returns ``(in_specs, args, input_output_aliases)``: vals and the
    ``(1, G)`` mask, the optional aliased carry, then G gathers of ``b``
    indexed by the scalar-prefetched ``panel_cols`` — one DMA stream per
    panel lane, ``bz`` batch slices wide when batched.
    """
    depth = check_pipeline_depth(pipeline_depth)
    if depth > 1 and npanels is None:
        raise ValueError("pipelined panel_operands needs npanels")
    lidx, cidx = pipeline_index(depth, npanels if npanels is not None else 0)
    vals_index = (0,) * (len(vals_block) - 1)
    if bz is None:
        def _meta(block):
            return pl.BlockSpec(block, lambda j, k, rows, cols:
                                (cidx(k),) + vals_index)
        mask_spec = pl.BlockSpec((1, g),
                                 lambda j, k, rows, cols: (lidx(k), 0))
        b_specs = [
            pl.BlockSpec((1, bn), lambda j, k, rows, cols, i=i:
                         (cols[lidx(k), i], j))
            for i in range(g)]
        carry_spec = carry_block and pl.BlockSpec(
            carry_block, lambda j, k, rows, cols: row_map(rows, cidx(k), j))
    else:
        def _meta(block):
            return pl.BlockSpec(block, lambda z, j, k, rows, cols:
                                (cidx(k),) + vals_index)
        mask_spec = pl.BlockSpec((1, g),
                                 lambda z, j, k, rows, cols: (lidx(k), 0))
        b_specs = [
            pl.BlockSpec((bz, 1, bn), lambda z, j, k, rows, cols, i=i:
                         (z, cols[lidx(k), i], j))
            for i in range(g)]
        carry_spec = carry_block and pl.BlockSpec(
            (bz,) + tuple(carry_block),
            lambda z, j, k, rows, cols: (z,) + row_map(rows, cidx(k), j))

    in_specs = [_meta(vals_block), mask_spec]
    args = [vals, mask]
    aliases = {}
    if carry is not None:
        in_specs.append(carry_spec)
        args.append(carry)
        aliases = {CARRY_OPERAND_INDEX: 0}
    in_specs.extend(b_specs)
    args.extend([b] * g)
    return in_specs, args, aliases
