"""Shared scaffolding for the G-wide panel kernels.

Both panel kernels (``csr_spmm``, ``bcsr_spmm``) speak the same operand
protocol: scalar-prefetched ``(panel_rows, panel_cols)``, then the tensor
train ``[panel_vals, panel_mask, carry?, B x G]``, then outputs and scratch.
The operand ORDER is load-bearing — ``input_output_aliases`` is positional —
so it is defined here exactly once and both kernels assemble their specs and
unpack their refs through these helpers.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["CARRY_OPERAND_INDEX", "first_last", "panel_operands",
           "split_panel_refs"]

# Position of the fused-path carry among ALL pallas_call operands (scalar
# prefetch included): rows(0), cols(1), vals(2), mask(3), carry(4).
CARRY_OPERAND_INDEX = 4


def first_last(rows_ref):
    """(first, last) predicates for the nondecreasing-row revisit protocol:
    does the inner grid step ``k`` open / close its output row's visit?"""
    k = pl.program_id(1)
    npanels = pl.num_programs(1)
    row_here = rows_ref[k]
    row_prev = rows_ref[jnp.maximum(k - 1, 0)]
    row_next = rows_ref[jnp.minimum(k + 1, npanels - 1)]
    first = jnp.logical_or(k == 0, row_here != row_prev)
    last = jnp.logical_or(k == npanels - 1, row_here != row_next)
    return first, last


def split_panel_refs(refs, g: int, has_carry: bool):
    """Unpack a panel kernel's ref train into
    ``(rows, cols, vals, mask, b_refs, tail)`` where ``tail`` is the
    kernel-specific (outputs + scratch) remainder.  The carry ref, when
    present, is never read in-kernel (aliasing preserves it) and is
    skipped here."""
    rows_ref, cols_ref, vals_ref, mask_ref = refs[:4]
    rest = refs[4 + (1 if has_carry else 0):]
    return rows_ref, cols_ref, vals_ref, mask_ref, rest[:g], rest[g:]


def panel_operands(*, g: int, bn: int, vals_spec, vals, mask, b,
                   carry=None, carry_spec=None):
    """Assemble the tensor-operand train shared by both panel kernels.

    Returns ``(in_specs, args, input_output_aliases)``: vals and the
    ``(1, G)`` mask, the optional aliased carry, then G independent
    ``(1, bn)`` gathers of ``b`` indexed by the scalar-prefetched
    ``panel_cols`` — one DMA stream per panel lane.
    """
    in_specs = [vals_spec,
                pl.BlockSpec((1, g), lambda j, k, rows, cols: (k, 0))]
    args = [vals, mask]
    aliases = {}
    if carry is not None:
        in_specs.append(carry_spec)
        args.append(carry)
        aliases = {CARRY_OPERAND_INDEX: 0}
    in_specs.extend(
        pl.BlockSpec((1, bn), lambda j, k, rows, cols, i=i: (cols[k, i], j))
        for i in range(g))
    args.extend([b] * g)
    return in_specs, args, aliases
