"""Shared scaffolding for the G-wide panel kernels.

Both panel kernels (``csr_spmm``, ``bcsr_spmm``) speak the same operand
protocol: scalar-prefetched ``(panel_rows, panel_cols)``, then the tensor
train ``[panel_vals, panel_mask, carry?, B x G]``, then outputs and scratch.
The operand ORDER is load-bearing — ``input_output_aliases`` is positional —
so it is defined here exactly once and both kernels assemble their specs and
unpack their refs through these helpers.

Batched execution: when the dense operand carries a leading batch dimension
``(batch, K, N)``, the grid gains a leading batch-block axis and every
tensor BlockSpec gains a leading ``bz``-wide block dimension (``bz`` batch
slices per grid step, :func:`repro.kernels.engine.batch_block`).  The
scalar-prefetch panel metadata is shared across the batch — A's static
panel layout is loaded once per grid step and applied to all ``bz``
slices.  ``grid_dims`` centralises the two grid layouts so the kernels'
``first``/``last`` revisit predicates can never disagree with the specs.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["CARRY_OPERAND_INDEX", "first_last", "grid_dims", "panel_operands",
           "split_panel_refs"]

# Position of the fused-path carry among ALL pallas_call operands (scalar
# prefetch included): rows(0), cols(1), vals(2), mask(3), carry(4).
CARRY_OPERAND_INDEX = 4


def grid_dims(*, batch: int | None, bz: int, n: int, bn: int, npanels: int):
    """``(grid, panel_axis)`` for a panel kernel: the panel axis is always
    innermost (the accumulator-revisit protocol needs all panels of a row
    consecutive); batched calls prepend a batch-block axis."""
    if batch is None:
        return (n // bn, npanels), 1
    return (batch // bz, n // bn, npanels), 2


def first_last(rows_ref, panel_axis: int = 1):
    """(first, last) predicates for the nondecreasing-row revisit protocol:
    does the inner grid step ``k`` (on ``panel_axis``) open / close its
    output row's visit?"""
    k = pl.program_id(panel_axis)
    npanels = pl.num_programs(panel_axis)
    row_here = rows_ref[k]
    row_prev = rows_ref[jnp.maximum(k - 1, 0)]
    row_next = rows_ref[jnp.minimum(k + 1, npanels - 1)]
    first = jnp.logical_or(k == 0, row_here != row_prev)
    last = jnp.logical_or(k == npanels - 1, row_here != row_next)
    return first, last


def split_panel_refs(refs, g: int, has_carry: bool):
    """Unpack a panel kernel's ref train into
    ``(rows, cols, vals, mask, b_refs, tail)`` where ``tail`` is the
    kernel-specific (outputs + scratch) remainder.  The carry ref, when
    present, is never read in-kernel (aliasing preserves it) and is
    skipped here."""
    rows_ref, cols_ref, vals_ref, mask_ref = refs[:4]
    rest = refs[4 + (1 if has_carry else 0):]
    return rows_ref, cols_ref, vals_ref, mask_ref, rest[:g], rest[g:]


def panel_operands(*, g: int, bn: int, vals_block, vals, mask, b,
                   carry=None, carry_block=None, row_map=None,
                   bz: int | None = None):
    """Assemble the tensor-operand train shared by both panel kernels.

    Args:
      vals_block:  block shape of the panel-values operand ((1, g) for CSR,
                   (1, br, g) for BCSR) — indexed ``(k, 0, ...)`` on the
                   panel axis regardless of batching.
      row_map:     ``row_index(rows, k, j)`` → the (row-ish, col) block
                   index of the carry/output; used to build the carry spec.
      bz:          batch slices per grid step, or None for the unbatched
                   2-D layout.

    Returns ``(in_specs, args, input_output_aliases)``: vals and the
    ``(1, G)`` mask, the optional aliased carry, then G gathers of ``b``
    indexed by the scalar-prefetched ``panel_cols`` — one DMA stream per
    panel lane, ``bz`` batch slices wide when batched.
    """
    vals_index = (0,) * (len(vals_block) - 1)
    if bz is None:
        def _meta(block):
            return pl.BlockSpec(block, lambda j, k, rows, cols:
                                (k,) + vals_index)
        mask_spec = pl.BlockSpec((1, g), lambda j, k, rows, cols: (k, 0))
        b_specs = [
            pl.BlockSpec((1, bn), lambda j, k, rows, cols, i=i:
                         (cols[k, i], j))
            for i in range(g)]
        carry_spec = carry_block and pl.BlockSpec(
            carry_block, lambda j, k, rows, cols: row_map(rows, k, j))
    else:
        def _meta(block):
            return pl.BlockSpec(block, lambda z, j, k, rows, cols:
                                (k,) + vals_index)
        mask_spec = pl.BlockSpec((1, g), lambda z, j, k, rows, cols: (k, 0))
        b_specs = [
            pl.BlockSpec((bz, 1, bn), lambda z, j, k, rows, cols, i=i:
                         (z, cols[k, i], j))
            for i in range(g)]
        carry_spec = carry_block and pl.BlockSpec(
            (bz,) + tuple(carry_block),
            lambda z, j, k, rows, cols: (z,) + row_map(rows, k, j))

    in_specs = [_meta(vals_block), mask_spec]
    args = [vals, mask]
    aliases = {}
    if carry is not None:
        in_specs.append(carry_spec)
        args.append(carry)
        aliases = {CARRY_OPERAND_INDEX: 0}
    in_specs.extend(b_specs)
    args.extend([b] * g)
    return in_specs, args, aliases
