"""Pallas TPU kernels for the LOOPS hot paths.

``csr_spmm``  — VPU row-wise AXPY kernel (paper's NEON kernel).
``bcsr_spmm`` — MXU outer-product-chain kernel (paper's SME fmopa kernel).
``spmm_sdd``  — sampled dense-dense backward kernels (gradient of the
stored values at the stored coordinates; the custom VJP's dA half).

Each kernel ships a pure-jnp oracle in ``ref.py``; ``ops.py`` dispatches
between real-TPU Pallas, interpret-mode Pallas (CPU validation) and the
reference path, and exposes ``loops_sdd`` for the backward pass.
"""
from . import ops, ref
from .bcsr_spmm import bcsr_spmm_pallas
from .csr_spmm import csr_spmm_pallas

__all__ = ["ops", "ref", "bcsr_spmm_pallas", "csr_spmm_pallas"]
