"""Pallas TPU kernels for the LOOPS hot paths.

``csr_spmm``  — VPU row-wise AXPY kernel (paper's NEON kernel).
``bcsr_spmm`` — MXU outer-product-chain kernel (paper's SME fmopa kernel).
``spmm_sdd``  — sampled dense-dense backward kernels (gradient of the
stored values at the stored coordinates; the custom VJP's dA half).

Each kernel ships a pure-jnp oracle in ``ref.py`` and registers itself with
the execution engine (``engine.py``) under a ``(part, op)`` key.  The engine
is the one dispatch layer: it picks the backend (real-TPU Pallas,
interpret-mode Pallas for CPU validation, or the jnp reference), owns the
half-precision promotion rule, scatters traced ``vals=`` overrides into the
static panel layout, and flattens any leading batch dims of the dense
operand into the kernels' native batch grid dimension.  ``ops.py`` is a
compatibility re-export of the engine's entry points.
"""
from . import engine, ops, ref
from .bcsr_spmm import bcsr_spmm_pallas
from .csr_spmm import csr_spmm_pallas

__all__ = ["engine", "ops", "ref", "bcsr_spmm_pallas", "csr_spmm_pallas"]
