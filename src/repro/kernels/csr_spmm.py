"""CSR-part SpMM Pallas kernel — the VPU (vector-pipeline) half of LOOPS.

Paper mapping (§3.3 "AXPY based NEON kernel for CSR part"): for each nonzero
``(r, c, v)`` of the CSR-part, AXPY-accumulate ``v * B[c, :]`` into output row
``r``.  On Arm this vectorises over NEON lanes; on TPU it vectorises over the
VPU's 8x128 lanes along the N (dense-column) dimension.  No MXU involvement —
this kernel exists precisely so that irregular rows do not pay the
outer-product padding cost (paper C1) and so that the matrix pipeline is left
free for the BCSR-part (paper C3).

Implementation notes
--------------------
* grid = (N // bn, nnz): the inner grid dimension walks nonzeros in (row, col)
  order; the *output* BlockSpec index_map scatters to ``row_ids[k]`` which is
  nondecreasing, so Pallas legally keeps the current output block resident in
  VMEM across consecutive grid steps of the same row (the TPU analogue of
  keeping the NEON accumulator registers live across a row).
* ``row_ids``/``col_idx`` arrive via scalar prefetch (SMEM) so the B-row
  gather is expressed in the BlockSpec index_map — the standard Pallas-TPU
  sparse-gather idiom; the DMA for step k+1 overlaps with compute of step k.
* Accumulation runs in fp32 scratch for {bf16, f16} inputs (f16f16f32
  contract) and in the native dtype for f32/f64.
* every output row must appear in ``row_ids`` at least once (format layer
  guarantees this via explicit zero entries) or its block would be left
  uninitialised on real hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import acc_dtype_for

__all__ = ["csr_spmm_pallas"]


def _kernel(row_ids_ref, col_idx_ref, vals_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(1)
    nnz = pl.num_programs(1)

    row_here = row_ids_ref[k]
    row_prev = row_ids_ref[jnp.maximum(k - 1, 0)]
    row_next = row_ids_ref[jnp.minimum(k + 1, nnz - 1)]
    first = jnp.logical_or(k == 0, row_here != row_prev)
    last = jnp.logical_or(k == nnz - 1, row_here != row_next)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    v = vals_ref[0, 0].astype(acc_ref.dtype)       # scalar nonzero value
    acc_ref[...] += v * b_ref[...].astype(acc_ref.dtype)  # AXPY over N lanes

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("nrows", "bn", "out_dtype", "interpret"))
def csr_spmm_pallas(row_ids: jax.Array, col_idx: jax.Array, vals: jax.Array,
                    b: jax.Array, *, nrows: int, bn: int | None = None,
                    out_dtype=None, interpret: bool = True) -> jax.Array:
    """C[r] += vals[k] * B[col_idx[k], :] for every nonzero k (rows sorted).

    Args:
      row_ids: (nnz,) int32, nondecreasing output row per nonzero.
      col_idx: (nnz,) int32 gather row of ``b`` per nonzero.
      vals:    (nnz,) values.
      b:       (K, N) dense operand.
      nrows:   output row count (static).
      bn:      dense-column block width; defaults to min(N, 512) — the wide
               block is the analogue of the paper's multi-tile trick (several
               128-lane column tiles processed per visit).
      interpret: run the Pallas interpreter (CPU validation); False on TPU.
    """
    nnz = row_ids.shape[0]
    n = b.shape[1]
    bn = bn or min(n, 512)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype = acc_dtype_for(vals.dtype)
    out_dtype = out_dtype or acc_dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # row_ids, col_idx
        grid=(n // bn, nnz),
        in_specs=[
            pl.BlockSpec((1, 1), lambda j, k, rows, cols: (k, 0)),       # vals
            pl.BlockSpec((1, bn), lambda j, k, rows, cols: (cols[k], j)),  # B row
        ],
        out_specs=pl.BlockSpec((1, bn), lambda j, k, rows, cols: (rows[k], j)),
        scratch_shapes=[pltpu.VMEM((1, bn), acc_dtype)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nrows, n), out_dtype),
        interpret=interpret,
    )(row_ids, col_idx, vals.reshape(nnz, 1), b)
