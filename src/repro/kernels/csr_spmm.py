"""CSR-part SpMM Pallas kernel — the VPU (vector-pipeline) half of LOOPS.

Paper mapping (§3.3 "AXPY based NEON kernel for CSR part"): for each nonzero
``(r, c, v)`` of the CSR-part, AXPY-accumulate ``v * B[c, :]`` into output row
``r``.  On Arm this vectorises over NEON lanes; on TPU it vectorises over the
VPU's 8x128 lanes along the N (dense-column) dimension.  No MXU involvement —
this kernel exists precisely so that irregular rows do not pay the
outer-product padding cost (paper C1) and so that the matrix pipeline is left
free for the BCSR-part (paper C3).

Panelized execution (paper Figure 2 "multi-tile" batching)
----------------------------------------------------------
The kernel consumes ``(P, G)`` panels (``repro.core.formats.PanelCSR``): one
grid step gathers the G rows ``B[panel_cols[p]]`` (G independent scalar-
prefetch-indexed DMAs that all overlap with compute of the previous step) and
masked-broadcast-multiply-reduces them against ``panel_vals[p]`` into the
resident accumulator.  The grid shrinks from ``nnz`` to ``ceil(nnz/G)`` inner
steps — the TPU analogue of batching several fmopa rounds per ZA-tile visit.
G = 1 with a trivial mask reproduces the historical one-nonzero-per-step
kernel exactly (``csr_spmm_pallas`` is that wrapper).

Batched execution (multi-RHS)
-----------------------------
A rank-3 dense operand ``(batch, K, N)`` adds a leading batch-block grid
axis: each grid step loads A's ``(1, G)`` panel metadata ONCE and applies it
to ``bz`` batch slices (``repro.kernels.engine.batch_block``) of B at a
time, producing a ``(bz, 1, bn)`` output block per step.  Grid steps grow by
``ceil(batch / bz)`` — not ``batch`` — over the unbatched call, which is
what lets one batched engine call replace a per-element Python loop.

Implementation notes
--------------------
* grid = (N // bn, P) (batched: (batch // bz, N // bn, P)): the innermost
  grid dimension walks panels in (row, col) order; the *output* BlockSpec
  index_map scatters to ``panel_rows[p]`` which is nondecreasing, so Pallas
  legally keeps the current output block resident in VMEM across consecutive
  grid steps of the same row (the TPU analogue of keeping the NEON
  accumulator registers live across a row).
* ``panel_rows``/``panel_cols`` arrive via scalar prefetch (SMEM) so the B-row
  gathers are expressed in BlockSpec index_maps — the standard Pallas-TPU
  sparse-gather idiom; the DMAs for step k+1 overlap with compute of step k.
* Accumulation runs in fp32 scratch for {bf16, f16} inputs (f16f16f32
  contract) and in the native dtype for f32/f64 — the shared promotion
  helper ``repro.kernels.engine.resolve_dtypes``.
* every output row must appear in ``panel_rows`` at least once (format layer
  guarantees this via >= 1 panel per row) or its block would be left
  uninitialised on real hardware.
* ``carry``: optional full-size output operand aliased to the result
  (``input_output_aliases``) for the fused single-pass ``loops_spmm`` — rows
  this kernel does not visit keep the carry's values, letting the CSR and
  BCSR kernels fill disjoint row ranges of ONE buffer with no concatenate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .engine import batch_block, register_kernel, resolve_dtypes
from .panel_common import (check_pipeline_depth, default_bn, first_last,
                           first_last_at, grid_dims, panel_operands, parity,
                           split_panel_refs)

__all__ = ["csr_spmm_pallas", "csr_panels_spmm_pallas"]


def _panel_kernel(g: int, has_carry: bool, bz: int | None, *refs):
    """One grid step: masked gather of G rows of B, multiply-reduce over G
    into the resident accumulator (``bz`` batch slices at once when
    batched)."""
    rows_ref, _, vals_ref, mask_ref, b_refs, (o_ref, acc_ref) = \
        split_panel_refs(refs, g, has_carry)
    first, last = first_last(rows_ref, panel_axis=1 if bz is None else 2)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Masked broadcast-multiply-reduce over the G axis: lane i contributes
    # vals[i] * B[cols[i], :] iff mask[i] (padding lanes are dropped by the
    # mask, so panels shorter than G — nnz not divisible by G, row
    # boundaries — are exact, not approximate).  B's rows stay packed in
    # their storage dtype; only the multiply promotes (bf16 -> f32 is exact,
    # so half-precision panels cost half the VMEM traffic at identical
    # results).
    acc = acc_ref[...]
    for i, b_ref in enumerate(b_refs):
        v = vals_ref[0, i].astype(acc_ref.dtype)
        row = b_ref[...] if bz is None else b_ref[...][:, 0, :]
        contrib = v * row  # AXPY over N lanes; promotion at the multiply
        acc = acc + jnp.where(mask_ref[0, i] > 0, contrib,
                              jnp.zeros_like(contrib))
    acc_ref[...] = acc

    @pl.when(last)
    def _flush():
        out = acc_ref[...]
        o_ref[...] = (out if bz is None else out[:, None, :]).astype(
            o_ref.dtype)


def _piped_panel_kernel(g: int, has_carry: bool, bz: int | None, depth: int,
                        *refs):
    """Depth-2 software pipeline: grid step ``k`` assembles panel
    ``min(k, P-1)``'s (masked) B rows into ping-pong scratch slot ``k % 2``
    while contracting panel ``max(k - 1, 0)`` out of slot ``(k+1) % 2`` —
    the B gathers of the next panel overlap the AXPY of the current one.
    The grid carries ``depth - 1`` extra fill/drain ramp steps; compute,
    init and flush are predicated off during the fill ramp."""
    rows_ref, _, vals_ref, mask_ref, b_refs, \
        (o_ref, bpan_ref, mpan_ref, acc_ref) = \
        split_panel_refs(refs, g, has_carry)
    axis = 1 if bz is None else 2
    k = pl.program_id(axis)
    npanels = pl.num_programs(axis) - (depth - 1)

    def _assemble(slot):
        # Stage the raw (packed-dtype) B rows plus the mask panel; the
        # compute stream applies the mask exactly like the depth-1 kernel
        # (where AFTER the multiply) so results stay bitwise identical.
        mpan_ref[slot] = mask_ref[...]
        for i, b_ref in enumerate(b_refs):
            if bz is None:
                bpan_ref[slot, i, :] = b_ref[...][0]
            else:
                bpan_ref[slot, i, :, :] = b_ref[...][:, 0, :]

    for s in (0, 1):
        @pl.when(parity(k) == s)
        def _(s=s):
            _assemble(s)

    @pl.when(k >= depth - 1)
    def _compute():
        c = jnp.maximum(k - (depth - 1), 0)
        first, last = first_last_at(rows_ref, c, npanels)

        @pl.when(first)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        def _accumulate(slot):
            acc = acc_ref[...]
            for i in range(g):
                v = vals_ref[0, i].astype(acc_ref.dtype)
                row = (bpan_ref[slot, i, :][None] if bz is None
                       else bpan_ref[slot, i, :, :])
                contrib = v * row   # promotion at the multiply (packed B)
                acc = acc + jnp.where(mpan_ref[slot, 0, i] > 0, contrib,
                                      jnp.zeros_like(contrib))
            acc_ref[...] = acc

        for s in (0, 1):
            @pl.when(parity(k + 1) == s)
            def _(s=s):
                _accumulate(s)

        @pl.when(last)
        def _flush():
            out = acc_ref[...]
            o_ref[...] = (out if bz is None else out[:, None, :]).astype(
                o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("nrows", "out_rows", "bn", "out_dtype", "interpret",
                     "pipeline_depth"))
def csr_panels_spmm_pallas(panel_rows: jax.Array, panel_cols: jax.Array,
                           panel_vals: jax.Array, panel_mask: jax.Array,
                           b: jax.Array, *, nrows: int,
                           out_rows: int | None = None, bn: int | None = None,
                           out_dtype=None, interpret: bool = True,
                           carry: jax.Array | None = None,
                           pipeline_depth: int = 1) -> jax.Array:
    """C[r] += sum_i mask[p,i] * vals[p,i] * B[cols[p,i], :] per panel p.

    Args:
      panel_rows: (P,) int32, nondecreasing output row per panel.
      panel_cols: (P, G) int32 gather rows of ``b`` per panel lane.
      panel_vals: (P, G) values (0 on padding lanes).
      panel_mask: (P, G) lane validity (1 real / 0 padding), vals dtype.
      b:          (K, N) dense operand, or (batch, K, N) for the native
                  batched grid (one kernel call serves every slice).
      nrows:      logical output row count this kernel writes (static).
      out_rows:   total rows of the returned array (>= nrows; rows beyond
                  ``nrows`` are the fused path's BCSR territory).  Defaults
                  to ``nrows``.
      bn:         dense-column block width; defaults to
                  ``panel_common.default_bn(N)`` (min(N, 512) when 512 | N,
                  else the largest lane-aligned divisor) — the wide block is
                  the column-direction analogue of the paper's multi-tile
                  trick (several 128-lane tiles per visit).
      carry:      optional (..., out_rows, N) array aliased into the output;
                  rows not visited here keep its contents (fused mode).
      interpret:  run the Pallas interpreter (CPU validation); False on TPU.
      pipeline_depth: 1 (serial gather->contract, default) or 2 (double-
                  buffered B-panel prefetch: the next panel's rows assemble
                  into a ping-pong VMEM slot while this panel contracts).
                  Unbatched results are bitwise identical across depths
                  (the compute stream replays the depth-1 expression);
                  batched results agree to ~1 ulp (XLA's multiply-add
                  contraction differs across the two graphs).
    """
    if b.ndim not in (2, 3):
        raise ValueError(f"b must be (K, N) or (batch, K, N); got rank "
                         f"{b.ndim}")
    depth = check_pipeline_depth(pipeline_depth)
    npanels, g = panel_cols.shape
    n = b.shape[-1]
    bn = bn or default_bn(n)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype, out_dtype = resolve_dtypes(panel_vals.dtype, out_dtype)
    out_rows = out_rows or nrows
    has_carry = carry is not None
    batch = b.shape[0] if b.ndim == 3 else None
    bz = batch_block(batch) if batch is not None else 0
    grid, _ = grid_dims(batch=batch, bz=bz, n=n, bn=bn, npanels=npanels,
                        pipeline_depth=depth)

    def _rows(rows, k, j):
        return (rows[k], j)

    in_specs, args, aliases = panel_operands(
        g=g, bn=bn, vals_block=(1, g), vals=panel_vals, mask=panel_mask,
        b=b, carry=carry, carry_block=(1, bn), row_map=_rows,
        bz=None if batch is None else bz, pipeline_depth=depth,
        npanels=npanels)

    if depth == 1:
        def _out_k(k):
            return k
    else:
        def _out_k(k):
            return jnp.maximum(k - (depth - 1), 0)

    if batch is None:
        out_specs = pl.BlockSpec(
            (1, bn), lambda j, k, rows, cols: _rows(rows, _out_k(k), j))
        out_shape = jax.ShapeDtypeStruct((out_rows, n), out_dtype)
        acc_shape = (1, bn)
        bpan_shape = (depth, g, bn)
    else:
        out_specs = pl.BlockSpec(
            (bz, 1, bn),
            lambda z, j, k, rows, cols: (z,) + _rows(rows, _out_k(k), j))
        out_shape = jax.ShapeDtypeStruct((batch, out_rows, n), out_dtype)
        acc_shape = (bz, bn)
        bpan_shape = (depth, g, bz, bn)   # contiguous (bz, bn) row reads

    scratch = [pltpu.VMEM(acc_shape, acc_dtype)]
    if depth > 1:
        # Ping-pong B-panel buffer, packed in B's storage dtype (half
        # precision stays half-width in VMEM; promotion happens at the
        # multiply against the fp32-resident accumulator), plus the staged
        # mask panel the compute stream applies one step later.
        scratch.insert(0, pltpu.VMEM((depth, 1, g), panel_mask.dtype))
        scratch.insert(0, pltpu.VMEM(bpan_shape, b.dtype))
        kernel = functools.partial(_piped_panel_kernel, g, has_carry,
                                   None if batch is None else bz, depth)
    else:
        kernel = functools.partial(_panel_kernel, g, has_carry,
                                   None if batch is None else bz)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # panel_rows, panel_cols
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(panel_rows, panel_cols, *args)


@functools.partial(
    jax.jit,
    static_argnames=("nrows", "bn", "out_dtype", "interpret"))
def csr_spmm_pallas(row_ids: jax.Array, col_idx: jax.Array, vals: jax.Array,
                    b: jax.Array, *, nrows: int, bn: int | None = None,
                    out_dtype=None, interpret: bool = True) -> jax.Array:
    """Flat-array entry point: one nonzero per panel (G = 1).

    Packing a (row, col)-sorted nonzero stream into width-1 panels is pure
    reshaping, so this stays jit-traceable; format-level callers should
    prefer :func:`csr_panels_spmm_pallas` with a host-packed
    ``PanelCSR`` for real G-wide panels.
    """
    nnz = row_ids.shape[0]
    return csr_panels_spmm_pallas(
        row_ids, col_idx.reshape(nnz, 1), vals.reshape(nnz, 1),
        jnp.ones((nnz, 1), vals.dtype), b, nrows=nrows, bn=bn,
        out_dtype=out_dtype, interpret=interpret)


register_kernel("csr", "spmm", "panels", csr_panels_spmm_pallas)
register_kernel("csr", "spmm", "flat", csr_spmm_pallas)
