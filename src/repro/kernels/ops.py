"""Compatibility shim over :mod:`repro.kernels.engine`.

This module used to hold six near-duplicate dispatch entry points —
``csr_spmm``, ``bcsr_spmm``, ``loops_spmm_fused``, ``loops_sdd`` and the
``vals=``-override variants threaded through each, every one re-implementing
backend selection, precision promotion and the panel-value scatter.  That
logic now lives once in the registry-driven execution engine
(``kernels/engine.py``), which also adds the native batched ``(..., K, N)``
dense-operand contract; the names below are re-exports kept so existing
imports (``from repro.kernels import ops``) keep working.
"""
from __future__ import annotations

from .engine import (bcsr_spmm, csr_spmm, default_backend,  # noqa: F401
                     loops_sdd, loops_spmm_fused)

__all__ = ["csr_spmm", "bcsr_spmm", "loops_spmm_fused", "loops_sdd",
           "default_backend"]
