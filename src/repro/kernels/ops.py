"""jit'd dispatch wrappers over the LOOPS Pallas kernels.

``ops`` is the layer the rest of the framework calls: it accepts the host-side
format dataclasses (``repro.core.formats``), moves arrays to device, picks the
execution backend (Pallas-on-TPU, Pallas-interpret on CPU for validation, or
the pure-jnp reference), and handles precision promotion.

The Pallas backends execute G-wide panels when the caller supplies them
(``panels=``, from ``LoopsFormat.csr_panels``/``bcsr_panels``); otherwise they
fall back to the flat G=1 layout.  ``loops_spmm_fused`` is the single-pass
hybrid: both kernels write disjoint row ranges of one preallocated buffer via
``input_output_aliases`` + offset index_maps, so the output is produced with
no ``concatenate`` copy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .bcsr_spmm import bcsr_panels_spmm_pallas, bcsr_spmm_pallas
from .csr_spmm import csr_panels_spmm_pallas, csr_spmm_pallas

__all__ = ["csr_spmm", "bcsr_spmm", "loops_spmm_fused", "default_backend"]


def default_backend() -> str:
    """'pallas' on real TPUs, 'interpret' elsewhere (CPU validation), matching
    the assignment contract: TPU is the target, interpret mode the oracle
    runner."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def csr_spmm(csr, b: jax.Array, *, backend: str | None = None,
             bn: int | None = None, out_dtype=None, panels=None) -> jax.Array:
    """SpMM of a ``repro.core.formats.CSR`` against dense ``b`` (K, N).

    ``panels`` — a ``repro.core.formats.PanelCSR`` view of the same matrix —
    routes the Pallas backends through the G-wide panel kernel (one masked
    G-row gather + multiply-reduce per grid step instead of one nonzero).
    """
    backend = backend or default_backend()
    if backend == "jnp":
        return ref.csr_spmm_ref(jnp.asarray(csr.row_ids),
                                jnp.asarray(csr.col_idx),
                                jnp.asarray(csr.vals), b, csr.nrows,
                                out_dtype=out_dtype)
    interpret = backend == "interpret"
    if panels is not None:
        return csr_panels_spmm_pallas(
            jnp.asarray(panels.panel_rows), jnp.asarray(panels.panel_cols),
            jnp.asarray(panels.panel_vals), jnp.asarray(panels.panel_mask),
            b, nrows=csr.nrows, bn=bn, out_dtype=out_dtype,
            interpret=interpret)
    return csr_spmm_pallas(jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx),
                           jnp.asarray(csr.vals), b, nrows=csr.nrows,
                           bn=bn, out_dtype=out_dtype, interpret=interpret)


def bcsr_spmm(bcsr, b: jax.Array, *, backend: str | None = None,
              bn: int | None = None, out_dtype=None, panels=None) -> jax.Array:
    """SpMM of a ``repro.core.formats.VectorBCSR`` against dense ``b``.

    Returns the *logical* (bcsr.nrows, N) result (padding rows trimmed).
    ``panels`` — a ``repro.core.formats.PanelBCSR`` — routes the Pallas
    backends through the G-wide kernel (one (Br,G)@(G,bn) MXU matmul per
    grid step instead of a rank-1 update).
    """
    backend = backend or default_backend()
    if backend == "jnp":
        padded = ref.bcsr_spmm_ref(jnp.asarray(bcsr.tile_rows),
                                   jnp.asarray(bcsr.tile_cols),
                                   jnp.asarray(bcsr.tile_vals), b,
                                   bcsr.nblocks, out_dtype=out_dtype)
    elif panels is not None:
        padded = bcsr_panels_spmm_pallas(
            jnp.asarray(panels.panel_rows), jnp.asarray(panels.panel_cols),
            jnp.asarray(panels.panel_vals), jnp.asarray(panels.panel_mask),
            b, nblocks=panels.nblocks, bn=bn, out_dtype=out_dtype,
            interpret=(backend == "interpret"))
    else:
        padded = bcsr_spmm_pallas(jnp.asarray(bcsr.tile_rows),
                                  jnp.asarray(bcsr.tile_cols),
                                  jnp.asarray(bcsr.tile_vals), b,
                                  nblocks=bcsr.nblocks, bn=bn,
                                  out_dtype=out_dtype,
                                  interpret=(backend == "interpret"))
    return padded[:bcsr.nrows]


def loops_spmm_fused(fmt, b: jax.Array, *, backend: str | None = None,
                     bn: int | None = None, out_dtype=None) -> jax.Array:
    """Single-pass hybrid SpMM into ONE preallocated output.

    Pass 1 (CSR panels) allocates the full ``(r_boundary + nblocks*Br, N)``
    buffer and fills rows ``[0, r_boundary)``; pass 2 (BCSR panels) takes
    that buffer as an aliased carry and fills the remaining blocks at
    ``row_block_offset = r_boundary // Br`` — the pallas-level
    ``input_output_aliases`` keeps pass 1's rows intact with zero copies.
    No ``concatenate`` appears in the jaxpr; the only residual movement is
    the final ``[:nrows]`` trim when the last block-row overhangs.

    Requires both parts non-empty, panel views present, and ``r_boundary``
    aligned to ``Br`` (planners guarantee the alignment; ``loops_spmm``
    falls back to the two-output path otherwise).
    """
    backend = backend or default_backend()
    if backend == "jnp":
        raise ValueError("fused path is Pallas-only; use backend="
                         "'interpret' or 'pallas'")
    cp, bp = fmt.csr_panels, fmt.bcsr_panels
    r_b, br = fmt.r_boundary, bp.br
    if r_b % br or not 0 < r_b < fmt.nrows:
        raise ValueError(f"fused path needs 0 < r_boundary < nrows with "
                         f"r_boundary % Br == 0, got {r_b} (Br={br})")
    interpret = backend == "interpret"
    r_pad = r_b + bp.nblocks * br
    out = csr_panels_spmm_pallas(
        jnp.asarray(cp.panel_rows), jnp.asarray(cp.panel_cols),
        jnp.asarray(cp.panel_vals), jnp.asarray(cp.panel_mask),
        b, nrows=r_b, out_rows=r_pad, bn=bn, out_dtype=out_dtype,
        interpret=interpret)
    out = bcsr_panels_spmm_pallas(
        jnp.asarray(bp.panel_rows), jnp.asarray(bp.panel_cols),
        jnp.asarray(bp.panel_vals), jnp.asarray(bp.panel_mask),
        b, nblocks=bp.nblocks, row_block_offset=r_b // br, out_rows=r_pad,
        bn=bn, out_dtype=out_dtype, interpret=interpret, carry=out)
    return out if r_pad == fmt.nrows else out[:fmt.nrows]
