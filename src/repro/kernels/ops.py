"""jit'd dispatch wrappers over the LOOPS Pallas kernels.

``ops`` is the layer the rest of the framework calls: it accepts the host-side
format dataclasses (``repro.core.formats``), moves arrays to device, picks the
execution backend (Pallas-on-TPU, Pallas-interpret on CPU for validation, or
the pure-jnp reference), and handles precision promotion.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .bcsr_spmm import bcsr_spmm_pallas
from .csr_spmm import csr_spmm_pallas

__all__ = ["csr_spmm", "bcsr_spmm", "default_backend"]


def default_backend() -> str:
    """'pallas' on real TPUs, 'interpret' elsewhere (CPU validation), matching
    the assignment contract: TPU is the target, interpret mode the oracle
    runner."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def csr_spmm(csr, b: jax.Array, *, backend: str | None = None,
             bn: int | None = None, out_dtype=None) -> jax.Array:
    """SpMM of a ``repro.core.formats.CSR`` against dense ``b`` (K, N)."""
    backend = backend or default_backend()
    row_ids = jnp.asarray(csr.row_ids)
    col_idx = jnp.asarray(csr.col_idx)
    vals = jnp.asarray(csr.vals)
    if backend == "jnp":
        return ref.csr_spmm_ref(row_ids, col_idx, vals, b, csr.nrows,
                                out_dtype=out_dtype)
    return csr_spmm_pallas(row_ids, col_idx, vals, b, nrows=csr.nrows,
                           bn=bn, out_dtype=out_dtype,
                           interpret=(backend == "interpret"))


def bcsr_spmm(bcsr, b: jax.Array, *, backend: str | None = None,
              bn: int | None = None, out_dtype=None) -> jax.Array:
    """SpMM of a ``repro.core.formats.VectorBCSR`` against dense ``b``.

    Returns the *logical* (bcsr.nrows, N) result (padding rows trimmed).
    """
    backend = backend or default_backend()
    tile_rows = jnp.asarray(bcsr.tile_rows)
    tile_cols = jnp.asarray(bcsr.tile_cols)
    tile_vals = jnp.asarray(bcsr.tile_vals)
    if backend == "jnp":
        padded = ref.bcsr_spmm_ref(tile_rows, tile_cols, tile_vals, b,
                                   bcsr.nblocks, out_dtype=out_dtype)
    else:
        padded = bcsr_spmm_pallas(tile_rows, tile_cols, tile_vals, b,
                                  nblocks=bcsr.nblocks, bn=bn,
                                  out_dtype=out_dtype,
                                  interpret=(backend == "interpret"))
    return padded[:bcsr.nrows]
