"""jit'd dispatch wrappers over the LOOPS Pallas kernels.

``ops`` is the layer the rest of the framework calls: it accepts the host-side
format dataclasses (``repro.core.formats``), moves arrays to device, picks the
execution backend (Pallas-on-TPU, Pallas-interpret on CPU for validation, or
the pure-jnp reference), and handles precision promotion.

The Pallas backends execute G-wide panels when the caller supplies them
(``panels=``, from ``LoopsFormat.csr_panels``/``bcsr_panels``); otherwise they
fall back to the flat G=1 layout.  ``loops_spmm_fused`` is the single-pass
hybrid: both kernels write disjoint row ranges of one preallocated buffer via
``input_output_aliases`` + offset index_maps, so the output is produced with
no ``concatenate`` copy.

Autodiff support (two levers consumed by ``repro.core.spmm``'s custom VJP):

  * every forward entry point takes an optional ``vals``/``*_vals`` override
    — *traced* value arrays scattered into the static panel layout via the
    panels' ``src_panel``/``src_lane`` maps — so learned-sparse-weight
    layers execute (and re-execute, in the backward ``dB = Aᵀ·dY`` pass)
    the exact same kernels with live parameters;
  * ``loops_sdd`` dispatches the sampled dense-dense kernels
    (``repro.kernels.spmm_sdd``) that produce the gradient of A's stored
    values without ever materialising ``dY @ Bᵀ``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .bcsr_spmm import bcsr_panels_spmm_pallas, bcsr_spmm_pallas
from .csr_spmm import csr_panels_spmm_pallas, csr_spmm_pallas
from .spmm_sdd import bcsr_sdd_panels_pallas, csr_sdd_panels_pallas

__all__ = ["csr_spmm", "bcsr_spmm", "loops_spmm_fused", "loops_sdd",
           "default_backend"]


def default_backend() -> str:
    """'pallas' on real TPUs, 'interpret' elsewhere (CPU validation), matching
    the assignment contract: TPU is the target, interpret mode the oracle
    runner."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def _panel_vals(panels, vals):
    """Static host-packed panel values, or the traced scatter of ``vals``."""
    if vals is None:
        return jnp.asarray(panels.panel_vals)
    return panels.scatter_values(jnp.asarray(vals))


def csr_spmm(csr, b: jax.Array, *, backend: str | None = None,
             bn: int | None = None, out_dtype=None, panels=None,
             vals=None) -> jax.Array:
    """SpMM of a ``repro.core.formats.CSR`` against dense ``b`` (K, N).

    ``panels`` — a ``repro.core.formats.PanelCSR`` view of the same matrix —
    routes the Pallas backends through the G-wide panel kernel (one masked
    G-row gather + multiply-reduce per grid step instead of one nonzero).
    ``vals`` — optional traced (nnz,) values replacing ``csr.vals`` (live
    parameters of a learned-sparse layer); the structure stays static.
    """
    backend = backend or default_backend()
    if backend == "jnp":
        v = jnp.asarray(csr.vals) if vals is None else jnp.asarray(vals)
        return ref.csr_spmm_ref(jnp.asarray(csr.row_ids),
                                jnp.asarray(csr.col_idx),
                                v, b, csr.nrows, out_dtype=out_dtype)
    interpret = backend == "interpret"
    if panels is not None:
        return csr_panels_spmm_pallas(
            jnp.asarray(panels.panel_rows), jnp.asarray(panels.panel_cols),
            _panel_vals(panels, vals), jnp.asarray(panels.panel_mask),
            b, nrows=csr.nrows, bn=bn, out_dtype=out_dtype,
            interpret=interpret)
    v = jnp.asarray(csr.vals) if vals is None else jnp.asarray(vals)
    return csr_spmm_pallas(jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx),
                           v, b, nrows=csr.nrows,
                           bn=bn, out_dtype=out_dtype, interpret=interpret)


def bcsr_spmm(bcsr, b: jax.Array, *, backend: str | None = None,
              bn: int | None = None, out_dtype=None, panels=None,
              vals=None) -> jax.Array:
    """SpMM of a ``repro.core.formats.VectorBCSR`` against dense ``b``.

    Returns the *logical* (bcsr.nrows, N) result (padding rows trimmed).
    ``panels`` — a ``repro.core.formats.PanelBCSR`` — routes the Pallas
    backends through the G-wide kernel (one (Br,G)@(G,bn) MXU matmul per
    grid step instead of a rank-1 update).  ``vals`` — optional traced
    (ntiles, Br) tile values replacing ``bcsr.tile_vals``.
    """
    backend = backend or default_backend()
    if backend == "jnp":
        v = jnp.asarray(bcsr.tile_vals) if vals is None else jnp.asarray(vals)
        padded = ref.bcsr_spmm_ref(jnp.asarray(bcsr.tile_rows),
                                   jnp.asarray(bcsr.tile_cols),
                                   v, b, bcsr.nblocks, out_dtype=out_dtype)
    elif panels is not None:
        padded = bcsr_panels_spmm_pallas(
            jnp.asarray(panels.panel_rows), jnp.asarray(panels.panel_cols),
            _panel_vals(panels, vals), jnp.asarray(panels.panel_mask),
            b, nblocks=panels.nblocks, bn=bn, out_dtype=out_dtype,
            interpret=(backend == "interpret"))
    else:
        v = jnp.asarray(bcsr.tile_vals) if vals is None else jnp.asarray(vals)
        padded = bcsr_spmm_pallas(jnp.asarray(bcsr.tile_rows),
                                  jnp.asarray(bcsr.tile_cols),
                                  v, b, nblocks=bcsr.nblocks, bn=bn,
                                  out_dtype=out_dtype,
                                  interpret=(backend == "interpret"))
    return padded[:bcsr.nrows]


def loops_spmm_fused(fmt, b: jax.Array, *, backend: str | None = None,
                     bn: int | None = None, out_dtype=None,
                     csr_vals=None, bcsr_vals=None) -> jax.Array:
    """Single-pass hybrid SpMM into ONE preallocated output.

    Pass 1 (CSR panels) allocates the full ``(r_boundary + nblocks*Br, N)``
    buffer and fills rows ``[0, r_boundary)``; pass 2 (BCSR panels) takes
    that buffer as an aliased carry and fills the remaining blocks at
    ``row_block_offset = r_boundary // Br`` — the pallas-level
    ``input_output_aliases`` keeps pass 1's rows intact with zero copies.
    No ``concatenate`` appears in the jaxpr; the only residual movement is
    the final ``[:nrows]`` trim when the last block-row overhangs.

    Requires both parts non-empty, panel views present, and ``r_boundary``
    aligned to ``Br`` (planners guarantee the alignment; ``loops_spmm``
    falls back to the two-output path otherwise).  ``csr_vals``/``bcsr_vals``
    optionally substitute traced live values for the host-packed constants
    — the aliasing is on the carry operand, so the fused single-pass shape
    of the computation is identical either way.
    """
    backend = backend or default_backend()
    if backend == "jnp":
        raise ValueError("fused path is Pallas-only; use backend="
                         "'interpret' or 'pallas'")
    cp, bp = fmt.csr_panels, fmt.bcsr_panels
    r_b, br = fmt.r_boundary, bp.br
    if r_b % br or not 0 < r_b < fmt.nrows:
        raise ValueError(f"fused path needs 0 < r_boundary < nrows with "
                         f"r_boundary % Br == 0, got {r_b} (Br={br})")
    interpret = backend == "interpret"
    r_pad = r_b + bp.nblocks * br
    out = csr_panels_spmm_pallas(
        jnp.asarray(cp.panel_rows), jnp.asarray(cp.panel_cols),
        _panel_vals(cp, csr_vals), jnp.asarray(cp.panel_mask),
        b, nrows=r_b, out_rows=r_pad, bn=bn, out_dtype=out_dtype,
        interpret=interpret)
    out = bcsr_panels_spmm_pallas(
        jnp.asarray(bp.panel_rows), jnp.asarray(bp.panel_cols),
        _panel_vals(bp, bcsr_vals), jnp.asarray(bp.panel_mask),
        b, nblocks=bp.nblocks, row_block_offset=r_b // br, out_rows=r_pad,
        bn=bn, out_dtype=out_dtype, interpret=interpret, carry=out)
    return out if r_pad == fmt.nrows else out[:fmt.nrows]


def loops_sdd(fmt, dy: jax.Array, b: jax.Array, *,
              backend: str | None = None, bn: int | None = None):
    """Gradient of ``Y = A @ B`` w.r.t. A's stored values (both parts).

    Args:
      fmt: the forward :class:`~repro.core.formats.LoopsFormat` (structure
        source — its value arrays are not read).
      dy:  (nrows, N) output cotangent.
      b:   (K, N) the forward dense operand.
    Returns:
      ``(d_csr_vals, d_bcsr_tile_vals)`` with shapes ``(nnz_csr,)`` and
      ``(ntiles, Br)`` in the accumulation dtype (callers cast back to the
      parameter dtype).  Pallas backends run the G-wide SDD kernels
      (``repro.kernels.spmm_sdd``); the jnp backend runs the gather-based
      references — both sample ``dY @ Bᵀ`` only at stored coordinates.
    """
    backend = backend or default_backend()
    csr, bc = fmt.csr_part, fmt.bcsr_part
    nblocks, br = bc.nblocks, bc.br
    acc = ref.acc_dtype_for(b.dtype)
    has_csr = fmt.r_boundary > 0
    has_bcsr = fmt.r_boundary < fmt.nrows
    # BCSR region of the cotangent, zero-padded to whole blocks: rows the
    # forward pass trims carry exactly zero gradient.
    dy_b = dy[fmt.r_boundary:]
    pad = nblocks * br - dy_b.shape[0]
    dy_pad = jnp.pad(dy_b, ((0, pad), (0, 0))) if pad else dy_b
    if backend == "jnp":
        d_csr = ref.csr_sdd_ref(jnp.asarray(csr.row_ids),
                                jnp.asarray(csr.col_idx), dy, b) \
            if has_csr else jnp.zeros((csr.nnz,), acc)
        d_bcsr = ref.bcsr_sdd_ref(jnp.asarray(bc.tile_rows),
                                  jnp.asarray(bc.tile_cols), dy_pad, b,
                                  nblocks) \
            if has_bcsr else jnp.zeros(bc.tile_vals.shape, acc)
        return d_csr, d_bcsr
    interpret = backend == "interpret"
    cp, bp = fmt.csr_panels, fmt.bcsr_panels
    if has_csr:
        d_csr = cp.gather_values(csr_sdd_panels_pallas(
            jnp.asarray(cp.panel_rows), jnp.asarray(cp.panel_cols), dy, b,
            bn=bn, interpret=interpret))
    else:
        d_csr = jnp.zeros((csr.nnz,), acc)
    if has_bcsr:
        d_bcsr = bp.gather_values(bcsr_sdd_panels_pallas(
            jnp.asarray(bp.panel_rows), jnp.asarray(bp.panel_cols), dy_pad,
            b, br=br, bn=bn, interpret=interpret))
    else:
        d_bcsr = jnp.zeros(bc.tile_vals.shape, acc)
    return d_csr, d_bcsr
