"""Batched SpMM execution engine — the one dispatch layer over the kernels.

Historically ``kernels/ops.py`` grew six near-duplicate entry points
(``csr_spmm``, ``bcsr_spmm``, ``loops_spmm_fused``, ``loops_sdd`` plus the
``vals=``-override variants threaded through each), every one re-implementing
the same three decisions: which backend executes, how half precision promotes,
and how traced values ride the static panel layout.  This module collapses
them into a single engine:

  * **one registry** — kernel implementations are registered under a
    ``(part, op)`` key (``part`` ∈ {"csr", "bcsr"}, ``op`` ∈ {"spmm", "sdd"})
    with an implementation flavour per backend class (``panels`` — the G-wide
    Pallas kernels, ``flat`` — the G=1 wrappers, ``ref`` — the jnp oracles).
    The kernel home modules register themselves on import
    (:func:`register_kernel`); dispatch resolves through :func:`get_kernel`.
  * **one precision-promotion path** — :func:`acc_dtype_for` /
    :func:`resolve_dtypes` are defined here and re-exported by ``ref.py``
    (the ``{bf16, f16} → fp32-accumulate`` contract lives in exactly one
    place);
  * **one backend-pick path** — :func:`resolve_backend`;
  * **one panel-vals scatter path** — :func:`panel_values` (traced live
    values into the static panel layout);
  * **one shape contract** — every entry point accepts a dense operand of
    shape ``(..., K, N)``.  Leading dimensions are flattened into the
    kernels' native batch grid dimension (:func:`flatten_batch`); rank or
    K mismatches raise a clear :class:`ValueError` (:func:`check_rhs`)
    instead of an opaque Pallas shape error, and an empty batch returns
    correctly-shaped zeros on every backend.

Batched execution (ROADMAP: "heavy traffic, many scenarios")
------------------------------------------------------------
The Pallas kernels take a leading batch grid dimension and block it by
:func:`batch_block` (``bz`` slices per grid step, VMEM-bounded): one grid
step loads A's panel once and applies it to ``bz`` batch slices of B, so the
grid-step count grows by ``ceil(batch / bz)`` — NOT by ``batch`` — relative
to the unbatched call.  A per-element Python loop pays ``batch ×`` steps and
``batch ×`` dispatches; the native batched call pays one dispatch and, for
``batch ≤ MAX_BATCH_BLOCK``, the *same* step count as a single-element call.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..resilience import fallback as _fallback

__all__ = [
    "acc_dtype_for", "resolve_dtypes", "default_backend", "resolve_backend",
    "check_rhs", "flatten_batch", "unflatten_batch", "batch_block",
    "padded_batch", "MAX_BATCH_BLOCK", "register_kernel", "get_kernel",
    "panel_values", "csr_spmm", "bcsr_spmm", "loops_spmm_fused", "loops_sdd",
    "set_tracer", "get_tracer",
]

# Max batch slices processed per kernel grid step.  8 slices × bn=512 lanes
# × 4 bytes ≈ 16 KiB per gathered B row — G of those plus the accumulator
# stay comfortably inside VMEM while buying up to an 8× grid-step reduction
# over per-element execution.
MAX_BATCH_BLOCK = 8


# ---------------------------------------------------------------------------
# precision promotion (the ONE copy; ref.py re-exports for compatibility)
# ---------------------------------------------------------------------------

def acc_dtype_for(dtype) -> jnp.dtype:
    """fp32 accumulation for half precision (the paper's f16f16f32 contract,
    realised on TPU as the native bf16xbf16->f32 MXU mode); otherwise the
    input precision.  Canonicalised so f64 degrades to f32 when x64 is off."""
    dtype = jax.dtypes.canonicalize_dtype(jnp.dtype(dtype))
    if dtype in (jnp.bfloat16, jnp.float16):
        return jnp.dtype(jnp.float32)
    return dtype


def resolve_dtypes(value_dtype, out_dtype) -> Tuple[jnp.dtype, jnp.dtype]:
    """``(accumulation dtype, output dtype)`` for stored values of
    ``value_dtype`` — the promotion decision every kernel and dispatch layer
    shares.  ``out_dtype`` (when given) overrides the output only; the
    accumulator always follows the promotion contract."""
    acc = acc_dtype_for(value_dtype)
    return acc, (jnp.dtype(out_dtype) if out_dtype is not None else acc)


# ---------------------------------------------------------------------------
# backend pick (the ONE copy)
# ---------------------------------------------------------------------------

def default_backend() -> str:
    """'pallas' on real TPUs, 'interpret' elsewhere (CPU validation), matching
    the assignment contract: TPU is the target, interpret mode the oracle
    runner."""
    return "pallas" if jax.default_backend() == "tpu" else "interpret"


def resolve_backend(backend: str | None) -> str:
    """Normalise a caller's backend choice (``None`` → platform default)."""
    backend = backend or default_backend()
    if backend not in ("pallas", "interpret", "jnp"):
        raise ValueError(f"unknown backend {backend!r}; expected 'pallas', "
                         "'interpret' or 'jnp'")
    return backend


# ---------------------------------------------------------------------------
# the (..., K, N) shape contract
# ---------------------------------------------------------------------------

def check_rhs(ncols: int, b, *, what: str = "B") -> None:
    """Validate the dense operand's shape contract ``(..., K, N)`` against
    A's column count, raising a clear ValueError instead of letting a rank
    or contraction mismatch surface as an opaque Pallas shape error."""
    if b.ndim < 2:
        raise ValueError(
            f"dense operand {what} must have shape (..., K, N); got rank "
            f"{b.ndim} with shape {tuple(b.shape)}")
    if b.shape[-2] != ncols:
        raise ValueError(
            f"dense operand {what} has K={b.shape[-2]} rows but A has "
            f"ncols={ncols}; shapes must contract as (M, K) @ (..., K, N)")


def flatten_batch(b: jax.Array) -> Tuple[jax.Array, Tuple[int, ...]]:
    """``(..., K, N)`` → ``((B, K, N) or (K, N), leading batch shape)``.

    Rank ≤ 3 passes through untouched (no reshape in the jaxpr); higher
    ranks collapse every leading dim into the kernels' single native batch
    grid dimension."""
    if b.ndim <= 3:
        return b, b.shape[:-2]
    batch = b.shape[:-2]
    return b.reshape((-1,) + b.shape[-2:]), batch


def unflatten_batch(out: jax.Array, batch: Tuple[int, ...]) -> jax.Array:
    """Inverse of :func:`flatten_batch` on the kernel output's leading dim."""
    if out.ndim == 2 or len(batch) == 1:
        return out
    return out.reshape(batch + out.shape[-2:])


def batch_block(batch: int) -> int:
    """Batch slices per grid step: the largest divisor of ``batch`` that is
    ≤ :data:`MAX_BATCH_BLOCK` (the grid needs ``batch % bz == 0``).  The
    engine entry points first round the flat batch up to
    :func:`padded_batch`, so an awkward size (a prime beyond the cap) is
    zero-padded into a wide block instead of degrading to per-slice
    steps."""
    if batch <= 0:
        return 1
    for d in range(min(batch, MAX_BATCH_BLOCK), 0, -1):
        if batch % d == 0:
            return d
    return 1


def padded_batch(batch: int) -> int:
    """Flat batch size after zero-padding to the step-minimising block.

    Two candidates per size: keep ``batch`` and block by its largest
    divisor ≤ :data:`MAX_BATCH_BLOCK` (no padded compute), or round up to a
    multiple of ``min(batch, MAX_BATCH_BLOCK)`` (full-width blocks, some
    zero slices).  Whichever yields fewer grid-step groups wins; ties keep
    the unpadded batch.  E.g. 12 stays 12 (bz=6, 2 groups), 11 pads to 16
    (bz=8, 2 groups instead of 11).  ``batch_block`` of the returned size
    recovers the chosen block width."""
    if batch <= 0:
        return batch
    bz_pad = min(batch, MAX_BATCH_BLOCK)
    groups_pad = -(-batch // bz_pad)
    if groups_pad < batch // batch_block(batch):
        return groups_pad * bz_pad
    return batch


def _pad_flat_batch(x: jax.Array) -> jax.Array:
    """Zero-pad a flat-batched ``(B, ..., N)`` operand to ``padded_batch(B)``
    slices (rank-2 operands pass through).  Padding slices are all-zero, so
    they contribute zero rows (trimmed by the caller) to a forward product
    and zero terms to the SDD batch sum."""
    if x.ndim == 2:
        return x
    nb = x.shape[0]
    target = padded_batch(nb)
    if target == nb:
        return x
    return jnp.concatenate(
        [x, jnp.zeros((target - nb,) + x.shape[1:], x.dtype)])


def _empty_batch(b) -> bool:
    return any(d == 0 for d in b.shape[:-2])


# ---------------------------------------------------------------------------
# dispatch tracer (repro.perf.trace attaches here)
# ---------------------------------------------------------------------------

# A single process-wide tracer hook.  The entry points below call
# ``_note(part, op, ...)`` with STRUCTURAL dispatch facts (which kernel
# flavour ran, how many panels/nonzeros the grid walks, the flat batch and
# column extents).  The calls fire at trace time — under ``jax.jit`` that is
# once per compilation, not once per execution — so a tracer must never
# record wall-clock here; timing belongs at blocking call sites
# (``repro.perf.trace.TraceRecorder``'s timed wrappers).
_TRACER = None


def set_tracer(tracer):
    """Install ``tracer`` (an object with ``on_dispatch(**fields)``, or
    ``None`` to detach) as the engine's dispatch hook; returns the previous
    tracer so callers can restore it."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def get_tracer():
    return _TRACER


def _note(part: str, op: str, **fields) -> None:
    if _TRACER is None:
        return
    if "steps" not in fields:
        # Grid steps this dispatch walks: panels × batch groups for the
        # Pallas kernels (the flat batch is already padded to a multiple of
        # its block), or plain units for the gather-based references.
        units = int(fields.get("units", 0))
        nb = int(fields.get("batch", 1))
        if fields.get("impl") == "ref":
            fields["steps"] = units
        else:
            fields["steps"] = units * max(-(-nb // batch_block(nb)), 1)
    _TRACER.on_dispatch(part=part, op=op, **fields)


def _panel_note_fields(*, part: str, depth: int, npanels: int, nb: int,
                       n: int, bn: int | None, g: int, br: int,
                       b_dtype, value_dtype) -> dict:
    """Pipeline observability fields for a G-wide panel dispatch.

    ``steps`` — grid steps including the ``depth - 1`` fill/drain ramp
    (× batch groups, matching ``_note``'s default accounting at depth 1);
    ``scratch_bytes`` — VMEM scratch footprint (accumulator + the packed
    ping-pong B-panel buffer, which stays in B's storage dtype);
    ``prefetch_overlap`` — fraction of grid steps whose B-row gathers
    overlap a contraction (0.0 for the serial depth-1 kernels).
    """
    from .panel_common import default_bn
    groups = max(-(-nb // batch_block(nb)), 1)
    bz = batch_block(nb)
    bn_eff = bn or default_bn(n)
    acc = acc_dtype_for(value_dtype)
    acc_rows = br if part == "bcsr" else 1
    scratch = bz * acc_rows * bn_eff * jnp.dtype(acc).itemsize
    b_item = jnp.dtype(b_dtype).itemsize
    if part == "bcsr":
        bpan_elems = max(depth, 1) * g * bn_eff * bz
    else:   # depth-1 CSR reads gathered B rows directly (no staging buffer)
        bpan_elems = depth * g * bn_eff * bz if depth > 1 else 0
    steps = npanels + depth - 1
    overlap = (max(npanels - 1, 0) / steps) if depth > 1 else 0.0
    return {"pipeline_depth": depth,
            "steps": steps * groups,
            "scratch_bytes": int(scratch + bpan_elems * b_item),
            "prefetch_overlap": float(overlap)}


# ---------------------------------------------------------------------------
# kernel registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[Tuple[str, str], Dict[str, Callable]] = {}
_POPULATED = False


def register_kernel(part: str, op: str, impl: str, fn: Callable) -> Callable:
    """Register a kernel implementation under ``(part, op)`` with flavour
    ``impl`` ∈ {"panels", "flat", "ref"}.  Called by the kernel home modules
    at import time; idempotent (last registration wins)."""
    _REGISTRY.setdefault((part, op), {})[impl] = fn
    return fn


def get_kernel(part: str, op: str, impl: str = "panels") -> Callable:
    """Resolve a registered kernel, importing the kernel homes on first use
    (registration is a side effect of importing them — lazy so this module
    never holds a static import cycle with the kernels it dispatches)."""
    global _POPULATED
    if not _POPULATED:
        from . import bcsr_spmm, csr_spmm, ref, spmm_sdd  # noqa: F401
        _POPULATED = True
    try:
        return _REGISTRY[(part, op)][impl]
    except KeyError:
        raise KeyError(f"no kernel registered for part={part!r} op={op!r} "
                       f"impl={impl!r}; known: {sorted(_REGISTRY)}") from None


# ---------------------------------------------------------------------------
# panel-value scatter (the ONE copy)
# ---------------------------------------------------------------------------

def panel_values(panels, vals):
    """Static host-packed panel values, or the traced scatter of ``vals``
    into the panels' ``src_panel``/``src_lane`` layout (live parameters of a
    learned-sparse layer ride the static structure)."""
    if vals is None:
        return jnp.asarray(panels.panel_vals)
    return panels.scatter_values(jnp.asarray(vals))


# ---------------------------------------------------------------------------
# dispatch entry points
# ---------------------------------------------------------------------------

def csr_spmm(csr, b: jax.Array, *, backend: str | None = None,
             bn: int | None = None, out_dtype=None, panels=None,
             vals=None, pipeline_depth: int = 1) -> jax.Array:
    """SpMM of a ``repro.core.formats.CSR`` against dense ``b`` (..., K, N).

    ``panels`` — a ``repro.core.formats.PanelCSR`` view of the same matrix —
    routes the Pallas backends through the G-wide panel kernel.  ``vals`` —
    optional traced (nnz,) values replacing ``csr.vals``.  Leading batch
    dims of ``b`` execute as the kernels' native batch grid dimension.
    ``pipeline_depth=2`` double-buffers the B-row gathers on the panel
    kernel (ignored by the flat and jnp paths).
    """
    backend = resolve_backend(backend)
    check_rhs(csr.ncols, b)
    v = jnp.asarray(csr.vals) if vals is None else jnp.asarray(vals)
    if _empty_batch(b):
        _, out = resolve_dtypes(v.dtype, out_dtype)
        return jnp.zeros(b.shape[:-2] + (csr.nrows, b.shape[-1]), out)

    def attempt(bk: str) -> jax.Array:
        if bk == "jnp":
            _note("csr", "spmm", backend=bk, impl="ref", units=csr.nnz,
                  batch=1, n=int(b.shape[-1]))
            return get_kernel("csr", "spmm", "ref")(
                jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx), v, b,
                csr.nrows, out_dtype=out_dtype)
        interpret = bk == "interpret"
        b3, batch = flatten_batch(b)
        b3p = _pad_flat_batch(b3)
        nb = int(b3p.shape[0]) if b3p.ndim == 3 else 1
        depth = int(pipeline_depth) if panels is not None else 1
        extra = _panel_note_fields(
            part="csr", depth=depth, npanels=int(panels.npanels), nb=nb,
            n=int(b.shape[-1]), bn=bn, g=int(panels.g), br=1,
            b_dtype=b.dtype, value_dtype=v.dtype) if panels is not None else {}
        _note("csr", "spmm", backend=bk,
              impl="panels" if panels is not None else "flat",
              units=int(panels.npanels) if panels is not None
              else int(csr.nnz),
              batch=nb, n=int(b.shape[-1]), **extra)
        if panels is not None:
            out = get_kernel("csr", "spmm", "panels")(
                jnp.asarray(panels.panel_rows), jnp.asarray(panels.panel_cols),
                panel_values(panels, vals), jnp.asarray(panels.panel_mask),
                b3p, nrows=csr.nrows, bn=bn, out_dtype=out_dtype,
                interpret=interpret, pipeline_depth=depth)
        else:
            out = get_kernel("csr", "spmm", "flat")(
                jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx), v, b3p,
                nrows=csr.nrows, bn=bn, out_dtype=out_dtype,
                interpret=interpret)
        if b3p is not b3:
            out = out[:b3.shape[0]]
        return unflatten_batch(out, batch)

    return _fallback.run_chain("csr", "spmm", backend, attempt)


def bcsr_spmm(bcsr, b: jax.Array, *, backend: str | None = None,
              bn: int | None = None, out_dtype=None, panels=None,
              vals=None, pipeline_depth: int = 1) -> jax.Array:
    """SpMM of a ``repro.core.formats.VectorBCSR`` against dense ``b``.

    Returns the *logical* (..., bcsr.nrows, N) result (padding rows
    trimmed).  ``panels`` — a ``repro.core.formats.PanelBCSR`` — routes the
    Pallas backends through the G-wide kernel; ``vals`` — optional traced
    (ntiles, Br) tile values replacing ``bcsr.tile_vals``.
    """
    backend = resolve_backend(backend)
    check_rhs(bcsr.ncols, b)
    v = jnp.asarray(bcsr.tile_vals) if vals is None else jnp.asarray(vals)
    if _empty_batch(b):
        _, out = resolve_dtypes(v.dtype, out_dtype)
        return jnp.zeros(b.shape[:-2] + (bcsr.nrows, b.shape[-1]), out)

    def attempt(bk: str) -> jax.Array:
        if bk == "jnp":
            _note("bcsr", "spmm", backend=bk, impl="ref",
                  units=int(bcsr.ntiles), batch=1, n=int(b.shape[-1]))
            padded = get_kernel("bcsr", "spmm", "ref")(
                jnp.asarray(bcsr.tile_rows), jnp.asarray(bcsr.tile_cols), v,
                b, bcsr.nblocks, out_dtype=out_dtype)
            return padded[..., :bcsr.nrows, :]
        interpret = bk == "interpret"
        b3, batch = flatten_batch(b)
        b3p = _pad_flat_batch(b3)
        nb = int(b3p.shape[0]) if b3p.ndim == 3 else 1
        depth = int(pipeline_depth) if panels is not None else 1
        extra = _panel_note_fields(
            part="bcsr", depth=depth, npanels=int(panels.npanels), nb=nb,
            n=int(b.shape[-1]), bn=bn, g=int(panels.g), br=int(panels.br),
            b_dtype=b.dtype, value_dtype=v.dtype) if panels is not None else {}
        _note("bcsr", "spmm", backend=bk,
              impl="panels" if panels is not None else "flat",
              units=int(panels.npanels) if panels is not None
              else int(bcsr.ntiles),
              batch=nb, n=int(b.shape[-1]), **extra)
        if panels is not None:
            padded = get_kernel("bcsr", "spmm", "panels")(
                jnp.asarray(panels.panel_rows), jnp.asarray(panels.panel_cols),
                panel_values(panels, vals), jnp.asarray(panels.panel_mask),
                b3p, nblocks=panels.nblocks, bn=bn, out_dtype=out_dtype,
                interpret=interpret, pipeline_depth=depth)
        else:
            padded = get_kernel("bcsr", "spmm", "flat")(
                jnp.asarray(bcsr.tile_rows), jnp.asarray(bcsr.tile_cols), v,
                b3p, nblocks=bcsr.nblocks, bn=bn, out_dtype=out_dtype,
                interpret=interpret)
        if b3p is not b3:
            padded = padded[:b3.shape[0]]
        return unflatten_batch(padded[..., :bcsr.nrows, :], batch)

    return _fallback.run_chain("bcsr", "spmm", backend, attempt)


def loops_spmm_fused(fmt, b: jax.Array, *, backend: str | None = None,
                     bn: int | None = None, out_dtype=None,
                     csr_vals=None, bcsr_vals=None,
                     pipeline_depth: int = 1) -> jax.Array:
    """Single-pass hybrid SpMM into ONE preallocated output.

    Pass 1 (CSR panels) allocates the full ``(..., r_boundary + nblocks*Br,
    N)`` buffer and fills rows ``[0, r_boundary)``; pass 2 (BCSR panels)
    takes that buffer as an aliased carry and fills the remaining blocks at
    ``row_block_offset = r_boundary // Br`` — the pallas-level
    ``input_output_aliases`` keeps pass 1's rows intact with zero copies,
    per batch element.  No ``concatenate`` appears in the jaxpr; the only
    residual movement is the final row trim when the last block-row
    overhangs.

    Requires both parts non-empty, panel views present, and ``r_boundary``
    aligned to ``Br`` (planners guarantee the alignment; ``loops_spmm``
    falls back to the two-output path otherwise).  ``csr_vals``/``bcsr_vals``
    optionally substitute traced live values for the host-packed constants.
    """
    backend = resolve_backend(backend)
    if backend == "jnp":
        raise ValueError("fused path is Pallas-only; use backend="
                         "'interpret' or 'pallas'")
    check_rhs(fmt.ncols, b)
    cp, bp = fmt.csr_panels, fmt.bcsr_panels
    r_b, br = fmt.r_boundary, bp.br
    if r_b % br or not 0 < r_b < fmt.nrows:
        raise ValueError(f"fused path needs 0 < r_boundary < nrows with "
                         f"r_boundary % Br == 0, got {r_b} (Br={br})")
    if _empty_batch(b):
        _, out = resolve_dtypes(fmt.csr_part.vals.dtype, out_dtype)
        return jnp.zeros(b.shape[:-2] + (fmt.nrows, b.shape[-1]), out)

    def attempt(bk: str) -> jax.Array:
        interpret = bk == "interpret"
        b3, batch = flatten_batch(b)
        b3p = _pad_flat_batch(b3)
        nb = int(b3p.shape[0]) if b3p.ndim == 3 else 1
        depth = int(pipeline_depth)
        vdt = fmt.csr_part.vals.dtype
        _note("csr", "spmm", backend=bk, impl="panels", fused=True,
              units=int(cp.npanels), batch=nb, n=int(b.shape[-1]),
              **_panel_note_fields(
                  part="csr", depth=depth, npanels=int(cp.npanels), nb=nb,
                  n=int(b.shape[-1]), bn=bn, g=int(cp.g), br=1,
                  b_dtype=b.dtype, value_dtype=vdt))
        _note("bcsr", "spmm", backend=bk, impl="panels", fused=True,
              units=int(bp.npanels), batch=nb, n=int(b.shape[-1]),
              **_panel_note_fields(
                  part="bcsr", depth=depth, npanels=int(bp.npanels), nb=nb,
                  n=int(b.shape[-1]), bn=bn, g=int(bp.g), br=int(bp.br),
                  b_dtype=b.dtype, value_dtype=vdt))
        r_pad = r_b + bp.nblocks * br
        out = get_kernel("csr", "spmm", "panels")(
            jnp.asarray(cp.panel_rows), jnp.asarray(cp.panel_cols),
            panel_values(cp, csr_vals), jnp.asarray(cp.panel_mask),
            b3p, nrows=r_b, out_rows=r_pad, bn=bn, out_dtype=out_dtype,
            interpret=interpret, pipeline_depth=depth)
        out = get_kernel("bcsr", "spmm", "panels")(
            jnp.asarray(bp.panel_rows), jnp.asarray(bp.panel_cols),
            panel_values(bp, bcsr_vals), jnp.asarray(bp.panel_mask),
            b3p, nblocks=bp.nblocks, row_block_offset=r_b // br,
            out_rows=r_pad, bn=bn, out_dtype=out_dtype, interpret=interpret,
            carry=out, pipeline_depth=depth)
        if b3p is not b3:
            out = out[:b3.shape[0]]
        if r_pad != fmt.nrows:
            out = out[..., :fmt.nrows, :]
        return unflatten_batch(out, batch)

    # The fused chain ends at interpret (no jnp single-pass exists);
    # core.spmm._loops_execute catches an exhausted chain and degrades to
    # the two-pass parts path, whose per-part chains reach the oracle.
    return _fallback.run_chain("fused", "spmm", backend, attempt)


def loops_sdd(fmt, dy: jax.Array, b: jax.Array, *,
              backend: str | None = None, bn: int | None = None,
              pipeline_depth: int = 1):
    """Gradient of ``Y = A @ B`` w.r.t. A's stored values (both parts).

    Args:
      fmt: the forward :class:`~repro.core.formats.LoopsFormat` (structure
        source — its value arrays are not read).
      dy:  (..., nrows, N) output cotangent.
      b:   (..., K, N) the forward dense operand (leading dims must match
        ``dy``'s).
    Returns:
      ``(d_csr_vals, d_bcsr_tile_vals)`` with shapes ``(nnz_csr,)`` and
      ``(ntiles, Br)`` in the accumulation dtype — **summed over any batch
      dims** (the stored values are shared across the batch, so their
      cotangent is the batch sum).  Pallas backends run the G-wide SDD
      kernels with the batch folded into the grid; the jnp backend runs the
      gather-based references — both sample ``dY @ Bᵀ`` only at stored
      coordinates.

    Under ``jax.vmap`` a custom batching rule unrolls per mapped element
    (each element then carries its *own* value cotangent — vmap semantics,
    not the shared-values batch sum).
    """
    backend = resolve_backend(backend)
    check_rhs(fmt.ncols, b)
    if dy.shape[:-2] != b.shape[:-2]:
        raise ValueError(f"dy batch dims {dy.shape[:-2]} do not match b "
                         f"batch dims {b.shape[:-2]}")
    if backend == "jnp" or _empty_batch(b):
        return _loops_sdd_impl(fmt, dy, b, backend, bn, pipeline_depth)

    def attempt(bk: str):
        if bk == "jnp":
            return _loops_sdd_impl(fmt, dy, b, bk, bn, pipeline_depth)

        @jax.custom_batching.custom_vmap
        def call(dy_, b_):
            return _loops_sdd_impl(fmt, dy_, b_, bk, bn, pipeline_depth)

        @call.def_vmap
        def _vmap_rule(axis_size, in_batched, dy_, b_):
            dy_b, b_b = in_batched
            outs = [loops_sdd(fmt, dy_[i] if dy_b else dy_,
                              b_[i] if b_b else b_, backend=bk, bn=bn,
                              pipeline_depth=pipeline_depth)
                    for i in range(axis_size)]
            return (jnp.stack([o[0] for o in outs]),
                    jnp.stack([o[1] for o in outs])), (True, True)

        return call(dy, b)

    return _fallback.run_chain("loops", "sdd", backend, attempt)


def _loops_sdd_impl(fmt, dy, b, backend, bn, pipeline_depth=1):
    """The actual SDD dispatch (batch summed); see :func:`loops_sdd`."""
    csr, bc = fmt.csr_part, fmt.bcsr_part
    nblocks, br = bc.nblocks, bc.br
    acc, _ = resolve_dtypes(b.dtype, None)
    has_csr = fmt.r_boundary > 0
    has_bcsr = fmt.r_boundary < fmt.nrows
    if _empty_batch(b):
        return (jnp.zeros((csr.nnz,), acc),
                jnp.zeros(bc.tile_vals.shape, acc))
    # BCSR region of the cotangent, zero-padded to whole blocks: rows the
    # forward pass trims carry exactly zero gradient.
    dy_b = dy[..., fmt.r_boundary:, :]
    pad = nblocks * br - dy_b.shape[-2]
    if pad:
        widths = [(0, 0)] * (dy_b.ndim - 2) + [(0, pad), (0, 0)]
        dy_pad = jnp.pad(dy_b, widths)
    else:
        dy_pad = dy_b
    if backend == "jnp":
        d_csr = get_kernel("csr", "sdd", "ref")(
            jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx), dy, b) \
            if has_csr else jnp.zeros((csr.nnz,), acc)
        d_bcsr = get_kernel("bcsr", "sdd", "ref")(
            jnp.asarray(bc.tile_rows), jnp.asarray(bc.tile_cols), dy_pad, b,
            nblocks) \
            if has_bcsr else jnp.zeros(bc.tile_vals.shape, acc)
        return d_csr, d_bcsr
    interpret = backend == "interpret"
    # Zero pad-slices contribute zero terms to the batch sum, so the SDD
    # outputs need no trim.
    b3 = _pad_flat_batch(flatten_batch(b)[0])
    dy3 = _pad_flat_batch(flatten_batch(dy)[0])
    dy_pad3 = _pad_flat_batch(flatten_batch(dy_pad)[0])
    cp, bp = fmt.csr_panels, fmt.bcsr_panels
    nb = int(b3.shape[0]) if b3.ndim == 3 else 1
    depth = int(pipeline_depth)
    if has_csr:
        _note("csr", "sdd", backend=backend, impl="panels",
              units=int(cp.npanels), batch=nb, n=int(b.shape[-1]),
              pipeline_depth=depth)
    if has_bcsr:
        _note("bcsr", "sdd", backend=backend, impl="panels",
              units=int(bp.npanels), batch=nb, n=int(b.shape[-1]),
              pipeline_depth=depth)
    if has_csr:
        d_csr = cp.gather_values(get_kernel("csr", "sdd", "panels")(
            jnp.asarray(cp.panel_rows), jnp.asarray(cp.panel_cols), dy3, b3,
            bn=bn, interpret=interpret, pipeline_depth=depth))
    else:
        d_csr = jnp.zeros((csr.nnz,), acc)
    if has_bcsr:
        d_bcsr = bp.gather_values(get_kernel("bcsr", "sdd", "panels")(
            jnp.asarray(bp.panel_rows), jnp.asarray(bp.panel_cols), dy_pad3,
            b3, br=br, bn=bn, interpret=interpret, pipeline_depth=depth))
    else:
        d_bcsr = jnp.zeros(bc.tile_vals.shape, acc)
    return d_csr, d_bcsr
