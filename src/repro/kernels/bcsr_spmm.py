"""BCSR-part SpMM Pallas kernel — the MXU (matrix-pipeline) half of LOOPS.

Paper mapping (§3.3 "Outer-product based SME kernel for BCSR part",
Algorithm 2 + Figure 2): the BCSR-part stores ``Br x 1`` column tiles; each
tile contributes a rank-1 update

    C[block p] += tile_vals[t] (x) B[tile_cols[t], :]

accumulated in a ZA tile register.  On TPU the accumulator is a VMEM block and
the rank-1 updates stream through the MXU: a chain of ``(Br,1) @ (1,bn)`` dots
accumulated into the same resident block is exactly how the systolic array
consumes a matmul — the MXU *is* a hardware "sum of outer products" engine, so
the paper's fmopa loop maps 1:1 onto consecutive grid steps that revisit one
output block.

Precision (§3.3 FP16 path, Algorithm 3): the paper uses the 2-way widening
``fmopa`` (two f16 outer products into one f32 ZA tile) with vzip register
shuffles.  The TPU MXU natively multiplies bf16 operands and accumulates in
fp32 (``preferred_element_type=float32``), which realises the same
half-in/single-accumulate contract without any shuffle — the packing is done
by the hardware.  FP64 uses ``preferred_element_type=float64`` (lowered by
XLA to VPU sequences on real TPUs, which have no f64 MXU mode).

The paper's Figure-2 "multi-tile" optimisation (multiple 1 x cntd tiles of B
per fmopa round, several ZA tiles in flight) is realised by the ``bn`` block
width: one (1, bn) B block with bn = 128 * za covers ``za`` lane tiles per
visit.

grid = (N // bn, ntiles); ``tile_rows`` is nondecreasing so output-block
revisiting is legal, exactly as in the CSR kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import acc_dtype_for

__all__ = ["bcsr_spmm_pallas"]


def _kernel(tile_rows_ref, tile_cols_ref, vals_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(1)
    ntiles = pl.num_programs(1)

    row_here = tile_rows_ref[k]
    row_prev = tile_rows_ref[jnp.maximum(k - 1, 0)]
    row_next = tile_rows_ref[jnp.minimum(k + 1, ntiles - 1)]
    first = jnp.logical_or(k == 0, row_here != row_prev)
    last = jnp.logical_or(k == ntiles - 1, row_here != row_next)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_tile = vals_ref[0]         # (Br, 1) column tile of A
    b_row = b_ref[...]           # (1, bn) gathered row of B
    # Rank-1 outer product, accumulated — the fmopa analogue.  For bf16 the
    # MXU widens to fp32 in hardware (2-way fmopa equivalent).
    acc_ref[...] += jax.lax.dot_general(
        a_tile, b_row, (((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("nblocks", "bn", "out_dtype", "interpret"))
def bcsr_spmm_pallas(tile_rows: jax.Array, tile_cols: jax.Array,
                     tile_vals: jax.Array, b: jax.Array, *, nblocks: int,
                     bn: int | None = None, out_dtype=None,
                     interpret: bool = True) -> jax.Array:
    """Vector-wise BCSR SpMM; returns the padded (nblocks * Br, N) result.

    Args:
      tile_rows: (T,) int32 block-row per tile, nondecreasing.
      tile_cols: (T,) int32 gather row of ``b`` per tile.
      tile_vals: (T, Br) tile values (Br = the paper's cntd/cntf/cnth).
      b:         (K, N) dense operand.
      nblocks:   number of block-rows (static).
      bn:        B/accumulator column width per visit (multi-ZA-tile factor);
                 defaults to min(N, 512) = 4 lane tiles.
    """
    ntiles, br = tile_vals.shape
    n = b.shape[1]
    bn = bn or min(n, 512)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype = acc_dtype_for(tile_vals.dtype)
    out_dtype = out_dtype or acc_dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # tile_rows, tile_cols
        grid=(n // bn, ntiles),
        in_specs=[
            pl.BlockSpec((1, br, 1), lambda j, k, rows, cols: (k, 0, 0)),
            pl.BlockSpec((1, bn), lambda j, k, rows, cols: (cols[k], j)),
        ],
        out_specs=pl.BlockSpec((br, bn), lambda j, k, rows, cols: (rows[k], j)),
        scratch_shapes=[pltpu.VMEM((br, bn), acc_dtype)],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nblocks * br, n), out_dtype),
        interpret=interpret,
    )(tile_rows, tile_cols, tile_vals.reshape(ntiles, br, 1), b)
