"""BCSR-part SpMM Pallas kernel — the MXU (matrix-pipeline) half of LOOPS.

Paper mapping (§3.3 "Outer-product based SME kernel for BCSR part",
Algorithm 2 + Figure 2): the BCSR-part stores ``Br x 1`` column tiles; each
tile contributes a rank-1 update

    C[block p] += tile_vals[t] (x) B[tile_cols[t], :]

accumulated in a ZA tile register.  On TPU the accumulator is a VMEM block
streamed through the MXU.

Panelized execution (paper Figure 2 "multi-tile" batching)
----------------------------------------------------------
The kernel consumes ``(P, Br, G)`` panels (``repro.core.formats.PanelBCSR``):
G same-block-row tiles stacked side by side form a real ``(Br, G)`` operand,
and one grid step performs a single

    C[block] += A_panel(Br, G) @ B_panel(G, bn)

MXU contraction — G fmopa rounds batched per ZA-tile visit, exactly the
paper's multi-tile optimisation.  The B panel is assembled in VMEM scratch
from G scalar-prefetch-indexed row gathers with masked (padding-dropping)
stores.  G = 1 degenerates to the historical rank-1-per-step kernel
(``bcsr_spmm_pallas`` is that wrapper).

Batched execution (multi-RHS)
-----------------------------
A rank-3 dense operand ``(batch, K, N)`` adds a leading batch-block grid
axis: each grid step loads the static ``(Br, G)`` A panel ONCE, assembles
``bz`` B panels (one per batch slice) in scratch, and issues one batched
``(bz, Br, G) @ (bz, G, bn)`` MXU contraction — ``bz`` independent matmuls
sharing the A operand.  Grid steps grow by ``ceil(batch / bz)`` over the
unbatched call.

Precision (§3.3 FP16 path, Algorithm 3): the paper uses the 2-way widening
``fmopa`` (two f16 outer products into one f32 ZA tile) with vzip register
shuffles.  The TPU MXU natively multiplies bf16 operands and accumulates in
fp32 (``preferred_element_type=float32``), which realises the same
half-in/single-accumulate contract without any shuffle — the packing is done
by the hardware.  FP64 uses ``preferred_element_type=float64`` (lowered by
XLA to VPU sequences on real TPUs, which have no f64 MXU mode).

grid = (N // bn, P) (batched: (batch // bz, N // bn, P)); ``panel_rows`` is
nondecreasing so output-block revisiting is legal, exactly as in the CSR
kernel.  ``carry`` + ``row_block_offset`` support the fused single-pass
``loops_spmm``: the kernel writes its blocks at a row offset into a shared
buffer whose other rows (the CSR part's) are preserved through
``input_output_aliases``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .engine import batch_block, register_kernel, resolve_dtypes
from .panel_common import (check_pipeline_depth, default_bn, first_last,
                           first_last_at, grid_dims, panel_operands, parity,
                           split_panel_refs)

__all__ = ["bcsr_spmm_pallas", "bcsr_panels_spmm_pallas"]


def _panel_kernel(g: int, has_carry: bool, bz: int | None, *refs):
    """One grid step: gather G rows of B into scratch, one (Br,G)@(G,bn)
    MXU contraction (``bz`` of them, sharing the A panel, when batched)."""
    rows_ref, _, vals_ref, mask_ref, b_refs, (o_ref, bpan_ref, acc_ref) = \
        split_panel_refs(refs, g, has_carry)
    first, last = first_last(rows_ref, panel_axis=1 if bz is None else 2)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Masked gather: assemble the (G, bn) B panel(s) in VMEM scratch, zeroing
    # padding lanes (panels shorter than G at block-row boundaries).
    for i, b_ref in enumerate(b_refs):
        if bz is None:
            row = b_ref[...].astype(bpan_ref.dtype)      # (1, bn)
            bpan_ref[i, :] = jnp.where(mask_ref[0, i] > 0, row,
                                       jnp.zeros_like(row))[0]
        else:
            row = b_ref[...][:, 0, :].astype(bpan_ref.dtype)  # (bz, bn)
            bpan_ref[:, i, :] = jnp.where(mask_ref[0, i] > 0, row,
                                          jnp.zeros_like(row))

    # One real MXU contraction per grid step: G batched fmopa rounds
    # (Figure 2) instead of a chain of rank-1 (Br,1)@(1,bn) updates.  For
    # bf16 the MXU widens to fp32 in hardware (2-way fmopa equivalent).
    a_panel = vals_ref[0]        # (Br, G)
    if bz is None:
        acc_ref[...] += jax.lax.dot_general(
            a_panel, bpan_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=acc_ref.dtype)
    else:
        # The A panel is shared across the bz batch slices: broadcast it and
        # contract batch-wise — (bz, Br, G) @ (bz, G, bn) -> (bz, Br, bn).
        a_b = jnp.broadcast_to(a_panel, (bz,) + a_panel.shape)
        acc_ref[...] += jax.lax.dot_general(
            a_b, bpan_ref[...], (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=acc_ref.dtype)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _piped_panel_kernel(g: int, has_carry: bool, bz: int | None, depth: int,
                        *refs):
    """Depth-2 software pipeline: grid step ``k`` assembles panel
    ``min(k, P-1)``'s B rows into ping-pong scratch slot ``k % 2`` while the
    MXU contracts panel ``max(k - 1, 0)`` out of slot ``(k+1) % 2`` — the
    gather DMAs of the next panel overlap this panel's ``(Br,G)@(G,bn)``
    contraction.  Compute/init/flush are predicated off during the
    ``depth - 1`` fill ramp steps."""
    rows_ref, _, vals_ref, mask_ref, b_refs, (o_ref, bpan_ref, acc_ref) = \
        split_panel_refs(refs, g, has_carry)
    axis = 1 if bz is None else 2
    k = pl.program_id(axis)
    npanels = pl.num_programs(axis) - (depth - 1)

    def _assemble(slot):
        for i, b_ref in enumerate(b_refs):
            if bz is None:
                row = b_ref[...].astype(bpan_ref.dtype)          # (1, bn)
                bpan_ref[slot, i, :] = jnp.where(
                    mask_ref[0, i] > 0, row, jnp.zeros_like(row))[0]
            else:
                row = b_ref[...][:, 0, :].astype(bpan_ref.dtype)  # (bz, bn)
                bpan_ref[slot, :, i, :] = jnp.where(
                    mask_ref[0, i] > 0, row, jnp.zeros_like(row))

    for s in (0, 1):
        @pl.when(parity(k) == s)
        def _(s=s):
            _assemble(s)

    @pl.when(k >= depth - 1)
    def _compute():
        c = jnp.maximum(k - (depth - 1), 0)
        first, last = first_last_at(rows_ref, c, npanels)

        @pl.when(first)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a_panel = vals_ref[0]        # (Br, G), panel c's values

        def _contract(slot):
            if bz is None:
                acc_ref[...] += jax.lax.dot_general(
                    a_panel, bpan_ref[slot], (((1,), (0,)), ((), ())),
                    preferred_element_type=acc_ref.dtype)
            else:
                a_b = jnp.broadcast_to(a_panel, (bz,) + a_panel.shape)
                acc_ref[...] += jax.lax.dot_general(
                    a_b, bpan_ref[slot], (((2,), (1,)), ((0,), (0,))),
                    preferred_element_type=acc_ref.dtype)

        for s in (0, 1):
            @pl.when(parity(k + 1) == s)
            def _(s=s):
                _contract(s)

        @pl.when(last)
        def _flush():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("nblocks", "row_block_offset", "out_rows", "bn",
                     "out_dtype", "interpret", "pipeline_depth"))
def bcsr_panels_spmm_pallas(panel_rows: jax.Array, panel_cols: jax.Array,
                            panel_vals: jax.Array, panel_mask: jax.Array,
                            b: jax.Array, *, nblocks: int,
                            row_block_offset: int = 0,
                            out_rows: int | None = None,
                            bn: int | None = None, out_dtype=None,
                            interpret: bool = True,
                            carry: jax.Array | None = None,
                            pipeline_depth: int = 1) -> jax.Array:
    """Panelized vector-wise BCSR SpMM.

    Args:
      panel_rows: (P,) int32 block-row per panel, nondecreasing.
      panel_cols: (P, G) int32 gather rows of ``b`` per panel lane.
      panel_vals: (P, Br, G) stacked tile values (zero columns = padding).
      panel_mask: (P, G) lane validity (1 real / 0 padding), vals dtype.
      b:          (K, N) dense operand, or (batch, K, N) for the native
                  batched grid (one kernel call serves every slice).
      nblocks:    number of block-rows (static).
      row_block_offset: first output block-row this kernel writes (static;
                  the fused path sets it to ``r_boundary // Br``).
      out_rows:   total rows of the returned array; defaults to
                  ``(row_block_offset + nblocks) * Br``.
      bn:         B/accumulator column width per visit (multi-ZA-tile
                  factor); defaults to ``panel_common.default_bn(N)`` —
                  min(N, 512) when 512 | N, else the largest lane-aligned
                  divisor (N=600 -> 200).
      carry:      optional (..., out_rows, N) array aliased into the output;
                  rows not visited here keep its contents (fused mode).
      pipeline_depth: 1 (serial gather->contract, default) or 2 (double-
                  buffered B-panel prefetch through a ping-pong scratch
                  slot).  Unbatched results are bitwise identical across
                  depths; batched results agree to ~1 ulp.
    """
    if b.ndim not in (2, 3):
        raise ValueError(f"b must be (K, N) or (batch, K, N); got rank "
                         f"{b.ndim}")
    depth = check_pipeline_depth(pipeline_depth)
    npanels, br, g = panel_vals.shape
    n = b.shape[-1]
    bn = bn or default_bn(n)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype, out_dtype = resolve_dtypes(panel_vals.dtype, out_dtype)
    out_rows = out_rows or (row_block_offset + nblocks) * br
    has_carry = carry is not None
    batch = b.shape[0] if b.ndim == 3 else None
    bz = batch_block(batch) if batch is not None else 0
    grid, _ = grid_dims(batch=batch, bz=bz, n=n, bn=bn, npanels=npanels,
                        pipeline_depth=depth)

    def _rows(rows, k, j):
        return (row_block_offset + rows[k], j)

    in_specs, args, aliases = panel_operands(
        g=g, bn=bn, vals_block=(1, br, g), vals=panel_vals, mask=panel_mask,
        b=b, carry=carry, carry_block=(br, bn), row_map=_rows,
        bz=None if batch is None else bz, pipeline_depth=depth,
        npanels=npanels)

    if depth == 1:
        def _out_k(k):
            return k
    else:
        def _out_k(k):
            return jnp.maximum(k - (depth - 1), 0)

    if batch is None:
        out_specs = pl.BlockSpec(
            (br, bn), lambda j, k, rows, cols: _rows(rows, _out_k(k), j))
        out_shape = jax.ShapeDtypeStruct((out_rows, n), out_dtype)
        bpan_shape = (g, bn) if depth == 1 else (depth, g, bn)
        scratch = [pltpu.VMEM(bpan_shape, b.dtype),     # B panel (packed)
                   pltpu.VMEM((br, bn), acc_dtype)]     # accumulator
    else:
        out_specs = pl.BlockSpec(
            (bz, br, bn),
            lambda z, j, k, rows, cols: (z,) + _rows(rows, _out_k(k), j))
        out_shape = jax.ShapeDtypeStruct((batch, out_rows, n), out_dtype)
        bpan_shape = (bz, g, bn) if depth == 1 else (depth, bz, g, bn)
        scratch = [pltpu.VMEM(bpan_shape, b.dtype),     # B panels (packed)
                   pltpu.VMEM((bz, br, bn), acc_dtype)]

    if depth > 1:
        kernel = functools.partial(_piped_panel_kernel, g, has_carry,
                                   None if batch is None else bz, depth)
    else:
        kernel = functools.partial(_panel_kernel, g, has_carry,
                                   None if batch is None else bz)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # panel_rows, panel_cols
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(panel_rows, panel_cols, *args)


@functools.partial(
    jax.jit,
    static_argnames=("nblocks", "bn", "out_dtype", "interpret"))
def bcsr_spmm_pallas(tile_rows: jax.Array, tile_cols: jax.Array,
                     tile_vals: jax.Array, b: jax.Array, *, nblocks: int,
                     bn: int | None = None, out_dtype=None,
                     interpret: bool = True) -> jax.Array:
    """Flat-array entry point: one tile per panel (G = 1, rank-1 updates).

    Returns the padded (..., nblocks * Br, N) result.  Format-level callers
    should prefer :func:`bcsr_panels_spmm_pallas` with a host-packed
    ``PanelBCSR`` for real G-wide matmul panels.
    """
    ntiles, br = tile_vals.shape
    return bcsr_panels_spmm_pallas(
        tile_rows, tile_cols.reshape(ntiles, 1),
        tile_vals.reshape(ntiles, br, 1), jnp.ones((ntiles, 1),
                                                   tile_vals.dtype),
        b, nblocks=nblocks, bn=bn, out_dtype=out_dtype, interpret=interpret)


register_kernel("bcsr", "spmm", "panels", bcsr_panels_spmm_pallas)
register_kernel("bcsr", "spmm", "flat", bcsr_spmm_pallas)
