"""Pure-jnp oracles for the LOOPS Pallas kernels.

These are the ground truth for every kernel test (swept over shapes, dtypes
and sparsity patterns) and the fallback execution path on backends without
Pallas support.  They also stand in for the paper's CPU baselines:
``csr_spmm_ref`` is the TACO-style row-wise CSR schedule and ``dense_spmm``
is the Armadillo-style dense product.

Every oracle accepts the engine's batched shape contract ``(..., K, N)``:
leading dims are folded through ``jax.vmap`` (one XLA computation — a
batched oracle, not a Python loop), and the SDD oracles sum the batch, the
shared-values cotangent contract of the backward pass.

``acc_dtype_for`` is re-exported from :mod:`repro.kernels.engine` — the
single home of the ``{bf16, f16} → fp32-accumulate`` promotion rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .engine import acc_dtype_for, register_kernel

__all__ = ["csr_spmm_ref", "bcsr_spmm_ref", "csr_sdd_ref", "bcsr_sdd_ref",
           "dense_spmm", "acc_dtype_for"]


def _map_batch(fn, b):
    """Apply a (K, N)-operand oracle over the leading batch dims of ``b``
    as one vmapped XLA computation."""
    lead = b.shape[:-2]
    flat = b.reshape((-1,) + b.shape[-2:])
    out = jax.vmap(fn)(flat)
    return out.reshape(lead + out.shape[-2:])


def csr_spmm_ref(row_ids: jax.Array, col_idx: jax.Array, vals: jax.Array,
                 b: jax.Array, nrows: int, out_dtype=None) -> jax.Array:
    """Row-wise CSR SpMM: C[r] = sum_{k in row r} vals[k] * B[col[k], :]."""
    if b.ndim > 2:
        return _map_batch(lambda bb: csr_spmm_ref(
            row_ids, col_idx, vals, bb, nrows, out_dtype=out_dtype), b)
    acc = acc_dtype_for(vals.dtype)
    out_dtype = out_dtype or acc
    contrib = vals.astype(acc)[:, None] * b[col_idx].astype(acc)
    out = jax.ops.segment_sum(contrib, row_ids, num_segments=nrows)
    return out.astype(out_dtype)


def bcsr_spmm_ref(tile_rows: jax.Array, tile_cols: jax.Array,
                  tile_vals: jax.Array, b: jax.Array, nblocks: int,
                  out_dtype=None) -> jax.Array:
    """Vector-wise BCSR SpMM as a sum of rank-1 (outer-product) updates:

        C[block p] = sum_{tile t in p} tile_vals[t] (x) B[tile_cols[t], :]

    Returns the padded (..., nblocks * Br, N) result; callers trim to the
    logical row count.
    """
    if b.ndim > 2:
        return _map_batch(lambda bb: bcsr_spmm_ref(
            tile_rows, tile_cols, tile_vals, bb, nblocks,
            out_dtype=out_dtype), b)
    acc = acc_dtype_for(tile_vals.dtype)
    out_dtype = out_dtype or acc
    br = tile_vals.shape[1]
    outer = (tile_vals.astype(acc)[:, :, None]
             * b[tile_cols].astype(acc)[:, None, :])  # (T, Br, N)
    blocks = jax.ops.segment_sum(outer, tile_rows, num_segments=nblocks)
    return blocks.reshape(nblocks * br, b.shape[1]).astype(out_dtype)


def csr_sdd_ref(row_ids: jax.Array, col_idx: jax.Array, dy: jax.Array,
                b: jax.Array) -> jax.Array:
    """Sampled dense-dense product at the CSR-part coordinates:

        dA[k] = dY[row_ids[k], :] · B[col_idx[k], :]

    — the per-nonzero gradient of ``Y = A @ B`` w.r.t. A's stored values
    (``dY ⊙ B`` sampled on the sparsity pattern), **summed over any batch
    dims** (stored values are shared across the batch).  Returns (nnz,) in
    the fp32-accumulating dtype.
    """
    if b.ndim > 2:
        flat_dy = dy.reshape((-1,) + dy.shape[-2:])
        flat_b = b.reshape((-1,) + b.shape[-2:])
        return jax.vmap(csr_sdd_ref, in_axes=(None, None, 0, 0))(
            row_ids, col_idx, flat_dy, flat_b).sum(axis=0)
    acc = acc_dtype_for(b.dtype)
    return (dy[row_ids].astype(acc) * b[col_idx].astype(acc)).sum(axis=-1)


def bcsr_sdd_ref(tile_rows: jax.Array, tile_cols: jax.Array, dy_pad: jax.Array,
                 b: jax.Array, nblocks: int) -> jax.Array:
    """Sampled dense-dense product at the BCSR-part tile coordinates:

        dA[t, r] = dY[tile_rows[t]*Br + r, :] · B[tile_cols[t], :]

    ``dy_pad`` is the BCSR region of the cotangent padded to
    ``nblocks * Br`` rows (trimmed forward rows carry zero cotangent),
    batch dims summed.  Returns (ntiles, Br) in the fp32-accumulating
    dtype.
    """
    if b.ndim > 2:
        flat_dy = dy_pad.reshape((-1,) + dy_pad.shape[-2:])
        flat_b = b.reshape((-1,) + b.shape[-2:])
        return jax.vmap(bcsr_sdd_ref, in_axes=(None, None, 0, 0, None))(
            tile_rows, tile_cols, flat_dy, flat_b, nblocks).sum(axis=0)
    acc = acc_dtype_for(b.dtype)
    br = dy_pad.shape[0] // nblocks
    blocks = dy_pad.reshape(nblocks, br, dy_pad.shape[1]).astype(acc)
    return jnp.einsum("tbn,tn->tb", blocks[tile_rows],
                      b[tile_cols].astype(acc))


def dense_spmm(a_dense: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    acc = acc_dtype_for(a_dense.dtype)
    out_dtype = out_dtype or acc
    return jax.lax.dot(a_dense, b,
                       preferred_element_type=acc).astype(out_dtype)


register_kernel("csr", "spmm", "ref", csr_spmm_ref)
register_kernel("bcsr", "spmm", "ref", bcsr_spmm_ref)
register_kernel("csr", "sdd", "ref", csr_sdd_ref)
register_kernel("bcsr", "sdd", "ref", bcsr_sdd_ref)
