"""Pure-jnp oracles for the LOOPS Pallas kernels.

These are the ground truth for every kernel test (swept over shapes, dtypes
and sparsity patterns) and the fallback execution path on backends without
Pallas support.  They also stand in for the paper's CPU baselines:
``csr_spmm_ref`` is the TACO-style row-wise CSR schedule and ``dense_spmm``
is the Armadillo-style dense product.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["csr_spmm_ref", "bcsr_spmm_ref", "csr_sdd_ref", "bcsr_sdd_ref",
           "dense_spmm", "acc_dtype_for"]


def acc_dtype_for(dtype) -> jnp.dtype:
    """fp32 accumulation for half precision (the paper's f16f16f32 contract,
    realised on TPU as the native bf16xbf16->f32 MXU mode); otherwise the
    input precision.  Canonicalised so f64 degrades to f32 when x64 is off."""
    dtype = jax.dtypes.canonicalize_dtype(jnp.dtype(dtype))
    if dtype in (jnp.bfloat16, jnp.float16):
        return jnp.dtype(jnp.float32)
    return dtype


def csr_spmm_ref(row_ids: jax.Array, col_idx: jax.Array, vals: jax.Array,
                 b: jax.Array, nrows: int, out_dtype=None) -> jax.Array:
    """Row-wise CSR SpMM: C[r] = sum_{k in row r} vals[k] * B[col[k], :]."""
    acc = acc_dtype_for(vals.dtype)
    out_dtype = out_dtype or acc
    contrib = vals.astype(acc)[:, None] * b[col_idx].astype(acc)
    out = jax.ops.segment_sum(contrib, row_ids, num_segments=nrows)
    return out.astype(out_dtype)


def bcsr_spmm_ref(tile_rows: jax.Array, tile_cols: jax.Array,
                  tile_vals: jax.Array, b: jax.Array, nblocks: int,
                  out_dtype=None) -> jax.Array:
    """Vector-wise BCSR SpMM as a sum of rank-1 (outer-product) updates:

        C[block p] = sum_{tile t in p} tile_vals[t] (x) B[tile_cols[t], :]

    Returns the padded (nblocks * Br, N) result; callers trim to the logical
    row count.
    """
    acc = acc_dtype_for(tile_vals.dtype)
    out_dtype = out_dtype or acc
    br = tile_vals.shape[1]
    outer = (tile_vals.astype(acc)[:, :, None]
             * b[tile_cols].astype(acc)[:, None, :])  # (T, Br, N)
    blocks = jax.ops.segment_sum(outer, tile_rows, num_segments=nblocks)
    return blocks.reshape(nblocks * br, b.shape[1]).astype(out_dtype)


def csr_sdd_ref(row_ids: jax.Array, col_idx: jax.Array, dy: jax.Array,
                b: jax.Array) -> jax.Array:
    """Sampled dense-dense product at the CSR-part coordinates:

        dA[k] = dY[row_ids[k], :] · B[col_idx[k], :]

    — the per-nonzero gradient of ``Y = A @ B`` w.r.t. A's stored values
    (``dY ⊙ B`` sampled on the sparsity pattern).  Returns (nnz,) in the
    fp32-accumulating dtype.
    """
    acc = acc_dtype_for(b.dtype)
    return (dy[row_ids].astype(acc) * b[col_idx].astype(acc)).sum(axis=-1)


def bcsr_sdd_ref(tile_rows: jax.Array, tile_cols: jax.Array, dy_pad: jax.Array,
                 b: jax.Array, nblocks: int) -> jax.Array:
    """Sampled dense-dense product at the BCSR-part tile coordinates:

        dA[t, r] = dY[tile_rows[t]*Br + r, :] · B[tile_cols[t], :]

    ``dy_pad`` is the BCSR region of the cotangent padded to
    ``nblocks * Br`` rows (trimmed forward rows carry zero cotangent).
    Returns (ntiles, Br) in the fp32-accumulating dtype.
    """
    acc = acc_dtype_for(b.dtype)
    br = dy_pad.shape[0] // nblocks
    blocks = dy_pad.reshape(nblocks, br, dy_pad.shape[1]).astype(acc)
    return jnp.einsum("tbn,tn->tb", blocks[tile_rows],
                      b[tile_cols].astype(acc))


def dense_spmm(a_dense: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    acc = acc_dtype_for(a_dense.dtype)
    out_dtype = out_dtype or acc
    return jax.lax.dot(a_dense, b,
                       preferred_element_type=acc).astype(out_dtype)
