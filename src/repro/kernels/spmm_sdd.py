"""Sampled dense-dense (SDD) Pallas kernels — the value-gradient half of the
LOOPS custom VJP.

For ``Y = A @ B`` with A sparse, the cotangent of A's *stored values* is the
dense product ``dY @ Bᵀ`` sampled at the stored coordinates only:

    dA[i, j] = dY[i, :] · B[j, :]        (i, j) ∈ structure(A)

Materialising ``dY @ Bᵀ`` would cost O(M·K·N) and defeat the point of
training a pruned layer; these kernels spend O(nnz·N) by walking the same
G-wide panels the forward kernels execute (``repro.core.formats.PanelCSR`` /
``PanelBCSR``), gathering the G rows ``B[panel_cols[p]]`` per grid step and
contracting them against the panel's cotangent rows:

  * CSR part — one grid step computes the G dot products
    ``dY[panel_rows[p], :] · B[panel_cols[p, i], :]`` (a VPU
    multiply-reduce, the AXPY kernel read backwards);
  * BCSR part — one grid step computes a ``(Br, bn) @ (bn, G)`` MXU
    contraction between the block-row's cotangent slab and the gathered B
    panel, yielding all ``Br × G`` per-tile-element gradients at once.

The grid is ``(P, N // bn)`` with the *column* blocks innermost: each panel's
accumulator stays resident in VMEM scratch while the N-reduction streams
through, then flushes once — the transpose of the forward kernels' resident
output block.  Padding lanes produce garbage that is never read: the callers
(``repro.kernels.engine.loops_sdd``) gather only real slots via the panels'
``src_panel``/``src_lane`` maps, so no in-kernel mask is needed.

Batched execution (multi-RHS backward)
--------------------------------------
With rank-3 ``(batch, ..., N)`` cotangent/operand pairs the grid becomes
``(P, batch // bz, N // bn)``: the stored values are shared across the
batch, so their cotangent is the **batch sum**, which the kernels realise
by folding the batch axis into the same resident accumulation the
N-reduction already uses — ``bz`` slices per step, one flush per panel.

Outputs are panel-layout ``(P, G)`` / ``(P, Br, G)`` arrays in the fp32
accumulation dtype (the f16f16f32 contract of the forward kernels applies to
the backward pass too).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .engine import acc_dtype_for, batch_block, register_kernel

__all__ = ["csr_sdd_panels_pallas", "bcsr_sdd_panels_pallas"]


def _reduction_edges(bz: int | None):
    """(first, last) predicates over the per-panel reduction axes — the
    column blocks and, when batched, the batch blocks — shared by both SDD
    kernels so init/flush can never disagree with the grid layout."""
    if bz is None:
        j = pl.program_id(1)
        nb = pl.num_programs(1)
        return j == 0, j == nb - 1
    z, j = pl.program_id(1), pl.program_id(2)
    nz, nb = pl.num_programs(1), pl.num_programs(2)
    return jnp.logical_and(z == 0, j == 0), \
        jnp.logical_and(z == nz - 1, j == nb - 1)


def _csr_sdd_kernel(g: int, bz: int | None, *refs):
    """One grid step: G masked-free dot products dY[row]·B[col_i] into the
    panel's (1, G) accumulator (summed over batch slices when batched);
    flush after the last reduction block."""
    _, _, dy_ref, *rest = refs
    b_refs, (o_ref, acc_ref) = rest[:g], rest[g:]
    first, last = _reduction_edges(bz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dy = dy_ref[...].astype(acc_ref.dtype)       # (1, bn) or (bz, 1, bn)
    # jnp.sum over every axis reduces the batch slices too — exactly the
    # shared-values batch-sum contract of the backward pass.
    lanes = [jnp.sum(dy * b_ref[...].astype(acc_ref.dtype))[None]
             for b_ref in b_refs]
    acc_ref[...] += jnp.stack(lanes, axis=-1)    # (1, g)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def csr_sdd_panels_pallas(panel_rows: jax.Array, panel_cols: jax.Array,
                          dy: jax.Array, b: jax.Array, *,
                          bn: int | None = None,
                          interpret: bool = True) -> jax.Array:
    """Per-nonzero gradients for the CSR part, in panel layout.

    Args:
      panel_rows: (P,) int32 — cotangent row per panel (``PanelCSR`` order).
      panel_cols: (P, G) int32 — gather rows of ``b`` per lane.
      dy:         (M, N) output cotangent, or (batch, M, N) — batch summed
                  (rows beyond the CSR region are simply never indexed).
      b:          (K, N) or (batch, K, N) the forward dense operand.
    Returns:
      (P, G) gradients in the accumulation dtype; padding lanes undefined —
      gather real slots with ``PanelCSR.gather_values``.
    """
    if dy.ndim != b.ndim or b.ndim not in (2, 3):
        raise ValueError(f"dy/b must both be rank 2 or 3; got {dy.ndim} / "
                         f"{b.ndim}")
    npanels, g = panel_cols.shape
    n = b.shape[-1]
    bn = bn or min(n, 512)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype = acc_dtype_for(b.dtype)
    batch = b.shape[0] if b.ndim == 3 else None
    if batch is None:
        grid = (npanels, n // bn)
        bz = None
        in_specs = [
            pl.BlockSpec((1, bn), lambda p, j, rows, cols: (rows[p], j)),
            *[pl.BlockSpec((1, bn),
                           lambda p, j, rows, cols, i=i: (cols[p, i], j))
              for i in range(g)],
        ]
        out_specs = pl.BlockSpec((1, g), lambda p, j, rows, cols: (p, 0))
    else:
        bz = batch_block(batch)
        grid = (npanels, batch // bz, n // bn)
        in_specs = [
            pl.BlockSpec((bz, 1, bn),
                         lambda p, z, j, rows, cols: (z, rows[p], j)),
            *[pl.BlockSpec((bz, 1, bn),
                           lambda p, z, j, rows, cols, i=i: (z, cols[p, i], j))
              for i in range(g)],
        ]
        out_specs = pl.BlockSpec((1, g), lambda p, z, j, rows, cols: (p, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # panel_rows, panel_cols
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((1, g), acc_dtype)],
    )
    return pl.pallas_call(
        functools.partial(_csr_sdd_kernel, g, bz),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((npanels, g), acc_dtype),
        interpret=interpret,
    )(panel_rows, panel_cols, dy, *([b] * g))


def _bcsr_sdd_kernel(g: int, bz: int | None, *refs):
    """One grid step: gather the G B-rows into scratch, one (Br,bn)@(bn,G)
    MXU contraction against the block-row's cotangent slab (contracted over
    the batch slices too when batched)."""
    _, _, dy_ref, *rest = refs
    b_refs, (o_ref, bpan_ref, acc_ref) = rest[:g], rest[g:]
    first, last = _reduction_edges(bz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if bz is None:
        for i, b_ref in enumerate(b_refs):
            bpan_ref[i, :] = b_ref[...].astype(bpan_ref.dtype)[0]
        acc_ref[...] += jax.lax.dot_general(
            dy_ref[...].astype(acc_ref.dtype), bpan_ref[...],
            (((1,), (1,)), ((), ())),
            preferred_element_type=acc_ref.dtype)       # (br, g)
    else:
        for i, b_ref in enumerate(b_refs):
            bpan_ref[:, i, :] = b_ref[...][:, 0, :].astype(bpan_ref.dtype)
        # (bz, br, bn) x (bz, g, bn) contracted over (batch, bn) -> (br, g):
        # the batch axis joins the N-reduction, realising the batch sum.
        acc_ref[...] += jax.lax.dot_general(
            dy_ref[...].astype(acc_ref.dtype), bpan_ref[...],
            (((0, 2), (0, 2)), ((), ())),
            preferred_element_type=acc_ref.dtype)       # (br, g)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "bn", "interpret"))
def bcsr_sdd_panels_pallas(panel_rows: jax.Array, panel_cols: jax.Array,
                           dy_pad: jax.Array, b: jax.Array, *, br: int,
                           bn: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """Per-tile-element gradients for the BCSR part, in panel layout.

    Args:
      panel_rows: (P,) int32 — block-row per panel (``PanelBCSR`` order).
      panel_cols: (P, G) int32 — gather rows of ``b`` per lane.
      dy_pad:     (nblocks * Br, N) or (batch, nblocks * Br, N) — the BCSR
                  region of the cotangent, zero-padded to full blocks
                  (trimmed rows ⇒ zero grad); batch summed.
      b:          (K, N) or (batch, K, N) the forward dense operand.
    Returns:
      (P, Br, G) gradients in the accumulation dtype; padding lanes
      undefined — gather real slots with ``PanelBCSR.gather_values``.
    """
    if dy_pad.ndim != b.ndim or b.ndim not in (2, 3):
        raise ValueError(f"dy_pad/b must both be rank 2 or 3; got "
                         f"{dy_pad.ndim} / {b.ndim}")
    npanels, g = panel_cols.shape
    n = b.shape[-1]
    bn = bn or min(n, 512)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype = acc_dtype_for(b.dtype)
    batch = b.shape[0] if b.ndim == 3 else None
    if batch is None:
        bz = None
        grid = (npanels, n // bn)
        in_specs = [
            pl.BlockSpec((br, bn), lambda p, j, rows, cols: (rows[p], j)),
            *[pl.BlockSpec((1, bn),
                           lambda p, j, rows, cols, i=i: (cols[p, i], j))
              for i in range(g)],
        ]
        out_specs = pl.BlockSpec((1, br, g),
                                 lambda p, j, rows, cols: (p, 0, 0))
        scratch = [pltpu.VMEM((g, bn), acc_dtype),      # B panel
                   pltpu.VMEM((br, g), acc_dtype)]      # accumulator
    else:
        bz = batch_block(batch)
        grid = (npanels, batch // bz, n // bn)
        in_specs = [
            pl.BlockSpec((bz, br, bn),
                         lambda p, z, j, rows, cols: (z, rows[p], j)),
            *[pl.BlockSpec((bz, 1, bn),
                           lambda p, z, j, rows, cols, i=i: (z, cols[p, i], j))
              for i in range(g)],
        ]
        out_specs = pl.BlockSpec((1, br, g),
                                 lambda p, z, j, rows, cols: (p, 0, 0))
        scratch = [pltpu.VMEM((bz, g, bn), acc_dtype),
                   pltpu.VMEM((br, g), acc_dtype)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # panel_rows, panel_cols
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_bcsr_sdd_kernel, g, bz),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((npanels, br, g), acc_dtype),
        interpret=interpret,
    )(panel_rows, panel_cols, dy_pad, *([b] * g))


register_kernel("csr", "sdd", "panels", csr_sdd_panels_pallas)
register_kernel("bcsr", "sdd", "panels", bcsr_sdd_panels_pallas)
