"""Sampled dense-dense (SDD) Pallas kernels — the value-gradient half of the
LOOPS custom VJP.

For ``Y = A @ B`` with A sparse, the cotangent of A's *stored values* is the
dense product ``dY @ Bᵀ`` sampled at the stored coordinates only:

    dA[i, j] = dY[i, :] · B[j, :]        (i, j) ∈ structure(A)

Materialising ``dY @ Bᵀ`` would cost O(M·K·N) and defeat the point of
training a pruned layer; these kernels spend O(nnz·N) by walking the same
G-wide panels the forward kernels execute (``repro.core.formats.PanelCSR`` /
``PanelBCSR``), gathering the G rows ``B[panel_cols[p]]`` per grid step and
contracting them against the panel's cotangent rows:

  * CSR part — one grid step computes the G dot products
    ``dY[panel_rows[p], :] · B[panel_cols[p, i], :]`` (a VPU
    multiply-reduce, the AXPY kernel read backwards);
  * BCSR part — one grid step computes a ``(Br, bn) @ (bn, G)`` MXU
    contraction between the block-row's cotangent slab and the gathered B
    panel, yielding all ``Br × G`` per-tile-element gradients at once.

The grid is ``(P, N // bn)`` with the *column* blocks innermost: each panel's
accumulator stays resident in VMEM scratch while the N-reduction streams
through, then flushes once — the transpose of the forward kernels' resident
output block.  Padding lanes produce garbage that is never read: the callers
(``repro.kernels.ops.loops_sdd``) gather only real slots via the panels'
``src_panel``/``src_lane`` maps, so no in-kernel mask is needed.

Outputs are panel-layout ``(P, G)`` / ``(P, Br, G)`` arrays in the fp32
accumulation dtype (the f16f16f32 contract of the forward kernels applies to
the backward pass too).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import acc_dtype_for

__all__ = ["csr_sdd_panels_pallas", "bcsr_sdd_panels_pallas"]


def _csr_sdd_kernel(g: int, *refs):
    """One grid step: G masked-free dot products dY[row]·B[col_i] into the
    panel's (1, G) accumulator; flush after the last column block."""
    _, _, dy_ref, *rest = refs
    b_refs, (o_ref, acc_ref) = rest[:g], rest[g:]
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dy = dy_ref[...].astype(acc_ref.dtype)          # (1, bn)
    lanes = [jnp.sum(dy * b_ref[...].astype(acc_ref.dtype))[None]
             for b_ref in b_refs]
    acc_ref[...] += jnp.stack(lanes, axis=-1)       # (1, g)

    @pl.when(j == nb - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def csr_sdd_panels_pallas(panel_rows: jax.Array, panel_cols: jax.Array,
                          dy: jax.Array, b: jax.Array, *,
                          bn: int | None = None,
                          interpret: bool = True) -> jax.Array:
    """Per-nonzero gradients for the CSR part, in panel layout.

    Args:
      panel_rows: (P,) int32 — cotangent row per panel (``PanelCSR`` order).
      panel_cols: (P, G) int32 — gather rows of ``b`` per lane.
      dy:         (M, N) output cotangent (rows beyond the CSR region are
                  simply never indexed).
      b:          (K, N) the forward dense operand.
    Returns:
      (P, G) gradients in the accumulation dtype; padding lanes undefined —
      gather real slots with ``PanelCSR.gather_values``.
    """
    npanels, g = panel_cols.shape
    n = b.shape[1]
    bn = bn or min(n, 512)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype = acc_dtype_for(b.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # panel_rows, panel_cols
        grid=(npanels, n // bn),
        in_specs=[
            pl.BlockSpec((1, bn), lambda p, j, rows, cols: (rows[p], j)),
            *[pl.BlockSpec((1, bn),
                           lambda p, j, rows, cols, i=i: (cols[p, i], j))
              for i in range(g)],
        ],
        out_specs=pl.BlockSpec((1, g), lambda p, j, rows, cols: (p, 0)),
        scratch_shapes=[pltpu.VMEM((1, g), acc_dtype)],
    )
    return pl.pallas_call(
        functools.partial(_csr_sdd_kernel, g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((npanels, g), acc_dtype),
        interpret=interpret,
    )(panel_rows, panel_cols, dy, *([b] * g))


def _bcsr_sdd_kernel(g: int, *refs):
    """One grid step: gather the G B-rows into scratch, one (Br,bn)@(bn,G)
    MXU contraction against the block-row's cotangent slab."""
    _, _, dy_ref, *rest = refs
    b_refs, (o_ref, bpan_ref, acc_ref) = rest[:g], rest[g:]
    j = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for i, b_ref in enumerate(b_refs):
        bpan_ref[i, :] = b_ref[...].astype(bpan_ref.dtype)[0]

    acc_ref[...] += jax.lax.dot_general(
        dy_ref[...].astype(acc_ref.dtype), bpan_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=acc_ref.dtype)       # (br, g)

    @pl.when(j == nb - 1)
    def _flush():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "bn", "interpret"))
def bcsr_sdd_panels_pallas(panel_rows: jax.Array, panel_cols: jax.Array,
                           dy_pad: jax.Array, b: jax.Array, *, br: int,
                           bn: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """Per-tile-element gradients for the BCSR part, in panel layout.

    Args:
      panel_rows: (P,) int32 — block-row per panel (``PanelBCSR`` order).
      panel_cols: (P, G) int32 — gather rows of ``b`` per lane.
      dy_pad:     (nblocks * Br, N) — the BCSR region of the cotangent,
                  zero-padded to full blocks (trimmed rows ⇒ zero grad).
      b:          (K, N) the forward dense operand.
    Returns:
      (P, Br, G) gradients in the accumulation dtype; padding lanes
      undefined — gather real slots with ``PanelBCSR.gather_values``.
    """
    npanels, g = panel_cols.shape
    n = b.shape[1]
    bn = bn or min(n, 512)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype = acc_dtype_for(b.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # panel_rows, panel_cols
        grid=(npanels, n // bn),
        in_specs=[
            pl.BlockSpec((br, bn), lambda p, j, rows, cols: (rows[p], j)),
            *[pl.BlockSpec((1, bn),
                           lambda p, j, rows, cols, i=i: (cols[p, i], j))
              for i in range(g)],
        ],
        out_specs=pl.BlockSpec((1, br, g),
                               lambda p, j, rows, cols: (p, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, bn), acc_dtype),     # B panel
                        pltpu.VMEM((br, g), acc_dtype)],    # accumulator
    )
    return pl.pallas_call(
        functools.partial(_bcsr_sdd_kernel, g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((npanels, br, g), acc_dtype),
        interpret=interpret,
    )(panel_rows, panel_cols, dy_pad, *([b] * g))
