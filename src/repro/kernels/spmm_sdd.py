"""Sampled dense-dense (SDD) Pallas kernels — the value-gradient half of the
LOOPS custom VJP.

For ``Y = A @ B`` with A sparse, the cotangent of A's *stored values* is the
dense product ``dY @ Bᵀ`` sampled at the stored coordinates only:

    dA[i, j] = dY[i, :] · B[j, :]        (i, j) ∈ structure(A)

Materialising ``dY @ Bᵀ`` would cost O(M·K·N) and defeat the point of
training a pruned layer; these kernels spend O(nnz·N) by walking the same
G-wide panels the forward kernels execute (``repro.core.formats.PanelCSR`` /
``PanelBCSR``), gathering the G rows ``B[panel_cols[p]]`` per grid step and
contracting them against the panel's cotangent rows:

  * CSR part — one grid step computes the G dot products
    ``dY[panel_rows[p], :] · B[panel_cols[p, i], :]`` (a VPU
    multiply-reduce, the AXPY kernel read backwards);
  * BCSR part — one grid step computes a ``(Br, bn) @ (bn, G)`` MXU
    contraction between the block-row's cotangent slab and the gathered B
    panel, yielding all ``Br × G`` per-tile-element gradients at once.

The grid is ``(P, N // bn)`` with the *column* blocks innermost: each panel's
accumulator stays resident in VMEM scratch while the N-reduction streams
through, then flushes once — the transpose of the forward kernels' resident
output block.  Padding lanes produce garbage that is never read: the callers
(``repro.kernels.engine.loops_sdd``) gather only real slots via the panels'
``src_panel``/``src_lane`` maps, so no in-kernel mask is needed.

Batched execution (multi-RHS backward)
--------------------------------------
With rank-3 ``(batch, ..., N)`` cotangent/operand pairs the grid becomes
``(P, batch // bz, N // bn)``: the stored values are shared across the
batch, so their cotangent is the **batch sum**, which the kernels realise
by folding the batch axis into the same resident accumulation the
N-reduction already uses — ``bz`` slices per step, one flush per panel.

Outputs are panel-layout ``(P, G)`` / ``(P, Br, G)`` arrays in the fp32
accumulation dtype (the f16f16f32 contract of the forward kernels applies to
the backward pass too).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .engine import acc_dtype_for, batch_block, register_kernel
from .panel_common import check_pipeline_depth, default_bn, parity

__all__ = ["csr_sdd_panels_pallas", "bcsr_sdd_panels_pallas"]


def _reduction_edges(bz: int | None, depth: int = 1):
    """(first, last) predicates over the per-panel reduction axes — the
    column blocks and, when batched, the batch blocks — shared by both SDD
    kernels so init/flush can never disagree with the grid layout.  A
    depth-``d`` pipeline skews the compute stream ``d - 1`` steps behind
    the column-block grid axis (the fill-ramp steps are load-only), so the
    reduction opens at ``j == depth - 1`` instead of 0."""
    if bz is None:
        j = pl.program_id(1)
        nb = pl.num_programs(1)
        return j == depth - 1, j == nb - 1
    z, j = pl.program_id(1), pl.program_id(2)
    nz, nb = pl.num_programs(1), pl.num_programs(2)
    return jnp.logical_and(z == 0, j == depth - 1), \
        jnp.logical_and(z == nz - 1, j == nb - 1)


def _sdd_col_maps(depth: int, nb: int):
    """``(lj, cj)`` column-block index maps for the SDD reduction axis:
    grid step ``jj`` loads B's column block ``lj(jj) = min(jj, nb-1)`` and
    reduces the cotangent's column block ``cj(jj) = max(jj - (depth-1), 0)``.
    Identity maps at depth 1."""
    if depth == 1:
        return (lambda jj: jj), (lambda jj: jj)
    return (lambda jj: jnp.minimum(jj, nb - 1),
            lambda jj: jnp.maximum(jj - (depth - 1), 0))


def _csr_sdd_kernel(g: int, bz: int | None, *refs):
    """One grid step: G masked-free dot products dY[row]·B[col_i] into the
    panel's (1, G) accumulator (summed over batch slices when batched);
    flush after the last reduction block."""
    _, _, dy_ref, *rest = refs
    b_refs, (o_ref, acc_ref) = rest[:g], rest[g:]
    first, last = _reduction_edges(bz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dy = dy_ref[...].astype(acc_ref.dtype)       # (1, bn) or (bz, 1, bn)
    # jnp.sum over every axis reduces the batch slices too — exactly the
    # shared-values batch-sum contract of the backward pass.
    lanes = [jnp.sum(dy * b_ref[...].astype(acc_ref.dtype))[None]
             for b_ref in b_refs]
    acc_ref[...] += jnp.stack(lanes, axis=-1)    # (1, g)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _piped_csr_sdd_kernel(g: int, bz: int | None, depth: int, *refs):
    """Depth-2 SDD pipeline over the column-block reduction axis: step
    ``jj`` copies B's column block ``min(jj, nb-1)`` into ping-pong scratch
    slot ``jj % 2`` (packed in B's storage dtype) while reducing the
    cotangent's column block ``max(jj - 1, 0)`` against slot
    ``(jj+1) % 2``."""
    _, _, dy_ref, *rest = refs
    b_refs, (o_ref, bpan_ref, acc_ref) = rest[:g], rest[g:]
    jaxis = 1 if bz is None else 2
    jj = pl.program_id(jaxis)
    first, last = _reduction_edges(bz, depth)

    def _assemble(slot):
        for i, b_ref in enumerate(b_refs):
            if bz is None:
                bpan_ref[slot, i, :] = b_ref[...].astype(bpan_ref.dtype)[0]
            else:
                bpan_ref[slot, :, i, :] = \
                    b_ref[...][:, 0, :].astype(bpan_ref.dtype)

    for s in (0, 1):
        @pl.when(parity(jj) == s)
        def _(s=s):
            _assemble(s)

    @pl.when(jj >= depth - 1)
    def _compute():
        @pl.when(first)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        dy = dy_ref[...].astype(acc_ref.dtype)   # (1, bn) or (bz, 1, bn)

        def _reduce(slot):
            if bz is None:
                lanes = [jnp.sum(dy * bpan_ref[slot, i, :]
                                 .astype(acc_ref.dtype))[None]
                         for i in range(g)]
            else:
                lanes = [jnp.sum(dy[:, 0, :] * bpan_ref[slot, :, i, :]
                                 .astype(acc_ref.dtype))[None]
                         for i in range(g)]
            acc_ref[...] += jnp.stack(lanes, axis=-1)    # (1, g)

        for s in (0, 1):
            @pl.when(parity(jj + 1) == s)
            def _(s=s):
                _reduce(s)

        @pl.when(last)
        def _flush():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bn", "interpret", "pipeline_depth"))
def csr_sdd_panels_pallas(panel_rows: jax.Array, panel_cols: jax.Array,
                          dy: jax.Array, b: jax.Array, *,
                          bn: int | None = None,
                          interpret: bool = True,
                          pipeline_depth: int = 1) -> jax.Array:
    """Per-nonzero gradients for the CSR part, in panel layout.

    Args:
      panel_rows: (P,) int32 — cotangent row per panel (``PanelCSR`` order).
      panel_cols: (P, G) int32 — gather rows of ``b`` per lane.
      dy:         (M, N) output cotangent, or (batch, M, N) — batch summed
                  (rows beyond the CSR region are simply never indexed).
      b:          (K, N) or (batch, K, N) the forward dense operand.
    Returns:
      (P, G) gradients in the accumulation dtype; padding lanes undefined —
      gather real slots with ``PanelCSR.gather_values``.
    """
    if dy.ndim != b.ndim or b.ndim not in (2, 3):
        raise ValueError(f"dy/b must both be rank 2 or 3; got {dy.ndim} / "
                         f"{b.ndim}")
    depth = check_pipeline_depth(pipeline_depth)
    npanels, g = panel_cols.shape
    n = b.shape[-1]
    bn = bn or default_bn(n)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype = acc_dtype_for(b.dtype)
    batch = b.shape[0] if b.ndim == 3 else None
    nb = n // bn
    lj, cj = _sdd_col_maps(depth, nb)
    if batch is None:
        grid = (npanels, nb + depth - 1)
        bz = None
        in_specs = [
            pl.BlockSpec((1, bn),
                         lambda p, j, rows, cols: (rows[p], cj(j))),
            *[pl.BlockSpec((1, bn),
                           lambda p, j, rows, cols, i=i: (cols[p, i], lj(j)))
              for i in range(g)],
        ]
        out_specs = pl.BlockSpec((1, g), lambda p, j, rows, cols: (p, 0))
        bpan_shape = (depth, g, bn)
    else:
        bz = batch_block(batch)
        grid = (npanels, batch // bz, nb + depth - 1)
        in_specs = [
            pl.BlockSpec((bz, 1, bn),
                         lambda p, z, j, rows, cols: (z, rows[p], cj(j))),
            *[pl.BlockSpec((bz, 1, bn),
                           lambda p, z, j, rows, cols, i=i:
                           (z, cols[p, i], lj(j)))
              for i in range(g)],
        ]
        out_specs = pl.BlockSpec((1, g), lambda p, z, j, rows, cols: (p, 0))
        bpan_shape = (depth, bz, g, bn)
    scratch = [pltpu.VMEM((1, g), acc_dtype)]
    if depth > 1:
        scratch.insert(0, pltpu.VMEM(bpan_shape, b.dtype))  # packed ping-pong
        kernel = functools.partial(_piped_csr_sdd_kernel, g, bz, depth)
    else:
        kernel = functools.partial(_csr_sdd_kernel, g, bz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # panel_rows, panel_cols
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((npanels, g), acc_dtype),
        interpret=interpret,
    )(panel_rows, panel_cols, dy, *([b] * g))


def _bcsr_sdd_kernel(g: int, bz: int | None, *refs):
    """One grid step: gather the G B-rows into scratch, one (Br,bn)@(bn,G)
    MXU contraction against the block-row's cotangent slab (contracted over
    the batch slices too when batched)."""
    _, _, dy_ref, *rest = refs
    b_refs, (o_ref, bpan_ref, acc_ref) = rest[:g], rest[g:]
    first, last = _reduction_edges(bz)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The B panel stays packed in B's storage dtype in scratch (half the
    # VMEM for bf16/f16); promotion to the accumulation dtype happens at
    # the dot operand read — bf16 -> f32 is exact, so results are
    # unchanged.
    if bz is None:
        for i, b_ref in enumerate(b_refs):
            bpan_ref[i, :] = b_ref[...].astype(bpan_ref.dtype)[0]
        acc_ref[...] += jax.lax.dot_general(
            dy_ref[...].astype(acc_ref.dtype),
            bpan_ref[...].astype(acc_ref.dtype),
            (((1,), (1,)), ((), ())),
            preferred_element_type=acc_ref.dtype)       # (br, g)
    else:
        for i, b_ref in enumerate(b_refs):
            bpan_ref[:, i, :] = b_ref[...][:, 0, :].astype(bpan_ref.dtype)
        # (bz, br, bn) x (bz, g, bn) contracted over (batch, bn) -> (br, g):
        # the batch axis joins the N-reduction, realising the batch sum.
        acc_ref[...] += jax.lax.dot_general(
            dy_ref[...].astype(acc_ref.dtype),
            bpan_ref[...].astype(acc_ref.dtype),
            (((0, 2), (0, 2)), ((), ())),
            preferred_element_type=acc_ref.dtype)       # (br, g)

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


def _piped_bcsr_sdd_kernel(g: int, bz: int | None, depth: int, *refs):
    """Depth-2 SDD pipeline over the column-block reduction axis (BCSR
    part): step ``jj`` assembles B's column block ``min(jj, nb-1)`` into
    ping-pong slot ``jj % 2`` while the MXU contracts the cotangent's
    column block ``max(jj - 1, 0)`` against slot ``(jj+1) % 2``."""
    _, _, dy_ref, *rest = refs
    b_refs, (o_ref, bpan_ref, acc_ref) = rest[:g], rest[g:]
    jaxis = 1 if bz is None else 2
    jj = pl.program_id(jaxis)
    first, last = _reduction_edges(bz, depth)

    def _assemble(slot):
        for i, b_ref in enumerate(b_refs):
            if bz is None:
                bpan_ref[slot, i, :] = b_ref[...].astype(bpan_ref.dtype)[0]
            else:
                bpan_ref[slot, :, i, :] = \
                    b_ref[...][:, 0, :].astype(bpan_ref.dtype)

    for s in (0, 1):
        @pl.when(parity(jj) == s)
        def _(s=s):
            _assemble(s)

    @pl.when(jj >= depth - 1)
    def _compute():
        @pl.when(first)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        def _contract(slot):
            if bz is None:
                acc_ref[...] += jax.lax.dot_general(
                    dy_ref[...].astype(acc_ref.dtype),
                    bpan_ref[slot].astype(acc_ref.dtype),
                    (((1,), (1,)), ((), ())),
                    preferred_element_type=acc_ref.dtype)       # (br, g)
            else:
                acc_ref[...] += jax.lax.dot_general(
                    dy_ref[...].astype(acc_ref.dtype),
                    bpan_ref[slot].astype(acc_ref.dtype),
                    (((0, 2), (0, 2)), ((), ())),
                    preferred_element_type=acc_ref.dtype)       # (br, g)

        for s in (0, 1):
            @pl.when(parity(jj + 1) == s)
            def _(s=s):
                _contract(s)

        @pl.when(last)
        def _flush():
            o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "bn", "interpret",
                                             "pipeline_depth"))
def bcsr_sdd_panels_pallas(panel_rows: jax.Array, panel_cols: jax.Array,
                           dy_pad: jax.Array, b: jax.Array, *, br: int,
                           bn: int | None = None,
                           interpret: bool = True,
                           pipeline_depth: int = 1) -> jax.Array:
    """Per-tile-element gradients for the BCSR part, in panel layout.

    Args:
      panel_rows: (P,) int32 — block-row per panel (``PanelBCSR`` order).
      panel_cols: (P, G) int32 — gather rows of ``b`` per lane.
      dy_pad:     (nblocks * Br, N) or (batch, nblocks * Br, N) — the BCSR
                  region of the cotangent, zero-padded to full blocks
                  (trimmed rows ⇒ zero grad); batch summed.
      b:          (K, N) or (batch, K, N) the forward dense operand.
    Returns:
      (P, Br, G) gradients in the accumulation dtype; padding lanes
      undefined — gather real slots with ``PanelBCSR.gather_values``.
    """
    if dy_pad.ndim != b.ndim or b.ndim not in (2, 3):
        raise ValueError(f"dy_pad/b must both be rank 2 or 3; got "
                         f"{dy_pad.ndim} / {b.ndim}")
    depth = check_pipeline_depth(pipeline_depth)
    npanels, g = panel_cols.shape
    n = b.shape[-1]
    bn = bn or default_bn(n)
    if n % bn:
        raise ValueError(f"N={n} not divisible by bn={bn}")
    acc_dtype = acc_dtype_for(b.dtype)
    batch = b.shape[0] if b.ndim == 3 else None
    nb = n // bn
    lj, cj = _sdd_col_maps(depth, nb)
    if batch is None:
        bz = None
        grid = (npanels, nb + depth - 1)
        in_specs = [
            pl.BlockSpec((br, bn),
                         lambda p, j, rows, cols: (rows[p], cj(j))),
            *[pl.BlockSpec((1, bn),
                           lambda p, j, rows, cols, i=i: (cols[p, i], lj(j)))
              for i in range(g)],
        ]
        out_specs = pl.BlockSpec((1, br, g),
                                 lambda p, j, rows, cols: (p, 0, 0))
        bpan_shape = (g, bn) if depth == 1 else (depth, g, bn)
        scratch = [pltpu.VMEM(bpan_shape, b.dtype),     # B panel (packed)
                   pltpu.VMEM((br, g), acc_dtype)]      # accumulator
    else:
        bz = batch_block(batch)
        grid = (npanels, batch // bz, nb + depth - 1)
        in_specs = [
            pl.BlockSpec((bz, br, bn),
                         lambda p, z, j, rows, cols: (z, rows[p], cj(j))),
            *[pl.BlockSpec((bz, 1, bn),
                           lambda p, z, j, rows, cols, i=i:
                           (z, cols[p, i], lj(j)))
              for i in range(g)],
        ]
        out_specs = pl.BlockSpec((1, br, g),
                                 lambda p, z, j, rows, cols: (p, 0, 0))
        bpan_shape = (bz, g, bn) if depth == 1 else (depth, bz, g, bn)
        scratch = [pltpu.VMEM(bpan_shape, b.dtype),     # B panels (packed)
                   pltpu.VMEM((br, g), acc_dtype)]
    if depth > 1:
        kernel = functools.partial(_piped_bcsr_sdd_kernel, g, bz, depth)
    else:
        kernel = functools.partial(_bcsr_sdd_kernel, g, bz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # panel_rows, panel_cols
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((npanels, br, g), acc_dtype),
        interpret=interpret,
    )(panel_rows, panel_cols, dy_pad, *([b] * g))


register_kernel("csr", "sdd", "panels", csr_sdd_panels_pallas)
register_kernel("bcsr", "sdd", "panels", bcsr_sdd_panels_pallas)
