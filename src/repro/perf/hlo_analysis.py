"""Optimized-HLO text analyzer: FLOPs / HBM bytes / collective bytes with
while-loop trip-count multiplication.

Why this exists: ``compiled.cost_analysis()`` visits a ``while`` body ONCE
(verified empirically), so any scanned layer stack (all 10 archs), microbatch
loop or CE chunk loop is undercounted by its trip count.  This module parses
``compiled.as_text()`` (post-SPMD, post-fusion, per-device), reconstructs the
computation call graph (while bodies x trip count, fusions, calls) and
accumulates:

  * flops        — 2 * |result| * |contracted dims| per dot (incl. dots
                   inside fused/wrapped computations);
  * hbm_bytes    — post-fusion traffic model: each top-level op reads its
                   operands and writes its result; slicing ops (dynamic-slice
                   / gather / dynamic-update-slice) count the slice, not the
                   sliced-into operand (a scan reading one layer's weights
                   must not be charged the whole stack);
  * collective_bytes — operand bytes of all-reduce / all-gather /
                   reduce-scatter / all-to-all / collective-permute, using
                   replica-group sizes for the gather/scatter asymmetry.

Trip counts come from the loop-condition computation's comparison constant
(jax scans lower to 0..N LT loops).  Unknown conditions default to 1 (and are
reported so the roofline table can flag them).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Tuple

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "copy-start", "copy-done", "reshape", "iota",
             "partition-id", "replica-id"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?)\s*"
    r"([a-z0-9\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^%?([\w.\-]+)\s*\(.*->.*\{\s*$")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    rest: str  # operands + attrs


def _fusion_traffic(op: "_Op", names: Dict[str, str], callee: str | None,
                    ops_by_comp) -> int:
    """HBM bytes charged at a fusion boundary.

    Refinements over naive (result + all operands):
      * a parameter whose only fused uses are (dynamic-)slice/gather ops is
        charged the slice bytes (a scan reading one timestep is not billed
        the whole sequence);
      * an in-place dynamic-update-slice root (XLA aliases the target) is
        charged 2x the update-slice bytes, and the aliased target parameter
        is charged 0 (a scan writing one timestep is not billed the whole
        stacked output).
    """
    callee_ops = ops_by_comp.get(callee, []) if callee else []
    cnames = {o.name: o.shape for o in callee_ops}
    param_idx: Dict[str, int] = {}
    for o in callee_ops:
        if o.opcode == "parameter":
            m = re.search(r"(\d+)", o.rest)
            if m:
                param_idx[o.name] = int(m.group(1))
    uses: Dict[str, list] = {p: [] for p in param_idx}
    # follow single-step bitcast chains back to parameters
    alias_of: Dict[str, str] = {}
    for o in callee_ops:
        if o.opcode in ("bitcast", "reshape", "copy"):
            src = _operand_names(o.rest)
            if src and src[0] in param_idx:
                alias_of[o.name] = src[0]
    for o in callee_ops:
        if o.opcode == "parameter":
            continue
        for src in _operand_names(o.rest):
            root_param = alias_of.get(src, src)
            if root_param in uses:
                uses[root_param].append((o, src))

    # detect an in-place DUS root
    dus = [o for o in callee_ops if o.opcode == "dynamic-update-slice"]
    result_bytes = _shape_bytes(op.shape)
    dus_target_param = None
    if len(dus) == 1 and _shape_bytes(dus[0].shape) == result_bytes:
        operands = _operand_names(dus[0].rest)
        upd_shape = cnames.get(operands[1], "") if len(operands) > 1 else ""
        result_bytes = 2 * _shape_bytes(upd_shape)
        tgt = alias_of.get(operands[0], operands[0]) if operands else None
        if tgt in param_idx:
            dus_target_param = param_idx[tgt]

    charged: Dict[int, int] = {}
    for pname, ulist in uses.items():
        idx = param_idx[pname]
        if idx == dus_target_param:
            non_dus = [u for (u, _) in ulist
                       if u.opcode != "dynamic-update-slice"]
            if not non_dus:
                charged[idx] = 0
                continue
            ulist = [(u, s) for (u, s) in ulist
                     if u.opcode != "dynamic-update-slice"]
        if ulist and all(u.opcode in ("dynamic-slice", "slice", "gather")
                         for (u, _) in ulist):
            charged[idx] = sum(_shape_bytes(u.shape) for (u, _) in ulist)

    total = result_bytes
    for idx, o in enumerate(_operand_names(op.rest)):
        if idx in charged:
            total += charged[idx]
        else:
            total += _shape_bytes(names.get(o, ""))
    return total


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    unknown_trip_loops: int = 0
    # optional per-op attribution: (comp, op_name, opcode, metadata_op) ->
    # bytes BEFORE trip multiplication; filled when detail=True
    detail: Dict[tuple, float] = dataclasses.field(default_factory=dict)

    def scaled(self, k: float) -> "HloStats":
        return HloStats(
            self.flops * k, self.hbm_bytes * k, self.collective_bytes * k,
            {kk: v * k for kk, v in self.collective_by_kind.items()},
            self.unknown_trip_loops)

    def add(self, o: "HloStats"):
        self.flops += o.flops
        self.hbm_bytes += o.hbm_bytes
        self.collective_bytes += o.collective_bytes
        for k, v in o.collective_by_kind.items():
            self.collective_by_kind[k] = self.collective_by_kind.get(k, 0) + v
        self.unknown_trip_loops += o.unknown_trip_loops


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    body: List[str] = []
    for line in text.splitlines():
        stripped = line.strip()
        probe = stripped[len("ENTRY "):] if stripped.startswith("ENTRY ") \
            else stripped
        hdr = _COMP_HDR_RE.match(probe) if "{" in line else None
        if cur is None and hdr and "->" in line:
            cur = hdr.group(1).lstrip("%")
            body = []
            continue
        if cur is not None:
            if stripped == "}":
                comps[cur] = body
                cur = None
            else:
                body.append(line)
    return comps


def _parse_ops(lines: List[str]) -> List[_Op]:
    ops = []
    for line in lines:
        m = _OP_RE.match(line)
        if m:
            ops.append(_Op(name=m.group(1), shape=m.group(2),
                           opcode=m.group(3), rest=m.group(4)))
    return ops


def _operand_names(rest: str) -> List[str]:
    # operands are inside the first balanced (...) of rest (rest starts after '(')
    depth = 1
    buf = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    inner = "".join(buf)
    # split at top-level commas only: shape dims ([4,8,16]) and layouts
    # ({2,1,0}) carry commas of their own on XLA versions that print typed
    # operands ("f32[4,8]{1,0} %name" instead of just "%name")
    parts: List[str] = []
    d = 0
    cur: List[str] = []
    for ch in inner:
        if ch in "([{":
            d += 1
        elif ch in ")]}":
            d -= 1
        if ch == "," and d == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    # the operand name is the (possibly only) trailing %token
    return [t.strip().split()[-1].lstrip("%")
            for t in parts if t.strip()]


def _group_size(rest: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,\s]*)\}", rest)
    if m and m.group(1).strip():
        return len(m.group(1).split(","))
    return 1


def _trip_count(cond_lines: List[str]) -> int | None:
    consts = []
    for line in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", line):
            consts.append(int(m.group(1)))
    has_lt = any("direction=LT" in l for l in cond_lines)
    if consts and has_lt:
        return max(consts)
    if consts:
        return max(consts)
    return None


def analyze_hlo(text: str) -> HloStats:
    comps = _split_computations(text)
    ops_by_comp = {name: _parse_ops(lines) for name, lines in comps.items()}
    shape_by_name: Dict[str, Dict[str, str]] = {}
    for cname, ops in ops_by_comp.items():
        shape_by_name[cname] = {op.name: op.shape for op in ops}

    # dot flops inside a computation (fusions call these "wrapped" comps)
    def comp_flops_local(cname: str) -> float:
        fl = 0.0
        for op in ops_by_comp.get(cname, []):
            if op.opcode in ("dot", "convolution"):
                out_elems = 1
                for d in _shape_dims(op.shape):
                    out_elems *= d
                operands = _operand_names(op.rest)
                k = 1
                mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
                if mcd and operands:
                    lhs_shape = shape_by_name[cname].get(operands[0], "")
                    dims = _shape_dims(lhs_shape)
                    for ci in mcd.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
                fl += 2.0 * out_elems * max(k, 1)
        return fl

    memo: Dict[str, HloStats] = {}

    def visit(cname: str) -> HloStats:
        if cname in memo:
            return memo[cname]
        memo[cname] = HloStats()  # cycle guard
        st = HloStats()
        names = shape_by_name.get(cname, {})
        for op in ops_by_comp.get(cname, []):
            code = op.opcode
            if code in _FREE_OPS:
                continue
            # --- control flow ---
            if code == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                inner = visit(mb.group(1)) if mb else HloStats()
                # best source: XLA's own loop analysis in backend_config
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                if mt:
                    trips = int(mt.group(1))
                else:
                    trips = (_trip_count(comps.get(mc.group(1), []))
                             if mc else None)
                if trips is None:
                    st.unknown_trip_loops += 1
                    trips = 1
                st.add(inner.scaled(trips))
                continue
            if code in ("call", "conditional"):
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                     op.rest):
                    st.add(visit(m.group(1)))
                if code == "conditional":
                    for m in re.finditer(
                            r"(?:true_computation|false_computation|branch_computations=\{)([^,}]+)",
                            op.rest):
                        pass  # covered by calls regex in modern HLO
                continue
            if code == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.rest)
                callee = m.group(1) if m else None
                if callee:
                    st.flops += comp_flops_local(callee)
                st.hbm_bytes += _fusion_traffic(op, names, callee,
                                                ops_by_comp)
                continue
            # --- collectives ---
            if code in _COLLECTIVES:
                g = _group_size(op.rest)
                out_b = _shape_bytes(op.shape)
                if code == "all-gather":
                    operand_b = out_b / max(g, 1)
                elif code == "reduce-scatter":
                    operand_b = out_b * g
                else:  # all-reduce, all-to-all, collective-permute
                    operand_b = out_b
                st.collective_bytes += operand_b
                st.collective_by_kind[code] = (
                    st.collective_by_kind.get(code, 0) + operand_b)
                # collectives also move HBM
                st.hbm_bytes += out_b
                continue
            # --- slicing: charge the slice, not the sliced operand ---
            if code in ("dynamic-slice", "gather", "slice"):
                st.hbm_bytes += 2 * _shape_bytes(op.shape)
                continue
            if code in ("dynamic-update-slice", "scatter"):
                operands = _operand_names(op.rest)
                upd = names.get(operands[1], "") if len(operands) > 1 else ""
                st.hbm_bytes += 2 * _shape_bytes(upd)
                continue
            # --- dots / everything else: operands + result ---
            if code in ("dot", "convolution"):
                st.flops += comp_flops_local_single(cname, op, names)
            st.hbm_bytes += _shape_bytes(op.shape)
            for o in _operand_names(op.rest):
                st.hbm_bytes += _shape_bytes(names.get(o, ""))
        memo[cname] = st
        return st

    def comp_flops_local_single(cname, op, names) -> float:
        out_elems = 1
        for d in _shape_dims(op.shape):
            out_elems *= d
        operands = _operand_names(op.rest)
        k = 1
        mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
        if mcd and operands:
            dims = _shape_dims(names.get(operands[0], ""))
            for ci in mcd.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
        return 2.0 * out_elems * max(k, 1)

    analyze_hlo._internals = {  # exposed for profile_traffic
        "comps": comps, "ops_by_comp": ops_by_comp,
        "shape_by_name": shape_by_name,
    }
    # find the entry computation: the one not referenced by others, or the
    # one whose header contained ENTRY (first computation in text order that
    # XLA marks ENTRY is usually printed with 'ENTRY').
    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None or entry not in comps:
        # fallback: largest computation
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    return visit(entry)


def profile_traffic(text: str, top: int = 25):
    """Hillclimb profiler: top HBM-traffic contributors, trip-multiplied.

    Returns [(bytes_total, comp, op_name, opcode, jax_op_name_metadata)].
    """
    analyze_hlo(text)  # populate parse caches
    internals = analyze_hlo._internals
    comps = internals["comps"]
    ops_by_comp = internals["ops_by_comp"]
    shape_by_name = internals["shape_by_name"]

    # execution multiplier per computation (visit counts via call graph)
    mult: Dict[str, float] = {}

    def spread(cname: str, k: float):
        mult[cname] = mult.get(cname, 0.0) + k
        for op in ops_by_comp.get(cname, []):
            if op.opcode == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
                mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', op.rest)
                trips = int(mt.group(1)) if mt else (
                    _trip_count(comps.get(mc.group(1), [])) or 1 if mc else 1)
                if mb:
                    spread(mb.group(1), k * trips)
            elif op.opcode in ("call", "conditional"):
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)",
                                     op.rest):
                    spread(m.group(1), k)

    entry = None
    for line in text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line.strip())
            if m:
                entry = m.group(1)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c])) if comps else ""
    spread(entry, 1.0)

    rows = []
    for cname, k in mult.items():
        names = shape_by_name.get(cname, {})
        for op in ops_by_comp.get(cname, []):
            code = op.opcode
            if code in _FREE_OPS or code in ("while", "call", "conditional"):
                continue
            if code in _COLLECTIVES:
                b = _shape_bytes(op.shape)
            elif code in ("dynamic-slice", "gather", "slice"):
                b = 2 * _shape_bytes(op.shape)
            elif code in ("dynamic-update-slice", "scatter"):
                operands = _operand_names(op.rest)
                upd = names.get(operands[1], "") if len(operands) > 1 else ""
                b = 2 * _shape_bytes(upd)
            elif code == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.rest)
                b = _fusion_traffic(op, names, m.group(1) if m else None,
                                    ops_by_comp)
            else:
                b = _shape_bytes(op.shape)
                for o in _operand_names(op.rest):
                    b += _shape_bytes(names.get(o, ""))
            meta = ""
            mm = re.search(r'op_name="([^"]*)"', op.rest)
            if mm:
                meta = mm.group(1)
            rows.append((b * k, cname, op.name, code, meta))
    rows.sort(key=lambda r: -r[0])
    return rows[:top]
