"""Minimal JSON-Schema (draft-07 subset) validator — no dependencies.

The container pins its package set (no ``jsonschema`` wheel), so the
golden-schema tests and ``tools/perf_gate.py`` validate the committed
``benchmarks/bench_schema.json`` with this ~100-line subset instead.

Supported keywords: ``type`` (scalar or list), ``properties``,
``required``, ``additionalProperties`` (bool), ``items``, ``enum``,
``const``, ``minimum``, ``oneOf``, ``anyOf``, ``$ref`` (into
``#/definitions/...`` only).  Anything else in a schema is ignored —
which is the permissive direction: the gate can only get *stricter* by
upgrading the validator, never silently looser on the keywords it claims.

:func:`validate` returns a list of problem strings (empty = valid), each
prefixed with a JSON-pointer-ish path into the instance.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, List

__all__ = ["validate", "load_schema"]

_TYPES = {
    "object": dict, "array": list, "string": str, "boolean": bool,
    "null": type(None),
}


def load_schema(path) -> Dict:
    with open(pathlib.Path(path)) as f:
        return json.load(f)


def _type_ok(value: Any, tname: str) -> bool:
    if tname == "number":
        return isinstance(value, (int, float)) and not isinstance(value, bool)
    if tname == "integer":
        return (isinstance(value, int) and not isinstance(value, bool)) or \
            (isinstance(value, float) and float(value).is_integer())
    py = _TYPES.get(tname)
    if py is None:
        return True   # unknown type names never reject (permissive direction)
    if py is dict or py is list or py is str:
        return isinstance(value, py)
    if tname == "boolean":
        return isinstance(value, bool)
    return isinstance(value, py)


def _resolve_ref(ref: str, root: Dict) -> Dict:
    if not ref.startswith("#/"):
        raise ValueError(f"only local '#/' refs supported, got {ref!r}")
    node: Any = root
    for part in ref[2:].split("/"):
        node = node[part]
    return node


def _validate(value: Any, schema: Dict, root: Dict, path: str,
              problems: List[str]) -> None:
    if "$ref" in schema:
        _validate(value, _resolve_ref(schema["$ref"], root), root, path,
                  problems)
        return

    for combo in ("oneOf", "anyOf"):
        if combo in schema:
            branches = schema[combo]
            failures = []
            matched = 0
            for i, sub in enumerate(branches):
                sub_probs: List[str] = []
                _validate(value, sub, root, path, sub_probs)
                if not sub_probs:
                    matched += 1
                else:
                    failures.append(f"[{i}] {sub_probs[0]}")
            want_one = combo == "oneOf"
            if matched == 0 or (want_one and matched > 1):
                detail = "; ".join(failures[:3])
                problems.append(
                    f"{path}: matched {matched} of {len(branches)} {combo} "
                    f"branches ({detail})")
            return   # combinators subsume the sibling keywords we support

    t = schema.get("type")
    if t is not None:
        names = t if isinstance(t, list) else [t]
        if not any(_type_ok(value, n) for n in names):
            problems.append(f"{path}: expected type {t}, got "
                            f"{type(value).__name__}")
            return

    if "const" in schema and value != schema["const"]:
        problems.append(f"{path}: expected const {schema['const']!r}, "
                        f"got {value!r}")
        return
    if "enum" in schema and value not in schema["enum"]:
        problems.append(f"{path}: {value!r} not in enum {schema['enum']}")
        return
    if "minimum" in schema and isinstance(value, (int, float)) \
            and not isinstance(value, bool) and value < schema["minimum"]:
        problems.append(f"{path}: {value!r} < minimum {schema['minimum']}")

    if isinstance(value, dict):
        for key in schema.get("required", []):
            if key not in value:
                problems.append(f"{path}: missing required property {key!r}")
        props = schema.get("properties", {})
        for key, sub in props.items():
            if key in value:
                _validate(value[key], sub, root, f"{path}/{key}", problems)
        if schema.get("additionalProperties") is False:
            for key in value:
                if key not in props:
                    problems.append(f"{path}: unexpected property {key!r}")

    if isinstance(value, list) and "items" in schema:
        for i, item in enumerate(value):
            _validate(item, schema["items"], root, f"{path}[{i}]", problems)


def validate(value: Any, schema: Dict, root: Dict | None = None) -> List[str]:
    """Validate ``value`` against ``schema``; returns problem strings
    (empty list = valid).  ``root`` is the document ``$ref``s resolve
    against (defaults to ``schema`` itself)."""
    problems: List[str] = []
    _validate(value, schema, root if root is not None else schema, "$",
              problems)
    return problems
