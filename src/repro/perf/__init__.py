"""Perf tooling: HLO analysis for roofline terms."""
