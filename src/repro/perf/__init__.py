"""Perf tooling: HLO roofline analysis + the trace → fit → replay loop.

  * :mod:`repro.perf.hlo_analysis` — roofline terms from compiled HLO;
  * :mod:`repro.perf.trace` — :class:`TraceRecorder`, JSONL trace files,
    :func:`fit_cost_model` (Eq. 2 refit from measurement, provenance
    stamped);
  * :mod:`repro.perf.replay` — :class:`TraceDB`, structural
    :func:`predict_grid_steps`, and :func:`replay` — a plan's predicted
    step time before any conversion is paid;
  * :mod:`repro.perf.schema` — the dependency-free JSON-Schema subset
    validator the bench/trace golden-schema tests and ``tools/perf_gate.py``
    share.
"""
from .replay import TraceDB, predict_grid_steps, predict_part_steps, replay
from .trace import (TRACE_SCHEMA_VERSION, TraceRecorder, fit_cost_model,
                    load_traces, matrix_key)

__all__ = ["TRACE_SCHEMA_VERSION", "TraceRecorder", "fit_cost_model",
           "load_traces", "matrix_key", "TraceDB", "predict_grid_steps",
           "predict_part_steps", "replay"]
