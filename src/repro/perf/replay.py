"""Offline what-if replay: predict a plan's step time before converting.

The expensive part of trying a candidate plan is the Algorithm 1 conversion
(slice + re-tile + panelize, O(nnz) with real allocation) followed by a jit
compile and wall-clock runs.  But the *cost* of a plan on the Pallas
backends is carried almost entirely by its grid-step count — interpret mode
executes grid steps sequentially, and on hardware each step is one
panel-load + matmul round — and that count is a pure function of the CSR
structure and the plan knobs.  This module computes it without converting:

  * :func:`predict_part_steps` / :func:`predict_grid_steps` — exact
    replicas of ``core.spmm.loops_grid_steps`` semantics from the raw CSR
    (tests/test_perf_trace.py asserts exact agreement against the real
    conversion);
  * :class:`TraceDB` — a bag of measured trace records
    (``repro.perf.trace``) that fits ``wall_us ≈ c0 + c_csr·steps_csr +
    c_bcsr·steps_bcsr`` per backend (ridge-regularised least squares);
  * :func:`replay` — combine the two: predicted wall seconds of ``plan``
    on ``csr``, **before** paying any conversion.

``tune/search.py`` uses replay as its pre-measurement pruning stage and
``core.distributed.shard_loops_auto`` accepts a ``trace_db`` whose fitted
cost model drives the device split (Eq. 3 with measured coefficients).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..kernels.panel_common import default_bn
from .trace import fit_cost_model, load_traces

__all__ = ["predict_grid_steps", "predict_part_steps", "TraceDB", "replay"]


def predict_part_steps(csr, plan, n_cols: int,
                       bn: int | None = None) -> Tuple[int, int]:
    """Per-part grid steps of executing ``csr`` under ``plan`` against an
    ``(K, n_cols)`` operand — WITHOUT running the Algorithm 1 conversion.

    Matches ``loops_grid_steps(loops_from_csr(csr, r_b, br, panel_g), n_cols)``
    exactly, part by part:

      * CSR-part: rows ``[0, r_b)`` contribute ``max(ceil(c_i / g), 1)``
        panels each (``csr_slice_rows`` pads empty rows, ``panelize_csr``
        floors at one panel per row);
      * BCSR-part: block-rows of ``br`` rows contribute
        ``max(ceil(u_b / g), 1)`` panels, where ``u_b`` counts distinct
        columns among *nonzero-valued* entries in the block
        (``bcsr_from_csr_rows`` drops zero-valued structural pads and keeps
        ≥ 1 pad tile per empty block-row);
      * a part the executor skips entirely (``r_b == 0`` / ``r_b == nrows``)
        contributes zero;
      * ``macro_m > 1`` panelizes at the effective width ``panel_g·macro_m``
        and ``pipeline_depth = d`` adds ``d - 1`` ramp steps per non-empty
        part, exactly like the conversion;
      * both counts scale by ``ceil(n_cols / bn)`` column blocks
        (``bn`` defaults to ``panel_common.default_bn(n_cols)`` like the
        executor).
    """
    r_b = int(plan.r_boundary)
    br = int(plan.br)
    g = max(int(plan.panel_g), 1) * max(int(getattr(plan, "macro_m", 1)), 1)
    depth = max(int(getattr(plan, "pipeline_depth", 1)), 1)
    n = int(csr.nrows)
    bn = bn or default_bn(int(n_cols))
    col_blocks = -(-int(n_cols) // bn)

    counts = np.diff(csr.row_ptr).astype(np.int64)

    # CSR-part panels over rows [0, r_b).
    if r_b <= 0:
        p_csr = 0
    else:
        c = counts[:r_b]
        p_csr = int(np.maximum(-(-c // g), 1).sum())

    # BCSR-part panels over rows [r_b, n).
    if r_b >= n:
        p_bcsr = 0
    else:
        s, e = int(csr.row_ptr[r_b]), int(csr.row_ptr[n])
        rows = csr.row_ids[s:e].astype(np.int64) - r_b
        cols = csr.col_idx[s:e].astype(np.int64)
        nzmask = np.asarray(csr.vals[s:e]) != 0
        blocks = rows[nzmask] // br
        nblocks = max(-(-(n - r_b) // br), 1)
        # Distinct (block, col) pairs = tiles; zero-valued pads are dropped.
        lin = np.unique(blocks * int(csr.ncols) + cols[nzmask])
        tiles_per_block = np.bincount((lin // int(csr.ncols)).astype(np.int64),
                                      minlength=nblocks)
        p_bcsr = int(np.maximum(-(-tiles_per_block // g), 1).sum())

    ramp = depth - 1
    s_csr = (p_csr + ramp) * col_blocks if p_csr > 0 else 0
    s_bcsr = (p_bcsr + ramp) * col_blocks if p_bcsr > 0 else 0
    return s_csr, s_bcsr


def predict_grid_steps(csr, plan, n_cols: int, bn: int | None = None) -> int:
    """Total predicted grid steps (see :func:`predict_part_steps`)."""
    s_csr, s_bcsr = predict_part_steps(csr, plan, n_cols, bn)
    return s_csr + s_bcsr


@dataclasses.dataclass
class TraceDB:
    """Queryable bag of measured trace records.

    Construct from in-memory records (``TraceDB(records)``), a recorder
    (``TraceDB(rec.records)``) or from disk (:meth:`load` — a JSONL file or
    a whole trace directory).
    """

    records: List[Dict] = dataclasses.field(default_factory=list)

    @classmethod
    def load(cls, path: os.PathLike | str) -> "TraceDB":
        return cls(records=load_traces(path))

    def extend(self, records) -> None:
        self.records.extend(records)

    def _cells(self, backend: Optional[str]) -> List[Dict]:
        cells = [r for r in self.records
                 if r.get("kind") in ("spmm", "search_trial")
                 and "grid_steps" in r and "wall_us" in r]
        if backend is not None:
            matching = [r for r in cells if r.get("backend") == backend]
            if matching:
                return matching
        return cells

    def step_cost(self, backend: Optional[str] = None,
                  ridge: float = 1e-6) -> Optional[np.ndarray]:
        """Fit the per-step cost surface over the measured cells
        (preferring records of ``backend``; falling back to all cells when
        none match)::

            wall_us ≈ c0 + (a_csr + b_csr·G)·steps_csr
                         + (a_bcsr + b_bcsr·G)·steps_bcsr

        The ``·G`` cross terms matter because a G-wide panel step does G×
        the gather/multiply work of a G=1 step — per-step cost is affine in
        the panel width, not constant.  When the cells don't span multiple
        panel widths (or there are too few for 5 coefficients) the fit
        drops to the 3-term form with the ``b`` terms pinned at zero.

        When the cells span more than one ``pipeline_depth`` a sixth
        ``d_pipe·(depth-1)·(steps_csr+steps_bcsr)`` term is fitted — the
        marginal cost (or saving) of running a step under the
        double-buffered pipeline.

        Returns ``[c0, a_csr, a_bcsr, b_csr, b_bcsr]`` (optionally extended
        with ``d_pipe``) or ``None`` when the cells cannot determine a
        positive per-step cost (fewer than two distinct step counts, or a
        degenerate fit).
        """
        cells = self._cells(backend)
        if len(cells) < 2:
            return None
        sc = np.array([r.get("grid_steps_csr",
                             r["grid_steps"]) for r in cells], np.float64)
        sb = np.array([r.get("grid_steps_bcsr", 0) for r in cells],
                      np.float64)
        g = np.array([r.get("panel_g", 1) for r in cells], np.float64)
        d = np.array([r.get("pipeline_depth", 1) for r in cells], np.float64)
        w = np.array([r["wall_us"] for r in cells], np.float64)
        if len(np.unique(sc + sb)) < 2:
            return None
        use_g = len(np.unique(g)) > 1 and len(cells) >= 6
        use_d = len(np.unique(d)) > 1 and len(cells) >= (8 if use_g else 5)
        cols = [np.ones_like(sc), sc, sb]
        if use_g:
            cols += [sc * g, sb * g]
        if use_d:
            cols += [(d - 1.0) * (sc + sb)]
        design = np.stack(cols, axis=1)
        ncoef = design.shape[1]
        ata = design.T @ design
        lam = ridge * max(float(np.trace(ata)) / ncoef, 1.0)
        coef = np.linalg.solve(ata + lam * np.eye(ncoef), design.T @ w)
        if use_d:
            d_pipe = coef[-1:]          # may legitimately be negative
            coef = coef[:-1]
        else:
            d_pipe = np.zeros((0,))
        if not use_g:
            coef = np.concatenate([coef, [0.0, 0.0]])
        # A usable model needs a non-negative floor and at least one
        # positive per-step cost; clamp tiny negatives from noise (the
        # pipeline term is exempt — overlap SHOULD make it negative).
        coef = np.maximum(coef, 0.0)
        if coef[1:].sum() <= 0:
            return None
        return np.concatenate([coef, d_pipe]) if use_d else coef

    def predict_us(self, coef: np.ndarray, s_csr: int, s_bcsr: int,
                   g: int, depth: int = 1) -> float:
        """Evaluate a :meth:`step_cost` coefficient vector at one cell.
        ``g`` is the *effective* panel width (``panel_g × macro_m``)."""
        us = float(coef[0] + (coef[1] + coef[3] * g) * s_csr
                   + (coef[2] + coef[4] * g) * s_bcsr)
        if len(coef) > 5:
            us += float(coef[5]) * (depth - 1) * (s_csr + s_bcsr)
        return max(us, 0.0)

    def cost_model(self, *, ridge: float = 1e-3):
        """Eq. 2 / panel-extended model refit from these records
        (:func:`repro.perf.trace.fit_cost_model`); ``None`` when
        underdetermined."""
        return fit_cost_model(self.records, ridge=ridge)


def replay(plan, trace_db: TraceDB, *, csr, n_cols: int,
           backend: Optional[str] = None,
           bn: int | None = None) -> Optional[float]:
    """Predicted wall seconds of executing ``csr`` under ``plan`` — no
    conversion, no compile, no measurement.

    Combines the structural step count (:func:`predict_part_steps`) with
    the per-step cost fitted from ``trace_db``; returns ``None`` when the
    database cannot support a fit (caller falls back to its prior).
    """
    coef = trace_db.step_cost(backend)
    if coef is None:
        return None
    s_csr, s_bcsr = predict_part_steps(csr, plan, n_cols, bn)
    g_eff = (max(int(plan.panel_g), 1)
             * max(int(getattr(plan, "macro_m", 1)), 1))
    us = trace_db.predict_us(coef, s_csr, s_bcsr, g_eff,
                             depth=int(getattr(plan, "pipeline_depth", 1)))
    return us * 1e-6
