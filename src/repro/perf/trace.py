"""Versioned perf traces: record what the engine ran and how long it took.

This is the measurement half of the trace → fit → replay → gate loop
(docs/architecture.md §"Perf trace & replay").  A :class:`TraceRecorder`
captures three kinds of records into one append-only list:

  * ``dispatch`` — structural facts from the execution engine's tracer hook
    (:func:`repro.kernels.engine.set_tracer`): which ``(part, op)`` kernel
    flavour ran, its panel/nonzero count, batch and column extents.  These
    fire at *trace* time (once per jit compilation), so they carry no
    wall-clock — they attribute a workload to pipelines.
  * ``spmm`` / ``search_trial`` — one measured SpMM cell: the plan knobs
    (``r_frac``, ``t_vpu``, ``t_mxu``, ``br``, ``panel_g``), the matrix key,
    per-part and total grid-step counts, and the median wall microseconds of
    the blocking call.  These are what :func:`fit_cost_model` and
    :class:`repro.perf.replay.TraceDB` consume.
  * ``step`` — per-call wall-clock of a wrapped ``dist/step.py`` step
    function (train / prefill / decode), indexed by call number.

Traces serialise to JSONL (one JSON object per line, every line stamped with
``schema = TRACE_SCHEMA_VERSION``) under ``benchmarks/results/traces/`` by
default; :func:`load_traces` refuses a future schema version instead of
silently misreading it.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import pathlib
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from ..core.perf_model import QuadraticPerfModel, fit_perf_model

__all__ = ["TRACE_SCHEMA_VERSION", "TraceRecorder", "default_trace_dir",
           "load_traces", "fit_cost_model", "matrix_key"]

TRACE_SCHEMA_VERSION = 1

# Record kinds a trace file may contain (bench_schema.json mirrors this).
TRACE_KINDS = ("dispatch", "spmm", "search_trial", "step")


def default_trace_dir() -> pathlib.Path:
    """``benchmarks/results/traces/`` at the repo root (the checkout layout
    this project runs from), overridable via ``$REPRO_TRACE_DIR``."""
    env = os.environ.get("REPRO_TRACE_DIR")
    if env:
        return pathlib.Path(env)
    root = pathlib.Path(__file__).resolve().parents[3]
    return root / "benchmarks" / "results" / "traces"


def matrix_key(csr) -> str:
    """Stable identity of a matrix's *row-statistics* structure.

    Uses only the permutation-invariant prefix of the tuner fingerprint
    (shape, nnz, per-row mean/cv/max — the Table-2 statistics), quantised
    into the same 0.5-wide log-space bins as the plan cache.  Two matrices
    that differ only by a row permutation — or by values — share a key, so
    trace records transfer exactly when a measured step time is expected to
    transfer (tests/test_formats_properties.py holds this invariant).
    """
    from ..tune.fingerprint import fingerprint
    fp = fingerprint(csr)
    inv = fp.quantised()[:6]   # permutation-invariant row-stat features
    return "mx-" + ",".join(f"{q:.1f}" for q in inv)


def _plan_fields(plan, nrows: int) -> Dict:
    return {
        "r_frac": float(plan.r_boundary) / max(int(nrows), 1),
        "t_vpu": int(plan.t_vpu), "t_mxu": int(plan.t_mxu),
        "br": int(plan.br), "panel_g": int(plan.panel_g),
        "pipeline_depth": int(getattr(plan, "pipeline_depth", 1)),
        "macro_m": int(getattr(plan, "macro_m", 1)),
    }


@dataclasses.dataclass
class TraceRecorder:
    """Collects schema-stamped perf records; attach/save are explicit.

    Typical benchmark usage::

        rec = TraceRecorder(source="fig4")
        with rec.attach_engine():          # dispatch attribution (optional)
            rec.record_spmm(csr, plan, wall_s=secs, n_cols=N, backend="jnp")
        rec.save()                         # -> benchmarks/results/traces/
    """

    source: str = "manual"
    records: List[Dict] = dataclasses.field(default_factory=list)

    # -- raw record entry -------------------------------------------------
    def record(self, kind: str, **fields) -> Dict:
        if kind not in TRACE_KINDS:
            raise ValueError(f"unknown trace record kind {kind!r}; "
                             f"expected one of {TRACE_KINDS}")
        rec = {"schema": TRACE_SCHEMA_VERSION, "kind": kind,
               "source": self.source, **fields}
        self.records.append(rec)
        return rec

    # -- engine dispatch hook --------------------------------------------
    def on_dispatch(self, **fields) -> None:
        """Engine tracer callback (structure only — fires at trace time)."""
        self.record("dispatch", **fields)

    def attach_engine(self):
        """Context manager installing this recorder as the engine tracer."""
        from ..kernels import engine
        recorder = self

        class _Attach:
            def __enter__(self):
                self._prev = engine.set_tracer(recorder)
                return recorder

            def __exit__(self, *exc):
                engine.set_tracer(self._prev)
                return False

        return _Attach()

    # -- measured SpMM cells ----------------------------------------------
    def record_spmm(self, csr, plan, *, wall_s: float, n_cols: int,
                    backend: str, kind: str = "spmm",
                    label: Optional[str] = None,
                    gflops: Optional[float] = None) -> Dict:
        """One measured (matrix, plan) cell.

        ``wall_s`` is the blocking median wall seconds of the call the
        caller timed; grid-step counts are derived structurally from
        ``(csr, plan)`` via :func:`repro.perf.replay.predict_part_steps`
        (no conversion is performed here).
        """
        from .replay import predict_part_steps
        s_csr, s_bcsr = predict_part_steps(csr, plan, n_cols)
        nnz = int(np.count_nonzero(csr.vals))
        if gflops is None and wall_s > 0:
            gflops = 2.0 * nnz * int(n_cols) / wall_s / 1e9
        return self.record(
            kind,
            matrix=label if label is not None else matrix_key(csr),
            backend=str(backend), n_cols=int(n_cols), nnz=nnz,
            nrows=int(csr.nrows), ncols=int(csr.ncols),
            wall_us=float(wall_s) * 1e6,
            gflops=float(gflops) if gflops is not None else 0.0,
            grid_steps=int(s_csr + s_bcsr),
            grid_steps_csr=int(s_csr), grid_steps_bcsr=int(s_bcsr),
            **_plan_fields(plan, csr.nrows))

    # -- step-function wrapping (dist/step.py builders) -------------------
    def wrap_step(self, fn: Callable, *, op: str,
                  part: str = "step") -> Callable:
        """Wrap a (jitted) step function: each call blocks on its outputs
        and appends a ``step`` record with the call's wall microseconds."""
        import jax
        counter = [0]

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            self.record("step", part=part, op=op, step=counter[0],
                        wall_us=dt * 1e6)
            counter[0] += 1
            return out

        return wrapped

    # -- persistence ------------------------------------------------------
    def save(self, path: os.PathLike | str | None = None) -> pathlib.Path:
        """Write all records as JSONL.  Default target:
        ``default_trace_dir()/<source>.jsonl`` (deterministic name, so a
        re-run replaces the previous trace instead of accumulating)."""
        if path is None:
            path = default_trace_dir() / f"{self.source}.jsonl"
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "w") as f:
            for rec in self.records:
                f.write(json.dumps(rec, sort_keys=True) + "\n")
        return path


def load_traces(path: os.PathLike | str) -> List[Dict]:
    """Read one JSONL trace file (or every ``*.jsonl`` in a directory),
    validating the schema stamp on every record."""
    path = pathlib.Path(path)
    files = sorted(path.glob("*.jsonl")) if path.is_dir() else [path]
    records: List[Dict] = []
    for fp in files:
        with open(fp) as f:
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                ver = rec.get("schema")
                if ver != TRACE_SCHEMA_VERSION:
                    raise ValueError(
                        f"{fp}:{ln}: trace schema {ver!r} != supported "
                        f"{TRACE_SCHEMA_VERSION}")
                records.append(rec)
    return records


def fit_cost_model(traces: Iterable[Dict], *, ridge: float = 1e-3,
                   g_choices: Sequence[int] | None = None
                   ) -> Optional[QuadraticPerfModel]:
    """Refit the Eq. 2 / panel-extended Eq. 2 coefficients from measured
    trace records, replacing hand-set model inputs.

    Groups ``spmm``/``search_trial`` records by their plan knobs: each
    record is one ``(t_vpu, t_mxu, panel_g) -> gflops`` sample (multiple
    records of the same knobs average).  The fit is ridge-regularised
    (``ridge`` is relative Tikhonov strength — measured perfs are noisy) and
    the returned model carries a ``calibrated_from`` provenance stamp.

    Returns ``None`` when the traces hold too few distinct samples to
    determine even the 5-coefficient Eq. 2 form.
    """
    by_knobs: Dict[tuple, List[float]] = {}
    nused = 0
    for rec in traces:
        if rec.get("kind") not in ("spmm", "search_trial"):
            continue
        if not all(k in rec for k in ("t_vpu", "t_mxu", "panel_g")):
            continue
        perf = rec.get("gflops")
        if perf is None or not np.isfinite(perf) or perf <= 0:
            continue
        nused += 1
        knobs = (int(rec["t_vpu"]), int(rec["t_mxu"]), int(rec["panel_g"]))
        by_knobs.setdefault(knobs, []).append(float(perf))

    samples = [(x, y, g) for (x, y, g) in by_knobs]
    perfs = [float(np.mean(by_knobs[k])) for k in by_knobs]
    gs = {g for (_, _, g) in samples}
    use_g = len(gs) > 1 if g_choices is None else len(g_choices) > 1
    if use_g and len(samples) < 7:
        use_g = False   # not enough knobs for the panel terms; drop to Eq. 2
    if not use_g:
        # Collapse the G axis: re-average over (x, y) alone.
        by_xy: Dict[tuple, List[float]] = {}
        for (x, y, g), p in zip(samples, perfs):
            by_xy.setdefault((x, y), []).append(p)
        samples = list(by_xy)
        perfs = [float(np.mean(by_xy[k])) for k in by_xy]
    ncoef = 7 if use_g else 5
    if len(samples) < ncoef:
        return None
    return fit_perf_model(
        samples, perfs, ridge=ridge,
        calibrated_from=f"traces:{nused} records, "
                        f"{len(samples)} distinct knobs")
