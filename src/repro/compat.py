"""jax version bridging.

The codebase targets the modern sharding surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, positional ``AbstractMesh``), but the
pinned toolchain ships jax 0.4.37 where

  * ``shard_map`` still lives in ``jax.experimental.shard_map``,
  * ``jax.make_mesh`` has no ``axis_types`` parameter (and
    ``jax.sharding.AxisType`` does not exist — every mesh axis behaves as the
    later ``Auto`` type, which is exactly what this repo wants),
  * ``AbstractMesh`` takes a ``((name, size), ...)`` shape-tuple instead of
    separate shapes/names sequences.

Everything that touches one of those APIs goes through this module so the
rest of the tree reads like current-jax code.  Each shim probes the modern
spelling first, so on a newer jax these become thin pass-throughs.
"""
from __future__ import annotations

from typing import Sequence

import jax

__all__ = ["shard_map", "make_mesh", "abstract_mesh"]


if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None) -> "jax.sharding.Mesh":
    """``jax.make_mesh`` with all axes Auto-typed, on any jax version.

    Also slices ``jax.devices()`` down to the mesh size when ``devices`` is
    not given — a (1, 1) test mesh must work inside a subprocess that forced
    8 host devices.
    """
    import math
    if devices is None:
        devices = jax.devices()[:math.prod(tuple(axis_shapes))]
    kwargs = {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):  # jax >= 0.5: be explicit
        kwargs["axis_types"] = (
            (jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def abstract_mesh(axis_shapes: Sequence[int],
                  axis_names: Sequence[str]) -> "jax.sharding.AbstractMesh":
    """Device-free mesh (sharding-spec rules only read shape/axis names)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:  # jax 0.4.x signature: ((name, size), ...)
        return AbstractMesh(tuple(zip(axis_names, axis_shapes)))
