"""Wall-clock spans with thread-local nesting — the attribution half of obs.

A span is one timed region of host execution::

    with obs.span("serve.decode_step", token=i) as sp:
        cache, logits = serve_fn(params, cache, toks, pos)
        sp.fence(logits)        # block_until_ready before the clock stops

Completed spans become Chrome-trace ``"X"`` (complete) events: name,
category, start timestamp (µs since the recorder's epoch), duration, thread
id and a free-form ``args`` dict.  Nesting is structural — each thread keeps
its own span stack, a child opened under a parent always closes before it —
so the exported events are properly nested per thread and Perfetto renders
them as a flame graph without any reparenting pass.

jit-safety: a ``with span(...)`` placed *inside* a jitted function's Python
body executes while jax is abstractly tracing — the timed interval would be
compile time, recorded once per compilation and never again.  Entering a
span under an active trace therefore records **nothing** (the span is
dropped and counted in the sink's ``obs.spans_dropped_traced`` counter);
spans belong at blocking host call sites, with :meth:`Span.fence` pinning
the async dispatch tail into the measured interval.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

__all__ = ["Span", "SpanSink", "current_span"]

_LOCAL = threading.local()


def _stack() -> List["Span"]:
    st = getattr(_LOCAL, "stack", None)
    if st is None:
        st = _LOCAL.stack = []
    return st


def current_span() -> Optional["Span"]:
    """The innermost open span on this thread (None outside any span)."""
    st = _stack()
    return st[-1] if st else None


def _tracing() -> bool:
    """True while jax is abstractly tracing on this thread."""
    try:
        import jax.core
        return not jax.core.trace_state_clean()
    except Exception:
        return False


class SpanSink:
    """Collects completed span events against one perf_counter epoch.

    ``events`` is append-only; each event is a plain dict with ``name``,
    ``cat``, ``ts`` (µs since epoch), ``dur`` (µs), ``tid``, ``depth`` and
    ``args`` — the exporter's native unit (Chrome traces are µs-based).
    """

    def __init__(self, on_drop=None):
        self.epoch = time.perf_counter()
        self.events: List[Dict] = []
        self._lock = threading.Lock()
        self._on_drop = on_drop

    def now_us(self) -> float:
        return (time.perf_counter() - self.epoch) * 1e6

    def span(self, name: str, cat: str = "obs", **args) -> "Span":
        return Span(self, name, cat=cat, args=args)

    def emit(self, event: Dict) -> None:
        with self._lock:
            self.events.append(event)

    def dropped(self, name: str) -> None:
        if self._on_drop is not None:
            self._on_drop(name)


class Span:
    """Context manager for one timed region (see module docstring)."""

    __slots__ = ("sink", "name", "cat", "args", "_t0", "_fences", "_live")

    def __init__(self, sink: SpanSink, name: str, cat: str = "obs",
                 args: Optional[Dict] = None):
        self.sink = sink
        self.name = name
        self.cat = cat
        self.args = dict(args or {})
        self._fences: list = []
        self._live = False

    def fence(self, *values) -> None:
        """Register jax values to ``block_until_ready`` before the span
        closes, so asynchronously dispatched device work lands inside the
        measured interval instead of leaking into the next span."""
        self._fences.extend(values)

    def set(self, **args) -> None:
        """Attach/overwrite args after entry (e.g. a result size)."""
        self.args.update(args)

    def __enter__(self) -> "Span":
        if _tracing():
            # Abstract tracing: recording here would mean once-per-compile
            # semantics.  Drop (counted), keep the context-manager shape.
            self.sink.dropped(self.name)
            self._live = False
            return self
        self._live = True
        _stack().append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if not self._live:
            return False
        if self._fences:
            import jax
            jax.block_until_ready(self._fences)
        t1 = time.perf_counter()
        st = _stack()
        # Tolerate exceptions unwinding several spans at once: pop until us.
        while st and st[-1] is not self:
            st.pop()
        if st:
            st.pop()
        depth = len(st)
        if exc_type is not None:
            self.args.setdefault("error", exc_type.__name__)
        self.sink.emit({
            "name": self.name, "cat": self.cat,
            "ts": (self._t0 - self.sink.epoch) * 1e6,
            "dur": (t1 - self._t0) * 1e6,
            "tid": threading.get_ident(), "depth": depth,
            "args": self.args,
        })
        return False
