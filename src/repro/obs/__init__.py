"""``repro.obs`` — runtime observability for live train/serve runs.

What ``repro.perf.trace`` is to *benchmark capture* (measured SpMM cells,
cost-model fitting, CI gating), this package is to *live runs*: labeled
metrics (counters / gauges / fixed-bucket histograms with p50/p90/p99),
wall-clock spans with thread-local nesting, and exporters producing a
versioned JSONL stream plus a Perfetto-loadable Chrome trace.  See
``docs/observability.md`` for the API tour and
``docs/architecture.md`` §8 for where the layer sits.

Quick start::

    from repro.obs import Obs

    obs = Obs(source="serve")
    with obs.attach_engine():                 # (part, op) dispatch counters
        with obs.span("prefill") as sp:
            cache, logits = prefill_fn(params, batch)
            sp.fence(logits)
        obs.histogram("serve.prefill_us").observe(...)
    jsonl, chrome = obs.save()                # benchmarks/results/obs/
    print(obs.summary())

``tools/obs_report.py`` renders a saved capture as a terminal table.
"""
from .export import (OBS_KINDS, OBS_SCHEMA_VERSION, chrome_trace,
                     default_obs_dir, load_obs, obs_records,
                     write_chrome_trace, write_jsonl)
from .metrics import (DEFAULT_BUCKETS_US, Counter, Gauge, Histogram,
                      MetricsRegistry)
from .runtime import Obs, get_active, note_collective, set_active
from .spans import Span, SpanSink, current_span

__all__ = [
    "Obs", "set_active", "get_active", "note_collective",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "DEFAULT_BUCKETS_US",
    "Span", "SpanSink", "current_span",
    "OBS_SCHEMA_VERSION", "OBS_KINDS", "obs_records", "chrome_trace",
    "write_jsonl", "write_chrome_trace", "load_obs", "default_obs_dir",
]
