"""The ``Obs`` facade — one capture object for a live train/serve run.

Ties the registry (``repro.obs.metrics``), the span sink
(``repro.obs.spans``) and the exporters (``repro.obs.export``) together and
owns the built-in instrumentation seams:

  * :meth:`Obs.attach_engine` — hooks the execution engine's dispatch
    tracer (``repro.kernels.engine.set_tracer``) and turns every kernel
    dispatch into ``engine.dispatch`` counters (labeled ``part``/``op``/
    ``backend``/``impl``) plus ``engine.grid_steps`` gauges.  Dispatches
    fire at *trace* time — once per jit compilation — so these are
    compilation-workload counters, deliberately not per-execution (the
    per-execution signal is the step/request latency recorded on the
    host).  The hook **chains**: a previously installed tracer (e.g. a
    ``repro.perf.trace.TraceRecorder``) keeps receiving every dispatch.
  * :meth:`Obs.watch_cache` — registers a ``repro.tune.PlanCache`` whose
    ``CacheStats`` are exported as ``tune.cache.*`` gauges at snapshot
    time (pull model: the cache is read when records are exported, so the
    hit rate reflects the whole run).
  * :meth:`Obs.wrap_step` — wraps a jitted step function (the
    ``dist/step.py`` builder products take ``obs=``): each call runs under
    a span, blocks on its outputs, and lands one observation in the
    ``step.wall_us`` histogram for its ``op``.
  * collective accounting — ``repro.dist.compress.compressed_psum``
    reports its per-call wire bytes to the *active* capture
    (:func:`set_active`; trace-time, so the gauge is bytes-per-call and
    the counter counts compiled call sites).

``save()`` writes both serialisations (JSONL + Chrome trace) next to each
other under ``benchmarks/results/obs/`` by default.
"""
from __future__ import annotations

import functools
import pathlib
import time
from typing import Dict, Optional, Tuple

from .export import (chrome_trace, default_obs_dir, obs_records, write_chrome_trace,
                     write_jsonl)
from .metrics import MetricsRegistry
from .spans import SpanSink

__all__ = ["Obs", "set_active", "get_active", "note_collective"]

# Process-wide active capture (the collective hook's rendezvous; launch
# drivers install their Obs here for the duration of a run).
_ACTIVE: Optional["Obs"] = None


def set_active(obs: Optional["Obs"]) -> Optional["Obs"]:
    """Install ``obs`` as the process-wide capture (None detaches);
    returns the previous one so callers can restore it."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, obs
    return prev


def get_active() -> Optional["Obs"]:
    return _ACTIVE


def note_collective(nbytes: int, *, kind: str, precision: str) -> None:
    """Report one collective call site's per-call wire bytes to the active
    capture (no-op without one).  Called from inside traced code, so it
    fires once per compilation: ``dist.collective_bytes`` is a
    bytes-per-call gauge and ``dist.collective_sites`` counts compiled
    call sites — never per-execution totals."""
    obs = _ACTIVE
    if obs is None:
        return
    obs.metrics.gauge("dist.collective_bytes", kind=kind,
                      precision=precision).set(float(nbytes))
    obs.metrics.counter("dist.collective_sites", kind=kind,
                        precision=precision).inc()


class _EngineTracer:
    """Adapter from the engine's ``on_dispatch`` hook to obs instruments,
    forwarding every event to a previously installed tracer."""

    def __init__(self, obs: "Obs", prev=None):
        self.obs = obs
        self.prev = prev

    def on_dispatch(self, *, part: str, op: str, **fields) -> None:
        m = self.obs.metrics
        m.counter("engine.dispatch", part=part, op=op,
                  backend=fields.get("backend", "?"),
                  impl=fields.get("impl", "?")).inc()
        steps = fields.get("steps")
        if steps is not None:
            m.gauge("engine.grid_steps", part=part, op=op).set(float(steps))
            m.counter("engine.grid_steps_compiled", part=part,
                      op=op).inc(float(steps))
        sb = fields.get("scratch_bytes")
        if sb is not None:
            m.gauge("kernel.scratch_bytes", part=part, op=op).set(float(sb))
        ov = fields.get("prefetch_overlap")
        if ov is not None:
            m.gauge("engine.prefetch_overlap", part=part,
                    op=op).set(float(ov))
        if self.prev is not None:
            self.prev.on_dispatch(part=part, op=op, **fields)


class _Attach:
    def __init__(self, obs: "Obs"):
        self.obs = obs

    def __enter__(self):
        from ..kernels import engine
        self._prev = engine.set_tracer(_EngineTracer(self.obs,
                                                     prev=engine.get_tracer()))
        return self.obs

    def __exit__(self, *exc):
        from ..kernels import engine
        engine.set_tracer(self._prev)
        return False


class Obs:
    """One observability capture: metrics + spans + exporters."""

    def __init__(self, source: str = "run"):
        self.source = source
        self.metrics = MetricsRegistry()
        self.sink = SpanSink(on_drop=self._on_span_drop)
        self.started_at = time.time()   # wall epoch, metadata only — all
        # interval timing inside the capture is perf_counter-based

    # -- spans -------------------------------------------------------------

    def _on_span_drop(self, name: str) -> None:
        self.metrics.counter("obs.spans_dropped_traced", span=name).inc()

    def span(self, name: str, cat: str = "obs", **args):
        """Open a wall-clock span (see :mod:`repro.obs.spans`); inside an
        abstract trace this records nothing and counts a drop instead."""
        return self.sink.span(name, cat=cat, **args)

    # -- instruments (delegates) ------------------------------------------

    def counter(self, name: str, **labels):
        return self.metrics.counter(name, **labels)

    def gauge(self, name: str, **labels):
        return self.metrics.gauge(name, **labels)

    def histogram(self, name: str, buckets=None, **labels):
        return self.metrics.histogram(name, buckets=buckets, **labels)

    # -- engine seam -------------------------------------------------------

    def attach_engine(self) -> _Attach:
        """Context manager installing the dispatch adapter on the engine
        tracer hook (chains to any tracer already installed)."""
        return _Attach(self)

    # -- tuner seam --------------------------------------------------------

    def watch_cache(self, cache, name: str = "plan") -> None:
        """Export ``cache.stats`` (a ``repro.tune.CacheStats``) as
        ``tune.cache.*`` gauges whenever records are exported."""
        self._caches = getattr(self, "_caches", [])
        self._caches.append((name, cache))

    def _collect_caches(self) -> None:
        for name, cache in getattr(self, "_caches", []):
            st = cache.stats
            self.metrics.gauge("tune.cache.hits", cache=name).set(st.hits)
            self.metrics.gauge("tune.cache.near_hits",
                               cache=name).set(st.near_hits)
            self.metrics.gauge("tune.cache.misses", cache=name).set(st.misses)
            self.metrics.gauge("tune.cache.hit_rate",
                               cache=name).set(st.hit_rate)
            # distinct name from the ``tune.cache.quarantined`` *counter*
            # (note_degraded): the registry forbids one name in two kinds
            self.metrics.gauge("tune.cache.quarantined_files",
                               cache=name).set(st.quarantined)
            self.metrics.gauge("tune.cache.prewarmed",
                               cache=name).set(getattr(st, "prewarmed", 0))

    # -- step seam ---------------------------------------------------------

    def wrap_step(self, fn, *, op: str):
        """Wrap a (jitted) step function: every call runs under a span,
        blocks on its outputs (the async dispatch tail lands inside the
        measured interval) and records ``step.wall_us{op=...}``."""
        hist = self.metrics.histogram("step.wall_us", op=op)
        counter = [0]

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            t0 = time.perf_counter()
            with self.span(f"step.{op}", cat="step", step=counter[0]) as sp:
                out = fn(*args, **kwargs)
                sp.fence(out)
            hist.observe((time.perf_counter() - t0) * 1e6)
            counter[0] += 1
            return out

        return wrapped

    # -- readout / persistence --------------------------------------------

    def records(self):
        self._collect_caches()
        return obs_records(self)

    def chrome(self) -> Dict:
        self._collect_caches()
        return chrome_trace(self)

    def summary(self) -> Dict:
        """Small human-oriented digest (the launch drivers print this)."""
        self._collect_caches()
        out: Dict = {"source": self.source, "spans": len(self.sink.events)}
        dispatches = sum(
            inst.value for kind, inst in self.metrics.instruments()
            if kind == "counter" and inst.name == "engine.dispatch")
        out["engine_dispatches"] = int(dispatches)
        for kind, inst in self.metrics.instruments():
            if kind == "hist" and inst.count:
                label = ",".join(f"{k}={v}"
                                 for k, v in sorted(inst.labels.items()))
                key = f"{inst.name}{{{label}}}" if label else inst.name
                s = inst.summary()
                out[key] = {"count": s["count"],
                            "p50_us": round(s["p50"], 1),
                            "p99_us": round(s["p99"], 1)}
        return out

    def save(self, directory=None, stem: Optional[str] = None
             ) -> Tuple[pathlib.Path, pathlib.Path]:
        """Write ``<stem>.jsonl`` and ``<stem>.trace.json`` (Chrome trace)
        under ``directory`` (default ``benchmarks/results/obs/``); returns
        both paths.  Deterministic names — a re-run replaces the previous
        capture instead of accumulating."""
        directory = pathlib.Path(directory) if directory is not None \
            else default_obs_dir()
        stem = stem or self.source
        jsonl = write_jsonl(self.records(), directory / f"{stem}.jsonl")
        chrome = write_chrome_trace(self.chrome(),
                                    directory / f"{stem}.trace.json")
        return jsonl, chrome
