"""Obs exporters: versioned JSONL sink + Chrome-trace/Perfetto JSON.

Two serialisations of one capture, following the ``repro.perf.trace``
conventions (every JSONL line schema-stamped, loaders reject *newer*
schemas instead of misreading them):

  * **JSONL** (``<stem>.jsonl``) — the machine-readable record stream:
    one ``meta`` line, one line per completed span, one line per metric
    instrument (counter / gauge / hist with bucket counts and p50/p90/p99).
    This is what ``tools/obs_report.py`` and the golden-schema tests
    consume, and it is merge-compatible with the ``--obs-trace`` output of
    ``benchmarks/run.py`` (same kinds, same stamps — live runs and
    benchmark runs diff with the same tooling).
  * **Chrome trace** (``<stem>.trace.json``) — a ``{"traceEvents": [...]}``
    object loadable by Perfetto (ui.perfetto.dev) or ``chrome://tracing``:
    spans as ``"X"`` complete events, counters/gauges as ``"C"`` counter
    events, plus ``"M"`` metadata naming the process after the capture
    source.
"""
from __future__ import annotations

import json
import os
import pathlib
from typing import Dict, Iterable, List

__all__ = ["OBS_SCHEMA_VERSION", "OBS_KINDS", "obs_records", "chrome_trace",
           "write_jsonl", "write_chrome_trace", "load_obs",
           "default_obs_dir"]

OBS_SCHEMA_VERSION = 1

# Record kinds an obs JSONL may contain (bench_schema.json mirrors this).
OBS_KINDS = ("meta", "span", "counter", "gauge", "hist")


def default_obs_dir() -> pathlib.Path:
    """``benchmarks/results/obs/`` at the repo root, overridable via
    ``$REPRO_OBS_DIR`` (sibling of the perf-trace directory)."""
    env = os.environ.get("REPRO_OBS_DIR")
    if env:
        return pathlib.Path(env)
    root = pathlib.Path(__file__).resolve().parents[3]
    return root / "benchmarks" / "results" / "obs"


def _stamp(kind: str, source: str, fields: Dict) -> Dict:
    return {"schema": OBS_SCHEMA_VERSION, "kind": kind, "source": source,
            **fields}


def obs_records(obs) -> List[Dict]:
    """Flatten an :class:`repro.obs.runtime.Obs` capture into schema-stamped
    JSONL records: ``meta`` first, then spans in completion order, then one
    record per metric instrument."""
    src = obs.source
    recs = [_stamp("meta", src, {"spans": len(obs.sink.events),
                                 "metrics": len(obs.metrics.instruments())})]
    for ev in obs.sink.events:
        recs.append(_stamp("span", src, {
            "name": ev["name"], "cat": ev["cat"], "ts": float(ev["ts"]),
            "dur": float(ev["dur"]), "tid": int(ev["tid"]),
            "depth": int(ev["depth"]),
            "args": {k: v for k, v in ev["args"].items()}}))
    for rec in obs.metrics.as_records():
        recs.append(_stamp(rec.pop("kind"), src, rec))
    return recs


def chrome_trace(obs) -> Dict:
    """The capture as a Chrome-trace JSON object (Perfetto-loadable)."""
    src = obs.source
    events: List[Dict] = [
        {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
         "args": {"name": f"repro.obs:{src}"}},
    ]
    tids = sorted({int(ev["tid"]) for ev in obs.sink.events})
    for tid in tids:
        events.append({"ph": "M", "pid": 0, "tid": tid,
                       "name": "thread_name",
                       "args": {"name": f"thread-{tid}"}})
    for ev in obs.sink.events:
        events.append({"ph": "X", "pid": 0, "tid": int(ev["tid"]),
                       "name": ev["name"], "cat": ev["cat"],
                       "ts": float(ev["ts"]), "dur": float(ev["dur"]),
                       "args": {**ev["args"], "depth": ev["depth"]}})
    # Counters/gauges become single-sample counter tracks at the capture
    # end (the registry aggregates; it does not keep a time series).
    end_ts = max([float(ev["ts"]) + float(ev["dur"])
                  for ev in obs.sink.events], default=0.0)
    for kind, inst in obs.metrics.instruments():
        if kind == "hist":
            continue   # distributions render via the report, not a track
        label = ",".join(f"{k}={v}" for k, v in sorted(inst.labels.items()))
        name = f"{inst.name}{{{label}}}" if label else inst.name
        events.append({"ph": "C", "pid": 0, "tid": 0, "name": name,
                       "ts": end_ts, "args": {"value": float(inst.value)}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"source": src, "schema": OBS_SCHEMA_VERSION}}


def write_jsonl(records: Iterable[Dict], path) -> pathlib.Path:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
    return path


def write_chrome_trace(trace: Dict, path) -> pathlib.Path:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace, f, sort_keys=True)
    return path


def load_obs(path) -> List[Dict]:
    """Read one obs JSONL file (or every ``*.jsonl`` in a directory),
    validating the schema stamp on every line — a *newer* stamp raises
    instead of being silently misread (same contract as
    ``repro.perf.trace.load_traces``)."""
    path = pathlib.Path(path)
    files = sorted(path.glob("*.jsonl")) if path.is_dir() else [path]
    records: List[Dict] = []
    for fp in files:
        with open(fp) as f:
            for ln, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                ver = rec.get("schema")
                if ver != OBS_SCHEMA_VERSION:
                    raise ValueError(
                        f"{fp}:{ln}: obs schema {ver!r} != supported "
                        f"{OBS_SCHEMA_VERSION}")
                if rec.get("kind") not in OBS_KINDS:
                    raise ValueError(
                        f"{fp}:{ln}: unknown obs record kind "
                        f"{rec.get('kind')!r}")
                records.append(rec)
    return records
