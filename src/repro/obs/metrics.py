"""Labeled runtime metrics: counters, gauges, fixed-bucket histograms.

The registry is the *aggregation* half of ``repro.obs`` (spans are the
*attribution* half, ``repro.obs.spans``).  Three instrument kinds, all
host-side Python state:

  * :class:`Counter` — monotonically increasing totals (engine dispatches,
    tokens served, collective calls);
  * :class:`Gauge` — last-written values (grid steps of the most recent
    dispatch, bytes-per-call of a collective, steps/s);
  * :class:`Histogram` — fixed-bucket latency distributions with
    p50/p90/p99 summaries.  Buckets are *fixed at creation* (default: a
    1-2-5 geometric ladder from 1 µs to 100 s), so memory is O(buckets)
    regardless of sample count and two histograms of the same name merge
    bucket-wise; exact ``min``/``max``/``sum``/``count`` ride along and
    quantiles interpolate linearly inside the winning bucket.

jit-safety contract
-------------------
Instruments mutate **host** state and must never run as a tracing-time side
effect: a ``hist.observe(x)`` placed inside a jitted function's Python body
would fire once per *compilation*, not once per execution, silently
under-counting every steady-state call.  For values computed inside jit,
:meth:`MetricsRegistry.observe_in_jit` stages the observation through
``jax.debug.callback`` — the callback runs on every *execution* with the
concrete value (record-once semantics; asserted by ``tests/test_obs.py``).
Everything else (step latencies, request latencies) should be recorded at
blocking call sites on the host, where a plain ``observe()`` is already
execution-scoped.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_BUCKETS_US", "label_key"]


def _ladder(lo: float, hi: float) -> Tuple[float, ...]:
    """1-2-5 geometric bucket boundaries covering [lo, hi]."""
    out: List[float] = []
    decade = lo
    while decade <= hi:
        for m in (1.0, 2.0, 5.0):
            v = decade * m
            if lo <= v <= hi:
                out.append(v)
        decade *= 10.0
    return tuple(out)


# Default latency ladder: 1 µs … 100 s in microseconds.  Wide enough for a
# single fused kernel and for a cold-compile prefill in the same histogram.
DEFAULT_BUCKETS_US: Tuple[float, ...] = _ladder(1.0, 1e8)


def label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    """Canonical (sorted, stringified) identity of a label set."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclasses.dataclass
class Counter:
    name: str
    labels: Dict[str, str]
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def as_record(self) -> Dict:
        return {"metric": self.name, "labels": dict(self.labels),
                "value": float(self.value)}


@dataclasses.dataclass
class Gauge:
    name: str
    labels: Dict[str, str]
    value: float = 0.0
    _written: bool = False

    def set(self, v: float) -> None:
        self.value = float(v)
        self._written = True

    def as_record(self) -> Dict:
        return {"metric": self.name, "labels": dict(self.labels),
                "value": float(self.value)}


class Histogram:
    """Fixed-bucket histogram with interpolated quantiles.

    ``buckets`` are ascending upper bounds; an implicit +inf bucket catches
    overflow.  ``percentile(q)`` walks the cumulative counts to the bucket
    holding the q-quantile and interpolates linearly between that bucket's
    bounds (clamped to the exact observed ``min``/``max``, so a
    single-sample histogram reports that sample at every quantile).
    """

    def __init__(self, name: str, labels: Dict[str, str],
                 buckets: Optional[Sequence[float]] = None):
        self.name = name
        self.labels = labels
        bounds = tuple(float(b) for b in (buckets or DEFAULT_BUCKETS_US))
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram buckets must be strictly "
                             f"ascending, got {bounds}")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # +1: overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        lo, hi = 0, len(self.bounds)
        while lo < hi:                           # first bound >= v
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        self.counts[lo] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Interpolated q-quantile (q in [0, 1]); 0.0 on an empty histogram."""
        if not self.count:
            return 0.0
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        rank = q * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            lo = self.bounds[i - 1] if i > 0 else min(self.min, 0.0)
            hi = self.bounds[i] if i < len(self.bounds) else self.max
            if cum + c >= rank:
                frac = (rank - cum) / c
                est = lo + (hi - lo) * max(0.0, min(frac, 1.0))
                return max(self.min, min(est, self.max))
            cum += c
        return self.max

    def summary(self) -> Dict:
        return {"count": int(self.count), "sum": float(self.sum),
                "mean": float(self.mean),
                "min": float(self.min) if self.count else 0.0,
                "max": float(self.max) if self.count else 0.0,
                "p50": self.percentile(0.50),
                "p90": self.percentile(0.90),
                "p99": self.percentile(0.99)}

    def as_record(self) -> Dict:
        return {"metric": self.name, "labels": dict(self.labels),
                "buckets": list(self.bounds), "counts": list(self.counts),
                **self.summary()}


class MetricsRegistry:
    """Get-or-create store of labeled instruments (thread-safe).

    One instrument per ``(kind, name, labels)``; asking for an existing
    name with a different kind raises (a counter can never silently shadow
    a histogram of the same name).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: Dict[Tuple[str, str, tuple], object] = {}

    def _get(self, kind: str, cls, name: str, labels: Dict, **kwargs):
        lk = label_key(labels)
        with self._lock:
            for (k, n, other_lk), inst in self._instruments.items():
                if n == name and k != kind:
                    raise ValueError(
                        f"metric {name!r} already registered as {k}, "
                        f"cannot re-register as {kind}")
            key = (kind, name, lk)
            inst = self._instruments.get(key)
            if inst is None:
                inst = cls(name, {str(k): str(v) for k, v in labels.items()},
                           **kwargs)
                self._instruments[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, buckets: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get("hist", Histogram, name, labels, buckets=buckets)

    def observe_in_jit(self, name: str, value, **labels):
        """Stage a histogram observation from inside a jitted computation.

        Returns ``value`` unchanged so the call can be inserted inline.
        The observation happens on the host via ``jax.debug.callback`` —
        once per *execution* of the compiled function, never once per
        trace (the record-once contract ``tests/test_obs.py`` asserts).
        """
        import jax

        hist = self.histogram(name, **labels)
        jax.debug.callback(lambda v: hist.observe(float(v)), value)
        return value

    def count_in_jit(self, name: str, n=1, **labels) -> None:
        """Execution-scoped counter increment from inside jit (callback)."""
        import jax

        ctr = self.counter(name, **labels)
        jax.debug.callback(lambda k: ctr.inc(float(k)), n)

    # -- readout ----------------------------------------------------------

    def instruments(self) -> List[Tuple[str, object]]:
        with self._lock:
            return [(kind, inst) for (kind, _, _), inst
                    in self._instruments.items()]

    def find(self, kind: str, name: str, /, **labels):
        """The existing instrument, or None (no get-or-create side effect).
        ``kind``/``name`` are positional-only so labels may use those words.
        """
        with self._lock:
            return self._instruments.get((kind, name, label_key(labels)))

    def as_records(self) -> List[Dict]:
        """One plain-dict record per instrument, ``kind`` tagged (the JSONL
        exporter stamps these with the obs schema version)."""
        out = []
        for kind, inst in self.instruments():
            out.append({"kind": kind, **inst.as_record()})
        return out
