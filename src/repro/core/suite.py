"""Synthetic SuiteSparse-like matrix suite.

The container is offline, so the paper's dataset (the full SuiteSparse
collection + the 20 representative matrices of Table 2) is reproduced as a
family of generators matching the structural features the paper keys on:
per-row nnz mean/std/max (Table 2 columns), banded vs power-law vs
block-dense patterns, and the block-density statistic the paper credits for
its GCN wins (§4.5).

``table2_like(id)`` yields a scaled-down matrix whose per-row nnz statistics
are proportional to the corresponding Table 2 entry, so the benchmark labels
(m1..m20) remain meaningful on CPU-sized problems.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import numpy as np

from .formats import CSR, csr_from_coo

__all__ = ["banded", "uniform", "powerlaw", "block_dense", "table2_like",
           "TABLE2_STATS", "gcn_graph"]


def _rng(seed):
    return np.random.default_rng(seed)


def uniform(nrows: int, ncols: int, density: float, *, seed=0,
            dtype=np.float32) -> CSR:
    rng = _rng(seed)
    nnz = max(int(nrows * ncols * density), 1)
    rows = rng.integers(0, nrows, nnz)
    cols = rng.integers(0, ncols, nnz)
    vals = rng.standard_normal(nnz).astype(dtype)
    return csr_from_coo(rows, cols, vals, (nrows, ncols))


def banded(nrows: int, ncols: int, bandwidth: int, *, fill: float = 1.0,
           seed=0, dtype=np.float32) -> CSR:
    """Stencil/FEM-style band — the regular pattern where BCSR shines (pwtk,
    shipsec1, consph, cant in Table 2)."""
    rng = _rng(seed)
    rows_l, cols_l, vals_l = [], [], []
    for i in range(nrows):
        lo = max(i - bandwidth, 0)
        hi = min(i + bandwidth + 1, ncols)
        js = np.arange(lo, hi)
        if fill < 1.0:
            js = js[rng.random(len(js)) < fill]
        rows_l.append(np.full(len(js), i))
        cols_l.append(js)
        vals_l.append(rng.standard_normal(len(js)).astype(dtype))
    return csr_from_coo(np.concatenate(rows_l), np.concatenate(cols_l),
                        np.concatenate(vals_l), (nrows, ncols))


def powerlaw(nrows: int, ncols: int, mean_nnz: float, *, alpha: float = 2.1,
             seed=0, dtype=np.float32) -> CSR:
    """Scale-free web/circuit-style skew (circuit5M, FullChip, in-2004):
    few enormous hub rows + many near-empty rows — the CSR-part's reason to
    exist."""
    rng = _rng(seed)
    raw = rng.pareto(alpha - 1.0, nrows) + 1.0
    counts = np.minimum((raw / raw.mean() * mean_nnz).astype(np.int64), ncols)
    rows = np.repeat(np.arange(nrows), counts)
    cols = rng.integers(0, ncols, rows.shape[0])
    vals = rng.standard_normal(rows.shape[0]).astype(dtype)
    return csr_from_coo(rows, cols, vals, (nrows, ncols))


def block_dense(nrows: int, ncols: int, block: int, block_density: float,
                *, in_block_fill: float = 0.8, seed=0,
                dtype=np.float32) -> CSR:
    """Matrices whose nonzeros cluster in dense blocks (mip1, pdb1HYS,
    TSOPF-style) — highest LOOPS win per the paper (block density drives the
    BCSR-part's efficiency)."""
    rng = _rng(seed)
    nbr, nbc = nrows // block, ncols // block
    rows_l, cols_l = [], []
    picks = rng.random((nbr, nbc)) < block_density
    for bi, bj in zip(*np.nonzero(picks)):
        mask = rng.random((block, block)) < in_block_fill
        ii, jj = np.nonzero(mask)
        rows_l.append(bi * block + ii)
        cols_l.append(bj * block + jj)
    if not rows_l:
        rows_l, cols_l = [np.array([0])], [np.array([0])]
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    vals = rng.standard_normal(rows.shape[0]).astype(dtype)
    return csr_from_coo(rows, cols, vals, (nrows, ncols))


@dataclasses.dataclass(frozen=True)
class Table2Entry:
    name: str
    nrow: int
    nnz: int
    nnz_mean: float
    nnz_std: float
    kind: str  # generator family


# Paper Table 2, with the generator family inferred from the domain.
TABLE2_STATS: Dict[str, Table2Entry] = {
    "m1": Table2Entry("circuit5M", 5_600_000, 59_500_000, 10.71, 1356.62, "powerlaw"),
    "m2": Table2Entry("Si41Ge41H72", 200_000, 15_000_000, 80.86, 126.97, "banded"),
    "m3": Table2Entry("Ga41As41H72", 300_000, 18_500_000, 68.96, 105.39, "banded"),
    "m4": Table2Entry("in-2004", 1_400_000, 16_900_000, 12.23, 37.23, "powerlaw"),
    "m5": Table2Entry("eu-2005", 900_000, 19_200_000, 22.30, 29.33, "powerlaw"),
    "m6": Table2Entry("pwtk", 200_000, 11_600_000, 53.39, 4.74, "banded"),
    "m7": Table2Entry("FullChip", 3_000_000, 26_600_000, 8.91, 1806.80, "powerlaw"),
    "m8": Table2Entry("mip1", 100_000, 10_400_000, 155.77, 350.74, "block"),
    "m9": Table2Entry("mc2depi", 500_000, 2_100_000, 3.99, 0.08, "banded"),
    "m10": Table2Entry("webbase-1M", 1_000_000, 3_100_000, 3.11, 25.35, "powerlaw"),
    "m11": Table2Entry("shipsec1", 100_000, 7_800_000, 55.46, 11.07, "banded"),
    "m12": Table2Entry("econ_fwd500", 200_000, 1_300_000, 6.17, 4.44, "uniform"),
    "m13": Table2Entry("scircuit", 200_000, 1_000_000, 5.61, 4.39, "powerlaw"),
    "m14": Table2Entry("pdb1HYS", 36_000, 4_300_000, 119.31, 31.86, "block"),
    "m15": Table2Entry("consph", 100_000, 6_000_000, 72.13, 19.08, "banded"),
    "m16": Table2Entry("cant", 100_000, 4_000_000, 64.17, 14.06, "banded"),
    "m17": Table2Entry("cop20k_A", 100_000, 2_600_000, 21.65, 13.79, "uniform"),
    "m18": Table2Entry("dc2", 100_000, 800_000, 6.56, 361.50, "powerlaw"),
    "m19": Table2Entry("rma10", 47_000, 2_400_000, 50.69, 27.78, "block"),
    "m20": Table2Entry("ASIC_680k", 700_000, 3_900_000, 5.67, 659.81, "powerlaw"),
}


def table2_like(mid: str, *, scale_rows: int = 2048, seed=0,
                dtype=np.float32) -> CSR:
    """A matrix with the Table 2 entry's per-row statistics at a CPU-friendly
    row count (dry-run/roofline use full sizes via ShapeDtypeStructs; compute
    tests use this scaled variant)."""
    e = TABLE2_STATS[mid]
    n = scale_rows
    if e.kind == "banded":
        return banded(n, n, max(int(e.nnz_mean) // 2, 1), seed=seed,
                      dtype=dtype)
    if e.kind == "powerlaw":
        return powerlaw(n, n, e.nnz_mean, seed=seed, dtype=dtype)
    if e.kind == "block":
        blk = 16
        bd = min(e.nnz_mean / blk / (n // blk) * (n / blk), 0.25)
        return block_dense(n, n, blk, max(bd, 0.02), seed=seed, dtype=dtype)
    return uniform(n, n, min(e.nnz_mean / n, 0.5), seed=seed, dtype=dtype)


def gcn_graph(num_nodes: int, avg_degree: int, *, seed=0,
              dtype=np.float32) -> CSR:
    """Symmetric normalised adjacency  hat(A) = D^-1/2 (A + I) D^-1/2 for the
    GCN case study (paper §4.5)."""
    rng = _rng(seed)
    nnz = num_nodes * avg_degree
    rows = rng.integers(0, num_nodes, nnz)
    cols = rng.integers(0, num_nodes, nnz)
    rows = np.concatenate([rows, cols, np.arange(num_nodes)])
    cols = np.concatenate([cols, rows[:nnz], np.arange(num_nodes)])
    vals = np.ones(rows.shape[0], dtype)
    deg = np.bincount(rows, weights=vals, minlength=num_nodes)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    vals = (dinv[rows] * dinv[cols]).astype(dtype)
    return csr_from_coo(rows, cols, vals, (num_nodes, num_nodes))
