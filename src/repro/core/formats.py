"""Sparse formats for LOOPS (paper §3.2).

The LOOPS hybrid format row-splits a CSR matrix at ``r_boundary`` into

  * a **CSR-part** (rows ``[0, r_boundary)``) kept in row-wise CSR and executed by
    the *vector* pipeline (paper: NEON AXPY kernel; here: TPU VPU Pallas kernel),
  * a **vector-wise BCSR-part** (rows ``[r_boundary, nrows)``) re-tiled into
    asymmetric ``Br x 1`` column tiles executed by the *matrix* pipeline
    (paper: SME ``fmopa`` outer products into ZA tiles; here: TPU MXU rank-1
    accumulation chains — the systolic array natively sums rank-1 updates).

Construction follows the paper's Algorithm 1.  All format construction is
host-side numpy (the paper likewise excludes conversion from kernel timing and
amortizes it in end-to-end runs, §4.5); the resulting arrays are jit-traceable
constants or device arrays.

TPU-specific invariants (documented deviations from the Arm layout):
  * every CSR row and every BCSR block-row carries at least one (possibly
    zero-valued) entry so that the scatter-style Pallas output ``index_map``
    visits — and therefore initialises — every output block;
  * entries are sorted by (row, col) / (block_row, col): the kernels rely on the
    *monotone* output index to legally revisit accumulator blocks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np

__all__ = [
    "CSR",
    "VectorBCSR",
    "PanelCSR",
    "PanelBCSR",
    "LoopsFormat",
    "TransposedLoops",
    "csr_from_dense",
    "csr_to_dense",
    "csr_slice_rows",
    "bcsr_from_csr_rows",
    "panelize_csr",
    "panelize_bcsr",
    "loops_from_csr",
    "loops_from_csr_mapped",
    "transposed_values",
    "SUBLANE_ROWS",
    "HALF_PACKED_ROWS",
    "DEFAULT_PANEL_G",
]

# Tile heights (paper: cntd / cntf / cnth — elements per vector register).
# TPU vregs are (8, 128): fp32/fp64 tiles use the 8-sublane extent; bf16/fp16
# pack two values per 32-bit lane, doubling the natural tile height exactly as
# the paper's cnth = 2 * cntf.  ``core.spmm.default_br`` selects between them.
SUBLANE_ROWS = 8
HALF_PACKED_ROWS = 2 * SUBLANE_ROWS

# Default panel width G: nonzeros (CSR part) / tiles (BCSR part) processed per
# kernel grid step.  8 matches the paper's Figure-2 multi-tile fmopa batching
# (several outer-product rounds per ZA-tile visit) and shrinks the Pallas grid
# from nnz to ~nnz/G steps.
DEFAULT_PANEL_G = 8


@dataclasses.dataclass(frozen=True)
class CSR:
    """Standard CSR with an auxiliary per-nonzero row-id array.

    ``row_ids`` is redundant with ``row_ptr`` but makes both the pure-jnp
    reference (segment-sum) and the Pallas scatter kernel static-shape friendly.
    """

    row_ptr: np.ndarray  # (nrows + 1,) int32
    col_idx: np.ndarray  # (nnz,) int32
    vals: np.ndarray     # (nnz,) float
    row_ids: np.ndarray  # (nnz,) int32, nondecreasing
    shape: Tuple[int, int]

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.vals.shape[0])

    def astype(self, dtype) -> "CSR":
        return dataclasses.replace(self, vals=self.vals.astype(dtype))


@dataclasses.dataclass(frozen=True)
class VectorBCSR:
    """Vector-wise BCSR: ``Br x 1`` column tiles grouped by block-row.

    A tile ``t`` holds the ``Br`` values of column ``tile_cols[t]`` for the rows
    ``[row_offset + tile_rows[t]*Br, ... + Br)``.  ``tile_rows`` is sorted
    nondecreasing; within a block-row tiles are sorted by column.  This is the
    paper's LOOPS BCSR-part with ``(B_r, B_c) = (vector_size, 1)`` — the
    asymmetric shape that kills the zero-propagation padding of square tiles
    (paper C1) — stored as CSR-of-tiles rather than ELL so that skewed
    block-rows cost no padding.
    """

    tile_rows: np.ndarray  # (ntiles,) int32 block-row index, nondecreasing
    tile_cols: np.ndarray  # (ntiles,) int32 column index
    tile_vals: np.ndarray  # (ntiles, Br) float
    block_ptr: np.ndarray  # (nblocks + 1,) int32 tile extents per block-row
    br: int                # tile height (paper: cntd / cntf / cnth)
    nrows: int             # logical row count covered (<= nblocks * br)
    shape: Tuple[int, int]  # (nrows, ncols)

    @property
    def nblocks(self) -> int:
        return int(self.block_ptr.shape[0] - 1)

    @property
    def ntiles(self) -> int:
        return int(self.tile_cols.shape[0])

    @property
    def ncols(self) -> int:
        return self.shape[1]

    def astype(self, dtype) -> "VectorBCSR":
        return dataclasses.replace(self, tile_vals=self.tile_vals.astype(dtype))


@dataclasses.dataclass(frozen=True)
class PanelCSR:
    """CSR-part nonzeros packed into dense ``(P, G)`` panels.

    Panel ``p`` holds up to ``G`` nonzeros of the single output row
    ``panel_rows[p]``: the kernel gathers the ``G`` rows
    ``B[panel_cols[p]]`` once and broadcast-multiply-reduces them against
    ``panel_vals[p]`` in one grid step — the paper's Figure-2 multi-tile
    batching applied to the vector pipeline.  Rows never share a panel
    (the scatter-output index map writes one row per step), so a row's
    last panel is padded: ``panel_mask`` is 1 for real entries, 0 for
    padding (padding has col 0 and value 0).  ``panel_rows`` is
    nondecreasing and covers every row at least once, preserving the
    output-coverage and monotone-revisit invariants of the G=1 layout.
    """

    panel_rows: np.ndarray  # (P,) int32 output row per panel, nondecreasing
    panel_cols: np.ndarray  # (P, G) int32 gather rows of B (0 where padded)
    panel_vals: np.ndarray  # (P, G) values (0 where padded)
    panel_mask: np.ndarray  # (P, G) validity, same dtype as vals (1 / 0)
    src_panel: np.ndarray   # (nnz,) int32 panel of flat nonzero k
    src_lane: np.ndarray    # (nnz,) int32 lane of flat nonzero k
    g: int
    nrows: int
    shape: Tuple[int, int]

    @property
    def npanels(self) -> int:
        return int(self.panel_rows.shape[0])

    def astype(self, dtype) -> "PanelCSR":
        return dataclasses.replace(self,
                                   panel_vals=self.panel_vals.astype(dtype),
                                   panel_mask=self.panel_mask.astype(dtype))

    def scatter_values(self, vals):
        """Traced flat ``(nnz,)`` values -> the ``(P, G)`` panel layout.

        The scatter indices are static, so this stays a single XLA scatter;
        padding lanes (no source nonzero) remain exactly zero.  Used by the
        autodiff path to execute the Pallas panel kernels with *live* (traced)
        values instead of the host-packed constants.
        """
        import jax.numpy as jnp
        out = jnp.zeros(self.panel_vals.shape, vals.dtype)
        return out.at[self.src_panel, self.src_lane].set(vals)

    def gather_values(self, panel_arr):
        """Inverse of :meth:`scatter_values`: ``(P, G)`` -> flat ``(nnz,)``
        (padding lanes dropped).  Used to read per-nonzero gradients out of
        the SDD kernel's panel-layout output."""
        return panel_arr[self.src_panel, self.src_lane]


@dataclasses.dataclass(frozen=True)
class PanelBCSR:
    """BCSR-part tiles packed into dense ``(P, Br, G)`` value panels.

    Panel ``p`` stacks up to ``G`` of block-row ``panel_rows[p]``'s
    ``Br x 1`` column tiles side by side: ``panel_vals[p]`` is a real
    ``(Br, G)`` operand and the kernel's contraction becomes one
    ``(Br, G) @ (G, bn)`` MXU matmul per grid step instead of a chain of
    G rank-1 updates — the multi-round fmopa batching of paper Figure 2.
    Block-rows never share a panel; the trailing panel of each block-row
    is padded (mask 0, zero columns).  ``panel_rows`` is nondecreasing.
    """

    panel_rows: np.ndarray  # (P,) int32 block-row per panel, nondecreasing
    panel_cols: np.ndarray  # (P, G) int32 gather rows of B (0 where padded)
    panel_vals: np.ndarray  # (P, Br, G) tile values (zero columns = padding)
    panel_mask: np.ndarray  # (P, G) validity, same dtype as vals (1 / 0)
    src_panel: np.ndarray   # (ntiles,) int32 panel of tile t
    src_lane: np.ndarray    # (ntiles,) int32 lane of tile t
    g: int
    br: int
    nblocks: int
    nrows: int              # logical rows covered (<= nblocks * br)
    shape: Tuple[int, int]

    @property
    def npanels(self) -> int:
        return int(self.panel_rows.shape[0])

    def astype(self, dtype) -> "PanelBCSR":
        return dataclasses.replace(self,
                                   panel_vals=self.panel_vals.astype(dtype),
                                   panel_mask=self.panel_mask.astype(dtype))

    def scatter_values(self, tile_vals):
        """Traced ``(ntiles, Br)`` tile values -> the ``(P, Br, G)`` panel
        layout (static scatter indices; padding columns stay zero)."""
        import jax.numpy as jnp
        p, br, g = self.panel_vals.shape
        out = jnp.zeros((p, g, br), tile_vals.dtype)
        out = out.at[self.src_panel, self.src_lane].set(tile_vals)
        return out.transpose(0, 2, 1)

    def gather_values(self, panel_arr):
        """Inverse of :meth:`scatter_values`: ``(P, Br, G)`` panel-layout
        data -> ``(ntiles, Br)`` (padding columns dropped)."""
        return panel_arr[self.src_panel, :, self.src_lane]


@dataclasses.dataclass(frozen=True)
class LoopsFormat:
    """The hybrid LOOPS format (paper §3.2.1, Algorithm 1).

    ``csr_panels``/``bcsr_panels`` are the G-wide panelized views of the two
    parts (``panel_g`` is the width G).  They are built lazily on first
    access and cached: the Pallas kernels execute the panels, while the
    pure-jnp reference executes the flat ``csr_part``/``bcsr_part`` arrays
    and never pays for the packing — both views hold identical values.

    ``macro_m`` is the macro-step fusion factor: ``macro_m`` consecutive
    same-(block-)row G-panels are packed into ONE grid step by panelizing at
    the effective width ``panel_g * macro_m`` (:attr:`panel_g_eff`).  The
    kernels are macro-blind — they just see wider panels — and tails that
    don't fill a macro step are validity-safe for free through the existing
    per-lane padding mask.  Accumulator init/flush and the A-panel load thus
    amortise over ``macro_m * G`` nonzeros and grid steps shrink ``~M×`` on
    dense rows.

    ``pipeline_depth`` selects the kernels' software-pipeline depth (1 =
    serial gather->contract, 2 = double-buffered B-panel prefetch); it does
    not change the panel layout, only how the engine dispatches it.
    """

    csr_part: CSR          # rows [0, r_boundary)
    bcsr_part: VectorBCSR  # rows [r_boundary, nrows)
    r_boundary: int
    shape: Tuple[int, int]
    panel_g: int = 1
    macro_m: int = 1
    pipeline_depth: int = 1

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    @property
    def panel_g_eff(self) -> int:
        """Effective panel width after macro-step fusion: the panels are
        physically packed at ``panel_g * macro_m`` lanes per grid step."""
        return max(self.panel_g, 1) * max(self.macro_m, 1)

    @functools.cached_property
    def csr_panels(self) -> "PanelCSR":
        return panelize_csr(self.csr_part, self.panel_g_eff)

    @functools.cached_property
    def bcsr_panels(self) -> "PanelBCSR":
        return panelize_bcsr(self.bcsr_part, self.panel_g_eff)

    @functools.cached_property
    def nnz(self) -> int:
        # Logical nonzeros (excluding structural zero padding).  Cached:
        # ``loops_spmm`` consults it on every call and the count is an
        # O(nnz) host scan over the value arrays.
        return int(np.count_nonzero(self.csr_part.vals)
                   + np.count_nonzero(self.bcsr_part.tile_vals))

    def astype(self, dtype) -> "LoopsFormat":
        # Panel views are derived state: the replaced instance rebuilds
        # them (lazily) from the cast parts.
        return dataclasses.replace(
            self, csr_part=self.csr_part.astype(dtype),
            bcsr_part=self.bcsr_part.astype(dtype))

    def transposed(self, *, plan=None, tuner=None,
                   total_workers: int = 8) -> "TransposedLoops":
        """Aᵀ as a LOOPS format plus the value-linear maps from A's stored
        values — the backward-pass operand of the custom VJP (``dB = Aᵀ·dY``
        runs through the same panel kernels, just on this format).

        ``plan`` pins the transposed execution plan (a
        :class:`repro.core.spmm.SpmmPlan`); otherwise it is resolved through
        ``tuner`` (the measured plan cache) or the model-only
        ``plan_and_convert`` with ``total_workers``.  The result is cached on
        this instance per ``(plan, tuner, total_workers)``, so repeated
        backward passes — every training step — pay the O(nnz) transpose
        conversion exactly once.
        """
        key = (plan, id(tuner) if tuner is not None else None, total_workers)
        cache = self.__dict__.setdefault("_transposed_cache", {})
        if key not in cache:
            # The entry pins the tuner: id() is only a safe key while the
            # object is alive (a freed address can be recycled by a new
            # tuner, which must not hit this entry).
            cache[key] = (tuner, _build_transposed(
                self, plan=plan, tuner=tuner, total_workers=total_workers))
        return cache[key][1]


# ---------------------------------------------------------------------------
# CSR construction
# ---------------------------------------------------------------------------

def _ensure_nonempty_rows(row_ptr, col_idx, vals):
    """Insert a single explicit zero entry (col 0) into every empty row.

    Guarantees the scatter-output Pallas kernels visit every output row, so no
    block is left uninitialised on hardware where out-of-grid blocks are
    undefined (interpret mode zero-fills; real TPUs do not).
    """
    counts = np.diff(row_ptr)
    if (counts > 0).all() and len(counts) > 0:
        return row_ptr, col_idx, vals
    nrows = len(counts)
    new_counts = np.maximum(counts, 1)
    new_ptr = np.zeros(nrows + 1, np.int32)
    np.cumsum(new_counts, out=new_ptr[1:])
    new_cols = np.zeros(new_ptr[-1], np.int32)
    new_vals = np.zeros(new_ptr[-1], vals.dtype)
    for i in range(nrows):
        s, e = row_ptr[i], row_ptr[i + 1]
        ns = new_ptr[i]
        if e > s:
            new_cols[ns:ns + (e - s)] = col_idx[s:e]
            new_vals[ns:ns + (e - s)] = vals[s:e]
        # else: the zero pad entry at (i, 0) is already in place.
    return new_ptr, new_cols, new_vals


def _csr_from_arrays(row_ptr, col_idx, vals, shape) -> CSR:
    row_ptr = np.asarray(row_ptr, np.int32)
    col_idx = np.asarray(col_idx, np.int32)
    vals = np.asarray(vals)
    row_ptr, col_idx, vals = _ensure_nonempty_rows(row_ptr, col_idx, vals)
    row_ids = np.repeat(
        np.arange(shape[0], dtype=np.int32), np.diff(row_ptr)).astype(np.int32)
    return CSR(row_ptr=row_ptr, col_idx=col_idx, vals=vals, row_ids=row_ids,
               shape=tuple(shape))


def csr_from_dense(dense: np.ndarray) -> CSR:
    dense = np.asarray(dense)
    nrows, _ = dense.shape
    mask = dense != 0
    counts = mask.sum(axis=1)
    row_ptr = np.zeros(nrows + 1, np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    rows, cols = np.nonzero(mask)
    return _csr_from_arrays(row_ptr, cols, dense[rows, cols], dense.shape)


def csr_from_coo(rows, cols, vals, shape, *,
                 validate: str | None = "strict") -> CSR:
    """COO -> CSR, coalescing duplicates: values sharing a ``(row, col)``
    coordinate are *summed* (random generators like ``suite.uniform`` emit
    colliding coordinates; un-coalesced duplicates inflate nnz and every
    statistic derived from it).

    Coordinates are validated first (``repro.resilience.validate``): a
    negative or out-of-range coordinate used to corrupt the linearised
    dedup silently — under ``validate="strict"`` (default) it now raises a
    classified ``SparseInputError``; ``"drop"``/``"clip"`` repair instead
    (drop the entry, or clip it into range), recording ``validate.repaired``
    counters; ``None`` skips the gate (trusted internal callers only).
    """
    if validate is not None:
        from ..resilience.validate import validate_coo
        rows, cols, vals, _ = validate_coo(
            rows, cols, vals, shape,
            repair=None if validate == "strict" else validate)
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    # np.unique on the linearised coordinate both dedups and (row, col)-sorts.
    lin = rows * int(shape[1]) + cols
    uniq, inv = np.unique(lin, return_inverse=True)
    summed = np.zeros(len(uniq), vals.dtype)
    np.add.at(summed, inv, vals)
    rows = uniq // int(shape[1])
    cols = uniq % int(shape[1])
    counts = np.bincount(rows, minlength=shape[0])
    row_ptr = np.zeros(shape[0] + 1, np.int32)
    np.cumsum(counts, out=row_ptr[1:])
    return _csr_from_arrays(row_ptr, cols, summed, shape)


def csr_to_dense(csr: CSR) -> np.ndarray:
    out = np.zeros(csr.shape, csr.vals.dtype)
    # += (not =) so structural-zero pads coexisting with real entries are safe.
    np.add.at(out, (csr.row_ids, csr.col_idx), csr.vals)
    return out


def csr_slice_rows(csr: CSR, start: int, stop: int) -> CSR:
    """Rows [start, stop) as a new CSR (paper Alg. 1 Step 1)."""
    s, e = int(csr.row_ptr[start]), int(csr.row_ptr[stop])
    row_ptr = (csr.row_ptr[start:stop + 1] - csr.row_ptr[start]).astype(np.int32)
    return _csr_from_arrays(row_ptr, csr.col_idx[s:e], csr.vals[s:e],
                            (stop - start, csr.shape[1]))


# ---------------------------------------------------------------------------
# Vector-wise BCSR construction (paper Alg. 1 Step 2, with B_c = 1)
# ---------------------------------------------------------------------------

def bcsr_from_csr_rows(csr: CSR, start: int, stop: int, br: int, *,
                       keep_zeros: bool = False, return_map: bool = False):
    """Re-tile rows [start, stop) of ``csr`` into ``br x 1`` tiles.

    Mirrors Algorithm 1's tile-map construction: each nonzero (i, j) lands in
    tile ``(i // br, j)`` at intra-tile offset ``i % br``.  Tiles are emitted
    sorted by (block_row, col); every block-row gets >= 1 tile.

    ``keep_zeros`` keeps zero-*valued* stored entries as tile coordinates
    instead of dropping them — required when the structure must be a function
    of the sparsity pattern only, never the values (the autodiff transpose:
    a trainable entry that happens to be zero at conversion time must not
    lose its slot).  ``return_map`` additionally returns ``slot_map``, an
    int64 array over the sliced entries where ``slot_map[k]`` is the flat
    destination ``tile_index * br + offset`` of entry ``row_ptr[start] + k``
    (−1 for dropped entries) — the static scatter that carries *traced*
    values into the tile layout.
    """
    nrows = stop - start
    nblocks = max((nrows + br - 1) // br, 1)
    tile_map = {}
    entry_dest = []  # (tr, j, off) per sliced entry, or None when dropped
    for i in range(start, stop):
        local = i - start
        tr = local // br
        off = local % br
        for k in range(int(csr.row_ptr[i]), int(csr.row_ptr[i + 1])):
            j = int(csr.col_idx[k])
            v = csr.vals[k]
            if v == 0 and not keep_zeros:
                entry_dest.append(None)
                continue  # drop structural pads from the parent CSR
            key = (tr, j)
            tile = tile_map.get(key)
            if tile is None:
                tile = np.zeros(br, csr.vals.dtype)
                tile_map[key] = tile
            tile[off] += v
            entry_dest.append((tr, j, off))

    # Ensure every block-row is visited at least once.
    present = {tr for tr, _ in tile_map}
    for tr in range(nblocks):
        if tr not in present:
            tile_map[(tr, 0)] = np.zeros(br, csr.vals.dtype)

    keys = sorted(tile_map.keys())
    ntiles = len(keys)
    tile_rows = np.fromiter((k[0] for k in keys), np.int32, ntiles)
    tile_cols = np.fromiter((k[1] for k in keys), np.int32, ntiles)
    tile_vals = np.stack([tile_map[k] for k in keys]) if ntiles else \
        np.zeros((0, br), csr.vals.dtype)
    counts = np.bincount(tile_rows, minlength=nblocks)
    block_ptr = np.zeros(nblocks + 1, np.int32)
    np.cumsum(counts, out=block_ptr[1:])
    bcsr = VectorBCSR(tile_rows=tile_rows, tile_cols=tile_cols,
                      tile_vals=tile_vals, block_ptr=block_ptr, br=br,
                      nrows=nrows, shape=(nrows, csr.shape[1]))
    if not return_map:
        return bcsr
    tile_of = {k: t for t, k in enumerate(keys)}
    slot_map = np.fromiter(
        (-1 if d is None else tile_of[(d[0], d[1])] * br + d[2]
         for d in entry_dest), np.int64, len(entry_dest))
    return bcsr, slot_map


# ---------------------------------------------------------------------------
# G-wide panelization (paper Figure 2 multi-tile batching)
# ---------------------------------------------------------------------------

def _pack_panels(group_of_item: np.ndarray, group_ptr: np.ndarray,
                 ngroups: int, g: int):
    """Shared panel bookkeeping: split each group's items into ceil(n/g)
    dense panels (>= 1 per group so output coverage is preserved).

    Returns ``(panel_rows, item_panel, item_lane, npanels)`` where item ``t``
    lands in panel ``item_panel[t]`` at lane ``item_lane[t]``.
    """
    counts = np.diff(group_ptr).astype(np.int64)
    per_group = np.maximum(-(-counts // g), 1)          # ceil, min 1
    start = np.zeros(ngroups + 1, np.int64)
    np.cumsum(per_group, out=start[1:])
    npanels = int(start[-1])
    panel_rows = np.repeat(np.arange(ngroups, dtype=np.int32),
                           per_group).astype(np.int32)
    offset = np.arange(len(group_of_item), dtype=np.int64) \
        - group_ptr[group_of_item].astype(np.int64)
    item_panel = start[group_of_item] + offset // g
    item_lane = offset % g
    return panel_rows, item_panel, item_lane, npanels


def panelize_csr(csr: CSR, g: int) -> PanelCSR:
    """Pack the CSR-part nonzeros into ``(P, G)`` panels, G per row-visit.

    O(nnz) and fully vectorised; a row with ``c`` nonzeros yields
    ``max(ceil(c / g), 1)`` panels (empty rows get one fully-masked panel so
    the kernel still zero-initialises their output block).
    """
    if g < 1:
        raise ValueError(f"panel width g must be >= 1, got {g}")
    panel_rows, pnl, lane, npanels = _pack_panels(
        csr.row_ids, csr.row_ptr, csr.nrows, g)
    cols = np.zeros((npanels, g), np.int32)
    vals = np.zeros((npanels, g), csr.vals.dtype)
    mask = np.zeros((npanels, g), csr.vals.dtype)
    cols[pnl, lane] = csr.col_idx
    vals[pnl, lane] = csr.vals
    mask[pnl, lane] = 1
    return PanelCSR(panel_rows=panel_rows, panel_cols=cols, panel_vals=vals,
                    panel_mask=mask, src_panel=pnl.astype(np.int32),
                    src_lane=lane.astype(np.int32), g=g, nrows=csr.nrows,
                    shape=csr.shape)


def panelize_bcsr(bcsr: VectorBCSR, g: int) -> PanelBCSR:
    """Pack the BCSR-part ``Br x 1`` tiles into ``(P, Br, G)`` panels.

    Each panel stacks up to G same-block-row tiles into one ``(Br, G)``
    matmul operand; block-rows with ``t`` tiles yield ``max(ceil(t/g), 1)``
    panels.
    """
    if g < 1:
        raise ValueError(f"panel width g must be >= 1, got {g}")
    panel_rows, pnl, lane, npanels = _pack_panels(
        bcsr.tile_rows, bcsr.block_ptr, bcsr.nblocks, g)
    cols = np.zeros((npanels, g), np.int32)
    mask = np.zeros((npanels, g), bcsr.tile_vals.dtype)
    cols[pnl, lane] = bcsr.tile_cols
    mask[pnl, lane] = 1
    # (P, G, Br) scatter then transpose to the (P, Br, G) operand layout.
    vals = np.zeros((npanels, g, bcsr.br), bcsr.tile_vals.dtype)
    vals[pnl, lane] = bcsr.tile_vals
    return PanelBCSR(panel_rows=panel_rows, panel_cols=cols,
                     panel_vals=np.ascontiguousarray(vals.transpose(0, 2, 1)),
                     panel_mask=mask, src_panel=pnl.astype(np.int32),
                     src_lane=lane.astype(np.int32), g=g, br=bcsr.br,
                     nblocks=bcsr.nblocks, nrows=bcsr.nrows, shape=bcsr.shape)


# ---------------------------------------------------------------------------
# Hybrid LOOPS format (Algorithm 1)
# ---------------------------------------------------------------------------

def loops_from_csr(csr: CSR, r_boundary: int, br: int,
                   panel_g: int = DEFAULT_PANEL_G, *,
                   macro_m: int = 1,
                   pipeline_depth: int = 1) -> LoopsFormat:
    """Algorithm 1: CSR-part = rows [0, r_boundary), BCSR-part = the rest.

    ``panel_g`` is the panel width the Pallas kernels consume (G nonzeros /
    tiles per grid step); the panelized views are derived lazily from the
    flat arrays on first kernel use.  ``macro_m`` fuses that many
    consecutive same-row panels into one grid step (the panels pack at
    ``panel_g * macro_m`` lanes); ``pipeline_depth`` selects the kernels'
    software-pipeline depth (1 or 2).  Both default to the knob-less
    layout.
    """
    if not 0 <= r_boundary <= csr.nrows:
        raise ValueError(f"r_boundary {r_boundary} out of range [0, {csr.nrows}]")
    if macro_m < 1:
        raise ValueError(f"macro_m must be >= 1, got {macro_m}")
    return LoopsFormat(csr_part=csr_slice_rows(csr, 0, r_boundary),
                       bcsr_part=bcsr_from_csr_rows(csr, r_boundary,
                                                    csr.nrows, br),
                       r_boundary=r_boundary, shape=csr.shape,
                       panel_g=panel_g, macro_m=macro_m,
                       pipeline_depth=pipeline_depth)


def permute_rows(csr: CSR, order: np.ndarray) -> CSR:
    """New CSR whose row i is ``csr`` row ``order[i]`` (O(nnz))."""
    counts = np.diff(csr.row_ptr)[order]
    new_ptr = np.zeros(csr.nrows + 1, np.int32)
    np.cumsum(counts, out=new_ptr[1:])
    idx = np.concatenate([
        np.arange(csr.row_ptr[r], csr.row_ptr[r + 1]) for r in order
    ]) if csr.nnz else np.zeros(0, np.int64)
    return _csr_from_arrays(new_ptr, csr.col_idx[idx], csr.vals[idx],
                            csr.shape)


def loops_from_csr_sorted(csr: CSR, r_boundary: int, br: int,
                          panel_g: int = DEFAULT_PANEL_G, *,
                          macro_m: int = 1, pipeline_depth: int = 1
                          ) -> Tuple[LoopsFormat, np.ndarray]:
    """Beyond-paper variant (§Perf): sort rows by nnz descending before the
    positional split, so scattered hub rows all land in the CSR(vector) part
    and the BCSR region has no monster block-rows (which are indivisible
    under contiguous device chunking and explode the padding).

    Returns (format, order) with ``C_permuted[i] == C[order[i]]``; consumers
    either apply the inverse permutation to the output or keep operating in
    permuted row space (GNN layers don't care about row order)."""
    order = np.argsort(-np.diff(csr.row_ptr), kind="stable").astype(np.int64)
    return loops_from_csr(permute_rows(csr, order), r_boundary, br,
                          panel_g=panel_g, macro_m=macro_m,
                          pipeline_depth=pipeline_depth), order


# ---------------------------------------------------------------------------
# Transposed format (autodiff: dB = Aᵀ · dY through the same kernels)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransposedLoops:
    """Aᵀ in LOOPS form plus the *value-linear* maps from A's stored values.

    The structure is a function of A's sparsity pattern only; the maps are
    static index arrays, so the transposed value arrays can be rebuilt from
    **traced** values (learned-sparse-weight layers) with two XLA scatters —
    see :func:`transposed_values`.  A's "flat value vector" is
    ``concat(csr_part.vals, bcsr_part.tile_vals.ravel())``; BCSR tile slots
    on padding rows (``row >= nrows``) are excluded (the forward pass trims
    those rows, so they carry no gradient and contribute nothing to Aᵀ).
    """

    fmt: LoopsFormat        # Aᵀ, converted under the resolved plan
    plan: object            # the SpmmPlan the conversion used
    entry_src: np.ndarray   # (E,) int64 — index into A's flat value vector
    entry_slot: np.ndarray  # (E,) int64 — destination slot in Aᵀ's CSR
    n_slots: int            # stored entries of Aᵀ (incl. empty-row pads)
    csr_len: int            # slots [0, csr_len) are fmt.csr_part.vals
    bcsr_slot: np.ndarray   # (n_slots - csr_len,) int64 flat tile*Br+off


def loops_from_csr_mapped(csr: CSR, r_boundary: int, br: int,
                          panel_g: int = DEFAULT_PANEL_G, *,
                          macro_m: int = 1, pipeline_depth: int = 1
                          ) -> Tuple[LoopsFormat, int, np.ndarray]:
    """Algorithm 1 with value-slot bookkeeping (autodiff transpose variant).

    Like :func:`loops_from_csr` but the BCSR part keeps zero-valued stored
    entries (structure must not depend on values) and the return carries the
    maps from ``csr``'s flat value order into the two parts:
    ``(fmt, csr_len, bcsr_slot)`` where entries ``[0, csr_len)`` become
    ``fmt.csr_part.vals`` verbatim and entry ``csr_len + j`` lands at flat
    tile slot ``bcsr_slot[j]``.  Requires ``csr`` to have no empty rows
    (the transposed-CSR builder guarantees this via explicit pad slots).
    """
    if not 0 <= r_boundary <= csr.nrows:
        raise ValueError(f"r_boundary {r_boundary} out of range "
                         f"[0, {csr.nrows}]")
    csr_part = csr_slice_rows(csr, 0, r_boundary)
    csr_len = int(csr.row_ptr[r_boundary])
    if csr_part.nnz != csr_len:
        raise ValueError("loops_from_csr_mapped needs a CSR with no empty "
                         "rows (slicing inserted pad entries)")
    bcsr_part, bcsr_slot = bcsr_from_csr_rows(
        csr, r_boundary, csr.nrows, br, keep_zeros=True, return_map=True)
    fmt = LoopsFormat(csr_part=csr_part, bcsr_part=bcsr_part,
                      r_boundary=r_boundary, shape=csr.shape,
                      panel_g=panel_g, macro_m=macro_m,
                      pipeline_depth=pipeline_depth)
    return fmt, csr_len, bcsr_slot


def _transposed_csr(fmt: LoopsFormat) -> Tuple[CSR, np.ndarray, np.ndarray]:
    """Aᵀ as a (row, col)-sorted CSR with *every* row populated, plus the
    entry maps ``(csr_t, entry_src, entry_slot)``: A's flat stored entry
    ``entry_src[e]`` contributes (additively — duplicate coordinates
    coalesce) to ``csr_t.vals[entry_slot[e]]``.  Empty rows of Aᵀ get an
    explicit zero pad at column 0 with no source entry.
    """
    csr, bc = fmt.csr_part, fmt.bcsr_part
    m, k = fmt.shape
    t, br = bc.tile_vals.shape
    # Global (row, col) coordinate of every flat stored value of A.
    rows = np.concatenate([
        csr.row_ids.astype(np.int64),
        fmt.r_boundary + np.repeat(bc.tile_rows.astype(np.int64), br) * br
        + np.tile(np.arange(br, dtype=np.int64), t)])
    cols = np.concatenate([csr.col_idx.astype(np.int64),
                           np.repeat(bc.tile_cols.astype(np.int64), br)])
    keep = rows < m          # BCSR padding rows never reach the output
    entry_src = np.nonzero(keep)[0].astype(np.int64)
    # Transposed coordinate, linearised in Aᵀ's (row, col) = (col, row) order.
    lin = cols[keep] * m + rows[keep]
    uniq, inv = np.unique(lin, return_inverse=True)
    missing = np.setdiff1d(np.arange(k, dtype=np.int64),
                           np.unique(uniq // m))
    all_lin = np.sort(np.concatenate([uniq, missing * m]))
    entry_slot = np.searchsorted(all_lin, uniq)[inv].astype(np.int64)
    rows_t = (all_lin // m).astype(np.int32)
    cols_t = (all_lin % m).astype(np.int32)
    flat_vals = np.concatenate([np.asarray(csr.vals).ravel(),
                                np.asarray(bc.tile_vals).ravel()])
    vals_t = np.zeros(len(all_lin), flat_vals.dtype)
    np.add.at(vals_t, entry_slot, flat_vals[entry_src])
    row_ptr = np.zeros(k + 1, np.int32)
    np.cumsum(np.bincount(rows_t, minlength=k), out=row_ptr[1:])
    csr_t = CSR(row_ptr=row_ptr, col_idx=cols_t, vals=vals_t,
                row_ids=rows_t, shape=(k, m))
    return csr_t, entry_src, entry_slot


def _build_transposed(fmt: LoopsFormat, *, plan=None, tuner=None,
                      total_workers: int = 8) -> TransposedLoops:
    """Materialise :class:`TransposedLoops` (cached by
    ``LoopsFormat.transposed``).  Plan resolution goes through the same
    front door as the forward format — ``plan_and_convert`` / the tuner —
    so the backward SpMM is scheduled for Aᵀ's own row statistics, not A's.
    """
    from .spmm import plan_and_convert  # lazy: formats <- spmm at import time
    csr_t, entry_src, entry_slot = _transposed_csr(fmt)
    if plan is None:
        _, plan = plan_and_convert(csr_t, total_workers=total_workers,
                                   panel_g=fmt.panel_g or None, tuner=tuner,
                                   macro_m=fmt.macro_m,
                                   pipeline_depth=fmt.pipeline_depth)
    fmt_t, csr_len, bcsr_slot = loops_from_csr_mapped(
        csr_t, plan.r_boundary, plan.br, panel_g=plan.panel_g,
        macro_m=int(getattr(plan, "macro_m", 1)),
        pipeline_depth=int(getattr(plan, "pipeline_depth", 1)))
    tl = TransposedLoops(fmt=fmt_t, plan=plan, entry_src=entry_src,
                         entry_slot=entry_slot, n_slots=csr_t.nnz,
                         csr_len=csr_len, bcsr_slot=bcsr_slot)
    # Static round-trip check: injecting A's own values must reproduce the
    # converted parts exactly (catches any map/structure drift at build
    # time, where it is cheap, instead of as a silent wrong gradient).
    # Pure numpy — this runs under jit *tracing* of the backward pass, where
    # any jnp op would be staged into the jaxpr instead of executed.
    flat = np.concatenate([np.asarray(fmt.csr_part.vals).ravel(),
                           np.asarray(fmt.bcsr_part.tile_vals).ravel()])
    vals_t = np.zeros(tl.n_slots, flat.dtype)
    np.add.at(vals_t, tl.entry_slot, flat[tl.entry_src])
    nt, brr = fmt_t.bcsr_part.tile_vals.shape
    tile_flat = np.zeros(nt * brr, flat.dtype)
    np.add.at(tile_flat, tl.bcsr_slot, vals_t[tl.csr_len:])
    if not (np.allclose(vals_t[:tl.csr_len].astype(np.float64),
                        np.asarray(fmt_t.csr_part.vals, np.float64))
            and np.allclose(tile_flat.reshape(nt, brr).astype(np.float64),
                            np.asarray(fmt_t.bcsr_part.tile_vals,
                                       np.float64))):
        raise AssertionError("transposed value maps disagree with the "
                             "converted transposed format")
    return tl


def transposed_values(tl: TransposedLoops, csr_vals, bcsr_vals):
    """Carry (possibly traced) values of A into the transposed layout.

    Returns ``(csr_vals_t, bcsr_tile_vals_t)`` matching
    ``tl.fmt.csr_part`` / ``tl.fmt.bcsr_part`` — two static-index scatters,
    linear in the inputs, so gradients flow through them natively.
    """
    import jax.numpy as jnp
    flat = jnp.concatenate([jnp.reshape(csr_vals, (-1,)),
                            jnp.reshape(bcsr_vals, (-1,))])
    vals_t = jnp.zeros((tl.n_slots,), flat.dtype)
    vals_t = vals_t.at[tl.entry_slot].add(flat[tl.entry_src])
    nt, br = tl.fmt.bcsr_part.tile_vals.shape
    tile_flat = jnp.zeros((nt * br,), flat.dtype)
    tile_flat = tile_flat.at[tl.bcsr_slot].add(vals_t[tl.csr_len:])
    return vals_t[:tl.csr_len], tile_flat.reshape(nt, br)
