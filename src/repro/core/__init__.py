"""LOOPS core — the paper's primary contribution in JAX.

Hybrid CSR(vector-pipeline) + vector-wise-BCSR(matrix-pipeline) SpMM with an
adaptive quadratic-performance-model scheduler, plus the distributed
(shard_map device-group) two-level execution.
"""
from .formats import (CSR, DEFAULT_PANEL_G, LoopsFormat, PanelBCSR, PanelCSR,
                      TransposedLoops, VectorBCSR, bcsr_from_csr_rows,
                      csr_from_coo, csr_from_dense, csr_to_dense,
                      loops_from_csr, loops_from_csr_mapped, panelize_bcsr,
                      panelize_csr, transposed_values)
from .partition import choose_r_boundary, regularity_boundary, row_stats
from .perf_model import (QuadraticPerfModel, best_allocation, calibrate,
                         fit_perf_model)
from .spmm import (SpmmPlan, loops_batched_grid_steps, loops_grid_steps,
                   loops_spmm, loops_spmm_values, plan_and_convert,
                   spmm_csr_baseline, spmm_dense_baseline)
from .distributed import (ShardedLoops, distributed_spmm, shard_loops,
                          shard_loops_auto)

__all__ = [
    "CSR", "DEFAULT_PANEL_G", "LoopsFormat", "PanelBCSR", "PanelCSR",
    "TransposedLoops", "VectorBCSR", "bcsr_from_csr_rows", "csr_from_coo",
    "csr_from_dense", "csr_to_dense", "loops_from_csr",
    "loops_from_csr_mapped", "panelize_bcsr",
    "panelize_csr", "transposed_values", "choose_r_boundary",
    "regularity_boundary", "row_stats", "QuadraticPerfModel",
    "best_allocation", "calibrate", "fit_perf_model", "SpmmPlan",
    "loops_batched_grid_steps", "loops_grid_steps", "loops_spmm",
    "loops_spmm_values",
    "plan_and_convert", "spmm_csr_baseline",
    "spmm_dense_baseline", "ShardedLoops", "distributed_spmm", "shard_loops",
    "shard_loops_auto",
]
