"""Public LOOPS SpMM API (paper §3.1 pipeline: partition -> schedule -> execute).

``loops_spmm`` executes a pre-converted ``LoopsFormat`` (CSR-part on the
vector pipeline, BCSR-part on the matrix pipeline, concatenated row-wise —
output rows are exclusive so no atomics are needed, paper §3.4).

``plan_and_convert`` is the front half of the pipeline: calibrate/query the
quadratic performance model, solve Eq. 1 for ``r_boundary``, and run
Algorithm 1.

Both execution entry points (``loops_spmm`` for static matrices,
``loops_spmm_values`` for trainable stored values) are differentiable on
the Pallas backends via ``jax.custom_vjp`` — ``dB = Aᵀ·dY`` through the
same kernels on the cached transposed format, ``dA``-at-nonzeros through
the SDD kernels; see ``docs/training.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops, ref
from . import partition
from .formats import (CSR, DEFAULT_PANEL_G, HALF_PACKED_ROWS, LoopsFormat,
                      SUBLANE_ROWS, loops_from_csr)
from .perf_model import QuadraticPerfModel

__all__ = ["loops_spmm", "loops_spmm_values", "loops_grid_steps",
           "plan_and_convert", "SpmmPlan", "spmm_csr_baseline",
           "spmm_dense_baseline"]


@dataclasses.dataclass(frozen=True)
class SpmmPlan:
    """Resolved execution plan for one sparse matrix (paper Fig. 1)."""

    r_boundary: int
    t_vpu: int      # paper: t_neon — workers for the CSR part
    t_mxu: int      # paper: t_sme  — workers for the BCSR part
    br: int         # tile height (cntd / cntf / cnth analogue)
    panel_g: int = DEFAULT_PANEL_G  # panel width (Fig. 2 multi-tile count)


def default_br(dtype) -> int:
    """Paper: B_r = elements per vector register (cntd=2 f64 ... cnth=8 f16 on
    128-bit NEON).  TPU registers are (8, 128) vregs and the MXU contraction
    wants sublane multiples, so fp32 and fp64 both use the 8-sublane extent
    (``formats.SUBLANE_ROWS``); half precision packs 2x per 32-bit lane
    (``formats.HALF_PACKED_ROWS``), mirroring cnth = 2*cntf."""
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.bfloat16, jnp.float16):
        return HALF_PACKED_ROWS
    return SUBLANE_ROWS


def plan_and_convert(csr: CSR, *, total_workers: int = 8,
                     model: QuadraticPerfModel | None = None,
                     tp_vpu: float = 1.0, tp_mxu: float = 4.0,
                     br: int | None = None, panel_g: int | None = None,
                     paper_literal: bool = False,
                     tuner=None) -> tuple[LoopsFormat, SpmmPlan]:
    """Pick (t_vpu, t_mxu) via the perf model, solve Eq. 1, run Algorithm 1.

    ``tp_vpu``/``tp_mxu`` are per-worker row throughputs; defaults reflect the
    v5e VPU:MXU FLOP ratio for regular rows.  When ``model`` is given, the
    allocation is the model argmax (Eq. 3); otherwise it is proportional to
    the throughputs.

    ``tuner`` — a :class:`repro.tune.Tuner` (or anything with
    ``.tune(csr) -> (fmt, plan)``) — replaces the model-only path entirely:
    the plan comes from the measured, fingerprint-keyed cache, so repeated
    call sites (FFN layers, GCN epochs, serving) never re-derive it.
    """
    if tuner is not None:
        return tuner.tune(csr)
    br = br or default_br(csr.vals.dtype)
    panel_g = panel_g or DEFAULT_PANEL_G
    if model is not None:
        t_vpu, t_mxu = model.best_allocation(total_workers)
    else:
        t_mxu = max(int(round(total_workers * tp_mxu / (tp_vpu + tp_mxu))), 1)
        t_vpu = max(total_workers - t_mxu, 1)
    r_b = partition.choose_r_boundary(
        csr.nrows, tp_vpu, tp_mxu, t_vpu, t_mxu, br=br,
        paper_literal=paper_literal)
    return loops_from_csr(csr, r_b, br, panel_g=panel_g), SpmmPlan(
        r_boundary=r_b, t_vpu=t_vpu, t_mxu=t_mxu, br=br, panel_g=panel_g)


def _loops_execute(fmt: LoopsFormat, b: jax.Array, backend: str, bn,
                   out_dtype, csr_vals=None, bcsr_vals=None) -> jax.Array:
    """Backend dispatch for one hybrid SpMM (no differentiation rule).

    ``csr_vals``/``bcsr_vals`` optionally substitute traced live values for
    the format's host-packed constants (learned-sparse-weight layers and the
    transposed backward pass both need this); the structure stays static.
    """
    has_csr = fmt.r_boundary > 0
    has_bcsr = fmt.r_boundary < fmt.nrows
    pallas = backend != "jnp"   # panel views only materialise for Pallas
    if (has_csr and has_bcsr and pallas
            and fmt.r_boundary % fmt.bcsr_part.br == 0):
        return ops.loops_spmm_fused(fmt, b, backend=backend, bn=bn,
                                    out_dtype=out_dtype, csr_vals=csr_vals,
                                    bcsr_vals=bcsr_vals)
    parts = []
    if has_csr:
        parts.append(ops.csr_spmm(fmt.csr_part, b, backend=backend, bn=bn,
                                  out_dtype=out_dtype,
                                  panels=fmt.csr_panels if pallas else None,
                                  vals=csr_vals))
    if has_bcsr:
        parts.append(ops.bcsr_spmm(fmt.bcsr_part, b, backend=backend, bn=bn,
                                   out_dtype=out_dtype,
                                   panels=fmt.bcsr_panels if pallas
                                   else None,
                                   vals=bcsr_vals))
    if not parts:
        return jnp.zeros((fmt.nrows, b.shape[1]), out_dtype)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)


def _backward_db(fmt: LoopsFormat, dy: jax.Array, backend: str, bn,
                 transpose_plan, csr_vals=None, bcsr_vals=None) -> jax.Array:
    """``dB = Aᵀ · dY`` through the same panel kernels on the (cached)
    transposed format.  The cotangent is cast to the format's value dtype
    first — the backward matmuls honour the forward kernels' precision
    contract (bf16 operands, fp32 accumulation) instead of silently running
    a wider product."""
    from .formats import transposed_values
    tl = fmt.transposed(plan=transpose_plan)
    dy = dy.astype(tl.fmt.csr_part.vals.dtype)
    cv = bv = None
    if csr_vals is not None:
        cv, bv = transposed_values(tl, csr_vals, bcsr_vals)
    return _loops_execute(tl.fmt, dy, backend, bn, None,
                          csr_vals=cv, bcsr_vals=bv)


def loops_spmm(fmt: LoopsFormat, b: jax.Array, *, backend: str | None = None,
               bn: int | None = None, out_dtype=None,
               transpose_plan: "SpmmPlan | None" = None) -> jax.Array:
    """Execute the hybrid SpMM: C = A @ B with A in LOOPS format.

    The CSR-part rows land in C[:r_boundary], the BCSR-part rows in
    C[r_boundary:]; each output row is written by exactly one kernel
    (paper §3.4 — conflict-free by construction).

    On the Pallas backends a hybrid format executes single-pass
    (:func:`repro.kernels.ops.loops_spmm_fused`): both kernels fill disjoint
    row ranges of ONE buffer through ``input_output_aliases`` + offset
    index_maps, so no ``concatenate`` copy appears in the jaxpr.  The
    two-output + concatenate fallback remains for the jnp reference and for
    boundaries not aligned to the tile height.

    Differentiable end-to-end: on the Pallas backends a ``jax.custom_vjp``
    computes ``dB = Aᵀ · dY`` through the *same* panel kernels on a lazily
    materialised, cached transposed format (``fmt.transposed()``);
    ``transpose_plan`` pins that format's execution plan (otherwise it is
    resolved by ``plan_and_convert`` on Aᵀ's own row statistics).  The jnp
    reference differentiates natively and stays the gradient oracle.  A's
    values are compile-time constants here — for trainable values use
    :func:`loops_spmm_values`.  (Reverse mode only; the VJP itself is not
    further differentiable.)
    """
    backend = backend or ops.default_backend()
    out_dtype = out_dtype or ref.acc_dtype_for(
        jnp.dtype(fmt.csr_part.vals.dtype))
    if fmt.nnz == 0:
        # All-zero matrix: every stored entry is structural padding, so the
        # product is identically zero — including the nrows > 0 case, which
        # must yield a full (nrows, N) block, not a (0, N) stub.
        return jnp.zeros((fmt.nrows, b.shape[1]), out_dtype)
    if backend == "jnp":
        return _loops_execute(fmt, b, backend, bn, out_dtype)

    @jax.custom_vjp
    def run(b_):
        return _loops_execute(fmt, b_, backend, bn, out_dtype)

    def run_fwd(b_):
        return run(b_), None   # A is static: dB needs only the cotangent

    def run_bwd(_, dy):
        db = _backward_db(fmt, dy, backend, bn, transpose_plan)
        return (db.astype(b.dtype),)

    run.defvjp(run_fwd, run_bwd)
    return run(b)


def loops_spmm_values(fmt: LoopsFormat, csr_vals: jax.Array,
                      bcsr_vals: jax.Array, b: jax.Array, *,
                      backend: str | None = None, bn: int | None = None,
                      out_dtype=None,
                      transpose_plan: "SpmmPlan | None" = None) -> jax.Array:
    """Hybrid SpMM with *trainable* stored values: C = A(vals) @ B.

    ``csr_vals`` (nnz,) and ``bcsr_vals`` (ntiles, Br) are live (traced)
    pytree leaves laid out exactly like ``fmt.csr_part.vals`` /
    ``fmt.bcsr_part.tile_vals``; the structure in ``fmt`` stays static.
    This is the learned-sparse-weight entry point
    (:mod:`repro.models.sparse_ffn`).

    On the Pallas backends a ``jax.custom_vjp`` supplies all three
    cotangents:

      * ``dB = Aᵀ · dY`` — the same panel kernels on the cached transposed
        format, with the live values carried across by the static
        value-linear maps (:func:`repro.core.formats.transposed_values`);
      * ``dA`` at stored coordinates — the sampled dense-dense kernels
        (:func:`repro.kernels.ops.loops_sdd`), never materialising
        ``dY @ Bᵀ``.

    The jnp reference differentiates natively (gradient oracle).
    """
    backend = backend or ops.default_backend()
    out_dtype = out_dtype or ref.acc_dtype_for(jnp.dtype(csr_vals.dtype))
    if backend == "jnp":
        return _loops_execute(fmt, b, backend, bn, out_dtype,
                              csr_vals=csr_vals, bcsr_vals=bcsr_vals)

    @jax.custom_vjp
    def run(cv, bv, b_):
        return _loops_execute(fmt, b_, backend, bn, out_dtype,
                              csr_vals=cv, bcsr_vals=bv)

    def run_fwd(cv, bv, b_):
        return run(cv, bv, b_), (cv, bv, b_)

    def run_bwd(res, dy):
        cv, bv, b_ = res
        db = _backward_db(fmt, dy, backend, bn, transpose_plan,
                          csr_vals=cv, bcsr_vals=bv)
        d_cv, d_bv = ops.loops_sdd(fmt, dy, b_, backend=backend, bn=bn)
        return (d_cv.astype(cv.dtype), d_bv.astype(bv.dtype),
                db.astype(b_.dtype))

    run.defvjp(run_fwd, run_bwd)
    return run(csr_vals, bcsr_vals, b)


def loops_grid_steps(fmt: LoopsFormat, n_cols: int,
                     bn: int | None = None) -> int:
    """Total Pallas grid steps to execute ``fmt`` against an (K, n_cols)
    operand — the hardware-independent cost proxy the benchmarks track.

    With G-wide panels the inner grid walks panels, not nonzeros, so the
    count drops from ``(nnz_csr + ntiles) * col_blocks`` at G=1 towards a
    ``~G``-fold reduction (padding at row/block-row boundaries is the gap
    from the ideal).
    """
    bn = bn or min(n_cols, 512)
    col_blocks = -(-n_cols // bn)
    p_csr = fmt.csr_panels.npanels
    p_bcsr = fmt.bcsr_panels.npanels
    # A part that loops_spmm skips contributes nothing — the empty BCSR part
    # is not inherently zero-count (``bcsr_from_csr_rows`` keeps >= 1
    # structural pad tile even for zero rows).
    if fmt.r_boundary == 0:
        p_csr = 0
    if fmt.r_boundary == fmt.nrows:
        p_bcsr = 0
    return (p_csr + p_bcsr) * col_blocks


# ---------------------------------------------------------------------------
# Baselines the paper compares against (implemented, per assignment scope)
# ---------------------------------------------------------------------------

def spmm_csr_baseline(csr: CSR, b: jax.Array, out_dtype=None) -> jax.Array:
    """TACO-style row-wise CSR schedule (pure XLA segment-sum lowering)."""
    return ref.csr_spmm_ref(jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx),
                            jnp.asarray(csr.vals), b, csr.nrows,
                            out_dtype=out_dtype)


def spmm_dense_baseline(a_dense: np.ndarray, b: jax.Array,
                        out_dtype=None) -> jax.Array:
    """Armadillo-style dense GEMM on the densified operand."""
    return ref.dense_spmm(jnp.asarray(a_dense), b, out_dtype=out_dtype)
