"""Public LOOPS SpMM API (paper §3.1 pipeline: partition -> schedule -> execute).

``loops_spmm`` executes a pre-converted ``LoopsFormat`` (CSR-part on the
vector pipeline, BCSR-part on the matrix pipeline, concatenated row-wise —
output rows are exclusive so no atomics are needed, paper §3.4).

``plan_and_convert`` is the front half of the pipeline: calibrate/query the
quadratic performance model, solve Eq. 1 for ``r_boundary``, and run
Algorithm 1.

Both execution entry points (``loops_spmm`` for static matrices,
``loops_spmm_values`` for trainable stored values) are differentiable on
the Pallas backends via ``jax.custom_vjp`` — ``dB = Aᵀ·dY`` through the
same kernels on the cached transposed format, ``dA``-at-nonzeros through
the SDD kernels; see ``docs/training.md``.

Batched multi-RHS execution
---------------------------
The dense operand may carry any leading batch dims — ``B`` of shape
``(..., K, N)`` returns ``(..., M, N)`` — and executes as ONE batched
engine call (``kernels/engine.py``): the Pallas grids gain a leading
batch-block axis that reuses A's static panel layout across all slices.
``jax.vmap`` over the operand lowers to the same native batched call via a
``jax.custom_batching.custom_vmap`` rule instead of unrolling one
``pallas_call`` per element; the custom VJP carries the batch through
``dB = Aᵀ·dY`` (batched) and the SDD ``dA`` (summed over the batch — the
stored values are shared).  An empty batch returns correctly-shaped zeros
on every backend.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import engine, ref
from ..kernels.panel_common import default_bn
from ..resilience import fallback as _resilience
from . import partition
from .formats import (CSR, DEFAULT_PANEL_G, HALF_PACKED_ROWS, LoopsFormat,
                      SUBLANE_ROWS, loops_from_csr)
from .perf_model import QuadraticPerfModel

__all__ = ["loops_spmm", "loops_spmm_values", "loops_grid_steps",
           "loops_batched_grid_steps", "plan_and_convert", "SpmmPlan",
           "spmm_csr_baseline", "spmm_dense_baseline"]


@dataclasses.dataclass(frozen=True)
class SpmmPlan:
    """Resolved execution plan for one sparse matrix (paper Fig. 1)."""

    r_boundary: int
    t_vpu: int      # paper: t_neon — workers for the CSR part
    t_mxu: int      # paper: t_sme  — workers for the BCSR part
    br: int         # tile height (cntd / cntf / cnth analogue)
    panel_g: int = DEFAULT_PANEL_G  # panel width (Fig. 2 multi-tile count)
    pipeline_depth: int = 1  # kernel software-pipeline depth (1 = serial)
    macro_m: int = 1         # same-row panels fused per grid step


def default_br(dtype) -> int:
    """Paper: B_r = elements per vector register (cntd=2 f64 ... cnth=8 f16 on
    128-bit NEON).  TPU registers are (8, 128) vregs and the MXU contraction
    wants sublane multiples, so fp32 and fp64 both use the 8-sublane extent
    (``formats.SUBLANE_ROWS``); half precision packs 2x per 32-bit lane
    (``formats.HALF_PACKED_ROWS``), mirroring cnth = 2*cntf."""
    dtype = jnp.dtype(dtype)
    if dtype in (jnp.bfloat16, jnp.float16):
        return HALF_PACKED_ROWS
    return SUBLANE_ROWS


def plan_and_convert(csr: CSR, *, total_workers: int = 8,
                     model: QuadraticPerfModel | None = None,
                     tp_vpu: float = 1.0, tp_mxu: float = 4.0,
                     br: int | None = None, panel_g: int | None = None,
                     paper_literal: bool = False,
                     tuner=None, validate: str | None = "strict",
                     pipeline_depth: int = 1, macro_m: int = 1
                     ) -> tuple[LoopsFormat, SpmmPlan]:
    """Pick (t_vpu, t_mxu) via the perf model, solve Eq. 1, run Algorithm 1.

    ``tp_vpu``/``tp_mxu`` are per-worker row throughputs; defaults reflect the
    v5e VPU:MXU FLOP ratio for regular rows.  When ``model`` is given, the
    allocation is the model argmax (Eq. 3); otherwise it is proportional to
    the throughputs.

    ``tuner`` — a :class:`repro.tune.Tuner` (or anything with
    ``.tune(csr) -> (fmt, plan)``) — replaces the model-only path entirely:
    the plan comes from the measured, fingerprint-keyed cache, so repeated
    call sites (FFN layers, GCN epochs, serving) never re-derive it.

    ``validate`` gates ingestion validation of ``csr``
    (:mod:`repro.resilience.validate`): ``"strict"`` (default) raises a
    classified :class:`repro.resilience.SparseInputError` on a malformed
    input before Algorithm 1 can index with it; ``"drop"``/``"clip"`` repair
    instead (recording ``validate.repaired`` counters); ``None`` trusts the
    caller (hot inner loops that already validated).
    """
    if validate is not None:
        from ..resilience.validate import validate_csr
        csr, _ = validate_csr(
            csr, repair=None if validate == "strict" else validate)
    if tuner is not None:
        return tuner.tune(csr)
    br = br or default_br(csr.vals.dtype)
    panel_g = panel_g or DEFAULT_PANEL_G
    if model is not None:
        t_vpu, t_mxu = model.best_allocation(total_workers)
    else:
        t_mxu = max(int(round(total_workers * tp_mxu / (tp_vpu + tp_mxu))), 1)
        t_vpu = max(total_workers - t_mxu, 1)
    r_b = partition.choose_r_boundary(
        csr.nrows, tp_vpu, tp_mxu, t_vpu, t_mxu, br=br,
        paper_literal=paper_literal)
    fmt = loops_from_csr(csr, r_b, br, panel_g=panel_g,
                         macro_m=macro_m, pipeline_depth=pipeline_depth)
    return fmt, SpmmPlan(
        r_boundary=r_b, t_vpu=t_vpu, t_mxu=t_mxu, br=br, panel_g=panel_g,
        pipeline_depth=pipeline_depth, macro_m=macro_m)


def _loops_execute(fmt: LoopsFormat, b: jax.Array, backend: str, bn,
                   out_dtype, csr_vals=None, bcsr_vals=None) -> jax.Array:
    """Backend dispatch for one hybrid SpMM (no differentiation rule).

    ``b`` may carry leading batch dims (the engine folds them into the
    kernels' native batch grid).  ``csr_vals``/``bcsr_vals`` optionally
    substitute traced live values for the format's host-packed constants
    (learned-sparse-weight layers and the transposed backward pass both need
    this); the structure stays static.
    """
    has_csr = fmt.r_boundary > 0
    has_bcsr = fmt.r_boundary < fmt.nrows
    pallas = backend != "jnp"   # panel views only materialise for Pallas
    depth = int(getattr(fmt, "pipeline_depth", 1))
    if (has_csr and has_bcsr and pallas
            and fmt.r_boundary % fmt.bcsr_part.br == 0):
        try:
            return engine.loops_spmm_fused(
                fmt, b, backend=backend, bn=bn, out_dtype=out_dtype,
                csr_vals=csr_vals, bcsr_vals=bcsr_vals,
                pipeline_depth=depth)
        except Exception as e:   # noqa: BLE001 - the parts path IS the handler
            # The fused chain (pallas → interpret) is exhausted: degrade to
            # the two-pass parts path below, whose per-part chains reach the
            # jnp oracle.  Respect the kill switch — with fallback disabled
            # the failure must propagate for tests/operators to see.
            if not _resilience.get_policy().enabled:
                raise
            _resilience.note_degraded("engine.fallback", part="fused",
                                      op="spmm",
                                      reason=_resilience.classify(e))
    parts = []
    if has_csr:
        parts.append(engine.csr_spmm(
            fmt.csr_part, b, backend=backend, bn=bn, out_dtype=out_dtype,
            panels=fmt.csr_panels if pallas else None, vals=csr_vals,
            pipeline_depth=depth))
    if has_bcsr:
        parts.append(engine.bcsr_spmm(
            fmt.bcsr_part, b, backend=backend, bn=bn, out_dtype=out_dtype,
            panels=fmt.bcsr_panels if pallas else None, vals=bcsr_vals,
            pipeline_depth=depth))
    if not parts:
        _, out = engine.resolve_dtypes(fmt.csr_part.vals.dtype, out_dtype)
        return jnp.zeros(b.shape[:-2] + (fmt.nrows, b.shape[-1]), out)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-2)


def _index_maybe(x, batched: bool, i):
    return None if x is None else (x[i] if batched else x)


def _execute_engine(fmt: LoopsFormat, b: jax.Array, backend: str, bn,
                    out_dtype, csr_vals=None, bcsr_vals=None) -> jax.Array:
    """Pallas-path executor with a custom batching rule.

    ``jax.vmap`` over the dense operand folds the mapped axis into the
    kernels' native leading batch dimension — one batched ``pallas_call``
    per part — instead of relying on generic per-element unrolling.  A vmap
    over A's *values* has no native batched kernel (the operand panels
    change per element) and falls back to trace-time unrolling, the exact
    pre-batched behaviour.
    """

    @jax.custom_batching.custom_vmap
    def call(b_, cv, bv):
        return _loops_execute(fmt, b_, backend, bn, out_dtype,
                              csr_vals=cv, bcsr_vals=bv)

    @call.def_vmap
    def _batch_rule(axis_size, in_batched, b_, cv, bv):
        b_batched = bool(jax.tree.leaves(in_batched[0])[0])
        vals_batched = (any(jax.tree.leaves(in_batched[1]))
                        or any(jax.tree.leaves(in_batched[2])))
        if vals_batched or not b_batched:
            outs = [_execute_engine(
                fmt, _index_maybe(b_, b_batched, i), backend, bn, out_dtype,
                _index_maybe(cv, any(jax.tree.leaves(in_batched[1])), i),
                _index_maybe(bv, any(jax.tree.leaves(in_batched[2])), i))
                for i in range(axis_size)]
            return jnp.stack(outs), True
        lead = b_.shape[:-2]
        out = _execute_engine(fmt, b_.reshape((-1,) + b_.shape[-2:]),
                              backend, bn, out_dtype, cv, bv)
        return out.reshape(lead + out.shape[-2:]), True

    return call(b, csr_vals, bcsr_vals)


def _backward_db(fmt: LoopsFormat, dy: jax.Array, backend: str, bn,
                 transpose_plan, csr_vals=None, bcsr_vals=None) -> jax.Array:
    """``dB = Aᵀ · dY`` through the same panel kernels on the (cached)
    transposed format — batched per cotangent slice when ``dy`` carries
    batch dims.  The cotangent is cast to the format's value dtype first —
    the backward matmuls honour the forward kernels' precision contract
    (bf16 operands, fp32 accumulation) instead of silently running a wider
    product."""
    from .formats import transposed_values
    tl = fmt.transposed(plan=transpose_plan)
    dy = dy.astype(tl.fmt.csr_part.vals.dtype)
    cv = bv = None
    if csr_vals is not None:
        cv, bv = transposed_values(tl, csr_vals, bcsr_vals)
    if backend == "jnp":
        return _loops_execute(tl.fmt, dy, backend, bn, None,
                              csr_vals=cv, bcsr_vals=bv)
    return _execute_engine(tl.fmt, dy, backend, bn, None, cv, bv)


def loops_spmm(fmt: LoopsFormat, b: jax.Array, *, backend: str | None = None,
               bn: int | None = None, out_dtype=None,
               transpose_plan: "SpmmPlan | None" = None) -> jax.Array:
    """Execute the hybrid SpMM: C = A @ B with A in LOOPS format.

    ``b`` has shape ``(..., K, N)``; the result is ``(..., nrows, N)``.
    Leading batch dims execute as ONE batched engine call — the Pallas
    grids gain a batch axis that reuses A's static panel layout across all
    slices — and ``jax.vmap`` over ``b`` lowers to the same call via a
    custom batching rule.  A batch dim of zero returns correctly-shaped
    zeros on every backend; a rank-1 or K-mismatched ``b`` raises
    ``ValueError``.

    The CSR-part rows land in C[..., :r_boundary, :], the BCSR-part rows in
    C[..., r_boundary:, :]; each output row is written by exactly one kernel
    (paper §3.4 — conflict-free by construction).

    On the Pallas backends a hybrid format executes single-pass
    (:func:`repro.kernels.engine.loops_spmm_fused`): both kernels fill
    disjoint row ranges of ONE buffer through ``input_output_aliases`` +
    offset index_maps, so no ``concatenate`` copy appears in the jaxpr.  The
    two-output + concatenate fallback remains for the jnp reference and for
    boundaries not aligned to the tile height.

    Differentiable end-to-end: on the Pallas backends a ``jax.custom_vjp``
    computes ``dB = Aᵀ · dY`` through the *same* panel kernels on a lazily
    materialised, cached transposed format (``fmt.transposed()``);
    ``transpose_plan`` pins that format's execution plan (otherwise it is
    resolved by ``plan_and_convert`` on Aᵀ's own row statistics).  The jnp
    reference differentiates natively and stays the gradient oracle.  A's
    values are compile-time constants here — for trainable values use
    :func:`loops_spmm_values`.  (Reverse mode only; the VJP itself is not
    further differentiable.)
    """
    backend = engine.resolve_backend(backend)
    _, out_dtype = engine.resolve_dtypes(fmt.csr_part.vals.dtype, out_dtype)
    engine.check_rhs(fmt.ncols, b)
    if fmt.nnz == 0 or any(d == 0 for d in b.shape[:-2]):
        # All-zero matrix (every stored entry is structural padding) or an
        # empty batch: the product is identically zero with the full
        # (..., nrows, N) shape — never a (0, N) stub.
        return jnp.zeros(b.shape[:-2] + (fmt.nrows, b.shape[-1]), out_dtype)
    if backend == "jnp":
        return _loops_execute(fmt, b, backend, bn, out_dtype)

    @jax.custom_vjp
    def run(b_):
        return _execute_engine(fmt, b_, backend, bn, out_dtype)

    def run_fwd(b_):
        return run(b_), None   # A is static: dB needs only the cotangent

    def run_bwd(_, dy):
        db = _backward_db(fmt, dy, backend, bn, transpose_plan)
        return (db.astype(b.dtype),)

    run.defvjp(run_fwd, run_bwd)
    return run(b)


def loops_spmm_values(fmt: LoopsFormat, csr_vals: jax.Array,
                      bcsr_vals: jax.Array, b: jax.Array, *,
                      backend: str | None = None, bn: int | None = None,
                      out_dtype=None,
                      transpose_plan: "SpmmPlan | None" = None) -> jax.Array:
    """Hybrid SpMM with *trainable* stored values: C = A(vals) @ B.

    ``csr_vals`` (nnz,) and ``bcsr_vals`` (ntiles, Br) are live (traced)
    pytree leaves laid out exactly like ``fmt.csr_part.vals`` /
    ``fmt.bcsr_part.tile_vals``; the structure in ``fmt`` stays static.
    This is the learned-sparse-weight entry point
    (:mod:`repro.models.sparse_ffn`).  ``b`` follows the same batched
    ``(..., K, N)`` contract as :func:`loops_spmm`.

    On the Pallas backends a ``jax.custom_vjp`` supplies all three
    cotangents:

      * ``dB = Aᵀ · dY`` — the same panel kernels on the cached transposed
        format, with the live values carried across by the static
        value-linear maps (:func:`repro.core.formats.transposed_values`),
        batched per cotangent slice;
      * ``dA`` at stored coordinates — the sampled dense-dense kernels
        (:func:`repro.kernels.engine.loops_sdd`), never materialising
        ``dY @ Bᵀ``, **summed over batch dims** (the values are shared
        across the batch).

    The jnp reference differentiates natively (gradient oracle).
    """
    backend = engine.resolve_backend(backend)
    _, out_dtype = engine.resolve_dtypes(jnp.dtype(csr_vals.dtype), out_dtype)
    engine.check_rhs(fmt.ncols, b)
    if any(d == 0 for d in b.shape[:-2]):
        return jnp.zeros(b.shape[:-2] + (fmt.nrows, b.shape[-1]), out_dtype)
    if backend == "jnp":
        return _loops_execute(fmt, b, backend, bn, out_dtype,
                              csr_vals=csr_vals, bcsr_vals=bcsr_vals)

    @jax.custom_vjp
    def run(cv, bv, b_):
        return _execute_engine(fmt, b_, backend, bn, out_dtype, cv, bv)

    def run_fwd(cv, bv, b_):
        return run(cv, bv, b_), (cv, bv, b_)

    def run_bwd(res, dy):
        cv, bv, b_ = res
        db = _backward_db(fmt, dy, backend, bn, transpose_plan,
                          csr_vals=cv, bcsr_vals=bv)
        d_cv, d_bv = engine.loops_sdd(
            fmt, dy, b_, backend=backend, bn=bn,
            pipeline_depth=int(getattr(fmt, "pipeline_depth", 1)))
        return (d_cv.astype(cv.dtype), d_bv.astype(bv.dtype),
                db.astype(b_.dtype))

    run.defvjp(run_fwd, run_bwd)
    return run(csr_vals, bcsr_vals, b)


def loops_grid_steps(fmt: LoopsFormat, n_cols: int,
                     bn: int | None = None) -> int:
    """Total Pallas grid steps to execute ``fmt`` against an (K, n_cols)
    operand — the hardware-independent cost proxy the benchmarks track.

    With G-wide panels the inner grid walks panels, not nonzeros, so the
    count drops from ``(nnz_csr + ntiles) * col_blocks`` at G=1 towards a
    ``~G``-fold reduction (padding at row/block-row boundaries is the gap
    from the ideal).  ``macro_m > 1`` widens the effective panels (the
    cached panel views are built at ``panel_g_eff``), shrinking the count
    a further ``~macro_m``-fold; ``pipeline_depth = d`` adds ``d - 1``
    fill/drain ramp steps per *executed* (non-empty) part.
    """
    bn = bn or default_bn(n_cols)
    col_blocks = -(-n_cols // bn)
    depth = max(int(getattr(fmt, "pipeline_depth", 1)), 1)
    p_csr = fmt.csr_panels.npanels
    p_bcsr = fmt.bcsr_panels.npanels
    # A part that loops_spmm skips contributes nothing — the empty BCSR part
    # is not inherently zero-count (``bcsr_from_csr_rows`` keeps >= 1
    # structural pad tile even for zero rows).
    if fmt.r_boundary == 0:
        p_csr = 0
    if fmt.r_boundary == fmt.nrows:
        p_bcsr = 0
    steps = 0
    for p in (p_csr, p_bcsr):
        if p > 0:
            steps += (p + depth - 1) * col_blocks
    return steps


def loops_batched_grid_steps(fmt: LoopsFormat, batch, n_cols: int,
                             bn: int | None = None) -> int:
    """Grid steps of ONE native batched engine call against a
    ``(*batch, K, n_cols)`` operand.

    The batched grids process ``engine.batch_block`` slices per step (A's
    panel loaded once, applied to every slice), so the count grows by
    ``ceil(batch / bz)`` — at ``batch ≤ MAX_BATCH_BLOCK`` it EQUALS the
    single-element count, while a per-element Python loop pays
    ``batch × loops_grid_steps`` (plus a dispatch per element).
    """
    b = int(np.prod(batch)) if np.ndim(batch) else int(batch)
    if b == 0:
        return 0
    bp = engine.padded_batch(b)   # awkward sizes zero-pad into wide blocks
    return (bp // engine.batch_block(bp)) * loops_grid_steps(fmt, n_cols, bn)


# ---------------------------------------------------------------------------
# Baselines the paper compares against (implemented, per assignment scope)
# ---------------------------------------------------------------------------

def spmm_csr_baseline(csr: CSR, b: jax.Array, out_dtype=None) -> jax.Array:
    """TACO-style row-wise CSR schedule (pure XLA segment-sum lowering)."""
    return ref.csr_spmm_ref(jnp.asarray(csr.row_ids), jnp.asarray(csr.col_idx),
                            jnp.asarray(csr.vals), b, csr.nrows,
                            out_dtype=out_dtype)


def spmm_dense_baseline(a_dense: np.ndarray, b: jax.Array,
                        out_dtype=None) -> jax.Array:
    """Armadillo-style dense GEMM on the densified operand."""
    return ref.dense_spmm(jnp.asarray(a_dense), b, out_dtype=out_dtype)
