"""Distributed two-level LOOPS SpMM (paper §3.4 + §3.5, scaled out).

Coarse level (paper: disjoint OpenMP thread groups) -> **disjoint device
groups** along one mesh axis inside a ``shard_map``: the first ``g`` devices
execute the CSR(vector) kernel on the irregular-row region, the remaining
``D - g`` devices execute the BCSR(matrix) kernel on the regular-row region.
Fine level (paper: row / row-block thread parallelism) -> each device's local
kernel grid over its row shard.

Row-exclusive outputs make the whole thing synchronisation-free exactly as in
the paper: every global output row belongs to exactly one device, so the
combined result is a pure concatenation — no atomics, no all-reduce on C.

Workload balance *within* each group uses nnz-balanced (not row-balanced)
chunking, which is the distributed analogue of the paper's fine-grained
row-wise OpenMP partitioning.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..compat import shard_map
from ..dist.sharding import loops_in_specs, loops_out_spec
from ..kernels import ref
from .formats import LoopsFormat
from .perf_model import QuadraticPerfModel

__all__ = ["ShardedLoops", "shard_loops", "shard_loops_auto",
           "distributed_spmm"]


@dataclasses.dataclass(frozen=True)
class ShardedLoops:
    """Device-stacked LOOPS workload: leading axis = device along the spmm
    mesh axis.  VPU-group devices carry real CSR chunks and a trivial
    (single zero tile) BCSR chunk; MXU-group devices vice versa."""

    row_ids: np.ndarray    # (D, nnz_pad) int32 — local row ids
    col_idx: np.ndarray    # (D, nnz_pad) int32
    vals: np.ndarray       # (D, nnz_pad)
    tile_rows: np.ndarray  # (D, t_pad) int32 — local block-row ids
    tile_cols: np.ndarray  # (D, t_pad) int32
    tile_vals: np.ndarray  # (D, t_pad, Br)
    row_offset: Tuple[int, ...]  # global first row per device
    row_count: Tuple[int, ...]   # logical rows per device
    rows_pad: int                # uniform local output height
    g_vpu: int                   # devices in the CSR(vector) group
    br: int
    shape: Tuple[int, int]


def _balanced_chunks(weights: np.ndarray, parts: int) -> list[tuple[int, int]]:
    """Contiguous [start, stop) unit ranges with ~equal total weight."""
    total = float(weights.sum())
    cum = np.concatenate([[0.0], np.cumsum(weights)])
    bounds = [0]
    for p in range(1, parts):
        target = total * p / parts
        bounds.append(int(np.searchsorted(cum, target)))
    bounds.append(len(weights))
    bounds = np.maximum.accumulate(bounds)
    return [(bounds[i], bounds[i + 1]) for i in range(parts)]


def shard_loops(fmt: LoopsFormat, num_devices: int, g_vpu: int) -> ShardedLoops:
    """Split a LoopsFormat across ``num_devices`` with ``g_vpu`` vector-group
    devices (paper: t_neon) and the rest matrix-group (t_sme)."""
    if not 0 <= g_vpu <= num_devices:
        raise ValueError("g_vpu out of range")
    csr, bcsr = fmt.csr_part, fmt.bcsr_part
    g_mxu = num_devices - g_vpu
    dtype = csr.vals.dtype

    # --- CSR group: nnz-balanced contiguous row ranges of the CSR-part.
    row_chunks = []
    if g_vpu:
        counts = np.diff(csr.row_ptr)
        for (r0, r1) in _balanced_chunks(counts.astype(np.float64),
                                         g_vpu):
            row_chunks.append((r0, r1))
    # --- BCSR group: tile-balanced contiguous block-row ranges.
    blk_chunks = []
    if g_mxu:
        bcounts = np.diff(bcsr.block_ptr)
        for (b0, b1) in _balanced_chunks(bcounts.astype(np.float64), g_mxu):
            blk_chunks.append((b0, b1))

    nnz_pad = 1
    for (r0, r1) in row_chunks:
        nnz_pad = max(nnz_pad, int(csr.row_ptr[r1] - csr.row_ptr[r0]), r1 - r0)
    t_pad = 1
    for (b0, b1) in blk_chunks:
        t_pad = max(t_pad, int(bcsr.block_ptr[b1] - bcsr.block_ptr[b0]))

    rows_pad = 1
    for (r0, r1) in row_chunks:
        rows_pad = max(rows_pad, r1 - r0)
    for (b0, b1) in blk_chunks:
        rows_pad = max(rows_pad, (b1 - b0) * bcsr.br)

    D = num_devices
    row_ids = np.zeros((D, nnz_pad), np.int32)
    col_idx = np.zeros((D, nnz_pad), np.int32)
    vals = np.zeros((D, nnz_pad), dtype)
    tile_rows = np.zeros((D, t_pad), np.int32)
    tile_cols = np.zeros((D, t_pad), np.int32)
    tile_vals = np.zeros((D, t_pad, bcsr.br), dtype)
    row_offset, row_count = [], []

    for d, (r0, r1) in enumerate(row_chunks):
        s, e = int(csr.row_ptr[r0]), int(csr.row_ptr[r1])
        row_ids[d, :e - s] = csr.row_ids[s:e] - r0
        # Padding entries keep writing row 0 with val 0 — harmless.
        col_idx[d, :e - s] = csr.col_idx[s:e]
        vals[d, :e - s] = csr.vals[s:e]
        row_offset.append(r0)
        row_count.append(r1 - r0)
    for i, (b0, b1) in enumerate(blk_chunks):
        d = g_vpu + i
        s, e = int(bcsr.block_ptr[b0]), int(bcsr.block_ptr[b1])
        tile_rows[d, :e - s] = bcsr.tile_rows[s:e] - b0
        tile_cols[d, :e - s] = bcsr.tile_cols[s:e]
        tile_vals[d, :e - s] = bcsr.tile_vals[s:e]
        row_offset.append(fmt.r_boundary + b0 * bcsr.br)
        row_count.append(min((b1 - b0) * bcsr.br,
                             bcsr.nrows - b0 * bcsr.br))

    return ShardedLoops(
        row_ids=row_ids, col_idx=col_idx, vals=vals, tile_rows=tile_rows,
        tile_cols=tile_cols, tile_vals=tile_vals,
        row_offset=tuple(row_offset), row_count=tuple(row_count),
        rows_pad=rows_pad, g_vpu=g_vpu, br=bcsr.br, shape=fmt.shape)


def shard_loops_auto(fmt: LoopsFormat, num_devices: int, *,
                     model: QuadraticPerfModel | None = None,
                     measure: Callable[[int, int], float] | None = None,
                     cache=None, trace_db=None) -> ShardedLoops:
    """Coarse-level scheduling (paper §3.5.3): let the quadratic perf model
    pick the (vector-group, matrix-group) *device* split, then shard.

    This is Eq. 3's argmax applied one level up from threads: ``x`` devices
    run the CSR(vector) kernel on the irregular region, ``y = D - x`` run the
    BCSR(matrix) kernel on the regular region.  ``model`` is a pre-fitted
    :class:`~repro.core.perf_model.QuadraticPerfModel`; alternatively pass
    ``measure(x, y) -> perf`` to calibrate one from warm-up probes (wall
    clock at small scale, roofline terms from the dry-run at production
    scale).  With neither, the split falls back to proportional nnz weight —
    the same default as ``plan_and_convert``'s thread-level path.

    ``cache`` — a :class:`repro.tune.PlanCache` — is consulted *before*
    solving Eq. 3: if a structurally matching device split was recorded for
    this ``num_devices``, it is reused (calibration and the argmax are both
    skipped); otherwise the solved split is stored for the next caller.

    ``trace_db`` — a :class:`repro.perf.replay.TraceDB` of measured trace
    records — supplies the model when neither ``model`` nor ``measure`` is
    given: the Eq. 2 coefficients are refit from the traces
    (:func:`repro.perf.trace.fit_cost_model`, ``calibrated_from`` stamped)
    and Eq. 3's argmax runs on measured numbers instead of the
    proportional-nnz fallback.  An underdetermined database degrades to the
    fallback silently.
    """
    has_csr = fmt.r_boundary > 0
    has_bcsr = fmt.r_boundary < fmt.nrows
    if num_devices < 2 and has_csr and has_bcsr:
        # one device cannot host two disjoint groups; the single-device
        # hybrid path is core.spmm.loops_spmm
        raise ValueError("shard_loops_auto needs >= 2 devices when both the "
                         "CSR and BCSR regions are non-empty; use "
                         "loops_spmm for single-device execution")
    key = fp = None
    if cache is not None:
        from ..tune.fingerprint import cache_key, loops_fingerprint
        fp = loops_fingerprint(fmt)
        dt = np.dtype(fmt.csr_part.vals.dtype)
        key = cache_key(fp, n_cols=0, dtype=dt,
                        backend=f"dist{num_devices}")
        rec = cache.lookup(key, features=fp.features(), dtype=dt.name,
                           n_cols=0, backend=f"dist{num_devices}",
                           max_distance=0.25)
        if rec is not None:
            g_vpu = int(rec["plan"]["t_vpu"])
            g_vpu = int(np.clip(g_vpu, 1 if has_csr else 0,
                                num_devices - 1 if has_bcsr
                                else num_devices))
            return shard_loops(fmt, num_devices, g_vpu)
    if model is None and measure is not None:
        from .perf_model import calibrate
        model = calibrate(measure, num_devices)
    if model is None and trace_db is not None:
        model = trace_db.cost_model()   # None when underdetermined
    if model is not None:
        # best_allocation may leave devices idle (x + y < D); only the
        # ratio matters here, every device gets a chunk of its group's work
        g_vpu, _ = model.best_allocation(num_devices)
    else:
        nnz_csr = int(np.count_nonzero(fmt.csr_part.vals))
        nnz_b = int(np.count_nonzero(fmt.bcsr_part.tile_vals))
        total = max(nnz_csr + nnz_b, 1)
        g_vpu = int(round(num_devices * nnz_csr / total))
    if has_csr:
        g_vpu = max(g_vpu, 1)
    if has_bcsr:
        g_vpu = min(g_vpu, num_devices - 1)
    g_vpu = int(np.clip(g_vpu, 0, num_devices))
    if cache is not None and key is not None:
        from ..tune.api import make_record
        cache.put(key, make_record(
            fp.features(), dtype=fmt.csr_part.vals.dtype, n_cols=0,
            backend=f"dist{num_devices}",
            r_frac=fmt.r_boundary / max(fmt.nrows, 1),
            t_vpu=g_vpu, t_mxu=num_devices - g_vpu,
            br=fmt.bcsr_part.br, panel_g=fmt.panel_g))
    return shard_loops(fmt, num_devices, g_vpu)


def distributed_spmm(sharded: ShardedLoops, b: jax.Array, mesh: Mesh,
                     axis="model", assemble: bool = True) -> jax.Array:
    """Run the two-level schedule on ``mesh[axis]``; returns the global C.

    ``axis`` may be a single mesh axis or a tuple (e.g. ("data", "model") to
    flatten the whole production pod into one SpMM worker axis).

    ``b`` follows the engine's batched contract ``(..., K, N)`` and is
    consumed by the batched reference kernels directly — one shard_map call
    serves every batch slice (no per-element Python loop, no flattening
    reshape at the call site); the result is ``(..., M, N)`` assembled, or
    ``(D, ..., rows_pad, N)`` stacked.

    Every device computes its local kernel over its shard (the off-group
    kernel sees a single zero entry and contributes nothing), then the
    per-device row slices are concatenated with statically known offsets —
    zero inter-device communication beyond B's broadcast, the scaled-out
    version of the paper's conflict-free row ownership.

    Differentiable w.r.t. ``b`` via a custom VJP: each device transposes its
    own row shard against its exclusive slice of the cotangent
    (``Aᵀ_shard · dY_shard``, batch dims carried through) and the partials
    are summed with :func:`repro.dist.step.loops_cotangent_psum` — the
    backward dual of B's replicated entry in ``loops_in_specs`` — so ``dB``
    comes back replicated exactly like the operand it is the gradient of.
    """
    from ..kernels.engine import check_rhs
    check_rhs(sharded.shape[1], b)

    @jax.custom_vjp
    def run_vjp(b_):
        return _distributed_execute(sharded, b_, mesh, axis, assemble)

    def run_fwd(b_):
        return run_vjp(b_), None   # workload is static; bwd needs only dY

    def run_bwd(_, dy):
        return (_distributed_db(sharded, dy, mesh, axis,
                                assemble).astype(b.dtype),)

    run_vjp.defvjp(run_fwd, run_bwd)
    return run_vjp(b)


def _worker_axes(mesh: Mesh, axis):
    """Normalise the SpMM worker ``axis`` (name or tuple of names) and
    return ``(axes, D)`` — shared by the forward and backward shard_maps so
    their axis handling can never diverge."""
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    d = 1
    for a in axes:
        d *= mesh.shape[a]
    return axes, d


def _distributed_execute(sharded: ShardedLoops, b: jax.Array, mesh: Mesh,
                         axis, assemble: bool) -> jax.Array:
    """The forward shard_map body of :func:`distributed_spmm`."""
    axes, D = _worker_axes(mesh, axis)
    rows_pad, br = sharded.rows_pad, sharded.br
    nblocks_pad = (rows_pad + br - 1) // br

    @partial(
        shard_map, mesh=mesh,
        in_specs=loops_in_specs(axes),
        out_specs=loops_out_spec(axes))
    def run(row_ids, col_idx, vals, tile_rows, tile_cols, tile_vals, bloc):
        row_ids, col_idx, vals = row_ids[0], col_idx[0], vals[0]
        tile_rows, tile_cols, tile_vals = (tile_rows[0], tile_cols[0],
                                           tile_vals[0])
        out_c = ref.csr_spmm_ref(row_ids, col_idx, vals, bloc, rows_pad)
        out_b = ref.bcsr_spmm_ref(tile_rows, tile_cols, tile_vals, bloc,
                                  nblocks_pad)[..., :rows_pad, :]
        return (out_c + out_b)[None]

    stacked = run(jnp.asarray(sharded.row_ids), jnp.asarray(sharded.col_idx),
                  jnp.asarray(sharded.vals), jnp.asarray(sharded.tile_rows),
                  jnp.asarray(sharded.tile_cols),
                  jnp.asarray(sharded.tile_vals), b)

    if not assemble:
        # §Perf iteration: leave C row-sharded (D, ..., rows_pad, N).  Row
        # ownership is exclusive (paper §3.4), so downstream row-parallel
        # consumers (GNN layers, further SpMMs) read their shard locally —
        # assembling to a replicated dense C is pure collective overhead.
        return stacked
    pieces = [stacked[d][..., :sharded.row_count[d], :] for d in range(D)
              if sharded.row_count[d] > 0]
    return jnp.concatenate(pieces, axis=-2)


def _distributed_db(sharded: ShardedLoops, dy: jax.Array, mesh: Mesh,
                    axis, assemble: bool) -> jax.Array:
    """Backward of :func:`distributed_spmm` w.r.t. the dense operand.

    Each device computes ``Aᵀ_local · dY_local`` over its exclusive row
    shard (a scatter-by-column segment-sum — the transposed reading of the
    two reference kernels), then the partials are psummed over the worker
    axis (:func:`repro.dist.step.loops_cotangent_psum`).  ``dy`` arrives
    assembled ``(..., M, N)`` or stacked ``(D, ..., rows_pad, N)`` to mirror
    whichever layout the forward produced; batch dims pass straight through
    (``dB`` is per batch element — only the worker axis is summed).
    """
    from ..dist.step import loops_cotangent_psum   # lazy: avoids import cycle
    axes, D = _worker_axes(mesh, axis)
    rows_pad, br = sharded.rows_pad, sharded.br
    nblocks_pad = (rows_pad + br - 1) // br
    k = sharded.shape[1]
    n = dy.shape[-1]
    if assemble:
        # Slice the global cotangent back into the devices' exclusive row
        # ranges (static offsets — pure data movement, no collective).
        no_pad = [(0, 0)] * (dy.ndim - 2)
        slices = []
        for d in range(D):
            o, c = sharded.row_offset[d], sharded.row_count[d]
            slices.append(jnp.pad(dy[..., o:o + c, :],
                                  no_pad + [(0, rows_pad - c), (0, 0)]))
        dy_stacked = jnp.stack(slices)
    else:
        dy_stacked = dy

    from jax.sharding import PartitionSpec as P
    # workload specs as in the forward; the cotangent rides the *output*
    # spec (row-sharded), the result comes back replicated like B was
    in_specs = loops_in_specs(axes)[:6] + (loops_out_spec(axes),)

    @partial(shard_map, mesh=mesh, in_specs=in_specs, out_specs=P())
    def run(row_ids, col_idx, vals, tile_rows, tile_cols, tile_vals, dyl):
        row_ids, col_idx, vals = row_ids[0], col_idx[0], vals[0]
        tile_rows, tile_cols, tile_vals = (tile_rows[0], tile_cols[0],
                                           tile_vals[0])
        dyl = dyl[0]                                   # (..., rows_pad, N)
        acc = ref.acc_dtype_for(vals.dtype)

        def _local_db(dyl2):                           # (rows_pad, N)
            db_c = jax.ops.segment_sum(
                vals.astype(acc)[:, None] * dyl2[row_ids].astype(acc),
                col_idx, num_segments=k)
            pad = nblocks_pad * br - rows_pad
            dyb = jnp.pad(dyl2, ((0, pad), (0, 0))) if pad else dyl2
            blocks = dyb.reshape(nblocks_pad, br, n).astype(acc)
            contrib = jnp.einsum("tb,tbn->tn", tile_vals.astype(acc),
                                 blocks[tile_rows])
            db_b = jax.ops.segment_sum(contrib, tile_cols, num_segments=k)
            return db_c + db_b

        if dyl.ndim > 2:                               # batched cotangent
            lead = dyl.shape[:-2]
            flat = dyl.reshape((-1,) + dyl.shape[-2:])
            db = jax.vmap(_local_db)(flat).reshape(lead + (k, n))
        else:
            db = _local_db(dyl)
        return loops_cotangent_psum(db, axes)

    return run(jnp.asarray(sharded.row_ids), jnp.asarray(sharded.col_idx),
               jnp.asarray(sharded.vals), jnp.asarray(sharded.tile_rows),
               jnp.asarray(sharded.tile_cols),
               jnp.asarray(sharded.tile_vals), dy_stacked)
