"""Row-boundary selection for the LOOPS hybrid split (paper §3.1, Eq. 1).

The boundary ``r_boundary`` separates the CSR(vector)-part from the
BCSR(matrix)-part.  The paper balances the two pipelines:

    r_b * TP_neon * t_neon = (r_total - r_b) * TP_sme * t_sme        (Eq. 1)

Note on Eq. 1 as printed: equalising *work x capability* products assigns
FEWER rows to the FASTER pipeline, which is dimensionally inconsistent with
the stated goal ("equalizes the workload and computational capability").  The
physically balanced-time condition is

    r_b / (TP_vpu * t_vpu) = (r_total - r_b) / (TP_mxu * t_mxu)

i.e. each group finishes at the same instant.  We implement balanced-time by
default and keep the literal printed form behind ``paper_literal=True``; the
discrepancy is recorded in DESIGN.md.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .formats import CSR

__all__ = ["RowStats", "row_stats", "choose_r_boundary", "regularity_boundary"]


@dataclasses.dataclass(frozen=True)
class RowStats:
    """Per-row nonzero statistics (paper Table 2 feature values)."""

    nrows: int
    nnz: int
    nnz_max: int
    nnz_min: int
    nnz_mean: float
    nnz_std: float


def row_stats(csr: CSR) -> RowStats:
    counts = np.diff(csr.row_ptr)
    return RowStats(
        nrows=csr.nrows, nnz=csr.nnz,
        nnz_max=int(counts.max(initial=0)),
        nnz_min=int(counts.min(initial=0)),
        nnz_mean=float(counts.mean()) if len(counts) else 0.0,
        nnz_std=float(counts.std()) if len(counts) else 0.0)


def choose_r_boundary(nrows: int, tp_vpu: float, tp_mxu: float,
                      t_vpu: int, t_mxu: int, *, br: int = 8,
                      paper_literal: bool = False) -> int:
    """Solve Eq. 1 for ``r_boundary`` and round to a tile-height multiple.

    ``tp_*`` are per-worker row-throughputs (rows/s) of the two kernels,
    ``t_*`` the worker (thread/device) counts chosen by the scheduler.
    Degenerate allocations collapse to pure-CSR (t_mxu == 0) or pure-BCSR
    (t_vpu == 0) — the ablation baselines of paper §4.3.
    """
    cap_v = tp_vpu * t_vpu
    cap_m = tp_mxu * t_mxu
    if cap_v <= 0 and cap_m <= 0:
        raise ValueError("at least one pipeline must have capacity")
    if cap_m <= 0:
        return nrows  # pure vector path: everything CSR
    if cap_v <= 0:
        return 0      # pure matrix path: everything BCSR
    if paper_literal:
        # r_b * cap_v = (r_total - r_b) * cap_m  (printed form)
        frac = cap_m / (cap_v + cap_m)
    else:
        # balanced completion time: r_b / cap_v = (r_total - r_b) / cap_m
        frac = cap_v / (cap_v + cap_m)
    r_b = int(round(frac * nrows))
    # Snap so the BCSR region starts on a tile boundary-friendly offset.
    r_b = min(max((r_b // br) * br, 0), nrows)
    return r_b


def regularity_boundary(csr: CSR, *, br: int = 8,
                        density_threshold: float | None = None) -> int:
    """Beyond-paper heuristic: find the positional boundary that maximises the
    regularity of the BCSR region.

    The paper splits positionally (top rows -> CSR).  Many SuiteSparse
    matrices have their irregular (hub) rows scattered; a cheap improvement
    that keeps the positional-split kernel contract is to scan candidate
    boundaries and pick the one whose suffix has per-row nnz closest to
    uniform (low padding waste in ``Br x 1`` tiles, i.e. high block density).
    """
    counts = np.diff(csr.row_ptr).astype(np.float64)
    n = csr.nrows
    if n == 0:
        return 0
    mean = counts.mean()
    thr = density_threshold if density_threshold is not None else mean
    # Suffix statistics via reverse cumulative sums.
    rev = counts[::-1]
    c1 = np.cumsum(rev)[::-1]                # sum of counts in suffix
    c2 = np.cumsum(rev * rev)[::-1]          # sum of squares in suffix
    sizes = np.arange(n, 0, -1, dtype=np.float64)
    suf_mean = c1 / sizes
    suf_var = np.maximum(c2 / sizes - suf_mean ** 2, 0.0)
    # Score: prefer large, dense, low-variance suffixes.
    score = (suf_mean - thr) * sizes - np.sqrt(suf_var) * sizes * 0.25
    boundaries = np.arange(0, n, max(br, 1))
    best = int(boundaries[np.argmax(score[boundaries])])
    return best
