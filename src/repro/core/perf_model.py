"""Lightweight quadratic performance model + scheduler (paper §3.5).

The paper models throughput as a quadratic in the two thread-group sizes with
no cross term (Eq. 2) because the NEON and SME pipelines are independent:

    perf(x, y) = a0 + a1*x + a2*y + a3*x^2 + a4*y^2

and schedules by enumerating all (x, y) with x + y <= T (Eq. 3).

TPU adaptation: "threads" become *device-group sizes* of the VPU-kernel group
and the MXU-kernel group inside a shard_map (coarse level), or — within a
single chip — the fraction of Pallas grid steps routed through each pipeline.
The functional form and the argmax scheduler are kept verbatim; only the
calibration source changes (wall-clock interpret runs at small scale, or
roofline terms from the compiled dry-run at production scale).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence, Tuple

import numpy as np

__all__ = ["QuadraticPerfModel", "fit_perf_model", "best_allocation",
           "calibrate"]


@dataclasses.dataclass(frozen=True)
class QuadraticPerfModel:
    """perf(x, y) = a0 + a1 x + a2 y + a3 x**2 + a4 y**2 (paper Eq. 2).

    Panel-extended variant (this repo's kernel layer): when calibrated over
    ``(x, y, g)`` samples — ``g`` the panel width of the G-wide kernels —
    two extra terms model the panelization axis with the same no-cross-term
    independence assumption:

        perf(x, y, g) = Eq.2(x, y) + a5 g + a6 g**2

    (the grid-step reduction saturates once padding dominates, which the
    concave ``a6 < 0`` fit captures).  A 5-coefficient model simply ignores
    ``g``, keeping every pre-panelization caller intact.
    """

    coef: np.ndarray  # (5,) [a0..a4] or (7,) [a0..a4, a5, a6]
    # Provenance: where the coefficients came from ("traces:<n> records",
    # "calibrate:<n> probes", None for hand-set/prior models).  The trace
    # layer (repro.perf.trace.fit_cost_model) stamps this so a schedule can
    # always be traced back to its measurement source.
    calibrated_from: str | None = None

    @property
    def has_panel_terms(self) -> bool:
        return int(self.coef.shape[0]) >= 7

    def predict(self, x, y, g=None):
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        a = self.coef
        base = a[0] + a[1] * x + a[2] * y + a[3] * x * x + a[4] * y * y
        if g is not None and self.has_panel_terms:
            g = np.asarray(g, np.float64)
            base = base + a[5] * g + a[6] * g * g
        return base

    def best_allocation(self, total: int,
                        allow_zero: bool = True) -> Tuple[int, int]:
        """Paper Eq. 3: argmax over x + y <= total (exhaustive — core counts
        are small, and so are practical device-group splits)."""
        lo = 0 if allow_zero else 1
        best, best_perf = (lo, lo), -np.inf
        for x in range(lo, total + 1):
            for y in range(lo, total - x + 1):
                if x + y == 0:
                    continue
                p = float(self.predict(x, y))
                if p > best_perf:
                    best, best_perf = (x, y), p
        return best

    def best_allocation_g(self, total: int,
                          g_choices: Sequence[int] = (1, 4, 8),
                          allow_zero: bool = True) -> Tuple[int, int, int]:
        """Eq. 3 extended with the panel-width axis: argmax over
        ``x + y <= total`` and ``g in g_choices``."""
        lo = 0 if allow_zero else 1
        best, best_perf = (lo, lo, min(g_choices)), -np.inf
        for x in range(lo, total + 1):
            for y in range(lo, total - x + 1):
                if x + y == 0:
                    continue
                for g in g_choices:
                    p = float(self.predict(x, y, g))
                    if p > best_perf:
                        best, best_perf = (x, y, g), p
        return best


def _design(samples: np.ndarray) -> np.ndarray:
    """Design matrix for Eq. 2 ((n, 2) samples) or its panel-extended form
    ((n, 3) samples with a trailing g column)."""
    x, y = samples[:, 0], samples[:, 1]
    cols = [np.ones_like(x), x, y, x * x, y * y]
    if samples.shape[1] == 3:
        g = samples[:, 2]
        cols.extend([g, g * g])
    return np.stack(cols, axis=1)


def fit_perf_model(samples: Sequence[Tuple[int, ...]],
                   perfs: Sequence[float], *,
                   ridge: float | None = None,
                   calibrated_from: str | None = None) -> QuadraticPerfModel:
    """Least-squares fit of Eq. 2 over measured (x, y) -> perf samples, or of
    the panel-extended form over (x, y, g) triples.

    Rank-deficient candidate sets (fewer distinct points than coefficients —
    e.g. a caller probing only the axes' endpoints) underdetermine the
    coefficients; plain ``lstsq`` then returns one of infinitely many exact
    fits whose extrapolation ``best_allocation`` would trust blindly.  We
    fall back to a ridge (Tikhonov) solution: minimal-norm coefficients that
    still interpolate the measurements, with the quadratic terms shrunk so
    the argmax cannot run away on unmeasured configurations.

    ``ridge`` — an explicit Tikhonov strength (relative to the mean design
    energy) — forces the regularised solve even on full-rank systems.  The
    trace-calibrated path (:func:`repro.perf.trace.fit_cost_model`) uses
    this: measured samples carry wall-clock noise, and an unregularised
    quadratic happily chases it.  ``calibrated_from`` stamps the returned
    model's provenance field.
    """
    xy = np.asarray(samples, np.float64)
    if xy.ndim != 2 or xy.shape[1] not in (2, 3):
        raise ValueError("samples must be (x, y) pairs or (x, y, g) triples")
    ncoef = 5 if xy.shape[1] == 2 else 7
    if xy.shape[0] < ncoef:
        raise ValueError(f"need >= {ncoef} samples to fit {ncoef} "
                         "coefficients")
    design = _design(xy)
    p = np.asarray(perfs, np.float64)
    deficient = np.linalg.matrix_rank(design) < design.shape[1]
    if ridge is not None or deficient:
        rel = ridge if ridge is not None else 1e-6
        ata = design.T @ design
        lam = rel * max(float(np.trace(ata)) / design.shape[1], 1.0)
        coef = np.linalg.solve(ata + lam * np.eye(design.shape[1]),
                               design.T @ p)
    else:
        coef, *_ = np.linalg.lstsq(design, p, rcond=None)
    return QuadraticPerfModel(coef=coef, calibrated_from=calibrated_from)


def default_candidates(total: int) -> Iterable[Tuple[int, int]]:
    """Representative warm-up configurations (paper §3.1: 'a representative set
    of parameter configurations'): the axes, the diagonal, and the corners."""
    cand = set()
    for t in (1, max(total // 4, 1), max(total // 2, 1), total):
        cand.add((t, 0))
        cand.add((0, t))
        cand.add((t, max(total - t, 0)))
        cand.add((max(total - t, 0), t))
    cand.add((max(total // 2, 1), max(total // 2, 1)))
    return sorted((x, y) for (x, y) in cand if 0 < x + y <= total)


def calibrate(measure: Callable[..., float], total: int,
              candidates: Iterable[Tuple[int, ...]] | None = None,
              g_choices: Sequence[int] | None = None
              ) -> QuadraticPerfModel:
    """Fit the model from warm-up measurements.

    ``measure(x, y)`` returns a performance score (higher is better; e.g.
    GFLOP/s) for ``x`` vector-group and ``y`` matrix-group workers.  With
    ``g_choices``, the warm-up sweep crosses the candidate splits (explicit
    ``candidates`` included, unless they already carry a g column) with each
    panel width and ``measure(x, y, g)`` is expected instead, yielding the
    panel-extended model.
    """
    cand = list(candidates if candidates is not None
                else default_candidates(total))
    if g_choices is not None and (not cand or len(cand[0]) == 2):
        cand = [(x, y, g) for (x, y) in cand for g in g_choices]
    perfs = [measure(*c) for c in cand]
    return fit_perf_model(cand, perfs,
                          calibrated_from=f"calibrate:{len(cand)} probes")


def best_allocation(measure: Callable[[int, int], float], total: int
                    ) -> Tuple[int, int]:
    """Calibrate + schedule in one call (paper §3.5.3)."""
    return calibrate(measure, total).best_allocation(total)
