"""Deterministic, shardable, resumable synthetic data pipeline.

Every batch is a pure function of ``(seed, step)`` via PRNG fold-in — which
buys three fault-tolerance properties for free:

  * **resumability**: the iterator "state" is just the step counter (stored in
    the checkpoint); restart reproduces the exact token stream;
  * **host independence / straggler isolation**: host h can materialise its
    own batch shard without talking to any other host (fold_in(seed, step) is
    position-addressable), so a slow host never blocks data for the others;
  * **elasticity**: after a topology change, the same global batch is
    re-sharded over the surviving hosts by slicing the same deterministic
    global batch differently — no data-loader state migration.

Tokens follow a Zipfian distribution (vocab-realistic), labels are the
next-token shift with the final position masked.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig

__all__ = ["DataConfig", "global_batch_at", "host_shard"]

I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_alpha: float = 1.1


def _zipf_tokens(key, shape, vocab: int, alpha: float):
    """Zipf via inverse-CDF on a uniform draw (cheap, vectorised)."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    # approximate inverse CDF of Zipf(alpha) truncated at vocab
    ranks = jnp.power(u, -1.0 / (alpha - 1.0) if alpha > 1.0 else -1.0)
    toks = jnp.clip(ranks.astype(jnp.int32) % vocab, 0, vocab - 1)
    return toks


def global_batch_at(data: DataConfig, cfg: ModelConfig, shape: ShapeConfig,
                    n_microbatches: int, step: int) -> Dict[str, Any]:
    """The full (n_mb, mb, ...) training batch for ``step`` (jit-friendly)."""
    key = jax.random.fold_in(jax.random.key(data.seed), step)
    mb = shape.global_batch // n_microbatches
    lead = (n_microbatches, mb)
    ktok, kfe = jax.random.split(key)
    seq = _zipf_tokens(ktok, (*lead, shape.seq_len + 1), cfg.vocab_size,
                       data.zipf_alpha)
    tokens = seq[..., :-1]
    labels = jnp.where(
        jnp.arange(shape.seq_len) < shape.seq_len - 1, seq[..., 1:], -1)
    batch = {"tokens": tokens, "labels": labels.astype(I32)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = 0.02 * jax.random.normal(
            kfe, (*lead, cfg.num_patches, cfg.d_model), jnp.float32)
    elif cfg.frontend == "audio_stub":
        batch["frames"] = 0.02 * jax.random.normal(
            kfe, (*lead, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


def host_shard(batch: Dict[str, Any], host_id: int, num_hosts: int):
    """Slice a host's rows from the global batch (dim 1 = batch)."""
    def leaf(x):
        per = x.shape[1] // num_hosts
        return x[:, host_id * per:(host_id + 1) * per]
    return jax.tree.map(leaf, batch)
