"""Deterministic, resumable, shardable synthetic data pipeline."""
from .pipeline import DataConfig, global_batch_at, host_shard
