"""Engine fallback chains and retry/deadline helpers.

The LOOPS design always has a slower-but-correct way to run any matrix —
ultimately the jnp oracle the whole test suite is pinned against.  This
module encodes that as an explicit per-``(part, op)`` **fallback chain**

    pallas → interpret → jnp

walked by :func:`run_chain`: the engine entry points wrap each backend's
dispatch in an ``attempt(backend)`` closure, and a failing attempt degrades
to the next link with an ``engine.fallback{part,op,reason}`` counter instead
of letting the exception escape ``loops_spmm``.  The fused single-pass
kernel has no jnp equivalent, so its chain ends at ``interpret`` and
``core.spmm._loops_execute`` catches the exhausted chain and re-runs the
two-pass parts path (each part then owns its own chain down to the oracle).

Fallback fires at trace time when the failure does (kernel lowering and
interpret-mode faults raise during tracing), so under ``jax.jit`` a degraded
call compiles the fallback backend — the counter is per-compilation, like
every engine dispatch counter.

Kill switch: ``REPRO_NO_FALLBACK=1`` (or the :func:`disabled` context
manager) makes every chain single-link so failures propagate — tests that
assert error behaviour, and operators who prefer crash-fast, use this.

:func:`retry_with_backoff` is the host-side half: transient *step* failures
(serving/training) retry with exponential backoff under an optional
deadline.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Callable, Dict, Tuple

from .inject import (InjectedFault, InjectedTimeout, fault_point,
                     note_degraded)

__all__ = ["DEFAULT_CHAIN", "FallbackPolicy", "get_policy", "set_policy",
           "disabled", "run_chain", "classify", "retry_with_backoff",
           "DeadlineExceeded"]

# The canonical degradation order: fastest first, oracle last.
DEFAULT_CHAIN: Tuple[str, ...] = ("pallas", "interpret", "jnp")

# Per-(part, op) overrides.  The fused kernel is Pallas-only (it relies on
# input_output_aliases); its chain ends at interpret and the caller
# (core.spmm._loops_execute) degrades to the two-pass parts path.
CHAIN_OVERRIDES: Dict[Tuple[str, str], Tuple[str, ...]] = {
    ("fused", "spmm"): ("pallas", "interpret"),
}


@dataclasses.dataclass
class FallbackPolicy:
    """Which chain each ``(part, op)`` walks, and whether chains are live."""

    enabled: bool = True
    chains: Dict[Tuple[str, str], Tuple[str, ...]] = dataclasses.field(
        default_factory=lambda: dict(CHAIN_OVERRIDES))

    def chain_for(self, part: str, op: str, backend: str) -> Tuple[str, ...]:
        """The chain starting at the caller's resolved ``backend`` — a
        caller already on a degraded link never climbs back up."""
        if not self.enabled:
            return (backend,)
        chain = self.chains.get((part, op), DEFAULT_CHAIN)
        if backend in chain:
            return chain[chain.index(backend):]
        return (backend,)


_POLICY = FallbackPolicy(
    enabled=os.environ.get("REPRO_NO_FALLBACK", "") not in ("1", "true"))


def get_policy() -> FallbackPolicy:
    return _POLICY


def set_policy(policy: FallbackPolicy) -> FallbackPolicy:
    """Install ``policy`` process-wide; returns the previous one."""
    global _POLICY
    prev, _POLICY = _POLICY, policy
    return prev


@contextlib.contextmanager
def disabled():
    """Temporarily make every chain single-link (failures propagate) —
    the test-facing form of ``REPRO_NO_FALLBACK``."""
    prev = set_policy(FallbackPolicy(enabled=False))
    try:
        yield
    finally:
        set_policy(prev)


def classify(exc: BaseException) -> str:
    """Compact counter-label reason for a failure."""
    if isinstance(exc, InjectedTimeout):
        return "timeout"
    if isinstance(exc, InjectedFault):
        return "injected"
    if isinstance(exc, TimeoutError):
        return "timeout"
    return type(exc).__name__


def run_chain(part: str, op: str, backend: str,
              attempt: Callable[[str], object], *, site: str | None = None):
    """Walk the ``(part, op)`` chain from ``backend``: call
    ``attempt(link)`` per link, degrading on any exception with an
    ``engine.fallback`` counter; re-raise the last failure when the chain
    is exhausted.  Each attempt passes through a
    ``engine.{part}.{op}.{link}`` fault point first (the chaos harness
    fails *attempts*, so an injected first-link fault proves the
    degradation end-to-end)."""
    site = site or f"engine.{part}.{op}"
    chain = get_policy().chain_for(part, op, backend)
    last_exc: BaseException | None = None
    for i, link in enumerate(chain):
        if i:
            note_degraded("engine.fallback", part=part, op=op,
                          reason=classify(last_exc))
        try:
            fault_point(f"{site}.{link}")
            return attempt(link)
        except Exception as e:        # noqa: BLE001 - the chain IS the handler
            last_exc = e
    raise last_exc


class DeadlineExceeded(TimeoutError):
    """A retried call ran out of its deadline budget."""


def retry_with_backoff(fn: Callable, *args, retries: int = 2,
                       backoff_s: float = 0.01, deadline_s: float | None = None,
                       on_retry: Callable[[int, BaseException], None] | None
                       = None, **kwargs):
    """Call ``fn(*args, **kwargs)``; on failure retry up to ``retries``
    times with exponential backoff (``backoff_s`` doubling per attempt).

    ``deadline_s`` bounds the *total* wall clock: a retry that cannot start
    before the deadline raises :class:`DeadlineExceeded` from the last
    failure instead of sleeping past it.  ``on_retry(attempt, exc)`` fires
    before each backoff sleep — the serving driver counts degradations
    there.
    """
    t0 = time.perf_counter()
    delay = backoff_s
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except Exception as e:        # noqa: BLE001 - retry IS the handler
            attempt += 1
            if attempt > retries:
                raise
            if deadline_s is not None \
                    and time.perf_counter() - t0 + delay > deadline_s:
                raise DeadlineExceeded(
                    f"deadline {deadline_s:.3f}s exhausted after "
                    f"{attempt} attempt(s)") from e
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(delay)
            delay *= 2
