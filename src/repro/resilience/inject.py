"""Seeded, site-addressable fault injection — the chaos harness.

Every resilience seam in the codebase calls :func:`fault_point` with a
stable **site name** before doing the real work; with no active
:class:`FaultPlan` the call is a cheap no-op, so production paths pay one
attribute read.  A plan (installed via :func:`set_plan` or parsed from the
``REPRO_FAULT_PLAN`` env var by :func:`install_from_env`) makes selected
sites fail deterministically — the tests and the CI chaos smoke use this to
*prove* each documented fallback actually fires.

Site naming (see docs/robustness.md for the full registry):

  * ``engine.{part}.{op}.{backend}`` — one per kernel-dispatch attempt
    (``engine.csr.spmm.interpret``, ``engine.fused.spmm.pallas``, ...);
  * ``cache.read`` — plan-cache file parse (payload: the raw bytes);
  * ``tune.trial`` — one tuner measurement trial;
  * ``dist.psum.{precision}`` — one ``compressed_psum`` call site;
  * ``serve.step`` / ``serve.prefill`` / ``train.step`` — host-level step
    calls (the retry/deadline wrappers cover these);
  * ``ingest.serve.weights`` — serving weight ingestion (payload: the
    dense weight array; ``nan-values`` corrupts it).

Plan syntax (``;``-separated clauses, glob site match)::

    REPRO_FAULT_PLAN='engine.*.interpret:raise:0;cache.read:corrupt-bytes:0:0'
    #                 site-glob          kind  nth[:count]

``kind`` ∈ {``raise``, ``timeout``, ``corrupt-bytes``, ``nan-values``}.
``nth`` (default 0) is the first per-site call index that fires; ``count``
(default 1) is how many consecutive calls fire — ``0`` means *every* call
from ``nth`` on (needed when the consumer retries reads).  A leading
``seed=N`` clause seeds the value-corruption kinds; everything else is a
per-site call counter, so a plan is bit-deterministic across runs.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import os
import threading
import zlib
from typing import Dict, Optional, Tuple

__all__ = ["FaultClause", "FaultPlan", "InjectedFault", "InjectedTimeout",
           "fault_point", "set_plan", "get_plan", "install_from_env",
           "note_degraded"]

KINDS = ("raise", "timeout", "corrupt-bytes", "nan-values")

ENV_VAR = "REPRO_FAULT_PLAN"


class InjectedFault(RuntimeError):
    """An injected failure (the ``raise`` / payload-less kinds)."""

    def __init__(self, site: str, kind: str):
        super().__init__(f"injected fault at {site!r} (kind={kind})")
        self.site = site
        self.kind = kind


class InjectedTimeout(InjectedFault):
    """An injected deadline overrun (classified as ``timeout``)."""


@dataclasses.dataclass(frozen=True)
class FaultClause:
    """One ``site-glob:kind[:nth[:count]]`` clause of a plan."""

    site: str         # fnmatch glob over site names ('*' crosses dots)
    kind: str         # one of KINDS
    nth: int = 0      # first per-site call index that fires
    count: int = 1    # consecutive firing calls; 0 = every call from nth

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")

    def fires(self, n: int) -> bool:
        if n < self.nth:
            return False
        return self.count == 0 or n < self.nth + self.count


@dataclasses.dataclass
class FaultPlan:
    """A deterministic set of fault clauses plus per-site call counters."""

    clauses: Tuple[FaultClause, ...]
    seed: int = 0
    _calls: Dict[str, int] = dataclasses.field(default_factory=dict)
    _lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the ``REPRO_FAULT_PLAN`` syntax (see module docstring)."""
        clauses, seed = [], 0
        for raw in spec.split(";"):
            raw = raw.strip()
            if not raw:
                continue
            if raw.startswith("seed="):
                seed = int(raw[len("seed="):])
                continue
            parts = raw.split(":")
            if len(parts) < 2:
                raise ValueError(f"bad fault clause {raw!r}: expected "
                                 "site:kind[:nth[:count]]")
            site, kind = parts[0], parts[1]
            nth = int(parts[2]) if len(parts) > 2 else 0
            count = int(parts[3]) if len(parts) > 3 else 1
            clauses.append(FaultClause(site=site, kind=kind, nth=nth,
                                       count=count))
        return cls(clauses=tuple(clauses), seed=seed)

    def reset(self) -> None:
        """Zero all per-site call counters (fresh run under the same plan)."""
        with self._lock:
            self._calls.clear()

    def match(self, site: str) -> Optional[FaultClause]:
        """Count one call at ``site``; return the clause that fires, if any."""
        with self._lock:
            n = self._calls.get(site, 0)
            self._calls[site] = n + 1
        for c in self.clauses:
            if fnmatch.fnmatchcase(site, c.site) and c.fires(n):
                return c
        return None


# Process-wide active plan (None = injection disabled, the production state).
_ACTIVE_PLAN: Optional[FaultPlan] = None


def set_plan(plan: Optional[FaultPlan]) -> Optional[FaultPlan]:
    """Install ``plan`` as the process-wide fault plan (None disables);
    returns the previous plan so callers (tests) can restore it."""
    global _ACTIVE_PLAN
    prev, _ACTIVE_PLAN = _ACTIVE_PLAN, plan
    return prev


def get_plan() -> Optional[FaultPlan]:
    return _ACTIVE_PLAN


def install_from_env(environ=None) -> Optional[FaultPlan]:
    """Install a plan from ``$REPRO_FAULT_PLAN`` if set (launch drivers call
    this at startup so the chaos CI can steer a whole run)."""
    spec = (environ or os.environ).get(ENV_VAR)
    if not spec:
        return None
    plan = FaultPlan.parse(spec)
    set_plan(plan)
    return plan


def _site_seed(plan: FaultPlan, site: str) -> int:
    return plan.seed ^ zlib.crc32(site.encode("utf-8"))


def _corrupt_bytes(payload: bytes, seed: int) -> bytes:
    """Deterministically mangle a byte payload: truncate to ~half and
    overwrite a seed-chosen window — reliably unparseable JSON, never
    accidentally valid."""
    data = bytearray(payload[: max(len(payload) // 2, 1)])
    if data:
        start = seed % len(data)
        for i in range(start, min(start + 8, len(data))):
            data[i] = 0xFF
    return bytes(data)


def _corrupt_nans(payload, seed: int):
    """Seed-chosen positions of an array payload become NaN (works on both
    numpy arrays and traced jax arrays — ``.at[].set`` on the latter)."""
    import numpy as np
    size = 1
    for d in payload.shape:
        size *= int(d)
    if size == 0:
        return payload
    rng = np.random.default_rng(seed)
    idx = rng.choice(size, size=max(size // 16, 1), replace=False)
    if isinstance(payload, np.ndarray):
        flat = payload.astype(payload.dtype, copy=True).reshape(-1)
        flat[idx] = np.nan
        return flat.reshape(payload.shape)
    import jax.numpy as jnp
    flat = jnp.reshape(payload, (-1,)).at[idx].set(jnp.nan)
    return jnp.reshape(flat, payload.shape)


def fault_point(site: str, payload=None):
    """The injection seam: returns ``payload`` (possibly corrupted), or
    raises :class:`InjectedFault` / :class:`InjectedTimeout` when the active
    plan has a firing clause for ``site``.  No active plan → pure
    pass-through."""
    plan = _ACTIVE_PLAN
    if plan is None:
        return payload
    clause = plan.match(site)
    if clause is None:
        return payload
    note_degraded("inject.fired", site=site, kind=clause.kind)
    if clause.kind == "raise":
        raise InjectedFault(site, "raise")
    if clause.kind == "timeout":
        raise InjectedTimeout(site, "timeout")
    if payload is None:
        # A value-corruption clause on a payload-less site degenerates to a
        # raise — there is nothing to corrupt, but the plan asked for a fault.
        raise InjectedFault(site, clause.kind)
    if clause.kind == "corrupt-bytes":
        return _corrupt_bytes(bytes(payload), _site_seed(plan, site))
    return _corrupt_nans(payload, _site_seed(plan, site))


def note_degraded(metric: str, n: float = 1.0, **labels) -> None:
    """Record a degradation event on the active obs capture (no-op without
    one — the resilience layer must never *require* observability).  Lazy
    import mirrors ``dist.compress._note_bytes``."""
    try:
        from ..obs.runtime import get_active
    except ImportError:     # pragma: no cover - obs is part of the tree
        return
    obs = get_active()
    if obs is not None:
        obs.counter(metric, **labels).inc(n)
