"""Validated sparse ingestion: defect taxonomy, strict mode, repair mode.

A malformed CSR (non-monotone ``indptr``, out-of-range or negative column
indices, NaN/Inf stored values, mismatched array lengths) must never reach
the conversion pipeline silently — Algorithm 1 and the kernels index with
it.  This module is the one gate:

  * **strict** (``repair=None``): raise :class:`SparseInputError` carrying
    the first defect's ``kind`` from a fixed taxonomy (the order below), so
    callers and tests can branch on *what* was wrong;
  * **repair** (``repair="drop"`` / ``"clip"``): fix the input — drop (or
    clip/zero) offending entries, rebuild monotone ``indptr`` by running
    maximum — and record every fix on the active obs capture as
    ``validate.repaired{defect,mode}`` counters.

Taxonomy (``SparseInputError.kind``), checked in this order::

    shape-mismatch        bad shape tuple / row_ptr length != nrows+1
    dtype-mismatch        non-integer index arrays or non-numeric values
    length-mismatch       col_idx and vals lengths disagree
    nonmonotone-indptr    decreasing / negative / wrong head or tail
    negative-index        row or column index < 0
    out-of-range-index    row or column index >= extent
    nonfinite-value       NaN or Inf stored value

Wired into :func:`repro.core.formats.csr_from_coo` (strict by default —
the satellite fix for silently corrupt COO coordinates),
:func:`repro.core.spmm.plan_and_convert`, and the serve/train launch paths.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from .inject import note_degraded

__all__ = ["SparseInputError", "ValidationReport", "DEFECT_KINDS",
           "csr_defects", "validate_coo", "validate_csr", "validate_loops",
           "check_finite_tree"]

DEFECT_KINDS = ("shape-mismatch", "dtype-mismatch", "length-mismatch",
                "nonmonotone-indptr", "negative-index",
                "out-of-range-index", "nonfinite-value")

REPAIR_MODES = ("drop", "clip")


class SparseInputError(ValueError):
    """A classified ingestion defect (``kind`` ∈ :data:`DEFECT_KINDS`)."""

    def __init__(self, kind: str, message: str):
        assert kind in DEFECT_KINDS, kind
        super().__init__(f"[{kind}] {message}")
        self.kind = kind


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    """What a validation pass found and (in repair mode) fixed."""

    defects: Tuple[str, ...] = ()          # kinds found, taxonomy order
    repaired: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.defects


def _check_repair(repair: Optional[str]) -> None:
    if repair is not None and repair not in REPAIR_MODES:
        raise ValueError(f"unknown repair mode {repair!r}; expected None, "
                         f"'drop' or 'clip'")


def _numeric_dtype(dt: np.dtype) -> bool:
    """True for any dtype the kernels can store values in: native
    int/uint/float/bool plus extension floats (ml_dtypes bfloat16 / fp8
    register as numpy kind ``'V'`` yet cast cleanly through float32)."""
    if dt.kind in "iufb":
        return True
    if dt.kind == "V" and dt.names is None:
        try:
            np.zeros((), dt).astype(np.float32)
            return True
        except (TypeError, ValueError):
            return False
    return False


def _finite_mask(vals: np.ndarray) -> np.ndarray:
    """Per-entry finiteness, robust to extension float dtypes (ml_dtypes
    bfloat16 lacks a native isfinite ufunc — promote through float32)."""
    if vals.dtype.kind in "iub":
        return np.ones(vals.shape, bool)
    try:
        return np.isfinite(vals)
    except TypeError:
        return np.isfinite(vals.astype(np.float32))


def _note_repairs(repaired: Dict[str, int], mode: str) -> None:
    for kind, n in repaired.items():
        if n:
            note_degraded("validate.repaired", n=float(n), defect=kind,
                          mode=mode)


# ---------------------------------------------------------------------------
# COO
# ---------------------------------------------------------------------------

def validate_coo(rows, cols, vals, shape, *, repair: Optional[str] = None):
    """Validate (and optionally repair) COO triplets against ``shape``.

    Returns ``(rows, cols, vals, report)`` — in strict mode the arrays pass
    through untouched or a :class:`SparseInputError` raises; in repair mode
    offending entries are dropped (``"drop"``) or clipped into range with
    nonfinite values zeroed (``"clip"``).
    """
    _check_repair(repair)
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    if len(shape) != 2 or shape[0] < 0 or shape[1] < 0:
        raise SparseInputError("shape-mismatch", f"bad matrix shape {shape}")
    if rows.dtype.kind not in "iu" or cols.dtype.kind not in "iu":
        if repair is None:
            raise SparseInputError(
                "dtype-mismatch", "COO coordinates must be integer arrays; "
                f"got rows={rows.dtype} cols={cols.dtype}")
        rows, cols = rows.astype(np.int64), cols.astype(np.int64)
    if not (rows.shape == cols.shape == vals.shape) or rows.ndim != 1:
        raise SparseInputError(
            "length-mismatch", "COO triplet arrays must be equal-length 1-D; "
            f"got rows={rows.shape} cols={cols.shape} vals={vals.shape}")
    rows = rows.astype(np.int64)
    cols = cols.astype(np.int64)

    neg = (rows < 0) | (cols < 0)
    oob = (rows >= shape[0]) | (cols >= shape[1])
    nonfin = ~_finite_mask(vals)
    if repair is None:
        if neg.any():
            k = int(np.flatnonzero(neg)[0])
            raise SparseInputError(
                "negative-index", f"COO entry {k} has negative coordinate "
                f"({int(rows[k])}, {int(cols[k])})")
        if oob.any():
            k = int(np.flatnonzero(oob)[0])
            raise SparseInputError(
                "out-of-range-index", f"COO entry {k} at "
                f"({int(rows[k])}, {int(cols[k])}) exceeds shape {shape}")
        if nonfin.any():
            k = int(np.flatnonzero(nonfin)[0])
            raise SparseInputError(
                "nonfinite-value", f"COO entry {k} has nonfinite value "
                f"{vals[k]!r}")
        return rows, cols, vals, ValidationReport()

    repaired = {"negative-index": int(neg.sum()),
                "out-of-range-index": int((oob & ~neg).sum()),
                "nonfinite-value": int(nonfin.sum())}
    defects = tuple(k for k in DEFECT_KINDS if repaired.get(k))
    if repair == "drop":
        keep = ~(neg | oob | nonfin)
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
    else:
        rows = np.clip(rows, 0, max(shape[0] - 1, 0))
        cols = np.clip(cols, 0, max(shape[1] - 1, 0))
        vals = np.where(nonfin, np.zeros((), vals.dtype), vals)
    _note_repairs(repaired, repair)
    return rows, cols, vals, ValidationReport(defects=defects,
                                              repaired=repaired)


# ---------------------------------------------------------------------------
# CSR
# ---------------------------------------------------------------------------

def csr_defects(row_ptr, col_idx, vals, shape) -> Tuple[str, ...]:
    """Classify every defect of raw CSR arrays (taxonomy order, no repair,
    no exception) — the shared detector behind strict and repair modes."""
    row_ptr = np.asarray(row_ptr)
    col_idx = np.asarray(col_idx)
    vals = np.asarray(vals)
    found = []
    if len(shape) != 2 or shape[0] < 0 or shape[1] < 0 \
            or row_ptr.ndim != 1 or row_ptr.shape[0] != shape[0] + 1:
        found.append("shape-mismatch")
    if row_ptr.dtype.kind not in "iu" or col_idx.dtype.kind not in "iu" \
            or not _numeric_dtype(vals.dtype):
        found.append("dtype-mismatch")
    if col_idx.shape != vals.shape or col_idx.ndim != 1:
        found.append("length-mismatch")
    nnz = int(col_idx.shape[0]) if col_idx.ndim == 1 else -1
    if row_ptr.ndim == 1 and row_ptr.shape[0] >= 1 \
            and row_ptr.dtype.kind in "iu":
        ptr = row_ptr.astype(np.int64)
        if (np.diff(ptr) < 0).any() or ptr[0] != 0 \
                or (nnz >= 0 and ptr[-1] != nnz) or (ptr < 0).any():
            found.append("nonmonotone-indptr")
    if col_idx.dtype.kind in "iu" and col_idx.ndim == 1:
        if (col_idx.astype(np.int64) < 0).any():
            found.append("negative-index")
        if (col_idx.astype(np.int64) >= shape[1]).any():
            found.append("out-of-range-index")
    if _numeric_dtype(vals.dtype) and not _finite_mask(vals).all():
        found.append("nonfinite-value")
    return tuple(k for k in DEFECT_KINDS if k in found)


def validate_csr(csr, *, repair: Optional[str] = None):
    """Validate (and optionally repair) a :class:`repro.core.formats.CSR`.

    Returns ``(csr, report)``.  Strict mode raises
    :class:`SparseInputError` with the first defect's kind.  Repair mode
    returns a rebuilt CSR: the indptr is made monotone (running maximum,
    clamped to ``[0, nnz]``), then offending entries are dropped
    (``"drop"``) or column-clipped with nonfinite values zeroed
    (``"clip"``); every fix lands in ``validate.repaired`` counters.
    Structural defects the entry repairs cannot express (wrong array
    lengths, bad shapes, non-integer indices) raise in both modes.
    """
    _check_repair(repair)
    defects = csr_defects(csr.row_ptr, csr.col_idx, csr.vals, csr.shape)
    if not defects:
        return csr, ValidationReport()
    unrepairable = [k for k in defects if k in
                    ("shape-mismatch", "dtype-mismatch", "length-mismatch")]
    if repair is None or unrepairable:
        kind = unrepairable[0] if unrepairable else defects[0]
        raise SparseInputError(kind, f"CSR{csr.shape} failed validation: "
                               f"defects={list(defects)}")

    from ..core.formats import _csr_from_arrays
    nnz = int(csr.col_idx.shape[0])
    ptr = csr.row_ptr.astype(np.int64)
    repaired: Dict[str, int] = {}
    if "nonmonotone-indptr" in defects:
        fixed = np.clip(np.maximum.accumulate(np.clip(ptr, 0, nnz)), 0, nnz)
        fixed[0], fixed[-1] = 0, nnz
        fixed = np.maximum.accumulate(fixed)
        repaired["nonmonotone-indptr"] = int((fixed != ptr).sum())
        ptr = fixed
    col = csr.col_idx.astype(np.int64)
    vals = np.asarray(csr.vals)
    neg = col < 0
    oob = col >= csr.shape[1]
    nonfin = ~_finite_mask(vals)
    repaired.update({"negative-index": int(neg.sum()),
                     "out-of-range-index": int(oob.sum()),
                     "nonfinite-value": int(nonfin.sum())})
    if repair == "drop":
        keep = ~(neg | oob | nonfin)
        row_ids = np.repeat(np.arange(csr.shape[0], dtype=np.int64),
                            np.diff(ptr))
        counts = np.bincount(row_ids[keep], minlength=csr.shape[0])
        new_ptr = np.zeros(csr.shape[0] + 1, np.int64)
        np.cumsum(counts, out=new_ptr[1:])
        ptr, col, vals = new_ptr, col[keep], vals[keep]
    else:
        col = np.clip(col, 0, max(csr.shape[1] - 1, 0))
        vals = np.where(nonfin, np.zeros((), vals.dtype), vals)
    _note_repairs(repaired, repair)
    out = _csr_from_arrays(ptr, col, vals, csr.shape)
    return out, ValidationReport(defects=defects,
                                 repaired={k: v for k, v in repaired.items()
                                           if v})


# ---------------------------------------------------------------------------
# LOOPS hybrid format
# ---------------------------------------------------------------------------

def validate_loops(fmt, *, what: str = "LoopsFormat") -> ValidationReport:
    """Strict structural validation of a converted
    :class:`repro.core.formats.LoopsFormat` (both parts) — raises
    :class:`SparseInputError`; repair belongs upstream (re-run the
    conversion on a repaired CSR)."""
    if not 0 <= fmt.r_boundary <= fmt.nrows:
        raise SparseInputError(
            "shape-mismatch", f"{what}: r_boundary={fmt.r_boundary} outside "
            f"[0, {fmt.nrows}]")
    defects = csr_defects(fmt.csr_part.row_ptr, fmt.csr_part.col_idx,
                          fmt.csr_part.vals, fmt.csr_part.shape)
    if defects:
        raise SparseInputError(defects[0],
                               f"{what}: CSR part failed: {list(defects)}")
    bc = fmt.bcsr_part
    if bc.br <= 0:
        raise SparseInputError("shape-mismatch",
                               f"{what}: BCSR br={bc.br} must be positive")
    bp = np.asarray(bc.block_ptr, np.int64)
    if bp.shape[0] != bc.nblocks + 1 or bp[0] != 0 or bp[-1] != bc.ntiles \
            or (np.diff(bp) < 0).any():
        raise SparseInputError("nonmonotone-indptr",
                               f"{what}: BCSR block_ptr is inconsistent")
    tr = np.asarray(bc.tile_rows, np.int64)
    tc = np.asarray(bc.tile_cols, np.int64)
    if (np.diff(tr) < 0).any():
        raise SparseInputError("nonmonotone-indptr",
                               f"{what}: BCSR tile_rows must be nondecreasing")
    if (tr < 0).any() or (tc < 0).any():
        raise SparseInputError("negative-index",
                               f"{what}: negative BCSR tile coordinate")
    if (tr >= max(bc.nblocks, 1)).any() or (tc >= bc.ncols).any():
        raise SparseInputError("out-of-range-index",
                               f"{what}: BCSR tile coordinate out of range")
    if bc.tile_vals.shape != (bc.ntiles, bc.br):
        raise SparseInputError(
            "length-mismatch", f"{what}: tile_vals shape "
            f"{bc.tile_vals.shape} != (ntiles={bc.ntiles}, br={bc.br})")
    if not _finite_mask(np.asarray(bc.tile_vals)).all():
        raise SparseInputError("nonfinite-value",
                               f"{what}: nonfinite BCSR tile value")
    return ValidationReport()


# ---------------------------------------------------------------------------
# parameter trees (checkpoint-restore ingestion)
# ---------------------------------------------------------------------------

def check_finite_tree(tree, *, what: str = "params") -> int:
    """Raise ``SparseInputError('nonfinite-value')`` if any array leaf of a
    pytree holds NaN/Inf (a corrupt checkpoint restore must fail loudly at
    ingestion, not as diverging loss ten steps later).  Returns the number
    of leaves checked."""
    import jax
    leaves = [x for x in jax.tree_util.tree_leaves(tree)
              if hasattr(x, "dtype")]
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        if not _finite_mask(arr).all():
            raise SparseInputError(
                "nonfinite-value",
                f"{what}: leaf {i} of {len(leaves)} (shape "
                f"{tuple(arr.shape)}) holds nonfinite values")
    return len(leaves)
