"""repro.resilience — degrade, don't crash.

The LOOPS design always has a correct slower path for any matrix (the jnp
oracle at the bottom of every chain); this package makes the system
actually take it under faults instead of dying.  Four pillars, threaded
through formats / engine / tune / dist / serving (docs/robustness.md):

  * :mod:`~repro.resilience.validate` — validated ingestion: the
    :class:`SparseInputError` defect taxonomy, strict and repair modes;
  * :mod:`~repro.resilience.fallback` — engine fallback chains
    (``pallas → interpret → jnp``), tuner trial isolation support, and the
    host-side :func:`retry_with_backoff`;
  * :mod:`~repro.resilience.inject` — seeded, site-addressable fault
    injection (:class:`FaultPlan` / ``$REPRO_FAULT_PLAN``): the chaos
    harness that proves every fallback fires;
  * degraded-mode serving lives in :mod:`repro.launch.serve` on top of the
    pieces above (plan-on-miss policy, per-step deadlines and retries).

Every degradation is visible: ``engine.fallback``, ``serve.degraded``,
``tune.cache.quarantined``, ``tune.search.trial_failed``, ``dist.fallback``
and ``validate.repaired`` counters land on the active obs capture
(:func:`note_degraded`), rendered by ``tools/obs_report.py``'s
Degradations section and gated in CI by ``--fail-on-degraded``.
"""
from .fallback import (DEFAULT_CHAIN, DeadlineExceeded, FallbackPolicy,
                       classify, disabled, get_policy, retry_with_backoff,
                       run_chain, set_policy)
from .inject import (FaultClause, FaultPlan, InjectedFault, InjectedTimeout,
                     fault_point, get_plan, install_from_env, note_degraded,
                     set_plan)
from .validate import (DEFECT_KINDS, SparseInputError, ValidationReport,
                       check_finite_tree, csr_defects, validate_coo,
                       validate_csr, validate_loops)

__all__ = [
    "DEFAULT_CHAIN", "DeadlineExceeded", "FallbackPolicy", "classify",
    "disabled", "get_policy", "retry_with_backoff", "run_chain",
    "set_policy",
    "FaultClause", "FaultPlan", "InjectedFault", "InjectedTimeout",
    "fault_point", "get_plan", "install_from_env", "note_degraded",
    "set_plan",
    "DEFECT_KINDS", "SparseInputError", "ValidationReport",
    "check_finite_tree", "csr_defects", "validate_coo", "validate_csr",
    "validate_loops",
]
