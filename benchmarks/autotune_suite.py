"""Autotune suite: model-only planning vs measured, cached plans.

For each Table-2-like matrix, plan twice:

  * **model-only** — ``plan_and_convert`` exactly as every call site did
    before the tuner existed (hand-set ``total_workers=8``, proportional
    split, Eq. 1 boundary);
  * **tuned** — ``repro.tune.autotune`` (budgeted search on first sight,
    fingerprint-keyed cache thereafter),

then time the hybrid execution of both plans and report throughputs side by
side.  A second pass over the same matrices demonstrates the amortisation
claim: every lookup is a cache hit, zero measurements, and the hit rate is
printed as its own CSV row and recorded into bench.json (the
``autotune_cache_record`` columns of ``bench_schema.json``) so the perf
trajectory tracks cache effectiveness alongside throughput.

The cache lives in a temp directory by default so benchmark runs are
hermetic; set ``REPRO_TUNE_CACHE`` to persist plans across runs instead.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import loops_spmm, plan_and_convert, suite
from repro.tune import PlanCache, SearchBudget, autotune

from ._util import csv_row, gflops, time_fn

N = 32  # paper fixes N=32
MATRICES = ["m6", "m9", "m10", "m12", "m13", "m16", "m17"]


def _throughput(fmt, b, nnz: int) -> float:
    f = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))
    return gflops(nnz, N, time_fn(f, b, repeats=5, warmup=1))


def main(out=print, scale_rows: int = 512, record=None):
    cache_dir = os.environ.get("REPRO_TUNE_CACHE") or tempfile.mkdtemp(
        prefix="repro-tune-bench-")
    cache = PlanCache(cache_dir)
    budget = SearchBudget(top_k=4, repeats=3, warmup=1)
    rng = np.random.default_rng(0)

    mats = {mid: suite.table2_like(mid, scale_rows=scale_rows, seed=3)
            for mid in MATRICES}
    speedups = []
    for mid, csr in mats.items():
        b = jnp.asarray(rng.standard_normal((csr.shape[1], N)),
                        jnp.float32)
        fmt_model, plan_model = plan_and_convert(csr, total_workers=8)
        fmt_tuned, plan_tuned = autotune(csr, n_cols=N, cache=cache,
                                         budget=budget, backend="jnp")
        g_model = _throughput(fmt_model, b, csr.nnz)
        g_tuned = _throughput(fmt_tuned, b, csr.nnz)
        speedups.append(g_tuned / g_model)
        out(csv_row(
            f"autotune_{mid}_{suite.TABLE2_STATS[mid].name}", 0.0,
            f"GFLOPS_model={g_model:.2f};GFLOPS_tuned={g_tuned:.2f};"
            f"speedup={g_tuned / g_model:.2f}x;"
            f"plan_model=r{plan_model.r_boundary}b{plan_model.br};"
            f"plan_tuned=r{plan_tuned.r_boundary}b{plan_tuned.br}"))

    # Second pass: the amortisation claim — all hits, no measurement.
    before = cache.stats.misses
    for mid, csr in mats.items():
        autotune(csr, n_cols=N, cache=cache, budget=budget, backend="jnp")
    assert cache.stats.misses == before, "second pass must not search"
    sp = np.asarray(speedups)
    geomean = float(np.exp(np.log(sp).mean()))
    out(csv_row("autotune_geomean", 0.0, f"tuned_vs_model={geomean:.2f}x"))
    out(csv_row("autotune_cache", 0.0,
                f"hits={cache.stats.hits};near={cache.stats.near_hits};"
                f"misses={cache.stats.misses};"
                f"hit_rate={cache.stats.hit_rate:.2f};"
                f"stored={len(cache)}"))
    if record is not None:
        # bench.json row (schema: autotune_cache_record) — the hit-rate
        # columns the perf trajectory tracks alongside the CSV.
        record({"suite": "autotune", "matrix": "cache",
                "hits": cache.stats.hits, "near_hits": cache.stats.near_hits,
                "misses": cache.stats.misses,
                "hit_rate": round(cache.stats.hit_rate, 4),
                "stored": len(cache),
                "tuned_vs_model_geomean": round(geomean, 4)})


if __name__ == "__main__":
    main()
