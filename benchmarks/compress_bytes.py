import os
if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    # Standalone CLI only — must precede the jax import.  Under
    # benchmarks/run.py the runtime is already initialised; main() then
    # skip-records unless 16 devices are actually available.
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"

"""Quantifies the int8 gradient-compression trick (dist/compress.py):
collective operand bytes of a fp32 ``psum`` vs ``compressed_psum`` for a
gradient-sized block on a 16-device axis, measured from optimized HLO.

Registered in benchmarks/run.py as suite ``compress_bytes``; needs a
16-device platform (``python -m benchmarks.compress_bytes`` forces one),
otherwise emits a schema'd skip record."""
import functools

import jax
import jax.numpy as jnp

from repro.compat import make_mesh, shard_map
from repro.dist.compress import compressed_psum
from repro.perf.hlo_analysis import analyze_hlo

from ._util import csv_row


def main(out=print, record=None):
    if jax.device_count() < 16:
        reason = (f"needs 16 devices for the compression-axis mesh, have "
                  f"{jax.device_count()}; run standalone: "
                  "python -m benchmarks.compress_bytes")
        out(csv_row("compress_bytes_SKIPPED", 0.0, reason))
        if record is not None:
            record({"suite": "compress_bytes", "skipped": True,
                    "reason": reason})
        return
    mesh = make_mesh((16,), ("d",))
    n = 1 << 22  # 4M fp32 grads per device (a ~16M-param shard)
    x = jax.ShapeDtypeStruct((16, n), jnp.float32)
    from jax.sharding import PartitionSpec as P

    def bytes_of(fn):
        f = shard_map(fn, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
        c = jax.jit(f).lower(x).compile()
        return analyze_hlo(c.as_text()).collective_bytes

    b_fp32 = bytes_of(lambda xs: jax.lax.psum(xs[0], "d")[None])
    b_bf16 = bytes_of(lambda xs: compressed_psum(xs[0], "d", "bf16")[None])
    b_int8 = bytes_of(lambda xs: compressed_psum(xs[0], "d")[None])
    out(csv_row("compress_psum_fp32_bytes", 0.0, f"{b_fp32:.3e}"))
    out(csv_row("compress_psum_bf16_bytes", 0.0,
                f"{b_bf16:.3e};reduction={b_fp32 / max(b_bf16, 1):.2f}x"))
    out(csv_row("compress_psum_int8_bytes", 0.0,
                f"{b_int8:.3e};reduction={b_fp32 / max(b_int8, 1):.2f}x"))


if __name__ == "__main__":
    main()
