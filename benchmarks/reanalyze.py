"""Re-run the HLO analyzer over cached .hlo.txt dry-run artifacts and update
the JSON records in place (analyzer improvements shouldn't need recompiles)."""
import glob
import json
import os
import sys

from repro.perf.hlo_analysis import analyze_hlo

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def main():
    n = 0
    for jpath in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        hpath = jpath.replace(".json", ".hlo.txt")
        if not os.path.exists(hpath):
            continue
        rec = json.load(open(jpath))
        st = analyze_hlo(open(hpath).read())
        rec["hlo"].update({
            "flops_per_device": st.flops,
            "hbm_bytes_per_device": st.hbm_bytes,
            "collective_bytes_per_device": st.collective_bytes,
            "collective_by_kind": st.collective_by_kind,
            "unknown_trip_loops": st.unknown_trip_loops,
        })
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        n += 1
    print(f"reanalyzed {n} cells")


if __name__ == "__main__":
    main()
