"""Paper Table 3: energy efficiency (GFLOPS/W), modeled.

No power rails exist in this container, so energy is MODELED as
``roofline_time x chip_power`` for the TPU-v5e target (170 W/chip) and the
measured-on-CPU proxy time for reference.  The A100 comparison column quotes
the paper's own Table 3 measurements (cuSparse FP16) — reproduced verbatim as
the comparison target, clearly labeled paper-reported.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csr_from_dense, csr_to_dense, loops_spmm, \
    plan_and_convert, suite

from ._util import (A100_POWER_W, CHIP_POWER_W, HBM_BW, PEAK_FLOPS_BF16,
                    csv_row, gflops, time_fn)

N = 32
# (id, paper A100 cuSparse eff GFLOPS/W, paper M4Pro LOOPS eff GFLOPS/W)
PAPER_TABLE3 = [
    ("m6", 2.30, 23.08), ("m8", 2.87, 84.70), ("m14", 2.69, 71.36),
    ("m17", 0.86, 8.53), ("m13", 1.70, 2.56), ("m10", 1.36, 2.76),
]


def main(out=print):
    rng = np.random.default_rng(2)
    for mid, a100_eff, m4_eff in PAPER_TABLE3:
        csr32 = suite.table2_like(mid, scale_rows=1024, seed=6)
        dense16 = jnp.asarray(csr_to_dense(csr32), jnp.bfloat16)
        csr = csr_from_dense(np.asarray(dense16))
        b = jnp.asarray(rng.standard_normal((csr.shape[1], N)), jnp.bfloat16)
        fmt, _ = plan_and_convert(csr, total_workers=8)
        f = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))
        t_cpu = time_fn(f, b, repeats=5)
        flops = 2.0 * csr.nnz * N
        # roofline-time model on one v5e chip: memory-bound SpMM
        bytes_moved = (csr.nnz * 2          # A values (bf16)
                       + csr.nnz * 4        # indices
                       + csr.nnz * N * 2    # B rows gathered per nnz (worst)
                       + csr.shape[0] * N * 4)  # C write (f32)
        t_model = max(flops / PEAK_FLOPS_BF16, bytes_moved / HBM_BW)
        eff_model = flops / t_model / CHIP_POWER_W / 1e9
        out(csv_row(f"table3_{mid}_{suite.TABLE2_STATS[mid].name}",
                    t_cpu * 1e6,
                    f"modeled_v5e_eff_GFLOPSperW={eff_model:.2f};"
                    f"paper_A100_cuSparse={a100_eff};paper_M4Pro={m4_eff};"
                    f"modeled_vs_A100={eff_model / a100_eff:.1f}x"))


if __name__ == "__main__":
    main()
