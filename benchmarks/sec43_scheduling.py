"""Paper §4.3: effectiveness of adaptive scheduling — LOOPS (perf-model
driven hybrid) vs pure-vector (r_b = nrows) vs pure-matrix (r_b = 0)
across sparsity patterns, reporting how often the adaptive choice wins
(paper: best on 83.3% of SuiteSparse)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import loops_from_csr, loops_spmm, plan_and_convert, suite
from repro.core.perf_model import calibrate

from ._util import csv_row, time_fn

N = 32
CASES = [  # name -> generator exercising a distinct regime
    ("banded", lambda: suite.banded(768, 768, 6, seed=0)),
    ("powerlaw", lambda: suite.powerlaw(768, 768, 8.0, seed=1)),
    ("block", lambda: suite.block_dense(768, 768, 16, 0.05, seed=2)),
    ("uniform", lambda: suite.uniform(768, 768, 0.01, seed=3)),
    ("hypersparse", lambda: suite.uniform(768, 768, 0.001, seed=4)),
]


def main(out=print):
    rng = np.random.default_rng(5)
    wins = 0
    for name, gen in CASES:
        csr = gen()
        b = jnp.asarray(rng.standard_normal((csr.shape[1], N)), jnp.float32)

        def measure(x, y, _csr=csr, _b=b):
            """Warm-up measurement for the perf model: time the hybrid at
            the boundary implied by (x, y)."""
            from repro.core.partition import choose_r_boundary
            r = choose_r_boundary(_csr.nrows, 1.0, 4.0, max(x, 0),
                                  max(y, 0), br=8)
            fmt = loops_from_csr(_csr, r, 8)
            f = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))
            return 1.0 / time_fn(f, _b, repeats=3, warmup=1)

        model = calibrate(measure, total=4)
        fmt_ad, plan = plan_and_convert(csr, total_workers=4, model=model)
        fmt_v = loops_from_csr(csr, csr.nrows, 8)
        fmt_m = loops_from_csr(csr, 0, 8)

        ts = {}
        for tag, fmt in [("adaptive", fmt_ad), ("pure_vector", fmt_v),
                         ("pure_matrix", fmt_m)]:
            f = jax.jit(lambda bb, _f=fmt: loops_spmm(_f, bb, backend="jnp"))
            ts[tag] = time_fn(f, b, repeats=5)
        best = min(ts.values())
        won = ts["adaptive"] <= best * 1.05  # within 5% of best = win
        wins += won
        out(csv_row(f"sec43_{name}", ts["adaptive"] * 1e6,
                    f"vs_pure_vector={ts['pure_vector'] / ts['adaptive']:.2f}x;"
                    f"vs_pure_matrix={ts['pure_matrix'] / ts['adaptive']:.2f}x;"
                    f"r_b={fmt_ad.r_boundary}/{csr.nrows};win={int(won)}"))
    out(csv_row("sec43_summary", 0.0,
                f"adaptive_best_frac={wins / len(CASES):.2f} "
                f"(paper: 0.833 on full SuiteSparse)"))


if __name__ == "__main__":
    main()
