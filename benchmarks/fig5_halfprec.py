"""Paper Fig. 5: half-precision SpMM (paper: FP16 2-way fmopa; here: bf16 in
/ fp32 accumulate — the TPU-native equivalent).

Baselines:
  * block-only — pure vector-wise-BCSR execution (r_boundary = 0): the
    Magicube-style "everything through the matrix unit" strategy, which pays
    padding on irregular rows;
  * csr-only   — pure row-wise execution (the no-matrix-unit strategy).
LOOPS is the adaptive hybrid of the two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (csr_from_dense, csr_to_dense, loops_from_csr,
                        loops_spmm, plan_and_convert, suite)

from ._util import csv_row, gflops, time_fn

N = 32
MATRICES = ["m6", "m8", "m10", "m13", "m14", "m17"]


def main(out=print):
    rng = np.random.default_rng(1)
    sp_block, sp_csr = [], []
    for mid in MATRICES:
        csr32 = suite.table2_like(mid, scale_rows=1024, seed=4)
        dense16 = jnp.asarray(csr_to_dense(csr32), jnp.bfloat16)
        csr = csr_from_dense(np.asarray(dense16))
        nnz = csr.nnz
        b = jnp.asarray(rng.standard_normal((csr.shape[1], N)), jnp.bfloat16)

        from .fig4_throughput import calibrated_plan
        fmt, plan = calibrated_plan(csr, b)
        fmt_block = loops_from_csr(csr, 0, plan.br)       # pure BCSR
        fmt_csr = loops_from_csr(csr, csr.nrows, plan.br)  # pure CSR

        f_hybrid = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))
        f_block = jax.jit(lambda bb: loops_spmm(fmt_block, bb, backend="jnp"))
        f_csr = jax.jit(lambda bb: loops_spmm(fmt_csr, bb, backend="jnp"))

        t_h = time_fn(f_hybrid, b)
        t_b = time_fn(f_block, b)
        t_c = time_fn(f_csr, b)
        g = gflops(nnz, N, t_h)
        # Packed-path wall clock: the Pallas kernels (interpret mode off-TPU)
        # keep the bf16 B panels packed in scratch and accumulate in fp32 —
        # measured on the macro-fused depth-2 pipeline vs the serial layout.
        fmt_piped = loops_from_csr(csr, plan.r_boundary, plan.br,
                                   panel_g=plan.panel_g, macro_m=4,
                                   pipeline_depth=2)
        f_packed = jax.jit(lambda bb: loops_spmm(fmt, bb,
                                                 backend="interpret"))
        f_packed_piped = jax.jit(lambda bb: loops_spmm(fmt_piped, bb,
                                                       backend="interpret"))
        t_p = time_fn(f_packed, b, repeats=2, warmup=1)
        t_pp = time_fn(f_packed_piped, b, repeats=2, warmup=1)
        out(csv_row(f"fig5_bf16_{mid}_packed", t_p * 1e6,
                    f"packed_piped_us={t_pp * 1e6:.1f};"
                    f"pipeline_depth=2;macro_m=4;"
                    f"piped_speedup={t_p / max(t_pp, 1e-12):.2f}x"))
        # padding waste of the block-only format (zero fraction of tiles)
        tiles = fmt_block.bcsr_part.tile_vals
        waste = 1.0 - (np.count_nonzero(tiles) / max(tiles.size, 1))
        out(csv_row(f"fig5_bf16_{mid}_{suite.TABLE2_STATS[mid].name}",
                    t_h * 1e6,
                    f"GFLOPS={g:.2f};vs_blockonly={t_b / t_h:.2f}x;"
                    f"vs_csronly={t_c / t_h:.2f}x;block_pad_waste={waste:.2f}"))
        sp_block.append(t_b / t_h)
        sp_csr.append(t_c / t_h)
    out(csv_row("fig5_bf16_geomean", 0.0,
                f"vs_blockonly={np.exp(np.log(sp_block).mean()):.2f}x;"
                f"vs_csronly={np.exp(np.log(sp_csr).mean()):.2f}x"))


if __name__ == "__main__":
    main()
