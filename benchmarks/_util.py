"""Shared benchmark utilities."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# Hardware constants (TPU v5e target; used for roofline + modeled energy)
PEAK_FLOPS_BF16 = 197e12      # per chip
PEAK_FLOPS_F32 = 98.5e12      # MXU f32 ~ half of bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per chip, 1 link budget)
CHIP_POWER_W = 170.0          # v5e-ish board power
A100_POWER_W = 250.0          # paper Table 3 comparison point
M4PRO_POWER_W = 40.0          # paper's CPU TDP


def bench_rng(offset: int = 0) -> np.random.Generator:
    """Seeded RNG for benchmark inputs.

    Every suite draws its matrices/operands through this, so one
    ``REPRO_TEST_SEED`` env var re-seeds the whole benchmark sweep (the
    determinism test in tests/test_perf_trace.py runs a suite twice and
    asserts identical grid-step columns).  ``offset`` decorrelates multiple
    streams within one suite without decoupling them from the seed.
    """
    seed = int(os.environ.get("REPRO_TEST_SEED", "0"))
    return np.random.default_rng(seed + offset)


def time_fn(fn, *args, repeats: int = 20, warmup: int = 3) -> float:
    """Median wall seconds per call of a jitted fn."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def gflops(nnz: int, n: int, seconds: float) -> float:
    return 2.0 * nnz * n / seconds / 1e9


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
