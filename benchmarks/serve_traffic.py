"""Closed-loop serving load: continuous batching vs a no-batching baseline.

A fleet of closed-loop clients drives the ``repro.serve`` queue on a
reduced config: each client submits a request, waits for its completion,
thinks for a seeded-exponential interval, and submits the next — the
classic closed-loop load shape whose offered rate adapts to the server.
Request shapes (prompt length, generation budget) are drawn from a mixed
pool, so the shape-keyed coalescer actually has work to do.

Two clocks (docs/serving.md): the *scheduler* runs on a *virtual* clock —
one tick per engine action, arrivals/think-times in tick units — so batch
formation, admission and interleave decisions are a pure function of
``REPRO_TEST_SEED`` (the determinism test runs the suite twice and asserts
identical structural columns).  *Latency* is measured on the wall clock
around the real engine calls, so p50/p99 and goodput are real numbers even
though the schedule is simulated.

Modes, same seeded trace for both:

  * **batched**    — the continuous-batching path: shape-keyed groups up
                     to ``max_batch=8``, two groups in flight;
  * **sequential** — the no-batching baseline: ``max_batch=1``,
                     ``max_in_flight=1`` — every request pays its own
                     prefill and its own decode steps.

Each mode runs the trace twice through one shared ``ExecutorPool``: the
first pass pays every jit compile, the second is the timed one — so the
goodput comparison is steady-state, which is the regime continuous
batching is for.  Records land in bench.json (``serve_traffic`` schema)
for the perf gate; the suite asserts the batched path never issues more
engine calls than the baseline, and (when no fault plan is active) wins
goodput.

Standalone: ``PYTHONPATH=src python -m benchmarks.serve_traffic --smoke``
(add ``--max-queue-depth 1`` to exercise admission shedding — the chaos CI
step does, asserting rejections are counted while still exiting 0).
"""
from __future__ import annotations

import heapq
import os
import time

import numpy as np

from ._util import bench_rng, csv_row

# (prompt_len, gen_len) pool; weights via seeded draws.  Prompt lengths
# repeat across the pool on purpose — same-prompt-shape requests are what
# the coalescer can merge.
SHAPES = [(16, 8), (16, 4), (32, 8), (32, 16)]
SMOKE_SHAPES = [(8, 2), (8, 4), (16, 4)]
ARCH = "llama3.2-1b"
MEAN_THINK_TICKS = 3.0


def build_trace(rng, n_clients: int, rounds: int, shapes, vocab: int):
    """Per-client request list: (prompt tokens, gen_len, think_ticks).

    Round 0 arrives at tick 0 for every client (load-test ramp burst — the
    scheduler coalesces the burst by shape); later arrivals are closed-loop:
    completion + think.  Everything is drawn up front from the seeded rng,
    so the trace is identical across modes and runs.
    """
    trace = []
    for _ in range(n_clients):
        reqs = []
        for _ in range(rounds):
            p_len, g_len = shapes[rng.integers(len(shapes))]
            prompt = rng.integers(0, vocab, p_len).tolist()
            think = float(rng.exponential(MEAN_THINK_TICKS))
            reqs.append((prompt, int(g_len), think))
        trace.append(reqs)
    return trace


def run_traffic(cfg, mesh, params, trace, *, sched_cfg, pool, obs=None,
                seed: int = 0):
    """Drive one full closed-loop pass of ``trace`` through a fresh queue.

    Returns the stats dict for the pass.  The virtual clock advances one
    tick per engine action and jumps across idle gaps to the next arrival;
    wall time is measured around the whole pass.
    """
    from repro.serve.queue import ServeQueue

    queue = ServeQueue(cfg, mesh, params, config=sched_cfg, pool=pool,
                       obs=obs, temperature=0.0, seed=seed,
                       retry_kw={"retries": 2, "backoff_s": 0.01})
    # (arrival_tick, client, round) heap; client order breaks tick ties
    # deterministically.
    arrivals = [(0.0, c, 0) for c in range(len(trace))]
    heapq.heapify(arrivals)
    owner = {}           # rid -> (client, round)
    n_done_seen = 0
    vt = 0.0
    wall0 = time.perf_counter()
    while arrivals or queue.pending:
        while arrivals and arrivals[0][0] <= vt:
            _, c, k = heapq.heappop(arrivals)
            prompt, g_len, _think = trace[c][k]
            req = queue.submit(prompt, g_len, now=vt)
            owner[req.rid] = (c, k)
        progressed = queue.step(now=vt)
        if progressed:
            vt += 1.0
        # Closed loop: a finished request re-arms its client after think.
        for r in queue.completed[n_done_seen:]:
            c, k = owner[r.rid]
            if k + 1 < len(trace[c]):
                think = trace[c][k + 1][2]
                heapq.heappush(arrivals, (vt + think, c, k + 1))
        n_done_seen = len(queue.completed)
        if not progressed:
            if arrivals:
                vt = max(vt, arrivals[0][0])
            elif not queue.pending:
                break
    wall = time.perf_counter() - wall0

    done = queue.completed
    e2e = np.array([r.wall_e2e_s for r in done if r.wall_e2e_s is not None])
    ttft = np.array([r.wall_ttft_s for r in done
                     if r.wall_ttft_s is not None])
    ctr = queue.sched.counters
    tokens = sum(r.tokens_generated for r in done)
    n_requests = sum(len(reqs) for reqs in trace)
    return {
        "n_requests": n_requests,
        "completed": len(done),
        "rejected": ctr["rejected"],
        "evicted": ctr["evicted"],
        "prefill_batches": ctr["prefill_batches"],
        "decode_steps": ctr["decode_steps"],
        "engine_calls": ctr["prefill_batches"] + ctr["decode_steps"],
        "padded_slots": ctr["padded_slots"],
        "tokens": tokens,
        "goodput_tok_s": tokens / max(wall, 1e-9),
        "p50_ms": float(np.percentile(e2e, 50) * 1e3) if e2e.size else 0.0,
        "p99_ms": float(np.percentile(e2e, 99) * 1e3) if e2e.size else 0.0,
        "ttft_p50_ms": (float(np.percentile(ttft, 50) * 1e3)
                        if ttft.size else 0.0),
        "ttft_p99_ms": (float(np.percentile(ttft, 99) * 1e3)
                        if ttft.size else 0.0),
        "wall_s": wall,
    }


def main(out=print, record=None, smoke: bool = False,
         max_queue_depth: int = 64, n_clients: int = None,
         rounds: int = None):
    import jax

    from repro.configs import REDUCED
    from repro.launch.mesh import make_test_mesh
    from repro.models import api
    from repro.obs import get_active
    from repro.resilience.inject import install_from_env
    from repro.serve.queue import ExecutorPool
    from repro.serve.scheduler import SchedulerConfig

    # Chaos harness: honour REPRO_FAULT_PLAN (docs/robustness.md) — the
    # chaos CI smoke injects step faults and still expects exit 0 with
    # retries absorbed and rejections counted.
    install_from_env()

    shapes = SMOKE_SHAPES if smoke else SHAPES
    # The goodput assertion is a benchmark-scale claim: it holds for the
    # canonical loads, but a custom-shrunk run (the determinism test uses
    # two clients, one round) can be too small for the batching win to
    # clear wall-clock noise — such runs keep the structural assert only.
    canonical_load = n_clients is None and rounds is None
    n_clients = n_clients or (4 if smoke else 6)
    rounds = rounds or (2 if smoke else 3)
    seed = int(os.environ.get("REPRO_TEST_SEED", "0"))

    cfg = REDUCED[ARCH]()
    mesh = make_test_mesh(1, 1)
    params = api.init_params(cfg, jax.random.key(seed))
    obs = get_active()
    pool = ExecutorPool(cfg, mesh, params, obs=obs)
    trace = build_trace(bench_rng(), n_clients, rounds, shapes,
                        cfg.vocab_size)

    modes = {
        "batched": SchedulerConfig(max_queue_depth=max_queue_depth,
                                   max_in_flight=2, max_batch=8,
                                   min_batch=1, max_wait_s=2.0),
        "sequential": SchedulerConfig(max_queue_depth=max_queue_depth,
                                      max_in_flight=1, max_batch=1,
                                      min_batch=1, max_wait_s=0.0),
    }
    results = {}
    for mode, sched_cfg in modes.items():
        # pass 1 pays the jit compiles; pass 2 is the timed steady state
        run_traffic(cfg, mesh, params, trace, sched_cfg=sched_cfg,
                    pool=pool, obs=None, seed=seed)
        res = run_traffic(cfg, mesh, params, trace, sched_cfg=sched_cfg,
                          pool=pool, obs=obs, seed=seed)
        results[mode] = res
        out(csv_row(
            f"serve_traffic_{mode}", res["p50_ms"] * 1e3,
            f"goodput_tok_s={res['goodput_tok_s']:.1f};"
            f"p99_ms={res['p99_ms']:.1f};"
            f"ttft_p50_ms={res['ttft_p50_ms']:.1f};"
            f"engine_calls={res['engine_calls']};"
            f"completed={res['completed']}/{res['n_requests']};"
            f"rejected={res['rejected']}"))
        if record is not None:
            record({"suite": "serve_traffic", "matrix": mode, **res})

    b, s = results["batched"], results["sequential"]
    # Structural win: coalescing can only merge engine calls, never add
    # them (group decode steps = max over members <= sum over members).
    assert b["engine_calls"] <= s["engine_calls"], \
        (f"batched path issued MORE engine calls than the no-batching "
         f"baseline: {b['engine_calls']} vs {s['engine_calls']}")
    # Goodput win: steady-state batched throughput must beat one-at-a-time.
    # Skipped under an active fault plan (retries distort wall time) or
    # when admission shed requests (the chaos smoke runs with a tiny queue
    # depth precisely to exercise that path).
    chaotic = bool(os.environ.get("REPRO_FAULT_PLAN")) \
        or b["rejected"] or s["rejected"]
    if not chaotic and canonical_load:
        assert b["goodput_tok_s"] >= s["goodput_tok_s"], \
            (f"continuous batching lost goodput to the no-batching "
             f"baseline: {b['goodput_tok_s']:.1f} vs "
             f"{s['goodput_tok_s']:.1f} tok/s")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--max-queue-depth", type=int, default=64,
                    help="admission depth for BOTH modes; small values "
                         "shed the arrival burst (counted rejections)")
    ap.add_argument("--obs", nargs="?", const="serve_traffic", default=None,
                    metavar="STEM", help="capture the run with repro.obs")
    ap.add_argument("--obs-dir", default=None)
    args = ap.parse_args()
    obs = None
    if args.obs:
        from repro.obs import Obs, set_active
        obs = Obs(source=args.obs)
        set_active(obs)
    records = []
    try:
        main(smoke=args.smoke, max_queue_depth=args.max_queue_depth,
             record=records.append)
    finally:
        if obs is not None:
            from repro.obs import set_active
            jsonl, chrome = obs.save(args.obs_dir, stem=args.obs)
            print(f"obs: {jsonl}")
            print(f"obs: {chrome}")
            set_active(None)
    print(f"records: {len(records)}")
