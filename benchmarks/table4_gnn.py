"""Paper §4.5 / Table 4: end-to-end GCN training with the LOOPS aggregation
operator vs the dense-adjacency and CSR-baseline aggregations.

A 2-layer GCN on a synthetic graph: hat(A) @ relu(hat(A) @ X W0) W1.
Reports per-epoch time, speedups, accuracy parity (loss trajectories must
match to fp tolerance — same math, different operator), and the
preprocessing (format conversion) share, which the paper amortises (1.3%).

Since the custom VJP, a ``train_step_us`` column also times the full
fwd+bwd step through the *real* kernel path (interpret mode off-TPU): the
forward panel kernels plus the transposed-format backward — the number that
was impossible while training required the jnp fallback.  Its gradient is
parity-checked against the dense-adjacency reference on the way."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csr_to_dense, loops_spmm, plan_and_convert, \
    spmm_csr_baseline, suite
from repro.kernels import ops as kernel_ops

from ._util import csv_row, time_fn

GRAPHS = [("reddit-like", 2048, 24), ("amazon-like", 1024, 8),
          ("yelp-like", 1536, 16)]
# The fwd+bwd column runs the sequential interpret oracle off-TPU, so it
# times a scaled-down replica of each graph (same degree statistics).
TRAIN_STEP_NODES = 256
F_IN, F_HID, F_OUT = 32, 32, 8


def _gcn_loss(agg_fn, x, w0, w1, y):
    h = jax.nn.relu(agg_fn(x @ w0))
    logits = agg_fn(h @ w1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def main(out=print):
    rng = np.random.default_rng(0)
    for name, n_nodes, deg in GRAPHS:
        t0 = time.perf_counter()
        adj = suite.gcn_graph(n_nodes, deg, seed=1)
        import jax.numpy as _jnp
        probe = _jnp.zeros((n_nodes, F_HID), _jnp.float32)
        from .fig4_throughput import calibrated_plan
        fmt, _ = calibrated_plan(adj, probe)
        t_prep = time.perf_counter() - t0

        dense_adj = jnp.asarray(csr_to_dense(adj))
        x = jnp.asarray(rng.standard_normal((n_nodes, F_IN)), jnp.float32)
        w0 = jnp.asarray(rng.standard_normal((F_IN, F_HID)) * 0.1,
                         jnp.float32)
        w1 = jnp.asarray(rng.standard_normal((F_HID, F_OUT)) * 0.1,
                         jnp.float32)
        y = jnp.asarray(rng.integers(0, F_OUT, n_nodes), jnp.int32)

        agg_loops = lambda h: loops_spmm(fmt, h, backend="jnp")
        agg_dense = lambda h: dense_adj @ h
        agg_csr = lambda h: spmm_csr_baseline(adj, h)

        grads = {}
        times = {}
        for tag, agg in [("loops", agg_loops), ("dense", agg_dense),
                         ("csr", agg_csr)]:
            step = jax.jit(jax.value_and_grad(
                lambda w0_, w1_, _agg=agg: _gcn_loss(_agg, x, w0_, w1_, y),
                argnums=(0, 1)))
            times[tag] = time_fn(step, w0, w1, repeats=5)
            grads[tag] = step(w0, w1)
        # accuracy parity: identical losses/grads across operators
        l_loops = float(grads["loops"][0])
        l_dense = float(grads["dense"][0])
        assert abs(l_loops - l_dense) < 1e-3, (l_loops, l_dense)

        # fwd+bwd train step through the REAL kernel path (custom VJP):
        # scaled-down replica, interpret backend off-TPU
        nodes_t = min(TRAIN_STEP_NODES, n_nodes)
        adj_t = suite.gcn_graph(nodes_t, min(deg, nodes_t // 4 or 1), seed=1)
        fmt_t, _ = plan_and_convert(adj_t, total_workers=8)
        backend = kernel_ops.default_backend()
        x_t = jnp.asarray(rng.standard_normal((nodes_t, F_IN)), jnp.float32)
        y_t = jnp.asarray(rng.integers(0, F_OUT, nodes_t), jnp.int32)
        dense_t = jnp.asarray(csr_to_dense(adj_t))
        agg_real = lambda h: loops_spmm(fmt_t, h, backend=backend)
        step_real = jax.jit(jax.value_and_grad(
            lambda w0_, w1_: _gcn_loss(agg_real, x_t, w0_, w1_, y_t),
            argnums=(0, 1)))
        step_ref = jax.jit(jax.value_and_grad(
            lambda w0_, w1_: _gcn_loss(lambda h: dense_t @ h, x_t, w0_, w1_,
                                       y_t), argnums=(0, 1)))
        t_train = time_fn(step_real, w0, w1, repeats=3)
        g_real, g_ref = step_real(w0, w1)[1], step_ref(w0, w1)[1]
        gerr = max(float(jnp.abs(a - b).max())
                   for a, b in zip(jax.tree.leaves(g_real),
                                   jax.tree.leaves(g_ref)))
        assert gerr <= 1e-4, f"custom-VJP grads off by {gerr:.2e}"

        epochs_to_amortize = t_prep / max(times["loops"], 1e-9)
        out(csv_row(f"table4_{name}", times["loops"] * 1e6,
                    f"vs_dense={times['dense'] / times['loops']:.2f}x;"
                    f"vs_csr={times['csr'] / times['loops']:.2f}x;"
                    f"loss_parity={abs(l_loops - l_dense):.1e};"
                    f"train_step_us={t_train * 1e6:.0f};"
                    f"train_step_backend={backend};"
                    f"train_grad_err={gerr:.1e};"
                    f"prep_amortized_over_epochs={epochs_to_amortize:.0f}"))


if __name__ == "__main__":
    main()
