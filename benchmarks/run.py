"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and mirrors them to
benchmarks/results/bench.csv).  Suites that emit structured records (fig4's
panelization columns, the batched engine suite) also land in
benchmarks/results/bench.json — the machine-readable perf trajectory
(``panel_g``, grid-step reductions, wall-clock) that CI diffs against a
committed baseline via tools/perf_gate.py.

  fig4   — FP64/FP32 SpMM throughput vs TACO-like / Armadillo-like (Fig. 4)
           + the G=1 vs tuned-G panelization columns
  fig5   — bf16(=FP16) SpMM vs block-only / csr-only strategies (Fig. 5)
  sec43  — adaptive scheduling ablation (§4.3)
  table3 — modeled energy efficiency (Table 3)
  table4 — end-to-end GCN training (§4.5 / Table 4)
  roofline — §Roofline terms for every dry-run cell (assignment)
  autotune — model-only vs measured/cached plans + cache hit rates
  batched  — multi-RHS engine: per-element loop vs vmap-unrolled vs
             native batched (fwd and fwd+bwd, grid-step columns)
  spmm_dryrun    — production-mesh distributed SpMM cell; skip-records
                   unless a 256-device platform is live (standalone CLI
                   forces one: ``python -m benchmarks.spmm_dryrun``)
  compress_bytes — int8/bf16 compressed-psum collective bytes; skip-records
                   unless 16 devices are live (standalone CLI forces them)
  serve_traffic  — closed-loop serving load through the continuous-batching
                   queue: p50/p99 latency + goodput, batched vs no-batching

``--smoke`` shrinks the suites that support it (tiny matrices, fewer
repeats) for CI: kernel-layer regressions then surface as benchmark
failures, not only as test failures.  In smoke mode fig4 plans
deterministically (no wall-clock calibration), so the grid-step columns
are a pure function of the seeded matrices — the property the perf gate's
exact checks rely on.

Perf-gate flags: ``--baseline F`` runs tools/perf_gate.py against F after
the suites finish (non-zero exit on regression); ``--update-baseline``
copies the freshly merged bench.json over F instead (refreshing the
committed BENCH_<PR>.json after an intentional change).  ``--trace``
records a perf trace (engine dispatches + per-matrix SpMM wall-clock) to
benchmarks/results/traces/<source>.jsonl for replay/cost-model fitting.
``--obs-trace`` additionally captures the run with ``repro.obs`` (per-suite
spans + engine dispatch counters) to benchmarks/results/obs/ in the same
JSONL schema live ``--obs`` runs use, so ``tools/obs_report.py`` renders
benchmark and serving captures interchangeably.
"""
from __future__ import annotations

import argparse
import contextlib
import inspect
import json
import os
import shutil
import sys
import traceback

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "results",
                                "BENCH_010.json")


def _suite_registry():
    from . import (autotune_suite, batched_spmm, compress_bytes,
                   fig4_throughput, fig5_halfprec, roofline, sec43_scheduling,
                   serve_traffic, spmm_dryrun, table3_energy, table4_gnn)
    return {
        "fig4": fig4_throughput.main,
        "fig5": fig5_halfprec.main,
        "sec43": sec43_scheduling.main,
        "table3": table3_energy.main,
        "table4": table4_gnn.main,
        "roofline": roofline.main,
        "autotune": autotune_suite.main,
        "batched": batched_spmm.main,
        "spmm_dryrun": spmm_dryrun.bench_main,
        "compress_bytes": compress_bytes.main,
        "serve_traffic": serve_traffic.main,
    }


# Keep --only's help in sync with the registry without importing the suite
# modules (and therefore jax) just to print --help.
SUITE_NAMES = ["fig4", "fig5", "sec43", "table3", "table4", "roofline",
               "autotune", "batched", "spmm_dryrun", "compress_bytes",
               "serve_traffic"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of suites: " + ",".join(SUITE_NAMES))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-suite CI mode (suites that support it)")
    ap.add_argument("--trace", action="store_true",
                    help="record a perf trace (engine dispatch + SpMM "
                         "wall-clock) to benchmarks/results/traces/")
    ap.add_argument("--obs-trace", action="store_true",
                    help="capture the run with repro.obs (per-suite spans, "
                         "engine dispatch counters) to "
                         "benchmarks/results/obs/ — same JSONL schema as "
                         "live-run --obs captures, so obs_report.py and "
                         "diff tooling treat them interchangeably")
    ap.add_argument("--baseline", nargs="?", const=DEFAULT_BASELINE,
                    default=None, metavar="F",
                    help="after the run, gate the merged bench.json against "
                         f"this baseline (default {DEFAULT_BASELINE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="copy the merged bench.json over the baseline file "
                         "instead of gating against it")
    args = ap.parse_args()

    suites = _suite_registry()
    assert sorted(suites) == sorted(SUITE_NAMES), \
        "suite registry drifted from SUITE_NAMES — update both"
    chosen = (args.only.split(",") if args.only else list(suites))
    unknown = [n for n in chosen if n not in suites]
    if unknown:
        ap.error(f"unknown suite(s) {unknown}; choose from "
                 + ",".join(SUITE_NAMES))

    results_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(results_dir, exist_ok=True)
    rows: list[str] = []
    records: list[dict] = []

    recorder = None
    if args.trace:
        from repro.perf.trace import TraceRecorder
        recorder = TraceRecorder(source="bench-" + "-".join(chosen))
    obs = None
    if args.obs_trace:
        from repro.obs import Obs, set_active
        obs = Obs(source="bench-" + "-".join(chosen))
        set_active(obs)

    def emit(line: str):
        print(line, flush=True)
        rows.append(line)

    emit("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        fn = suites[name]
        kwargs = {}
        params = inspect.signature(fn).parameters
        if "smoke" in params:
            kwargs["smoke"] = args.smoke
        if "record" in params:
            kwargs["record"] = records.append
        if recorder is not None and "recorder" in params:
            kwargs["recorder"] = recorder
        try:
            with contextlib.ExitStack() as stack:
                if recorder is not None:
                    stack.enter_context(recorder.attach_engine())
                if obs is not None:
                    # obs chains onto the recorder's tracer, so --trace and
                    # --obs-trace compose (both see every dispatch)
                    stack.enter_context(obs.attach_engine())
                    stack.enter_context(obs.span(f"suite.{name}",
                                                 cat="bench"))
                fn(out=emit, **kwargs)
        except Exception:
            failures += 1
            emit(f"{name}_FAILED,0,{traceback.format_exc(limit=1).strip()}")
    with open(os.path.join(results_dir, "bench.csv"), "w") as f:
        f.write("\n".join(rows) + "\n")
    # bench.json merges per suite: records of the suites run THIS invocation
    # are replaced (so a re-run can never leave stale numbers), records of
    # suites not selected by --only survive.
    json_path = os.path.join(results_dir, "bench.json")
    try:
        with open(json_path) as f:
            kept = [r for r in json.load(f)
                    if not any(str(r.get("suite", "")).startswith(name)
                               for name in chosen)]
    except (OSError, ValueError):
        kept = []
    with open(json_path, "w") as f:
        json.dump(kept + records, f, indent=1, sort_keys=True)
    if recorder is not None and recorder.records:
        print(f"trace: {recorder.save()}", flush=True)
    if obs is not None:
        from repro.obs import set_active
        jsonl, chrome = obs.save()
        print(f"obs: {jsonl}", flush=True)
        print(f"obs: {chrome}", flush=True)
        set_active(None)
    if failures:
        sys.exit(1)

    if args.update_baseline:
        target = args.baseline or DEFAULT_BASELINE
        shutil.copyfile(json_path, target)
        print(f"baseline updated: {target}", flush=True)
    elif args.baseline:
        tools_dir = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "tools")
        sys.path.insert(0, tools_dir)
        import perf_gate
        sys.exit(perf_gate.main(["--baseline", args.baseline,
                                 "--current", json_path]))


if __name__ == "__main__":
    main()
