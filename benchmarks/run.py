"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and mirrors them to
benchmarks/results/bench.csv).  Suites that emit structured records (fig4's
panelization columns) also land in benchmarks/results/bench.json — the
machine-readable perf trajectory (``panel_g``, grid-step reductions,
wall-clock) that CI diffs against.

  fig4   — FP64/FP32 SpMM throughput vs TACO-like / Armadillo-like (Fig. 4)
           + the G=1 vs tuned-G panelization columns
  fig5   — bf16(=FP16) SpMM vs block-only / csr-only strategies (Fig. 5)
  sec43  — adaptive scheduling ablation (§4.3)
  table3 — modeled energy efficiency (Table 3)
  table4 — end-to-end GCN training (§4.5 / Table 4)
  roofline — §Roofline terms for every dry-run cell (assignment)
  autotune — model-only vs measured/cached plans + cache hit rates
  batched  — multi-RHS engine: per-element loop vs vmap-unrolled vs
             native batched (fwd and fwd+bwd, grid-step columns)

``--smoke`` shrinks the suites that support it (tiny matrices, fewer
repeats) for CI: kernel-layer regressions then surface as benchmark
failures, not only as test failures.
"""
from __future__ import annotations

import argparse
import inspect
import json
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig5,sec43,table3,table4,"
                         "roofline,autotune,batched")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-suite CI mode (suites that support it)")
    args = ap.parse_args()

    from . import (autotune_suite, batched_spmm, fig4_throughput,
                   fig5_halfprec, roofline, sec43_scheduling, table3_energy,
                   table4_gnn)
    suites = {
        "fig4": fig4_throughput.main,
        "fig5": fig5_halfprec.main,
        "sec43": sec43_scheduling.main,
        "table3": table3_energy.main,
        "table4": table4_gnn.main,
        "roofline": roofline.main,
        "autotune": autotune_suite.main,
        "batched": batched_spmm.main,
    }
    chosen = (args.only.split(",") if args.only else list(suites))

    results_dir = os.path.join(os.path.dirname(__file__), "results")
    os.makedirs(results_dir, exist_ok=True)
    rows: list[str] = []
    records: list[dict] = []

    def emit(line: str):
        print(line, flush=True)
        rows.append(line)

    emit("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        fn = suites[name]
        kwargs = {}
        params = inspect.signature(fn).parameters
        if "smoke" in params:
            kwargs["smoke"] = args.smoke
        if "record" in params:
            kwargs["record"] = records.append
        try:
            fn(out=emit, **kwargs)
        except Exception:
            failures += 1
            emit(f"{name}_FAILED,0,{traceback.format_exc(limit=1).strip()}")
    with open(os.path.join(results_dir, "bench.csv"), "w") as f:
        f.write("\n".join(rows) + "\n")
    # bench.json merges per suite: records of the suites run THIS invocation
    # are replaced (so a re-run can never leave stale numbers), records of
    # suites not selected by --only survive.
    json_path = os.path.join(results_dir, "bench.json")
    try:
        with open(json_path) as f:
            kept = [r for r in json.load(f)
                    if not any(str(r.get("suite", "")).startswith(name)
                               for name in chosen)]
    except (OSError, ValueError):
        kept = []
    with open(json_path, "w") as f:
        json.dump(kept + records, f, indent=1, sort_keys=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
