"""Benchmark orchestrator — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (and mirrors them to
benchmarks/results/bench.csv).

  fig4   — FP64/FP32 SpMM throughput vs TACO-like / Armadillo-like (Fig. 4)
  fig5   — bf16(=FP16) SpMM vs block-only / csr-only strategies (Fig. 5)
  sec43  — adaptive scheduling ablation (§4.3)
  table3 — modeled energy efficiency (Table 3)
  table4 — end-to-end GCN training (§4.5 / Table 4)
  roofline — §Roofline terms for every dry-run cell (assignment)
  autotune — model-only vs measured/cached plans + cache hit rates
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig5,sec43,table3,table4,"
                         "roofline,autotune")
    args = ap.parse_args()

    from . import (autotune_suite, fig4_throughput, fig5_halfprec, roofline,
                   sec43_scheduling, table3_energy, table4_gnn)
    suites = {
        "fig4": fig4_throughput.main,
        "fig5": fig5_halfprec.main,
        "sec43": sec43_scheduling.main,
        "table3": table3_energy.main,
        "table4": table4_gnn.main,
        "roofline": roofline.main,
        "autotune": autotune_suite.main,
    }
    chosen = (args.only.split(",") if args.only else list(suites))

    out_path = os.path.join(os.path.dirname(__file__), "results", "bench.csv")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    rows = []

    def emit(line: str):
        print(line, flush=True)
        rows.append(line)

    emit("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        try:
            suites[name](out=emit)
        except Exception:
            failures += 1
            emit(f"{name}_FAILED,0,{traceback.format_exc(limit=1).strip()}")
    with open(out_path, "w") as f:
        f.write("\n".join(rows) + "\n")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
