"""Paper Fig. 4: SpMM throughput (GFLOPS), FP64 and FP32, LOOPS vs CPU
baselines across the Table-2-like suite.

Baselines (implemented, per assignment scope):
  * taco-like   — row-wise CSR schedule in pure XLA (segment-sum), the
                  schedule TACO emits for CSR SpMM;
  * armadillo-like — dense GEMM on the densified operand (Armadillo stores
                  sparse, but its SpMM lowers to generic kernels; the dense
                  GEMM is the upper-bound-friendly stand-in).

Container caveat (recorded in EXPERIMENTS.md): wall-clock numbers are
CPU-XLA proxies — this machine has ONE homogeneous engine, so the paper's
heterogeneous-engine speedup mechanism cannot appear in wall-clock; what IS
reproducible here is the *adaptive scheduling* claim: the calibrated perf
model (Eq. 2) discovers the machine's best split per matrix (on CPU that is
usually CSR-heavy; on the TPU target the roofline terms in §Roofline carry
the perf claim).  The Pallas kernels are TPU-targeted and validated in
interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (csr_to_dense, loops_from_csr, loops_grid_steps,
                        loops_spmm, plan_and_convert, spmm_csr_baseline,
                        spmm_dense_baseline, suite)
from repro.core.partition import choose_r_boundary
from repro.core.perf_model import calibrate

from ._util import bench_rng, csv_row, gflops, time_fn

N = 32  # paper fixes N=32
MATRICES = ["m6", "m8", "m9", "m10", "m12", "m13", "m14", "m16", "m17", "m19"]
SMOKE_MATRICES = ["m6", "m12", "m13"]
G_CHOICES = (4, 8)         # tuned-G candidates (G=1 is the baseline column)
WALL_MATRICES = 3          # matrices that also get interpret wall-clock
PIPE_DEPTH = 2             # piped column: double-buffered B-panel prefetch
MACRO_M = 4                # piped column: same-row panels fused per step


def calibrated_plan(csr, b, total: int = 4, deterministic: bool = False):
    """Paper §3.5: fit Eq. 2 from warm-up runs of candidate splits, then
    argmax (Eq. 3) -> boundary (Eq. 1).

    ``deterministic`` skips the wall-clock calibration and plans from the
    proportional prior alone — smoke mode uses it so the recorded plan (and
    with it every grid-step column the perf gate diffs exactly) is a pure
    function of the seeded matrix, not of machine timing noise.
    """
    if deterministic:
        return plan_and_convert(csr, total_workers=total)

    def measure(x, y):
        r = choose_r_boundary(csr.nrows, 1.0, 4.0, max(x, 0), max(y, 0),
                              br=8)
        fmt = loops_from_csr(csr, r, 8)
        f = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))
        return 1.0 / time_fn(f, b, repeats=2, warmup=1)

    model = calibrate(measure, total=total)
    return plan_and_convert(csr, total_workers=total, model=model)


def panel_comparison(csr, plan, b, *, mid: str, name_dt: str, out,
                     record=None, wall_clock: bool, smoke: bool):
    """G=1 vs tuned-G vs piped column: grid-step cost proxy for every
    matrix, plus interpret-mode (Pallas) wall-clock on a subset — the
    panelization and pipeline speedups tracked in the perf trajectory
    (benchmark JSON).

    Two pipelined columns ride on the tuned-G conversion:

      * *fused*  — macro-step fusion alone (``macro_m=MACRO_M``, depth 1):
        ~MACRO_M× fewer grid steps, and the wall-clock win interpret mode
        can actually observe (fewer sequential grid dispatches);
      * *piped*  — the same fusion under the double-buffered pipeline
        (``pipeline_depth=PIPE_DEPTH``).  Interpret mode executes grid
        steps serially, so the prefetch overlap the second buffer buys on
        hardware shows up here only as scratch-staging overhead — the
        column is correctness-gated instead: depth-2 at the same conversion
        must be *bitwise* equal to the depth-1 baseline (unbatched parity
        contract of the piped kernels), and the macro-fused results must
        agree to tolerance."""
    fmts = {g: loops_from_csr(csr, plan.r_boundary, plan.br, panel_g=g)
            for g in (1,) + tuple(G_CHOICES)}
    steps = {g: loops_grid_steps(f, N) for g, f in fmts.items()}
    tuned_g = min(G_CHOICES, key=lambda g: steps[g])
    g_ref = max(G_CHOICES)   # the reduction the acceptance tracking pins
    red_tuned = steps[1] / max(steps[tuned_g], 1)
    red_ref = steps[1] / max(steps[g_ref], 1)

    fmt_fused = loops_from_csr(csr, plan.r_boundary, plan.br,
                               panel_g=tuned_g, macro_m=MACRO_M)
    fmt_piped = loops_from_csr(csr, plan.r_boundary, plan.br,
                               panel_g=tuned_g, macro_m=MACRO_M,
                               pipeline_depth=PIPE_DEPTH)
    steps_fused = loops_grid_steps(fmt_fused, N)
    steps_piped = loops_grid_steps(fmt_piped, N)

    wall = {}
    if wall_clock:
        repeats, warmup = (2, 1) if smoke else (3, 1)
        for g in (1, tuned_g):
            f = jax.jit(lambda bb, fg=fmts[g]: loops_spmm(
                fg, bb, backend="interpret"))
            wall[g] = time_fn(f, b, repeats=repeats, warmup=warmup)
        for key, fmt_k in (("fused", fmt_fused), ("piped", fmt_piped)):
            f = jax.jit(lambda bb, fk=fmt_k: loops_spmm(
                fk, bb, backend="interpret"))
            wall[key] = time_fn(f, b, repeats=repeats, warmup=warmup)
        # Correctness gates for the pipelined columns.
        fmt_d2 = loops_from_csr(csr, plan.r_boundary, plan.br,
                                panel_g=tuned_g, pipeline_depth=PIPE_DEPTH)
        y_base = np.asarray(loops_spmm(fmts[tuned_g], b,
                                       backend="interpret"))
        y_d2 = np.asarray(loops_spmm(fmt_d2, b, backend="interpret"))
        np.testing.assert_array_equal(y_d2, y_base)   # bitwise, unbatched
        tol = 1e-10 if name_dt == "fp64" else 1e-4
        for fmt_k in (fmt_fused, fmt_piped):
            np.testing.assert_allclose(
                np.asarray(loops_spmm(fmt_k, b, backend="interpret")),
                y_base, rtol=tol, atol=tol)

    wall_note = (f";wall_g1_us={wall[1] * 1e6:.1f}"
                 f";wall_tuned_us={wall[tuned_g] * 1e6:.1f}"
                 f";wall_fused_us={wall['fused'] * 1e6:.1f}"
                 f";wall_piped_us={wall['piped'] * 1e6:.1f}"
                 f";wall_speedup={wall[1] / wall[tuned_g]:.2f}x"
                 f";wall_speedup_fused="
                 f"{wall[tuned_g] / wall['fused']:.2f}x"
                 if wall else "")
    out(csv_row(f"fig4_{name_dt}_{mid}_panelG", steps[tuned_g],
                f"panel_g={tuned_g};steps_g1={steps[1]};"
                f"steps_tuned={steps[tuned_g]};steps_fused={steps_fused};"
                f"steps_piped={steps_piped};"
                f"pipeline_depth={PIPE_DEPTH};macro_m={MACRO_M};"
                f"step_reduction="
                f"{red_tuned:.2f}x;step_reduction_g{g_ref}={red_ref:.2f}x"
                + wall_note))
    if record is not None:
        record({
            "suite": "fig4_panel", "matrix": mid, "dtype": name_dt,
            "panel_g": tuned_g,
            "pipeline_depth": PIPE_DEPTH, "macro_m": MACRO_M,
            "steps_g1": steps[1], f"steps_g{g_ref}": steps[g_ref],
            "steps_tuned": steps[tuned_g],
            "steps_fused": steps_fused,
            "steps_piped": steps_piped,
            "step_reduction_tuned": red_tuned,
            f"step_reduction_g{g_ref}": red_ref,
            "step_reduction_piped": steps[1] / max(steps_piped, 1),
            "wall_us_g1": wall.get(1, 0.0) * 1e6,
            "wall_us_tuned": wall.get(tuned_g, 0.0) * 1e6,
            "wall_us_fused": wall.get("fused", 0.0) * 1e6,
            "wall_us_piped": wall.get("piped", 0.0) * 1e6,
        })
    return red_ref


def run(dtype=np.float32, scale_rows: int = 1024, out=print, record=None,
        smoke: bool = False, recorder=None):
    name_dt = {np.float32: "fp32", np.float64: "fp64"}[dtype]
    if dtype == np.float64:
        jax.config.update("jax_enable_x64", True)
    try:
        rng = bench_rng()
        matrices = SMOKE_MATRICES if smoke else MATRICES
        rows, g8_reds = [], []
        for i, mid in enumerate(matrices):
            csr = suite.table2_like(mid, scale_rows=scale_rows, seed=3,
                                    dtype=dtype)
            nnz = csr.nnz
            b = jnp.asarray(rng.standard_normal((csr.shape[1], N)), dtype)
            fmt, plan = calibrated_plan(csr, b, deterministic=smoke)
            dense = jnp.asarray(csr_to_dense(csr))

            f_loops = jax.jit(lambda bb: loops_spmm(fmt, bb, backend="jnp"))
            f_taco = jax.jit(lambda bb: spmm_csr_baseline(csr, bb))
            f_arma = jax.jit(lambda bb: spmm_dense_baseline(dense, bb))

            t_loops = time_fn(f_loops, b)
            t_taco = time_fn(f_taco, b)
            t_arma = time_fn(f_arma, b)
            g = gflops(nnz, N, t_loops)
            out(csv_row(f"fig4_{name_dt}_{mid}_{suite.TABLE2_STATS[mid].name}",
                        t_loops * 1e6,
                        f"GFLOPS={g:.2f};vs_taco={t_taco / t_loops:.2f}x;"
                        f"vs_dense={t_arma / t_loops:.2f}x"))
            rows.append((t_taco / t_loops, t_arma / t_loops))
            if record is not None:
                record({"suite": "fig4", "matrix": mid, "dtype": name_dt,
                        "panel_g": plan.panel_g, "nnz": nnz,
                        "pipeline_depth": getattr(plan, "pipeline_depth", 1),
                        "macro_m": getattr(plan, "macro_m", 1),
                        "us_per_call": t_loops * 1e6, "gflops": g,
                        "vs_taco": t_taco / t_loops,
                        "vs_dense": t_arma / t_loops})
            if recorder is not None:
                recorder.record_spmm(csr, plan, wall_s=t_loops, n_cols=N,
                                     backend="jnp", gflops=g)
            g8_reds.append(panel_comparison(
                csr, plan, b, mid=mid, name_dt=name_dt, out=out,
                record=record, wall_clock=(i < WALL_MATRICES), smoke=smoke))
        sp = np.array(rows)
        g_ref = max(G_CHOICES)
        ref_geo = float(np.exp(np.log(np.maximum(g8_reds, 1e-9)).mean()))
        out(csv_row(f"fig4_{name_dt}_geomean", 0.0,
                    f"speedup_vs_taco={np.exp(np.log(sp[:, 0]).mean()):.2f}x;"
                    f"speedup_vs_dense={np.exp(np.log(sp[:, 1]).mean()):.2f}x;"
                    f"step_reduction_g{g_ref}={ref_geo:.2f}x"))
        if record is not None:
            record({"suite": "fig4_panel_geomean", "matrix": "geomean",
                    "dtype": name_dt,
                    f"step_reduction_g{g_ref}": ref_geo})
    finally:
        if dtype == np.float64:
            jax.config.update("jax_enable_x64", False)


def main(out=print, record=None, smoke: bool = False, recorder=None):
    scale = 192 if smoke else 1024
    run(np.float32, scale_rows=scale, out=out, record=record, smoke=smoke,
        recorder=recorder)
    if not smoke:
        run(np.float64, out=out, record=record, recorder=recorder)


if __name__ == "__main__":
    main()
